//! The end-to-end training driver (EXPERIMENTS.md §E2E): train the original
//! mini ResNet from scratch on synthetic data, one-shot decompose the
//! trained weights, then fine-tune with FULL updates (lrd) vs LAYER
//! FREEZING (§2.2) and compare loss curves, wall-clock and accuracy.
//!
//! ```sh
//! make artifacts && cargo run --release --example finetune_freeze -- \
//!     [--train-steps 250] [--finetune-steps 120]
//! ```

use anyhow::{anyhow, Result};
use lrdx::decompose::params::decompose_params;
use lrdx::model::Arch;
use lrdx::runtime::artifacts::{ArtifactLibrary, ForwardModel, TrainSession};
use lrdx::runtime::Engine;
use lrdx::trainsim::{data::SynthData, evaluate, run_training};
use lrdx::util::cli::Args;
use lrdx::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let train_steps = args.usize_or("train-steps", 250)?;
    let ft_steps = args.usize_or("finetune-steps", 200)?;
    let root = args.get_or("artifacts", "artifacts").to_string();

    let engine = Engine::cpu()?;
    let lib = ArtifactLibrary::load(&root)?;
    let arch = Arch::by_name("resnet-mini").unwrap();
    let gen = SynthData::new(32, arch.classes);
    let mut rng = Rng::new(2024_0731);

    // ---- phase 1: train the ORIGINAL from scratch ----
    println!("phase 1: training resnet-mini/orig from scratch ({train_steps} steps)");
    let orig_train = lib
        .find_by("resnet-mini", "orig", "train")
        .ok_or_else(|| anyhow!("run `make artifacts`"))?;
    let mut sess = TrainSession::load(&engine, orig_train)?;
    let (curve, secs, acc) = run_training(&mut sess, &gen, &mut rng, train_steps, 25)?;
    for (s, l) in &curve {
        println!("  step {s:>4}  loss {l:.4}");
    }
    let trained = sess.export_params()?;
    let ospec = lib.find_by("resnet-mini", "orig", "forward").unwrap();
    let ofwd = ForwardModel::load_with_params(&engine, ospec, &trained)?;
    let mut er = Rng::new(0xE7A1);
    let oacc = evaluate(&ofwd, &gen, &mut er, 8)?;
    println!("  trained in {secs:.1}s — train acc {:.1}%, eval acc {:.1}%\n", acc * 100.0, oacc * 100.0);

    // ---- phase 2: decompose the trained weights & fine-tune both ways ----
    let mut results = Vec::new();
    for variant in ["lrd", "freeze"] {
        println!("phase 2: fine-tune `{variant}` ({ft_steps} steps)");
        let tspec = lib.find_by("resnet-mini", variant, "train").unwrap();
        let init = decompose_params(&arch, &tspec.plan, &trained)?;
        let mut fsess = TrainSession::load_with_params(&engine, tspec, &init)?;
        println!(
            "  trainable tensors: {}, frozen tensors: {}",
            fsess.n_trainable(),
            fsess.n_frozen()
        );
        let (curve, ft_secs, _) = run_training(&mut fsess, &gen, &mut rng, ft_steps, 20)?;
        for (s, l) in &curve {
            println!("  step {s:>4}  loss {l:.4}");
        }
        let tuned = fsess.export_params()?;
        let fspec = lib.find_by("resnet-mini", "lrd", "forward").unwrap();
        let ffwd = ForwardModel::load_with_params(&engine, fspec, &tuned)?;
        let mut er = Rng::new(0xE7A1);
        let facc = evaluate(&ffwd, &gen, &mut er, 8)?;
        println!("  {variant}: {ft_secs:.1}s, eval acc {:.1}%\n", facc * 100.0);
        results.push((variant, ft_secs, facc));
    }

    let (full, freeze) = (&results[0], &results[1]);
    println!("== summary ==");
    println!("original eval acc: {:.1}%", oacc * 100.0);
    for (v, secs, acc) in &results {
        println!(
            "{v:8} fine-tune {secs:.1}s  eval acc {:.1}%  (ΔTop-1 {:+.1})",
            acc * 100.0,
            (acc - oacc) * 100.0
        );
    }
    println!(
        "layer freezing fine-tune speed-up vs full updates: {:+.1}% (paper Table 3: +24.57% on R50)",
        (full.1 / freeze.1 - 1.0) * 100.0
    );
    Ok(())
}
