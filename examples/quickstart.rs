//! Quickstart: load an AOT artifact, verify its numerics, run inference,
//! and print the analytic cost story of the paper's five variants.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use lrdx::decompose::{plan_variant, Variant};
use lrdx::model::{cost, Arch};
use lrdx::runtime::artifacts::{ArtifactLibrary, ForwardModel};
use lrdx::runtime::{Engine, HostTensor};

fn main() -> Result<()> {
    // 1. PJRT runtime (CPU) — python is NOT involved from here on.
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());

    // 2. Load a python-AOT artifact: the LRD-decomposed mini ResNet.
    let lib = ArtifactLibrary::load("artifacts")?;
    let spec = lib
        .find_by("resnet-mini", "lrd", "forward")
        .expect("run `make artifacts` first");
    let model = ForwardModel::load(&engine, spec)?;
    println!("loaded {} ({} weight tensors)", spec.name, spec.params.len());

    // 3. Verify against the numerics recorded at AOT time.
    let delta = model.verify()?;
    println!("numerics check vs jax: max |Δ| = {delta:.2e}  ✔");

    // 4. Run a real inference batch.
    let x = HostTensor::new(
        vec![spec.batch, 3, spec.hw, spec.hw],
        lrdx::util::det_input(spec.batch, spec.hw),
    );
    let logits = model.infer(&x)?;
    println!(
        "inference: batch {} -> logits {:?}, argmax[0] = {}",
        spec.batch,
        logits.dims,
        logits.data[..spec.classes]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    );

    // 5. The paper's story in one table: what each method costs.
    let arch = Arch::by_name("resnet50").unwrap();
    println!("\nresnet50 @224 (analytic):");
    println!("{:14} {:>7} {:>11} {:>10}", "variant", "layers", "params(M)", "GFLOPs");
    for v in [Variant::Orig, Variant::Lrd, Variant::Merged, Variant::Branched] {
        let plan = plan_variant(&arch, v, 2.0, 4, None)?;
        let r = cost::report(&arch, &plan, 224);
        println!(
            "{:14} {:>7} {:>11.2} {:>10.2}",
            v.name(),
            r.layers,
            r.params as f64 / 1e6,
            2.0 * r.macs as f64 / 1e9
        );
    }
    println!("\nnext: `lrdx bench table1` … `lrdx bench fig5`, `lrdx serve`, `lrdx train`");
    Ok(())
}
