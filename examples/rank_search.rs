//! Algorithm 1 live on the real backend: sweep Tucker ranks of one conv
//! layer with XLA:CPU wall-clock timing and print the throughput curve,
//! the detected cliff, and the final keep-or-decompose decision.
//!
//! ```sh
//! cargo run --release --example rank_search -- [--c 256] [--s 256] [--hw 16]
//! ```

use anyhow::Result;
use lrdx::decompose::rank_opt::{optimize_site, RankOptConfig};
use lrdx::model::{ConvSite, SiteKind};
use lrdx::profiler::Timer;
use lrdx::runtime::layer_factory::PjrtLayerTimer;
use lrdx::runtime::Engine;
use lrdx::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let c = args.usize_or("c", 128)?;
    let s = args.usize_or("s", 128)?;
    let hw = args.usize_or("hw", 16)?;
    let batch = args.usize_or("batch", 2)?;

    let site = ConvSite {
        name: format!("example.{c}x{s}x3"),
        c,
        s,
        k: 3,
        stride: 1,
        padding: 1,
        kind: SiteKind::Conv,
    };
    let engine = Engine::cpu()?;
    let mut timer = PjrtLayerTimer::with_timer(
        engine,
        Timer { warmup: 1, min_samples: 4, max_samples: 10, cv_target: 0.15 },
    );
    let cfg = RankOptConfig {
        alpha: 2.0,
        rmin_frac: 0.5,
        stride: args.usize_or("stride", 4)?,
        refine: args.usize_or("refine", 4)?,
        batch,
        hw,
    };
    println!(
        "Algorithm 1 on a [{s}, {c}, 3, 3] conv (batch {batch}, {hw}x{hw}), XLA:CPU timing"
    );
    let d = optimize_site(&mut timer, &site, &cfg)?;

    println!("\n rank   ms/call   items/s");
    for &(r, t) in &d.sweep {
        let marker = if Some(r) == d.chosen_rank { "  <= chosen" } else { "" };
        println!("{r:>5}  {:>8.3}  {:>8.1}{marker}", t * 1e3, batch as f64 / t);
    }
    println!("\noriginal layer: {:.3} ms/call", d.t_orig * 1e3);
    match d.chosen_rank {
        Some(r) => println!(
            "decision: decompose at rank {r} (eq.7 gave {}), speedup {:.2}x over original",
            d.initial_rank,
            d.speedup()
        ),
        None => println!(
            "decision: KEEP ORIGINAL (no decomposed rank beat {:.3} ms — the paper's \
             layer1.0.conv1 case)",
            d.t_orig * 1e3
        ),
    }
    println!(
        "({} XLA compiles, {} executable-cache hits)",
        timer.compiles, timer.cache_hits
    );
    Ok(())
}
