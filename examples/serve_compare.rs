//! Serving comparison: original vs decomposed ResNet-50 artifacts behind
//! the coordinator (router + dynamic batcher), reporting throughput and
//! latency percentiles per variant — the deployment-facing version of the
//! paper's "Infer Speed-up" column.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_compare -- [--requests 96]
//! ```

use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};
use lrdx::coordinator::batcher::BatchPolicy;
use lrdx::coordinator::{Coordinator, ServableModel};
use lrdx::runtime::artifacts::{ArtifactLibrary, ForwardModel};
use lrdx::trainsim::data::SynthData;
use lrdx::util::cli::Args;
use lrdx::util::rng::Rng;
use lrdx::util::stats::Summary;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let requests = args.usize_or("requests", 96)?;
    let arch = args.get_or("arch", "resnet50").to_string();
    let variants: Vec<String> = args
        .get_or("variants", "orig,lrd,merged,branched")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let root = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));

    let lib = ArtifactLibrary::load(&root)?;
    let hw = lib
        .find_by(&arch, &variants[0], "forward")
        .ok_or_else(|| anyhow!("missing {arch} artifacts — run `make artifacts`"))?
        .hw;

    let mut coord = Coordinator::new(BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(5),
        ..Default::default()
    });
    for v in &variants {
        let (root2, arch2, v2) = (root.clone(), arch.clone(), v.clone());
        coord.register(v, hw, 1, move |ctx| {
            let lib = ArtifactLibrary::load(&root2)?;
            let spec = lib
                .find_by(&arch2, &v2, "forward")
                .ok_or_else(|| anyhow!("no {arch2}/{v2} forward artifact"))?;
            Ok(Box::new(ForwardModel::load(ctx.engine(), spec)?)
                as Box<dyn ServableModel>)
        })?;
        println!("registered {arch}/{v}");
    }

    let gen = SynthData::new(hw, 10);
    let mut rng = Rng::new(123);
    println!("\n{:10} {:>9} {:>9} {:>9} {:>9}", "variant", "req/s", "p50 ms", "p99 ms", "speedup");
    let mut base_rps = None;
    for v in &variants {
        // warmup (compile + first batches)
        for _ in 0..4 {
            let (x, _) = gen.batch(&mut rng, 1);
            coord.infer_blocking(v, x)?;
        }
        let t0 = Instant::now();
        let pending: Vec<_> = (0..requests)
            .map(|_| {
                let (x, _) = gen.batch(&mut rng, 1);
                coord.infer(v, x)
            })
            .collect::<Result<_>>()?;
        let mut lats = Vec::with_capacity(requests);
        for rx in pending {
            let resp = rx.recv().map_err(|_| anyhow!("worker died"))??;
            lats.push(resp.latency);
        }
        let rps = requests as f64 / t0.elapsed().as_secs_f64();
        let s = Summary::of(&lats);
        let speedup = match base_rps {
            None => {
                base_rps = Some(rps);
                "1.00x".to_string()
            }
            Some(b) => format!("{:+.1}%", (rps / b - 1.0) * 100.0),
        };
        println!(
            "{v:10} {rps:>9.1} {:>9.2} {:>9.2} {speedup:>9}",
            s.p50 * 1e3,
            s.p99 * 1e3
        );
    }
    println!("\ncoordinator metrics:\n{}", coord.metrics.snapshot().render());
    coord.shutdown();
    Ok(())
}
