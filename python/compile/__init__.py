# Build-time package: L1 pallas kernels + L2 jax model + AOT emitter.
# Never imported at runtime — the rust binary only reads artifacts/.
