"""AOT compile path: lower every model/train variant to HLO-text artifacts.

Python runs ONCE (``make artifacts``); the rust runtime then loads
``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and never
imports python again.

Interchange is HLO **text**, not ``.serialize()``: jax>=0.5 emits protos
with 64-bit instruction ids which the xla crate's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Emitted per artifact:
  * ``<name>.hlo.txt``            the lowered module (return_tuple=True)
  * ``params/<name>/<param>.bin`` flat little-endian f32 initial weights
  * a manifest entry (shapes, parameter order, expected outputs for the
    deterministic test input) in ``manifest.json``

Usage:  cd python && python -m compile.aot --out ../artifacts [--full]
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import resnet as RN
from . import train as T

SEED = 20240731  # paper date


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def det_input(batch: int, hw: int) -> np.ndarray:
    """Deterministic test image reproduced bit-for-bit by the rust side:
    x.flat[i] = sin(i * 0.01) * 0.5 (computed in f64, cast to f32)."""
    n = batch * 3 * hw * hw
    x = np.sin(np.arange(n, dtype=np.float64) * 0.01) * 0.5
    return x.astype(np.float32).reshape(batch, 3, hw, hw)


def det_labels(batch: int, classes: int) -> np.ndarray:
    return (np.arange(batch) % classes).astype(np.int32)


def _save_params(
    out: pathlib.Path, art_name: str, names: list[str], params: dict
) -> list[dict]:
    pdir = out / "params" / art_name
    pdir.mkdir(parents=True, exist_ok=True)
    entries = []
    for n in names:
        a = np.asarray(params[n], dtype=np.float32)
        f = pdir / f"{n}.bin"
        a.tofile(f)
        entries.append(
            {"name": n, "shape": list(a.shape), "file": str(f.relative_to(out))}
        )
    return entries


def emit_forward(
    out: pathlib.Path,
    arch_name: str,
    variant: str,
    *,
    hw: int,
    batch: int,
    use_pallas: bool = False,
    groups: int = 4,
) -> dict:
    arch = RN.ARCHS[arch_name]
    key = jax.random.PRNGKey(SEED)
    p0 = RN.init_params(arch, key)
    plan = RN.plan_variant(arch, variant, groups=groups)
    params = RN.decompose_params(arch, plan, p0)
    fn, names = T.make_flat_forward(arch, plan, params, use_pallas=use_pallas)

    arg_specs = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names]
    arg_specs.append(jax.ShapeDtypeStruct((batch, 3, hw, hw), jnp.float32))
    lowered = jax.jit(fn).lower(*arg_specs)
    suffix = "_pallas" if use_pallas else ""
    name = f"{arch_name}_{variant}{suffix}_hw{hw}_b{batch}_fwd"
    (out / f"{name}.hlo.txt").write_text(to_hlo_text(lowered))

    x = det_input(batch, hw)
    (logits,) = fn(*[params[n] for n in names], x)
    entry = {
        "name": name,
        "kind": "forward",
        "arch": arch_name,
        "variant": variant,
        "use_pallas": use_pallas,
        "hw": hw,
        "batch": batch,
        "classes": arch.classes,
        "groups": groups if variant == "branched" else 1,
        "hlo": f"{name}.hlo.txt",
        "params": _save_params(out, name, names, params),
        "plan": {k: list(v) for k, v in plan.items()},
        "expected": {
            "input": "det_sin",
            "logits_row0": [float(v) for v in np.asarray(logits)[0][:8]],
            "tol": 2e-2,
        },
    }
    print(f"  wrote {name} ({len(names)} params)")
    return entry


def emit_train(
    out: pathlib.Path,
    arch_name: str,
    variant: str,
    *,
    hw: int,
    batch: int,
    lr: float = 0.05,
    momentum: float = 0.9,
    use_pallas: bool = False,
    groups: int = 4,
) -> dict:
    arch = RN.ARCHS[arch_name]
    key = jax.random.PRNGKey(SEED)
    p0 = RN.init_params(arch, key)
    plan = RN.plan_variant(
        arch, variant if variant != "freeze" else "lrd", groups=groups
    )
    params = RN.decompose_params(arch, plan, p0)
    mask = RN.freeze_mask(arch, plan, params) if variant == "freeze" else None
    fn, t_names, f_names = T.make_flat_train_step(
        arch, plan, params, mask, lr=lr, momentum=momentum, use_pallas=use_pallas
    )
    specs = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in t_names]
    specs += [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in f_names]
    specs += [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in t_names]
    specs.append(jax.ShapeDtypeStruct((batch, 3, hw, hw), jnp.float32))
    specs.append(jax.ShapeDtypeStruct((batch,), jnp.int32))
    lowered = jax.jit(fn).lower(*specs)
    name = f"{arch_name}_{variant}_hw{hw}_b{batch}_train"
    (out / f"{name}.hlo.txt").write_text(to_hlo_text(lowered))

    # one smoke step for expected loss/accuracy
    x = det_input(batch, hw)
    y = det_labels(batch, arch.classes)
    v0 = [np.zeros(params[n].shape, np.float32) for n in t_names]
    res = fn(
        *[params[n] for n in t_names],
        *[params[n] for n in f_names],
        *v0,
        x,
        y,
    )
    loss, acc = float(res[-2]), float(res[-1])
    entry = {
        "name": name,
        "kind": "train",
        "arch": arch_name,
        "variant": variant,
        "use_pallas": use_pallas,
        "hw": hw,
        "batch": batch,
        "classes": arch.classes,
        "lr": lr,
        "momentum": momentum,
        "hlo": f"{name}.hlo.txt",
        "params": _save_params(out, name, t_names, params),
        "frozen_params": _save_params(out, name, f_names, params),
        "plan": {k: list(v) for k, v in plan.items()},
        "expected": {"input": "det_sin", "loss0": loss, "acc0": acc, "tol": 5e-2},
    }
    print(
        f"  wrote {name} (trainable={len(t_names)} frozen={len(f_names)}, loss0={loss:.4f})"
    )
    return entry


DEFAULT_SET = [
    # (emitter, arch, variant, kwargs)
    ("fwd", "resnet-mini", "orig", dict(hw=32, batch=8)),
    ("fwd", "resnet-mini", "lrd", dict(hw=32, batch=8)),
    ("fwd", "resnet-mini", "merged", dict(hw=32, batch=8)),
    ("fwd", "resnet-mini", "branched", dict(hw=32, batch=8, groups=2)),
    ("fwd", "resnet-mini", "lrd", dict(hw=32, batch=4, use_pallas=True)),
    ("train", "resnet-mini", "orig", dict(hw=32, batch=32)),
    ("train", "resnet-mini", "lrd", dict(hw=32, batch=32)),
    ("train", "resnet-mini", "freeze", dict(hw=32, batch=32)),
    ("train", "resnet-mini", "merged", dict(hw=32, batch=32)),
    ("train", "resnet-mini", "branched", dict(hw=32, batch=32, groups=2)),
    ("fwd", "resnet50", "orig", dict(hw=64, batch=8)),
    ("fwd", "resnet50", "lrd", dict(hw=64, batch=8)),
    ("fwd", "resnet50", "merged", dict(hw=64, batch=8)),
    ("fwd", "resnet50", "branched", dict(hw=64, batch=8)),
]

FULL_EXTRA = [
    ("fwd", "resnet101", "orig", dict(hw=64, batch=8)),
    ("fwd", "resnet101", "lrd", dict(hw=64, batch=8)),
    ("fwd", "resnet101", "merged", dict(hw=64, batch=8)),
    ("fwd", "resnet152", "orig", dict(hw=64, batch=8)),
    ("fwd", "resnet152", "lrd", dict(hw=64, batch=8)),
    ("fwd", "resnet152", "merged", dict(hw=64, batch=8)),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true", help="also emit resnet101/152")
    ap.add_argument("--only", default=None, help="substring filter on artifact name")
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    jobs = DEFAULT_SET + (FULL_EXTRA if args.full else [])
    # Merge with any existing manifest so partial (--only) rebuilds keep the
    # other artifacts' entries.
    mpath = out / "manifest.json"
    by_name: dict[str, dict] = {}
    if mpath.exists():
        try:
            for e in json.loads(mpath.read_text())["artifacts"]:
                by_name[e["name"]] = e
        except Exception:
            by_name = {}
    for kind, arch, variant, kw in jobs:
        tag = f"{arch}_{variant}{'_pallas' if kw.get('use_pallas') else ''}"
        if args.only and args.only not in tag:
            continue
        entry = (
            emit_forward(out, arch, variant, **kw)
            if kind == "fwd"
            else emit_train(out, arch, variant, **kw)
        )
        by_name[entry["name"]] = entry
    manifest = {"seed": SEED, "artifacts": sorted(by_name.values(), key=lambda e: e["name"])}
    mpath.write_text(json.dumps(manifest, indent=1))
    print(f"manifest: {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
