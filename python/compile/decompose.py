"""Low-rank decomposition of model weights (paper §2).

Implements, in JAX:

* eq. (1)-(3)   SVD decomposition of FC / 1x1-conv weights into two factors
* eq. (4)-(6)   Tucker-2 decomposition (HOSVD on the two channel modes) of
                k x k conv weights into a 1x1 -> core -> 1x1 stack
* eq. (7)       rank-from-compression-ratio for Tucker (and the SVD analogue)
* Fig. 3        layer merging: matrix product of adjacent 1x1 factors
* eq. (12)-(17) branch splitting of a Tucker stack into N groups

Conventions: conv weights are OIHW ``[S, C, k, k]``; 1x1 convs and FC
weights are ``[S, C]`` ("out x in", the transpose of the paper's W in
eq. 1 — chosen to match conv OIHW; all equations are transposed
accordingly and round-trip tested in python/tests/test_decompose.py).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# SVD (FC and 1x1 conv), eq. (1)-(3)
# --------------------------------------------------------------------------


class SvdFactors(NamedTuple):
    """``w ~= w1 @ w0`` with ``w0``: [R, C] (first layer), ``w1``: [S, R]."""

    w0: jax.Array
    w1: jax.Array


def svd_decompose(w: jax.Array, rank: int) -> SvdFactors:
    """Truncated SVD of ``w``: [S, C] into rank-``rank`` factors (eq. 3).

    Returns ``(w0, w1)`` such that the layer computes
    ``y = w1 @ (w0 @ x)`` — i.e. first a [R, C] projection then a [S, R]
    expansion, each factor absorbing ``sqrt(sigma)``.
    """
    u, s, vt = jnp.linalg.svd(w, full_matrices=False)
    r = int(rank)
    sq = jnp.sqrt(s[:r])
    w1 = u[:, :r] * sq[None, :]  # [S, R]
    w0 = sq[:, None] * vt[:r, :]  # [R, C]
    return SvdFactors(w0=w0, w1=w1)


def svd_reconstruct(f: SvdFactors) -> jax.Array:
    return f.w1 @ f.w0


def svd_rank_for_ratio(c: int, s: int, alpha: float) -> int:
    """Rank giving ``alpha``x parameter compression for an [S, C] matrix.

    orig = C*S params; decomposed = (C+S)*R  =>  R = C*S / (alpha*(C+S)).
    Matches the paper's Table 2 (e.g. 64x64 @ 2x -> 16; 2048x1001 @ 2x -> 336).
    """
    r = int(c * s / (alpha * (c + s)))
    return max(1, min(r, min(c, s)))


# --------------------------------------------------------------------------
# Tucker-2 (k x k conv), eq. (4)-(6)
# --------------------------------------------------------------------------


class TuckerFactors(NamedTuple):
    """1x1 -> core -> 1x1 stack (Fig. 1b).

    ``u``:    [r1, C]         input 1x1 projection
    ``core``: [r2, r1, k, k]  core conv
    ``v``:    [S, r2]         output 1x1 expansion
    """

    u: jax.Array
    core: jax.Array
    v: jax.Array


def _mode_unfold_svd(m: jax.Array, rank: int) -> jax.Array:
    """Leading ``rank`` left singular vectors of a matrix unfolding."""
    u, _s, _vt = jnp.linalg.svd(m, full_matrices=False)
    return u[:, :rank]


def tucker2_decompose(w: jax.Array, r1: int, r2: int) -> TuckerFactors:
    """Tucker-2 HOSVD of an OIHW tensor ``w``: [S, C, k, k] (eq. 4-6).

    Only the two channel modes are decomposed (spatial dims are tiny,
    paper §2): U from the mode-C unfolding, V from the mode-S unfolding,
    core = W x_C U^T x_S V^T.
    """
    s, c, kh, kw = w.shape
    r1, r2 = int(r1), int(r2)
    # mode-C unfolding: [C, S*k*k]
    m_c = jnp.transpose(w, (1, 0, 2, 3)).reshape(c, s * kh * kw)
    u_c = _mode_unfold_svd(m_c, r1)  # [C, r1]
    # mode-S unfolding: [S, C*k*k]
    m_s = w.reshape(s, c * kh * kw)
    u_s = _mode_unfold_svd(m_s, r2)  # [S, r2]
    core = jnp.einsum("schw,ci,sj->jihw", w, u_c, u_s)  # [r2, r1, k, k]
    return TuckerFactors(u=u_c.T, core=core, v=u_s)


def tucker2_reconstruct(f: TuckerFactors) -> jax.Array:
    """Inverse of :func:`tucker2_decompose`: W' = core x_C U x_S V."""
    return jnp.einsum("jihw,ic,sj->schw", f.core, f.u, f.v)


def tucker_rank_for_ratio(
    c: int, s: int, k: int, alpha: float, beta: float | None = None
) -> tuple[int, int]:
    """Eq. (7): ranks (r1, r2 = beta*r1) giving ``alpha``x compression.

    orig = C*S*k^2;  decomposed = C*r1 + beta*r1^2*k^2 + beta*r1*S.
    Solving the quadratic gives eq. (7) exactly. ``beta`` defaults to S/C
    so the ranks scale with their channel dims (r1/C == r2/S).

    Matches the paper's Table 2: (64,64,3,3) @ 2x -> 38; (512,512,3,3) @ 2x
    -> 309.
    """
    if beta is None:
        beta = s / c
    k2 = k * k
    term = (c + beta * s) / (beta * k2)
    r1 = (-term + math.sqrt(term * term + 4.0 * c * s / (beta * alpha))) / 2.0
    r1 = int(r1)
    r1 = max(1, min(r1, c))
    r2 = max(1, min(int(beta * r1), s))
    return r1, r2


# --------------------------------------------------------------------------
# Layer merging (Fig. 3)
# --------------------------------------------------------------------------


class MergedBottleneck(NamedTuple):
    """Bottleneck after Fig. 3 merging — back to exactly 3 conv layers.

    ``w1m``: [r1, C]       conv1 merged with the Tucker U of conv2
    ``core``: [r2, r1, k, k]
    ``w3m``: [S3, r2]      conv3 merged with the Tucker V of conv2
    """

    w1m: jax.Array
    core: jax.Array
    w3m: jax.Array


def merge_bottleneck(
    w1: jax.Array, f2: TuckerFactors, w3: jax.Array
) -> MergedBottleneck:
    """Fold the 1x1 Tucker factors of conv2 into the adjacent 1x1 convs.

    conv1':  U2 @ W1   ([r1, M] @ [M, C]  -> [r1, C])
    conv3':  W3 @ V2   ([S, M] @ [M, r2] -> [S, r2])

    Note (documented in DESIGN.md): the original block has BN+ReLU between
    conv1 and conv2; merging commutes the product past them, so the merged
    weights are an *initialisation* that fine-tuning polishes — exactly why
    the paper reports a small ΔTop-1 for Layer Merging rather than zero.
    """
    return MergedBottleneck(w1m=f2.u @ w1, core=f2.core, w3m=w3 @ f2.v)


# --------------------------------------------------------------------------
# Branching Tucker, eq. (12)-(17)
# --------------------------------------------------------------------------


class BranchedFactors(NamedTuple):
    """Grouped-conv implementation of N Tucker branches (Fig. 4 right).

    ``u``:    [r1, C]              full 1x1 (concat of U_j)
    ``core``: [r2, r1 // N, k, k]  grouped core (G = N)
    ``v``:    [S, r2]              full 1x1 (concat of V_j)
    """

    u: jax.Array
    core: jax.Array
    v: jax.Array
    groups: int


def branch_tucker(f: TuckerFactors, groups: int) -> BranchedFactors:
    """Split a Tucker stack into ``groups`` parallel branches (eq. 12-17).

    Rank blocks j get U_j = U[jR1:(j+1)R1], V_j = V[:, jR2:(j+1)R2] and the
    *diagonal* core blocks X_j = core[jR2:(j+1)R2, jR1:(j+1)R1]; off-diagonal
    core blocks are dropped — that is the paper's N-fold core-parameter
    reduction (eq. 18-20) and the reason branching needs fine-tuning.
    """
    r2, r1 = f.core.shape[0], f.core.shape[1]
    if r1 % groups or r2 % groups:
        raise ValueError(f"ranks ({r1},{r2}) not divisible by N={groups}")
    b1, b2 = r1 // groups, r2 // groups
    blocks = [
        f.core[j * b2 : (j + 1) * b2, j * b1 : (j + 1) * b1] for j in range(groups)
    ]
    core = jnp.concatenate(blocks, axis=0)  # [r2, r1/N, k, k] grouped OIHW
    return BranchedFactors(u=f.u, core=core, v=f.v, groups=groups)


def quantize_ranks(r1: int, r2: int, groups: int) -> tuple[int, int]:
    """Eq. (10)-(11): round ranks down to multiples of N (at least N)."""
    return max(groups, r1 - r1 % groups), max(groups, r2 - r2 % groups)
