# L1: Pallas kernels for the paper's compute hot-spots, plus the pure-jnp
# oracle (ref.py) used by pytest and by the L2 model's reference path.
from . import conv2d, grouped_conv, lowrank_matmul, ref  # noqa: F401
