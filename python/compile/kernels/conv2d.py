"""Pallas kernel for the k x k core convolution of a Tucker-2 stack.

Strategy (DESIGN.md §Hardware-Adaptation): instead of porting the paper's
CUDA im2col-into-shared-memory scheme, we tile for VMEM — the grid walks
(batch, out-channel tiles); each step holds one padded input image
``(C, Hp, Wp)`` and one weight tile ``(bs, C, k, k)`` in VMEM and expresses
the convolution as k*k shifted-slice matmuls that all hit the MXU:

    out[s, :, :] = sum_{kh,kw}  W[s, :, kh, kw] @ X[:, kh::stride, kw::stride]

The k*k loop is a static Python loop (k is 1/3/7 in ResNets), so the whole
body unrolls into k^2 MXU contractions of shape (bs, C) x (C, Ho*Wo) — the
same arithmetic as im2col without materialising the im2col matrix
(C*k*k*Ho*Wo words) in memory.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(k: int, stride: int, ho: int, wo: int):
    def kernel(x_ref, w_ref, o_ref):
        # x_ref: (C, Hp, Wp)   one padded image
        # w_ref: (bs, C, k, k) one output-channel tile
        # o_ref: (bs, Ho, Wo)
        c = x_ref.shape[0]
        bs = w_ref.shape[0]
        acc = jnp.zeros((bs, ho * wo), dtype=jnp.float32)
        for kh in range(k):
            for kw in range(k):
                # strided window starting at (kh, kw): (C, Ho, Wo)
                patch = jax.lax.slice(
                    x_ref[...],
                    (0, kh, kw),
                    (c, kh + (ho - 1) * stride + 1, kw + (wo - 1) * stride + 1),
                    (1, stride, stride),
                )
                acc += jnp.dot(
                    w_ref[:, :, kh, kw],
                    patch.reshape(c, ho * wo),
                    preferred_element_type=jnp.float32,
                )
        o_ref[...] = acc.reshape(bs, ho, wo).astype(o_ref.dtype)

    return kernel


def _round_block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(
    jax.jit, static_argnames=("stride", "padding", "block_s", "interpret")
)
def conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: int = 0,
    block_s: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """NCHW conv via shifted-slice matmuls. x: [N,C,H,W], w: [S,C,k,k]."""
    n, c, h, wdt = x.shape
    s, c2, kh, kw = w.shape
    if c != c2 or kh != kw:
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape}")
    k = kh
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    hp, wp = h + 2 * padding, wdt + 2 * padding
    ho = (hp - k) // stride + 1
    wo = (wp - k) // stride + 1
    bs = _round_block(s, block_s)
    grid = (n, s // bs)
    return pl.pallas_call(
        _make_kernel(k, stride, ho, wo),
        grid=grid,
        in_specs=[
            # Leading `None` squeezes the batch dim: the kernel sees (C,Hp,Wp).
            pl.BlockSpec((None, c, hp, wp), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((bs, c, k, k), lambda i, j: (j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bs, ho, wo), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, s, ho, wo), x.dtype),
        interpret=interpret,
    )(xp, w)


def vmem_bytes(
    c: int, s: int, h: int, w: int, k: int, padding: int = 0, block_s: int = 128
) -> int:
    """f32 VMEM footprint of one grid step (input image + weight tile + acc)."""
    bs = _round_block(s, block_s)
    hp, wp = h + 2 * padding, w + 2 * padding
    ho, wo = hp - k + 1, wp - k + 1
    words = c * hp * wp + bs * c * k * k + 2 * bs * ho * wo
    return 4 * words


def mxu_flops(n: int, c: int, s: int, ho: int, wo: int, k: int) -> int:
    """MACs through the MXU for one call."""
    return n * s * c * k * k * ho * wo
