"""Pallas kernel for the Branching-Tucker grouped convolution (Fig. 4).

The N parallel Tucker branches of eq. (17) become ONE grouped conv: the
grid walks (batch, group); each step convolves the group's input-channel
slab ``(Cg, Hp, Wp)`` against the group's weight block ``(Sg, Cg, k, k)``
and writes the group's output-channel slab. Branch parallelism is thus
expressed as grid parallelism — on TPU each branch is an independent MXU
stream with a 1/N^2-sized weight block (the paper's N-fold core-parameter
reduction, eq. 18-20), on CPU-PJRT each grid step is an independent
vectorised loop nest.

Same shifted-slice-matmul body as ``conv2d.py`` — see that file for the
im2col-free rationale.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(k: int, stride: int, ho: int, wo: int):
    def kernel(x_ref, w_ref, o_ref):
        # x_ref: (Cg, Hp, Wp) — this group's input slab
        # w_ref: (Sg, Cg, k, k) — this group's weights
        # o_ref: (Sg, Ho, Wo)
        cg = x_ref.shape[0]
        sg = w_ref.shape[0]
        acc = jnp.zeros((sg, ho * wo), dtype=jnp.float32)
        for kh in range(k):
            for kw in range(k):
                patch = jax.lax.slice(
                    x_ref[...],
                    (0, kh, kw),
                    (cg, kh + (ho - 1) * stride + 1, kw + (wo - 1) * stride + 1),
                    (1, stride, stride),
                )
                acc += jnp.dot(
                    w_ref[:, :, kh, kw],
                    patch.reshape(cg, ho * wo),
                    preferred_element_type=jnp.float32,
                )
        o_ref[...] = acc.reshape(sg, ho, wo).astype(o_ref.dtype)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("groups", "stride", "padding", "interpret")
)
def grouped_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    groups: int,
    stride: int = 1,
    padding: int = 0,
    interpret: bool = True,
) -> jax.Array:
    """Grouped NCHW conv. x: [N, C, H, W], w: [S, C//G, k, k] -> [N, S, Ho, Wo]."""
    n, c, h, wdt = x.shape
    s, cg, kh, kw = w.shape
    if kh != kw:
        raise ValueError(f"non-square kernel {w.shape}")
    if c % groups or s % groups or cg != c // groups:
        raise ValueError(f"bad grouping: C={c} S={s} G={groups} w{w.shape}")
    k = kh
    sg = s // groups
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    hp, wp = h + 2 * padding, wdt + 2 * padding
    ho = (hp - k) // stride + 1
    wo = (wp - k) // stride + 1
    grid = (n, groups)
    return pl.pallas_call(
        _make_kernel(k, stride, ho, wo),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, cg, hp, wp), lambda i, g: (i, g, 0, 0)),
            pl.BlockSpec((sg, cg, k, k), lambda i, g: (g, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, sg, ho, wo), lambda i, g: (i, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, s, ho, wo), x.dtype),
        interpret=interpret,
    )(xp, w)


def vmem_bytes(c: int, s: int, groups: int, h: int, w: int, k: int, padding: int = 0) -> int:
    """f32 VMEM footprint of one grid step (one group's slab + weights + acc)."""
    cg, sg = c // groups, s // groups
    hp, wp = h + 2 * padding, w + 2 * padding
    ho, wo = hp - k + 1, wp - k + 1
    words = cg * hp * wp + sg * cg * k * k + 2 * sg * ho * wo
    return 4 * words


def core_params(r1: int, r2: int, k: int, groups: int) -> int:
    """Eq. (18)-(20): grouped core holds (r1*r2*k^2)/N parameters."""
    return (r1 // groups) * (r2 // groups) * k * k * groups
