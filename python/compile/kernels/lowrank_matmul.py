"""Pallas kernel for the SVD-decomposed linear layer (paper eq. 3).

Computes ``y = (x @ W0) @ W1`` in one fused kernel so the rank-R
intermediate never round-trips through HBM: per grid step, a ``(bm, R)``
tile of ``t = x @ W0`` lives in VMEM scratch and is immediately contracted
against a ``(R, bn)`` tile of W1.

TPU mapping (DESIGN.md §Hardware-Adaptation): both contractions hit the
MXU; the win over two separate matmul dispatches is the elided HBM write +
read of ``t`` (2·B·R·4 bytes). VMEM footprint per step is
``bm·C + C·R + R·bn + bm·R + bm·bn`` f32 words — block shapes below are
chosen to keep that under ~2 MiB for the ResNet shapes we sweep.

CPU note: lowered with ``interpret=True`` (Mosaic custom-calls cannot run
on the CPU PJRT plugin); numerics are still exactly the kernel's.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w0_ref, w1_ref, o_ref):
    # x_ref: (bm, C); w0_ref: (C, R); w1_ref: (R, bn); o_ref: (bm, bn)
    t = jnp.dot(x_ref[...], w0_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(t, w1_ref[...], preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def _round_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (keeps the grid exact)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def lowrank_matmul(
    x: jax.Array,
    w0: jax.Array,
    w1: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Fused ``(x @ w0) @ w1``. x: [B, C], w0: [C, R], w1: [R, S] -> [B, S]."""
    b, c = x.shape
    c2, r = w0.shape
    r2, s = w1.shape
    if c != c2 or r != r2:
        raise ValueError(f"shape mismatch: x{x.shape} w0{w0.shape} w1{w1.shape}")
    bm = _round_block(b, block_m)
    bn = _round_block(s, block_n)
    grid = (b // bm, s // bn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, c), lambda i, j: (i, 0)),
            pl.BlockSpec((c, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, s), x.dtype),
        interpret=interpret,
    )(x, w0, w1)


def vmem_bytes(b: int, c: int, r: int, s: int, block_m: int = 128, block_n: int = 128) -> int:
    """Analytic VMEM footprint (f32 words x4) of one grid step.

    Used by the §Perf analysis and mirrored by the rust cost model
    (``model::cost::lowrank_vmem_bytes``) — keep the two in sync.
    """
    bm = _round_block(b, block_m)
    bn = _round_block(s, block_n)
    words = bm * c + c * r + r * bn + bm * r + bm * bn
    return 4 * words


def mxu_flops(b: int, c: int, r: int, s: int) -> int:
    """MACs routed through the MXU for one call (both contractions)."""
    return b * c * r + b * r * s
