"""Pure-jnp reference oracle for every Pallas kernel in this package.

These are the ground truth the pytest suite checks the Pallas kernels
against (`assert_allclose`). They are written with `jax.lax` / `jnp`
primitives only — no Pallas — so they execute on any backend and are
trivially auditable against the paper's equations:

* ``lowrank_matmul``   — eq. (3):  y = (x @ W0) @ W1      (SVD-decomposed FC / 1x1 conv)
* ``conv2d``           — the regular k x k convolution (NCHW)
* ``grouped_conv2d``   — Fig. 4: grouped convolution used by Branching Tucker
* ``tucker_conv_stack``— Fig. 1b: 1x1 -> k x k core -> 1x1 Tucker-2 stack
* ``branched_tucker``  — eq. (17): explicit N-branch sum (used to prove the
                          grouped-conv equivalence of Fig. 4)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lowrank_matmul(x: jax.Array, w0: jax.Array, w1: jax.Array) -> jax.Array:
    """SVD-decomposed linear layer, eq. (3): ``y = (x @ W0) @ W1``.

    x: [B, C], w0: [C, R], w1: [R, S] -> [B, S].
    """
    return (x @ w0) @ w1


def conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: int = 0,
) -> jax.Array:
    """NCHW convolution. x: [N, C, H, W], w: [S, C, kh, kw] -> [N, S, Ho, Wo]."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def grouped_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    groups: int,
    stride: int = 1,
    padding: int = 0,
) -> jax.Array:
    """Grouped NCHW convolution (Fig. 4 right).

    x: [N, C, H, W], w: [S, C // groups, kh, kw] -> [N, S, Ho, Wo].
    """
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups,
    )


def conv1x1(x: jax.Array, w: jax.Array) -> jax.Array:
    """1x1 convolution as a channel matmul. x: [N, C, H, W], w: [S, C]."""
    return jnp.einsum("nchw,sc->nshw", x, w)


def tucker_conv_stack(
    x: jax.Array,
    u: jax.Array,
    core: jax.Array,
    v: jax.Array,
    *,
    stride: int = 1,
    padding: int = 0,
) -> jax.Array:
    """Tucker-2 decomposed k x k conv (Fig. 1b).

    ``u``:    [r1, C]          first 1x1 conv (input projection, U'^T)
    ``core``: [r2, r1, k, k]   the core k x k conv
    ``v``:    [S, r2]          last 1x1 conv (output projection, V')
    """
    y = conv1x1(x, u)
    y = conv2d(y, core, stride=stride, padding=padding)
    return conv1x1(y, v)


def branched_tucker(
    x: jax.Array,
    us: jax.Array,
    cores: jax.Array,
    vs: jax.Array,
    *,
    stride: int = 1,
    padding: int = 0,
) -> jax.Array:
    """Eq. (17): N explicit parallel Tucker branches, summed.

    ``us``:    [N, R1, C]
    ``cores``: [N, R2, R1, k, k]
    ``vs``:    [N, S, R2]

    The paper's Fig. 4 claims this equals one grouped-conv stack with
    U = concat_j U_j, core = block-diag (grouped, G=N), V = concat_j V_j.
    """
    n = us.shape[0]
    out = None
    for j in range(n):
        y = tucker_conv_stack(
            x, us[j], cores[j], vs[j], stride=stride, padding=padding
        )
        out = y if out is None else out + y
    return out


def branched_as_grouped(
    x: jax.Array,
    us: jax.Array,
    cores: jax.Array,
    vs: jax.Array,
    *,
    stride: int = 1,
    padding: int = 0,
) -> jax.Array:
    """The grouped-convolution implementation of eq. (17) / Fig. 4.

    Same inputs as :func:`branched_tucker`; internally rewrites the N
    branches as   1x1 (C -> N*R1)  ->  grouped k x k (G=N)  ->  1x1 (N*R2 -> S).
    """
    n, r1, _c = us.shape
    _n, r2, _r1, kh, kw = cores.shape
    u_cat = us.reshape(n * r1, -1)  # [N*R1, C]
    core_cat = cores.reshape(n * r2, r1, kh, kw)  # grouped OIHW, G=N
    v_cat = jnp.concatenate([vs[j] for j in range(n)], axis=1)  # [S, N*R2]
    y = conv1x1(x, u_cat)
    y = grouped_conv2d(y, core_cat, groups=n, stride=stride, padding=padding)
    return conv1x1(y, v_cat)
