"""L2 model: functional ResNet family, parameterised by a decomposition plan.

One code path builds every variant the paper evaluates:

* ``orig``      — the stock architecture
* ``lrd``       — vanilla LRD (paper §2): SVD on FC/1x1, Tucker-2 on k x k
* ``opt``       — like ``lrd`` but with externally supplied (Algorithm 1)
                  per-site ranks; sites may opt out (keep the original layer)
* ``merged``    — Fig. 3 layer merging inside bottlenecks
* ``branched``  — Fig. 4 branching Tucker (grouped core convs)
* ``freeze``    — same params as ``lrd``; the *train step* freezes the
                  1x1 factor layers (see train.py), forward is identical

The network is described as a list of :class:`ConvSite` records; a *plan*
maps each site name to a :class:`Scheme`. ``decompose_params`` turns
original weights into variant weights (the paper's "one-shot KD" init), and
``forward`` interprets (sites, plan, params) functionally — so jit/grad/AOT
all see a single pure function.

BatchNorm is modelled as batch-statistics normalisation with learnable
scale/shift (train and eval — no running-stats state; documented in
DESIGN.md substitutions). Conv weights are OIHW; FC weight is [classes, F].
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from . import decompose as D
from .kernels import conv2d as pl_conv
from .kernels import grouped_conv as pl_gconv
from .kernels import lowrank_matmul as pl_lrmm
from .kernels import ref as R

# --------------------------------------------------------------------------
# Architecture description
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConvSite:
    """One decomposable weight site (conv or fc) in the network."""

    name: str
    c: int  # input channels (fc: input features)
    s: int  # output channels (fc: classes)
    k: int  # kernel size (fc: 1)
    stride: int = 1
    padding: int = 0
    kind: str = "conv"  # "stem" | "conv" | "downsample" | "fc"


@dataclasses.dataclass(frozen=True)
class Arch:
    name: str
    block: str  # "basic" | "bottleneck"
    layers: tuple[int, int, int, int]
    width: int = 64
    expansion: int = 4
    classes: int = 1000

    @property
    def stage_widths(self) -> tuple[int, int, int, int]:
        w = self.width
        return (w, 2 * w, 4 * w, 8 * w)


ARCHS: dict[str, Arch] = {
    "resnet18": Arch("resnet18", "basic", (2, 2, 2, 2), expansion=1),
    "resnet34": Arch("resnet34", "basic", (3, 4, 6, 3), expansion=1),
    "resnet50": Arch("resnet50", "bottleneck", (3, 4, 6, 3)),
    "resnet101": Arch("resnet101", "bottleneck", (3, 4, 23, 3)),
    "resnet152": Arch("resnet152", "bottleneck", (3, 8, 36, 3)),
    # tiny bottleneck net for the fine-tuning simulations (Tables 4-6)
    "resnet-mini": Arch("resnet-mini", "bottleneck", (1, 1, 1, 1), width=16, classes=10),
}


def sites(arch: Arch) -> list[ConvSite]:
    """Enumerate every decomposable site, torch-style names (Table 2)."""
    out: list[ConvSite] = [
        ConvSite("stem.conv", 3, arch.width, 7, stride=2, padding=3, kind="stem")
    ]
    c_in = arch.width
    for si, (n_blocks, w) in enumerate(zip(arch.layers, arch.stage_widths)):
        stride = 1 if si == 0 else 2
        c_out = w * arch.expansion
        for bi in range(n_blocks):
            pre = f"layer{si + 1}.{bi}"
            blk_stride = stride if bi == 0 else 1
            if arch.block == "bottleneck":
                out.append(ConvSite(f"{pre}.conv1", c_in, w, 1))
                out.append(
                    ConvSite(f"{pre}.conv2", w, w, 3, stride=blk_stride, padding=1)
                )
                out.append(ConvSite(f"{pre}.conv3", w, c_out, 1))
            else:
                c_out = w
                out.append(
                    ConvSite(f"{pre}.conv1", c_in, w, 3, stride=blk_stride, padding=1)
                )
                out.append(ConvSite(f"{pre}.conv2", w, w, 3, padding=1))
            if bi == 0 and (blk_stride != 1 or c_in != c_out):
                out.append(
                    ConvSite(
                        f"{pre}.downsample",
                        c_in,
                        c_out,
                        1,
                        stride=blk_stride,
                        kind="downsample",
                    )
                )
            c_in = c_out
    out.append(ConvSite("fc", c_in, arch.classes, 1, kind="fc"))
    return out


# --------------------------------------------------------------------------
# Plans
# --------------------------------------------------------------------------

# Scheme tuples (kept as plain tuples so plans serialise to JSON):
#   ("orig",)
#   ("svd", r)                      k == 1 or fc
#   ("tucker", r1, r2)              k > 1
#   ("branched", r1, r2, groups)    k > 1
#   ("merged", r1, r2)              on conv2; conv1/conv3 of the block get
#                                   ("merged_into", peer) markers
Scheme = tuple


def plan_variant(
    arch: Arch,
    variant: str,
    *,
    alpha: float = 2.0,
    groups: int = 4,
    ranks: dict[str, Scheme] | None = None,
) -> dict[str, Scheme]:
    """Build the decomposition plan for one of the paper's five variants.

    The stem conv is never decomposed (3 input channels — decomposition
    cannot reach the target ratio and the paper's Table 1 layer counts
    confirm they skip it). ``ranks`` overrides per-site schemes for the
    ``opt`` variant (output of the rust Algorithm 1 search).
    """
    plan: dict[str, Scheme] = {}
    site_list = sites(arch)
    by_name = {t.name: t for t in site_list}
    for t in site_list:
        if t.kind == "stem" or variant == "orig":
            plan[t.name] = ("orig",)
            continue
        if variant in ("lrd", "freeze"):
            plan[t.name] = _ratio_scheme(t, alpha)
        elif variant == "opt":
            plan[t.name] = (ranks or {}).get(t.name, _ratio_scheme(t, alpha))
        elif variant == "merged":
            plan[t.name] = _ratio_scheme(t, alpha)  # refined below
        elif variant == "branched":
            if t.k > 1:
                # Branch the alpha-compression ranks: eq. (18)-(20) shrinks the
                # core a further N-fold *without lowering the ranks*, which is
                # how Table 6 compounds -47.69% (vanilla) into -66.75%.
                r1, r2 = D.tucker_rank_for_ratio(t.c, t.s, t.k, alpha)
                r1, r2 = D.quantize_ranks(min(r1, t.c), min(r2, t.s), groups)
                plan[t.name] = ("branched", r1, r2, groups)
            else:
                plan[t.name] = _ratio_scheme(t, alpha)
        else:
            raise ValueError(f"unknown variant {variant!r}")
    if variant == "merged":
        if arch.block != "bottleneck":
            raise ValueError("layer merging is defined for bottleneck nets")
        for t in site_list:
            if t.name.endswith(".conv2"):
                pre = t.name[: -len(".conv2")]
                r1, r2 = D.tucker_rank_for_ratio(t.c, t.s, t.k, alpha)
                plan[t.name] = ("merged", r1, r2)
                plan[f"{pre}.conv1"] = ("merged_into", t.name)
                plan[f"{pre}.conv3"] = ("merged_into", t.name)
            elif t.kind == "downsample":
                plan[t.name] = _ratio_scheme(t, alpha)
            elif t.kind == "fc":
                # fc has no adjacent 1x1 to merge with; keeping it original
                # preserves the paper's "same layer count" claim (Table 3).
                plan[t.name] = ("orig",)
        # non-conv2 1x1s inside blocks already marked merged_into above
    _ = by_name
    return plan


def _ratio_scheme(t: ConvSite, alpha: float) -> Scheme:
    if t.k == 1:
        return ("svd", D.svd_rank_for_ratio(t.c, t.s, alpha))
    r1, r2 = D.tucker_rank_for_ratio(t.c, t.s, t.k, alpha)
    return ("tucker", r1, r2)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def init_params(arch: Arch, key: jax.Array) -> dict[str, jax.Array]:
    """He-init original weights + BN scale/shift for every site."""
    params: dict[str, jax.Array] = {}
    for t in sites(arch):
        key, sub = jax.random.split(key)
        fan_in = t.c * t.k * t.k
        std = (2.0 / fan_in) ** 0.5
        if t.kind == "fc":
            params[f"{t.name}.w"] = jax.random.normal(sub, (t.s, t.c)) * std
            params[f"{t.name}.b"] = jnp.zeros((t.s,))
        else:
            shape = (t.s, t.c) if t.k == 1 else (t.s, t.c, t.k, t.k)
            params[f"{t.name}.w"] = jax.random.normal(sub, shape) * std
            params[f"{t.name}.bn.g"] = jnp.ones((t.s,))
            params[f"{t.name}.bn.b"] = jnp.zeros((t.s,))
    return params


def decompose_params(
    arch: Arch, plan: dict[str, Scheme], params: dict[str, jax.Array]
) -> dict[str, jax.Array]:
    """One-shot init of the variant weights from the original weights.

    This is the paper's "built-in one-shot knowledge distillation": every
    factor is *computed* from the teacher weight, never random.
    """
    out: dict[str, jax.Array] = {}
    site_list = sites(arch)
    by_name = {t.name: t for t in site_list}
    for t in site_list:
        scheme = plan.get(t.name, ("orig",))
        kind = scheme[0]
        w = params[f"{t.name}.w"]
        if t.kind != "fc":
            out[f"{t.name}.bn.g"] = params[f"{t.name}.bn.g"]
            out[f"{t.name}.bn.b"] = params[f"{t.name}.bn.b"]
        if kind == "orig":
            out[f"{t.name}.w"] = w
            if t.kind == "fc":
                out[f"{t.name}.b"] = params[f"{t.name}.b"]
        elif kind == "svd":
            f = D.svd_decompose(w, scheme[1])
            out[f"{t.name}.w0"] = f.w0
            out[f"{t.name}.w1"] = f.w1
            if t.kind == "fc":
                out[f"{t.name}.b"] = params[f"{t.name}.b"]
        elif kind == "tucker":
            f = D.tucker2_decompose(w, scheme[1], scheme[2])
            out[f"{t.name}.u"] = f.u
            out[f"{t.name}.core"] = f.core
            out[f"{t.name}.v"] = f.v
        elif kind == "branched":
            r1, r2, g = scheme[1], scheme[2], scheme[3]
            f = D.branch_tucker(D.tucker2_decompose(w, r1, r2), g)
            out[f"{t.name}.u"] = f.u
            out[f"{t.name}.core"] = f.core
            out[f"{t.name}.v"] = f.v
        elif kind == "merged":
            pre = t.name[: -len(".conv2")]
            f = D.tucker2_decompose(w, scheme[1], scheme[2])
            w1 = params[f"{pre}.conv1.w"]
            w3 = params[f"{pre}.conv3.w"]
            m = D.merge_bottleneck(w1, f, w3)
            out[f"{pre}.conv1.w"] = m.w1m
            out[f"{t.name}.w"] = m.core
            out[f"{pre}.conv3.w"] = m.w3m
            # BN of conv1/conv3 now acts on r1/r2 channels; re-init affine.
            out[f"{pre}.conv1.bn.g"] = jnp.ones((scheme[1],))
            out[f"{pre}.conv1.bn.b"] = jnp.zeros((scheme[1],))
            out[f"{pre}.conv2.bn.g"] = jnp.ones((scheme[2],))
            out[f"{pre}.conv2.bn.b"] = jnp.zeros((scheme[2],))
        elif kind == "merged_into":
            pass  # weights written by the peer conv2 site above
        else:
            raise ValueError(f"unknown scheme {scheme!r} at {t.name}")
    _ = by_name
    return out


def freeze_mask(
    arch: Arch, plan: dict[str, Scheme], params: dict[str, jax.Array]
) -> dict[str, bool]:
    """Paper §2.2: trainable=False for the SVD/Tucker 1x1 factor weights.

    Frozen: ``w0`` of SVD pairs (Fig. 1a "first 1x1") and ``u``/``v`` of
    Tucker stacks (Fig. 1b "first and last 1x1"). Everything else trains.
    """
    frozen_suffix = (".w0", ".u", ".v")
    return {
        name: not any(name.endswith(sfx) for sfx in frozen_suffix)
        for name in params
    }


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------


def _bn(x: jax.Array, g: jax.Array, b: jax.Array) -> jax.Array:
    mean = jnp.mean(x, axis=(0, 2, 3), keepdims=True)
    var = jnp.var(x, axis=(0, 2, 3), keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + 1e-5)
    return xn * g[None, :, None, None] + b[None, :, None, None]


def _conv1x1(x: jax.Array, w: jax.Array, stride: int) -> jax.Array:
    if stride != 1:
        x = x[:, :, ::stride, ::stride]
    return R.conv1x1(x, w)


def _apply_site(
    t: ConvSite,
    plan: dict[str, Scheme],
    p: dict[str, jax.Array],
    x: jax.Array,
    *,
    use_pallas: bool,
) -> jax.Array:
    """Run one conv site's (possibly decomposed) stack, without BN/ReLU."""
    scheme = plan.get(t.name, ("orig",))
    kind = scheme[0]
    n = t.name
    if kind == "merged_into":
        # 1x1 conv carrying the Fig. 3 product weight ([r1, C] or [S, r2]).
        return _conv1x1(x, p[f"{n}.w"], t.stride)
    if kind in ("orig", "merged"):
        w = p[f"{n}.w"]
        if t.k == 1 and w.ndim == 2:
            return _conv1x1(x, w, t.stride)
        conv = pl_conv.conv2d if use_pallas else None
        if conv is not None:
            return conv(x, w, stride=t.stride, padding=t.padding)
        return R.conv2d(x, w, stride=t.stride, padding=t.padding)
    if kind == "svd":
        y = _conv1x1(x, p[f"{n}.w0"], t.stride)
        return R.conv1x1(y, p[f"{n}.w1"])
    if kind == "tucker":
        y = R.conv1x1(x, p[f"{n}.u"])
        core = p[f"{n}.core"]
        if use_pallas:
            y = pl_conv.conv2d(y, core, stride=t.stride, padding=t.padding)
        else:
            y = R.conv2d(y, core, stride=t.stride, padding=t.padding)
        return R.conv1x1(y, p[f"{n}.v"])
    if kind == "branched":
        g = scheme[3]
        y = R.conv1x1(x, p[f"{n}.u"])
        core = p[f"{n}.core"]
        if use_pallas:
            y = pl_gconv.grouped_conv2d(
                y, core, groups=g, stride=t.stride, padding=t.padding
            )
        else:
            y = R.grouped_conv2d(
                y, core, groups=g, stride=t.stride, padding=t.padding
            )
        return R.conv1x1(y, p[f"{n}.v"])
    raise ValueError(f"cannot apply scheme {scheme!r} at {t.name}")


def forward(
    arch: Arch,
    plan: dict[str, Scheme],
    params: dict[str, jax.Array],
    x: jax.Array,
    *,
    use_pallas: bool = False,
) -> jax.Array:
    """Full network forward. x: [N, 3, H, W] -> logits [N, classes]."""
    site_list = sites(arch)
    by_name = {t.name: t for t in site_list}

    def site_bn_relu(name: str, x: jax.Array, relu: bool = True) -> jax.Array:
        t = by_name[name]
        # Merged conv1/conv3 sites carry rewritten weights under their own
        # names; `merged_into` is resolved by _apply_site via stored params.
        y = _apply_site(t, plan, params, x, use_pallas=use_pallas)
        y = _bn(y, params[f"{name}.bn.g"], params[f"{name}.bn.b"])
        return jax.nn.relu(y) if relu else y

    # Stem
    y = site_bn_relu("stem.conv", x)
    y = _maxpool(y, 3, 2, 1)

    c_in = arch.width
    for si, (n_blocks, w) in enumerate(zip(arch.layers, arch.stage_widths)):
        stride = 1 if si == 0 else 2
        c_out = w * arch.expansion if arch.block == "bottleneck" else w
        for bi in range(n_blocks):
            pre = f"layer{si + 1}.{bi}"
            blk_stride = stride if bi == 0 else 1
            identity = y
            if arch.block == "bottleneck":
                h = site_bn_relu(f"{pre}.conv1", y)
                h = site_bn_relu(f"{pre}.conv2", h)
                h = site_bn_relu(f"{pre}.conv3", h, relu=False)
            else:
                h = site_bn_relu(f"{pre}.conv1", y)
                h = site_bn_relu(f"{pre}.conv2", h, relu=False)
            if f"{pre}.downsample" in by_name:
                identity = site_bn_relu(f"{pre}.downsample", y, relu=False)
            y = jax.nn.relu(h + identity)
            c_in = c_out
    _ = c_in

    # Head
    y = jnp.mean(y, axis=(2, 3))  # global average pool -> [N, F]
    fcn = "fc"
    scheme = plan.get(fcn, ("orig",))
    if scheme[0] == "svd":
        w0, w1 = params[f"{fcn}.w0"], params[f"{fcn}.w1"]
        if use_pallas:
            logits = pl_lrmm.lowrank_matmul(y, w0.T, w1.T)
        else:
            logits = R.lowrank_matmul(y, w0.T, w1.T)
    else:
        logits = y @ params[f"{fcn}.w"].T
    return logits + params[f"{fcn}.b"]


def _maxpool(x: jax.Array, k: int, stride: int, padding: int) -> jax.Array:
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        (1, 1, k, k),
        (1, 1, stride, stride),
        [(0, 0), (0, 0), (padding, padding), (padding, padding)],
    )


# --------------------------------------------------------------------------
# Cost accounting (mirrored by rust `model::cost` — keep in sync)
# --------------------------------------------------------------------------


def count_layers(arch: Arch, plan: dict[str, Scheme]) -> int:
    """Conv+FC layer count, the paper's Table 1 "Layers" column."""
    n = 0
    for t in sites(arch):
        if t.kind == "downsample":
            continue  # torch convention: downsample convs aren't counted
        scheme = plan.get(t.name, ("orig",))
        n += {
            "orig": 1,
            "merged": 1,
            "merged_into": 1,
            "svd": 2,
            "tucker": 3,
            "branched": 3,
        }[scheme[0]]
    return n


def count_params(plan: dict[str, Scheme], params: dict[str, jax.Array]) -> int:
    return sum(int(v.size) for v in params.values())


def flops(
    arch: Arch, plan: dict[str, Scheme], hw: int = 224
) -> int:
    """Multiply-accumulate count of the conv/fc stack (x2 for FLOPs)."""
    total = 0
    h = w = hw
    site_list = sites(arch)
    by_name = {t.name: t for t in site_list}
    spatial: dict[str, tuple[int, int]] = {}
    # replay the forward's spatial sizes
    h, w = (hw + 1) // 2, (hw + 1) // 2  # stem stride 2
    spatial["stem.conv"] = (h, w)
    h, w = (h + 1) // 2, (w + 1) // 2  # maxpool
    for si, n_blocks in enumerate(arch.layers):
        stride = 1 if si == 0 else 2
        for bi in range(n_blocks):
            pre = f"layer{si + 1}.{bi}"
            blk_stride = stride if bi == 0 else 1
            h_in, w_in = h, w
            if blk_stride == 2:
                h, w = (h + 1) // 2, (w + 1) // 2
            if arch.block == "bottleneck":
                # conv1 is stride-1 and runs at the block's input resolution;
                # the stride lives on conv2.
                spatial[f"{pre}.conv1"] = (h_in, w_in)
                spatial[f"{pre}.conv2"] = (h, w)
                spatial[f"{pre}.conv3"] = (h, w)
            else:
                spatial[f"{pre}.conv1"] = (h, w)
                spatial[f"{pre}.conv2"] = (h, w)
            if f"{pre}.downsample" in by_name:
                spatial[f"{pre}.downsample"] = (h, w)
    spatial["fc"] = (1, 1)
    for t in site_list:
        ho, wo = spatial[t.name]
        total += _site_macs(t, plan, ho, wo)
    return total


def _site_macs(t: ConvSite, plan: dict[str, Scheme], ho: int, wo: int) -> int:
    scheme = plan.get(t.name, ("orig",))
    a = ho * wo
    k2 = t.k * t.k
    kind = scheme[0]
    if kind == "orig":
        return a * t.c * t.s * k2
    if kind == "svd":
        r = scheme[1]
        return a * r * (t.c + t.s)
    if kind == "tucker":
        r1, r2 = scheme[1], scheme[2]
        return a * (t.c * r1 + r1 * r2 * k2 + r2 * t.s)
    if kind == "branched":
        r1, r2, g = scheme[1], scheme[2], scheme[3]
        return a * (t.c * r1 + (r1 // g) * (r2 // g) * k2 * g + r2 * t.s)
    if kind == "merged":
        # conv2 core only; merged 1x1s accounted by their own sites
        r1, r2 = scheme[1], scheme[2]
        return a * r1 * r2 * k2
    if kind == "merged_into":
        # rewritten 1x1: conv1' is [r1, C], conv3' is [S, r2] (Fig. 3)
        peer = plan[scheme[1]]
        r1, r2 = peer[1], peer[2]
        return a * t.c * r1 if t.name.endswith(".conv1") else a * r2 * t.s
    raise ValueError(f"unknown scheme {scheme!r}")
