"""L2 training step: SGD+momentum fine-tuning with optional layer freezing.

The paper's Layer Freezing (§2.2) accelerates *fine-tuning* by treating the
SVD/Tucker 1x1 factors as fixed "transformation functions": their gradients
are never computed. We implement that with a per-parameter trainable mask —
frozen params are routed around ``jax.grad`` (closed over, not
differentiated), so the saving is real in the lowered HLO, not a masked
no-op update.

The whole step (fwd + bwd + momentum update) lowers to ONE HLO artifact per
(arch, variant); the rust trainsim driver calls it in a loop. Parameters
are passed/returned as a flat, name-sorted tuple of arrays (the manifest
records the order).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from . import resnet as RN


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def param_order(params: dict[str, jax.Array]) -> list[str]:
    """Canonical (sorted) parameter order used by every flat interface."""
    return sorted(params.keys())


def make_train_step(
    arch: RN.Arch,
    plan: dict[str, RN.Scheme],
    mask: dict[str, bool] | None,
    *,
    lr: float = 0.05,
    momentum: float = 0.9,
    use_pallas: bool = False,
) -> Callable:
    """Build ``step(trainable, frozen, velocity, x, y) -> (new_t, new_v, loss, acc)``.

    ``trainable``/``frozen``/``velocity`` are dicts; freezing is structural:
    only ``trainable`` is differentiated, so the bwd graph for frozen 1x1
    factors is absent from the lowered HLO (the paper's training speedup).
    """

    def loss_fn(trainable, frozen, x, y):
        params = {**trainable, **frozen}
        logits = RN.forward(arch, plan, params, x, use_pallas=use_pallas)
        return cross_entropy(logits, y), logits

    def step(trainable, frozen, velocity, x, y):
        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            trainable, frozen, x, y
        )
        # Global-norm gradient clipping: decomposed stacks can transiently
        # amplify gradients through the factor pairs (w1 @ w0); clipping
        # keeps full fine-tuning stable at the same lr the original uses.
        gnorm = jnp.sqrt(
            sum(jnp.sum(g * g) for g in grads.values()) + 1e-12
        )
        clip = jnp.minimum(1.0, 5.0 / gnorm)
        new_v = {k: momentum * velocity[k] + grads[k] * clip for k in trainable}
        new_t = {k: trainable[k] - lr * new_v[k] for k in trainable}
        return new_t, new_v, loss, accuracy(logits, y)

    _ = mask
    return step


def split_by_mask(
    params: dict[str, jax.Array], mask: dict[str, bool] | None
) -> tuple[dict[str, jax.Array], dict[str, jax.Array]]:
    """Partition params into (trainable, frozen) dicts per the mask."""
    if mask is None:
        return dict(params), {}
    trainable = {k: v for k, v in params.items() if mask.get(k, True)}
    frozen = {k: v for k, v in params.items() if not mask.get(k, True)}
    return trainable, frozen


def make_flat_train_step(
    arch: RN.Arch,
    plan: dict[str, RN.Scheme],
    params: dict[str, jax.Array],
    mask: dict[str, bool] | None,
    *,
    lr: float = 0.05,
    momentum: float = 0.9,
    use_pallas: bool = False,
):
    """Flat-tuple wrapper for AOT export.

    Returns ``(fn, t_names, f_names)`` where
    ``fn(*t_arrays, *f_arrays, *v_arrays, x, y) -> (t'..., v'..., loss, acc)``
    with arrays in name-sorted order — the rust side reads the manifest and
    feeds/collects buffers positionally.
    """
    trainable, frozen = split_by_mask(params, mask)
    t_names = param_order(trainable)
    f_names = param_order(frozen)
    step = make_train_step(
        arch, plan, mask, lr=lr, momentum=momentum, use_pallas=use_pallas
    )

    def fn(*args):
        nt, nf = len(t_names), len(f_names)
        t = dict(zip(t_names, args[:nt]))
        f = dict(zip(f_names, args[nt : nt + nf]))
        v = dict(zip(t_names, args[nt + nf : 2 * nt + nf]))
        x, y = args[2 * nt + nf], args[2 * nt + nf + 1]
        new_t, new_v, loss, acc = step(t, f, v, x, y)
        return tuple(
            [new_t[k] for k in t_names] + [new_v[k] for k in t_names] + [loss, acc]
        )

    return fn, t_names, f_names


def make_flat_forward(
    arch: RN.Arch,
    plan: dict[str, RN.Scheme],
    params: dict[str, jax.Array],
    *,
    use_pallas: bool = False,
):
    """Flat-tuple inference fn for AOT export: ``fn(*params, x) -> (logits,)``."""
    names = param_order(params)

    def fn(*args):
        p = dict(zip(names, args[: len(names)]))
        x = args[len(names)]
        return (RN.forward(arch, plan, p, x, use_pallas=use_pallas),)

    return fn, names


# --------------------------------------------------------------------------
# Synthetic dataset (substitute for ImageNet — DESIGN.md §3)
# --------------------------------------------------------------------------


def synthetic_batch(
    key: jax.Array, batch: int, hw: int, classes: int
) -> tuple[jax.Array, jax.Array]:
    """Class-conditional structured images: each class is a distinct mixture
    of oriented sinusoidal gratings + class-colored mean, plus noise. Linear
    probes get ~chance; small CNNs separate them well — enough signal to
    measure the *relative* accuracy recovery of LRD variants."""
    kl, kn, kp = jax.random.split(key, 3)
    y = jax.random.randint(kl, (batch,), 0, classes)
    xs = jnp.linspace(0.0, 1.0, hw)
    xx, yy = jnp.meshgrid(xs, xs)
    freqs = 2.0 + 2.0 * jnp.arange(classes, dtype=jnp.float32)
    angle = jnp.pi * jnp.arange(classes, dtype=jnp.float32) / classes
    rot = (
        xx[None] * jnp.cos(angle)[:, None, None]
        + yy[None] * jnp.sin(angle)[:, None, None]
    )
    gratings = jnp.sin(2 * jnp.pi * freqs[:, None, None] * rot)  # [cls, hw, hw]
    mean_rgb = jax.nn.one_hot(jnp.arange(classes) % 3, 3)  # [cls, 3]
    phase = jax.random.uniform(kp, (batch, 1, 1)) * 2 * jnp.pi
    base = gratings[y] * jnp.cos(phase) + jnp.sqrt(1 - jnp.cos(phase) ** 2) * gratings[
        (y + 1) % classes
    ]
    x = base[:, None, :, :] * (0.5 + mean_rgb[y][:, :, None, None])
    x = x + 0.35 * jax.random.normal(kn, x.shape)
    return x.astype(jnp.float32), y
