"""AOT emitter: artifacts exist, manifests are consistent, HLO text is sane."""

import json
import pathlib

import numpy as np
import pytest

from compile import aot


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    fwd = aot.emit_forward(out, "resnet-mini", "lrd", hw=32, batch=2)
    trn = aot.emit_train(out, "resnet-mini", "freeze", hw=32, batch=4)
    return out, fwd, trn


class TestForwardArtifact:
    def test_hlo_text_structure(self, emitted):
        out, fwd, _ = emitted
        text = (out / fwd["hlo"]).read_text()
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # return_tuple=True: root is a tuple
        assert "(f32[" in text

    def test_param_files_match_manifest_shapes(self, emitted):
        out, fwd, _ = emitted
        for p in fwd["params"]:
            data = np.fromfile(out / p["file"], dtype=np.float32)
            assert data.size == int(np.prod(p["shape"])), p["name"]

    def test_expected_logits_recorded(self, emitted):
        _, fwd, _ = emitted
        row = fwd["expected"]["logits_row0"]
        assert len(row) == 8
        assert all(np.isfinite(row))

    def test_det_input_reproducible(self):
        a = aot.det_input(2, 8)
        b = aot.det_input(2, 8)
        np.testing.assert_array_equal(a, b)
        assert a[0, 0, 0, 0] == np.float32(0.0)
        assert abs(float(a.flat[1]) - np.sin(0.01) * 0.5) < 1e-9

    def test_plan_serialised(self, emitted):
        _, fwd, _ = emitted
        assert fwd["plan"]["stem.conv"] == ["orig"]
        assert fwd["plan"]["layer1.0.conv2"][0] == "tucker"


class TestTrainArtifact:
    def test_frozen_params_nonempty(self, emitted):
        _, _, trn = emitted
        assert len(trn["frozen_params"]) > 0
        for p in trn["frozen_params"]:
            assert p["name"].endswith((".w0", ".u", ".v"))

    def test_loss0_near_log_classes(self, emitted):
        _, _, trn = emitted
        # untrained net on 10 classes: loss ~ ln(10) = 2.30 (one-shot-KD init
        # keeps the head near uniform)
        assert 1.0 < trn["expected"]["loss0"] < 4.5

    def test_hlo_has_int_labels(self, emitted):
        out, _, trn = emitted
        text = (out / trn["hlo"]).read_text()
        assert "s32[4]" in text  # the label argument


class TestManifestCli:
    def test_cli_writes_manifest(self, tmp_path, monkeypatch):
        import sys

        monkeypatch.setattr(
            sys,
            "argv",
            ["aot", "--out", str(tmp_path), "--only", "resnet-mini_merged"],
        )
        aot.main()
        m = json.loads((tmp_path / "manifest.json").read_text())
        names = sorted(e["name"] for e in m["artifacts"])
        # the merged filter matches both the fwd and the train job
        assert names == [
            "resnet-mini_merged_hw32_b32_train",
            "resnet-mini_merged_hw32_b8_fwd",
        ]
        for e in m["artifacts"]:
            assert (tmp_path / e["hlo"]).exists()
            assert all((tmp_path / p["file"]).exists() for p in e["params"])
