"""Decomposition math vs the paper's equations and Table 2 rank values."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import decompose as D
from compile.kernels import ref

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


class TestSvd:
    def test_full_rank_exact(self):
        w = rand(0, 24, 32)
        f = D.svd_decompose(w, 24)
        np.testing.assert_allclose(D.svd_reconstruct(f), w, rtol=1e-4, atol=1e-4)

    @given(r=st.integers(1, 24))
    def test_shapes(self, r):
        w = rand(0, 24, 32)
        f = D.svd_decompose(w, r)
        assert f.w0.shape == (r, 32) and f.w1.shape == (24, r)

    def test_reconstruction_error_monotone_in_rank(self):
        w = rand(0, 32, 32)
        errs = []
        for r in (4, 8, 16, 32):
            f = D.svd_decompose(w, r)
            errs.append(float(jnp.linalg.norm(D.svd_reconstruct(f) - w)))
        assert errs == sorted(errs, reverse=True)
        assert errs[-1] < 1e-3

    def test_truncation_is_best_approximation(self):
        # Eckart-Young: SVD truncation beats a random rank-r factorisation
        w = rand(0, 16, 16)
        f = D.svd_decompose(w, 4)
        best = float(jnp.linalg.norm(D.svd_reconstruct(f) - w))
        rnd = float(jnp.linalg.norm(rand(1, 16, 4) @ rand(2, 4, 16) - w))
        assert best < rnd

    def test_factors_absorb_sqrt_sigma(self):
        # both factors should carry sqrt(sigma): their spectra match
        w = rand(0, 16, 16)
        f = D.svd_decompose(w, 8)
        s0 = jnp.linalg.svd(f.w0, compute_uv=False)
        s1 = jnp.linalg.svd(f.w1, compute_uv=False)
        np.testing.assert_allclose(s0, s1, rtol=1e-3, atol=1e-4)


class TestRankSelection:
    """Pin the paper's Table 2 '2x Ranks' column exactly."""

    @pytest.mark.parametrize(
        "c,s,expect",
        [
            (64, 64, 16),  # layer1.0.conv1
            (64, 256, 25),  # layer1.0.conv3
            (2048, 512, 204),  # layer4.2.conv1
            (512, 2048, 204),  # layer4.2.conv3
        ],
    )
    def test_svd_ranks_table2(self, c, s, expect):
        assert D.svd_rank_for_ratio(c, s, 2.0) == expect

    def test_fc_rank_table2(self):
        # paper reports 335 for fc 2048 -> 1001 @ 2x (floor-of-floor); we get
        # the exact algebraic floor 336 — assert within one
        assert abs(D.svd_rank_for_ratio(2048, 1001, 2.0) - 335) <= 1

    @pytest.mark.parametrize(
        "c,s,expect_r1",
        [(64, 64, 38), (512, 512, 309)],  # layer1.0.conv2, layer4.2.conv2
    )
    def test_tucker_ranks_table2(self, c, s, expect_r1):
        r1, r2 = D.tucker_rank_for_ratio(c, s, 3, 2.0)
        assert r1 == expect_r1
        assert r2 == expect_r1  # square layers: beta = 1

    @given(
        c=st.sampled_from([64, 128, 256, 512]),
        s=st.sampled_from([64, 128, 256, 512]),
        alpha=st.sampled_from([1.5, 2.0, 3.0, 4.0]),
    )
    def test_tucker_ratio_achieved(self, c, s, alpha):
        """eq. (7) really does produce ~alpha x compression."""
        k = 3
        r1, r2 = D.tucker_rank_for_ratio(c, s, k, alpha)
        orig = c * s * k * k
        dec = c * r1 + r1 * r2 * k * k + r2 * s
        assert dec <= orig / alpha * 1.05  # rounding slack
        # and not over-compressed by more than the integer-floor effect
        r1f = r1 + 1
        r2f = int(r1f * s / c)
        dec_next = c * r1f + r1f * r2f * k * k + r2f * s
        assert dec_next >= orig / alpha * 0.9

    @given(
        c=st.integers(8, 512),
        s=st.integers(8, 512),
        alpha=st.sampled_from([1.0, 2.0, 4.0]),
    )
    def test_svd_rank_bounds(self, c, s, alpha):
        r = D.svd_rank_for_ratio(c, s, alpha)
        assert 1 <= r <= min(c, s)


class TestTucker:
    def test_full_rank_exact(self):
        w = rand(0, 12, 10, 3, 3)
        f = D.tucker2_decompose(w, 10, 12)
        np.testing.assert_allclose(
            D.tucker2_reconstruct(f), w, rtol=1e-3, atol=1e-4
        )

    def test_shapes(self):
        w = rand(0, 24, 16, 3, 3)
        f = D.tucker2_decompose(w, 5, 7)
        assert f.u.shape == (5, 16)
        assert f.core.shape == (7, 5, 3, 3)
        assert f.v.shape == (24, 7)

    def test_stack_matches_reconstruction_conv(self):
        """Fig. 1b: running the 3-layer stack == conv with W' (reconstructed)."""
        w = rand(0, 12, 8, 3, 3)
        f = D.tucker2_decompose(w, 6, 9)
        x = rand(1, 2, 8, 10, 10)
        via_stack = ref.tucker_conv_stack(x, f.u, f.core, f.v, padding=1)
        via_recon = ref.conv2d(x, D.tucker2_reconstruct(f), padding=1)
        np.testing.assert_allclose(via_stack, via_recon, rtol=1e-3, atol=1e-3)

    def test_error_monotone_in_rank(self):
        w = rand(0, 16, 16, 3, 3)
        errs = []
        for r in (2, 4, 8, 16):
            f = D.tucker2_decompose(w, r, r)
            errs.append(float(jnp.linalg.norm(D.tucker2_reconstruct(f) - w)))
        assert errs == sorted(errs, reverse=True)

    def test_factor_orthonormality(self):
        w = rand(0, 16, 16, 3, 3)
        f = D.tucker2_decompose(w, 8, 8)
        np.testing.assert_allclose(f.u @ f.u.T, jnp.eye(8), atol=1e-4)
        np.testing.assert_allclose(f.v.T @ f.v, jnp.eye(8), atol=1e-4)


class TestMerge:
    def test_shapes(self):
        w1, w3 = rand(0, 16, 8), rand(1, 32, 16)  # conv1 [M,C], conv3 [S,M]
        f = D.tucker2_decompose(rand(2, 16, 16, 3, 3), 6, 7)
        m = D.merge_bottleneck(w1, f, w3)
        assert m.w1m.shape == (6, 8)
        assert m.core.shape == (7, 6, 3, 3)
        assert m.w3m.shape == (32, 7)

    def test_linear_equivalence_without_nonlinearity(self):
        """With BN/ReLU removed, merged == conv1 -> tucker-stack -> conv3."""
        c, m_ch, s = 8, 16, 32
        w1, w3 = rand(0, m_ch, c), rand(1, s, m_ch)
        w2 = rand(2, m_ch, m_ch, 3, 3)
        f = D.tucker2_decompose(w2, 16, 16)  # full rank: exact
        mg = D.merge_bottleneck(w1, f, w3)
        x = rand(3, 2, c, 9, 9)
        ref_path = ref.conv1x1(x, w1)
        ref_path = ref.tucker_conv_stack(ref_path, f.u, f.core, f.v, padding=1)
        ref_path = ref.conv1x1(ref_path, w3)
        got = ref.conv1x1(x, mg.w1m)
        got = ref.conv2d(got, mg.core, padding=1)
        got = ref.conv1x1(got, mg.w3m)
        np.testing.assert_allclose(got, ref_path, rtol=1e-3, atol=1e-3)


class TestBranch:
    def test_quantize_ranks(self):
        assert D.quantize_ranks(309, 309, 4) == (308, 308)
        assert D.quantize_ranks(3, 3, 4) == (4, 4)  # clamps up to N

    def test_rejects_indivisible(self):
        f = D.tucker2_decompose(rand(0, 8, 8, 3, 3), 6, 6)
        with pytest.raises(ValueError):
            D.branch_tucker(f, 4)

    def test_grouped_core_shape_and_params(self):
        f = D.tucker2_decompose(rand(0, 16, 16, 3, 3), 8, 8)
        b = D.branch_tucker(f, 4)
        assert b.core.shape == (8, 2, 3, 3)  # [r2, r1/N, k, k]
        assert b.core.size == f.core.size // 4  # eq. (18)-(20)

    def test_diagonal_blocks_kept(self):
        f = D.tucker2_decompose(rand(0, 8, 8, 3, 3), 4, 4)
        b = D.branch_tucker(f, 2)
        np.testing.assert_allclose(b.core[0:2, :, :, :], f.core[0:2, 0:2])
        np.testing.assert_allclose(b.core[2:4, :, :, :], f.core[2:4, 2:4])

    def test_branched_forward_matches_explicit_branches(self):
        """decompose.branch_tucker + grouped conv == explicit eq. (17) sum."""
        w = rand(0, 16, 12, 3, 3)
        f = D.tucker2_decompose(w, 8, 8)
        b = D.branch_tucker(f, 4)
        x = rand(1, 2, 12, 9, 9)
        got = ref.conv1x1(x, b.u)
        got = ref.grouped_conv2d(got, b.core, groups=4, padding=1)
        got = ref.conv1x1(got, b.v)
        us = jnp.stack([f.u[j * 2 : (j + 1) * 2] for j in range(4)])
        cores = jnp.stack(
            [f.core[j * 2 : (j + 1) * 2, j * 2 : (j + 1) * 2] for j in range(4)]
        )
        vs = jnp.stack([f.v[:, j * 2 : (j + 1) * 2] for j in range(4)])
        want = ref.branched_tucker(x, us, cores, vs, padding=1)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
