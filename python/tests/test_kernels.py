"""L1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/strides/groups/ranks; fixed cases pin the exact
ResNet shapes the paper benchmarks.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d as pl_conv
from compile.kernels import grouped_conv as pl_gconv
from compile.kernels import lowrank_matmul as pl_lrmm
from compile.kernels import ref

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


# --------------------------------------------------------------------------
# lowrank_matmul
# --------------------------------------------------------------------------


class TestLowrankMatmul:
    @given(
        b=st.integers(1, 64),
        c=st.integers(1, 96),
        r=st.integers(1, 48),
        s=st.integers(1, 96),
    )
    def test_matches_ref(self, b, c, r, s):
        x, w0, w1 = rand(0, b, c), rand(1, c, r), rand(2, r, s)
        got = pl_lrmm.lowrank_matmul(x, w0, w1)
        want = ref.lowrank_matmul(x, w0, w1)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_resnet_fc_shape(self):
        # the paper's fc site: 2048 -> 1001 at rank 335 (Table 2)
        x, w0, w1 = rand(0, 8, 2048), rand(1, 2048, 335), rand(2, 335, 1001)
        got = pl_lrmm.lowrank_matmul(x, w0, w1)
        np.testing.assert_allclose(
            got, ref.lowrank_matmul(x, w0, w1), rtol=1e-3, atol=1e-3
        )

    @pytest.mark.parametrize("block_m,block_n", [(8, 8), (32, 128), (128, 32)])
    def test_block_shapes_equivalent(self, block_m, block_n):
        x, w0, w1 = rand(0, 48, 64), rand(1, 64, 16), rand(2, 16, 40)
        got = pl_lrmm.lowrank_matmul(x, w0, w1, block_m=block_m, block_n=block_n)
        np.testing.assert_allclose(
            got, ref.lowrank_matmul(x, w0, w1), rtol=1e-4, atol=1e-4
        )

    def test_equals_full_matmul_at_full_rank(self):
        # eq. (1): with R = min(C, S) the factorisation is exact
        w = rand(3, 32, 24)
        u, s, vt = jnp.linalg.svd(w, full_matrices=False)
        w0 = u * jnp.sqrt(s)[None, :]
        w1 = jnp.sqrt(s)[:, None] * vt
        x = rand(0, 16, 32)
        np.testing.assert_allclose(
            pl_lrmm.lowrank_matmul(x, w0, w1), x @ w, rtol=1e-3, atol=1e-3
        )

    def test_vmem_estimate_positive_and_monotone_in_rank(self):
        lo = pl_lrmm.vmem_bytes(32, 256, 16, 256)
        hi = pl_lrmm.vmem_bytes(32, 256, 128, 256)
        assert 0 < lo < hi

    def test_mxu_flops(self):
        assert pl_lrmm.mxu_flops(2, 3, 5, 7) == 2 * 3 * 5 + 2 * 5 * 7


# --------------------------------------------------------------------------
# conv2d
# --------------------------------------------------------------------------


class TestConv2d:
    @given(
        n=st.integers(1, 3),
        c=st.integers(1, 8),
        s=st.integers(1, 12),
        h=st.integers(5, 14),
        k=st.sampled_from([1, 3]),
        stride=st.sampled_from([1, 2]),
        padding=st.integers(0, 2),
    )
    def test_matches_ref(self, n, c, s, h, k, stride, padding):
        if h + 2 * padding < k:
            return
        x, w = rand(0, n, c, h, h), rand(1, s, c, k, k)
        got = pl_conv.conv2d(x, w, stride=stride, padding=padding)
        want = ref.conv2d(x, w, stride=stride, padding=padding)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_resnet_core_shape(self):
        # Tucker core of the paper's [512,512,3,3] layer at rank 309
        x, w = rand(0, 1, 309, 8, 8), rand(1, 309, 309, 3, 3)
        got = pl_conv.conv2d(x, w, stride=1, padding=1)
        want = ref.conv2d(x, w, stride=1, padding=1)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_7x7_stride2_stem(self):
        x, w = rand(0, 2, 3, 32, 32), rand(1, 16, 3, 7, 7)
        got = pl_conv.conv2d(x, w, stride=2, padding=3)
        want = ref.conv2d(x, w, stride=2, padding=3)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_output_channel_tiling(self):
        x, w = rand(0, 1, 4, 10, 10), rand(1, 96, 4, 3, 3)
        got = pl_conv.conv2d(x, w, padding=1, block_s=32)
        want = ref.conv2d(x, w, padding=1)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_vmem_estimate(self):
        assert pl_conv.vmem_bytes(64, 64, 16, 16, 3, padding=1) > 0

    def test_mxu_flops(self):
        assert pl_conv.mxu_flops(1, 2, 3, 4, 5, 3) == 1 * 3 * 2 * 9 * 4 * 5


# --------------------------------------------------------------------------
# grouped_conv2d
# --------------------------------------------------------------------------


class TestGroupedConv:
    @given(
        n=st.integers(1, 2),
        cg=st.integers(1, 6),
        sg=st.integers(1, 6),
        g=st.sampled_from([1, 2, 4]),
        h=st.integers(5, 12),
        stride=st.sampled_from([1, 2]),
    )
    def test_matches_ref(self, n, cg, sg, g, h, stride):
        c, s = cg * g, sg * g
        x, w = rand(0, n, c, h, h), rand(1, s, cg, 3, 3)
        got = pl_gconv.grouped_conv2d(x, w, groups=g, stride=stride, padding=1)
        want = ref.grouped_conv2d(x, w, groups=g, stride=stride, padding=1)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_groups_one_equals_dense(self):
        x, w = rand(0, 2, 8, 9, 9), rand(1, 12, 8, 3, 3)
        got = pl_gconv.grouped_conv2d(x, w, groups=1, padding=1)
        want = ref.conv2d(x, w, padding=1)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_rejects_bad_grouping(self):
        x, w = rand(0, 1, 6, 8, 8), rand(1, 8, 2, 3, 3)
        with pytest.raises(ValueError):
            pl_gconv.grouped_conv2d(x, w, groups=4)

    def test_core_params_eq_18_20(self):
        # eq. (18)-(20): branched core holds 1/N of the vanilla core params
        r1, r2, k = 308, 308, 3
        for n in (1, 2, 4, 7, 11, 14, 22, 28, 44, 77, 154):
            if r1 % n == 0:
                assert pl_gconv.core_params(r1, r2, k, n) == r1 * r2 * k * k // n


# --------------------------------------------------------------------------
# Fig. 4: branched Tucker == grouped conv implementation
# --------------------------------------------------------------------------


class TestBranchedEquivalence:
    @given(
        g=st.sampled_from([1, 2, 4]),
        r1=st.integers(1, 4),
        r2=st.integers(1, 4),
        c=st.integers(2, 8),
        s=st.integers(2, 8),
    )
    def test_branch_sum_equals_grouped(self, g, r1, r2, c, s):
        x = rand(0, 1, c, 8, 8)
        us = rand(1, g, r1, c)
        cores = rand(2, g, r2, r1, 3, 3)
        vs = rand(3, g, s, r2)
        a = ref.branched_tucker(x, us, cores, vs, padding=1)
        b = ref.branched_as_grouped(x, us, cores, vs, padding=1)
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)

    def test_grouped_path_through_pallas(self):
        g, r1, r2, c, s = 4, 3, 5, 8, 12
        x = rand(0, 2, c, 8, 8)
        us = rand(1, g, r1, c)
        cores = rand(2, g, r2, r1, 3, 3)
        vs = rand(3, g, s, r2)
        want = ref.branched_tucker(x, us, cores, vs, padding=1)
        u_cat = us.reshape(g * r1, c)
        core_cat = cores.reshape(g * r2, r1, 3, 3)
        v_cat = jnp.concatenate([vs[j] for j in range(g)], axis=1)
        y = ref.conv1x1(x, u_cat)
        y = pl_gconv.grouped_conv2d(y, core_cat, groups=g, padding=1)
        got = ref.conv1x1(y, v_cat)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
