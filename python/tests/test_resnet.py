"""L2 model: variants, layer counts (Table 1/3), FLOPs, one-shot init."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import decompose as D
from compile import resnet as RN

MINI = RN.ARCHS["resnet-mini"]


@pytest.fixture(scope="module")
def mini_params():
    return RN.init_params(MINI, jax.random.PRNGKey(0))


def x_batch(b=2, hw=32):
    return jax.random.normal(jax.random.PRNGKey(1), (b, 3, hw, hw))


class TestSites:
    def test_resnet50_site_count(self):
        s = RN.sites(RN.ARCHS["resnet50"])
        convs = [t for t in s if t.kind in ("stem", "conv")]
        downs = [t for t in s if t.kind == "downsample"]
        assert len(convs) == 1 + 16 * 3  # stem + 16 bottlenecks x 3
        assert len(downs) == 4
        assert s[-1].kind == "fc" and s[-1].c == 2048 and s[-1].s == 1000

    def test_table2_shapes_present(self):
        """The exact layer shapes the paper's Table 2 lists for ResNet-152."""
        by = {t.name: t for t in RN.sites(RN.ARCHS["resnet152"])}
        assert (by["layer1.0.conv1"].c, by["layer1.0.conv1"].s) == (64, 64)
        assert (by["layer1.0.conv2"].c, by["layer1.0.conv2"].s) == (64, 64)
        assert (by["layer1.0.conv3"].c, by["layer1.0.conv3"].s) == (64, 256)
        assert (by["layer4.2.conv1"].c, by["layer4.2.conv1"].s) == (2048, 512)
        assert (by["layer4.2.conv2"].c, by["layer4.2.conv2"].s) == (512, 512)
        assert (by["layer4.2.conv3"].c, by["layer4.2.conv3"].s) == (512, 2048)

    def test_stride_placement(self):
        by = {t.name: t for t in RN.sites(RN.ARCHS["resnet50"])}
        assert by["layer2.0.conv2"].stride == 2  # stride lives on the 3x3
        assert by["layer2.0.conv1"].stride == 1
        assert by["layer2.0.downsample"].stride == 2


class TestLayerCounts:
    """Paper Table 1: 50->115, 101->233, 152->352 conv+fc layers."""

    @pytest.mark.parametrize(
        "arch,orig,lrd",
        [("resnet50", 50, 115), ("resnet101", 101, 233), ("resnet152", 152, 352)],
    )
    def test_table1_layer_counts(self, arch, orig, lrd):
        a = RN.ARCHS[arch]
        assert RN.count_layers(a, RN.plan_variant(a, "orig")) == orig
        got = RN.count_layers(a, RN.plan_variant(a, "lrd"))
        # paper: 115/233/352; our honest count differs by <=1 for 101/152
        # (they appear not to decompose one late 1x1; see EXPERIMENTS.md)
        assert abs(got - lrd) <= 1

    @pytest.mark.parametrize("arch", ["resnet50", "resnet101", "resnet152"])
    def test_merged_restores_depth(self, arch):
        a = RN.ARCHS[arch]
        assert RN.count_layers(a, RN.plan_variant(a, "merged")) == RN.count_layers(
            a, RN.plan_variant(a, "orig")
        )


class TestCost:
    def test_resnet50_macs_canonical(self):
        a = RN.ARCHS["resnet50"]
        macs = RN.flops(a, RN.plan_variant(a, "orig"), 224)
        assert 4.0e9 < macs < 4.2e9  # canonical ~4.1 GMACs

    def test_lrd_halves_flops_roughly(self):
        a = RN.ARCHS["resnet50"]
        orig = RN.flops(a, RN.plan_variant(a, "orig"), 224)
        lrd = RN.flops(a, RN.plan_variant(a, "lrd"), 224)
        assert 0.40 < lrd / orig < 0.60  # paper: -43.26%

    def test_merged_cheaper_than_lrd(self):
        a = RN.ARCHS["resnet50"]
        lrd = RN.flops(a, RN.plan_variant(a, "lrd"), 224)
        merged = RN.flops(a, RN.plan_variant(a, "merged"), 224)
        assert merged < lrd  # paper: -55.09% vs -43.26%

    def test_branched_cheaper_than_lrd(self):
        a = RN.ARCHS["resnet152"]
        lrd = RN.flops(a, RN.plan_variant(a, "lrd"), 224)
        br = RN.flops(a, RN.plan_variant(a, "branched", groups=4), 224)
        assert br < lrd  # Table 6: -66.75% vs -47.69%

    def test_params_compression_ratio(self, mini_params):
        plan = RN.plan_variant(MINI, "lrd")
        pv = RN.decompose_params(MINI, plan, mini_params)
        n0 = sum(int(v.size) for v in mini_params.values())
        n1 = sum(int(v.size) for v in pv.values())
        assert 0.4 < n1 / n0 < 0.6


class TestForward:
    @pytest.mark.parametrize("variant", ["orig", "lrd", "merged", "branched"])
    def test_shapes_and_finiteness(self, mini_params, variant):
        plan = RN.plan_variant(MINI, variant, groups=2)
        pv = RN.decompose_params(MINI, plan, mini_params)
        logits = RN.forward(MINI, plan, pv, x_batch())
        assert logits.shape == (2, 10)
        assert bool(jnp.isfinite(logits).all())

    def test_full_rank_lrd_matches_orig(self, mini_params):
        """At full ranks the decomposition is exact, so logits must match."""
        plan = {}
        for t in RN.sites(MINI):
            if t.kind in ("stem",):
                plan[t.name] = ("orig",)
            elif t.k == 1:
                plan[t.name] = ("svd", min(t.c, t.s))
            else:
                plan[t.name] = ("tucker", t.c, t.s)
        pv = RN.decompose_params(MINI, plan, mini_params)
        got = RN.forward(MINI, plan, pv, x_batch())
        want = RN.forward(MINI, RN.plan_variant(MINI, "orig"), mini_params, x_batch())
        np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)

    def test_pallas_path_matches_ref_path(self, mini_params):
        plan = RN.plan_variant(MINI, "lrd")
        pv = RN.decompose_params(MINI, plan, mini_params)
        x = x_batch(b=2)
        a = RN.forward(MINI, plan, pv, x, use_pallas=False)
        b = RN.forward(MINI, plan, pv, x, use_pallas=True)
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)

    def test_branched_pallas_path(self, mini_params):
        plan = RN.plan_variant(MINI, "branched", groups=2)
        pv = RN.decompose_params(MINI, plan, mini_params)
        x = x_batch(b=2)
        a = RN.forward(MINI, plan, pv, x, use_pallas=False)
        b = RN.forward(MINI, plan, pv, x, use_pallas=True)
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


class TestFreezeMask:
    def test_frozen_set_is_factor_1x1s(self, mini_params):
        plan = RN.plan_variant(MINI, "lrd")
        pv = RN.decompose_params(MINI, plan, mini_params)
        mask = RN.freeze_mask(MINI, plan, pv)
        frozen = {k for k, train in mask.items() if not train}
        assert frozen  # something actually freezes
        for k in frozen:
            assert k.endswith((".w0", ".u", ".v"))
        # cores and BN affines stay trainable
        assert all(mask[k] for k in pv if k.endswith(".core"))
        assert all(mask[k] for k in pv if ".bn." in k)

    def test_frozen_fraction_substantial(self, mini_params):
        plan = RN.plan_variant(MINI, "lrd")
        pv = RN.decompose_params(MINI, plan, mini_params)
        mask = RN.freeze_mask(MINI, plan, pv)
        frozen_params = sum(int(pv[k].size) for k, t in mask.items() if not t)
        total = sum(int(v.size) for v in pv.values())
        assert frozen_params / total > 0.2  # the paper's training saving


class TestPlanSerialisation:
    @pytest.mark.parametrize("variant", ["orig", "lrd", "merged", "branched"])
    def test_plans_are_json_roundtrippable(self, variant):
        import json

        plan = RN.plan_variant(MINI, variant, groups=2)
        s = json.dumps({k: list(v) for k, v in plan.items()})
        back = {k: tuple(v) for k, v in json.loads(s).items()}
        assert back == plan
