"""Train step: loss decreases; freezing shrinks the differentiated set."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import resnet as RN
from compile import train as T

MINI = RN.ARCHS["resnet-mini"]


@pytest.fixture(scope="module")
def setup():
    p0 = RN.init_params(MINI, jax.random.PRNGKey(0))
    plan = RN.plan_variant(MINI, "lrd")
    params = RN.decompose_params(MINI, plan, p0)
    return plan, params


class TestLossAndData:
    def test_cross_entropy_uniform(self):
        logits = jnp.zeros((4, 10))
        labels = jnp.array([0, 1, 2, 3])
        np.testing.assert_allclose(
            T.cross_entropy(logits, labels), jnp.log(10.0), rtol=1e-5
        )

    def test_accuracy(self):
        logits = jnp.array([[1.0, 0.0], [0.0, 1.0], [1.0, 0.0]])
        labels = jnp.array([0, 1, 1])
        assert float(T.accuracy(logits, labels)) == pytest.approx(2 / 3)

    def test_synthetic_batch_shapes_and_balance(self):
        x, y = T.synthetic_batch(jax.random.PRNGKey(0), 64, 32, 10)
        assert x.shape == (64, 3, 32, 32) and y.shape == (64,)
        assert x.dtype == jnp.float32
        assert int(y.min()) >= 0 and int(y.max()) < 10

    def test_synthetic_classes_differ(self):
        """Different classes must be statistically distinguishable."""
        x, y = T.synthetic_batch(jax.random.PRNGKey(1), 256, 16, 4)
        means = jnp.stack([x[y == c].mean(axis=0) for c in range(4)])
        d = jnp.linalg.norm((means[0] - means[1]).ravel())
        assert float(d) > 0.05


class TestTrainStep:
    def test_loss_decreases(self, setup):
        plan, params = setup
        step = jax.jit(T.make_train_step(MINI, plan, None, lr=0.02))
        t, f = T.split_by_mask(params, None)
        v = {k: jnp.zeros_like(p) for k, p in t.items()}
        key = jax.random.PRNGKey(2)
        x, y = T.synthetic_batch(key, 32, 32, 10)
        losses = []
        for i in range(8):
            t, v, loss, _acc = step(t, f, v, x, y)
            losses.append(float(loss))
        assert losses[-1] < losses[0]

    def test_freeze_keeps_frozen_params_constant(self, setup):
        plan, params = setup
        mask = RN.freeze_mask(MINI, plan, params)
        step = jax.jit(T.make_train_step(MINI, plan, mask, lr=0.05))
        t, f = T.split_by_mask(params, mask)
        assert f  # non-empty frozen set
        f_before = {k: np.asarray(v).copy() for k, v in f.items()}
        v = {k: jnp.zeros_like(p) for k, p in t.items()}
        x, y = T.synthetic_batch(jax.random.PRNGKey(3), 16, 32, 10)
        t, v, _loss, _acc = step(t, f, v, x, y)
        for k in f:
            np.testing.assert_array_equal(np.asarray(f[k]), f_before[k])

    def test_freeze_reduces_grad_arrays(self, setup):
        plan, params = setup
        mask = RN.freeze_mask(MINI, plan, params)
        t_all, _ = T.split_by_mask(params, None)
        t_frozen, f_frozen = T.split_by_mask(params, mask)
        assert len(t_frozen) < len(t_all)
        assert len(t_frozen) + len(f_frozen) == len(t_all)

    def test_flat_wrapper_roundtrip(self, setup):
        plan, params = setup
        mask = RN.freeze_mask(MINI, plan, params)
        fn, t_names, f_names = T.make_flat_train_step(MINI, plan, params, mask)
        x, y = T.synthetic_batch(jax.random.PRNGKey(4), 8, 32, 10)
        v0 = [jnp.zeros_like(params[n]) for n in t_names]
        out = fn(
            *[params[n] for n in t_names],
            *[params[n] for n in f_names],
            *v0,
            x,
            y,
        )
        assert len(out) == 2 * len(t_names) + 2
        loss, acc = float(out[-2]), float(out[-1])
        assert np.isfinite(loss) and 0.0 <= acc <= 1.0

    def test_flat_forward_matches_dict_forward(self, setup):
        plan, params = setup
        fn, names = T.make_flat_forward(MINI, plan, params)
        x = T.synthetic_batch(jax.random.PRNGKey(5), 4, 32, 10)[0]
        (got,) = fn(*[params[n] for n in names], x)
        want = RN.forward(MINI, plan, params, x)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestGradientClipping:
    def test_large_gradient_is_clipped(self, setup):
        """The step must stay finite even from a pathological init (the
        instability we observed fine-tuning decomposed stacks)."""
        import jax
        import jax.numpy as jnp
        import numpy as np

        plan, params = setup
        # blow up one factor pair to generate huge gradients
        bad = dict(params)
        bad["layer1.0.conv1.w0"] = bad["layer1.0.conv1.w0"] * 100.0
        bad["layer1.0.conv1.w1"] = bad["layer1.0.conv1.w1"] * 100.0
        step = jax.jit(T.make_train_step(MINI, plan, None, lr=0.05))
        t, f = T.split_by_mask(bad, None)
        v = {k: jnp.zeros_like(p) for k, p in t.items()}
        x, y = T.synthetic_batch(jax.random.PRNGKey(0), 16, 32, 10)
        for _ in range(3):
            t, v, loss, _ = step(t, f, v, x, y)
            assert np.isfinite(float(loss))
        for k, p in t.items():
            assert bool(jnp.isfinite(p).all()), k

    def test_update_norm_bounded(self, setup):
        import jax
        import jax.numpy as jnp

        plan, params = setup
        lr = 0.05
        step = jax.jit(T.make_train_step(MINI, plan, None, lr=lr))
        t, f = T.split_by_mask(params, None)
        v = {k: jnp.zeros_like(p) for k, p in t.items()}
        x, y = T.synthetic_batch(jax.random.PRNGKey(1), 16, 32, 10)
        t2, v2, _, _ = step(t, f, v, x, y)
        # first step: v = clip(g), |g_clipped| <= 5 => |Δw| <= lr * 5
        total = sum(
            float(jnp.sum((t2[k] - t[k]) ** 2)) for k in t
        ) ** 0.5
        assert total <= lr * 5.0 * 1.01
