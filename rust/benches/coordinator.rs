//! `cargo bench --bench coordinator` — serving-stack overhead + batching
//! characteristics (the L3 §Perf gate): direct executable calls vs the
//! full router/batcher path, and latency percentiles under load.
use std::time::{Duration, Instant};

use lrdx::coordinator::batcher::BatchPolicy;
use lrdx::coordinator::{BatchModel, Coordinator};
use lrdx::runtime::artifacts::{ArtifactLibrary, ForwardModel};
use lrdx::runtime::Engine;
use lrdx::trainsim::data::SynthData;
use lrdx::util::rng::Rng;
use lrdx::util::stats::Summary;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP coordinator bench: run `python python/compile/aot.py --out rust/artifacts` first");
        return;
    }
    let engine = Engine::cpu().expect("engine");
    let lib = ArtifactLibrary::load("artifacts").expect("manifest");
    let spec = lib.find_by("resnet-mini", "lrd", "forward").expect("artifact");
    let direct = ForwardModel::load(&engine, spec).expect("load");
    let b = spec.batch;
    let img = 3 * spec.hw * spec.hw;
    let gen = SynthData::new(spec.hw, spec.classes);
    let mut rng = Rng::new(3);
    let (xflat, _) = gen.batch(&mut rng, b);

    // direct path
    let n_batches = 40;
    for _ in 0..4 {
        direct.run_batch(&xflat).unwrap();
    }
    let t0 = Instant::now();
    for _ in 0..n_batches {
        direct.run_batch(&xflat).unwrap();
    }
    let direct_secs = t0.elapsed().as_secs_f64();
    println!(
        "direct:      {:>8.1} img/s ({:.3} ms/batch)",
        (n_batches * b) as f64 / direct_secs,
        direct_secs / n_batches as f64 * 1e3
    );

    // coordinated path, saturated
    let mut coord = Coordinator::new(BatchPolicy {
        max_batch: b,
        max_wait: Duration::from_millis(2),
    });
    coord
        .register("m", spec.hw, 1, move |ctx| {
            let lib = ArtifactLibrary::load("artifacts")?;
            let spec = lib.find_by("resnet-mini", "lrd", "forward").unwrap();
            Ok(Box::new(ForwardModel::load(ctx.engine(), spec)?) as Box<dyn BatchModel>)
        })
        .unwrap();
    coord.infer_blocking("m", xflat[..img].to_vec()).unwrap();
    let t0 = Instant::now();
    let pending: Vec<_> = (0..n_batches * b)
        .map(|i| coord.infer("m", xflat[(i % b) * img..(i % b + 1) * img].to_vec()).unwrap())
        .collect();
    let mut lats = Vec::new();
    for rx in pending {
        lats.push(rx.recv().unwrap().unwrap().latency);
    }
    let coord_secs = t0.elapsed().as_secs_f64();
    let s = Summary::of(&lats);
    println!(
        "coordinated: {:>8.1} img/s (overhead {:+.1}%)",
        (n_batches * b) as f64 / coord_secs,
        (coord_secs / direct_secs - 1.0) * 100.0
    );
    println!(
        "latency: p50 {:.2} ms  p90 {:.2} ms  p99 {:.2} ms",
        s.p50 * 1e3,
        s.p90 * 1e3,
        s.p99 * 1e3
    );
    println!("{}", coord.metrics.snapshot().render());
    coord.shutdown();
}
