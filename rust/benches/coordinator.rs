//! `cargo bench --bench coordinator [-- --smoke]` — serving-stack bench:
//! fixed-batch padding vs the shape-bucketed executable ladder on the
//! merged O2 variant (synthetic resnet-mini netbuilder models, so no
//! artifacts are needed and CI can run the smoke subset).
//!
//! Two load shapes per mode:
//! * `light`     — sequential single blocking requests: the case a fixed
//!                 batch-8 executable answers by burning 8× the FLOPs on
//!                 padding, and a bucket ladder answers at batch 1;
//! * `saturated` — a concurrent closed-loop burst: both modes batch up,
//!                 so throughput should be comparable.
//!
//! Emits `BENCH_serve.json` (p50/p99 latency, throughput, padding-waste
//! ratio, occupancy, sheds per mode × load); `--smoke` runs a small
//! subset with the same schema (the CI schema gate).

use std::time::{Duration, Instant};

use lrdx::coordinator::batcher::BatchPolicy;
use lrdx::coordinator::{Coordinator, ServableModel};
use lrdx::decompose::{plan_variant, Variant};
use lrdx::model::Arch;
use lrdx::runtime::netbuilder::{pow2_ladder, ServableNet};
use lrdx::runtime::CompileOptions;
use lrdx::util::json::Json;
use lrdx::util::stats::Summary;

const HW: usize = 32;
const BATCH: usize = 8;

struct Row {
    mode: &'static str,
    load: &'static str,
    p50_ms: f64,
    p99_ms: f64,
    req_per_sec: f64,
    padding_waste: f64,
    occupancy: f64,
    sheds: u64,
}

/// One single-replica coordinator serving resnet-mini/merged at O2:
/// `fixed` = one ceiling bucket (the pre-ladder pad-to-8 world),
/// `bucketed` = the power-of-two ladder.
fn build_coord(mode: &'static str) -> Coordinator {
    let buckets = if mode == "fixed" { vec![BATCH] } else { pow2_ladder(BATCH) };
    let mut coord = Coordinator::with_thread_budget(
        BatchPolicy {
            max_batch: BATCH,
            max_wait: Duration::from_millis(2),
            ..Default::default()
        },
        1, // one kernel thread: stable, comparable timings
    );
    coord
        .register("m", HW, 1, move |ctx| {
            let arch = Arch::by_name("resnet-mini").expect("arch");
            let plan = plan_variant(&arch, Variant::Merged, 2.0, 2, None)?;
            let opts = CompileOptions { threads: ctx.threads(), ..Default::default() };
            let mut net = ServableNet::compile(
                ctx.engine(),
                &arch,
                &plan,
                &buckets,
                HW,
                0x5EED,
                &opts,
            )?;
            // pay every bucket's compile at registration: the measured
            // windows must price serving, not lazy compilation
            net.precompile_all()?;
            Ok(Box::new(net) as Box<dyn ServableModel>)
        })
        .expect("register");
    coord
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let light_n = if smoke { 8 } else { 40 };
    let sat_n = if smoke { 3 * BATCH } else { 15 * BATCH };
    println!(
        "serve bench: resnet-mini/merged O2 hw={HW} ceiling={BATCH} ({})",
        if smoke { "smoke" } else { "full" }
    );
    println!(
        "{:9} {:>10} {:>9} {:>9} {:>9} {:>7} {:>6} {:>6}",
        "mode", "load", "p50 ms", "p99 ms", "req/s", "waste", "occ", "sheds"
    );

    let img = lrdx::util::det_input(1, HW);
    let mut rows: Vec<Row> = Vec::new();
    for mode in ["fixed", "bucketed"] {
        for load in ["light", "saturated"] {
            let coord = build_coord(mode);
            // warmup: compiles the single-request path of either mode
            for _ in 0..3 {
                coord.infer_blocking("m", img.clone()).expect("warmup");
            }
            // baseline snapshot so every reported field covers ONLY the
            // measured window (warmup batches excluded via deltas)
            let base = coord.metrics.snapshot();
            let mut lats = Vec::with_capacity(light_n.max(sat_n));
            let t0 = Instant::now();
            let served = match load {
                "light" => {
                    for _ in 0..light_n {
                        let r = coord.infer_blocking("m", img.clone()).expect("infer");
                        lats.push(r.latency);
                    }
                    light_n
                }
                _ => {
                    let pending: Vec<_> = (0..sat_n)
                        .map(|_| coord.infer("m", img.clone()).expect("infer"))
                        .collect();
                    for rx in pending {
                        lats.push(rx.recv().expect("response").expect("ok").latency);
                    }
                    sat_n
                }
            };
            let secs = t0.elapsed().as_secs_f64();
            let snap = coord.metrics.snapshot();
            let d_items = snap.batch_items - base.batch_items;
            let d_cap = snap.bucket_capacity - base.bucket_capacity;
            let d_batches = snap.batches - base.batches;
            let s = Summary::of(&lats);
            let row = Row {
                mode,
                load,
                p50_ms: s.p50 * 1e3,
                p99_ms: s.p99 * 1e3,
                req_per_sec: served as f64 / secs,
                padding_waste: if d_cap == 0 {
                    0.0
                } else {
                    1.0 - d_items as f64 / d_cap as f64
                },
                occupancy: if d_batches == 0 {
                    0.0
                } else {
                    d_items as f64 / d_batches as f64
                },
                sheds: snap.sheds - base.sheds,
            };
            println!(
                "{:9} {:>10} {:>9.2} {:>9.2} {:>9.1} {:>6.1}% {:>6.2} {:>6}",
                row.mode,
                row.load,
                row.p50_ms,
                row.p99_ms,
                row.req_per_sec,
                row.padding_waste * 100.0,
                row.occupancy,
                row.sheds
            );
            rows.push(row);
            coord.shutdown();
        }
    }

    let fixed_light = rows.iter().find(|r| r.mode == "fixed" && r.load == "light");
    let bucketed_light = rows.iter().find(|r| r.mode == "bucketed" && r.load == "light");
    if let (Some(f), Some(b)) = (fixed_light, bucketed_light) {
        println!(
            "single-request p50: bucketed {:.2} ms vs fixed-batch-{BATCH} {:.2} ms ({:.2}x)",
            b.p50_ms,
            f.p50_ms,
            f.p50_ms / b.p50_ms
        );
    }

    let jrows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj_from(vec![
                ("mode", Json::Str(r.mode.to_string())),
                ("load", Json::Str(r.load.to_string())),
                ("p50_ms", Json::Num(r.p50_ms)),
                ("p99_ms", Json::Num(r.p99_ms)),
                ("req_per_sec", Json::Num(r.req_per_sec)),
                ("padding_waste", Json::Num(r.padding_waste)),
                ("occupancy", Json::Num(r.occupancy)),
                ("sheds", Json::Num(r.sheds as f64)),
            ])
        })
        .collect();
    let doc = Json::obj_from(vec![
        ("arch", Json::Str("resnet-mini".to_string())),
        ("variant", Json::Str("merged".to_string())),
        ("opt_level", Json::Str("O2".to_string())),
        ("hw", Json::Num(HW as f64)),
        ("ceiling_batch", Json::Num(BATCH as f64)),
        ("smoke", Json::Bool(smoke)),
        ("rows", Json::Arr(jrows)),
    ]);
    std::fs::write("BENCH_serve.json", doc.render()).expect("write BENCH_serve.json");
    println!("(saved BENCH_serve.json)");
}
