//! `cargo bench --bench decomp_search [-- --smoke]` — Algorithm 1's
//! timed rank sweep vs VBMF automatic rank selection on synthetic sites
//! with PLANTED low-rank weights.
//!
//! The contrast under measurement: Algorithm 1 compiles and wall-clocks
//! every candidate rank (search cost scales with the sweep), and its
//! R/2 floor can never reach a rank below half the eq.-7 initial rank.
//! VBMF reads the rank straight off the weight spectrum — one SVD per
//! unfolding, no compiles — so on genuinely low-rank weights it finds
//! the deep rank the sweep floor hides, at a fraction of the search
//! wall-time. Achieved speedup of both chosen schemes is scored with
//! the deterministic analytic tile model (lane 16) so the comparison is
//! reproducible; search wall-time is real. Emits `BENCH_decomp.json`;
//! `--smoke` shrinks the timer samples, same schema (the CI gate).

use lrdx::decompose::rank_opt::{
    optimize_site, vbmf_scheme, AnalyticTimer, LayerTimer, RankOptConfig,
};
use lrdx::decompose::Scheme;
use lrdx::linalg::{Matrix, Tensor4};
use lrdx::model::{ConvSite, SiteKind};
use lrdx::profiler::Timer;
use lrdx::runtime::layer_factory::EngineLayerTimer;
use lrdx::runtime::Engine;
use lrdx::util::json::Json;
use lrdx::util::rng::Rng;

const BATCH: usize = 4;
const HW: usize = 16;
const LANE: usize = 16;

fn site(name: &str, c: usize, s: usize, k: usize) -> ConvSite {
    ConvSite {
        name: name.into(),
        c,
        s,
        k,
        stride: 1,
        padding: if k > 1 { 1 } else { 0 },
        kind: SiteKind::Conv,
    }
}

/// Rank-`r` 1x1 weight plus iid noise: the spectrum VBMF reads.
fn planted_1x1(c: usize, s: usize, r: usize, rng: &mut Rng) -> Tensor4 {
    let a = Matrix::random(s, r, rng);
    let b = Matrix::random(r, c, rng);
    let mut w = a.matmul(&b);
    for x in w.data.iter_mut() {
        *x += 1e-3 * rng.normal_f32();
    }
    Tensor4::from_vec(s, c, 1, 1, w.data)
}

/// kxk weight with both channel-mode unfold ranks `r` (Tucker planted):
/// w[o,i,h,w] = Σ_{j,l} v[o,j] · g[j,l,h,w] · u[l,i], plus noise.
fn planted_kxk(c: usize, s: usize, k: usize, r: usize, rng: &mut Rng) -> Tensor4 {
    let v = Matrix::random(s, r, rng);
    let u = Matrix::random(r, c, rng);
    let g: Vec<f32> = (0..r * r * k * k).map(|_| rng.normal_f32()).collect();
    let mut data = vec![0f32; s * c * k * k];
    for o in 0..s {
        for i in 0..c {
            for h in 0..k {
                for w in 0..k {
                    let mut acc = 0f32;
                    for j in 0..r {
                        for l in 0..r {
                            acc += v[(o, j)] * g[((j * r + l) * k + h) * k + w] * u[(l, i)];
                        }
                    }
                    data[((o * c + i) * k + h) * k + w] =
                        acc / r as f32 + 1e-3 * rng.normal_f32();
                }
            }
        }
    }
    Tensor4::from_vec(s, c, k, k, data)
}

/// Deterministic achieved speedup of `scheme` vs the original layer
/// under the lane-16 analytic tile model.
fn analytic_speedup(t: &ConvSite, scheme: &Scheme) -> f64 {
    let mut timer = AnalyticTimer { lane: LANE, ..Default::default() };
    let t_orig = timer.time_layer(t, &Scheme::Orig, BATCH, HW).expect("orig");
    let t_dec = timer.time_layer(t, scheme, BATCH, HW).expect("scheme");
    t_orig / t_dec
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let samples = if smoke {
        Timer { warmup: 0, min_samples: 1, max_samples: 1, cv_target: f64::INFINITY }
    } else {
        Timer { warmup: 1, min_samples: 3, max_samples: 8, cv_target: 0.2 }
    };
    let cfg = RankOptConfig {
        alpha: 2.0,
        rmin_frac: 0.5,
        stride: 4,
        refine: 2,
        batch: BATCH,
        hw: HW,
        ..Default::default()
    };
    let mut rng = Rng::new(0xDEC0);
    let sites = [
        (site("planted.1x1", 64, 64, 1), 6usize),
        (site("planted.3x3", 64, 64, 3), 4usize),
    ];
    let weights =
        [planted_1x1(64, 64, 6, &mut rng), planted_kxk(64, 64, 3, 4, &mut rng)];

    println!(
        "Algorithm 1 vs VBMF on planted low-rank sites ({})",
        if smoke { "smoke" } else { "full" }
    );
    println!(
        "{:14} {:>7} {:>10} {:>9} {:>11} {:>10} {:>9}",
        "site", "planted", "algo1 rank", "speedup", "search ms", "vbmf pick", "speedup"
    );
    let mut jrows = Vec::new();
    let (mut algo1_total, mut vbmf_total) = (0f64, 0f64);
    let mut ratio_min = f64::INFINITY;
    for ((t, planted), w) in sites.iter().zip(weights.iter()) {
        // Algorithm 1: real compiles + wall-clock per candidate rank.
        let engine = Engine::cpu().expect("engine");
        let mut timer = EngineLayerTimer::with_timer(engine, samples.clone());
        let t0 = std::time::Instant::now();
        let d = optimize_site(&mut timer, t, &cfg).expect("optimize_site");
        let algo1_secs = t0.elapsed().as_secs_f64();
        let algo1_scheme = d.scheme(t);
        let algo1_speedup = analytic_speedup(t, &algo1_scheme);

        // VBMF: one SVD per channel-mode unfolding, no timing at all.
        let t1 = std::time::Instant::now();
        let vb_scheme = vbmf_scheme(t, w);
        let vbmf_secs = t1.elapsed().as_secs_f64().max(1e-9);
        let vbmf_speedup = analytic_speedup(t, &vb_scheme);

        algo1_total += algo1_secs;
        vbmf_total += vbmf_secs;
        ratio_min = ratio_min.min(vbmf_speedup / algo1_speedup);
        println!(
            "{:14} {:>7} {:>10} {:>8.2}x {:>11.2} {:>10} {:>8.2}x",
            t.name,
            planted,
            d.chosen_rank.map(|r| r.to_string()).unwrap_or_else(|| "ORG".into()),
            algo1_speedup,
            algo1_secs * 1e3,
            match vb_scheme {
                Scheme::Svd { r } => format!("svd{r}"),
                Scheme::Tucker { r1, r2 } => format!("tk{r1}x{r2}"),
                ref s => format!("{s:?}"),
            },
            vbmf_speedup,
        );
        jrows.push(Json::obj_from(vec![
            ("site", Json::Str(t.name.clone())),
            ("k", Json::Num(t.k as f64)),
            ("planted_rank", Json::Num(*planted as f64)),
            (
                "algo1_rank",
                d.chosen_rank.map(|r| Json::Num(r as f64)).unwrap_or(Json::Null),
            ),
            ("algo1_scheme", Json::Str(format!("{algo1_scheme:?}"))),
            ("algo1_speedup", Json::Num(algo1_speedup)),
            ("algo1_search_secs", Json::Num(algo1_secs)),
            ("algo1_timed_configs", Json::Num((d.sweep.len() + 1) as f64)),
            ("vbmf_scheme", Json::Str(format!("{vb_scheme:?}"))),
            ("vbmf_speedup", Json::Num(vbmf_speedup)),
            ("vbmf_search_secs", Json::Num(vbmf_secs)),
        ]));
    }
    let wall_ratio = algo1_total / vbmf_total;
    println!(
        "search wall-time: algo1 {:.1} ms vs vbmf {:.2} ms ({wall_ratio:.0}x); \
         min speedup ratio {ratio_min:.2}",
        algo1_total * 1e3,
        vbmf_total * 1e3
    );
    let doc = Json::obj_from(vec![
        ("smoke", Json::Bool(smoke)),
        ("batch", Json::Num(BATCH as f64)),
        ("hw", Json::Num(HW as f64)),
        ("lane", Json::Num(LANE as f64)),
        ("wall_ratio", Json::Num(wall_ratio)),
        ("speedup_ratio_min", Json::Num(ratio_min)),
        ("sites", Json::Arr(jrows)),
    ]);
    std::fs::write("BENCH_decomp.json", doc.render()).expect("write BENCH_decomp.json");
    println!("(saved BENCH_decomp.json)");
}
