//! `cargo bench --bench fig2` — rank sweep of the [512,512,3,3] layer with
//! REAL backend wall-clock timing (the paper's Fig. 2 rank-cliff curve).
use lrdx::harness::fig2;
use lrdx::runtime::Engine;

fn main() {
    let engine = Engine::cpu().expect("engine");
    let cfg = fig2::Config { real: true, step: 16, ..Default::default() };
    let report = fig2::run(&engine, &cfg).expect("fig2");
    print!("{}", report.render());
    report.save(std::path::Path::new("reports")).expect("save");
}
