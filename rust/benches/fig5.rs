//! `cargo bench --bench fig5` — model throughput vs number of Tucker
//! branches (paper Fig. 5).
use lrdx::harness::fig5;
use lrdx::runtime::Engine;

fn main() {
    let engine = Engine::cpu().expect("engine");
    let full = std::env::args().any(|a| a == "--full");
    let cfg = fig5::Config {
        arch: if full { "resnet152".into() } else { "resnet50".into() },
        ..Default::default()
    };
    let report = fig5::run(&engine, &cfg).expect("fig5");
    print!("{}", report.render());
    report.save(std::path::Path::new("reports")).expect("save");
}
