//! `cargo bench --bench native_exec [-- --smoke] [-- --arch NAME]` —
//! prices the planned arena executor against the seed's per-node
//! interpreter (`run_reference`): forward latency and throughput per
//! variant × executor × thread count × batch on the same O0 graphs, so
//! the delta is purely plan + arena + tiled parallel kernels. Also
//! sweeps the raw GEMM kernels (scalar `dot_scalar` baseline vs the
//! packed BLIS-style path, threads {1, 4}, autotuned tile) into a
//! per-shape GFLOP/s table — the standing measurement behind the
//! `PAR_MIN_MACS`/`PACK_MIN_MACS` thresholds and the cost model's lane
//! constants. Emits `BENCH_native.json` (`rows` + `gemm` sections);
//! `--smoke` runs a single-iteration subset with the same schema (the
//! CI schema gate asserts packed ≥ 2× scalar on the large square shape
//! and no regression on the small ones).

use std::sync::Arc;
use std::time::Instant;

use lrdx::decompose::{plan_variant, Variant};
use lrdx::model::Arch;
use lrdx::profiler::Timer;
use lrdx::runtime::native::kernels::{self, TileConfig};
use lrdx::runtime::native::pool::WorkerPool;
use lrdx::runtime::native::{autotune, NativeExecutable};
use lrdx::runtime::netbuilder::build_forward;
use lrdx::runtime::HostTensor;
use lrdx::util::json::Json;
use lrdx::util::rng::Rng;

/// Network arguments initialised exactly as `BuiltNet::compile` would.
fn make_args(
    arch: &Arch,
    variant: Variant,
    batch: usize,
    hw: usize,
) -> (lrdx::runtime::graph::Graph, Vec<Arc<HostTensor>>) {
    let plan = plan_variant(arch, variant, 2.0, 2, None).expect("plan");
    let (graph, specs) = build_forward(arch, &plan, batch, hw).expect("build");
    let mut rng = Rng::new(0xBE7C);
    let mut args = vec![Arc::new(HostTensor::new(
        vec![batch, 3, hw, hw],
        lrdx::util::det_input(batch, hw),
    ))];
    for spec in &specs {
        let host = lrdx::runtime::netbuilder::init_param_host(spec, &mut rng);
        args.push(Arc::new(HostTensor::new(spec.shape.clone(), host)));
    }
    (graph, args)
}

struct Row {
    variant: &'static str,
    executor: &'static str,
    threads: usize,
    batch: usize,
    secs: f64,
    speedup: f64,
    arena_peak: usize,
    arena_naive: usize,
}

struct GemmRow {
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
    scalar_gflops: f64,
    packed_gflops: f64,
    tile: String,
}

/// Best-of-`reps` per-call wall time for `f`, each rep averaging over
/// `iters` back-to-back calls (one untimed warmup call first).
fn time_best(reps: usize, iters: usize, mut f: impl FnMut()) -> f64 {
    f();
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        best = best.min(t0.elapsed().as_secs_f64() / iters as f64);
    }
    best
}

/// Raw-kernel GFLOP/s sweep: `dot_scalar` baseline vs the packed
/// microkernel at the autotuner's chosen tile. The small shapes sit
/// just above `PACK_MIN_MACS` (the planner's packing threshold, so
/// they are the worst case the packed path ships on), the large square
/// is the CI 2x acceptance gate, and the m=1 row drives the
/// tall-skinny column-panel partition.
fn gemm_sweep(smoke: bool) -> Vec<GemmRow> {
    let shapes: &[(usize, usize, usize)] =
        &[(48, 48, 48), (64, 64, 64), (256, 256, 256), (1, 4096, 256)];
    let reps = if smoke { 2 } else { 4 };
    let mut rows = Vec::new();
    for &(m, n, k) in shapes {
        let a: Vec<f32> = (0..m * k).map(|i| (i % 13) as f32 * 0.25 - 1.5).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 7) as f32 * 0.5 - 1.0).collect();
        let mut out = vec![0f32; m * n];
        let mut a_pack = vec![0f32; kernels::packed_a_len(m, k)];
        let mut b_pack = vec![0f32; kernels::packed_b_len(n, k)];
        let tile: TileConfig = autotune::choose(m, n, k);
        let macs = m * n * k;
        // Enough inner iterations to push each rep past timer noise.
        let iters = (8 * 1024 * 1024 / macs).clamp(1, 256);
        for &threads in &[1usize, 4] {
            let pool = WorkerPool::new(threads);
            let scalar_secs =
                time_best(reps, iters, || kernels::dot_scalar(&a, &b, n, k, &mut out, &pool));
            let packed_secs = time_best(reps, iters, || {
                kernels::dot_packed(&a, &b, n, k, &mut out, &pool, tile, &mut a_pack, &mut b_pack)
            });
            let flops = 2.0 * macs as f64;
            rows.push(GemmRow {
                m,
                n,
                k,
                threads,
                scalar_gflops: flops / scalar_secs / 1e9,
                packed_gflops: flops / packed_secs / 1e9,
                tile: tile.key(),
            });
        }
    }
    rows
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let arch_name = argv
        .iter()
        .skip_while(|a| *a != "--arch")
        .nth(1)
        .cloned()
        .unwrap_or_else(|| "resnet-mini".to_string());
    let arch = Arch::by_name(&arch_name).expect("known arch");
    let hw = 32usize;
    let timer = if smoke {
        Timer { warmup: 0, min_samples: 1, max_samples: 1, cv_target: f64::INFINITY }
    } else {
        Timer::default()
    };
    let variants: &[Variant] = if smoke {
        &[Variant::Lrd]
    } else {
        &[Variant::Orig, Variant::Lrd, Variant::Merged]
    };
    let batches: &[usize] = if smoke { &[8] } else { &[1, 8] };
    let thread_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };

    println!(
        "native executor bench: {} hw={hw} ({}) — seed interpreter vs planned arena",
        arch.name,
        if smoke { "smoke" } else { "full" }
    );
    println!(
        "{:10} {:>5} {:>10} {:>7} {:>12} {:>12} {:>8}",
        "variant", "batch", "executor", "threads", "ms/fwd", "img/s", "speedup"
    );
    let mut rows: Vec<Row> = Vec::new();
    for &variant in variants {
        for &batch in batches {
            let (graph, args) = make_args(&arch, variant, batch, hw);
            // seed interpreter baseline (per-node alloc, serial)
            let exe = NativeExecutable::new(graph.clone(), 1).expect("compile");
            let ref_secs = timer
                .measure(|| exe.run_reference(&args).map(|_| ()))
                .expect("measure")
                .trimmed_mean;
            let stats = exe.arena_stats().clone();
            rows.push(Row {
                variant: variant.name(),
                executor: "reference",
                threads: 1,
                batch,
                secs: ref_secs,
                speedup: 1.0,
                // the reference interpreter allocates per node — its real
                // resident footprint is the no-reuse total, not the plan
                arena_peak: stats.naive_bytes,
                arena_naive: stats.naive_bytes,
            });
            for &threads in thread_counts {
                let exe = NativeExecutable::new(graph.clone(), threads).expect("compile");
                let secs = timer
                    .measure(|| exe.run(&args).map(|_| ()))
                    .expect("measure")
                    .trimmed_mean;
                rows.push(Row {
                    variant: variant.name(),
                    executor: "planned",
                    threads,
                    batch,
                    secs,
                    speedup: ref_secs / secs,
                    arena_peak: stats.peak_bytes,
                    arena_naive: stats.naive_bytes,
                });
            }
            for r in rows.iter().rev().take(thread_counts.len() + 1).rev() {
                println!(
                    "{:10} {:>5} {:>10} {:>7} {:>12.3} {:>12.1} {:>7.2}x",
                    r.variant,
                    r.batch,
                    r.executor,
                    r.threads,
                    r.secs * 1e3,
                    r.batch as f64 / r.secs,
                    r.speedup
                );
            }
        }
    }

    println!("\ngemm kernel sweep: scalar baseline vs packed (autotuned tile)");
    println!(
        "{:>5} {:>5} {:>5} {:>7} {:>14} {:>14} {:>7} {:>14}",
        "m", "n", "k", "threads", "scalar GF/s", "packed GF/s", "ratio", "tile"
    );
    let gemm = gemm_sweep(smoke);
    for g in &gemm {
        println!(
            "{:>5} {:>5} {:>5} {:>7} {:>14.2} {:>14.2} {:>6.2}x {:>14}",
            g.m,
            g.n,
            g.k,
            g.threads,
            g.scalar_gflops,
            g.packed_gflops,
            g.packed_gflops / g.scalar_gflops,
            g.tile
        );
    }

    let jrows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj_from(vec![
                ("variant", Json::Str(r.variant.to_string())),
                ("executor", Json::Str(r.executor.to_string())),
                ("threads", Json::Num(r.threads as f64)),
                ("batch", Json::Num(r.batch as f64)),
                ("secs_per_fwd", Json::Num(r.secs)),
                ("imgs_per_sec", Json::Num(r.batch as f64 / r.secs)),
                ("speedup_vs_reference", Json::Num(r.speedup)),
                ("arena_peak_bytes", Json::Num(r.arena_peak as f64)),
                ("arena_naive_bytes", Json::Num(r.arena_naive as f64)),
            ])
        })
        .collect();
    let jgemm: Vec<Json> = gemm
        .iter()
        .map(|g| {
            Json::obj_from(vec![
                ("m", Json::Num(g.m as f64)),
                ("n", Json::Num(g.n as f64)),
                ("k", Json::Num(g.k as f64)),
                ("threads", Json::Num(g.threads as f64)),
                ("scalar_gflops", Json::Num(g.scalar_gflops)),
                ("packed_gflops", Json::Num(g.packed_gflops)),
                ("speedup", Json::Num(g.packed_gflops / g.scalar_gflops)),
                ("tile", Json::Str(g.tile.clone())),
            ])
        })
        .collect();
    let doc = Json::obj_from(vec![
        ("arch", Json::Str(arch.name.to_string())),
        ("hw", Json::Num(hw as f64)),
        ("smoke", Json::Bool(smoke)),
        ("rows", Json::Arr(jrows)),
        ("gemm", Json::Arr(jgemm)),
    ]);
    std::fs::write("BENCH_native.json", doc.render()).expect("write BENCH_native.json");
    println!("(saved BENCH_native.json)");
}
