//! `cargo bench --bench native_exec [-- --smoke] [-- --arch NAME]` —
//! prices the planned arena executor against the seed's per-node
//! interpreter (`run_reference`): forward latency and throughput per
//! variant × executor × thread count × batch on the same O0 graphs, so
//! the delta is purely plan + arena + tiled parallel kernels. Emits
//! `BENCH_native.json`; `--smoke` runs a single-iteration subset with
//! the same schema (the CI schema gate).

use std::sync::Arc;

use lrdx::decompose::{plan_variant, Variant};
use lrdx::model::Arch;
use lrdx::profiler::Timer;
use lrdx::runtime::native::NativeExecutable;
use lrdx::runtime::netbuilder::build_forward;
use lrdx::runtime::HostTensor;
use lrdx::util::json::Json;
use lrdx::util::rng::Rng;

/// Network arguments initialised exactly as `BuiltNet::compile` would.
fn make_args(
    arch: &Arch,
    variant: Variant,
    batch: usize,
    hw: usize,
) -> (lrdx::runtime::graph::Graph, Vec<Arc<HostTensor>>) {
    let plan = plan_variant(arch, variant, 2.0, 2, None).expect("plan");
    let (graph, specs) = build_forward(arch, &plan, batch, hw).expect("build");
    let mut rng = Rng::new(0xBE7C);
    let mut args = vec![Arc::new(HostTensor::new(
        vec![batch, 3, hw, hw],
        lrdx::util::det_input(batch, hw),
    ))];
    for spec in &specs {
        let host = lrdx::runtime::netbuilder::init_param_host(spec, &mut rng);
        args.push(Arc::new(HostTensor::new(spec.shape.clone(), host)));
    }
    (graph, args)
}

struct Row {
    variant: &'static str,
    executor: &'static str,
    threads: usize,
    batch: usize,
    secs: f64,
    speedup: f64,
    arena_peak: usize,
    arena_naive: usize,
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let arch_name = argv
        .iter()
        .skip_while(|a| *a != "--arch")
        .nth(1)
        .cloned()
        .unwrap_or_else(|| "resnet-mini".to_string());
    let arch = Arch::by_name(&arch_name).expect("known arch");
    let hw = 32usize;
    let timer = if smoke {
        Timer { warmup: 0, min_samples: 1, max_samples: 1, cv_target: f64::INFINITY }
    } else {
        Timer::default()
    };
    let variants: &[Variant] = if smoke {
        &[Variant::Lrd]
    } else {
        &[Variant::Orig, Variant::Lrd, Variant::Merged]
    };
    let batches: &[usize] = if smoke { &[8] } else { &[1, 8] };
    let thread_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };

    println!(
        "native executor bench: {} hw={hw} ({}) — seed interpreter vs planned arena",
        arch.name,
        if smoke { "smoke" } else { "full" }
    );
    println!(
        "{:10} {:>5} {:>10} {:>7} {:>12} {:>12} {:>8}",
        "variant", "batch", "executor", "threads", "ms/fwd", "img/s", "speedup"
    );
    let mut rows: Vec<Row> = Vec::new();
    for &variant in variants {
        for &batch in batches {
            let (graph, args) = make_args(&arch, variant, batch, hw);
            // seed interpreter baseline (per-node alloc, serial)
            let exe = NativeExecutable::new(graph.clone(), 1).expect("compile");
            let ref_secs = timer
                .measure(|| exe.run_reference(&args).map(|_| ()))
                .expect("measure")
                .trimmed_mean;
            let stats = exe.arena_stats().clone();
            rows.push(Row {
                variant: variant.name(),
                executor: "reference",
                threads: 1,
                batch,
                secs: ref_secs,
                speedup: 1.0,
                // the reference interpreter allocates per node — its real
                // resident footprint is the no-reuse total, not the plan
                arena_peak: stats.naive_bytes,
                arena_naive: stats.naive_bytes,
            });
            for &threads in thread_counts {
                let exe = NativeExecutable::new(graph.clone(), threads).expect("compile");
                let secs = timer
                    .measure(|| exe.run(&args).map(|_| ()))
                    .expect("measure")
                    .trimmed_mean;
                rows.push(Row {
                    variant: variant.name(),
                    executor: "planned",
                    threads,
                    batch,
                    secs,
                    speedup: ref_secs / secs,
                    arena_peak: stats.peak_bytes,
                    arena_naive: stats.naive_bytes,
                });
            }
            for r in rows.iter().rev().take(thread_counts.len() + 1).rev() {
                println!(
                    "{:10} {:>5} {:>10} {:>7} {:>12.3} {:>12.1} {:>7.2}x",
                    r.variant,
                    r.batch,
                    r.executor,
                    r.threads,
                    r.secs * 1e3,
                    r.batch as f64 / r.secs,
                    r.speedup
                );
            }
        }
    }

    let jrows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj_from(vec![
                ("variant", Json::Str(r.variant.to_string())),
                ("executor", Json::Str(r.executor.to_string())),
                ("threads", Json::Num(r.threads as f64)),
                ("batch", Json::Num(r.batch as f64)),
                ("secs_per_fwd", Json::Num(r.secs)),
                ("imgs_per_sec", Json::Num(r.batch as f64 / r.secs)),
                ("speedup_vs_reference", Json::Num(r.speedup)),
                ("arena_peak_bytes", Json::Num(r.arena_peak as f64)),
                ("arena_naive_bytes", Json::Num(r.arena_naive as f64)),
            ])
        })
        .collect();
    let doc = Json::obj_from(vec![
        ("arch", Json::Str(arch.name.to_string())),
        ("hw", Json::Num(hw as f64)),
        ("smoke", Json::Bool(smoke)),
        ("rows", Json::Arr(jrows)),
    ]);
    std::fs::write("BENCH_native.json", doc.render()).expect("write BENCH_native.json");
    println!("(saved BENCH_native.json)");
}
