//! `cargo bench --bench passes` — O0 vs optimized forward latency per
//! variant on the native backend, plus the pass-pipeline accounting.
//! Seeds the perf trajectory: emits `BENCH_passes.json` next to the cwd.

use lrdx::decompose::{plan_variant, Variant};
use lrdx::model::Arch;
use lrdx::profiler::Timer;
use lrdx::runtime::netbuilder::BuiltNet;
use lrdx::runtime::{CompileOptions, Engine, OptLevel};
use lrdx::util::json::Json;

fn measure(engine: &Engine, net: &BuiltNet, timer: &Timer) -> f64 {
    let x = lrdx::util::det_input(net.batch, net.hw);
    let xb = engine.upload(&x, &[net.batch, 3, net.hw, net.hw]).expect("upload");
    timer
        .measure(|| {
            let out = net.forward(&xb)?;
            out.sync()?;
            Ok(())
        })
        .expect("measure")
        .trimmed_mean
}

fn main() {
    let engine = Engine::cpu().expect("engine");
    let arch_name =
        std::env::args().skip_while(|a| a != "--arch").nth(1).unwrap_or("resnet-mini".into());
    let arch = Arch::by_name(&arch_name).expect("known arch");
    let (batch, hw) = (4usize, 32usize);
    let timer = Timer::default();

    println!(
        "pass-pipeline bench: {} on {} ({batch}x3x{hw}x{hw})",
        arch.name,
        engine.platform()
    );
    println!(
        "{:10} {:>9} {:>9} {:>8} {:>11} {:>11} {:>8}",
        "variant", "nodes O0", "nodes O2", "fusions", "O0 ms/fwd", "O2 ms/fwd", "speedup"
    );
    let mut jrows = Vec::new();
    for variant in [Variant::Orig, Variant::Lrd, Variant::Merged, Variant::Branched] {
        let plan = match plan_variant(&arch, variant, 2.0, 2, None) {
            Ok(p) => p,
            Err(_) => continue, // e.g. merged on basic-block archs
        };
        let o0 = CompileOptions::o0();
        let o2 = CompileOptions::level(OptLevel::O2);
        let net0 =
            BuiltNet::compile(&engine, &arch, &plan, batch, hw, 0xBE7C, &o0).expect("O0");
        let net2 =
            BuiltNet::compile(&engine, &arch, &plan, batch, hw, 0xBE7C, &o2).expect("O2");
        let (t0, t2) = (measure(&engine, &net0, &timer), measure(&engine, &net2, &timer));
        let s0 = net0.pass_stats().clone();
        let s2 = net2.pass_stats().clone();
        println!(
            "{:10} {:>9} {:>9} {:>8} {:>11.3} {:>11.3} {:>7.2}x",
            variant.name(),
            s0.nodes_after,
            s2.nodes_after,
            s2.fusions,
            t0 * 1e3,
            t2 * 1e3,
            t0 / t2
        );
        jrows.push(Json::obj_from(vec![
            ("variant", Json::Str(variant.name().into())),
            ("nodes_o0", Json::Num(s0.nodes_after as f64)),
            ("nodes_opt", Json::Num(s2.nodes_after as f64)),
            ("fusions", Json::Num(s2.fusions as f64)),
            ("secs_o0", Json::Num(t0)),
            ("secs_opt", Json::Num(t2)),
            ("speedup", Json::Num(t0 / t2)),
            ("pass_wall_secs", Json::Num(s2.wall_secs)),
        ]));
    }
    let doc = Json::obj_from(vec![
        ("arch", Json::Str(arch.name.to_string())),
        ("platform", Json::Str(engine.platform())),
        ("batch", Json::Num(batch as f64)),
        ("hw", Json::Num(hw as f64)),
        ("rows", Json::Arr(jrows)),
    ]);
    std::fs::write("BENCH_passes.json", doc.render()).expect("write BENCH_passes.json");
    println!("(saved BENCH_passes.json)");
}
