//! `cargo bench --bench profile_overhead [-- --smoke]` — prices the
//! observability layer on the merged O2 resnet-mini forward:
//!
//! * `overhead_off_pct` — profile-OFF executable vs the plain `new`
//!   constructor (an A/A comparison: the off path must be free). CI
//!   gates this under 2%.
//! * `overhead_on_pct` — profile-ON vs baseline (informational; two
//!   clock reads per step are not free, just cheap).
//! * `coverage` — Σ per-step measured time / end-to-end run time with
//!   profiling on. CI gates this at >= 0.9: the per-op numbers must
//!   explain the run they claim to decompose.
//!
//! Emits `BENCH_profile.json`; `--smoke` shrinks the rep counts with the
//! same schema (the CI schema + gate job).

use std::sync::Arc;

use lrdx::decompose::{plan_variant, Variant};
use lrdx::model::Arch;
use lrdx::runtime::native::NativeExecutable;
use lrdx::runtime::netbuilder::build_forward;
use lrdx::runtime::passes::run_pipeline;
use lrdx::runtime::{CompileOptions, HostTensor, OptLevel};
use lrdx::util::json::Json;
use lrdx::util::rng::Rng;

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let (batch, hw, threads) = (8usize, 32usize, 2usize);

    let arch = Arch::by_name("resnet-mini").expect("arch");
    let plan = plan_variant(&arch, Variant::Merged, 2.0, 2, None).expect("plan");
    let (graph, specs) = build_forward(&arch, &plan, batch, hw).expect("build");
    let opts = CompileOptions { opt_level: OptLevel::O2, threads, ..Default::default() };
    let (graph, _) = run_pipeline(&graph, &opts).expect("pipeline");

    let mut rng = Rng::new(0xBE7C);
    let mut args = vec![Arc::new(HostTensor::new(
        vec![batch, 3, hw, hw],
        lrdx::util::det_input(batch, hw),
    ))];
    for spec in &specs {
        let host = lrdx::runtime::netbuilder::init_param_host(spec, &mut rng);
        args.push(Arc::new(HostTensor::new(spec.shape.clone(), host)));
    }

    // Three executables over the SAME optimized graph: the plain
    // constructor (the pre-observability compile path), options with
    // profile off, and options with profile on.
    let exe_base = NativeExecutable::new(graph.clone(), threads).expect("compile base");
    let exe_off =
        NativeExecutable::with_options(graph.clone(), threads, false, false).expect("off");
    let exe_on =
        NativeExecutable::with_options(graph.clone(), threads, false, true).expect("on");

    let (warmup, reps, inner) = if smoke { (1, 4, 1) } else { (5, 40, 4) };
    for _ in 0..warmup {
        exe_base.run(&args).expect("run");
        exe_off.run(&args).expect("run");
        exe_on.run(&args).expect("run");
    }
    // Interleaved min-of-reps: scheduler noise hits all three arms alike,
    // and the min isolates the code path cost from the noise floor.
    let time = |exe: &NativeExecutable| {
        let t0 = std::time::Instant::now();
        for _ in 0..inner {
            exe.run(&args).expect("run");
        }
        t0.elapsed().as_secs_f64() / inner as f64
    };
    let (mut base, mut off, mut on) = (f64::INFINITY, f64::INFINITY, f64::INFINITY);
    for _ in 0..reps {
        base = base.min(time(&exe_base));
        off = off.min(time(&exe_off));
        on = on.min(time(&exe_on));
    }

    let overhead_off_pct = (off / base - 1.0) * 100.0;
    let overhead_on_pct = (on / base - 1.0) * 100.0;
    let profile = exe_on.exec_profile().expect("profile-on executable reports");
    let coverage = profile.coverage();

    println!("profile overhead on merged O2 {} (t{threads}, batch {batch}, hw {hw}):", arch.name);
    println!("  baseline       {:>9.3} ms/fwd", base * 1e3);
    println!("  profile off    {:>9.3} ms/fwd  ({overhead_off_pct:+.2}%)", off * 1e3);
    println!("  profile on     {:>9.3} ms/fwd  ({overhead_on_pct:+.2}%)", on * 1e3);
    println!("  step coverage  {:>9.1} %", coverage * 100.0);

    let doc = Json::obj_from(vec![
        ("arch", Json::Str(arch.name.to_string())),
        ("variant", Json::Str("merged".into())),
        ("opt_level", Json::Str("O2".into())),
        ("threads", Json::Num(threads as f64)),
        ("batch", Json::Num(batch as f64)),
        ("hw", Json::Num(hw as f64)),
        ("smoke", Json::Bool(smoke)),
        ("baseline_secs", Json::Num(base)),
        ("profile_off_secs", Json::Num(off)),
        ("profile_on_secs", Json::Num(on)),
        ("overhead_off_pct", Json::Num(overhead_off_pct)),
        ("overhead_on_pct", Json::Num(overhead_on_pct)),
        ("coverage", Json::Num(coverage)),
        ("profiled_runs", Json::Num(profile.runs as f64)),
    ]);
    std::fs::write("BENCH_profile.json", doc.render()).expect("write BENCH_profile.json");
    println!("(saved BENCH_profile.json)");
}
