//! `cargo bench --bench sparse_residual [-- --smoke]` — dense vs pure
//! chain vs chain+S on a planted spiky-low-rank site.
//!
//! The setting the sparse-residual subsystem exists for: a 64x64 1x1
//! weight that is genuinely rank-16 EXCEPT for a few large outliers
//! (5% "spikes" — the salient-weight structure quantization/pruning
//! papers keep finding). A pure factor chain must spend rank on the
//! spikes, so reaching the composed model's error costs it almost the
//! full dense budget; `W ~= chain + S` absorbs them at nnz extra MACs.
//! Per density the bench fits the alternating refit, scores analytic
//! MACs/output-pixel for the composition AND for the same-error pure
//! chain (smallest truncation rank whose SVD tail error matches), and
//! wall-clocks the lowered layer on the native engine. Emits
//! `BENCH_sparse.json`; `--smoke` shrinks timer samples, same schema
//! (the CI gate asserts chain+S@5% beats the same-error pure chain).

use lrdx::decompose::rank_opt::LayerTimer;
use lrdx::decompose::sparse::fit_site;
use lrdx::decompose::Scheme;
use lrdx::linalg::{svd, Matrix};
use lrdx::model::{ConvSite, SiteKind};
use lrdx::profiler::Timer;
use lrdx::runtime::layer_factory::EngineLayerTimer;
use lrdx::runtime::{Engine, HostTensor};
use lrdx::util::json::Json;
use lrdx::util::rng::Rng;

const BATCH: usize = 4;
const HW: usize = 16;
const C: usize = 64;
const S: usize = 64;
const RANK: usize = 16;
const DENSITIES: [f64; 4] = [0.0, 0.01, 0.05, 0.10];

/// W = lowrank(RANK) + 5% spikes + iid noise: the planted structure.
fn planted_weight(rng: &mut Rng) -> HostTensor {
    let a = Matrix::random(S, RANK, rng);
    let b = Matrix::random(RANK, C, rng);
    let mut w = a.matmul(&b).data;
    let spikes = S * C / 20;
    for j in 0..spikes {
        // stride 37 is odd, so positions are distinct mod the 4096 slots
        let pos = (j * 37) % (S * C);
        w[pos] += if j % 2 == 0 { 25.0 } else { -25.0 };
    }
    for x in w.iter_mut() {
        *x += 1e-2 * rng.normal_f32();
    }
    HostTensor::new(vec![S, C], w)
}

/// Relative Frobenius error of the best rank-`r` approximation, read off
/// the singular-value tail (exact for SVD truncation).
fn svd_tail_err(sv: &[f32], r: usize) -> f64 {
    let total: f64 = sv.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let tail: f64 = sv[r.min(sv.len())..].iter().map(|&x| (x as f64) * (x as f64)).sum();
    (tail / total.max(1e-300)).sqrt()
}

/// Smallest truncation rank whose SVD tail error is <= `err`.
fn equivalent_rank(sv: &[f32], err: f64) -> usize {
    (0..=sv.len()).find(|&r| svd_tail_err(sv, r) <= err).unwrap_or(sv.len())
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let samples = if smoke {
        Timer { warmup: 0, min_samples: 1, max_samples: 1, cv_target: f64::INFINITY }
    } else {
        Timer { warmup: 1, min_samples: 3, max_samples: 8, cv_target: 0.2 }
    };
    let site = ConvSite {
        name: "planted.64x64".into(),
        c: C,
        s: S,
        k: 1,
        stride: 1,
        padding: 0,
        kind: SiteKind::Conv,
    };
    let mut rng = Rng::new(0x5BA2);
    let w = planted_weight(&mut rng);
    let sv = svd(&Matrix::from_vec(S, C, w.data.clone())).s;

    let engine = Engine::cpu().expect("engine");
    let mut timer = EngineLayerTimer::with_timer(engine, samples);
    let t_dense = timer.time_layer(&site, &Scheme::Orig, BATCH, HW).expect("dense");

    println!(
        "dense vs chain vs chain+S on planted spiky rank-{RANK} {S}x{C} ({})",
        if smoke { "smoke" } else { "full" }
    );
    println!("dense: {:.3} ms/fwd, {} MACs/px", t_dense * 1e3, C * S);
    println!(
        "{:>8} {:>6} {:>9} {:>9} {:>10} {:>11} {:>10} {:>9}",
        "density", "nnz", "rel err", "MACs/px", "equiv rank", "equiv MACs", "ms/fwd", "speedup"
    );
    let chain_macs = RANK * (C + S);
    let mut jrows = Vec::new();
    for &density in &DENSITIES {
        let (scheme, nnz, rel_err) = if density == 0.0 {
            (Scheme::Svd { r: RANK }, 0usize, svd_tail_err(&sv, RANK))
        } else {
            let ppm = (density * 1e6).round() as u32;
            let fit = fit_site(&site, &Scheme::Svd { r: RANK }, &w, ppm, 3).expect("fit");
            let scheme = Scheme::Sparse { base: Box::new(Scheme::Svd { r: RANK }), ppm };
            (scheme, fit.sparse.nnz(), fit.rel_err)
        };
        let macs = chain_macs + nnz;
        let equiv_rank = equivalent_rank(&sv, rel_err);
        let equiv_macs = equiv_rank * (C + S);
        let secs = timer.time_layer(&site, &scheme, BATCH, HW).expect("layer");
        println!(
            "{:>7.0}% {:>6} {:>9.4} {:>9} {:>10} {:>11} {:>10.3} {:>8.2}x",
            density * 100.0,
            nnz,
            rel_err,
            macs,
            equiv_rank,
            equiv_macs,
            secs * 1e3,
            t_dense / secs
        );
        jrows.push(Json::obj_from(vec![
            ("density", Json::Num(density)),
            ("nnz", Json::Num(nnz as f64)),
            ("rel_err", Json::Num(rel_err)),
            ("macs_per_px", Json::Num(macs as f64)),
            ("equiv_rank", Json::Num(equiv_rank as f64)),
            ("equiv_macs_per_px", Json::Num(equiv_macs as f64)),
            ("secs_per_fwd", Json::Num(secs)),
            ("speedup_vs_dense", Json::Num(t_dense / secs)),
        ]));
    }
    let doc = Json::obj_from(vec![
        ("smoke", Json::Bool(smoke)),
        ("batch", Json::Num(BATCH as f64)),
        ("hw", Json::Num(HW as f64)),
        ("site", Json::Str(site.name.clone())),
        ("chain_rank", Json::Num(RANK as f64)),
        ("dense_macs_per_px", Json::Num((C * S) as f64)),
        ("t_dense_secs", Json::Num(t_dense)),
        ("rows", Json::Arr(jrows)),
    ]);
    std::fs::write("BENCH_sparse.json", doc.render()).expect("write BENCH_sparse.json");
    println!("(saved BENCH_sparse.json)");
}
