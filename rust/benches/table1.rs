//! `cargo bench --bench table1` — regenerate paper Table 1 (measured).
use lrdx::harness::table1;
use lrdx::runtime::Engine;

fn main() {
    let engine = Engine::cpu().expect("engine");
    let full = std::env::args().any(|a| a == "--full");
    let cfg = table1::Config {
        archs: if full {
            vec!["resnet50".into(), "resnet101".into(), "resnet152".into()]
        } else {
            vec!["resnet50".into()]
        },
        ..Default::default()
    };
    let report = table1::run(&engine, &cfg).expect("table1");
    print!("{}", report.render());
    report.save(std::path::Path::new("reports")).expect("save");
}
