//! `cargo bench --bench table2` — Algorithm 1 ranks with REAL backend wall-clock timing.
use lrdx::harness::table2;
use lrdx::runtime::Engine;

fn main() {
    let engine = Engine::cpu().expect("engine");
    let cfg = table2::Config { real: true, stride: 12, refine: 2, ..Default::default() };
    let report = table2::run(&engine, &cfg).expect("table2");
    print!("{}", report.render());
    report.save(std::path::Path::new("reports")).expect("save");
}
