//! `cargo bench --bench table3` — method comparison (measured infer speedups).
use lrdx::harness::table3;
use lrdx::runtime::Engine;

fn main() {
    let engine = Engine::cpu().expect("engine");
    let full = std::env::args().any(|a| a == "--full");
    let cfg = table3::Config {
        archs: if full {
            vec!["resnet50".into(), "resnet101".into(), "resnet152".into()]
        } else {
            vec!["resnet50".into()]
        },
        ..Default::default()
    };
    let report = table3::run(&engine, &cfg).expect("table3");
    print!("{}", report.render());
    report.save(std::path::Path::new("reports")).expect("save");
}
