//! `cargo bench --bench table456` — fine-tuning accuracy comparison
//! (train-from-scratch + one-shot decomposition + fine-tune + pruning
//! baseline). Uses shorter schedules than `lrdx bench table456` defaults so
//! the whole bench suite stays tractable.
use lrdx::harness::table456;
use lrdx::runtime::Engine;

fn main() {
    let engine = Engine::cpu().expect("engine");
    // A PJRT engine needs the AOT artifacts; the native engine runs the
    // identical protocol through the rust-native autograd train step.
    if engine.platform() != "native-cpu"
        && !std::path::Path::new("artifacts/manifest.json").exists()
    {
        eprintln!("SKIP table456: run `python python/compile/aot.py --out rust/artifacts` first");
        return;
    }
    let cfg = table456::Config {
        train_steps: 160,
        finetune_steps: 80,
        ..Default::default()
    };
    let report = table456::run(&engine, &cfg).expect("table456");
    print!("{}", report.render());
    println!("\npaper-quoted rows (Tables 4-6):");
    for (t, m, dt, df) in table456::paper_quoted_rows() {
        println!("  {t:8} {m:16} ΔTop-1 {dt:>6}  ΔFLOPs {df:>7}");
    }
    report.save(std::path::Path::new("reports")).expect("save");
}
