//! `cargo bench --bench train_step [-- --smoke] [-- --arch NAME]` —
//! prices the native autograd train step: one full fwd+bwd+SGD-update
//! graph per (variant × opt level × thread count), all compiled through
//! `Engine::compile_train` and executed by the planned arena executor
//! with the persistent worker pool. The O0-vs-O2 delta shows what the
//! pass pipeline (including the backward re-merge fusion) buys on
//! *training*, not just inference; the freeze variant is where the
//! backward fusions fire. Emits `BENCH_train.json`; `--smoke` runs a
//! single-iteration subset with the same schema (the CI schema gate).

use lrdx::decompose::{plan_variant, Variant};
use lrdx::model::Arch;
use lrdx::profiler::Timer;
use lrdx::runtime::{CompileOptions, Engine, OptLevel};
use lrdx::train::{NativeTrainSession, SgdHyper};
use lrdx::trainsim::data::SynthData;
use lrdx::util::json::Json;
use lrdx::util::rng::Rng;

struct Row {
    variant: &'static str,
    opt_level: &'static str,
    threads: usize,
    batch: usize,
    secs_per_step: f64,
    steps_per_sec: f64,
    nodes_after: usize,
    fusions_fwd: usize,
    fusions_bwd: usize,
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let arch_name = argv
        .iter()
        .skip_while(|a| *a != "--arch")
        .nth(1)
        .cloned()
        .unwrap_or_else(|| "resnet-mini".to_string());
    let arch = Arch::by_name(&arch_name).expect("known arch");
    let (hw, batch) = if smoke { (12, 4) } else { (24, 16) };
    let timer = if smoke {
        Timer { warmup: 0, min_samples: 1, max_samples: 1, cv_target: f64::INFINITY }
    } else {
        Timer { warmup: 2, min_samples: 5, max_samples: 20, cv_target: 0.10 }
    };
    let variants: &[Variant] = if smoke {
        &[Variant::Freeze]
    } else {
        &[Variant::Lrd, Variant::Freeze]
    };
    let levels = [OptLevel::O0, OptLevel::O2];
    let thread_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 4] };

    let engine = Engine::native();
    println!(
        "native train-step bench: {} hw={hw} batch={batch} ({})",
        arch.name,
        if smoke { "smoke" } else { "full" }
    );
    println!(
        "{:8} {:>4} {:>8} {:>10} {:>10} {:>7} {:>10}",
        "variant", "opt", "threads", "ms/step", "steps/s", "nodes", "fus f/b"
    );
    let mut rows: Vec<Row> = Vec::new();
    for &variant in variants {
        let plan = plan_variant(&arch, variant, 2.0, 2, None).expect("plan");
        for level in levels {
            for &threads in thread_counts {
                let opts = CompileOptions {
                    opt_level: level,
                    threads,
                    ..Default::default()
                };
                let mut sess = NativeTrainSession::new(
                    &engine,
                    &arch,
                    &plan,
                    batch,
                    hw,
                    variant == Variant::Freeze,
                    &SgdHyper::default(),
                    &opts,
                    None,
                    0xBE7C,
                )
                .expect("session");
                let stats = sess.pass_stats().clone();
                let gen = SynthData::new(hw, arch.classes);
                let mut rng = Rng::new(7);
                let (x, y) = gen.batch(&mut rng, batch);
                let secs = timer
                    .measure(|| sess.step(&x, &y).map(|_| ()))
                    .expect("measure")
                    .trimmed_mean;
                let (ff, fb) = stats
                    .train
                    .as_ref()
                    .map(|t| (t.fusions_fwd, t.fusions_bwd))
                    .unwrap_or((0, 0));
                println!(
                    "{:8} {:>4} {:>8} {:>10.3} {:>10.2} {:>7} {:>6}/{}",
                    variant.name(),
                    level.name(),
                    threads,
                    secs * 1e3,
                    1.0 / secs,
                    stats.nodes_after,
                    ff,
                    fb
                );
                rows.push(Row {
                    variant: variant.name(),
                    opt_level: level.name(),
                    threads,
                    batch,
                    secs_per_step: secs,
                    steps_per_sec: 1.0 / secs,
                    nodes_after: stats.nodes_after,
                    fusions_fwd: ff,
                    fusions_bwd: fb,
                });
            }
        }
    }

    let jrows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj_from(vec![
                ("variant", Json::Str(r.variant.to_string())),
                ("opt_level", Json::Str(r.opt_level.to_string())),
                ("threads", Json::Num(r.threads as f64)),
                ("batch", Json::Num(r.batch as f64)),
                ("secs_per_step", Json::Num(r.secs_per_step)),
                ("steps_per_sec", Json::Num(r.steps_per_sec)),
                ("nodes_after", Json::Num(r.nodes_after as f64)),
                ("fusions_fwd", Json::Num(r.fusions_fwd as f64)),
                ("fusions_bwd", Json::Num(r.fusions_bwd as f64)),
            ])
        })
        .collect();
    let doc = Json::obj_from(vec![
        ("arch", Json::Str(arch.name.to_string())),
        ("hw", Json::Num(hw as f64)),
        ("batch", Json::Num(batch as f64)),
        ("smoke", Json::Bool(smoke)),
        ("rows", Json::Arr(jrows)),
    ]);
    std::fs::write("BENCH_train.json", doc.render()).expect("write BENCH_train.json");
    println!("(saved BENCH_train.json)");
}
