//! Comparator baselines for Tables 4-6. The paper quotes pruning numbers
//! from their original publications; we additionally implement one for real
//! (magnitude filter pruning, the Li et al. 2016 family) so the comparison
//! is executable on our testbed.

pub mod pruning;
