//! Magnitude filter pruning baseline (Li et al., "Pruning Filters for
//! Efficient ConvNets" — the family every pruning row in Tables 4-6 builds
//! on): rank output filters of each conv by L2 norm, zero the smallest
//! fraction, fine-tune with the mask enforced.
//!
//! We keep the architecture dense (masked filters stay as zero rows), so
//! accuracy is measured exactly; the FLOPs/params reduction a structured
//! implementation would realise is computed analytically (`pruned_cost`).

use std::collections::BTreeMap;


use crate::decompose::params::Params;
use crate::model::{Arch, SiteKind};

/// Keep-masks per conv weight: name -> keep flag per output channel.
pub type FilterMasks = BTreeMap<String, Vec<bool>>;

/// Build magnitude keep-masks pruning `fraction` of the filters of every
/// conv site (stem and fc excluded, mirroring the LRD plans).
pub fn magnitude_masks(arch: &Arch, params: &Params, fraction: f64) -> FilterMasks {
    let mut masks = FilterMasks::new();
    for t in arch.sites() {
        if t.kind == SiteKind::Stem || t.kind == SiteKind::Fc {
            continue;
        }
        let name = format!("{}.w", t.name);
        let Some(w) = params.get(&name) else { continue };
        let s = w.dims[0];
        let span: usize = w.dims.iter().skip(1).product();
        let mut norms: Vec<(f64, usize)> = (0..s)
            .map(|o| {
                let n = w.data[o * span..(o + 1) * span]
                    .iter()
                    .map(|&x| (x as f64) * (x as f64))
                    .sum::<f64>()
                    .sqrt();
                (n, o)
            })
            .collect();
        // total order with an index tie-break: equal-norm filters (common
        // right after synthetic init) must mask identically on every run
        // and every platform, so fine-tune trajectories are replayable
        norms.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let drop = ((s as f64) * fraction) as usize;
        let mut keep = vec![true; s];
        for &(_, o) in norms.iter().take(drop.min(s.saturating_sub(1))) {
            keep[o] = false;
        }
        masks.insert(name, keep);
    }
    masks
}

/// Apply masks to a parameter set (zero the pruned filters' weights and
/// their BN affine so they stay dead through the forward pass).
pub fn apply_masks(params: &mut Params, masks: &FilterMasks) {
    for (name, keep) in masks {
        if let Some(w) = params.get_mut(name) {
            let span: usize = w.dims.iter().skip(1).product();
            for (o, k) in keep.iter().enumerate() {
                if !k {
                    w.data[o * span..(o + 1) * span].fill(0.0);
                }
            }
        }
        let site = name.trim_end_matches(".w");
        for bn in [format!("{site}.bn.g"), format!("{site}.bn.b")] {
            if let Some(g) = params.get_mut(&bn) {
                for (o, k) in keep.iter().enumerate() {
                    if !k && o < g.data.len() {
                        g.data[o] = 0.0;
                    }
                }
            }
        }
    }
}

/// Fraction of weights actually zeroed by the masks.
pub fn sparsity(params: &Params, masks: &FilterMasks) -> f64 {
    let mut zeroed = 0usize;
    let mut total = 0usize;
    for (name, t) in params {
        if name.contains(".bn.") {
            continue;
        }
        total += t.data.len();
        if let Some(keep) = masks.get(name) {
            let span: usize = t.dims.iter().skip(1).product();
            zeroed += keep.iter().filter(|k| !**k).count() * span;
        }
    }
    zeroed as f64 / total as f64
}

/// Achieved per-site and overall weight density after masking, measured
/// on the actual tensors (`HostTensor::density`) rather than the
/// requested fraction — `floor(s·fraction)` rounding and weights that
/// were already zero make the two differ.
pub struct DensityStats {
    /// weight name -> achieved nonzero fraction of that tensor
    pub per_site: BTreeMap<String, f64>,
    /// nonzero fraction across all masked weight tensors
    pub overall: f64,
}

pub fn density_stats(params: &Params, masks: &FilterMasks) -> DensityStats {
    let mut per_site = BTreeMap::new();
    let (mut nnz, mut total) = (0usize, 0usize);
    for name in masks.keys() {
        if let Some(w) = params.get(name) {
            per_site.insert(name.clone(), w.density());
            nnz += w.nnz();
            total += w.data.len();
        }
    }
    let overall = if total == 0 { 1.0 } else { nnz as f64 / total as f64 };
    DensityStats { per_site, overall }
}

/// FLOPs/params a *structured* implementation of these masks would save:
/// pruning fraction p of output filters removes ~p of this layer's MACs and
/// ~p of the next layer's input channels (we report the standard p plus
/// the cascade approximation the pruning literature uses).
pub fn pruned_cost_fraction(fraction: f64) -> f64 {
    // Output-filter pruning at rate p removes p of the layer's filters and
    // p of the following layer's input channels: (1-p)^2 of dense MACs in
    // the interior; report the interior approximation.
    1.0 - (1.0 - fraction) * (1.0 - fraction)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::params::init_orig_params;
    use crate::util::rng::Rng;

    fn setup() -> (Arch, Params) {
        let arch = Arch::by_name("resnet-mini").unwrap();
        let mut rng = Rng::new(5);
        let p = init_orig_params(&arch, &mut rng);
        (arch, p)
    }

    #[test]
    fn masks_prune_requested_fraction() {
        let (arch, p) = setup();
        let masks = magnitude_masks(&arch, &p, 0.5);
        assert!(!masks.is_empty());
        for (name, keep) in &masks {
            let dropped = keep.iter().filter(|k| !**k).count();
            let frac = dropped as f64 / keep.len() as f64;
            assert!((0.3..=0.5).contains(&frac), "{name}: {frac}");
        }
        // stem and fc untouched
        assert!(!masks.contains_key("stem.conv.w"));
        assert!(!masks.contains_key("fc.w"));
    }

    #[test]
    fn smallest_norm_filters_go_first() {
        let (arch, mut p) = setup();
        // make filter 0 of one conv tiny
        let w = p.get_mut("layer1.0.conv2.w").unwrap();
        let span: usize = w.dims.iter().skip(1).product();
        w.data[..span].fill(1e-6);
        let masks = magnitude_masks(&arch, &p, 0.25);
        assert!(!masks["layer1.0.conv2.w"][0], "tiny filter should be pruned");
    }

    #[test]
    fn apply_masks_zeroes_weights_and_bn() {
        let (arch, mut p) = setup();
        let masks = magnitude_masks(&arch, &p, 0.5);
        apply_masks(&mut p, &masks);
        for (name, keep) in &masks {
            let w = &p[name];
            let span: usize = w.dims.iter().skip(1).product();
            for (o, k) in keep.iter().enumerate() {
                if !k {
                    assert!(w.data[o * span..(o + 1) * span].iter().all(|&x| x == 0.0));
                }
            }
        }
        let s = sparsity(&p, &masks);
        assert!((0.2..0.6).contains(&s), "sparsity {s}");
    }

    #[test]
    fn tied_norms_break_deterministically_by_index() {
        let (arch, mut p) = setup();
        // all filters of this conv get identical norms: every comparison
        // is a tie, so the mask is pure tie-break territory
        let w = p.get_mut("layer1.0.conv2.w").unwrap();
        w.data.fill(0.25);
        let masks = magnitude_masks(&arch, &p, 0.5);
        let keep = &masks["layer1.0.conv2.w"];
        let dropped: Vec<usize> =
            keep.iter().enumerate().filter(|(_, k)| !**k).map(|(i, _)| i).collect();
        let expect: Vec<usize> = (0..dropped.len()).collect();
        assert_eq!(dropped, expect, "ties must drop the lowest filter indices");
        // mask pinning: a rerun reproduces every mask bit-for-bit
        let again = magnitude_masks(&arch, &p.clone(), 0.5);
        assert_eq!(again, masks);
    }

    #[test]
    fn density_stats_measure_achieved_masking() {
        let (arch, mut p) = setup();
        let masks = magnitude_masks(&arch, &p, 0.5);
        apply_masks(&mut p, &masks);
        let stats = density_stats(&p, &masks);
        assert_eq!(stats.per_site.len(), masks.len());
        for (name, keep) in &masks {
            let kept = keep.iter().filter(|k| **k).count() as f64 / keep.len() as f64;
            let d = stats.per_site[name];
            assert!((d - kept).abs() < 1e-6, "{name}: density {d} vs kept fraction {kept}");
        }
        assert!((0.4..0.8).contains(&stats.overall), "overall {}", stats.overall);
    }

    #[test]
    fn cost_fraction_sane() {
        assert!((pruned_cost_fraction(0.3) - 0.51).abs() < 1e-12);
        assert_eq!(pruned_cost_fraction(0.0), 0.0);
    }
}
