//! Dynamic batching policy: collect up to `max_batch` requests, waiting at
//! most `max_wait` after the first arrival (size-or-deadline flush — the
//! standard serving policy, cf. vllm router / TF-Serving batcher).
//!
//! Pure std-mpsc logic, fully testable without XLA.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

/// Outcome of one collection round.
pub enum Collected<T> {
    /// A batch of 1..=max_batch items (never empty).
    Batch(Vec<T>),
    /// The channel closed with nothing pending: the worker should exit.
    Closed,
}

/// Block for the first item, then keep collecting until the batch is full
/// or `max_wait` has elapsed since the first item arrived.
pub fn collect<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Collected<T> {
    let first = match rx.recv() {
        Ok(item) => item,
        Err(_) => return Collected::Closed,
    };
    let deadline = Instant::now() + policy.max_wait;
    let mut batch = Vec::with_capacity(policy.max_batch);
    batch.push(first);
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Collected::Batch(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn collects_full_batch_when_queue_is_hot() {
        let (tx, rx) = mpsc::channel();
        for i in 0..20 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) };
        match collect(&rx, &policy) {
            Collected::Batch(b) => {
                assert_eq!(b, (0..8).collect::<Vec<_>>());
            }
            Collected::Closed => panic!("should batch"),
        }
        // the rest are still queued
        match collect(&rx, &policy) {
            Collected::Batch(b) => assert_eq!(b.len(), 8),
            Collected::Closed => panic!(),
        }
    }

    #[test]
    fn flushes_partial_batch_on_deadline() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) };
        let t0 = Instant::now();
        match collect(&rx, &policy) {
            Collected::Batch(b) => {
                assert_eq!(b, vec![1, 2]);
                assert!(t0.elapsed() >= Duration::from_millis(9));
            }
            Collected::Closed => panic!(),
        }
    }

    #[test]
    fn closed_channel_reports_closed() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        assert!(matches!(
            collect(&rx, &BatchPolicy::default()),
            Collected::Closed
        ));
    }

    #[test]
    fn late_arrivals_join_within_window() {
        let (tx, rx) = mpsc::channel();
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(60) };
        let sender = std::thread::spawn(move || {
            tx.send(1).unwrap();
            std::thread::sleep(Duration::from_millis(15));
            tx.send(2).unwrap();
            std::thread::sleep(Duration::from_millis(15));
            tx.send(3).unwrap();
        });
        match collect(&rx, &policy) {
            Collected::Batch(b) => assert_eq!(b, vec![1, 2, 3]),
            Collected::Closed => panic!(),
        }
        sender.join().unwrap();
    }

    #[test]
    fn full_batch_flushes_without_waiting_for_deadline() {
        // size-limit flush: with max_batch items already queued, collect
        // must return immediately, far before max_wait elapses.
        let (tx, rx) = mpsc::channel();
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(250) };
        let t0 = Instant::now();
        match collect(&rx, &policy) {
            Collected::Batch(b) => assert_eq!(b.len(), 4),
            Collected::Closed => panic!(),
        }
        assert!(
            t0.elapsed() < Duration::from_millis(200),
            "size-limit flush waited for the deadline: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn max_batch_one_never_waits() {
        // degenerate size limit: every item is its own batch, and the
        // deadline never applies.
        let (tx, rx) = mpsc::channel();
        tx.send(7).unwrap();
        let policy = BatchPolicy { max_batch: 1, max_wait: Duration::from_secs(10) };
        let t0 = Instant::now();
        match collect(&rx, &policy) {
            Collected::Batch(b) => assert_eq!(b, vec![7]),
            Collected::Closed => panic!(),
        }
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn lone_item_flushes_at_deadline_limit() {
        // time-limit flush: one item and silence afterwards must flush a
        // 1-batch once max_wait has elapsed (not hang for more items).
        let (tx, rx) = mpsc::channel();
        tx.send(42).unwrap();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(15) };
        let t0 = Instant::now();
        match collect(&rx, &policy) {
            Collected::Batch(b) => assert_eq!(b, vec![42]),
            Collected::Closed => panic!(),
        }
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(14), "flushed early: {waited:?}");
        assert!(waited < Duration::from_secs(2), "deadline overshot: {waited:?}");
        drop(tx);
    }

    #[test]
    fn never_exceeds_max_batch_property() {
        crate::util::check::property(20, |rng| {
            let (tx, rx) = mpsc::channel();
            let n = rng.range(1, 40);
            for i in 0..n {
                tx.send(i).unwrap();
            }
            let policy = BatchPolicy {
                max_batch: rng.range(1, 12),
                max_wait: Duration::from_millis(1),
            };
            match collect(&rx, &policy) {
                Collected::Batch(b) => {
                    assert!(!b.is_empty() && b.len() <= policy.max_batch);
                    // FIFO order preserved
                    for w in b.windows(2) {
                        assert!(w[0] < w[1]);
                    }
                }
                Collected::Closed => panic!(),
            }
        });
    }
}
