//! Dynamic batching policy: collect up to `max_batch` requests, waiting at
//! most `max_wait` after the first arrival (size-or-deadline flush — the
//! standard serving policy, cf. vllm router / TF-Serving batcher), with
//! **bucket-aware** early flushing for workers that hold an executable
//! ladder instead of one fixed-batch executable.
//!
//! Pure std-mpsc logic, fully testable without XLA.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Bound on queued requests per replica. `Coordinator::infer` sheds
    /// load with an explicit error instead of letting a queue grow
    /// without bound when a replica is this far behind.
    pub queue_cap: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            queue_cap: 1024,
        }
    }
}

/// Outcome of one collection round.
pub enum Collected<T> {
    /// A batch of 1..=max_batch items (never empty).
    Batch(Vec<T>),
    /// The channel closed with nothing pending: the worker should exit.
    Closed,
}

/// Block for the first item, then keep collecting until the batch is full
/// or `max_wait` has elapsed since the first item arrived. Equivalent to
/// [`collect_bucketed`] with the single bucket `[max_batch]`.
pub fn collect<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Collected<T> {
    collect_bucketed(rx, policy, &[policy.max_batch])
}

/// Bucket-aware collection for a worker holding an executable ladder.
///
/// Inside a bucket the pending set pads up to the covering bucket anyway,
/// so growing it is free: wait out the deadline exactly like `collect` —
/// and once the deadline has expired, still take whatever is *already
/// queued* (non-blocking) up to the boundary, since dispatching a padded
/// slot while a real request sits in the queue helps no one. *At* a
/// bucket boundary the set already dispatches with zero padding, and one
/// more request would jump to the next bucket — roughly doubling the
/// batch's compute; paying deadline wait for that is only worth it when
/// arrivals are already outpacing the ladder, in which case they are
/// sitting in the queue. So at a boundary we likewise only drain what is
/// queued and flush the moment the queue runs dry.
pub fn collect_bucketed<T>(
    rx: &Receiver<T>,
    policy: &BatchPolicy,
    buckets: &[usize],
) -> Collected<T> {
    let first = match rx.recv() {
        Ok(item) => item,
        Err(_) => return Collected::Closed,
    };
    // Span starts at first arrival, not at the blocking recv above: the
    // idle wait for traffic is not collection work and would dominate
    // the trace row.
    let _sp = crate::obs::span("batch-collect", "serve");
    let deadline = Instant::now() + policy.max_wait;
    let mut batch = Vec::with_capacity(policy.max_batch);
    batch.push(first);
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if buckets.contains(&batch.len()) || now >= deadline {
            // boundary or expired deadline: free fills only — never wait
            match rx.try_recv() {
                Ok(item) => batch.push(item),
                Err(_) => break,
            }
            continue;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            // re-check: the expired-deadline branch drains the queue
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Collected::Batch(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn collects_full_batch_when_queue_is_hot() {
        let (tx, rx) = mpsc::channel();
        for i in 0..20 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(50),
            ..Default::default()
        };
        match collect(&rx, &policy) {
            Collected::Batch(b) => {
                assert_eq!(b, (0..8).collect::<Vec<_>>());
            }
            Collected::Closed => panic!("should batch"),
        }
        // the rest are still queued
        match collect(&rx, &policy) {
            Collected::Batch(b) => assert_eq!(b.len(), 8),
            Collected::Closed => panic!(),
        }
    }

    #[test]
    fn flushes_partial_batch_on_deadline() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(10),
            ..Default::default()
        };
        let t0 = Instant::now();
        match collect(&rx, &policy) {
            Collected::Batch(b) => {
                assert_eq!(b, vec![1, 2]);
                assert!(t0.elapsed() >= Duration::from_millis(9));
            }
            Collected::Closed => panic!(),
        }
    }

    #[test]
    fn closed_channel_reports_closed() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        assert!(matches!(
            collect(&rx, &BatchPolicy::default()),
            Collected::Closed
        ));
    }

    #[test]
    fn late_arrivals_join_within_window() {
        let (tx, rx) = mpsc::channel();
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(60),
            ..Default::default()
        };
        let sender = std::thread::spawn(move || {
            tx.send(1).unwrap();
            std::thread::sleep(Duration::from_millis(15));
            tx.send(2).unwrap();
            std::thread::sleep(Duration::from_millis(15));
            tx.send(3).unwrap();
        });
        match collect(&rx, &policy) {
            Collected::Batch(b) => assert_eq!(b, vec![1, 2, 3]),
            Collected::Closed => panic!(),
        }
        sender.join().unwrap();
    }

    #[test]
    fn full_batch_flushes_without_waiting_for_deadline() {
        // size-limit flush: with max_batch items already queued, collect
        // must return immediately, far before max_wait elapses.
        let (tx, rx) = mpsc::channel();
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(250),
            ..Default::default()
        };
        let t0 = Instant::now();
        match collect(&rx, &policy) {
            Collected::Batch(b) => assert_eq!(b.len(), 4),
            Collected::Closed => panic!(),
        }
        assert!(
            t0.elapsed() < Duration::from_millis(200),
            "size-limit flush waited for the deadline: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn max_batch_one_never_waits() {
        // degenerate size limit: every item is its own batch, and the
        // deadline never applies.
        let (tx, rx) = mpsc::channel();
        tx.send(7).unwrap();
        let policy = BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_secs(10),
            ..Default::default()
        };
        let t0 = Instant::now();
        match collect(&rx, &policy) {
            Collected::Batch(b) => assert_eq!(b, vec![7]),
            Collected::Closed => panic!(),
        }
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn lone_item_flushes_at_deadline_limit() {
        // time-limit flush: one item and silence afterwards must flush a
        // 1-batch once max_wait has elapsed (not hang for more items).
        let (tx, rx) = mpsc::channel();
        tx.send(42).unwrap();
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(15),
            ..Default::default()
        };
        let t0 = Instant::now();
        match collect(&rx, &policy) {
            Collected::Batch(b) => assert_eq!(b, vec![42]),
            Collected::Closed => panic!(),
        }
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(14), "flushed early: {waited:?}");
        assert!(waited < Duration::from_secs(2), "deadline overshot: {waited:?}");
        drop(tx);
    }

    #[test]
    fn bucket_boundary_flushes_without_deadline_wait() {
        // two queued items on ladder [1, 2, 4, 8]: the drain stops at the
        // 2-bucket boundary immediately, despite a huge max_wait — the
        // set already dispatches with zero padding.
        let (tx, rx) = mpsc::channel();
        tx.send(0).unwrap();
        tx.send(1).unwrap();
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs(5),
            ..Default::default()
        };
        let t0 = Instant::now();
        match collect_bucketed(&rx, &policy, &[1, 2, 4, 8]) {
            Collected::Batch(b) => assert_eq!(b, vec![0, 1]),
            Collected::Closed => panic!(),
        }
        assert!(t0.elapsed() < Duration::from_secs(1), "boundary must not wait");
        drop(tx);
    }

    #[test]
    fn inside_a_bucket_waits_for_the_deadline() {
        // one item strictly inside the 4-bucket of ladder [4, 8]: the pad
        // slots are free, so collect honours max_wait for late arrivals.
        let (tx, rx) = mpsc::channel();
        tx.send(7).unwrap();
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(20),
            ..Default::default()
        };
        let t0 = Instant::now();
        match collect_bucketed(&rx, &policy, &[4, 8]) {
            Collected::Batch(b) => assert_eq!(b, vec![7]),
            Collected::Closed => panic!(),
        }
        assert!(t0.elapsed() >= Duration::from_millis(19));
        drop(tx);
    }

    #[test]
    fn bucketed_collection_never_exceeds_max_batch_property() {
        crate::util::check::property(20, |rng| {
            let (tx, rx) = mpsc::channel();
            let n = rng.range(1, 40);
            for i in 0..n {
                tx.send(i).unwrap();
            }
            let max_batch = rng.range(1, 12);
            // random strictly-ascending ladder ending at max_batch
            let mut buckets: Vec<usize> =
                (1..max_batch).filter(|_| rng.range(0, 1) == 0).collect();
            buckets.push(max_batch);
            let policy = BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            };
            match collect_bucketed(&rx, &policy, &buckets) {
                Collected::Batch(b) => {
                    assert!(!b.is_empty() && b.len() <= max_batch);
                    // FIFO order preserved
                    for w in b.windows(2) {
                        assert!(w[0] < w[1]);
                    }
                }
                Collected::Closed => panic!(),
            }
        });
    }

    #[test]
    fn never_exceeds_max_batch_property() {
        crate::util::check::property(20, |rng| {
            let (tx, rx) = mpsc::channel();
            let n = rng.range(1, 40);
            for i in 0..n {
                tx.send(i).unwrap();
            }
            let policy = BatchPolicy {
                max_batch: rng.range(1, 12),
                max_wait: Duration::from_millis(1),
                ..Default::default()
            };
            match collect(&rx, &policy) {
                Collected::Batch(b) => {
                    assert!(!b.is_empty() && b.len() <= policy.max_batch);
                    // FIFO order preserved
                    for w in b.windows(2) {
                        assert!(w[0] < w[1]);
                    }
                }
                Collected::Closed => panic!(),
            }
        });
    }
}
