//! Serving metrics: lock-free counters + a sampled latency reservoir.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::Summary;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub batches: AtomicU64,
    pub batch_items: AtomicU64,
    pub errors: AtomicU64,
    /// end-to-end request latencies, seconds (bounded reservoir); covers
    /// BOTH successful and errored requests — a failed request still
    /// occupied the queue and the worker for its full latency
    latencies: Mutex<Vec<f64>>,
    /// latencies of errored requests only, seconds (bounded reservoir)
    error_latencies: Mutex<Vec<f64>>,
    /// time spent inside model execution, seconds
    exec_time: Mutex<Vec<f64>>,
}

const RESERVOIR: usize = 65_536;

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, items: usize, exec_secs: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_items.fetch_add(items as u64, Ordering::Relaxed);
        let mut t = self.exec_time.lock().unwrap();
        if t.len() < RESERVOIR {
            t.push(exec_secs);
        }
    }

    pub fn record_response(&self, latency_secs: f64) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        let mut l = self.latencies.lock().unwrap();
        if l.len() < RESERVOIR {
            l.push(latency_secs);
        }
    }

    /// An errored request still has an end-to-end latency; dropping it
    /// from the histogram (the seed behaviour) made tail latency look
    /// better exactly when the system was failing. Records into both the
    /// shared latency reservoir and the error-only reservoir.
    pub fn record_error_response(&self, latency_secs: f64) {
        self.errors.fetch_add(1, Ordering::Relaxed);
        let mut l = self.latencies.lock().unwrap();
        if l.len() < RESERVOIR {
            l.push(latency_secs);
        }
        drop(l);
        let mut e = self.error_latencies.lock().unwrap();
        if e.len() < RESERVOIR {
            e.push(latency_secs);
        }
    }

    pub fn snapshot(&self) -> MetricsReport {
        let latencies = self.latencies.lock().unwrap().clone();
        let error_latencies = self.error_latencies.lock().unwrap().clone();
        let exec = self.exec_time.lock().unwrap().clone();
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batch_items.load(Ordering::Relaxed);
        MetricsReport {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            batches,
            mean_batch_occupancy: if batches == 0 {
                0.0
            } else {
                items as f64 / batches as f64
            },
            latency: (!latencies.is_empty()).then(|| Summary::of(&latencies)),
            error_latency: (!error_latencies.is_empty())
                .then(|| Summary::of(&error_latencies)),
            exec: (!exec.is_empty()).then(|| Summary::of(&exec)),
        }
    }
}

#[derive(Debug)]
pub struct MetricsReport {
    pub requests: u64,
    pub responses: u64,
    pub errors: u64,
    pub batches: u64,
    pub mean_batch_occupancy: f64,
    /// All completed requests, errored ones included.
    pub latency: Option<Summary>,
    /// Errored requests only.
    pub error_latency: Option<Summary>,
    pub exec: Option<Summary>,
}

impl MetricsReport {
    pub fn render(&self) -> String {
        let mut s = format!(
            "requests={} responses={} errors={} batches={} occupancy={:.2}",
            self.requests, self.responses, self.errors, self.batches, self.mean_batch_occupancy
        );
        if let Some(l) = &self.latency {
            s.push_str(&format!(
                "\nlatency  p50={:.2}ms p90={:.2}ms p99={:.2}ms",
                l.p50 * 1e3,
                l.p90 * 1e3,
                l.p99 * 1e3
            ));
        }
        if let Some(e) = &self.error_latency {
            s.push_str(&format!(
                "\nerr-lat  p50={:.2}ms p99={:.2}ms",
                e.p50 * 1e3,
                e.p99 * 1e3
            ));
        }
        if let Some(e) = &self.exec {
            s.push_str(&format!("\nexec     mean={:.2}ms", e.trimmed_mean * 1e3));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_batch(2, 0.010);
        m.record_response(0.011);
        m.record_response(0.013);
        let r = m.snapshot();
        assert_eq!(r.requests, 2);
        assert_eq!(r.responses, 2);
        assert_eq!(r.batches, 1);
        assert_eq!(r.mean_batch_occupancy, 2.0);
        assert!(r.latency.unwrap().p50 > 0.010);
    }

    #[test]
    fn empty_snapshot_has_no_summaries() {
        let r = Metrics::new().snapshot();
        assert!(r.latency.is_none());
        assert!(r.error_latency.is_none());
        assert!(r.exec.is_none());
        assert_eq!(r.mean_batch_occupancy, 0.0);
    }

    #[test]
    fn errored_requests_stay_in_the_latency_histogram() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_response(0.001);
        m.record_error_response(0.250); // slow failure
        let r = m.snapshot();
        assert_eq!(r.errors, 1);
        assert_eq!(r.responses, 1);
        let lat = r.latency.expect("latency summary");
        assert!(
            lat.p99 > 0.2,
            "slow errored request must dominate the tail, p99={}",
            lat.p99
        );
        let el = r.error_latency.expect("error latency summary");
        assert!(el.p50 > 0.2);
        assert!(r.render().contains("err-lat"), "render must surface error latency");
    }

    #[test]
    fn render_contains_key_fields() {
        let m = Metrics::new();
        m.record_request();
        m.record_response(0.002);
        let s = m.snapshot().render();
        assert!(s.contains("requests=1"));
        assert!(s.contains("latency"));
    }
}
