//! Serving metrics: lock-free counters + uniformly-sampled latency
//! reservoirs (Vitter's Algorithm R, so p50/p99 describe the whole run,
//! not just warm-up), plus the bucketed-serving instrumentation:
//! per-bucket occupancy **and queue-wait vs execute-time split**, the
//! padding-waste ratio (real requests vs dispatched bucket capacity), a
//! queue-depth gauge sampled at admission, and load-shed / replica-death
//! counters.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::rng::Rng;
use crate::util::stats::Summary;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub batches: AtomicU64,
    pub batch_items: AtomicU64,
    pub errors: AtomicU64,
    /// Requests rejected at admission because the target replica's
    /// bounded queue was full.
    pub sheds: AtomicU64,
    /// Worker threads that died (panicked) while serving.
    pub replica_deaths: AtomicU64,
    /// Σ bucket capacity over all dispatched batches (`batch_items /
    /// bucket_capacity` is the fill ratio; `1 -` it the padding waste).
    bucket_capacity: AtomicU64,
    /// Deepest queue observed at admission (queued + executing).
    max_queue_depth: AtomicU64,
    /// bucket size -> dispatch aggregates (batches, items, exec, wait)
    bucket_counts: Mutex<BTreeMap<usize, BucketAgg>>,
    /// queue depth of the chosen replica at each admission. A RING (the
    /// `usize` is the overwrite cursor), not a first-N reservoir: depth
    /// is a time-varying gauge, so the summary must track the most
    /// recent window — a first-N capture would freeze on a quiet warmup
    /// period and report p99≈0 during the saturation that matters.
    queue_depths: Mutex<(Vec<f64>, usize)>,
    /// end-to-end request latencies, seconds (uniform reservoir); covers
    /// BOTH successful and errored requests — a failed request still
    /// occupied the queue and the worker for its full latency
    latencies: Mutex<Reservoir>,
    /// latencies of errored requests only, seconds (uniform reservoir);
    /// shed requests land here too (their latency is the admission time)
    error_latencies: Mutex<Reservoir>,
}

const RESERVOIR: usize = 65_536;

/// Per-bucket dispatch accumulator (interior of `bucket_counts`).
#[derive(Clone, Copy, Default)]
struct BucketAgg {
    batches: u64,
    items: u64,
    exec_secs: f64,
    wait_secs: f64,
}

/// Bounded uniform sample: Vitter's Algorithm R. Every observation —
/// first or ten-millionth — ends up in the sample with probability
/// `RESERVOIR / seen`, so percentiles describe the whole run. (The seed
/// version kept only the first `RESERVOIR` observations, which froze the
/// histogram on warmup traffic and hid late latency regressions.)
///
/// The RNG is our own deterministic [`Rng`], so two runs that observe
/// the same sequence report identical summaries.
struct Reservoir {
    samples: Vec<f64>,
    /// Total observations ever offered, including evicted/skipped ones.
    seen: u64,
    rng: Rng,
}

impl Default for Reservoir {
    fn default() -> Reservoir {
        Reservoir { samples: Vec::new(), seen: 0, rng: Rng::new(0x0b5e_7a11) }
    }
}

impl Reservoir {
    fn push(&mut self, sample: f64) {
        self.seen += 1;
        if self.samples.len() < RESERVOIR {
            self.samples.push(sample);
        } else {
            // Replace a random slot with probability RESERVOIR / seen.
            let j = self.rng.below(self.seen as usize);
            if j < RESERVOIR {
                self.samples[j] = sample;
            }
        }
    }
}

fn push_bounded(reservoir: &Mutex<Reservoir>, sample: f64) {
    reservoir.lock().unwrap().push(sample);
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// One dispatched batch: `items` real requests carried by a `bucket`-
    /// sized executable (`bucket - items` slots were padding).
    /// `exec_secs` is time inside model execution; `wait_secs` is the
    /// summed queue wait (admission → dispatch) of the carried requests.
    pub fn record_batch(&self, items: usize, bucket: usize, exec_secs: f64, wait_secs: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_items.fetch_add(items as u64, Ordering::Relaxed);
        self.bucket_capacity.fetch_add(bucket as u64, Ordering::Relaxed);
        let mut bc = self.bucket_counts.lock().unwrap();
        let e = bc.entry(bucket).or_insert_with(BucketAgg::default);
        e.batches += 1;
        e.items += items as u64;
        e.exec_secs += exec_secs;
        e.wait_secs += wait_secs;
    }

    /// Queue depth of the replica a request was just admitted to.
    pub fn record_queue_depth(&self, depth: usize) {
        self.max_queue_depth.fetch_max(depth as u64, Ordering::Relaxed);
        let mut q = self.queue_depths.lock().unwrap();
        let (buf, cursor) = &mut *q;
        if buf.len() < RESERVOIR {
            buf.push(depth as f64);
        } else {
            buf[*cursor % RESERVOIR] = depth as f64;
        }
        *cursor = cursor.wrapping_add(1);
    }

    pub fn record_response(&self, latency_secs: f64) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        push_bounded(&self.latencies, latency_secs);
    }

    /// An errored request still has an end-to-end latency; dropping it
    /// from the histogram (the seed behaviour) made tail latency look
    /// better exactly when the system was failing. Records into both the
    /// shared latency reservoir and the error-only reservoir.
    pub fn record_error_response(&self, latency_secs: f64) {
        self.errors.fetch_add(1, Ordering::Relaxed);
        push_bounded(&self.latencies, latency_secs);
        push_bounded(&self.error_latencies, latency_secs);
    }

    /// A request shed at admission (bounded queue full). The rejection is
    /// an explicit error the caller sees, so it lands in the error-latency
    /// reservoir — but NOT in the shared latency histogram: a
    /// microsecond-latency rejection would flatter p50 exactly when the
    /// system is saturated.
    pub fn record_shed(&self, latency_secs: f64) {
        self.sheds.fetch_add(1, Ordering::Relaxed);
        push_bounded(&self.error_latencies, latency_secs);
    }

    /// A request rejected at admission for a reason other than a full
    /// queue (today: every replica of the model is dead). Counts as an
    /// error the caller saw — keeping requests == responses + errors +
    /// sheds — with the same histogram treatment as a shed: error-latency
    /// reservoir only, never the shared latency histogram.
    pub fn record_rejected(&self, latency_secs: f64) {
        self.errors.fetch_add(1, Ordering::Relaxed);
        push_bounded(&self.error_latencies, latency_secs);
    }

    /// A worker thread died (panicked) while serving.
    pub fn record_replica_death(&self) {
        self.replica_deaths.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsReport {
        let (latencies, latency_seen) = {
            let r = self.latencies.lock().unwrap();
            (r.samples.clone(), r.seen)
        };
        let (error_latencies, error_latency_seen) = {
            let r = self.error_latencies.lock().unwrap();
            (r.samples.clone(), r.seen)
        };
        let queue_depths = self.queue_depths.lock().unwrap().0.clone();
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batch_items.load(Ordering::Relaxed);
        let capacity = self.bucket_capacity.load(Ordering::Relaxed);
        let buckets: Vec<BucketStat> = self
            .bucket_counts
            .lock()
            .unwrap()
            .iter()
            .map(|(&bucket, agg)| BucketStat {
                bucket,
                batches: agg.batches,
                items: agg.items,
                fill: if agg.batches == 0 {
                    0.0
                } else {
                    agg.items as f64 / (agg.batches * bucket as u64) as f64
                },
                exec_secs: agg.exec_secs,
                wait_secs: agg.wait_secs,
            })
            .collect();
        let exec_secs: f64 = buckets.iter().map(|b| b.exec_secs).sum();
        let wait_secs: f64 = buckets.iter().map(|b| b.wait_secs).sum();
        MetricsReport {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            sheds: self.sheds.load(Ordering::Relaxed),
            replica_deaths: self.replica_deaths.load(Ordering::Relaxed),
            batches,
            batch_items: items,
            bucket_capacity: capacity,
            mean_batch_occupancy: if batches == 0 {
                0.0
            } else {
                items as f64 / batches as f64
            },
            padding_waste: if capacity == 0 {
                0.0
            } else {
                1.0 - items as f64 / capacity as f64
            },
            buckets,
            max_queue_depth: self.max_queue_depth.load(Ordering::Relaxed),
            queue_depth: (!queue_depths.is_empty()).then(|| Summary::of(&queue_depths)),
            latency: (!latencies.is_empty()).then(|| Summary::of(&latencies)),
            latency_seen,
            error_latency: (!error_latencies.is_empty())
                .then(|| Summary::of(&error_latencies)),
            error_latency_seen,
            exec_secs,
            wait_secs,
        }
    }
}

/// Per-bucket dispatch accounting.
#[derive(Clone, Debug)]
pub struct BucketStat {
    pub bucket: usize,
    /// Batches dispatched at this bucket size.
    pub batches: u64,
    /// Real requests those batches carried.
    pub items: u64,
    /// `items / (batches * bucket)` — 1.0 means zero padding.
    pub fill: f64,
    /// Σ model-execution seconds over this bucket's batches.
    pub exec_secs: f64,
    /// Σ queue-wait seconds (admission → dispatch) over the requests
    /// this bucket's batches carried.
    pub wait_secs: f64,
}

impl BucketStat {
    /// Mean execution time per dispatched batch, seconds.
    pub fn exec_per_batch(&self) -> f64 {
        if self.batches == 0 { 0.0 } else { self.exec_secs / self.batches as f64 }
    }

    /// Mean queue wait per carried request, seconds.
    pub fn wait_per_item(&self) -> f64 {
        if self.items == 0 { 0.0 } else { self.wait_secs / self.items as f64 }
    }
}

#[derive(Debug)]
pub struct MetricsReport {
    pub requests: u64,
    pub responses: u64,
    pub errors: u64,
    pub sheds: u64,
    pub replica_deaths: u64,
    pub batches: u64,
    /// Σ real requests over all dispatched batches (raw counter — lets
    /// callers diff two snapshots, e.g. to exclude warmup traffic).
    pub batch_items: u64,
    /// Σ dispatched bucket capacity (raw counter, ditto).
    pub bucket_capacity: u64,
    pub mean_batch_occupancy: f64,
    /// Fraction of dispatched bucket slots that carried padding instead
    /// of a real request (0.0 = every slot was real work).
    pub padding_waste: f64,
    /// Occupancy histogram per bucket size, ascending.
    pub buckets: Vec<BucketStat>,
    /// Deepest replica queue observed at admission.
    pub max_queue_depth: u64,
    /// Queue depth of the chosen replica at each admission.
    pub queue_depth: Option<Summary>,
    /// All completed requests, errored ones included (shed excluded).
    /// Computed over a uniform reservoir sample of `latency_seen`
    /// observations.
    pub latency: Option<Summary>,
    /// Total latency observations ever offered to the reservoir (the
    /// summary's `n` caps at the reservoir size; this does not).
    pub latency_seen: u64,
    /// Errored requests, shed ones included.
    pub error_latency: Option<Summary>,
    /// Total error-latency observations ever offered to the reservoir.
    pub error_latency_seen: u64,
    /// Σ model-execution seconds over all dispatched batches.
    pub exec_secs: f64,
    /// Σ queue-wait seconds over all carried requests.
    pub wait_secs: f64,
}

impl MetricsReport {
    pub fn render(&self) -> String {
        let mut s = format!(
            "requests={} responses={} errors={} sheds={} deaths={} \
             batches={} occupancy={:.2} padding-waste={:.1}%",
            self.requests,
            self.responses,
            self.errors,
            self.sheds,
            self.replica_deaths,
            self.batches,
            self.mean_batch_occupancy,
            self.padding_waste * 100.0
        );
        if !self.buckets.is_empty() {
            s.push_str("\nbuckets ");
            for b in &self.buckets {
                s.push_str(&format!(
                    " {}: {} batches (fill {:.0}%, exec {:.2}ms/batch, wait {:.2}ms/req)",
                    b.bucket,
                    b.batches,
                    b.fill * 100.0,
                    b.exec_per_batch() * 1e3,
                    b.wait_per_item() * 1e3
                ));
            }
        }
        if let Some(q) = &self.queue_depth {
            s.push_str(&format!(
                "\nqueue    p50={:.1} p99={:.1} max={}",
                q.p50, q.p99, self.max_queue_depth
            ));
        }
        if let Some(l) = &self.latency {
            s.push_str(&format!(
                "\nlatency  p50={:.2}ms p90={:.2}ms p99={:.2}ms (sampled {} of {} seen)",
                l.p50 * 1e3,
                l.p90 * 1e3,
                l.p99 * 1e3,
                l.n,
                self.latency_seen
            ));
        }
        if let Some(e) = &self.error_latency {
            s.push_str(&format!(
                "\nerr-lat  p50={:.2}ms p99={:.2}ms",
                e.p50 * 1e3,
                e.p99 * 1e3
            ));
        }
        if self.batches > 0 {
            s.push_str(&format!(
                "\ntime     exec={:.1}ms queue-wait={:.1}ms (totals; per-bucket split above)",
                self.exec_secs * 1e3,
                self.wait_secs * 1e3
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_batch(2, 2, 0.010, 0.004);
        m.record_response(0.011);
        m.record_response(0.013);
        let r = m.snapshot();
        assert_eq!(r.requests, 2);
        assert_eq!(r.responses, 2);
        assert_eq!(r.batches, 1);
        assert_eq!(r.batch_items, 2);
        assert_eq!(r.bucket_capacity, 2);
        assert_eq!(r.mean_batch_occupancy, 2.0);
        assert!(r.latency.unwrap().p50 > 0.010);
    }

    #[test]
    fn empty_snapshot_has_no_summaries() {
        let r = Metrics::new().snapshot();
        assert!(r.latency.is_none());
        assert_eq!(r.latency_seen, 0);
        assert!(r.error_latency.is_none());
        assert_eq!(r.exec_secs, 0.0);
        assert_eq!(r.wait_secs, 0.0);
        assert!(r.queue_depth.is_none());
        assert!(r.buckets.is_empty());
        assert_eq!(r.mean_batch_occupancy, 0.0);
        assert_eq!(r.padding_waste, 0.0);
    }

    #[test]
    fn errored_requests_stay_in_the_latency_histogram() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_response(0.001);
        m.record_error_response(0.250); // slow failure
        let r = m.snapshot();
        assert_eq!(r.errors, 1);
        assert_eq!(r.responses, 1);
        let lat = r.latency.expect("latency summary");
        assert!(
            lat.p99 > 0.2,
            "slow errored request must dominate the tail, p99={}",
            lat.p99
        );
        let el = r.error_latency.expect("error latency summary");
        assert!(el.p50 > 0.2);
        assert!(r.render().contains("err-lat"), "render must surface error latency");
    }

    #[test]
    fn bucket_histogram_and_padding_waste() {
        let m = Metrics::new();
        // 3 real requests in a 4-bucket, 1 in a 1-bucket: 1 padded slot
        // over 5 dispatched -> 20% waste
        m.record_batch(3, 4, 0.010, 0.030);
        m.record_batch(1, 1, 0.002, 0.001);
        let r = m.snapshot();
        assert_eq!(r.batches, 2);
        assert!((r.padding_waste - 0.2).abs() < 1e-12, "waste {}", r.padding_waste);
        assert_eq!(r.buckets.len(), 2);
        assert_eq!(r.buckets[0].bucket, 1);
        assert_eq!(r.buckets[0].fill, 1.0);
        assert_eq!(r.buckets[1].bucket, 4);
        assert_eq!(r.buckets[1].batches, 1);
        assert!((r.buckets[1].fill - 0.75).abs() < 1e-12);
        // queue-wait vs execute split, per bucket and in aggregate
        assert!((r.buckets[1].exec_secs - 0.010).abs() < 1e-12);
        assert!((r.buckets[1].wait_secs - 0.030).abs() < 1e-12);
        assert!((r.buckets[1].exec_per_batch() - 0.010).abs() < 1e-12);
        assert!((r.buckets[1].wait_per_item() - 0.010).abs() < 1e-12);
        assert!((r.exec_secs - 0.012).abs() < 1e-12);
        assert!((r.wait_secs - 0.031).abs() < 1e-12);
        let rendered = r.render();
        assert!(rendered.contains("buckets"));
        assert!(rendered.contains("queue-wait"), "render must show the wait/exec split");
    }

    /// The whole point of Algorithm R over the seed's first-N capture: a
    /// latency regression that starts AFTER the reservoir fills must
    /// still move the reported percentiles.
    #[test]
    fn late_latency_shift_moves_p99() {
        let m = Metrics::new();
        // Fill the reservoir with fast warmup traffic, then regress.
        for _ in 0..RESERVOIR + 10_000 {
            m.record_response(0.001);
        }
        for _ in 0..RESERVOIR + 10_000 {
            m.record_response(0.100);
        }
        let r = m.snapshot();
        assert_eq!(r.latency_seen, 2 * (RESERVOIR as u64 + 10_000));
        let lat = r.latency.expect("latency summary");
        assert_eq!(lat.n, RESERVOIR);
        // ~half the sample should be late observations; a first-N capture
        // would report p99 = 1ms here.
        assert!(
            lat.p99 > 0.05,
            "late shift must reach the tail, p99={}",
            lat.p99
        );
        assert!(lat.max >= 0.1);
    }

    #[test]
    fn sheds_are_errors_the_caller_sees_but_not_latency_samples() {
        let m = Metrics::new();
        m.record_request();
        m.record_shed(0.0001);
        let r = m.snapshot();
        assert_eq!(r.sheds, 1);
        assert_eq!(r.responses, 0);
        assert!(r.latency.is_none(), "a shed must not flatter the latency histogram");
        assert!(r.error_latency.is_some(), "...but it IS an explicit error");
        assert!(r.render().contains("sheds=1"));
    }

    #[test]
    fn queue_depth_gauge_tracks_max() {
        let m = Metrics::new();
        m.record_queue_depth(1);
        m.record_queue_depth(7);
        m.record_queue_depth(3);
        let r = m.snapshot();
        assert_eq!(r.max_queue_depth, 7);
        assert_eq!(r.queue_depth.unwrap().n, 3);
        m.record_replica_death();
        assert_eq!(m.snapshot().replica_deaths, 1);
        // the gauge is a ring: once full, fresh samples overwrite the
        // oldest instead of being dropped (depth is a time-varying gauge
        // — the summary must describe the recent window)
        for _ in 0..65_546 {
            m.record_queue_depth(0);
        }
        m.record_queue_depth(42);
        let r = m.snapshot();
        let q = r.queue_depth.unwrap();
        assert_eq!(q.n, 65_536);
        assert_eq!(q.max, 42.0, "the newest sample must be present");
    }

    #[test]
    fn render_contains_key_fields() {
        let m = Metrics::new();
        m.record_request();
        m.record_response(0.002);
        let s = m.snapshot().render();
        assert!(s.contains("requests=1"));
        assert!(s.contains("latency"));
        assert!(s.contains("padding-waste"));
    }
}
