//! L3 serving coordinator: request router + dynamic batcher + worker pool,
//! built around a **shape-bucketed executable cache**.
//!
//! Architecture (threads + channels; no async runtime available offline):
//!
//! ```text
//!  clients ── Coordinator::infer(model, image)
//!                │  route by model name; replicas: least-loaded (queue
//!                │  depth), tie-broken by rotation; dead replicas skipped
//!                ▼
//!        bounded mpsc queue per worker ── admission sheds load with an
//!                │                        explicit error when full
//!                ▼
//!        batcher::collect_bucketed (size-or-deadline, flushes early at
//!                │                  bucket boundaries)
//!                ▼
//!        worker thread (owns Engine + a ServableModel: ONE weight set
//!                │      shared by a ladder of executables — batch 1, 2,
//!                │      4, …, max — compiled lazily; each collected
//!                ▼      batch pads only to its smallest covering bucket)
//!        per-request responses (logits + timing) via oneshot channels
//! ```
//!
//! The ladder is the point: a fixed-batch executable answers a single
//! request by padding it to the full device batch — the merged low-rank
//! model's latency win burned as padding FLOPs. With the bucket ladder a
//! 1-request batch runs the batch-1 executable, and all buckets share the
//! weights uploaded at worker construction (`netbuilder::ServableNet`).
//!
//! Backends are not required to be `Send` (the PJRT wrapper types hold raw
//! pointers), so each worker constructs its own `Engine` + model inside
//! its thread via the factory closure — no unsafe, clean shutdown by
//! dropping senders. Fixed-batch models (HLO-text artifacts) implement
//! [`ServableModel`] with a one-bucket ladder and keep the pad-to-ceiling
//! behaviour.
//!
//! A replica that panics mid-execution is detected (its `alive` flag
//! flips before the thread exits), counted in the metrics, and excluded
//! from routing; callers get a "replica died" error instead of a bare
//! channel disconnect.
//!
//! Factories receive a [`WorkerCtx`]: the worker's engine plus its share
//! of the coordinator's **kernel-thread budget**. The budget is
//! per-model: each `register` call splits it evenly across that model's
//! replicas (`max(1, budget / replicas)`), so replica scale-out never
//! oversubscribes the machine with `replicas × budget` executor
//! threads. A caller serving several models concurrently divides its
//! total budget across models before constructing the coordinator (see
//! `lrdx serve`).

pub mod batcher;
pub mod metrics;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::obs;
use crate::runtime::Engine;
use batcher::{BatchPolicy, Collected};
use metrics::Metrics;

/// A model a worker can execute over a ladder of batch buckets.
///
/// `buckets()` is ascending and ends at `max_batch()`; the worker
/// dispatches every collected batch to its smallest covering bucket and
/// pads only the bucket's free slots. Fixed-batch models (the HLO-text
/// artifacts) keep the default one-bucket ladder, which reproduces the
/// old pad-to-device-batch behaviour. `run_bucket` takes `&mut self` so
/// implementations may compile a bucket's executable lazily on first use.
pub trait ServableModel {
    /// Largest batch the worker may collect — the bucket-ladder ceiling.
    fn max_batch(&self) -> usize;
    /// Ascending executable bucket sizes; the last entry must equal
    /// `max_batch()`. Default: a single fixed bucket.
    fn buckets(&self) -> Vec<usize> {
        vec![self.max_batch()]
    }
    /// input spatial size
    fn hw(&self) -> usize;
    fn classes(&self) -> usize;
    /// `x` is a padded bucket `[bucket, 3, hw, hw]` flattened, where
    /// `bucket` is one of `buckets()`; returns flattened logits
    /// `[bucket, classes]`.
    fn run_bucket(&mut self, x: &[f32], bucket: usize) -> Result<Vec<f32>>;
}

/// One inference request: a single image [3, hw, hw], flattened.
pub struct InferRequest {
    pub image: Vec<f32>,
    enqueued: Instant,
    resp: SyncSender<Result<InferResponse>>,
}

/// Response with scheduling telemetry.
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub logits: Vec<f32>,
    /// end-to-end seconds (enqueue -> response)
    pub latency: f64,
    /// model execution seconds for the carrying batch
    pub exec: f64,
    /// how many real requests shared the batch
    pub batch_size: usize,
    /// the executable bucket that carried the batch (`>= batch_size`)
    pub bucket: usize,
    /// index of the replica that served the request
    pub replica: usize,
}

/// Shared router-visible state of one worker replica.
struct ReplicaState {
    /// replica index within its model entry (telemetry)
    index: usize,
    /// queued + executing requests — the least-loaded routing signal
    depth: AtomicUsize,
    /// flipped off when the worker thread dies; the router skips it
    alive: AtomicBool,
}

struct Replica {
    tx: SyncSender<InferRequest>,
    state: Arc<ReplicaState>,
    handle: std::thread::JoinHandle<()>,
}

struct ModelEntry {
    replicas: Vec<Replica>,
    /// rotation counter — breaks least-loaded ties so equal-depth
    /// replicas still interleave
    next: AtomicUsize,
    hw: usize,
}

/// What a worker factory gets to build its model with: the thread-local
/// engine and this worker's slice of the coordinator's thread budget
/// (feed it into `CompileOptions::threads` for native models).
pub struct WorkerCtx {
    engine: Engine,
    threads: usize,
}

impl WorkerCtx {
    pub fn new(engine: Engine, threads: usize) -> WorkerCtx {
        WorkerCtx { engine, threads: threads.max(1) }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Kernel threads this worker may use without oversubscribing.
    pub fn threads(&self) -> usize {
        self.threads
    }
}

/// The coordinator: owns the router table and all worker threads.
pub struct Coordinator {
    models: HashMap<String, ModelEntry>,
    pub metrics: Arc<Metrics>,
    policy: BatchPolicy,
    /// Native-executor threads granted to EACH registered model, split
    /// across that model's replicas (callers serving several models
    /// concurrently pre-divide their total budget — see `lrdx serve`).
    thread_budget: usize,
}

impl Coordinator {
    /// A coordinator whose kernel-thread budget is the machine's
    /// available parallelism.
    pub fn new(policy: BatchPolicy) -> Coordinator {
        Coordinator::with_thread_budget(policy, 0)
    }

    /// A coordinator with an explicit per-model kernel-thread budget
    /// (`lrdx serve` passes its `--threads` total divided by the number
    /// of served models; 0 means auto).
    pub fn with_thread_budget(policy: BatchPolicy, budget: usize) -> Coordinator {
        Coordinator {
            models: HashMap::new(),
            metrics: Arc::new(Metrics::new()),
            policy,
            thread_budget: crate::runtime::resolve_threads(budget),
        }
    }

    /// Register a model under `name` with `replicas` worker threads. The
    /// factory runs inside each worker thread (backends need not be Send)
    /// and must yield a model with consistent buckets/hw. The replicas
    /// share the coordinator's thread budget evenly.
    pub fn register<F>(&mut self, name: &str, hw: usize, replicas: usize, factory: F) -> Result<()>
    where
        F: Fn(&WorkerCtx) -> Result<Box<dyn ServableModel>> + Send + Sync + 'static,
    {
        if self.models.contains_key(name) {
            bail!("model {name:?} already registered");
        }
        let factory = Arc::new(factory);
        let n_replicas = replicas.max(1);
        let threads_per_worker = (self.thread_budget / n_replicas).max(1);
        let mut reps = Vec::new();
        for ri in 0..n_replicas {
            let (tx, rx) = mpsc::sync_channel::<InferRequest>(self.policy.queue_cap.max(1));
            let state = Arc::new(ReplicaState {
                index: ri,
                depth: AtomicUsize::new(0),
                alive: AtomicBool::new(true),
            });
            let metrics = self.metrics.clone();
            let policy = self.policy.clone();
            let factory = factory.clone();
            let nm = name.to_string();
            let wstate = state.clone();
            // report factory failure back synchronously
            let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
            let handle = std::thread::Builder::new()
                .name(format!("lrdx-worker-{nm}-{ri}"))
                .spawn(move || {
                    worker_loop(rx, metrics, policy, factory, threads_per_worker, wstate, ready_tx)
                })
                .expect("spawn worker");
            ready_rx
                .recv()
                .map_err(|_| anyhow!("worker {nm}-{ri} died during init"))??;
            reps.push(Replica { tx, state, handle });
        }
        self.models.insert(
            name.to_string(),
            ModelEntry { replicas: reps, next: AtomicUsize::new(0), hw },
        );
        Ok(())
    }

    pub fn model_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }

    /// Current queue depths (queued + executing) per replica of a model —
    /// the router's least-loaded signal, exposed for tests and telemetry.
    pub fn queue_depths(&self, model: &str) -> Option<Vec<usize>> {
        self.models.get(model).map(|e| {
            e.replicas.iter().map(|r| r.state.depth.load(Ordering::Relaxed)).collect()
        })
    }

    /// Submit one image; returns a receiver for the response (async-style).
    ///
    /// Routing is least-loaded over the model's live replicas (rotation
    /// breaks ties). A full replica queue sheds the request with an
    /// explicit "overloaded" error instead of queueing without bound; a
    /// replica found dead is skipped (and the request rerouted) — when
    /// every replica has died the error says so.
    pub fn infer(
        &self,
        model: &str,
        image: Vec<f32>,
    ) -> Result<Receiver<Result<InferResponse>>> {
        let entry = self
            .models
            .get(model)
            .ok_or_else(|| anyhow!("unknown model {model:?} (have {:?})", self.model_names()))?;
        let expect = 3 * entry.hw * entry.hw;
        if image.len() != expect {
            bail!("image has {} floats, model {model:?} expects {}", image.len(), expect);
        }
        self.metrics.record_request();
        // Covers routing: admission decision through enqueue (or shed).
        let _admit_sp = obs::span_with(|| format!("admit:{model}"), "serve");
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        let mut req = InferRequest { image, enqueued: Instant::now(), resp: resp_tx };
        let n = entry.replicas.len();
        let start = entry.next.fetch_add(1, Ordering::Relaxed);
        // Replicas whose queue we already found full this admission; a
        // request sheds only once every LIVE replica is full too.
        let mut full = vec![false; n];
        let mut any_full = false;
        loop {
            let mut best: Option<(usize, usize)> = None;
            for off in 0..n {
                let i = (start + off) % n;
                let r = &entry.replicas[i];
                if full[i] || !r.state.alive.load(Ordering::Relaxed) {
                    continue;
                }
                let d = r.state.depth.load(Ordering::Relaxed);
                let better = match best {
                    Some((_, bd)) => d < bd,
                    None => true,
                };
                if better {
                    best = Some((i, d));
                }
            }
            let Some((i, _)) = best else {
                if any_full {
                    self.metrics.record_shed(req.enqueued.elapsed().as_secs_f64());
                    bail!(
                        "model {model:?} overloaded: every live replica queue is \
                         full (cap {}), request shed",
                        self.policy.queue_cap
                    );
                }
                // the caller sees an error either way: count it, so
                // requests == responses + errors + sheds stays true
                self.metrics.record_rejected(req.enqueued.elapsed().as_secs_f64());
                bail!("all {n} replica(s) of model {model:?} died; request not routed");
            };
            let r = &entry.replicas[i];
            // count the request before sending so the worker can never
            // decrement a depth that was not yet incremented
            r.state.depth.fetch_add(1, Ordering::Relaxed);
            match r.tx.try_send(req) {
                Ok(()) => {
                    self.metrics
                        .record_queue_depth(r.state.depth.load(Ordering::Relaxed));
                    return Ok(resp_rx);
                }
                Err(TrySendError::Full(back)) => {
                    r.state.depth.fetch_sub(1, Ordering::Relaxed);
                    full[i] = true;
                    any_full = true;
                    req = back; // try the next-best live replica first
                }
                Err(TrySendError::Disconnected(back)) => {
                    r.state.depth.fetch_sub(1, Ordering::Relaxed);
                    r.state.alive.store(false, Ordering::Relaxed);
                    req = back; // replica died under us: reroute
                }
            }
        }
    }

    /// Submit and wait.
    pub fn infer_blocking(&self, model: &str, image: Vec<f32>) -> Result<InferResponse> {
        let rx = self.infer(model, image)?;
        match rx.recv() {
            Ok(result) => result,
            // the worker dropped the response channel without answering —
            // it panicked with this request queued or in flight
            Err(_) => bail!("replica serving {model:?} died while the request was in flight"),
        }
    }

    /// Drop queues and join workers.
    pub fn shutdown(self) {
        for (_, entry) in self.models {
            for r in entry.replicas {
                drop(r.tx);
                let _ = r.handle.join();
            }
        }
    }
}

/// Flips the replica's `alive` flag when the worker thread exits, and
/// counts a replica death unless the exit was a clean shutdown.
struct DeathWatch {
    state: Arc<ReplicaState>,
    metrics: Arc<Metrics>,
    armed: bool,
}

impl Drop for DeathWatch {
    fn drop(&mut self) {
        self.state.alive.store(false, Ordering::Relaxed);
        if self.armed {
            self.metrics.record_replica_death();
        }
    }
}

fn worker_loop(
    rx: Receiver<InferRequest>,
    metrics: Arc<Metrics>,
    policy: BatchPolicy,
    factory: Arc<dyn Fn(&WorkerCtx) -> Result<Box<dyn ServableModel>> + Send + Sync>,
    threads: usize,
    state: Arc<ReplicaState>,
    ready: SyncSender<Result<()>>,
) {
    let engine = match Engine::cpu() {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let ctx = WorkerCtx::new(engine, threads);
    let mut model = match factory(&ctx) {
        Ok(m) => m,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let max_batch = model.max_batch();
    let buckets = model.buckets();
    // the ladder rules live in netbuilder::validate_ladder; the worker
    // only adds its own contract (the ceiling is the collect bound)
    let ladder_check =
        crate::runtime::netbuilder::validate_ladder(&buckets).and_then(|b| {
            if *b.last().unwrap() != max_batch {
                bail!("bucket ladder {b:?} must end at max_batch {max_batch}");
            }
            Ok(())
        });
    if let Err(e) = ladder_check {
        let _ = ready.send(Err(e));
        return;
    }
    let img_len = 3 * model.hw() * model.hw();
    let classes = model.classes();
    let policy = BatchPolicy { max_batch, ..policy };
    let _ = ready.send(Ok(()));
    // From here the replica is routable: if this thread dies (a panic in
    // model execution), the watch flips `alive` so the router stops
    // sending work, and the death is counted in the metrics.
    let mut watch = DeathWatch { state, metrics: metrics.clone(), armed: true };

    // Reused batch assembly buffer — no allocation in the steady state.
    let mut xbatch = vec![0f32; max_batch * img_len];
    loop {
        let requests = match batcher::collect_bucketed(&rx, &policy, &buckets) {
            Collected::Batch(b) => b,
            Collected::Closed => {
                watch.armed = false; // clean shutdown, not a death
                obs::flush_thread();
                return;
            }
        };
        let n = requests.len();
        // smallest covering bucket; collect_bucketed caps n at the ladder
        // ceiling, so the find always succeeds
        let bucket = buckets.iter().copied().find(|&b| b >= n).unwrap_or(max_batch);
        // Queue wait: admission → dispatch, summed over carried requests.
        let wait_secs: f64 =
            requests.iter().map(|r| r.enqueued.elapsed().as_secs_f64()).sum();
        let t_asm = Instant::now();
        for (i, req) in requests.iter().enumerate() {
            xbatch[i * img_len..(i + 1) * img_len].copy_from_slice(&req.image);
        }
        // Pad only the bucket's free slots by repeating the first image.
        for i in n..bucket {
            let (head, tail) = xbatch.split_at_mut(i * img_len);
            tail[..img_len].copy_from_slice(&head[..img_len]);
        }
        if obs::enabled() {
            obs::event_from(&format!("bucket-dispatch:b{bucket}"), "serve", t_asm, t_asm.elapsed());
        }
        let t0 = Instant::now();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            model.run_bucket(&xbatch[..bucket * img_len], bucket)
        }));
        let exec = t0.elapsed().as_secs_f64();
        if obs::enabled() {
            obs::event_from(&format!("execute:b{bucket}"), "serve", t0, t0.elapsed());
        }
        metrics.record_batch(n, bucket, exec, wait_secs);
        let _reply_sp = obs::span_with(|| format!("reply:b{bucket}"), "serve");
        // the batch left the replica: the router sees it free before the
        // responses land
        watch.state.depth.fetch_sub(n, Ordering::Relaxed);
        let result = match result {
            Ok(r) => r,
            Err(panic) => {
                // The model panicked: this replica is done. Every admitted
                // request must still end as a response, an error or a shed
                // — so fail the carried batch AND whatever is still queued
                // (best-effort: `alive` flips first to stop new sends),
                // then exit; the armed watch counts the death.
                watch.state.alive.store(false, Ordering::Relaxed);
                let what = panic
                    .downcast_ref::<String>()
                    .map(|s| s.as_str())
                    .or_else(|| panic.downcast_ref::<&str>().copied())
                    .unwrap_or("<non-string panic>");
                let msg = format!("replica died: model panicked: {what}");
                fail_batch(&metrics, requests, &msg);
                let mut stranded = 0usize;
                while let Ok(req) = rx.try_recv() {
                    stranded += 1;
                    metrics.record_error_response(req.enqueued.elapsed().as_secs_f64());
                    let _ = req.resp.send(Err(anyhow!("{msg}")));
                }
                watch.state.depth.fetch_sub(stranded, Ordering::Relaxed);
                return;
            }
        };
        match result {
            Ok(logits) if logits.len() == bucket * classes => {
                for (i, req) in requests.into_iter().enumerate() {
                    let latency = req.enqueued.elapsed().as_secs_f64();
                    metrics.record_response(latency);
                    let _ = req.resp.send(Ok(InferResponse {
                        logits: logits[i * classes..(i + 1) * classes].to_vec(),
                        latency,
                        exec,
                        batch_size: n,
                        bucket,
                        replica: watch.state.index,
                    }));
                }
            }
            Ok(logits) => {
                // defensive: a malformed model must error the batch, not
                // panic the worker on a short slice
                let msg = format!(
                    "model returned {} logits for bucket {bucket} ({} expected)",
                    logits.len(),
                    bucket * classes
                );
                fail_batch(&metrics, requests, &msg);
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e:#}");
                fail_batch(&metrics, requests, &msg);
            }
        }
        // Publish this thread's buffered spans so a trace export taken
        // between batches sees the completed request path.
        drop(_reply_sp);
        if obs::enabled() {
            obs::flush_thread();
        }
    }
}

/// Errored requests keep their end-to-end latency: a failure that took
/// 300 ms must show up in the tail, not vanish from the histogram (each
/// failed request counts as one error).
fn fail_batch(metrics: &Metrics, requests: Vec<InferRequest>, msg: &str) {
    for req in requests {
        metrics.record_error_response(req.enqueued.elapsed().as_secs_f64());
        let _ = req.resp.send(Err(anyhow!("{msg}")));
    }
}

// --------------------------------------------------------------------------
// ServableModel impls for the runtime backends
// --------------------------------------------------------------------------

/// HLO-text artifacts are lowered at one fixed batch: a one-bucket ladder
/// (the worker pads every collected batch to the ceiling).
impl ServableModel for crate::runtime::artifacts::ForwardModel {
    fn max_batch(&self) -> usize {
        self.spec.batch
    }
    fn hw(&self) -> usize {
        self.spec.hw
    }
    fn classes(&self) -> usize {
        self.spec.classes
    }
    fn run_bucket(&mut self, x: &[f32], bucket: usize) -> Result<Vec<f32>> {
        if bucket != self.spec.batch {
            bail!(
                "{}: HLO artifact is fixed at batch {}, got bucket {bucket}",
                self.spec.name,
                self.spec.batch
            );
        }
        let t = crate::runtime::HostTensor::new(
            vec![self.spec.batch, 3, self.spec.hw, self.spec.hw],
            x.to_vec(),
        );
        Ok(self.infer(&t)?.data)
    }
}

/// A `BuiltNet` is compiled at one fixed batch — the fixed-batch baseline
/// the serve bench compares the ladder against.
impl ServableModel for crate::runtime::netbuilder::BuiltNet {
    fn max_batch(&self) -> usize {
        self.batch
    }
    fn hw(&self) -> usize {
        self.hw
    }
    fn classes(&self) -> usize {
        self.classes
    }
    fn run_bucket(&mut self, x: &[f32], bucket: usize) -> Result<Vec<f32>> {
        if bucket != self.batch {
            bail!("BuiltNet is fixed at batch {}, got bucket {bucket}", self.batch);
        }
        let eng = self.exe.engine().clone();
        let xb = eng.upload(x, &[self.batch, 3, self.hw, self.hw])?;
        let out = self.forward(&xb)?;
        Ok(out.to_host()?.data)
    }
}

/// The real ladder: lazily compiled per-bucket executables over one
/// weight upload.
impl ServableModel for crate::runtime::netbuilder::ServableNet {
    fn max_batch(&self) -> usize {
        *self.buckets().last().unwrap()
    }
    fn buckets(&self) -> Vec<usize> {
        crate::runtime::netbuilder::ServableNet::buckets(self).to_vec()
    }
    fn hw(&self) -> usize {
        self.hw
    }
    fn classes(&self) -> usize {
        self.classes
    }
    fn run_bucket(&mut self, x: &[f32], bucket: usize) -> Result<Vec<f32>> {
        crate::runtime::netbuilder::ServableNet::run_bucket(self, x, bucket)
    }
}

// --------------------------------------------------------------------------
// A trivial host-side model for coordinator unit tests (no XLA)
// --------------------------------------------------------------------------

#[cfg(test)]
pub(crate) struct EchoModel {
    pub batch: usize,
    pub buckets: Vec<usize>,
    pub hw: usize,
    pub delay: std::time::Duration,
}

#[cfg(test)]
impl EchoModel {
    fn fixed(batch: usize, hw: usize, delay: std::time::Duration) -> EchoModel {
        EchoModel { batch, buckets: vec![batch], hw, delay }
    }
}

#[cfg(test)]
impl ServableModel for EchoModel {
    fn max_batch(&self) -> usize {
        self.batch
    }
    fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }
    fn hw(&self) -> usize {
        self.hw
    }
    fn classes(&self) -> usize {
        2
    }
    fn run_bucket(&mut self, x: &[f32], bucket: usize) -> Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        let img = 3 * self.hw * self.hw;
        Ok((0..bucket)
            .flat_map(|i| {
                let s: f32 = x[i * img..(i + 1) * img].iter().sum();
                [s, -s]
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn coord(batch: usize, delay_ms: u64) -> Coordinator {
        let mut c = Coordinator::new(BatchPolicy {
            max_batch: batch,
            max_wait: Duration::from_millis(3),
            ..Default::default()
        });
        c.register("echo", 4, 1, move |_ctx| {
            Ok(Box::new(EchoModel::fixed(batch, 4, Duration::from_millis(delay_ms)))
                as Box<dyn ServableModel>)
        })
        .unwrap();
        c
    }

    #[test]
    fn single_request_roundtrip() {
        let c = coord(4, 0);
        let img = vec![1.0f32; 48];
        let r = c.infer_blocking("echo", img).unwrap();
        assert_eq!(r.logits, vec![48.0, -48.0]);
        assert_eq!(r.batch_size, 1);
        assert_eq!(r.bucket, 4, "fixed one-bucket ladder pads to the ceiling");
        c.shutdown();
    }

    #[test]
    fn concurrent_requests_share_batches() {
        let c = coord(8, 2);
        let rxs: Vec<_> = (0..16)
            .map(|i| c.infer("echo", vec![i as f32; 48]).unwrap())
            .collect();
        let mut max_batch_seen = 0;
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap().unwrap();
            assert_eq!(r.logits[0], 48.0 * i as f32);
            max_batch_seen = max_batch_seen.max(r.batch_size);
        }
        assert!(max_batch_seen > 1, "batching never kicked in");
        let snap = c.metrics.snapshot();
        assert_eq!(snap.responses, 16);
        assert!(snap.batches < 16, "each request got its own batch");
        c.shutdown();
    }

    #[test]
    fn bucketed_worker_dispatches_smallest_covering_bucket() {
        // ladder [1, 2, 4, 8]: three requests queued behind a busy worker
        // must come back as one batch in the 4-bucket — not padded to 8.
        let mut c = Coordinator::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            ..Default::default()
        });
        c.register("echo", 4, 1, |_ctx| {
            Ok(Box::new(EchoModel {
                batch: 8,
                buckets: vec![1, 2, 4, 8],
                hw: 4,
                delay: Duration::from_millis(50),
            }) as Box<dyn ServableModel>)
        })
        .unwrap();
        // warmup request keeps the worker busy for 50 ms...
        let warm = c.infer("echo", vec![1.0; 48]).unwrap();
        // (let the worker collect it alone before loading the queue)
        std::thread::sleep(Duration::from_millis(10));
        // ...while three more queue up behind it
        let rxs: Vec<_> =
            (0..3).map(|i| c.infer("echo", vec![i as f32; 48]).unwrap()).collect();
        let w = warm.recv().unwrap().unwrap();
        assert_eq!(w.batch_size, 1);
        assert_eq!(w.bucket, 1, "lone request must ride the 1-bucket");
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            assert_eq!(r.batch_size, 3);
            assert_eq!(r.bucket, 4, "3 requests must ride the 4-bucket");
        }
        let snap = c.metrics.snapshot();
        assert!(snap.padding_waste > 0.0, "the 4-bucket carried one pad slot");
        assert_eq!(snap.buckets.iter().map(|b| b.batches).sum::<u64>(), 2);
        c.shutdown();
    }

    #[test]
    fn unknown_model_rejected() {
        let c = coord(2, 0);
        assert!(c.infer("nope", vec![0.0; 48]).is_err());
        c.shutdown();
    }

    #[test]
    fn wrong_image_size_rejected() {
        let c = coord(2, 0);
        assert!(c.infer("echo", vec![0.0; 7]).is_err());
        c.shutdown();
    }

    #[test]
    fn duplicate_registration_rejected() {
        let mut c = coord(2, 0);
        let err = c.register("echo", 4, 1, |_ctx| unreachable!());
        assert!(err.is_err());
        c.shutdown();
    }

    #[test]
    fn invalid_bucket_ladder_rejected_at_register() {
        let mut c = Coordinator::new(BatchPolicy::default());
        let err = c.register("bad", 4, 1, |_ctx| {
            Ok(Box::new(EchoModel {
                batch: 8,
                buckets: vec![4, 2, 8], // not ascending
                hw: 4,
                delay: Duration::ZERO,
            }) as Box<dyn ServableModel>)
        });
        assert!(err.is_err());
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("ladder"), "unhelpful error: {msg}");
        c.shutdown();
    }

    #[test]
    fn replicas_share_the_thread_budget() {
        // budget 6 across 3 replicas -> 2 kernel threads per worker; a
        // budget smaller than the replica count still grants 1 each
        let mut c = Coordinator::with_thread_budget(
            BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            6,
        );
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let seen2 = seen.clone();
        c.register("m", 4, 3, move |ctx| {
            seen2.lock().unwrap().push(ctx.threads());
            Ok(Box::new(EchoModel::fixed(1, 4, Duration::ZERO))
                as Box<dyn ServableModel>)
        })
        .unwrap();
        let seen3 = seen.clone();
        c.register("starved", 4, 8, move |ctx| {
            seen3.lock().unwrap().push(ctx.threads());
            Ok(Box::new(EchoModel::fixed(1, 4, Duration::ZERO))
                as Box<dyn ServableModel>)
        })
        .unwrap();
        let got = seen.lock().unwrap().clone();
        assert_eq!(&got[..3], &[2, 2, 2], "6-thread budget over 3 replicas");
        assert_eq!(&got[3..], &[1; 8], "budget under-fill still grants 1");
        c.shutdown();
    }

    #[test]
    fn sequential_requests_are_served_correctly_by_replicas() {
        let mut c = Coordinator::new(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        c.register("m", 4, 3, |_ctx| {
            Ok(Box::new(EchoModel::fixed(1, 4, Duration::ZERO))
                as Box<dyn ServableModel>)
        })
        .unwrap();
        for i in 0..9 {
            let r = c.infer_blocking("m", vec![i as f32; 48]).unwrap();
            assert_eq!(r.logits[0], 48.0 * i as f32);
        }
        assert_eq!(c.metrics.snapshot().responses, 9);
        assert_eq!(c.queue_depths("m"), Some(vec![0, 0, 0]));
        c.shutdown();
    }

    #[test]
    fn least_loaded_routes_around_a_busy_replica() {
        // per-request delay model: x[0] milliseconds
        struct VarDelay;
        impl ServableModel for VarDelay {
            fn max_batch(&self) -> usize {
                1
            }
            fn hw(&self) -> usize {
                4
            }
            fn classes(&self) -> usize {
                2
            }
            fn run_bucket(&mut self, x: &[f32], _bucket: usize) -> Result<Vec<f32>> {
                std::thread::sleep(Duration::from_millis(x[0] as u64));
                let s: f32 = x.iter().sum();
                Ok(vec![s, -s])
            }
        }
        let mut c = Coordinator::new(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        c.register("m", 4, 2, |_ctx| Ok(Box::new(VarDelay) as Box<dyn ServableModel>))
            .unwrap();
        // a slow request occupies one replica (depth 1) for ~150 ms...
        let mut slow_img = vec![0.0f32; 48];
        slow_img[0] = 150.0;
        let slow = c.infer("m", slow_img).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        // ...so least-loaded must steer every fast request to the other
        let mut fast_replicas = Vec::new();
        for _ in 0..4 {
            let mut img = vec![0.0f32; 48];
            img[0] = 1.0;
            fast_replicas.push(c.infer_blocking("m", img).unwrap().replica);
        }
        let slow_replica = slow.recv().unwrap().unwrap().replica;
        assert!(
            fast_replicas.iter().all(|&r| r == fast_replicas[0]),
            "fast requests split across replicas: {fast_replicas:?}"
        );
        assert_ne!(
            fast_replicas[0], slow_replica,
            "a fast request queued behind the slow replica"
        );
        c.shutdown();
    }

    #[test]
    fn bounded_queue_sheds_load_instead_of_growing() {
        let mut c = Coordinator::new(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            queue_cap: 2,
        });
        c.register("m", 4, 1, |_ctx| {
            Ok(Box::new(EchoModel::fixed(1, 4, Duration::from_millis(20)))
                as Box<dyn ServableModel>)
        })
        .unwrap();
        let mut accepted = Vec::new();
        let mut shed = 0u64;
        for i in 0..20 {
            match c.infer("m", vec![i as f32; 48]) {
                Ok(rx) => accepted.push(rx),
                Err(e) => {
                    shed += 1;
                    let msg = format!("{e:#}");
                    assert!(msg.contains("overloaded"), "unhelpful shed error: {msg}");
                }
            }
        }
        assert!(shed > 0, "a 20-deep burst into cap 2 must shed");
        let n_accepted = accepted.len() as u64;
        for rx in accepted {
            rx.recv_timeout(Duration::from_secs(30))
                .expect("accepted request must still complete")
                .expect("inference ok");
        }
        let snap = c.metrics.snapshot();
        assert_eq!(snap.sheds, shed);
        assert_eq!(snap.responses, n_accepted);
        assert_eq!(snap.requests, 20);
        assert!(
            snap.max_queue_depth <= 2 + 1,
            "queue grew past cap + in-flight: {}",
            snap.max_queue_depth
        );
        assert!(snap.error_latency.is_some(), "sheds must hit the error histogram");
        c.shutdown();
    }

    #[test]
    fn dead_replica_is_reported_and_unrouted() {
        struct PanicModel;
        impl ServableModel for PanicModel {
            fn max_batch(&self) -> usize {
                1
            }
            fn hw(&self) -> usize {
                4
            }
            fn classes(&self) -> usize {
                2
            }
            fn run_bucket(&mut self, _x: &[f32], _bucket: usize) -> Result<Vec<f32>> {
                panic!("injected worker death");
            }
        }
        let mut c = Coordinator::new(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        c.register("p", 4, 1, |_ctx| Ok(Box::new(PanicModel) as Box<dyn ServableModel>))
            .unwrap();
        let err = c.infer_blocking("p", vec![0.0; 48]).expect_err("must fail");
        assert!(format!("{err:#}").contains("died"), "unclear death error: {err:#}");
        // give the unwinding worker a moment to flip its alive flag
        std::thread::sleep(Duration::from_millis(100));
        let err = match c.infer("p", vec![0.0; 48]) {
            Err(e) => e,
            Ok(rx) => {
                // raced the flag flip: the queued request must still fail
                assert!(rx.recv().unwrap_or(Err(anyhow!("dropped"))).is_err());
                c.infer("p", vec![0.0; 48]).expect_err("dead replica must unroute")
            }
        };
        assert!(format!("{err:#}").contains("died"), "unclear routing error: {err:#}");
        let snap = c.metrics.snapshot();
        assert_eq!(snap.replica_deaths, 1);
        // no request vanishes from the accounting: the batch carried by
        // the panic is an error, and so is the all-replicas-dead
        // rejection of the second request (requests == responses +
        // errors + sheds)
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.errors, 2);
        assert_eq!(snap.responses, 0);
        assert_eq!(snap.sheds, 0);
        assert!(snap.error_latency.is_some());
        c.shutdown();
    }

    #[test]
    fn failing_model_reports_errors_to_all_requests() {
        let mut c = Coordinator::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            ..Default::default()
        });
        struct Broken;
        impl ServableModel for Broken {
            fn max_batch(&self) -> usize {
                4
            }
            fn hw(&self) -> usize {
                4
            }
            fn classes(&self) -> usize {
                2
            }
            fn run_bucket(&mut self, _x: &[f32], _bucket: usize) -> Result<Vec<f32>> {
                bail!("injected failure")
            }
        }
        c.register("broken", 4, 1, |_ctx| Ok(Box::new(Broken) as Box<dyn ServableModel>))
            .unwrap();
        let rxs: Vec<_> = (0..4)
            .map(|_| c.infer("broken", vec![0.0; 48]).unwrap())
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().is_err());
        }
        let snap = c.metrics.snapshot();
        // every failed request counts, and none vanish from the histogram
        assert_eq!(snap.errors, 4);
        assert_eq!(snap.responses, 0);
        // a model that *errors* (vs panics) keeps its replica alive
        assert_eq!(snap.replica_deaths, 0);
        let lat = snap.latency.expect("errored requests must record latency");
        assert!(lat.n >= 4, "expected >= 4 latency samples, got {}", lat.n);
        assert!(snap.error_latency.is_some());
        c.shutdown();
    }

    #[test]
    fn short_logits_error_the_batch_without_killing_the_worker() {
        struct Short;
        impl ServableModel for Short {
            fn max_batch(&self) -> usize {
                2
            }
            fn hw(&self) -> usize {
                4
            }
            fn classes(&self) -> usize {
                2
            }
            fn run_bucket(&mut self, _x: &[f32], _bucket: usize) -> Result<Vec<f32>> {
                Ok(vec![1.0]) // malformed: too short for any bucket
            }
        }
        let mut c = Coordinator::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        c.register("s", 4, 1, |_ctx| Ok(Box::new(Short) as Box<dyn ServableModel>))
            .unwrap();
        let err = c.infer_blocking("s", vec![0.0; 48]).expect_err("must fail");
        assert!(format!("{err:#}").contains("logits"), "unclear error: {err:#}");
        assert_eq!(c.metrics.snapshot().replica_deaths, 0);
        c.shutdown();
    }
}
