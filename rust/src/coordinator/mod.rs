//! L3 serving coordinator: request router + dynamic batcher + worker pool.
//!
//! Architecture (threads + channels; no async runtime available offline):
//!
//! ```text
//!  clients ── Coordinator::infer(model, image)
//!                │  route by model name (replicas: round-robin)
//!                ▼
//!        mpsc queue per worker ── batcher::collect (size-or-deadline)
//!                ▼
//!        worker thread (owns Engine + compiled model, weights on device)
//!                ▼
//!        per-request responses (logits + timing) via oneshot channels
//! ```
//!
//! Backends are not required to be `Send` (the PJRT wrapper types hold raw
//! pointers), so each worker constructs its own `Engine` + model inside its
//! thread via the factory closure — no unsafe, clean shutdown by dropping
//! senders. The same code path serves native-backend synthetic models and
//! PJRT artifact models.
//!
//! Factories receive a [`WorkerCtx`]: the worker's engine plus its share
//! of the coordinator's **kernel-thread budget**. The budget is
//! per-model: each `register` call splits it evenly across that model's
//! replicas (`max(1, budget / replicas)`), so replica scale-out never
//! oversubscribes the machine with `replicas × budget` executor
//! threads. A caller serving several models concurrently divides its
//! total budget across models before constructing the coordinator (see
//! `lrdx serve`).

pub mod batcher;
pub mod metrics;

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::runtime::Engine;
use batcher::{BatchPolicy, Collected};
use metrics::Metrics;

/// A model a worker can execute batch-at-a-time.
pub trait BatchModel {
    /// fixed device batch size
    fn batch(&self) -> usize;
    /// input spatial size
    fn hw(&self) -> usize;
    fn classes(&self) -> usize;
    /// `x` is a full device batch [batch, 3, hw, hw] flattened; returns
    /// flattened logits [batch, classes].
    fn run_batch(&self, x: &[f32]) -> Result<Vec<f32>>;
}

/// One inference request: a single image [3, hw, hw], flattened.
pub struct InferRequest {
    pub image: Vec<f32>,
    enqueued: Instant,
    resp: SyncSender<Result<InferResponse>>,
}

/// Response with scheduling telemetry.
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub logits: Vec<f32>,
    /// end-to-end seconds (enqueue -> response)
    pub latency: f64,
    /// model execution seconds for the carrying batch
    pub exec: f64,
    /// how many real requests shared the batch
    pub batch_size: usize,
}

struct Replica {
    tx: Sender<InferRequest>,
    handle: std::thread::JoinHandle<()>,
}

struct ModelEntry {
    replicas: Vec<Replica>,
    next: AtomicUsize,
    hw: usize,
}

/// What a worker factory gets to build its model with: the thread-local
/// engine and this worker's slice of the coordinator's thread budget
/// (feed it into `CompileOptions::threads` for native models).
pub struct WorkerCtx {
    engine: Engine,
    threads: usize,
}

impl WorkerCtx {
    pub fn new(engine: Engine, threads: usize) -> WorkerCtx {
        WorkerCtx { engine, threads: threads.max(1) }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Kernel threads this worker may use without oversubscribing.
    pub fn threads(&self) -> usize {
        self.threads
    }
}

/// The coordinator: owns the router table and all worker threads.
pub struct Coordinator {
    models: HashMap<String, ModelEntry>,
    pub metrics: Arc<Metrics>,
    policy: BatchPolicy,
    /// Native-executor threads granted to EACH registered model, split
    /// across that model's replicas (callers serving several models
    /// concurrently pre-divide their total budget — see `lrdx serve`).
    thread_budget: usize,
}

impl Coordinator {
    /// A coordinator whose kernel-thread budget is the machine's
    /// available parallelism.
    pub fn new(policy: BatchPolicy) -> Coordinator {
        Coordinator::with_thread_budget(policy, 0)
    }

    /// A coordinator with an explicit per-model kernel-thread budget
    /// (`lrdx serve` passes its `--threads` total divided by the number
    /// of served models; 0 means auto).
    pub fn with_thread_budget(policy: BatchPolicy, budget: usize) -> Coordinator {
        Coordinator {
            models: HashMap::new(),
            metrics: Arc::new(Metrics::new()),
            policy,
            thread_budget: crate::runtime::resolve_threads(budget),
        }
    }

    /// Register a model under `name` with `replicas` worker threads. The
    /// factory runs inside each worker thread (backends need not be Send)
    /// and must yield a model with consistent batch/hw. The replicas
    /// share the coordinator's thread budget evenly.
    pub fn register<F>(&mut self, name: &str, hw: usize, replicas: usize, factory: F) -> Result<()>
    where
        F: Fn(&WorkerCtx) -> Result<Box<dyn BatchModel>> + Send + Sync + 'static,
    {
        if self.models.contains_key(name) {
            bail!("model {name:?} already registered");
        }
        let factory = Arc::new(factory);
        let n_replicas = replicas.max(1);
        let threads_per_worker = (self.thread_budget / n_replicas).max(1);
        let mut reps = Vec::new();
        for ri in 0..n_replicas {
            let (tx, rx) = mpsc::channel::<InferRequest>();
            let metrics = self.metrics.clone();
            let policy = self.policy.clone();
            let factory = factory.clone();
            let nm = name.to_string();
            // report factory failure back synchronously
            let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
            let handle = std::thread::Builder::new()
                .name(format!("lrdx-worker-{nm}-{ri}"))
                .spawn(move || {
                    worker_loop(rx, metrics, policy, factory, threads_per_worker, ready_tx)
                })
                .expect("spawn worker");
            ready_rx
                .recv()
                .map_err(|_| anyhow!("worker {nm}-{ri} died during init"))??;
            reps.push(Replica { tx, handle });
        }
        self.models.insert(
            name.to_string(),
            ModelEntry { replicas: reps, next: AtomicUsize::new(0), hw },
        );
        Ok(())
    }

    pub fn model_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }

    /// Submit one image; returns a receiver for the response (async-style).
    pub fn infer(
        &self,
        model: &str,
        image: Vec<f32>,
    ) -> Result<Receiver<Result<InferResponse>>> {
        let entry = self
            .models
            .get(model)
            .ok_or_else(|| anyhow!("unknown model {model:?} (have {:?})", self.model_names()))?;
        let expect = 3 * entry.hw * entry.hw;
        if image.len() != expect {
            bail!("image has {} floats, model {model:?} expects {}", image.len(), expect);
        }
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        let idx = entry.next.fetch_add(1, Ordering::Relaxed) % entry.replicas.len();
        self.metrics.record_request();
        entry.replicas[idx]
            .tx
            .send(InferRequest { image, enqueued: Instant::now(), resp: resp_tx })
            .map_err(|_| anyhow!("worker for {model:?} is gone"))?;
        Ok(resp_rx)
    }

    /// Submit and wait.
    pub fn infer_blocking(&self, model: &str, image: Vec<f32>) -> Result<InferResponse> {
        let rx = self.infer(model, image)?;
        rx.recv().map_err(|_| anyhow!("response channel closed"))?
    }

    /// Drop queues and join workers.
    pub fn shutdown(self) {
        for (_, entry) in self.models {
            for r in entry.replicas {
                drop(r.tx);
                let _ = r.handle.join();
            }
        }
    }
}

fn worker_loop(
    rx: Receiver<InferRequest>,
    metrics: Arc<Metrics>,
    policy: BatchPolicy,
    factory: Arc<dyn Fn(&WorkerCtx) -> Result<Box<dyn BatchModel>> + Send + Sync>,
    threads: usize,
    ready: SyncSender<Result<()>>,
) {
    let engine = match Engine::cpu() {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let ctx = WorkerCtx::new(engine, threads);
    let model = match factory(&ctx) {
        Ok(m) => m,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let device_batch = model.batch();
    let img_len = 3 * model.hw() * model.hw();
    let classes = model.classes();
    let policy = BatchPolicy { max_batch: device_batch, ..policy };
    let _ = ready.send(Ok(()));

    // Reused batch assembly buffer — no allocation in the steady state.
    let mut xbatch = vec![0f32; device_batch * img_len];
    loop {
        let requests = match batcher::collect(&rx, &policy) {
            Collected::Batch(b) => b,
            Collected::Closed => return,
        };
        let n = requests.len();
        for (i, req) in requests.iter().enumerate() {
            xbatch[i * img_len..(i + 1) * img_len].copy_from_slice(&req.image);
        }
        // Pad by repeating the first image (device batch is fixed).
        for i in n..device_batch {
            let (head, tail) = xbatch.split_at_mut(i * img_len);
            tail[..img_len].copy_from_slice(&head[..img_len]);
        }
        let t0 = Instant::now();
        let result = model.run_batch(&xbatch);
        let exec = t0.elapsed().as_secs_f64();
        metrics.record_batch(n, exec);
        match result {
            Ok(logits) => {
                for (i, req) in requests.into_iter().enumerate() {
                    let latency = req.enqueued.elapsed().as_secs_f64();
                    metrics.record_response(latency);
                    let _ = req.resp.send(Ok(InferResponse {
                        logits: logits[i * classes..(i + 1) * classes].to_vec(),
                        latency,
                        exec,
                        batch_size: n,
                    }));
                }
            }
            Err(e) => {
                // Errored requests keep their end-to-end latency: a
                // failure that took 300 ms must show up in the tail, not
                // vanish from the histogram (each failed request counts
                // as one error).
                let msg = format!("batch execution failed: {e:#}");
                for req in requests {
                    metrics.record_error_response(req.enqueued.elapsed().as_secs_f64());
                    let _ = req.resp.send(Err(anyhow!("{msg}")));
                }
            }
        }
    }
}

// --------------------------------------------------------------------------
// BatchModel impls for the two runtime backends
// --------------------------------------------------------------------------

impl BatchModel for crate::runtime::artifacts::ForwardModel {
    fn batch(&self) -> usize {
        self.spec.batch
    }
    fn hw(&self) -> usize {
        self.spec.hw
    }
    fn classes(&self) -> usize {
        self.spec.classes
    }
    fn run_batch(&self, x: &[f32]) -> Result<Vec<f32>> {
        let t = crate::runtime::HostTensor::new(
            vec![self.spec.batch, 3, self.spec.hw, self.spec.hw],
            x.to_vec(),
        );
        Ok(self.infer(&t)?.data)
    }
}

impl BatchModel for crate::runtime::netbuilder::BuiltNet {
    fn batch(&self) -> usize {
        self.batch
    }
    fn hw(&self) -> usize {
        self.hw
    }
    fn classes(&self) -> usize {
        self.classes
    }
    fn run_batch(&self, x: &[f32]) -> Result<Vec<f32>> {
        let eng = self.exe.engine().clone();
        let xb = eng.upload(x, &[self.batch, 3, self.hw, self.hw])?;
        let out = self.forward(&xb)?;
        Ok(out.to_host()?.data)
    }
}

// --------------------------------------------------------------------------
// A trivial host-side model for coordinator unit tests (no XLA)
// --------------------------------------------------------------------------

#[cfg(test)]
pub(crate) struct EchoModel {
    pub batch: usize,
    pub hw: usize,
    pub delay: std::time::Duration,
}

#[cfg(test)]
impl BatchModel for EchoModel {
    fn batch(&self) -> usize {
        self.batch
    }
    fn hw(&self) -> usize {
        self.hw
    }
    fn classes(&self) -> usize {
        2
    }
    fn run_batch(&self, x: &[f32]) -> Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        let img = 3 * self.hw * self.hw;
        Ok((0..self.batch)
            .flat_map(|i| {
                let s: f32 = x[i * img..(i + 1) * img].iter().sum();
                [s, -s]
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn coord(batch: usize, delay_ms: u64) -> Coordinator {
        let mut c = Coordinator::new(BatchPolicy {
            max_batch: batch,
            max_wait: Duration::from_millis(3),
        });
        c.register("echo", 4, 1, move |_ctx| {
            Ok(Box::new(EchoModel {
                batch,
                hw: 4,
                delay: Duration::from_millis(delay_ms),
            }) as Box<dyn BatchModel>)
        })
        .unwrap();
        c
    }

    #[test]
    fn single_request_roundtrip() {
        let c = coord(4, 0);
        let img = vec![1.0f32; 48];
        let r = c.infer_blocking("echo", img).unwrap();
        assert_eq!(r.logits, vec![48.0, -48.0]);
        assert_eq!(r.batch_size, 1);
        c.shutdown();
    }

    #[test]
    fn concurrent_requests_share_batches() {
        let c = coord(8, 2);
        let rxs: Vec<_> = (0..16)
            .map(|i| c.infer("echo", vec![i as f32; 48]).unwrap())
            .collect();
        let mut max_batch_seen = 0;
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap().unwrap();
            assert_eq!(r.logits[0], 48.0 * i as f32);
            max_batch_seen = max_batch_seen.max(r.batch_size);
        }
        assert!(max_batch_seen > 1, "batching never kicked in");
        let snap = c.metrics.snapshot();
        assert_eq!(snap.responses, 16);
        assert!(snap.batches < 16, "each request got its own batch");
        c.shutdown();
    }

    #[test]
    fn unknown_model_rejected() {
        let c = coord(2, 0);
        assert!(c.infer("nope", vec![0.0; 48]).is_err());
        c.shutdown();
    }

    #[test]
    fn wrong_image_size_rejected() {
        let c = coord(2, 0);
        assert!(c.infer("echo", vec![0.0; 7]).is_err());
        c.shutdown();
    }

    #[test]
    fn duplicate_registration_rejected() {
        let mut c = coord(2, 0);
        let err = c.register("echo", 4, 1, |_ctx| unreachable!());
        assert!(err.is_err());
        c.shutdown();
    }

    #[test]
    fn replicas_share_the_thread_budget() {
        // budget 6 across 3 replicas -> 2 kernel threads per worker; a
        // budget smaller than the replica count still grants 1 each
        let mut c = Coordinator::with_thread_budget(
            BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
            6,
        );
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let seen2 = seen.clone();
        c.register("m", 4, 3, move |ctx| {
            seen2.lock().unwrap().push(ctx.threads());
            Ok(Box::new(EchoModel { batch: 1, hw: 4, delay: Duration::ZERO })
                as Box<dyn BatchModel>)
        })
        .unwrap();
        let seen3 = seen.clone();
        c.register("starved", 4, 8, move |ctx| {
            seen3.lock().unwrap().push(ctx.threads());
            Ok(Box::new(EchoModel { batch: 1, hw: 4, delay: Duration::ZERO })
                as Box<dyn BatchModel>)
        })
        .unwrap();
        let got = seen.lock().unwrap().clone();
        assert_eq!(&got[..3], &[2, 2, 2], "6-thread budget over 3 replicas");
        assert_eq!(&got[3..], &[1; 8], "budget under-fill still grants 1");
        c.shutdown();
    }

    #[test]
    fn replicas_round_robin() {
        let mut c = Coordinator::new(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
        });
        c.register("m", 4, 3, |_ctx| {
            Ok(Box::new(EchoModel { batch: 1, hw: 4, delay: Duration::ZERO })
                as Box<dyn BatchModel>)
        })
        .unwrap();
        for i in 0..9 {
            let r = c.infer_blocking("m", vec![i as f32; 48]).unwrap();
            assert_eq!(r.logits[0], 48.0 * i as f32);
        }
        assert_eq!(c.metrics.snapshot().responses, 9);
        c.shutdown();
    }

    #[test]
    fn failing_model_reports_errors_to_all_requests() {
        let mut c = Coordinator::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        });
        struct Broken;
        impl BatchModel for Broken {
            fn batch(&self) -> usize {
                4
            }
            fn hw(&self) -> usize {
                4
            }
            fn classes(&self) -> usize {
                2
            }
            fn run_batch(&self, _x: &[f32]) -> Result<Vec<f32>> {
                bail!("injected failure")
            }
        }
        c.register("broken", 4, 1, |_ctx| Ok(Box::new(Broken) as Box<dyn BatchModel>))
            .unwrap();
        let rxs: Vec<_> = (0..4)
            .map(|_| c.infer("broken", vec![0.0; 48]).unwrap())
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().is_err());
        }
        let snap = c.metrics.snapshot();
        // every failed request counts, and none vanish from the histogram
        assert_eq!(snap.errors, 4);
        assert_eq!(snap.responses, 0);
        let lat = snap.latency.expect("errored requests must record latency");
        assert!(lat.n >= 4, "expected >= 4 latency samples, got {}", lat.n);
        assert!(snap.error_latency.is_some());
        c.shutdown();
    }
}
