//! Factor-chain descriptor: the N-factor generalization of the paper's
//! `W = W1*W0` pair. A chain is an ordered list of factors from input to
//! output, each with its parameter shape, its link channels, and the
//! per-pixel MAC/gate data the analytic cost model needs. One descriptor
//! feeds `model::cost`, `decompose::params` count checks and the
//! `rank_opt::AnalyticTimer` so the three can never disagree about what a
//! scheme costs.

use crate::model::{ConvSite, SiteKind};

use super::Scheme;

/// One factor of a chain, in application order (input side first).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Factor {
    /// parameter-name suffix (`.u`, `.core`, `.kh`, `.kw`, `.w0`, `.w1`, `.v`)
    pub suffix: &'static str,
    /// stored parameter tensor shape
    pub shape: Vec<usize>,
    /// channels entering this factor
    pub in_ch: usize,
    /// channels leaving this factor (the link rank to the next factor)
    pub out_ch: usize,
    /// MACs per output pixel contributed by this factor
    pub macs_per_px: usize,
    /// the dimension whose tile efficiency gates this factor's contraction
    pub gate_dim: usize,
}

impl Factor {
    pub fn params(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Ordered factor chain for one site under one scheme.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FactorChain {
    pub factors: Vec<Factor>,
}

impl FactorChain {
    /// The chain a scheme lowers to at `site`, or `None` for schemes that
    /// are not a per-site factor chain (`Orig`, the merged-bottleneck pair).
    pub fn of(site: &ConvSite, scheme: &Scheme) -> Option<FactorChain> {
        let (c, s, k) = (site.c, site.s, site.k);
        let f = |suffix, shape: Vec<usize>, in_ch, out_ch, macs, gate| Factor {
            suffix,
            shape,
            in_ch,
            out_ch,
            macs_per_px: macs,
            gate_dim: gate,
        };
        let factors = match scheme {
            Scheme::Orig | Scheme::Merged { .. } | Scheme::MergedInto { .. } => {
                return None
            }
            // the chain of a sparse-composed site is its base's chain; the
            // residual arm is costed separately (`Scheme::sparse_nnz`)
            Scheme::Sparse { base, .. } => return FactorChain::of(site, base),
            Scheme::Svd { r } => vec![
                f("w0", vec![*r, c], c, *r, r * c, *r),
                f("w1", vec![s, *r], *r, s, s * r, s),
            ],
            Scheme::Tucker { r1, r2 } | Scheme::Tucker2 { r1, r2 } => {
                let core_shape = if k == 1 && site.kind != SiteKind::Stem {
                    // 1x1 convs and the fc head store a 2-d core
                    vec![*r2, *r1]
                } else {
                    vec![*r2, *r1, k, k]
                };
                vec![
                    f("u", vec![*r1, c], c, *r1, r1 * c, *r1),
                    f("core", core_shape, *r1, *r2, r2 * r1 * k * k, *r2),
                    f("v", vec![s, *r2], *r2, s, s * r2, s),
                ]
            }
            Scheme::Branched { r1, r2, groups } => vec![
                f("u", vec![*r1, c], c, *r1, r1 * c, *r1),
                f(
                    "core",
                    vec![*r2, r1 / groups, k, k],
                    *r1,
                    *r2,
                    r2 * (r1 / groups) * k * k,
                    *r2,
                ),
                f("v", vec![s, *r2], *r2, s, s * r2, s),
            ],
            Scheme::Cp { r } => {
                if k == 1 {
                    vec![
                        f("w0", vec![*r, c], c, *r, r * c, *r),
                        f("w1", vec![s, *r], *r, s, s * r, s),
                    ]
                } else {
                    vec![
                        f("u", vec![*r, c], c, *r, r * c, *r),
                        f("kh", vec![*r, k], *r, *r, r * k, *r),
                        f("kw", vec![*r, k], *r, *r, r * k, *r),
                        f("w1", vec![s, *r], *r, s, s * r, s),
                    ]
                }
            }
        };
        Some(FactorChain { factors })
    }

    pub fn len(&self) -> usize {
        self.factors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.factors.is_empty()
    }

    /// Total stored parameters of the chain (excluding BN / bias).
    pub fn params(&self) -> usize {
        self.factors.iter().map(Factor::params).sum()
    }

    /// Channel widths of the links BETWEEN factors (len = factors - 1).
    pub fn link_ranks(&self) -> Vec<usize> {
        self.factors[..self.factors.len().saturating_sub(1)]
            .iter()
            .map(|f| f.out_ch)
            .collect()
    }

    /// Total MACs over `area` output pixels.
    pub fn macs(&self, area: usize) -> usize {
        self.factors.iter().map(|f| f.macs_per_px * area).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SiteKind;

    fn conv(c: usize, s: usize, k: usize) -> ConvSite {
        ConvSite {
            name: "t".into(),
            c,
            s,
            k,
            stride: 1,
            padding: if k > 1 { 1 } else { 0 },
            kind: SiteKind::Conv,
        }
    }

    #[test]
    fn svd_chain_params_hand_computed() {
        let t = conv(64, 64, 1);
        let ch = FactorChain::of(&t, &Scheme::Svd { r: 16 }).unwrap();
        assert_eq!(ch.len(), 2);
        // 16*64 + 64*16 = 2048
        assert_eq!(ch.params(), 2048);
        assert_eq!(ch.link_ranks(), vec![16]);
    }

    #[test]
    fn tucker2_chain_params_hand_computed() {
        // kxk conv: 64*38 + 38*38*9 + 38*64 = 2432 + 12996 + 2432 = 17860
        let t = conv(64, 64, 3);
        let ch = FactorChain::of(&t, &Scheme::Tucker2 { r1: 38, r2: 38 }).unwrap();
        assert_eq!(ch.len(), 3);
        assert_eq!(ch.params(), 17860);
        assert_eq!(ch.link_ranks(), vec![38, 38]);
        // 1x1 conv: 64*16 + 16*16 + 16*64 = 1024 + 256 + 1024 = 2304
        let t1 = conv(64, 64, 1);
        let ch1 = FactorChain::of(&t1, &Scheme::Tucker2 { r1: 16, r2: 16 }).unwrap();
        assert_eq!(ch1.params(), 2304);
        assert_eq!(ch1.factors[1].shape, vec![16, 16]);
    }

    #[test]
    fn cp_chain_params_hand_computed() {
        // kxk conv: 137*64 + 137*3 + 137*3 + 64*137 = 8768+411+411+8768 = 18358
        let t = conv(64, 64, 3);
        let ch = FactorChain::of(&t, &Scheme::Cp { r: 137 }).unwrap();
        assert_eq!(ch.len(), 4);
        assert_eq!(ch.params(), 18358);
        assert_eq!(ch.link_ranks(), vec![137, 137, 137]);
        // 1x1 degenerates to the SVD pair
        let t1 = conv(64, 64, 1);
        let ch1 = FactorChain::of(&t1, &Scheme::Cp { r: 16 }).unwrap();
        assert_eq!(ch1.len(), 2);
        assert_eq!(ch1.params(), 2048);
    }

    #[test]
    fn macs_scale_with_area_and_orig_is_none() {
        let t = conv(64, 64, 3);
        let ch = FactorChain::of(&t, &Scheme::Tucker { r1: 38, r2: 38 }).unwrap();
        assert_eq!(ch.macs(1) * 7, ch.macs(7));
        assert!(FactorChain::of(&t, &Scheme::Orig).is_none());
        assert!(FactorChain::of(
            &t,
            &Scheme::MergedInto { peer: "x".into() }
        )
        .is_none());
    }
}
