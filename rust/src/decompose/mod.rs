//! Decomposition planner: schemes, rank selection (eq. 7) and the paper's
//! five variants. Weight-level transforms live in `weights.rs`; the
//! Algorithm 1 rank optimizer in `rank_opt.rs`.

pub mod params;
pub mod rank_opt;
pub mod weights;

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::model::{Arch, BlockKind, ConvSite, SiteKind};
use crate::util::json::Json;

/// Per-site decomposition scheme. JSON form matches python
/// (`["svd", r]`, `["tucker", r1, r2]`, ...) so plans interchange freely.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Scheme {
    Orig,
    Svd { r: usize },
    Tucker { r1: usize, r2: usize },
    Branched { r1: usize, r2: usize, groups: usize },
    /// conv2 of a merged bottleneck: only the Tucker core remains
    Merged { r1: usize, r2: usize },
    /// conv1/conv3 of a merged bottleneck: carries the folded 1x1 product
    MergedInto { peer: String },
}

pub type Plan = BTreeMap<String, Scheme>;

/// The paper's five evaluated configurations (+ original).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Orig,
    /// vanilla LRD (§2)
    Lrd,
    /// Algorithm 1 optimized ranks (§2.1)
    Opt,
    /// layer freezing (§2.2) — same plan as Lrd; freezing lives in training
    Freeze,
    /// layer merging (§2.3, Fig. 3)
    Merged,
    /// branching Tucker (§2.4, Fig. 4)
    Branched,
}

impl Variant {
    pub fn by_name(s: &str) -> Option<Variant> {
        Some(match s {
            "orig" => Variant::Orig,
            "lrd" => Variant::Lrd,
            "opt" => Variant::Opt,
            "freeze" => Variant::Freeze,
            "merged" => Variant::Merged,
            "branched" => Variant::Branched,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Variant::Orig => "orig",
            Variant::Lrd => "lrd",
            Variant::Opt => "opt",
            Variant::Freeze => "freeze",
            Variant::Merged => "merged",
            Variant::Branched => "branched",
        }
    }

    pub fn all() -> &'static [Variant] {
        &[
            Variant::Orig,
            Variant::Lrd,
            Variant::Opt,
            Variant::Freeze,
            Variant::Merged,
            Variant::Branched,
        ]
    }
}

// --------------------------------------------------------------------------
// Rank selection
// --------------------------------------------------------------------------

/// SVD rank giving `alpha`x parameter compression for an [S, C] weight:
/// R = C*S / (alpha * (C+S)). Matches the paper's Table 2 (64x64@2x -> 16).
pub fn svd_rank_for_ratio(c: usize, s: usize, alpha: f64) -> usize {
    let r = (c as f64 * s as f64 / (alpha * (c + s) as f64)) as usize;
    r.clamp(1, c.min(s))
}

/// Eq. (7): Tucker ranks (r1, r2 = beta*r1) for `alpha`x compression of a
/// [S, C, k, k] conv. `beta` defaults to S/C (ranks proportional to their
/// channel dims). Matches Table 2 (64x64x3x3@2x -> 38; 512@2x -> 309).
pub fn tucker_rank_for_ratio(
    c: usize,
    s: usize,
    k: usize,
    alpha: f64,
    beta: Option<f64>,
) -> (usize, usize) {
    let beta = beta.unwrap_or(s as f64 / c as f64);
    let k2 = (k * k) as f64;
    let (cf, sf) = (c as f64, s as f64);
    let term = (cf + beta * sf) / (beta * k2);
    let r1 = (-term + (term * term + 4.0 * cf * sf / (beta * alpha)).sqrt()) / 2.0;
    let r1 = (r1 as usize).clamp(1, c);
    let r2 = ((beta * r1 as f64) as usize).clamp(1, s);
    (r1, r2)
}

/// Eq. (10)-(11): quantize ranks down to multiples of N (minimum N).
pub fn quantize_ranks(r1: usize, r2: usize, groups: usize) -> (usize, usize) {
    (
        (r1 - r1 % groups).max(groups),
        (r2 - r2 % groups).max(groups),
    )
}

fn ratio_scheme(t: &ConvSite, alpha: f64) -> Scheme {
    if t.k == 1 {
        Scheme::Svd { r: svd_rank_for_ratio(t.c, t.s, alpha) }
    } else {
        let (r1, r2) = tucker_rank_for_ratio(t.c, t.s, t.k, alpha, None);
        Scheme::Tucker { r1, r2 }
    }
}

// --------------------------------------------------------------------------
// Plans
// --------------------------------------------------------------------------

/// Build the plan for one of the paper's variants. The stem conv is never
/// decomposed (3 input channels; the paper's Table 1 layer counts confirm).
/// `overrides` supplies Algorithm 1 results for `Variant::Opt`.
pub fn plan_variant(
    arch: &Arch,
    variant: Variant,
    alpha: f64,
    groups: usize,
    overrides: Option<&Plan>,
) -> Result<Plan> {
    let mut plan = Plan::new();
    let sites = arch.sites();
    for t in &sites {
        let scheme = if t.kind == SiteKind::Stem || variant == Variant::Orig {
            Scheme::Orig
        } else {
            match variant {
                Variant::Orig => unreachable!(),
                Variant::Lrd | Variant::Freeze | Variant::Merged => ratio_scheme(t, alpha),
                Variant::Opt => overrides
                    .and_then(|o| o.get(&t.name).cloned())
                    .unwrap_or_else(|| ratio_scheme(t, alpha)),
                Variant::Branched => {
                    if t.k > 1 {
                        // Branch the alpha-compression ranks (Table 6 compounds
                        // -47.69% into -66.75% via the extra core/N saving).
                        let (r1, r2) = tucker_rank_for_ratio(t.c, t.s, t.k, alpha, None);
                        let (r1, r2) = quantize_ranks(r1.min(t.c), r2.min(t.s), groups);
                        Scheme::Branched { r1, r2, groups }
                    } else {
                        ratio_scheme(t, alpha)
                    }
                }
            }
        };
        plan.insert(t.name.clone(), scheme);
    }
    if variant == Variant::Merged {
        if arch.block != BlockKind::Bottleneck {
            bail!("layer merging is defined for bottleneck nets");
        }
        for t in &sites {
            if let Some(pre) = t.name.strip_suffix(".conv2") {
                let (r1, r2) = tucker_rank_for_ratio(t.c, t.s, t.k, alpha, None);
                plan.insert(t.name.clone(), Scheme::Merged { r1, r2 });
                plan.insert(
                    format!("{pre}.conv1"),
                    Scheme::MergedInto { peer: t.name.clone() },
                );
                plan.insert(
                    format!("{pre}.conv3"),
                    Scheme::MergedInto { peer: t.name.clone() },
                );
            } else if t.kind == SiteKind::Fc {
                // fc has no adjacent 1x1 to fold into; keep it original so the
                // merged model really has the original depth (Table 3).
                plan.insert(t.name.clone(), Scheme::Orig);
            }
        }
    }
    Ok(plan)
}

// --------------------------------------------------------------------------
// JSON interchange (matches python's list encoding)
// --------------------------------------------------------------------------

impl Scheme {
    pub fn to_json(&self) -> Json {
        let arr = match self {
            Scheme::Orig => vec![Json::Str("orig".into())],
            Scheme::Svd { r } => vec![Json::Str("svd".into()), Json::Num(*r as f64)],
            Scheme::Tucker { r1, r2 } => vec![
                Json::Str("tucker".into()),
                Json::Num(*r1 as f64),
                Json::Num(*r2 as f64),
            ],
            Scheme::Branched { r1, r2, groups } => vec![
                Json::Str("branched".into()),
                Json::Num(*r1 as f64),
                Json::Num(*r2 as f64),
                Json::Num(*groups as f64),
            ],
            Scheme::Merged { r1, r2 } => vec![
                Json::Str("merged".into()),
                Json::Num(*r1 as f64),
                Json::Num(*r2 as f64),
            ],
            Scheme::MergedInto { peer } => {
                vec![Json::Str("merged_into".into()), Json::Str(peer.clone())]
            }
        };
        Json::Arr(arr)
    }

    pub fn from_json(j: &Json) -> Result<Scheme> {
        let a = j.arr()?;
        let tag = a[0].str()?;
        Ok(match tag {
            "orig" => Scheme::Orig,
            "svd" => Scheme::Svd { r: a[1].int()? as usize },
            "tucker" => {
                Scheme::Tucker { r1: a[1].int()? as usize, r2: a[2].int()? as usize }
            }
            "branched" => Scheme::Branched {
                r1: a[1].int()? as usize,
                r2: a[2].int()? as usize,
                groups: a[3].int()? as usize,
            },
            "merged" => {
                Scheme::Merged { r1: a[1].int()? as usize, r2: a[2].int()? as usize }
            }
            "merged_into" => Scheme::MergedInto { peer: a[1].str()?.to_string() },
            _ => bail!("unknown scheme tag {tag:?}"),
        })
    }
}

pub fn plan_to_json(plan: &Plan) -> Json {
    Json::Obj(plan.iter().map(|(k, v)| (k.clone(), v.to_json())).collect())
}

pub fn plan_from_json(j: &Json) -> Result<Plan> {
    let mut plan = Plan::new();
    for (k, v) in j.obj()? {
        plan.insert(k.clone(), Scheme::from_json(v)?);
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_svd_ranks() {
        assert_eq!(svd_rank_for_ratio(64, 64, 2.0), 16);
        assert_eq!(svd_rank_for_ratio(64, 256, 2.0), 25);
        assert_eq!(svd_rank_for_ratio(2048, 512, 2.0), 204);
        assert_eq!(svd_rank_for_ratio(512, 2048, 2.0), 204);
        // paper reports 335 for 2048x1001; exact floor is 336
        let fc = svd_rank_for_ratio(2048, 1001, 2.0);
        assert!((335..=336).contains(&fc), "fc rank {fc}");
    }

    #[test]
    fn table2_tucker_ranks() {
        assert_eq!(tucker_rank_for_ratio(64, 64, 3, 2.0, None), (38, 38));
        assert_eq!(tucker_rank_for_ratio(512, 512, 3, 2.0, None), (309, 309));
    }

    #[test]
    fn eq7_achieves_ratio() {
        for (c, s) in [(64, 64), (128, 256), (512, 512), (256, 1024)] {
            for alpha in [1.5, 2.0, 4.0] {
                let (r1, r2) = tucker_rank_for_ratio(c, s, 3, alpha, None);
                let orig = c * s * 9;
                let dec = c * r1 + r1 * r2 * 9 + r2 * s;
                assert!(
                    (dec as f64) <= orig as f64 / alpha * 1.05,
                    "({c},{s})@{alpha}: {dec} vs {orig}"
                );
            }
        }
    }

    #[test]
    fn quantize() {
        assert_eq!(quantize_ranks(309, 309, 4), (308, 308));
        assert_eq!(quantize_ranks(3, 5, 4), (4, 4));
    }

    #[test]
    fn lrd_plan_decomposes_everything_but_stem() {
        let arch = Arch::by_name("resnet50").unwrap();
        let plan = plan_variant(&arch, Variant::Lrd, 2.0, 4, None).unwrap();
        assert_eq!(plan["stem.conv"], Scheme::Orig);
        assert!(matches!(plan["layer1.0.conv1"], Scheme::Svd { .. }));
        assert!(matches!(plan["layer1.0.conv2"], Scheme::Tucker { .. }));
        assert!(matches!(plan["fc"], Scheme::Svd { .. }));
    }

    #[test]
    fn merged_plan_structure() {
        let arch = Arch::by_name("resnet50").unwrap();
        let plan = plan_variant(&arch, Variant::Merged, 2.0, 4, None).unwrap();
        assert!(matches!(plan["layer1.0.conv2"], Scheme::Merged { .. }));
        assert_eq!(
            plan["layer1.0.conv1"],
            Scheme::MergedInto { peer: "layer1.0.conv2".into() }
        );
        assert_eq!(plan["fc"], Scheme::Orig);
        assert!(matches!(plan["layer1.0.downsample"], Scheme::Svd { .. }));
    }

    #[test]
    fn merged_rejected_for_basic_blocks() {
        let arch = Arch::by_name("resnet18").unwrap();
        assert!(plan_variant(&arch, Variant::Merged, 2.0, 4, None).is_err());
    }

    #[test]
    fn branched_ranks_divisible() {
        let arch = Arch::by_name("resnet50").unwrap();
        let plan = plan_variant(&arch, Variant::Branched, 2.0, 4, None).unwrap();
        for s in plan.values() {
            if let Scheme::Branched { r1, r2, groups } = s {
                assert_eq!(r1 % groups, 0);
                assert_eq!(r2 % groups, 0);
            }
        }
    }

    #[test]
    fn json_roundtrip() {
        let arch = Arch::by_name("resnet-mini").unwrap();
        for v in Variant::all() {
            if *v == Variant::Merged && arch.block != BlockKind::Bottleneck {
                continue;
            }
            let plan = plan_variant(&arch, *v, 2.0, 2, None).unwrap();
            let back = plan_from_json(&plan_to_json(&plan)).unwrap();
            assert_eq!(back, plan, "variant {v:?}");
        }
    }

    #[test]
    fn opt_overrides_apply() {
        let arch = Arch::by_name("resnet-mini").unwrap();
        let mut ov = Plan::new();
        ov.insert("layer1.0.conv2".into(), Scheme::Orig);
        let plan = plan_variant(&arch, Variant::Opt, 2.0, 4, Some(&ov)).unwrap();
        assert_eq!(plan["layer1.0.conv2"], Scheme::Orig);
        assert!(matches!(plan["layer2.0.conv2"], Scheme::Tucker { .. }));
    }
}
