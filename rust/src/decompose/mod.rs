//! Decomposition planner: schemes, rank selection (eq. 7) and the paper's
//! five variants. Weight-level transforms live in `weights.rs`; the
//! Algorithm 1 rank optimizer in `rank_opt.rs`.

pub mod chain;
pub mod params;
pub mod rank_opt;
pub mod sparse;
pub mod weights;

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::model::{Arch, BlockKind, ConvSite, SiteKind};
use crate::util::json::Json;

/// Per-site decomposition scheme. JSON form matches python
/// (`["svd", r]`, `["tucker", r1, r2]`, ...) so plans interchange freely.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Scheme {
    Orig,
    Svd { r: usize },
    Tucker { r1: usize, r2: usize },
    Branched { r1: usize, r2: usize, groups: usize },
    /// conv2 of a merged bottleneck: only the Tucker core remains
    Merged { r1: usize, r2: usize },
    /// conv1/conv3 of a merged bottleneck: carries the folded 1x1 product
    MergedInto { peer: String },
    /// explicit three-factor chain u[r1,C] -> core[r2,r1,k,k] -> v[S,r2];
    /// unlike `Tucker` it also applies to 1x1 convs and the fc head
    Tucker2 { r1: usize, r2: usize },
    /// CP / Lebedev chain: rank-r two-factor split for 1x1/fc sites, and
    /// the four-factor 1x1 -> kx1 -> 1xk -> 1x1 chain for kxk convs
    Cp { r: usize },
    /// sparse-residual composition W ~= chain + S: `base` is any chain
    /// scheme, `ppm` the residual density in parts-per-million (integer so
    /// `Eq` stays derivable; 50_000 = 5%). S holds the largest-magnitude
    /// entries of W - reconstruct(chain) and is mask-frozen in training.
    Sparse { base: Box<Scheme>, ppm: u32 },
}

impl Scheme {
    /// Strip one sparse wrapper: (base scheme, residual density ppm if any).
    pub fn split_sparse(&self) -> (&Scheme, Option<u32>) {
        match self {
            Scheme::Sparse { base, ppm } => (base, Some(*ppm)),
            s => (s, None),
        }
    }

    /// Whether the scheme lowers to a per-site factor chain — the set a
    /// sparse residual arm can compose onto.
    pub fn chainlike(&self) -> bool {
        matches!(
            self,
            Scheme::Svd { .. } | Scheme::Tucker { .. } | Scheme::Tucker2 { .. } | Scheme::Cp { .. }
        )
    }

    /// Residual size for a `[s, c, k, k]` site at `ppm` density: at least
    /// one entry, computable from shape alone (fitters must hit it exactly
    /// so planned parameter shapes never depend on weight values).
    pub fn sparse_nnz(c: usize, s: usize, k: usize, ppm: u32) -> usize {
        let dense = c * s * k * k;
        ((dense as u64 * ppm as u64) / 1_000_000).max(1) as usize
    }
}

pub type Plan = BTreeMap<String, Scheme>;

/// The paper's five evaluated configurations (+ original).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Orig,
    /// vanilla LRD (§2)
    Lrd,
    /// Algorithm 1 optimized ranks (§2.1)
    Opt,
    /// layer freezing (§2.2) — same plan as Lrd; freezing lives in training
    Freeze,
    /// layer merging (§2.3, Fig. 3)
    Merged,
    /// branching Tucker (§2.4, Fig. 4)
    Branched,
    /// Lrd-shaped plan forced to the Tucker-2 three-factor chain family
    Tucker2,
    /// Lrd-shaped plan forced to the CP chain family
    Cp,
}

impl Variant {
    pub fn by_name(s: &str) -> Option<Variant> {
        Some(match s {
            "orig" => Variant::Orig,
            "lrd" => Variant::Lrd,
            "opt" => Variant::Opt,
            "freeze" => Variant::Freeze,
            "merged" => Variant::Merged,
            "branched" => Variant::Branched,
            "tucker2" => Variant::Tucker2,
            "cp" => Variant::Cp,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Variant::Orig => "orig",
            Variant::Lrd => "lrd",
            Variant::Opt => "opt",
            Variant::Freeze => "freeze",
            Variant::Merged => "merged",
            Variant::Branched => "branched",
            Variant::Tucker2 => "tucker2",
            Variant::Cp => "cp",
        }
    }

    pub fn all() -> &'static [Variant] {
        &[
            Variant::Orig,
            Variant::Lrd,
            Variant::Opt,
            Variant::Freeze,
            Variant::Merged,
            Variant::Branched,
            Variant::Tucker2,
            Variant::Cp,
        ]
    }
}

/// Which factor-chain family rank selection lowers a site into. The CLI's
/// `--scheme` flag picks one; `Svd` reproduces the paper's convention
/// (SVD pair for 1x1/fc, Tucker sandwich for kxk).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchemeFamily {
    Svd,
    Tucker2,
    Cp,
}

impl SchemeFamily {
    pub fn by_name(s: &str) -> Option<SchemeFamily> {
        Some(match s {
            "svd" => SchemeFamily::Svd,
            "tucker2" => SchemeFamily::Tucker2,
            "cp" => SchemeFamily::Cp,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchemeFamily::Svd => "svd",
            SchemeFamily::Tucker2 => "tucker2",
            SchemeFamily::Cp => "cp",
        }
    }

    pub fn all() -> &'static [SchemeFamily] {
        &[SchemeFamily::Svd, SchemeFamily::Tucker2, SchemeFamily::Cp]
    }
}

// --------------------------------------------------------------------------
// Rank selection
// --------------------------------------------------------------------------

/// SVD rank giving `alpha`x parameter compression for an [S, C] weight:
/// R = C*S / (alpha * (C+S)). Matches the paper's Table 2 (64x64@2x -> 16).
pub fn svd_rank_for_ratio(c: usize, s: usize, alpha: f64) -> usize {
    let r = (c as f64 * s as f64 / (alpha * (c + s) as f64)) as usize;
    r.clamp(1, c.min(s))
}

/// Eq. (7): Tucker ranks (r1, r2 = beta*r1) for `alpha`x compression of a
/// [S, C, k, k] conv. `beta` defaults to S/C (ranks proportional to their
/// channel dims). Matches Table 2 (64x64x3x3@2x -> 38; 512@2x -> 309).
pub fn tucker_rank_for_ratio(
    c: usize,
    s: usize,
    k: usize,
    alpha: f64,
    beta: Option<f64>,
) -> (usize, usize) {
    let beta = beta.unwrap_or(s as f64 / c as f64);
    let k2 = (k * k) as f64;
    let (cf, sf) = (c as f64, s as f64);
    let term = (cf + beta * sf) / (beta * k2);
    let r1 = (-term + (term * term + 4.0 * cf * sf / (beta * alpha)).sqrt()) / 2.0;
    let r1 = (r1 as usize).clamp(1, c);
    let r2 = ((beta * r1 as f64) as usize).clamp(1, s);
    (r1, r2)
}

/// Eq. (10)-(11): quantize ranks down to multiples of N (minimum N).
pub fn quantize_ranks(r1: usize, r2: usize, groups: usize) -> (usize, usize) {
    (
        (r1 - r1 % groups).max(groups),
        (r2 - r2 % groups).max(groups),
    )
}

/// CP rank giving `alpha`x parameter compression. For 1x1/fc sites the CP
/// chain degenerates to the SVD pair; for kxk convs the Lebedev chain costs
/// R*(C + S + 2k) parameters against the original C*S*k^2.
pub fn cp_rank_for_ratio(c: usize, s: usize, k: usize, alpha: f64) -> usize {
    if k <= 1 {
        return svd_rank_for_ratio(c, s, alpha);
    }
    let denom = alpha * (c + s + 2 * k) as f64;
    let r = (c as f64 * s as f64 * (k * k) as f64 / denom) as usize;
    // CP rank may legitimately exceed min(C,S); cap at the separable bound
    r.clamp(1, c.min(s) * k * k)
}

fn ratio_scheme(t: &ConvSite, alpha: f64) -> Scheme {
    if t.k == 1 {
        Scheme::Svd { r: svd_rank_for_ratio(t.c, t.s, alpha) }
    } else {
        let (r1, r2) = tucker_rank_for_ratio(t.c, t.s, t.k, alpha, None);
        Scheme::Tucker { r1, r2 }
    }
}

/// Family-aware rank selection at compression ratio `alpha`.
pub fn ratio_scheme_with(t: &ConvSite, alpha: f64, family: SchemeFamily) -> Scheme {
    match family {
        SchemeFamily::Svd => ratio_scheme(t, alpha),
        SchemeFamily::Tucker2 => {
            // the k=1 case solves the same quadratic with k^2 = 1, i.e. the
            // exact three-matrix chain C*r1 + r1*r2 + r2*S
            let (r1, r2) = tucker_rank_for_ratio(t.c, t.s, t.k.max(1), alpha, None);
            Scheme::Tucker2 { r1, r2 }
        }
        SchemeFamily::Cp => Scheme::Cp { r: cp_rank_for_ratio(t.c, t.s, t.k, alpha) },
    }
}

// --------------------------------------------------------------------------
// Plans
// --------------------------------------------------------------------------

/// Build the plan for one of the paper's variants. The stem conv is never
/// decomposed (3 input channels; the paper's Table 1 layer counts confirm).
/// `overrides` supplies Algorithm 1 results for `Variant::Opt`.
pub fn plan_variant(
    arch: &Arch,
    variant: Variant,
    alpha: f64,
    groups: usize,
    overrides: Option<&Plan>,
) -> Result<Plan> {
    plan_variant_with(arch, variant, SchemeFamily::Svd, alpha, groups, overrides, None)
}

/// Compose a sparse residual arm onto every chain-decomposed site of an
/// existing plan (e.g. an Algorithm 1 result); other sites are untouched.
pub fn sparsify_plan(plan: Plan, ppm: u32) -> Plan {
    plan.into_iter()
        .map(|(name, scheme)| {
            let scheme = if scheme.chainlike() {
                Scheme::Sparse { base: Box::new(scheme), ppm }
            } else {
                scheme
            };
            (name, scheme)
        })
        .collect()
}

/// `plan_variant` with an explicit factor-chain family. `Variant::Tucker2`
/// and `Variant::Cp` force their own family; everything else lowers via
/// `family` (the CLI's `--scheme` flag lands here). `sparse_ppm` composes a
/// sparse residual arm onto every chain-decomposed site (the CLI's
/// `--sparse-density`); Orig/Branched/Merged sites are left untouched.
pub fn plan_variant_with(
    arch: &Arch,
    variant: Variant,
    family: SchemeFamily,
    alpha: f64,
    groups: usize,
    overrides: Option<&Plan>,
    sparse_ppm: Option<u32>,
) -> Result<Plan> {
    // User-reachable argument checks (CLI --alpha/--groups land here):
    // typed errors, not the div-by-zero panic `quantize_ranks` would hit.
    if groups == 0 {
        bail!("rank quantization groups must be >= 1 (got --groups 0)");
    }
    if !(alpha.is_finite() && alpha > 0.0) {
        bail!("compression ratio alpha must be a finite positive number, got {alpha}");
    }
    let family = match variant {
        Variant::Tucker2 => SchemeFamily::Tucker2,
        Variant::Cp => SchemeFamily::Cp,
        _ => family,
    };
    let mut plan = Plan::new();
    let sites = arch.sites();
    for t in &sites {
        let scheme = if t.kind == SiteKind::Stem || variant == Variant::Orig {
            Scheme::Orig
        } else {
            match variant {
                Variant::Orig => unreachable!(),
                Variant::Lrd
                | Variant::Freeze
                | Variant::Merged
                | Variant::Tucker2
                | Variant::Cp => ratio_scheme_with(t, alpha, family),
                Variant::Opt => overrides
                    .and_then(|o| o.get(&t.name).cloned())
                    .unwrap_or_else(|| ratio_scheme_with(t, alpha, family)),
                Variant::Branched => {
                    if t.k > 1 {
                        // Branch the alpha-compression ranks (Table 6 compounds
                        // -47.69% into -66.75% via the extra core/N saving).
                        let (r1, r2) = tucker_rank_for_ratio(t.c, t.s, t.k, alpha, None);
                        let (r1, r2) = quantize_ranks(r1.min(t.c), r2.min(t.s), groups);
                        Scheme::Branched { r1, r2, groups }
                    } else {
                        ratio_scheme(t, alpha)
                    }
                }
            }
        };
        let scheme = match sparse_ppm {
            Some(ppm) if scheme.chainlike() => {
                Scheme::Sparse { base: Box::new(scheme), ppm }
            }
            _ => scheme,
        };
        plan.insert(t.name.clone(), scheme);
    }
    if variant == Variant::Merged {
        if arch.block != BlockKind::Bottleneck {
            bail!("layer merging is defined for bottleneck nets");
        }
        for t in &sites {
            if let Some(pre) = t.name.strip_suffix(".conv2") {
                let (r1, r2) = tucker_rank_for_ratio(t.c, t.s, t.k, alpha, None);
                plan.insert(t.name.clone(), Scheme::Merged { r1, r2 });
                plan.insert(
                    format!("{pre}.conv1"),
                    Scheme::MergedInto { peer: t.name.clone() },
                );
                plan.insert(
                    format!("{pre}.conv3"),
                    Scheme::MergedInto { peer: t.name.clone() },
                );
            } else if t.kind == SiteKind::Fc {
                // fc has no adjacent 1x1 to fold into; keep it original so the
                // merged model really has the original depth (Table 3).
                plan.insert(t.name.clone(), Scheme::Orig);
            }
        }
    }
    Ok(plan)
}

// --------------------------------------------------------------------------
// JSON interchange (matches python's list encoding)
// --------------------------------------------------------------------------

impl Scheme {
    pub fn to_json(&self) -> Json {
        let arr = match self {
            Scheme::Orig => vec![Json::Str("orig".into())],
            Scheme::Svd { r } => vec![Json::Str("svd".into()), Json::Num(*r as f64)],
            Scheme::Tucker { r1, r2 } => vec![
                Json::Str("tucker".into()),
                Json::Num(*r1 as f64),
                Json::Num(*r2 as f64),
            ],
            Scheme::Branched { r1, r2, groups } => vec![
                Json::Str("branched".into()),
                Json::Num(*r1 as f64),
                Json::Num(*r2 as f64),
                Json::Num(*groups as f64),
            ],
            Scheme::Merged { r1, r2 } => vec![
                Json::Str("merged".into()),
                Json::Num(*r1 as f64),
                Json::Num(*r2 as f64),
            ],
            Scheme::MergedInto { peer } => {
                vec![Json::Str("merged_into".into()), Json::Str(peer.clone())]
            }
            Scheme::Tucker2 { r1, r2 } => vec![
                Json::Str("tucker2".into()),
                Json::Num(*r1 as f64),
                Json::Num(*r2 as f64),
            ],
            Scheme::Cp { r } => vec![Json::Str("cp".into()), Json::Num(*r as f64)],
            Scheme::Sparse { base, ppm } => {
                vec![Json::Str("sparse".into()), Json::Num(*ppm as f64), base.to_json()]
            }
        };
        Json::Arr(arr)
    }

    pub fn from_json(j: &Json) -> Result<Scheme> {
        let a = j.arr()?;
        let tag = a[0].str()?;
        Ok(match tag {
            "orig" => Scheme::Orig,
            "svd" => Scheme::Svd { r: a[1].int()? as usize },
            "tucker" => {
                Scheme::Tucker { r1: a[1].int()? as usize, r2: a[2].int()? as usize }
            }
            "branched" => Scheme::Branched {
                r1: a[1].int()? as usize,
                r2: a[2].int()? as usize,
                groups: a[3].int()? as usize,
            },
            "merged" => {
                Scheme::Merged { r1: a[1].int()? as usize, r2: a[2].int()? as usize }
            }
            "merged_into" => Scheme::MergedInto { peer: a[1].str()?.to_string() },
            "tucker2" => {
                Scheme::Tucker2 { r1: a[1].int()? as usize, r2: a[2].int()? as usize }
            }
            "cp" => Scheme::Cp { r: a[1].int()? as usize },
            "sparse" => Scheme::Sparse {
                ppm: a[1].int()? as u32,
                base: Box::new(Scheme::from_json(&a[2])?),
            },
            _ => bail!("unknown scheme tag {tag:?}"),
        })
    }
}

pub fn plan_to_json(plan: &Plan) -> Json {
    Json::Obj(plan.iter().map(|(k, v)| (k.clone(), v.to_json())).collect())
}

pub fn plan_from_json(j: &Json) -> Result<Plan> {
    let mut plan = Plan::new();
    for (k, v) in j.obj()? {
        plan.insert(k.clone(), Scheme::from_json(v)?);
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_svd_ranks() {
        assert_eq!(svd_rank_for_ratio(64, 64, 2.0), 16);
        assert_eq!(svd_rank_for_ratio(64, 256, 2.0), 25);
        assert_eq!(svd_rank_for_ratio(2048, 512, 2.0), 204);
        assert_eq!(svd_rank_for_ratio(512, 2048, 2.0), 204);
        // paper reports 335 for 2048x1001; exact floor is 336
        let fc = svd_rank_for_ratio(2048, 1001, 2.0);
        assert!((335..=336).contains(&fc), "fc rank {fc}");
    }

    #[test]
    fn table2_tucker_ranks() {
        assert_eq!(tucker_rank_for_ratio(64, 64, 3, 2.0, None), (38, 38));
        assert_eq!(tucker_rank_for_ratio(512, 512, 3, 2.0, None), (309, 309));
    }

    #[test]
    fn eq7_achieves_ratio() {
        for (c, s) in [(64, 64), (128, 256), (512, 512), (256, 1024)] {
            for alpha in [1.5, 2.0, 4.0] {
                let (r1, r2) = tucker_rank_for_ratio(c, s, 3, alpha, None);
                let orig = c * s * 9;
                let dec = c * r1 + r1 * r2 * 9 + r2 * s;
                assert!(
                    (dec as f64) <= orig as f64 / alpha * 1.05,
                    "({c},{s})@{alpha}: {dec} vs {orig}"
                );
            }
        }
    }

    #[test]
    fn quantize() {
        assert_eq!(quantize_ranks(309, 309, 4), (308, 308));
        assert_eq!(quantize_ranks(3, 5, 4), (4, 4));
    }

    #[test]
    fn lrd_plan_decomposes_everything_but_stem() {
        let arch = Arch::by_name("resnet50").unwrap();
        let plan = plan_variant(&arch, Variant::Lrd, 2.0, 4, None).unwrap();
        assert_eq!(plan["stem.conv"], Scheme::Orig);
        assert!(matches!(plan["layer1.0.conv1"], Scheme::Svd { .. }));
        assert!(matches!(plan["layer1.0.conv2"], Scheme::Tucker { .. }));
        assert!(matches!(plan["fc"], Scheme::Svd { .. }));
    }

    #[test]
    fn merged_plan_structure() {
        let arch = Arch::by_name("resnet50").unwrap();
        let plan = plan_variant(&arch, Variant::Merged, 2.0, 4, None).unwrap();
        assert!(matches!(plan["layer1.0.conv2"], Scheme::Merged { .. }));
        assert_eq!(
            plan["layer1.0.conv1"],
            Scheme::MergedInto { peer: "layer1.0.conv2".into() }
        );
        assert_eq!(plan["fc"], Scheme::Orig);
        assert!(matches!(plan["layer1.0.downsample"], Scheme::Svd { .. }));
    }

    #[test]
    fn merged_rejected_for_basic_blocks() {
        let arch = Arch::by_name("resnet18").unwrap();
        assert!(plan_variant(&arch, Variant::Merged, 2.0, 4, None).is_err());
    }

    #[test]
    fn branched_ranks_divisible() {
        let arch = Arch::by_name("resnet50").unwrap();
        let plan = plan_variant(&arch, Variant::Branched, 2.0, 4, None).unwrap();
        for s in plan.values() {
            if let Scheme::Branched { r1, r2, groups } = s {
                assert_eq!(r1 % groups, 0);
                assert_eq!(r2 % groups, 0);
            }
        }
    }

    #[test]
    fn json_roundtrip() {
        let arch = Arch::by_name("resnet-mini").unwrap();
        for v in Variant::all() {
            if *v == Variant::Merged && arch.block != BlockKind::Bottleneck {
                continue;
            }
            let plan = plan_variant(&arch, *v, 2.0, 2, None).unwrap();
            let back = plan_from_json(&plan_to_json(&plan)).unwrap();
            assert_eq!(back, plan, "variant {v:?}");
        }
    }

    #[test]
    fn cp_rank_achieves_ratio() {
        for (c, s, k) in [(64usize, 64usize, 3usize), (128, 256, 3), (64, 64, 1)] {
            for alpha in [1.5f64, 2.0, 4.0] {
                let r = cp_rank_for_ratio(c, s, k, alpha);
                let orig = c * s * k * k;
                let dec = if k == 1 { r * (c + s) } else { r * (c + s + 2 * k) };
                assert!(
                    (dec as f64) <= orig as f64 / alpha * 1.05,
                    "({c},{s},{k})@{alpha}: {dec} vs {orig}"
                );
            }
        }
    }

    #[test]
    fn family_plans_cover_every_non_stem_site() {
        let arch = Arch::by_name("resnet-mini").unwrap();
        let t2 = plan_variant(&arch, Variant::Tucker2, 2.0, 2, None).unwrap();
        let cp = plan_variant(&arch, Variant::Cp, 2.0, 2, None).unwrap();
        assert_eq!(t2["stem.conv"], Scheme::Orig);
        assert_eq!(cp["stem.conv"], Scheme::Orig);
        for (name, s) in &t2 {
            if name != "stem.conv" {
                assert!(matches!(s, Scheme::Tucker2 { .. }), "{name}: {s:?}");
            }
        }
        for (name, s) in &cp {
            if name != "stem.conv" {
                assert!(matches!(s, Scheme::Cp { .. }), "{name}: {s:?}");
            }
        }
        // plumbing an explicit family through an Lrd-shaped variant matches
        let via_family =
            plan_variant_with(&arch, Variant::Lrd, SchemeFamily::Tucker2, 2.0, 2, None, None)
                .unwrap();
        assert_eq!(via_family, t2);
    }

    #[test]
    fn sparse_ppm_wraps_chain_sites_only() {
        let arch = Arch::by_name("resnet-mini").unwrap();
        let plan = plan_variant_with(
            &arch,
            Variant::Lrd,
            SchemeFamily::Svd,
            2.0,
            2,
            None,
            Some(50_000),
        )
        .unwrap();
        assert_eq!(plan["stem.conv"], Scheme::Orig);
        for (name, s) in &plan {
            if name == "stem.conv" {
                continue;
            }
            match s {
                Scheme::Sparse { base, ppm } => {
                    assert_eq!(*ppm, 50_000, "{name}");
                    assert!(
                        matches!(**base, Scheme::Svd { .. } | Scheme::Tucker { .. }),
                        "{name}: {base:?}"
                    );
                }
                other => panic!("{name}: expected sparse wrapper, got {other:?}"),
            }
        }
        // roundtrips through the JSON interchange, including the nesting
        let back = plan_from_json(&plan_to_json(&plan)).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn sparse_nnz_floor_and_scaling() {
        assert_eq!(Scheme::sparse_nnz(64, 64, 1, 50_000), 204);
        assert_eq!(Scheme::sparse_nnz(64, 64, 3, 50_000), 1843);
        assert_eq!(Scheme::sparse_nnz(2, 2, 1, 1), 1); // floor: never empty
        let s = Scheme::Sparse { base: Box::new(Scheme::Svd { r: 16 }), ppm: 50_000 };
        let (base, ppm) = s.split_sparse();
        assert_eq!(*base, Scheme::Svd { r: 16 });
        assert_eq!(ppm, Some(50_000));
        assert_eq!(Scheme::Orig.split_sparse(), (&Scheme::Orig, None));
    }

    #[test]
    fn tucker2_k1_ranks_solve_the_three_matrix_chain() {
        // 64x64 1x1 @ 2x: C*r1 + r1*r2 + r2*S must be <= 4096/2
        let site = ConvSite {
            name: "t".into(),
            c: 64,
            s: 64,
            k: 1,
            stride: 1,
            padding: 0,
            kind: SiteKind::Conv,
        };
        match ratio_scheme_with(&site, 2.0, SchemeFamily::Tucker2) {
            Scheme::Tucker2 { r1, r2 } => {
                let dec = 64 * r1 + r1 * r2 + r2 * 64;
                assert!(dec <= 64 * 64 / 2 + 64, "{r1}x{r2} -> {dec}");
                assert!(r1 >= 1 && r2 >= 1);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn opt_overrides_apply() {
        let arch = Arch::by_name("resnet-mini").unwrap();
        let mut ov = Plan::new();
        ov.insert("layer1.0.conv2".into(), Scheme::Orig);
        let plan = plan_variant(&arch, Variant::Opt, 2.0, 4, Some(&ov)).unwrap();
        assert_eq!(plan["layer1.0.conv2"], Scheme::Orig);
        assert!(matches!(plan["layer2.0.conv2"], Scheme::Tucker { .. }));
    }
}
