//! Rust-native model parameters: He-init original weights and the one-shot
//! decomposition of them under a plan (the rust mirror of
//! `python/compile/resnet.py::init_params/decompose_params`).
//!
//! Used by the netbuilder cross-checks, the pruning baseline and anywhere a
//! model's weights must exist without python.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use super::sparse::{self, SparseResidual};
use super::weights::{branch_tucker, cp_stack, merge_bottleneck, svd_split, tucker_stack, CpStack};
use super::{Plan, Scheme};
use crate::linalg::{Matrix, Tensor4, Tucker2};
use crate::model::{Arch, ConvSite, SiteKind};
use crate::runtime::HostTensor;
use crate::util::rng::Rng;

pub type Params = BTreeMap<String, HostTensor>;

fn ht_mat(m: &Matrix) -> HostTensor {
    HostTensor::new(vec![m.rows, m.cols], m.data.clone())
}

fn ht_t4(t: &Tensor4) -> HostTensor {
    HostTensor::new(vec![t.o, t.i, t.h, t.w], t.data.clone())
}

fn as_mat(t: &HostTensor) -> Result<Matrix> {
    if t.dims.len() != 2 {
        bail!("expected a 2-d matrix tensor, got shape {:?}", t.dims);
    }
    Ok(Matrix::from_vec(t.dims[0], t.dims[1], t.data.clone()))
}

fn as_t4(t: &HostTensor) -> Result<Tensor4> {
    if t.dims.len() != 4 {
        bail!("expected a 4-d tensor, got shape {:?}", t.dims);
    }
    Ok(Tensor4::from_vec(t.dims[0], t.dims[1], t.dims[2], t.dims[3], t.data.clone()))
}

/// Param lookup with a typed error instead of the `BTreeMap` index panic —
/// `orig`/`dec` maps arrive from CLI-loaded artifacts, so a missing key is a
/// user-input problem, not an internal invariant.
fn get<'a>(p: &'a Params, key: &str) -> Result<&'a HostTensor> {
    p.get(key).ok_or_else(|| anyhow!("missing parameter '{key}' in the source param set"))
}

/// He-initialised ORIGINAL weights + BN affines for every site.
pub fn init_orig_params(arch: &Arch, rng: &mut Rng) -> Params {
    let mut out = Params::new();
    for t in arch.sites() {
        let fan_in = t.c * t.k * t.k;
        if t.kind == SiteKind::Fc {
            out.insert(
                format!("{}.w", t.name),
                HostTensor::new(vec![t.s, t.c], rng.he_weights(t.s * t.c, fan_in)),
            );
            out.insert(format!("{}.b", t.name), HostTensor::zeros(vec![t.s]));
        } else {
            let shape = if t.k == 1 {
                vec![t.s, t.c]
            } else {
                vec![t.s, t.c, t.k, t.k]
            };
            let n: usize = shape.iter().product();
            out.insert(
                format!("{}.w", t.name),
                HostTensor::new(shape, rng.he_weights(n, fan_in)),
            );
            out.insert(
                format!("{}.bn.g", t.name),
                HostTensor::new(vec![t.s], vec![1.0; t.s]),
            );
            out.insert(format!("{}.bn.b", t.name), HostTensor::zeros(vec![t.s]));
        }
    }
    out
}

/// One-shot decomposition of original weights under `plan` — the paper's
/// built-in knowledge-distillation init (every factor computed, not random).
pub fn decompose_params(arch: &Arch, plan: &Plan, orig: &Params) -> Result<Params> {
    let mut out = Params::new();
    for t in arch.sites() {
        let scheme = plan.get(&t.name).unwrap_or(&Scheme::Orig);
        let w = get(orig, &format!("{}.w", t.name))?;
        if t.kind != SiteKind::Fc {
            out.insert(
                format!("{}.bn.g", t.name),
                get(orig, &format!("{}.bn.g", t.name))?.clone(),
            );
            out.insert(
                format!("{}.bn.b", t.name),
                get(orig, &format!("{}.bn.b", t.name))?.clone(),
            );
        }
        match scheme {
            Scheme::Orig => {
                out.insert(format!("{}.w", t.name), w.clone());
                if t.kind == SiteKind::Fc {
                    out.insert(format!("{}.b", t.name), get(orig, &format!("{}.b", t.name))?.clone());
                }
            }
            Scheme::Svd { r } => {
                let (w0, w1) = svd_split(&as_mat(w)?, *r);
                out.insert(format!("{}.w0", t.name), ht_mat(&w0));
                out.insert(format!("{}.w1", t.name), ht_mat(&w1));
                if t.kind == SiteKind::Fc {
                    out.insert(format!("{}.b", t.name), get(orig, &format!("{}.b", t.name))?.clone());
                }
            }
            Scheme::Tucker { r1, r2 } => {
                let f = tucker_stack(&as_t4(w)?, *r1, *r2);
                out.insert(format!("{}.u", t.name), ht_mat(&f.u));
                out.insert(format!("{}.core", t.name), ht_t4(&f.core));
                out.insert(format!("{}.v", t.name), ht_mat(&f.v));
            }
            Scheme::Branched { r1, r2, groups } => {
                let f = tucker_stack(&as_t4(w)?, *r1, *r2);
                let b = branch_tucker(&f, *groups)?;
                out.insert(format!("{}.u", t.name), ht_mat(&b.u));
                out.insert(format!("{}.core", t.name), ht_t4(&b.core));
                out.insert(format!("{}.v", t.name), ht_mat(&b.v));
            }
            Scheme::Merged { r1, r2 } => {
                let pre = match t.name.strip_suffix(".conv2") {
                    Some(p) => p,
                    None => bail!("merged scheme on non-conv2 site {}", t.name),
                };
                let f = tucker_stack(&as_t4(w)?, *r1, *r2);
                let w1 = as_mat(get(orig, &format!("{pre}.conv1.w"))?)?;
                let w3 = as_mat(get(orig, &format!("{pre}.conv3.w"))?)?;
                let m = merge_bottleneck(&w1, &f, &w3)?;
                out.insert(format!("{pre}.conv1.w"), ht_mat(&m.w1m));
                out.insert(format!("{}.w", t.name), ht_t4(&m.core));
                out.insert(format!("{pre}.conv3.w"), ht_mat(&m.w3m));
                // BN affines of the rewritten 1x1s now act on r1/r2 channels
                out.insert(
                    format!("{pre}.conv1.bn.g"),
                    HostTensor::new(vec![*r1], vec![1.0; *r1]),
                );
                out.insert(format!("{pre}.conv1.bn.b"), HostTensor::zeros(vec![*r1]));
                out.insert(
                    format!("{}.bn.g", t.name),
                    HostTensor::new(vec![*r2], vec![1.0; *r2]),
                );
                out.insert(format!("{}.bn.b", t.name), HostTensor::zeros(vec![*r2]));
            }
            Scheme::MergedInto { .. } => {} // written by the peer conv2
            Scheme::Tucker2 { r1, r2 } => {
                // three-factor chain for every site shape: kxk convs keep the
                // 4-d core, 1x1 convs and the fc head store a 2-d [r2, r1] core
                if w.dims.len() == 4 {
                    let f = tucker_stack(&as_t4(w)?, *r1, *r2);
                    out.insert(format!("{}.u", t.name), ht_mat(&f.u));
                    out.insert(format!("{}.core", t.name), ht_t4(&f.core));
                    out.insert(format!("{}.v", t.name), ht_mat(&f.v));
                } else {
                    let w4 =
                        Tensor4::from_vec(w.dims[0], w.dims[1], 1, 1, w.data.clone());
                    let f = tucker_stack(&w4, *r1, *r2);
                    out.insert(format!("{}.u", t.name), ht_mat(&f.u));
                    out.insert(
                        format!("{}.core", t.name),
                        HostTensor::new(vec![*r2, *r1], f.core.data.clone()),
                    );
                    out.insert(format!("{}.v", t.name), ht_mat(&f.v));
                }
                if t.kind == SiteKind::Fc {
                    out.insert(format!("{}.b", t.name), get(orig, &format!("{}.b", t.name))?.clone());
                }
            }
            Scheme::Cp { r } => {
                if t.k == 1 {
                    // CP of a matrix degenerates to the SVD pair
                    let (w0, w1) = svd_split(&as_mat(w)?, *r);
                    out.insert(format!("{}.w0", t.name), ht_mat(&w0));
                    out.insert(format!("{}.w1", t.name), ht_mat(&w1));
                    if t.kind == SiteKind::Fc {
                        out.insert(
                            format!("{}.b", t.name),
                            get(orig, &format!("{}.b", t.name))?.clone(),
                        );
                    }
                } else {
                    let f = cp_stack(&as_t4(w)?, *r);
                    out.insert(format!("{}.u", t.name), ht_mat(&f.u));
                    out.insert(format!("{}.kh", t.name), ht_mat(&f.kh));
                    out.insert(format!("{}.kw", t.name), ht_mat(&f.kw));
                    out.insert(format!("{}.w1", t.name), ht_mat(&f.w1));
                }
            }
            Scheme::Sparse { base, ppm } => {
                let fit = sparse::fit_site(&t, base, w, *ppm, 2)?;
                for (suffix, tensor) in fit.factors {
                    out.insert(format!("{}.{suffix}", t.name), tensor);
                }
                let (vals, idx) = fit.sparse.to_tensors();
                out.insert(format!("{}.s", t.name), vals);
                out.insert(format!("{}.s_idx", t.name), idx);
                if t.kind == SiteKind::Fc {
                    out.insert(format!("{}.b", t.name), get(orig, &format!("{}.b", t.name))?.clone());
                }
            }
        }
    }
    Ok(out)
}

/// Dense re-composition of chain-decomposed params back into `Orig`-style
/// weights — the oracle for the "decomposed forward == original forward of
/// the reconstruction" equivalence tests.
pub fn reconstruct_params(arch: &Arch, plan: &Plan, dec: &Params) -> Result<Params> {
    let mut out = Params::new();
    for t in arch.sites() {
        let scheme = plan.get(&t.name).unwrap_or(&Scheme::Orig);
        if t.kind != SiteKind::Fc {
            out.insert(
                format!("{}.bn.g", t.name),
                get(dec, &format!("{}.bn.g", t.name))?.clone(),
            );
            out.insert(
                format!("{}.bn.b", t.name),
                get(dec, &format!("{}.bn.b", t.name))?.clone(),
            );
        } else if let Some(b) = dec.get(&format!("{}.b", t.name)) {
            out.insert(format!("{}.b", t.name), b.clone());
        }
        out.insert(format!("{}.w", t.name), recon_site(&t, scheme, dec)?);
    }
    Ok(out)
}

/// Dense reconstruction of one site's weight from its decomposed factors
/// (recursing through a sparse wrapper by scattering S onto the base).
fn recon_site(t: &ConvSite, scheme: &Scheme, dec: &Params) -> Result<HostTensor> {
    let name = |suf: &str| format!("{}.{suf}", t.name);
    Ok(match scheme {
        Scheme::Orig => get(dec, &name("w"))?.clone(),
        Scheme::Svd { .. } => {
            let w0 = as_mat(get(dec, &name("w0"))?)?;
            let w1 = as_mat(get(dec, &name("w1"))?)?;
            ht_mat(&w1.matmul(&w0))
        }
        Scheme::Tucker { .. } | Scheme::Tucker2 { .. } => {
            let u = as_mat(get(dec, &name("u"))?)?;
            let v = as_mat(get(dec, &name("v"))?)?;
            let core = get(dec, &name("core"))?;
            if core.dims.len() == 4 {
                let f = Tucker2 { u, core: as_t4(core)?, v };
                ht_t4(&f.reconstruct())
            } else {
                let cm = as_mat(core)?;
                ht_mat(&v.matmul(&cm).matmul(&u))
            }
        }
        Scheme::Cp { .. } => {
            if t.k == 1 {
                let w0 = as_mat(get(dec, &name("w0"))?)?;
                let w1 = as_mat(get(dec, &name("w1"))?)?;
                ht_mat(&w1.matmul(&w0))
            } else {
                let f = CpStack {
                    u: as_mat(get(dec, &name("u"))?)?,
                    kh: as_mat(get(dec, &name("kh"))?)?,
                    kw: as_mat(get(dec, &name("kw"))?)?,
                    w1: as_mat(get(dec, &name("w1"))?)?,
                };
                ht_t4(&f.reconstruct())
            }
        }
        Scheme::Sparse { base, .. } => {
            let mut w = recon_site(t, base, dec)?;
            let sr =
                SparseResidual::from_tensors(&w.dims, get(dec, &name("s"))?, get(dec, &name("s_idx"))?)?;
            for (j, &fi) in sr.idx.iter().enumerate() {
                w.data[fi as usize] += sr.vals[j];
            }
            w
        }
        Scheme::Branched { .. } | Scheme::Merged { .. } | Scheme::MergedInto { .. } => {
            bail!("no dense per-site reconstruction for {scheme:?} at {}", t.name)
        }
    })
}

/// Paper §2.2 freeze mask over decomposed params: the SVD/Tucker 1x1
/// factor weights and the CP depthwise taps are frozen (false = frozen);
/// the core / last factor stays trainable. The sparse residual (`.s`
/// values and `.s_idx` pattern) is mask-frozen too — autograd rejects
/// gradients w.r.t. CSR values, so S must never land in `wrt`.
pub fn freeze_mask(params: &Params) -> BTreeMap<String, bool> {
    params
        .keys()
        .map(|k| {
            let frozen = k.ends_with(".w0")
                || k.ends_with(".u")
                || k.ends_with(".v")
                || k.ends_with(".kh")
                || k.ends_with(".kw")
                || k.ends_with(".s")
                || k.ends_with(".s_idx");
            (k.clone(), !frozen)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::{plan_variant, Variant};
    use crate::model::cost;

    #[test]
    fn decomposed_param_count_matches_cost_model() {
        let arch = Arch::by_name("resnet-mini").unwrap();
        let mut rng = Rng::new(1);
        let orig = init_orig_params(&arch, &mut rng);
        for v in [
            Variant::Lrd,
            Variant::Merged,
            Variant::Branched,
            Variant::Tucker2,
            Variant::Cp,
        ] {
            let plan = plan_variant(&arch, v, 2.0, 2, None).unwrap();
            let params = decompose_params(&arch, &plan, &orig).unwrap();
            let all: usize = params.values().map(|t| t.data.len()).sum();
            let (want_total, _bn) = cost::count_params_split(&arch, &plan);
            assert_eq!(all, want_total, "{v:?}");
        }
    }

    #[test]
    fn chain_descriptor_matches_stored_factor_shapes() {
        // the chain descriptor and the actual decomposition must agree on
        // every factor's suffix and shape for the new families
        use crate::decompose::chain::FactorChain;
        let arch = Arch::by_name("resnet-mini").unwrap();
        let mut rng = Rng::new(9);
        let orig = init_orig_params(&arch, &mut rng);
        for v in [Variant::Tucker2, Variant::Cp] {
            let plan = plan_variant(&arch, v, 2.0, 2, None).unwrap();
            let params = decompose_params(&arch, &plan, &orig).unwrap();
            for t in arch.sites() {
                let scheme = &plan[&t.name];
                let Some(chain) = FactorChain::of(&t, scheme) else { continue };
                let mut stored = 0usize;
                for f in &chain.factors {
                    let p = &params[&format!("{}.{}", t.name, f.suffix)];
                    assert_eq!(p.dims, f.shape, "{} .{}", t.name, f.suffix);
                    stored += p.data.len();
                }
                assert_eq!(stored, chain.params(), "{}", t.name);
            }
        }
    }

    #[test]
    fn reconstruct_params_inverts_exact_decompositions() {
        // at full rank every chain reconstructs its original weight, so
        // reconstruct_params returns the original params (up to f32 noise)
        use crate::decompose::Plan;
        use crate::model::SiteKind;
        use crate::util::check::assert_allclose;
        let arch = Arch::by_name("resnet-mini").unwrap();
        let mut rng = Rng::new(10);
        let orig = init_orig_params(&arch, &mut rng);
        let mut plan = Plan::new();
        for t in arch.sites() {
            let scheme = if t.kind == SiteKind::Stem {
                Scheme::Orig
            } else if t.k == 1 {
                Scheme::Tucker2 { r1: t.c.min(t.s), r2: t.c.min(t.s) }
            } else {
                Scheme::Tucker2 { r1: t.c, r2: t.s }
            };
            plan.insert(t.name.clone(), scheme);
        }
        let dec = decompose_params(&arch, &plan, &orig).unwrap();
        let back = reconstruct_params(&arch, &plan, &dec).unwrap();
        for (k, v) in &orig {
            assert_eq!(back[k].dims, v.dims, "{k}");
            if k.ends_with(".w") {
                assert_allclose(&back[k].data, &v.data, 1e-2, 1e-2);
            }
        }
    }

    #[test]
    fn freeze_mask_targets_factors() {
        let arch = Arch::by_name("resnet-mini").unwrap();
        let mut rng = Rng::new(2);
        let orig = init_orig_params(&arch, &mut rng);
        for v in [Variant::Lrd, Variant::Cp] {
            let plan = plan_variant(&arch, v, 2.0, 2, None).unwrap();
            let params = decompose_params(&arch, &plan, &orig).unwrap();
            let mask = freeze_mask(&params);
            let frozen: Vec<_> =
                mask.iter().filter(|(_, &t)| !t).map(|(k, _)| k).collect();
            assert!(!frozen.is_empty());
            for k in frozen {
                assert!(
                    k.ends_with(".w0")
                        || k.ends_with(".u")
                        || k.ends_with(".v")
                        || k.ends_with(".kh")
                        || k.ends_with(".kw"),
                    "{k} frozen unexpectedly"
                );
            }
            if v == Variant::Lrd {
                assert!(mask["layer1.0.conv2.core"]);
            } else {
                // CP chain: depthwise taps frozen, the out 1x1 trainable
                assert!(!mask["layer1.0.conv2.kh"]);
                assert!(!mask["layer1.0.conv2.kw"]);
                assert!(mask["layer1.0.conv2.w1"]);
            }
        }
    }

    #[test]
    fn orig_params_have_bn_and_bias() {
        let arch = Arch::by_name("resnet-mini").unwrap();
        let mut rng = Rng::new(3);
        let p = init_orig_params(&arch, &mut rng);
        assert!(p.contains_key("stem.conv.bn.g"));
        assert!(p.contains_key("fc.b"));
        assert_eq!(p["fc.w"].dims, vec![10, 512]);
    }
}
