//! Rust-native model parameters: He-init original weights and the one-shot
//! decomposition of them under a plan (the rust mirror of
//! `python/compile/resnet.py::init_params/decompose_params`).
//!
//! Used by the netbuilder cross-checks, the pruning baseline and anywhere a
//! model's weights must exist without python.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::weights::{branch_tucker, merge_bottleneck, svd_split, tucker_stack};
use super::{Plan, Scheme};
use crate::linalg::{Matrix, Tensor4};
use crate::model::{Arch, SiteKind};
use crate::runtime::HostTensor;
use crate::util::rng::Rng;

pub type Params = BTreeMap<String, HostTensor>;

fn ht_mat(m: &Matrix) -> HostTensor {
    HostTensor::new(vec![m.rows, m.cols], m.data.clone())
}

fn ht_t4(t: &Tensor4) -> HostTensor {
    HostTensor::new(vec![t.o, t.i, t.h, t.w], t.data.clone())
}

fn as_mat(t: &HostTensor) -> Matrix {
    assert_eq!(t.dims.len(), 2, "expected matrix, got {:?}", t.dims);
    Matrix::from_vec(t.dims[0], t.dims[1], t.data.clone())
}

fn as_t4(t: &HostTensor) -> Tensor4 {
    assert_eq!(t.dims.len(), 4, "expected 4-d tensor, got {:?}", t.dims);
    Tensor4::from_vec(t.dims[0], t.dims[1], t.dims[2], t.dims[3], t.data.clone())
}

/// He-initialised ORIGINAL weights + BN affines for every site.
pub fn init_orig_params(arch: &Arch, rng: &mut Rng) -> Params {
    let mut out = Params::new();
    for t in arch.sites() {
        let fan_in = t.c * t.k * t.k;
        if t.kind == SiteKind::Fc {
            out.insert(
                format!("{}.w", t.name),
                HostTensor::new(vec![t.s, t.c], rng.he_weights(t.s * t.c, fan_in)),
            );
            out.insert(format!("{}.b", t.name), HostTensor::zeros(vec![t.s]));
        } else {
            let shape = if t.k == 1 {
                vec![t.s, t.c]
            } else {
                vec![t.s, t.c, t.k, t.k]
            };
            let n: usize = shape.iter().product();
            out.insert(
                format!("{}.w", t.name),
                HostTensor::new(shape, rng.he_weights(n, fan_in)),
            );
            out.insert(
                format!("{}.bn.g", t.name),
                HostTensor::new(vec![t.s], vec![1.0; t.s]),
            );
            out.insert(format!("{}.bn.b", t.name), HostTensor::zeros(vec![t.s]));
        }
    }
    out
}

/// One-shot decomposition of original weights under `plan` — the paper's
/// built-in knowledge-distillation init (every factor computed, not random).
pub fn decompose_params(arch: &Arch, plan: &Plan, orig: &Params) -> Result<Params> {
    let mut out = Params::new();
    for t in arch.sites() {
        let scheme = plan.get(&t.name).unwrap_or(&Scheme::Orig);
        let w = &orig[&format!("{}.w", t.name)];
        if t.kind != SiteKind::Fc {
            out.insert(
                format!("{}.bn.g", t.name),
                orig[&format!("{}.bn.g", t.name)].clone(),
            );
            out.insert(
                format!("{}.bn.b", t.name),
                orig[&format!("{}.bn.b", t.name)].clone(),
            );
        }
        match scheme {
            Scheme::Orig => {
                out.insert(format!("{}.w", t.name), w.clone());
                if t.kind == SiteKind::Fc {
                    out.insert(format!("{}.b", t.name), orig[&format!("{}.b", t.name)].clone());
                }
            }
            Scheme::Svd { r } => {
                let (w0, w1) = svd_split(&as_mat(w), *r);
                out.insert(format!("{}.w0", t.name), ht_mat(&w0));
                out.insert(format!("{}.w1", t.name), ht_mat(&w1));
                if t.kind == SiteKind::Fc {
                    out.insert(format!("{}.b", t.name), orig[&format!("{}.b", t.name)].clone());
                }
            }
            Scheme::Tucker { r1, r2 } => {
                let f = tucker_stack(&as_t4(w), *r1, *r2);
                out.insert(format!("{}.u", t.name), ht_mat(&f.u));
                out.insert(format!("{}.core", t.name), ht_t4(&f.core));
                out.insert(format!("{}.v", t.name), ht_mat(&f.v));
            }
            Scheme::Branched { r1, r2, groups } => {
                let f = tucker_stack(&as_t4(w), *r1, *r2);
                let b = branch_tucker(&f, *groups)?;
                out.insert(format!("{}.u", t.name), ht_mat(&b.u));
                out.insert(format!("{}.core", t.name), ht_t4(&b.core));
                out.insert(format!("{}.v", t.name), ht_mat(&b.v));
            }
            Scheme::Merged { r1, r2 } => {
                let pre = match t.name.strip_suffix(".conv2") {
                    Some(p) => p,
                    None => bail!("merged scheme on non-conv2 site {}", t.name),
                };
                let f = tucker_stack(&as_t4(w), *r1, *r2);
                let w1 = as_mat(&orig[&format!("{pre}.conv1.w")]);
                let w3 = as_mat(&orig[&format!("{pre}.conv3.w")]);
                let m = merge_bottleneck(&w1, &f, &w3)?;
                out.insert(format!("{pre}.conv1.w"), ht_mat(&m.w1m));
                out.insert(format!("{}.w", t.name), ht_t4(&m.core));
                out.insert(format!("{pre}.conv3.w"), ht_mat(&m.w3m));
                // BN affines of the rewritten 1x1s now act on r1/r2 channels
                out.insert(
                    format!("{pre}.conv1.bn.g"),
                    HostTensor::new(vec![*r1], vec![1.0; *r1]),
                );
                out.insert(format!("{pre}.conv1.bn.b"), HostTensor::zeros(vec![*r1]));
                out.insert(
                    format!("{}.bn.g", t.name),
                    HostTensor::new(vec![*r2], vec![1.0; *r2]),
                );
                out.insert(format!("{}.bn.b", t.name), HostTensor::zeros(vec![*r2]));
            }
            Scheme::MergedInto { .. } => {} // written by the peer conv2
        }
    }
    Ok(out)
}

/// Paper §2.2 freeze mask over decomposed params: the SVD/Tucker 1x1
/// factor weights are frozen (false = frozen).
pub fn freeze_mask(params: &Params) -> BTreeMap<String, bool> {
    params
        .keys()
        .map(|k| {
            let frozen = k.ends_with(".w0") || k.ends_with(".u") || k.ends_with(".v");
            (k.clone(), !frozen)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::{plan_variant, Variant};
    use crate::model::cost;

    #[test]
    fn decomposed_param_count_matches_cost_model() {
        let arch = Arch::by_name("resnet-mini").unwrap();
        let mut rng = Rng::new(1);
        let orig = init_orig_params(&arch, &mut rng);
        for v in [Variant::Lrd, Variant::Merged, Variant::Branched] {
            let plan = plan_variant(&arch, v, 2.0, 2, None).unwrap();
            let params = decompose_params(&arch, &plan, &orig).unwrap();
            let all: usize = params.values().map(|t| t.data.len()).sum();
            let (want_total, _bn) = cost::count_params_split(&arch, &plan);
            assert_eq!(all, want_total, "{v:?}");
        }
    }

    #[test]
    fn freeze_mask_targets_factors() {
        let arch = Arch::by_name("resnet-mini").unwrap();
        let mut rng = Rng::new(2);
        let orig = init_orig_params(&arch, &mut rng);
        let plan = plan_variant(&arch, Variant::Lrd, 2.0, 2, None).unwrap();
        let params = decompose_params(&arch, &plan, &orig).unwrap();
        let mask = freeze_mask(&params);
        let frozen: Vec<_> = mask.iter().filter(|(_, &t)| !t).map(|(k, _)| k).collect();
        assert!(!frozen.is_empty());
        for k in frozen {
            assert!(k.ends_with(".w0") || k.ends_with(".u") || k.ends_with(".v"));
        }
        assert!(mask["layer1.0.conv2.core"]);
    }

    #[test]
    fn orig_params_have_bn_and_bias() {
        let arch = Arch::by_name("resnet-mini").unwrap();
        let mut rng = Rng::new(3);
        let p = init_orig_params(&arch, &mut rng);
        assert!(p.contains_key("stem.conv.bn.g"));
        assert!(p.contains_key("fc.b"));
        assert_eq!(p["fc.w"].dims, vec![10, 512]);
    }
}
