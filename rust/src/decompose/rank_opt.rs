//! Algorithm 1 — hardware-aware rank optimization (§2.1).
//!
//! For each layer: start from the compression-ratio rank R (eq. 7), sweep
//! candidate ranks downward measuring real wall-clock of the decomposed
//! layer, pick the rank on the fast side of the largest throughput cliff
//! (argmax of the time step Δt), and keep the ORIGINAL layer when no
//! decomposed rank beats it.
//!
//! The timing oracle is abstracted (`LayerTimer`) so the same search runs
//! against a real execution backend (`runtime::layer_factory::
//! EngineLayerTimer` — native CPU by default, XLA:CPU under `xla-pjrt`)
//! in production and a deterministic analytic model in tests. A
//! coarse-sweep + local-refine schedule keeps the number of compiles per
//! site bounded (the paper scans every rank; DESIGN.md documents this
//! divergence).

use anyhow::Result;

use super::chain::FactorChain;
use super::{
    cp_rank_for_ratio, svd_rank_for_ratio, tucker_rank_for_ratio, Plan, Scheme, SchemeFamily,
};
use crate::linalg::{svd, Matrix, Tensor4};
use crate::model::{Arch, ConvSite, SiteKind};

/// Wall-clock oracle for one layer configuration (seconds per execution).
pub trait LayerTimer {
    fn time_layer(&mut self, site: &ConvSite, scheme: &Scheme, batch: usize, hw: usize)
        -> Result<f64>;
}

#[derive(Clone, Debug)]
pub struct RankOptConfig {
    /// target compression used for the initial rank (paper: 2x)
    pub alpha: f64,
    /// lower sweep bound as a fraction of the initial rank (paper's R_min)
    pub rmin_frac: f64,
    /// coarse sweep stride (1 = paper's exhaustive scan)
    pub stride: usize,
    /// half-width of the stride-1 refinement window around the coarse pick
    pub refine: usize,
    pub batch: usize,
    pub hw: usize,
    /// factor-chain family candidate ranks are lowered to during the
    /// sweep (Svd = the paper's two-factor convention)
    pub family: SchemeFamily,
}

impl Default for RankOptConfig {
    fn default() -> Self {
        RankOptConfig {
            alpha: 2.0,
            rmin_frac: 0.5,
            stride: 4,
            refine: 4,
            batch: 8,
            hw: 64,
            family: SchemeFamily::Svd,
        }
    }
}

/// Outcome of Algorithm 1 on one site.
#[derive(Clone, Debug)]
pub struct SiteDecision {
    pub name: String,
    /// eq. (7) / ratio-based initial rank
    pub initial_rank: usize,
    /// `None` = keep the original layer (decomposition is slower)
    pub chosen_rank: Option<usize>,
    /// measured time of the original layer
    pub t_orig: f64,
    /// measured time at the initial rank
    pub t_initial: f64,
    /// measured time at the chosen rank (== t_orig when kept original)
    pub t_chosen: f64,
    /// (rank, time) samples from the sweep, ascending rank
    pub sweep: Vec<(usize, f64)>,
    /// family the sweep's candidate schemes were drawn from
    pub family: SchemeFamily,
}

impl SiteDecision {
    pub fn scheme(&self, site: &ConvSite) -> Scheme {
        match self.chosen_rank {
            None => Scheme::Orig,
            Some(r) => scheme_at_rank(site, r, self.family),
        }
    }

    /// Throughput gain vs the original layer (>1 = faster).
    pub fn speedup(&self) -> f64 {
        self.t_orig / self.t_chosen
    }
}

/// The concrete scheme a candidate rank lowers to under a chain family.
/// The Svd family keeps the paper's convention (SVD pair for matrices,
/// Tucker stack for spatial convs); Tucker2 forces the explicit
/// three-factor chain everywhere; Cp uses the rank-`r` separable chain.
fn scheme_at_rank(site: &ConvSite, r: usize, family: SchemeFamily) -> Scheme {
    let beta = site.s as f64 / site.c as f64;
    let r2 = ((beta * r as f64) as usize).clamp(1, site.s);
    match family {
        SchemeFamily::Svd => {
            if site.k == 1 {
                Scheme::Svd { r }
            } else {
                Scheme::Tucker { r1: r, r2 }
            }
        }
        SchemeFamily::Tucker2 => {
            if site.k == 1 {
                Scheme::Tucker2 { r1: r, r2: r.min(site.s) }
            } else {
                Scheme::Tucker2 { r1: r, r2 }
            }
        }
        SchemeFamily::Cp => Scheme::Cp { r },
    }
}

/// Initial rank from the desired compression ratio.
pub fn initial_rank(site: &ConvSite, alpha: f64) -> usize {
    initial_rank_for(site, alpha, SchemeFamily::Svd)
}

/// Family-aware eq. (7): the rank achieving the target compression under
/// the chosen chain family's parameter count.
pub fn initial_rank_for(site: &ConvSite, alpha: f64, family: SchemeFamily) -> usize {
    match family {
        SchemeFamily::Cp => cp_rank_for_ratio(site.c, site.s, site.k, alpha),
        SchemeFamily::Svd | SchemeFamily::Tucker2 => {
            if site.k == 1 {
                svd_rank_for_ratio(site.c, site.s, alpha)
            } else {
                tucker_rank_for_ratio(site.c, site.s, site.k, alpha, None).0
            }
        }
    }
}

/// Run Algorithm 1 on one site.
pub fn optimize_site(
    timer: &mut dyn LayerTimer,
    site: &ConvSite,
    cfg: &RankOptConfig,
) -> Result<SiteDecision> {
    let r_init = initial_rank_for(site, cfg.alpha, cfg.family);
    let r_min = ((r_init as f64 * cfg.rmin_frac) as usize).max(1);
    let t_orig = timer.time_layer(site, &Scheme::Orig, cfg.batch, cfg.hw)?;

    // Coarse sweep r_init down to r_min.
    let mut sweep: Vec<(usize, f64)> = Vec::new();
    let mut r = r_init;
    loop {
        let t = timer.time_layer(site, &scheme_at_rank(site, r, cfg.family), cfg.batch, cfg.hw)?;
        sweep.push((r, t));
        if r <= r_min || r < cfg.stride {
            break;
        }
        r = (r - cfg.stride).max(r_min);
    }
    sweep.sort_by_key(|&(r, _)| r);

    // Largest cliff: the biggest time drop between adjacent sampled ranks
    // going downward; the chosen rank is the fast (lower) side.
    // Cliff score is the per-rank slope (t_hi - t_lo)/(r_hi - r_lo) so
    // coarse (gap > 1) and refined (gap = 1) samples compare fairly.
    let mut best_rank = r_init;
    let mut best_cliff = f64::NEG_INFINITY;
    for w in sweep.windows(2) {
        let (lo, t_lo) = w[0];
        let (hi, t_hi) = w[1];
        let cliff = (t_hi - t_lo) / (hi - lo) as f64;
        if cliff > best_cliff {
            best_cliff = cliff;
            best_rank = lo;
        }
    }

    // Stride-1 refinement around the coarse pick.
    if cfg.stride > 1 && cfg.refine > 0 {
        let lo = best_rank.saturating_sub(cfg.refine).max(r_min);
        let hi = (best_rank + cfg.refine).min(r_init);
        for r in lo..=hi {
            if sweep.iter().any(|&(rr, _)| rr == r) {
                continue;
            }
            let t =
                timer.time_layer(site, &scheme_at_rank(site, r, cfg.family), cfg.batch, cfg.hw)?;
            sweep.push((r, t));
        }
        sweep.sort_by_key(|&(r, _)| r);
        let mut cliff_best = f64::NEG_INFINITY;
        for w in sweep.windows(2) {
            let cliff = (w[1].1 - w[0].1) / (w[1].0 - w[0].0) as f64;
            if cliff > cliff_best {
                cliff_best = cliff;
                best_rank = w[0].0;
            }
        }
    }

    let t_initial = sweep
        .iter()
        .find(|&&(r, _)| r == r_init)
        .map(|&(_, t)| t)
        .unwrap_or(f64::NAN);
    let t_best = sweep
        .iter()
        .find(|&&(r, _)| r == best_rank)
        .map(|&(_, t)| t)
        .unwrap();

    // Paper: "if it could not find such a rank with lower computational
    // time, the original layer will be used instead".
    let (chosen, t_chosen) = if t_best < t_orig {
        (Some(best_rank), t_best)
    } else {
        (None, t_orig)
    };
    Ok(SiteDecision {
        name: site.name.clone(),
        initial_rank: r_init,
        chosen_rank: chosen,
        t_orig,
        t_initial,
        t_chosen,
        sweep,
        family: cfg.family,
    })
}

/// Run Algorithm 1 over every decomposable site of a model, returning the
/// per-site decisions and the resulting `Variant::Opt` plan overrides.
pub fn optimize_model(
    timer: &mut dyn LayerTimer,
    arch: &Arch,
    cfg: &RankOptConfig,
    mut progress: impl FnMut(&SiteDecision),
) -> Result<(Vec<SiteDecision>, Plan)> {
    let mut decisions = Vec::new();
    let mut plan = Plan::new();
    for site in arch.sites() {
        if site.kind == SiteKind::Stem {
            plan.insert(site.name.clone(), Scheme::Orig);
            continue;
        }
        let d = optimize_site(timer, &site, cfg)?;
        plan.insert(site.name.clone(), d.scheme(&site));
        progress(&d);
        decisions.push(d);
    }
    Ok((decisions, plan))
}

// --------------------------------------------------------------------------
// Analytic timer for tests & dry-runs: MAC count modulated by the Fig. 2
// tile-efficiency model, plus a fixed per-layer dispatch overhead.
// --------------------------------------------------------------------------

/// Deterministic cost-model timer. `lane` sets the tile width of the
/// simulated device (128 = MXU-like, 8 = AVX-like); `overhead` is the fixed
/// per-layer dispatch cost in seconds that makes depth expensive (the
/// paper's core observation).
pub struct AnalyticTimer {
    pub lane: usize,
    pub overhead: f64,
    pub flops_per_sec: f64,
}

impl Default for AnalyticTimer {
    fn default() -> Self {
        AnalyticTimer { lane: 8, overhead: 20e-6, flops_per_sec: 50e9 }
    }
}

impl AnalyticTimer {
    fn dims_of(&self, site: &ConvSite, scheme: &Scheme) -> Vec<(usize, usize)> {
        // (macs-weight, gating dim) per sub-layer
        let k2 = site.k * site.k;
        match scheme {
            Scheme::Orig => vec![(site.c * site.s * k2, site.s)],
            Scheme::Svd { r } => vec![(site.c * r, *r), (r * site.s, site.s)],
            Scheme::Tucker { r1, r2 } => vec![
                (site.c * r1, *r1),
                (r1 * r2 * k2, *r2),
                (r2 * site.s, site.s),
            ],
            Scheme::Branched { r1, r2, groups } => vec![
                (site.c * r1, *r1),
                ((r1 / groups) * (r2 / groups) * k2 * groups, r2 / groups),
                (r2 * site.s, site.s),
            ],
            Scheme::Merged { r1, r2 } => vec![(r1 * r2 * k2, *r2)],
            Scheme::MergedInto { .. } => vec![(site.c * site.s, site.s)],
            s @ (Scheme::Tucker2 { .. } | Scheme::Cp { .. }) => FactorChain::of(site, s)
                .expect("chain scheme")
                .factors
                .iter()
                .map(|f| (f.macs_per_px, f.gate_dim))
                .collect(),
            Scheme::Sparse { base, ppm } => {
                // chain sub-layers plus the residual arm: nnz MACs/px at
                // scalar rate (gate dim 1 -> tile efficiency 1/lane)
                let mut v = self.dims_of(site, base);
                v.push((Scheme::sparse_nnz(site.c, site.s, site.k, *ppm), 1));
                v
            }
        }
    }
}

impl LayerTimer for AnalyticTimer {
    fn time_layer(
        &mut self,
        site: &ConvSite,
        scheme: &Scheme,
        batch: usize,
        hw: usize,
    ) -> Result<f64> {
        let area = (hw / site.stride).max(1).pow(2);
        let mut t = 0.0;
        for (macs_w, gate) in self.dims_of(site, scheme) {
            let eff = crate::model::cost::tile_efficiency(gate, self.lane).max(1e-3);
            let flops = 2.0 * (batch * area * macs_w) as f64;
            t += flops / (self.flops_per_sec * eff) + self.overhead;
        }
        Ok(t)
    }
}

// --------------------------------------------------------------------------
// EVBMF — automatic rank selection from the weight spectrum (no timing)
// --------------------------------------------------------------------------

fn evb_tau(x: f64, alpha: f64) -> f64 {
    let d = x - (1.0 + alpha);
    0.5 * (d + (d * d - 4.0 * alpha).max(0.0).sqrt())
}

/// VB free energy of an `l x m` matrix at noise variance `sigma2`, up to
/// sigma2-independent terms (Nakajima et al. 2013, eq. 27 as implemented
/// by the musco/VBMF line of work).
fn evb_free_energy(sigma2: f64, l: usize, m: usize, s: &[f64], xubar: f64) -> f64 {
    let alpha = l as f64 / m as f64;
    let mut obj = 0.0;
    for &sv in s {
        let x = (sv * sv / (m as f64 * sigma2)).max(1e-300);
        if x > xubar {
            let t = evb_tau(x, alpha);
            obj += x - t + ((t + 1.0) / x).ln() + alpha * (t / alpha + 1.0).ln();
        } else {
            obj += x - x.ln();
        }
    }
    obj
}

/// Empirical Variational Bayes MF rank of an `l x m` (`l <= m`) matrix
/// from its descending singular values: the unknown noise variance is
/// found by golden-section search on the VB free energy, then the rank
/// is the number of singular values above the analytic EVB threshold.
/// This is the musco-style automatic selector — no timed sweeps, one
/// SVD per site.
pub fn evbmf_rank(s: &[f64], l: usize, m: usize) -> usize {
    assert!(l <= m, "evbmf_rank wants l <= m, got {l} x {m}");
    assert!(!s.is_empty());
    let (lf, mf) = (l as f64, m as f64);
    let alpha = lf / mf;
    let tauubar = 2.5129 * alpha.sqrt();
    let xubar = (1.0 + tauubar) * (1.0 + alpha / tauubar);
    // sigma2 bracket: everything-is-noise above, the spectrum tail below
    let sum_sq: f64 = s.iter().map(|&x| x * x).sum();
    let upper = (sum_sq / (lf * mf)).max(1e-30);
    let idx = (((lf / (1.0 + alpha)).ceil() - 1.0).max(0.0) as usize).min(s.len() - 1);
    let tail_mean =
        s[idx..].iter().map(|&x| x * x).sum::<f64>() / (s.len() - idx) as f64;
    let lower = (s[idx] * s[idx] / (mf * xubar)).max(tail_mean / mf).max(1e-30);
    let (mut a, mut b) = (lower.ln(), upper.max(lower * (1.0 + 1e-9)).ln());
    let phi = (5f64.sqrt() - 1.0) / 2.0;
    let f = |ls: f64| evb_free_energy(ls.exp(), l, m, s, xubar);
    let (mut c, mut d) = (b - phi * (b - a), a + phi * (b - a));
    let (mut fc, mut fd) = (f(c), f(d));
    for _ in 0..100 {
        if fc < fd {
            b = d;
            d = c;
            fd = fc;
            c = b - phi * (b - a);
            fc = f(c);
        } else {
            a = c;
            c = d;
            fc = fd;
            d = a + phi * (b - a);
            fd = f(d);
        }
    }
    let sigma2 = ((a + b) / 2.0).exp();
    let threshold = (mf * sigma2 * (1.0 + tauubar) * (1.0 + alpha / tauubar)).sqrt();
    s.iter().filter(|&&sv| sv > threshold).count()
}

/// EVBMF rank of a weight matrix (orientation-free: the spectrum of the
/// transpose is identical, so `l`/`m` are just sorted dims).
pub fn vbmf_matrix_rank(w: &Matrix) -> usize {
    let (l, m) = (w.rows.min(w.cols), w.rows.max(w.cols));
    let sv: Vec<f64> = svd(w).s.iter().map(|&x| x as f64).collect();
    let n = sv.len().min(l);
    evbmf_rank(&sv[..n], l, m)
}

/// EVBMF ranks of a conv weight's two channel-mode unfoldings — the
/// Tucker-2 `(r1, r2)` pair.
pub fn vbmf_ranks(w: &Tensor4) -> (usize, usize) {
    let r1 = vbmf_matrix_rank(&w.unfold_i()).max(1);
    let r2 = vbmf_matrix_rank(&w.unfold_o()).max(1);
    (r1, r2)
}

/// Map a site's VBMF ranks onto the paper's scheme convention (SVD pair
/// for 1x1/fc, Tucker stack for spatial convs) — the drop-in automatic
/// alternative to `optimize_site`'s timed sweep: one SVD per site, no
/// layer timing at all.
pub fn vbmf_scheme(site: &ConvSite, w: &Tensor4) -> Scheme {
    if site.k == 1 {
        let r = vbmf_matrix_rank(&w.unfold_o()).clamp(1, site.c.min(site.s));
        Scheme::Svd { r }
    } else {
        let (r1, r2) = vbmf_ranks(w);
        Scheme::Tucker { r1: r1.clamp(1, site.c), r2: r2.clamp(1, site.s) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Arch;

    fn site(c: usize, s: usize, k: usize) -> ConvSite {
        ConvSite {
            name: format!("t.{c}x{s}x{k}"),
            c,
            s,
            k,
            stride: 1,
            padding: if k > 1 { 1 } else { 0 },
            kind: SiteKind::Conv,
        }
    }

    fn cfg() -> RankOptConfig {
        RankOptConfig { stride: 1, refine: 0, batch: 2, hw: 16, ..Default::default() }
    }

    #[test]
    fn initial_ranks_match_table2() {
        assert_eq!(initial_rank(&site(64, 64, 1), 2.0), 16);
        assert_eq!(initial_rank(&site(64, 64, 3), 2.0), 38);
        assert_eq!(initial_rank(&site(512, 512, 3), 2.0), 309);
    }

    #[test]
    fn picks_tile_aligned_rank() {
        // lane=8 device: the optimizer should land on a multiple of 8 at or
        // below the eq.-7 rank 38 (the paper's Table 2 lands on 32).
        let mut timer = AnalyticTimer { lane: 8, ..Default::default() };
        let d = optimize_site(&mut timer, &site(64, 64, 3), &cfg()).unwrap();
        let r = d.chosen_rank.expect("should decompose");
        assert_eq!(r % 8, 0, "rank {r} not tile aligned");
        assert!(r <= d.initial_rank);
    }

    #[test]
    fn keeps_original_when_decomposition_slower() {
        // huge dispatch overhead: 3 layers can never beat 1
        let mut timer =
            AnalyticTimer { lane: 8, overhead: 10.0, flops_per_sec: 50e9 };
        let d = optimize_site(&mut timer, &site(64, 64, 3), &cfg()).unwrap();
        assert_eq!(d.chosen_rank, None);
        assert_eq!(d.t_chosen, d.t_orig);
        assert_eq!(d.speedup(), 1.0);
    }

    #[test]
    fn coarse_plus_refine_finds_cliff() {
        let mut timer = AnalyticTimer { lane: 16, ..Default::default() };
        let c = RankOptConfig { stride: 8, refine: 8, batch: 2, hw: 16, ..Default::default() };
        let d = optimize_site(&mut timer, &site(256, 256, 3), &c).unwrap();
        let r = d.chosen_rank.expect("should decompose");
        assert_eq!(r % 16, 0, "refined rank {r} should hit the lane-16 cliff");
    }

    #[test]
    fn optimize_model_covers_all_non_stem_sites() {
        let arch = Arch::by_name("resnet-mini").unwrap();
        let mut timer = AnalyticTimer::default();
        let (decisions, plan) =
            optimize_model(&mut timer, &arch, &cfg(), |_| {}).unwrap();
        assert_eq!(decisions.len(), arch.sites().len() - 1); // minus stem
        assert_eq!(plan["stem.conv"], Scheme::Orig);
    }

    #[test]
    fn sweep_is_recorded_and_sorted() {
        let mut timer = AnalyticTimer::default();
        let d = optimize_site(&mut timer, &site(64, 128, 1), &cfg()).unwrap();
        assert!(!d.sweep.is_empty());
        for w in d.sweep.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn family_sweeps_lower_to_their_own_schemes() {
        for (family, k) in [
            (SchemeFamily::Tucker2, 1),
            (SchemeFamily::Tucker2, 3),
            (SchemeFamily::Cp, 1),
            (SchemeFamily::Cp, 3),
        ] {
            let mut timer = AnalyticTimer { lane: 8, ..Default::default() };
            let c = RankOptConfig { family, ..cfg() };
            let t = site(64, 64, k);
            let d = optimize_site(&mut timer, &t, &c).unwrap();
            assert_eq!(d.family, family);
            match (family, d.scheme(&t)) {
                (_, Scheme::Orig) => {}
                (SchemeFamily::Tucker2, Scheme::Tucker2 { r1, r2 }) => {
                    assert!(r1 >= 1 && r2 >= 1 && r1 <= 64 && r2 <= 64);
                }
                (SchemeFamily::Cp, Scheme::Cp { r }) => assert!(r >= 1),
                (f, s) => panic!("family {f:?} produced scheme {s:?}"),
            }
        }
    }

    #[test]
    fn evbmf_recovers_planted_rank() {
        let mut rng = crate::util::rng::Rng::new(21);
        let a = Matrix::random(64, 12, &mut rng);
        let b = Matrix::random(12, 64, &mut rng);
        let mut w = a.matmul(&b);
        for x in w.data.iter_mut() {
            *x += 1e-3 * rng.normal_f32();
        }
        assert_eq!(vbmf_matrix_rank(&w), 12);
    }

    #[test]
    fn evbmf_full_noise_finds_no_rank() {
        // pure iid noise: every singular value is explained by sigma2,
        // nothing survives the threshold
        let mut rng = crate::util::rng::Rng::new(22);
        let w = Matrix::random(48, 64, &mut rng);
        assert_eq!(vbmf_matrix_rank(&w), 0);
    }

    #[test]
    fn vbmf_scheme_maps_both_kernel_shapes() {
        let mut rng = crate::util::rng::Rng::new(23);
        // k=1: planted rank-8 channel mixing
        let a = Matrix::random(32, 8, &mut rng);
        let b = Matrix::random(8, 32, &mut rng);
        let mut m = a.matmul(&b);
        for x in m.data.iter_mut() {
            *x += 1e-3 * rng.normal_f32();
        }
        let w1 = Tensor4::from_vec(32, 32, 1, 1, m.data.clone());
        match vbmf_scheme(&site(32, 32, 1), &w1) {
            Scheme::Svd { r } => assert_eq!(r, 8),
            s => panic!("k=1 must map to Svd, got {s:?}"),
        }
        // k=3: a random conv has full-ish mode ranks; just check mapping
        let w3 = Tensor4::random(16, 16, 3, 3, &mut rng);
        match vbmf_scheme(&site(16, 16, 3), &w3) {
            Scheme::Tucker { r1, r2 } => {
                assert!((1..=16).contains(&r1) && (1..=16).contains(&r2));
            }
            s => panic!("k=3 must map to Tucker, got {s:?}"),
        }
    }
}
