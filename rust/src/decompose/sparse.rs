//! Sparse residual fitting: W ~= chain + S (`Scheme::Sparse`).
//!
//! S holds the `nnz` largest-magnitude entries of the residual
//! `W - reconstruct(chain)`, refit alternately (re-decompose the dense
//! part after subtracting S, then re-threshold). Storage is two tensors
//! per site — `{site}.s` values `[nnz]` (a real, mask-frozen graph
//! parameter) and `{site}.s_idx` flat OIHW indices `[nnz]` f32-encoded
//! (pattern metadata, baked into the graph as CSR weights at compile
//! time, never a graph parameter). Indices are sorted tap-major
//! `(h, w, o, i)` so each kernel tap's values form one contiguous
//! `Slice` range and each tap is a ready-made CSR slab over `[s, c]`.
//!
//! f32 index encoding is exact up to 2^24; the largest paper-scale site
//! (512x512x3x3 = 2.36M entries) is well inside that.

use anyhow::{bail, Result};

use super::weights::{cp_stack, svd_split, tucker_stack, CpStack};
use super::Scheme;
use crate::linalg::{Matrix, Tensor4, Tucker2};
use crate::model::ConvSite;
use crate::runtime::HostTensor;

/// A fitted (or synthesized) sparse residual over a `[s, c]` or
/// `[s, c, k, k]` weight. `idx` is tap-major sorted and duplicate-free.
#[derive(Clone, Debug)]
pub struct SparseResidual {
    pub dims: Vec<usize>,
    /// flat OIHW indices, sorted by `(h, w, o, i)`
    pub idx: Vec<u32>,
    pub vals: Vec<f32>,
}

/// One kernel tap's slice of the residual: a CSR pattern over `[s, c]`
/// plus the contiguous `[lo, hi)` range of `vals` holding its entries.
#[derive(Clone, Debug)]
pub struct TapCsr {
    pub h: usize,
    pub w: usize,
    pub lo: usize,
    pub hi: usize,
    /// `[n_rows + 1]` over output channels
    pub row_ptr: Vec<u32>,
    /// column (input-channel) of each entry, ascending within a row
    pub col_idx: Vec<u32>,
}

/// `(o, i, kh, kw)` extents; 2-d weights are `kh = kw = 1`.
fn unpack(dims: &[usize]) -> Result<(usize, usize, usize, usize)> {
    match dims {
        [o, i] => Ok((*o, *i, 1, 1)),
        [o, i, h, w] => Ok((*o, *i, *h, *w)),
        _ => bail!("sparse residual needs a 2-d or 4-d weight, got {dims:?}"),
    }
}

/// Tap-major sort key of a flat OIHW index: `(h, w, o, i)`.
fn tap_key(geom: (usize, usize, usize, usize), f: u32) -> u64 {
    let (o_n, i_n, kh, kw) = geom;
    let f = f as usize;
    let w = f % kw;
    let h = (f / kw) % kh;
    let i = (f / (kw * kh)) % i_n;
    let o = f / (kw * kh * i_n);
    (((h * kw + w) * o_n + o) * i_n + i) as u64
}

impl SparseResidual {
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    pub fn density(&self) -> f64 {
        let n: usize = self.dims.iter().product();
        self.idx.len() as f64 / n as f64
    }

    /// The `nnz` largest-magnitude entries of `resid`. Ties on |value|
    /// break on the lower flat index (stable across runs and platforms —
    /// `total_cmp`, no hash iteration anywhere).
    pub fn top_k(dims: &[usize], resid: &[f32], nnz: usize) -> Result<SparseResidual> {
        let geom = unpack(dims)?;
        let n: usize = dims.iter().product();
        if resid.len() != n {
            bail!("residual has {} entries, dims {dims:?} want {n}", resid.len());
        }
        if nnz == 0 || nnz > n {
            bail!("nnz {nnz} out of range for {n} entries");
        }
        if n > (1 << 24) {
            bail!("{n} entries exceed the exact-f32 index range (2^24)");
        }
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_by(|&a, &b| {
            resid[b as usize]
                .abs()
                .total_cmp(&resid[a as usize].abs())
                .then(a.cmp(&b))
        });
        let mut keep = order[..nnz].to_vec();
        keep.sort_by_key(|&f| tap_key(geom, f));
        let vals = keep.iter().map(|&f| resid[f as usize]).collect();
        Ok(SparseResidual { dims: dims.to_vec(), idx: keep, vals })
    }

    /// Deterministic evenly-spaced pattern with zero values — the graph
    /// shape surrogate when compiling from a seed without fitted weights.
    pub fn synthetic(dims: &[usize], nnz: usize) -> Result<SparseResidual> {
        let geom = unpack(dims)?;
        let n: usize = dims.iter().product();
        if nnz == 0 || nnz > n {
            bail!("nnz {nnz} out of range for {n} entries");
        }
        let mut idx: Vec<u32> = (0..nnz).map(|j| (j * n / nnz) as u32).collect();
        idx.sort_by_key(|&f| tap_key(geom, f));
        Ok(SparseResidual { dims: dims.to_vec(), vals: vec![0.0; nnz], idx })
    }

    /// Scatter back to a dense weight-shaped buffer.
    pub fn to_dense(&self) -> Vec<f32> {
        let n: usize = self.dims.iter().product();
        let mut out = vec![0f32; n];
        for (j, &f) in self.idx.iter().enumerate() {
            out[f as usize] = self.vals[j];
        }
        out
    }

    /// `({site}.s values, {site}.s_idx f32-encoded indices)`.
    pub fn to_tensors(&self) -> (HostTensor, HostTensor) {
        let nnz = self.idx.len();
        let vals = HostTensor::new(vec![nnz], self.vals.clone());
        let idx = HostTensor::new(vec![nnz], self.idx.iter().map(|&x| x as f32).collect());
        (vals, idx)
    }

    /// Rebuild from the stored tensor pair, re-validating the invariants
    /// (integral in-range indices, tap-major strictly ascending).
    pub fn from_tensors(
        dims: &[usize],
        vals: &HostTensor,
        idx: &HostTensor,
    ) -> Result<SparseResidual> {
        let geom = unpack(dims)?;
        let n: usize = dims.iter().product();
        if vals.dims != idx.dims || vals.dims.len() != 1 {
            bail!("sparse tensors want matching [nnz] dims, got {:?}/{:?}", vals.dims, idx.dims);
        }
        let mut out_idx = Vec::with_capacity(idx.data.len());
        let mut prev: Option<u64> = None;
        for &x in &idx.data {
            if x < 0.0 || x.fract() != 0.0 || (x as usize) >= n {
                bail!("sparse index {x} invalid for {n} entries");
            }
            let f = x as u32;
            let key = tap_key(geom, f);
            if let Some(p) = prev {
                if key <= p {
                    bail!("sparse indices not strictly tap-major sorted");
                }
            }
            prev = Some(key);
            out_idx.push(f);
        }
        Ok(SparseResidual { dims: dims.to_vec(), idx: out_idx, vals: vals.data.clone() })
    }

    /// Split into per-tap CSR slabs (taps with no entries are omitted;
    /// their contribution is identically zero).
    pub fn taps(&self) -> Result<Vec<TapCsr>> {
        let (o_n, i_n, kh, kw) = unpack(&self.dims)?;
        let decode = |f: u32| {
            let f = f as usize;
            (f / (kw * kh * i_n), (f / (kw * kh)) % i_n, (f / kw) % kh, f % kw)
        };
        let mut out = Vec::new();
        let mut j = 0usize;
        while j < self.idx.len() {
            let (_, _, h, w) = decode(self.idx[j]);
            let lo = j;
            let mut row_ptr = vec![0u32; o_n + 1];
            let mut col_idx = Vec::new();
            while j < self.idx.len() {
                let (o, i, jh, jw) = decode(self.idx[j]);
                if (jh, jw) != (h, w) {
                    break;
                }
                row_ptr[o + 1] += 1;
                col_idx.push(i as u32);
                j += 1;
            }
            for r in 0..o_n {
                row_ptr[r + 1] += row_ptr[r];
            }
            out.push(TapCsr { h, w, lo, hi: j, row_ptr, col_idx });
        }
        Ok(out)
    }
}

// --------------------------------------------------------------------------
// Alternating refit
// --------------------------------------------------------------------------

/// A fitted `W ~= chain + S` site: the base chain's factor tensors under
/// their usual suffixes plus the residual, with the achieved error.
pub struct FitResult {
    /// `(suffix, tensor)` pairs matching `decompose_params` naming
    pub factors: Vec<(String, HostTensor)>,
    pub sparse: SparseResidual,
    /// relative Frobenius error of `chain + S` against `W`
    pub rel_err: f64,
    /// nonzero fraction of the scattered residual, measured on the dense
    /// tensor (`HostTensor::density`) — below the requested density when
    /// top-k lands on exactly-zero residual entries
    pub achieved_density: f64,
}

fn as_mat(t: &HostTensor) -> Result<Matrix> {
    if t.dims.len() != 2 {
        bail!("expected matrix, got {:?}", t.dims);
    }
    Ok(Matrix::from_vec(t.dims[0], t.dims[1], t.data.clone()))
}

fn as_t4(t: &HostTensor) -> Result<Tensor4> {
    if t.dims.len() != 4 {
        bail!("expected 4-d tensor, got {:?}", t.dims);
    }
    Ok(Tensor4::from_vec(t.dims[0], t.dims[1], t.dims[2], t.dims[3], t.data.clone()))
}

fn ht_mat(m: &Matrix) -> HostTensor {
    HostTensor::new(vec![m.rows, m.cols], m.data.clone())
}

fn ht_t4(t: &Tensor4) -> HostTensor {
    HostTensor::new(vec![t.o, t.i, t.h, t.w], t.data.clone())
}

/// Decompose `w` under the base chain scheme and return `(factors,
/// dense reconstruction)`. Mirrors `params::decompose_params` for the
/// chain families (the only bases `Scheme::Sparse` composes with).
fn split_and_recon(base: &Scheme, w: &HostTensor) -> Result<(Vec<(String, HostTensor)>, Vec<f32>)> {
    match base {
        Scheme::Svd { r } => {
            let (w0, w1) = svd_split(&as_mat(w)?, *r);
            let recon = w1.matmul(&w0).data;
            Ok((vec![("w0".into(), ht_mat(&w0)), ("w1".into(), ht_mat(&w1))], recon))
        }
        Scheme::Tucker { r1, r2 } => {
            let f = tucker_stack(&as_t4(w)?, *r1, *r2);
            let recon = f.reconstruct().data;
            Ok((
                vec![
                    ("u".into(), ht_mat(&f.u)),
                    ("core".into(), ht_t4(&f.core)),
                    ("v".into(), ht_mat(&f.v)),
                ],
                recon,
            ))
        }
        Scheme::Tucker2 { r1, r2 } => {
            if w.dims.len() == 4 {
                let f = tucker_stack(&as_t4(w)?, *r1, *r2);
                let recon = f.reconstruct().data;
                Ok((
                    vec![
                        ("u".into(), ht_mat(&f.u)),
                        ("core".into(), ht_t4(&f.core)),
                        ("v".into(), ht_mat(&f.v)),
                    ],
                    recon,
                ))
            } else {
                let w4 = Tensor4::from_vec(w.dims[0], w.dims[1], 1, 1, w.data.clone());
                let f = tucker_stack(&w4, *r1, *r2);
                let recon = f.reconstruct().data;
                Ok((
                    vec![
                        ("u".into(), ht_mat(&f.u)),
                        (
                            "core".into(),
                            HostTensor::new(vec![*r2, *r1], f.core.data.clone()),
                        ),
                        ("v".into(), ht_mat(&f.v)),
                    ],
                    recon,
                ))
            }
        }
        Scheme::Cp { r } => {
            if w.dims.len() == 2 {
                let (w0, w1) = svd_split(&as_mat(w)?, *r);
                let recon = w1.matmul(&w0).data;
                Ok((vec![("w0".into(), ht_mat(&w0)), ("w1".into(), ht_mat(&w1))], recon))
            } else {
                let f = cp_stack(&as_t4(w)?, *r);
                let recon = f.reconstruct().data;
                Ok((
                    vec![
                        ("u".into(), ht_mat(&f.u)),
                        ("kh".into(), ht_mat(&f.kh)),
                        ("kw".into(), ht_mat(&f.kw)),
                        ("w1".into(), ht_mat(&f.w1)),
                    ],
                    recon,
                ))
            }
        }
        other => bail!("sparse residual composes with chain schemes, not {other:?}"),
    }
}

fn frob(v: &[f32]) -> f64 {
    v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Alternating refit of `W ~= chain(base) + S` at exactly
/// `Scheme::sparse_nnz(..)` entries. Each iteration re-decomposes the
/// S-subtracted dense part, then re-thresholds the new residual — the
/// chain stops spending rank on the spikes S absorbs.
pub fn fit_site(
    t: &ConvSite,
    base: &Scheme,
    w: &HostTensor,
    ppm: u32,
    iters: usize,
) -> Result<FitResult> {
    let nnz = Scheme::sparse_nnz(t.c, t.s, t.k, ppm);
    let n: usize = w.dims.iter().product();
    if nnz > n {
        bail!("{}: nnz {nnz} exceeds weight size {n}", t.name);
    }
    let mut s_dense = vec![0f32; n];
    let mut best: Option<(Vec<(String, HostTensor)>, SparseResidual, f64)> = None;
    let mut best_err = f64::INFINITY;
    for _ in 0..iters.max(1) {
        let w_eff: Vec<f32> = w.data.iter().zip(&s_dense).map(|(&a, &b)| a - b).collect();
        let (factors, recon) =
            split_and_recon(base, &HostTensor::new(w.dims.clone(), w_eff))?;
        let resid: Vec<f32> = w.data.iter().zip(&recon).map(|(&a, &b)| a - b).collect();
        let sparse = SparseResidual::top_k(&w.dims, &resid, nnz)?;
        s_dense = sparse.to_dense();
        let err: Vec<f32> = resid.iter().zip(&s_dense).map(|(&a, &b)| a - b).collect();
        let rel = frob(&err) / frob(&w.data).max(1e-30);
        if rel < best_err {
            best_err = rel;
            best = Some((factors, sparse, rel));
        }
    }
    let (factors, sparse, rel_err) = best.expect("at least one refit iteration");
    let achieved_density = HostTensor::new(w.dims.clone(), sparse.to_dense()).density();
    Ok(FitResult { factors, sparse, rel_err, achieved_density })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SiteKind;
    use crate::runtime::graph::validate_csr;
    use crate::util::rng::Rng;

    fn site_1x1(c: usize, s: usize) -> ConvSite {
        ConvSite {
            name: "t".into(),
            c,
            s,
            k: 1,
            stride: 1,
            padding: 0,
            kind: SiteKind::Conv,
        }
    }

    #[test]
    fn top_k_picks_largest_with_stable_ties() {
        let dims = [2usize, 3];
        let resid = [0.5f32, -2.0, 0.5, 0.1, 2.0, -0.5];
        let s = SparseResidual::top_k(&dims, &resid, 4).unwrap();
        // |2.0| twice (idx 1 then 4), then the |0.5| tie broken low-index
        // first (idx 0), tap-major order == flat order for 2-d weights
        assert_eq!(s.idx, vec![0, 1, 4, 5]);
        assert_eq!(s.vals, vec![0.5, -2.0, 2.0, -0.5]);
        // rerun is bitwise identical
        let s2 = SparseResidual::top_k(&dims, &resid, 4).unwrap();
        assert_eq!(s2.idx, s.idx);
        assert_eq!(s2.vals, s.vals);
    }

    #[test]
    fn taps_are_contiguous_valid_csr_slabs() {
        let (o_n, i_n, k) = (5usize, 4usize, 3usize);
        let dims = [o_n, i_n, k, k];
        let n = o_n * i_n * k * k;
        let mut rng = Rng::new(11);
        let resid: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let s = SparseResidual::top_k(&dims, &resid, 37).unwrap();
        let taps = s.taps().unwrap();
        let mut covered = 0usize;
        let mut last_tap = None;
        for t in &taps {
            assert_eq!(t.lo, covered, "contiguous ranges");
            covered = t.hi;
            assert!(last_tap < Some((t.h, t.w)), "taps ascend");
            last_tap = Some((t.h, t.w));
            validate_csr(o_n, i_n, &t.row_ptr, &t.col_idx).unwrap();
            assert_eq!(t.col_idx.len(), t.hi - t.lo);
            // every entry maps back to the flat index it came from
            for r in 0..o_n {
                for e in t.row_ptr[r] as usize..t.row_ptr[r + 1] as usize {
                    let i = t.col_idx[e] as usize;
                    let flat = ((r * i_n + i) * k + t.h) * k + t.w;
                    assert_eq!(s.idx[t.lo + e] as usize, flat);
                }
            }
        }
        assert_eq!(covered, s.nnz());
    }

    #[test]
    fn tensor_roundtrip_and_validation() {
        let dims = [4usize, 4, 3, 3];
        let mut rng = Rng::new(3);
        let resid: Vec<f32> = (0..144).map(|_| rng.normal_f32()).collect();
        let s = SparseResidual::top_k(&dims, &resid, 12).unwrap();
        let (vals, idx) = s.to_tensors();
        assert_eq!(vals.dims, vec![12]);
        assert_eq!(idx.dims, vec![12]);
        let back = SparseResidual::from_tensors(&dims, &vals, &idx).unwrap();
        assert_eq!(back.idx, s.idx);
        assert_eq!(back.vals, s.vals);
        // out-of-range / unsorted inputs are rejected
        let bad = HostTensor::new(vec![12], vec![1e9; 12]);
        assert!(SparseResidual::from_tensors(&dims, &vals, &bad).is_err());
    }

    #[test]
    fn synthetic_pattern_is_exact_and_valid() {
        for (dims, nnz) in [(vec![8usize, 8], 5usize), (vec![4, 4, 3, 3], 17)] {
            let s = SparseResidual::synthetic(&dims, nnz).unwrap();
            assert_eq!(s.nnz(), nnz);
            for t in s.taps().unwrap() {
                validate_csr(dims[0], dims[1], &t.row_ptr, &t.col_idx).unwrap();
            }
            // deterministic: rebuild matches
            assert_eq!(SparseResidual::synthetic(&dims, nnz).unwrap().idx, s.idx);
        }
    }

    #[test]
    fn fit_absorbs_planted_spikes() {
        // W = low-rank + sparse spikes: the rank-r chain alone misses the
        // spikes; chain+S at the planted density recovers them
        let (c, s_ch, r) = (24usize, 24usize, 4usize);
        let mut rng = Rng::new(42);
        let a: Vec<f32> = (0..s_ch * r).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..r * c).map(|_| rng.normal_f32()).collect();
        let mut w = vec![0f32; s_ch * c];
        for o in 0..s_ch {
            for i in 0..c {
                let mut acc = 0f32;
                for j in 0..r {
                    acc += a[o * r + j] * b[j * c + i];
                }
                w[o * c + i] = acc;
            }
        }
        let nnz = Scheme::sparse_nnz(c, s_ch, 1, 50_000);
        for j in 0..nnz {
            w[(j * 37) % (s_ch * c)] += 25.0;
        }
        let wt = HostTensor::new(vec![s_ch, c], w);
        let site = site_1x1(c, s_ch);
        let base = Scheme::Svd { r };
        let with_s = fit_site(&site, &base, &wt, 50_000, 3).unwrap();
        assert_eq!(with_s.sparse.nnz(), nnz);
        // spikes of +25 guarantee every kept entry is a real nonzero
        let want_density = nnz as f64 / (s_ch * c) as f64;
        assert!((with_s.achieved_density - want_density).abs() < 1e-12);
        // pure chain at the same rank: error from the unabsorbed spikes
        let (_, recon) = split_and_recon(&base, &wt).unwrap();
        let resid: Vec<f32> =
            wt.data.iter().zip(&recon).map(|(&x, &y)| x - y).collect();
        let pure = frob(&resid) / frob(&wt.data);
        assert!(
            with_s.rel_err < pure * 0.5,
            "chain+S {} vs pure chain {pure}",
            with_s.rel_err
        );
    }
}
