//! Weight-level decomposition transforms (the rust mirror of
//! `python/compile/decompose.py`): SVD split, Tucker-2 stack, Fig. 3
//! merging and Fig. 4 branch splitting, over `linalg` types.

use anyhow::{bail, Result};

use crate::linalg::{svd, tucker2, Matrix, Tensor4, Tucker2};

/// Eq. (3): split an [S, C] weight into (w0: [R, C], w1: [S, R]) with each
/// factor absorbing sqrt(sigma).
pub fn svd_split(w: &Matrix, r: usize) -> (Matrix, Matrix) {
    svd(w).split(r)
}

/// Eq. (4)-(6): Tucker-2 stack of an OIHW conv weight.
pub fn tucker_stack(w: &Tensor4, r1: usize, r2: usize) -> Tucker2 {
    tucker2(w, r1, r2)
}

/// Fig. 3 merged bottleneck weights.
#[derive(Clone, Debug)]
pub struct MergedBottleneck {
    /// [r1, C] — conv1 folded with conv2's Tucker U
    pub w1m: Matrix,
    /// [r2, r1, k, k]
    pub core: Tensor4,
    /// [S, r2] — conv3 folded with conv2's Tucker V
    pub w3m: Matrix,
}

/// Fold the Tucker 1x1 factors into the adjacent bottleneck 1x1 convs:
/// conv1' = U2 @ W1 ([r1,M]@[M,C]), conv3' = W3 @ V2 ([S,M]@[M,r2]).
pub fn merge_bottleneck(w1: &Matrix, t2: &Tucker2, w3: &Matrix) -> Result<MergedBottleneck> {
    if t2.u.cols != w1.rows {
        bail!("U2 [.,{}] does not compose with conv1 [{},.]", t2.u.cols, w1.rows);
    }
    if w3.cols != t2.v.rows {
        bail!("conv3 [.,{}] does not compose with V2 [{},.]", w3.cols, t2.v.rows);
    }
    Ok(MergedBottleneck {
        w1m: t2.u.matmul(w1),
        core: t2.core.clone(),
        w3m: w3.matmul(&t2.v),
    })
}

/// Fig. 4 grouped-conv weights for N Tucker branches.
#[derive(Clone, Debug)]
pub struct Branched {
    /// [r1, C]
    pub u: Matrix,
    /// grouped OIHW: [r2, r1/N, k, k]
    pub core: Tensor4,
    /// [S, r2]
    pub v: Matrix,
    pub groups: usize,
}

/// Eq. (12)-(17): keep the diagonal core blocks (the off-diagonal blocks
/// are dropped — that is the N-fold parameter saving of eq. 18-20 and why
/// branching needs fine-tuning).
pub fn branch_tucker(t: &Tucker2, groups: usize) -> Result<Branched> {
    let (r2, r1) = (t.core.o, t.core.i);
    if r1 % groups != 0 || r2 % groups != 0 {
        bail!("ranks ({r1},{r2}) not divisible by N={groups}");
    }
    let (b1, b2) = (r1 / groups, r2 / groups);
    let mut core = Tensor4::zeros(r2, b1, t.core.h, t.core.w);
    for g in 0..groups {
        for j in 0..b2 {
            for i in 0..b1 {
                for h in 0..t.core.h {
                    for w in 0..t.core.w {
                        *core.at_mut(g * b2 + j, i, h, w) =
                            t.core.at(g * b2 + j, g * b1 + i, h, w);
                    }
                }
            }
        }
    }
    Ok(Branched { u: t.u.clone(), core, v: t.v.clone(), groups })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::assert_allclose;
    use crate::util::rng::Rng;

    #[test]
    fn svd_split_reconstructs_at_full_rank() {
        let mut rng = Rng::new(0);
        let w = Matrix::random(12, 8, &mut rng);
        let (w0, w1) = svd_split(&w, 8);
        assert_allclose(&w1.matmul(&w0).data, &w.data, 1e-3, 1e-3);
    }

    #[test]
    fn merge_shapes() {
        let mut rng = Rng::new(1);
        let (c, m, s) = (8, 16, 32);
        let w1 = Matrix::random(m, c, &mut rng);
        let w3 = Matrix::random(s, m, &mut rng);
        let t2 = tucker_stack(&Tensor4::random(m, m, 3, 3, &mut rng), 6, 7);
        let mg = merge_bottleneck(&w1, &t2, &w3).unwrap();
        assert_eq!((mg.w1m.rows, mg.w1m.cols), (6, c));
        assert_eq!((mg.core.o, mg.core.i), (7, 6));
        assert_eq!((mg.w3m.rows, mg.w3m.cols), (s, 7));
    }

    #[test]
    fn merge_shape_mismatch_rejected() {
        let mut rng = Rng::new(2);
        let w1 = Matrix::random(10, 4, &mut rng); // M=10 but tucker is over M=8
        let w3 = Matrix::random(16, 8, &mut rng);
        let t2 = tucker_stack(&Tensor4::random(8, 8, 3, 3, &mut rng), 4, 4);
        assert!(merge_bottleneck(&w1, &t2, &w3).is_err());
    }

    #[test]
    fn branch_extracts_diagonal_blocks() {
        let mut rng = Rng::new(3);
        let t = tucker_stack(&Tensor4::random(8, 8, 3, 3, &mut rng), 4, 4);
        let b = branch_tucker(&t, 2).unwrap();
        assert_eq!((b.core.o, b.core.i), (4, 2));
        assert_eq!(b.core.numel(), t.core.numel() / 2); // eq. (18)-(20)
        for j in 0..2 {
            for i in 0..2 {
                assert_eq!(b.core.at(j, i, 1, 1), t.core.at(j, i, 1, 1));
                assert_eq!(b.core.at(2 + j, i, 1, 1), t.core.at(2 + j, 2 + i, 1, 1));
            }
        }
    }

    #[test]
    fn branch_rejects_indivisible() {
        let mut rng = Rng::new(4);
        let t = tucker_stack(&Tensor4::random(9, 9, 3, 3, &mut rng), 6, 6);
        assert!(branch_tucker(&t, 4).is_err());
    }

    #[test]
    fn merged_linear_equivalence_at_full_rank() {
        // with full-rank Tucker and no nonlinearity, the merged 1x1 products
        // compute the same linear map as the unmerged chain
        let mut rng = Rng::new(5);
        let (c, m) = (4, 6);
        let w1 = Matrix::random(m, c, &mut rng);
        let w3 = Matrix::random(8, m, &mut rng);
        let w2 = Tensor4::random(m, m, 1, 1, &mut rng); // 1x1 core for exact algebra
        let t2 = tucker_stack(&w2, m, m);
        let mg = merge_bottleneck(&w1, &t2, &w3).unwrap();
        // chain: w3 @ (V (core U)) @ w1 as matrices (all 1x1)
        let core_m = Matrix::from_vec(t2.core.o, t2.core.i, t2.core.data.clone());
        let chain = w3
            .matmul(&t2.v)
            .matmul(&core_m)
            .matmul(&t2.u)
            .matmul(&w1);
        let merged_m = Matrix::from_vec(mg.core.o, mg.core.i, mg.core.data.clone());
        let merged = mg.w3m.matmul(&merged_m).matmul(&mg.w1m);
        assert_allclose(&merged.data, &chain.data, 1e-3, 1e-3);
    }
}
