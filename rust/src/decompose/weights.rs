//! Weight-level decomposition transforms (the rust mirror of
//! `python/compile/decompose.py`): SVD split, Tucker-2 stack, Fig. 3
//! merging and Fig. 4 branch splitting, over `linalg` types.

use anyhow::{bail, Result};

use crate::linalg::{svd, tucker2, Matrix, Tensor4, Tucker2};

/// Eq. (3): split an [S, C] weight into (w0: [R, C], w1: [S, R]) with each
/// factor absorbing sqrt(sigma).
pub fn svd_split(w: &Matrix, r: usize) -> (Matrix, Matrix) {
    svd(w).split(r)
}

/// Eq. (4)-(6): Tucker-2 stack of an OIHW conv weight.
pub fn tucker_stack(w: &Tensor4, r1: usize, r2: usize) -> Tucker2 {
    tucker2(w, r1, r2)
}

/// CP / Lebedev chain weights in application order:
/// `u` [R, C] (1x1 in), `kh` [R, k] (kx1 depthwise), `kw` [R, k]
/// (1xk depthwise), `w1` [S, R] (1x1 out).
#[derive(Clone, Debug)]
pub struct CpStack {
    pub u: Matrix,
    pub kh: Matrix,
    pub kw: Matrix,
    pub w1: Matrix,
}

/// Rank-1 separable projection of a [C, kh, kw] slab by alternating power
/// iterations: slab ~= a (x) b (x) c with b, c unit and a carrying scale.
fn separate_rank1(slab: &[f32], c: usize, kh: usize, kw: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let at = |ci: usize, hi: usize, wi: usize| slab[(ci * kh + hi) * kw + wi];
    let mut a = vec![0.0f32; c];
    let mut b = vec![1.0f32; kh];
    let mut cc = vec![1.0f32; kw];
    let norm1 = |v: &mut [f32]| {
        let n = v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32;
        if n > 1e-20 {
            for x in v.iter_mut() {
                *x /= n;
            }
        } else if let Some(first) = v.first_mut() {
            *first = 1.0;
        }
    };
    norm1(&mut b);
    norm1(&mut cc);
    for _ in 0..8 {
        for (ci, av) in a.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (hi, &bv) in b.iter().enumerate() {
                for (wi, &cv) in cc.iter().enumerate() {
                    acc += at(ci, hi, wi) * bv * cv;
                }
            }
            *av = acc;
        }
        let an = a.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32;
        if an <= 1e-20 {
            break;
        }
        for (hi, bv) in b.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (ci, &av) in a.iter().enumerate() {
                for (wi, &cv) in cc.iter().enumerate() {
                    acc += at(ci, hi, wi) * av * cv;
                }
            }
            *bv = acc / (an * an);
        }
        norm1(&mut b);
        for (wi, cv) in cc.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (ci, &av) in a.iter().enumerate() {
                for (hi, &bv) in b.iter().enumerate() {
                    acc += at(ci, hi, wi) * av * bv;
                }
            }
            *cv = acc / (an * an);
        }
        norm1(&mut cc);
    }
    (a, b, cc)
}

/// Deterministic CP chain construction: top-`r` SVD components of the
/// mode-O unfolding, each right singular vector projected to a separable
/// [C] (x) [kh] (x) [kw] triple. Cheaper than full ALS at paper-scale
/// layers (one Jacobi SVD, like `tucker_stack`); `linalg::cp_als` remains
/// the reference for small tensors. Components beyond the unfolding rank
/// are zero-padded so the factor shapes always match the requested rank.
pub fn cp_stack(w: &Tensor4, r: usize) -> CpStack {
    let (s_ch, c_ch, kh, kw) = (w.o, w.i, w.h, w.w);
    let dec = svd(&w.unfold_o());
    let r_eff = r.min(dec.s.len());
    let mut u = Matrix::zeros(r, c_ch);
    let mut kh_m = Matrix::zeros(r, kh);
    let mut kw_m = Matrix::zeros(r, kw);
    let mut w1 = Matrix::zeros(s_ch, r);
    for j in 0..r_eff {
        let sig = dec.s[j].max(0.0);
        let root = sig.sqrt();
        for si in 0..s_ch {
            w1[(si, j)] = dec.u[(si, j)] * root;
        }
        let slab: Vec<f32> = dec.vt.row(j).to_vec();
        let (a, b, c) = separate_rank1(&slab, c_ch, kh, kw);
        for (ci, &av) in a.iter().enumerate() {
            u[(j, ci)] = av * root;
        }
        for (hi, &bv) in b.iter().enumerate() {
            kh_m[(j, hi)] = bv;
        }
        for (wi, &cv) in c.iter().enumerate() {
            kw_m[(j, wi)] = cv;
        }
    }
    CpStack { u, kh: kh_m, kw: kw_m, w1 }
}

impl CpStack {
    /// Dense OIHW reconstruction of the chain (for error reporting and the
    /// lowering equivalence tests).
    pub fn reconstruct(&self) -> Tensor4 {
        let (r, c_ch) = (self.u.rows, self.u.cols);
        let (s_ch, kh, kw) = (self.w1.rows, self.kh.cols, self.kw.cols);
        let mut out = Tensor4::zeros(s_ch, c_ch, kh, kw);
        for j in 0..r {
            for si in 0..s_ch {
                let ws = self.w1[(si, j)];
                if ws == 0.0 {
                    continue;
                }
                for ci in 0..c_ch {
                    let wc = ws * self.u[(j, ci)];
                    if wc == 0.0 {
                        continue;
                    }
                    for hi in 0..kh {
                        let wh = wc * self.kh[(j, hi)];
                        for wi in 0..kw {
                            *out.at_mut(si, ci, hi, wi) += wh * self.kw[(j, wi)];
                        }
                    }
                }
            }
        }
        out
    }

    /// Exact parameter count of the four factors.
    pub fn params(&self) -> usize {
        self.u.rows * self.u.cols
            + self.kh.rows * self.kh.cols
            + self.kw.rows * self.kw.cols
            + self.w1.rows * self.w1.cols
    }
}

/// Fig. 3 merged bottleneck weights.
#[derive(Clone, Debug)]
pub struct MergedBottleneck {
    /// [r1, C] — conv1 folded with conv2's Tucker U
    pub w1m: Matrix,
    /// [r2, r1, k, k]
    pub core: Tensor4,
    /// [S, r2] — conv3 folded with conv2's Tucker V
    pub w3m: Matrix,
}

/// Fold the Tucker 1x1 factors into the adjacent bottleneck 1x1 convs:
/// conv1' = U2 @ W1 ([r1,M]@[M,C]), conv3' = W3 @ V2 ([S,M]@[M,r2]).
pub fn merge_bottleneck(w1: &Matrix, t2: &Tucker2, w3: &Matrix) -> Result<MergedBottleneck> {
    if t2.u.cols != w1.rows {
        bail!("U2 [.,{}] does not compose with conv1 [{},.]", t2.u.cols, w1.rows);
    }
    if w3.cols != t2.v.rows {
        bail!("conv3 [.,{}] does not compose with V2 [{},.]", w3.cols, t2.v.rows);
    }
    Ok(MergedBottleneck {
        w1m: t2.u.matmul(w1),
        core: t2.core.clone(),
        w3m: w3.matmul(&t2.v),
    })
}

/// Fig. 4 grouped-conv weights for N Tucker branches.
#[derive(Clone, Debug)]
pub struct Branched {
    /// [r1, C]
    pub u: Matrix,
    /// grouped OIHW: [r2, r1/N, k, k]
    pub core: Tensor4,
    /// [S, r2]
    pub v: Matrix,
    pub groups: usize,
}

/// Eq. (12)-(17): keep the diagonal core blocks (the off-diagonal blocks
/// are dropped — that is the N-fold parameter saving of eq. 18-20 and why
/// branching needs fine-tuning).
pub fn branch_tucker(t: &Tucker2, groups: usize) -> Result<Branched> {
    let (r2, r1) = (t.core.o, t.core.i);
    if r1 % groups != 0 || r2 % groups != 0 {
        bail!("ranks ({r1},{r2}) not divisible by N={groups}");
    }
    let (b1, b2) = (r1 / groups, r2 / groups);
    let mut core = Tensor4::zeros(r2, b1, t.core.h, t.core.w);
    for g in 0..groups {
        for j in 0..b2 {
            for i in 0..b1 {
                for h in 0..t.core.h {
                    for w in 0..t.core.w {
                        *core.at_mut(g * b2 + j, i, h, w) =
                            t.core.at(g * b2 + j, g * b1 + i, h, w);
                    }
                }
            }
        }
    }
    Ok(Branched { u: t.u.clone(), core, v: t.v.clone(), groups })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::assert_allclose;
    use crate::util::rng::Rng;

    #[test]
    fn svd_split_reconstructs_at_full_rank() {
        let mut rng = Rng::new(0);
        let w = Matrix::random(12, 8, &mut rng);
        let (w0, w1) = svd_split(&w, 8);
        assert_allclose(&w1.matmul(&w0).data, &w.data, 1e-3, 1e-3);
    }

    #[test]
    fn cp_stack_shapes_and_zero_padding() {
        let mut rng = Rng::new(7);
        let w = Tensor4::random(6, 5, 3, 3, &mut rng);
        // r beyond the unfolding rank (6): extra components are zero
        let s = cp_stack(&w, 9);
        assert_eq!((s.u.rows, s.u.cols), (9, 5));
        assert_eq!((s.kh.rows, s.kh.cols), (9, 3));
        assert_eq!((s.kw.rows, s.kw.cols), (9, 3));
        assert_eq!((s.w1.rows, s.w1.cols), (6, 9));
        assert_eq!(s.params(), 9 * 5 + 9 * 3 + 9 * 3 + 6 * 9);
        for j in 6..9 {
            assert!(s.u.row(j).iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn cp_stack_recovers_separable_tensor() {
        // W[s,c,h,w] = f[s] g[c] p[h] q[w] is exactly CP rank 1
        let (s_ch, c_ch, k) = (5usize, 4usize, 3usize);
        let f: Vec<f32> = (0..s_ch).map(|i| 0.5 + i as f32).collect();
        let g: Vec<f32> = (0..c_ch).map(|i| 1.0 - 0.1 * i as f32).collect();
        let p = [0.2f32, 1.0, 0.4];
        let q = [0.9f32, -0.3, 0.1];
        let mut w = Tensor4::zeros(s_ch, c_ch, k, k);
        for si in 0..s_ch {
            for ci in 0..c_ch {
                for hi in 0..k {
                    for wi in 0..k {
                        *w.at_mut(si, ci, hi, wi) = f[si] * g[ci] * p[hi] * q[wi];
                    }
                }
            }
        }
        let s = cp_stack(&w, 1);
        assert_allclose(&s.reconstruct().data, &w.data, 1e-3, 1e-4);
    }

    #[test]
    fn merge_shapes() {
        let mut rng = Rng::new(1);
        let (c, m, s) = (8, 16, 32);
        let w1 = Matrix::random(m, c, &mut rng);
        let w3 = Matrix::random(s, m, &mut rng);
        let t2 = tucker_stack(&Tensor4::random(m, m, 3, 3, &mut rng), 6, 7);
        let mg = merge_bottleneck(&w1, &t2, &w3).unwrap();
        assert_eq!((mg.w1m.rows, mg.w1m.cols), (6, c));
        assert_eq!((mg.core.o, mg.core.i), (7, 6));
        assert_eq!((mg.w3m.rows, mg.w3m.cols), (s, 7));
    }

    #[test]
    fn merge_shape_mismatch_rejected() {
        let mut rng = Rng::new(2);
        let w1 = Matrix::random(10, 4, &mut rng); // M=10 but tucker is over M=8
        let w3 = Matrix::random(16, 8, &mut rng);
        let t2 = tucker_stack(&Tensor4::random(8, 8, 3, 3, &mut rng), 4, 4);
        assert!(merge_bottleneck(&w1, &t2, &w3).is_err());
    }

    #[test]
    fn branch_extracts_diagonal_blocks() {
        let mut rng = Rng::new(3);
        let t = tucker_stack(&Tensor4::random(8, 8, 3, 3, &mut rng), 4, 4);
        let b = branch_tucker(&t, 2).unwrap();
        assert_eq!((b.core.o, b.core.i), (4, 2));
        assert_eq!(b.core.numel(), t.core.numel() / 2); // eq. (18)-(20)
        for j in 0..2 {
            for i in 0..2 {
                assert_eq!(b.core.at(j, i, 1, 1), t.core.at(j, i, 1, 1));
                assert_eq!(b.core.at(2 + j, i, 1, 1), t.core.at(2 + j, 2 + i, 1, 1));
            }
        }
    }

    #[test]
    fn branch_rejects_indivisible() {
        let mut rng = Rng::new(4);
        let t = tucker_stack(&Tensor4::random(9, 9, 3, 3, &mut rng), 6, 6);
        assert!(branch_tucker(&t, 4).is_err());
    }

    #[test]
    fn merged_linear_equivalence_at_full_rank() {
        // with full-rank Tucker and no nonlinearity, the merged 1x1 products
        // compute the same linear map as the unmerged chain
        let mut rng = Rng::new(5);
        let (c, m) = (4, 6);
        let w1 = Matrix::random(m, c, &mut rng);
        let w3 = Matrix::random(8, m, &mut rng);
        let w2 = Tensor4::random(m, m, 1, 1, &mut rng); // 1x1 core for exact algebra
        let t2 = tucker_stack(&w2, m, m);
        let mg = merge_bottleneck(&w1, &t2, &w3).unwrap();
        // chain: w3 @ (V (core U)) @ w1 as matrices (all 1x1)
        let core_m = Matrix::from_vec(t2.core.o, t2.core.i, t2.core.data.clone());
        let chain = w3
            .matmul(&t2.v)
            .matmul(&core_m)
            .matmul(&t2.u)
            .matmul(&w1);
        let merged_m = Matrix::from_vec(mg.core.o, mg.core.i, mg.core.data.clone());
        let merged = mg.w3m.matmul(&merged_m).matmul(&mg.w1m);
        assert_allclose(&merged.data, &chain.data, 1e-3, 1e-3);
    }
}
