//! Fig. 2: throughput vs Tucker rank for the [512, 512, 3, 3] conv of
//! ResNet-152 — the rank-cliff phenomenon that motivates Algorithm 1.
//!
//! The paper sweeps CUDA tiles (cliff at 257 -> 256). We sweep the same
//! layer on XLA:CPU (cliffs at vector-width multiples) and additionally
//! emit the analytic tile-model curve for a 128-lane (MXU-like) device —
//! the TPU adaptation described in DESIGN.md §Hardware-Adaptation.

use anyhow::Result;

use super::Report;
use crate::decompose::rank_opt::{AnalyticTimer, LayerTimer};
use crate::decompose::Scheme;
use crate::model::{ConvSite, SiteKind};
use crate::profiler::Timer;
use crate::runtime::layer_factory::EngineLayerTimer;
use crate::runtime::{CompileOptions, Engine};
use crate::util::json::Json;

pub struct Config {
    pub c: usize,
    pub s: usize,
    pub k: usize,
    pub rank_lo: usize,
    pub rank_hi: usize,
    pub step: usize,
    pub batch: usize,
    pub hw: usize,
    pub real: bool,
    /// compile options for the `--real` engine timer (`--opt-level`)
    pub opt: CompileOptions,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            c: 512,
            s: 512,
            k: 3,
            rank_lo: 240,
            rank_hi: 320,
            step: 4,
            batch: 2,
            hw: 16,
            real: false,
            opt: CompileOptions::default(),
        }
    }
}

pub fn run(engine: &Engine, cfg: &Config) -> Result<Report> {
    let site = ConvSite {
        name: format!("fig2.{}x{}x{}", cfg.c, cfg.s, cfg.k),
        c: cfg.c,
        s: cfg.s,
        k: cfg.k,
        stride: 1,
        padding: 1,
        kind: SiteKind::Conv,
    };
    let mut real_timer;
    let mut analytic_timer;
    let timer: &mut dyn LayerTimer = if cfg.real {
        real_timer = EngineLayerTimer::with_options(
            engine.clone(),
            Timer { warmup: 1, min_samples: 4, max_samples: 10, cv_target: 0.15 },
            cfg.opt.clone(),
        );
        &mut real_timer
    } else {
        analytic_timer = AnalyticTimer { lane: 128, ..Default::default() };
        &mut analytic_timer
    };

    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    let mut r = cfg.rank_lo;
    let mut prev: Option<f64> = None;
    let mut max_cliff = (0usize, 0.0f64);
    while r <= cfg.rank_hi {
        let scheme = Scheme::Tucker { r1: r, r2: r };
        let t = timer.time_layer(&site, &scheme, cfg.batch, cfg.hw)?;
        let fps = cfg.batch as f64 / t;
        if let Some(p) = prev {
            let jump = (fps - p) / p;
            if jump.abs() > max_cliff.1.abs() {
                max_cliff = (r, jump);
            }
        }
        prev = Some(fps);
        rows.push(vec![r.to_string(), format!("{:.3}", t * 1e3), format!("{fps:.1}")]);
        jrows.push(Json::Arr(vec![Json::Num(r as f64), Json::Num(t), Json::Num(fps)]));
        r += cfg.step;
    }
    Ok(Report {
        id: "fig2".into(),
        title: format!(
            "throughput vs Tucker rank, [{},{},{k},{k}] ({} timing)",
            cfg.c,
            cfg.s,
            if cfg.real {
                format!("{} wall-clock", engine.platform())
            } else {
                "analytic 128-lane tile model".to_string()
            },
            k = cfg.k
        ),
        header: ["rank", "ms/call", "items/s"].iter().map(|s| s.to_string()).collect(),
        rows,
        notes: vec![
            format!(
                "largest step between adjacent ranks: {:+.1}% at rank {} (paper: 15% at 257 -> 256 on CUDA)",
                max_cliff.1 * 100.0,
                max_cliff.0
            ),
            "cliff positions are device-specific (CUDA tile 32 / MXU lane 128 / AVX 8-16); \
             the *existence* of cliffs at tile multiples is the reproduced claim"
                .into(),
        ],
        json: Json::obj_from(vec![
            ("curve", Json::Arr(jrows)),
            ("max_cliff_rank", Json::Num(max_cliff.0 as f64)),
            ("max_cliff_jump", Json::Num(max_cliff.1)),
        ]),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_fig2_shows_cliff_at_lane_multiple() {
        let engine = Engine::cpu().unwrap();
        let cfg = Config { step: 1, rank_lo: 250, rank_hi: 262, ..Default::default() };
        let rep = run(&engine, &cfg).unwrap();
        assert_eq!(rep.rows.len(), 13);
        // the 128-lane model must place the big jump going 256 -> 257
        let cliff_rank = rep.json.get("max_cliff_rank").unwrap().int().unwrap();
        assert_eq!(cliff_rank, 257, "cliff should be crossing the 2x128 boundary");
        let jump = rep.json.get("max_cliff_jump").unwrap().num().unwrap();
        assert!(jump < -0.05, "throughput must DROP past the boundary, got {jump}");
    }
}
