//! Fig. 5: model throughput vs number of Tucker branches N.
//!
//! Builds the branched network for N in {1, 2, 4, 8, ...} and measures
//! images/sec, plus the analytic core-parameter saving (eq. 18-20).

use anyhow::Result;

use super::{measure_fps, Report};
use crate::decompose::{plan_variant, Variant};
use crate::model::{cost, Arch};
use crate::profiler::Timer;
use crate::runtime::netbuilder::BuiltNet;
use crate::runtime::{CompileOptions, Engine};
use crate::util::json::Json;

pub struct Config {
    pub arch: String,
    pub branch_counts: Vec<usize>,
    pub hw: usize,
    pub batch: usize,
    pub alpha: f64,
    pub no_measure: bool,
    /// compile options for the measured networks (`--opt-level`)
    pub opt: CompileOptions,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            arch: "resnet50".into(),
            branch_counts: vec![1, 2, 4],
            hw: 64,
            batch: 8,
            alpha: 2.0,
            no_measure: false,
            opt: CompileOptions::default(),
        }
    }
}

pub fn run(engine: &Engine, cfg: &Config) -> Result<Report> {
    let arch = Arch::by_name(&cfg.arch)
        .ok_or_else(|| anyhow::anyhow!("unknown arch {}", cfg.arch))?;
    let timer = Timer::default();
    let plan0 = plan_variant(&arch, Variant::Orig, cfg.alpha, 1, None)?;
    let macs0 = cost::count_macs(&arch, &plan0, 224);
    let fps0 = if cfg.no_measure {
        f64::NAN
    } else {
        let net = BuiltNet::compile(engine, &arch, &plan0, cfg.batch, cfg.hw, 2, &cfg.opt)?;
        measure_fps(engine, &net, &timer)?
    };

    let mut rows = vec![vec![
        "orig".into(),
        "-".into(),
        format!("{:.2}", 2.0 * macs0 as f64 / 1e9),
        if fps0.is_nan() { "-".into() } else { format!("{fps0:.1}") },
        "1.00x".into(),
    ]];
    let mut jrows = Vec::new();
    for &n in &cfg.branch_counts {
        let plan = plan_variant(&arch, Variant::Branched, cfg.alpha, n, None)?;
        let macs = cost::count_macs(&arch, &plan, 224);
        let fps = if cfg.no_measure {
            f64::NAN
        } else {
            let net =
                BuiltNet::compile(engine, &arch, &plan, cfg.batch, cfg.hw, 2, &cfg.opt)?;
            measure_fps(engine, &net, &timer)?
        };
        rows.push(vec![
            format!("N={n}"),
            n.to_string(),
            format!("{:.2}", 2.0 * macs as f64 / 1e9),
            if fps.is_nan() { "-".into() } else { format!("{fps:.1}") },
            if fps.is_nan() {
                format!("{:.2}x (analytic)", macs0 as f64 / macs as f64)
            } else {
                format!("{:.2}x", fps / fps0)
            },
        ]);
        jrows.push(Json::obj_from(vec![
            ("branches", Json::Num(n as f64)),
            ("flops", Json::Num(2.0 * macs as f64)),
            ("fps", Json::Num(fps)),
            ("fps_orig", Json::Num(fps0)),
        ]));
    }
    Ok(Report {
        id: "fig5".into(),
        title: format!("throughput vs branch count, {} (paper Fig. 5)", cfg.arch),
        header: ["config", "N", "FLOPs (B)", "fps", "speedup"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        rows,
        notes: vec![
            "N=1 is vanilla Tucker at the same ranks; larger N shrinks the core \
             N-fold (eq. 18-20) at fixed ranks"
                .into(),
        ],
        json: Json::obj_from(vec![("rows", Json::Arr(jrows))]),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_fig5_flops_fall_with_branches() {
        let engine = Engine::cpu().unwrap();
        let cfg = Config {
            arch: "resnet50".into(),
            branch_counts: vec![1, 2, 4],
            no_measure: true,
            ..Default::default()
        };
        let rep = run(&engine, &cfg).unwrap();
        let flops: Vec<f64> = rep.rows.iter().map(|r| r[2].parse::<f64>().unwrap()).collect();
        assert!(flops[1] < flops[0], "N=1 branched < orig");
        assert!(flops[2] < flops[1], "N=2 < N=1");
        assert!(flops[3] < flops[2], "N=4 < N=2");
    }
}
