//! Experiment harness: one regenerator per table/figure of the paper.
//!
//! Every experiment returns a `Report` (markdown-ish table + structured
//! JSON) and is reachable three ways: `lrdx bench <id>`, `cargo bench
//! --bench <id>`, and the functions here. Reports are also written to
//! `reports/<id>.json` for EXPERIMENTS.md.

pub mod fig2;
pub mod fig5;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table456;

use anyhow::Result;

use crate::profiler::Timer;
use crate::runtime::netbuilder::BuiltNet;
use crate::runtime::Engine;
use crate::util::json::Json;

/// A rendered experiment result.
pub struct Report {
    pub id: String,
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
    pub json: Json,
}

impl Report {
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(cell.len());
                }
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths.get(i).copied().unwrap_or(4)))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = format!("== {} — {} ==\n", self.id, self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }

    /// Persist the structured result under `reports/`.
    pub fn save(&self, dir: &std::path::Path) -> Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.json", self.id));
        std::fs::write(&path, self.json.render())?;
        Ok(path)
    }
}

/// Measure steady-state images/sec of a built network at its batch size.
pub fn measure_fps(engine: &Engine, net: &BuiltNet, timer: &Timer) -> Result<f64> {
    let x: Vec<f32> = crate::util::det_input(net.batch, net.hw);
    let xb = engine.upload(&x, &[net.batch, 3, net.hw, net.hw])?;
    let summary = timer.measure(|| {
        let out = net.forward(&xb)?;
        out.sync()?;
        Ok(())
    })?;
    if !summary.converged {
        eprintln!(
            "warning: fps measurement (batch={}, hw={}) did not converge \
             (cv={:.3} after {} samples) — treat the number as noisy",
            net.batch,
            net.hw,
            summary.cv(),
            summary.n
        );
    }
    Ok(net.batch as f64 / summary.trimmed_mean)
}

/// Percent delta vs a baseline (negative = reduction), rendered like the
/// paper's tables.
pub fn pct_delta(value: f64, baseline: f64) -> f64 {
    (value / baseline - 1.0) * 100.0
}

pub fn fmt_pct(v: f64) -> String {
    format!("{v:+.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_render_aligns_columns() {
        let r = Report {
            id: "t".into(),
            title: "demo".into(),
            header: vec!["a".into(), "bbbb".into()],
            rows: vec![
                vec!["xxxxx".into(), "1".into()],
                vec!["y".into(), "22".into()],
            ],
            notes: vec!["n1".into()],
            json: Json::Null,
        };
        let s = r.render();
        assert!(s.contains("demo"));
        assert!(s.contains("note: n1"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    fn pct_delta_signs() {
        assert!((pct_delta(50.0, 100.0) + 50.0).abs() < 1e-12);
        assert!((pct_delta(110.0, 100.0) - 10.0).abs() < 1e-12);
        assert_eq!(fmt_pct(-43.26), "-43.26");
    }
}
