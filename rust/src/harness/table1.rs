//! Table 1: layers / params / FLOPs / train fps / infer fps for
//! ResNet-50/101/152, original vs vanilla-LRD 2x.
//!
//! Layers/params/FLOPs are analytic (`model::cost`, exact); fps is measured
//! on XLA:CPU via the builder networks. The paper measured on GPU at
//! 224x224; we default to 64x64 (channel structure — what LRD changes — is
//! identical; see DESIGN.md §5). Train fps is estimated from infer fps via
//! the standard fwd:fwd+bwd MAC ratio (~1:3), cross-calibrated on the mini
//! train artifacts in table456.

use anyhow::Result;

use super::{measure_fps, Report};
use crate::decompose::{plan_variant, Variant};
use crate::model::{cost, Arch};
use crate::profiler::Timer;
use crate::runtime::netbuilder::BuiltNet;
use crate::runtime::{CompileOptions, Engine};
use crate::util::json::Json;

pub struct Config {
    pub archs: Vec<String>,
    pub hw: usize,
    pub batch: usize,
    pub alpha: f64,
    /// skip wall-clock measurement (analytic columns only)
    pub no_measure: bool,
    /// compile options for the measured networks (`--opt-level`)
    pub opt: CompileOptions,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            archs: vec!["resnet50".into()],
            hw: 64,
            batch: 8,
            alpha: 2.0,
            no_measure: false,
            opt: CompileOptions::default(),
        }
    }
}

pub fn run(engine: &Engine, cfg: &Config) -> Result<Report> {
    let timer = Timer::default();
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    // per-network heaviest-sites notes, only when `--profile` is on
    let mut profile_notes: Vec<String> = Vec::new();
    for arch_name in &cfg.archs {
        let arch = Arch::by_name(arch_name)
            .ok_or_else(|| anyhow::anyhow!("unknown arch {arch_name}"))?;
        for variant in [Variant::Orig, Variant::Lrd] {
            let plan = plan_variant(&arch, variant, cfg.alpha, 4, None)?;
            let rep = cost::report(&arch, &plan, 224); // paper-resolution FLOPs
            let mut arena_peak = 0f64;
            let fps = if cfg.no_measure {
                f64::NAN
            } else {
                let net = BuiltNet::compile(
                    engine, &arch, &plan, cfg.batch, cfg.hw, 0xBEEF, &cfg.opt,
                )?;
                if let Some(a) = &net.pass_stats().arena {
                    arena_peak = a.peak_bytes as f64;
                }
                let fps = measure_fps(engine, &net, &timer)?;
                if let Some(p) = net.exe.profile() {
                    let mut sites = p.by_site();
                    sites.truncate(3);
                    profile_notes.push(format!(
                        "profile {} {}: {}",
                        arch.name,
                        variant.name(),
                        sites
                            .iter()
                            .map(|s| format!(
                                "{} [{}] {:.3}ms/run ({:.1} GFLOP/s)",
                                s.site,
                                s.op,
                                s.ms_per_run(p.runs),
                                s.gflops()
                            ))
                            .collect::<Vec<_>>()
                            .join(", ")
                    ));
                }
                fps
            };
            let label = match variant {
                Variant::Orig => arch.name.to_string(),
                _ => "Vanilla LRD".to_string(),
            };
            rows.push(vec![
                label.clone(),
                rep.layers.to_string(),
                format!("{:.2}", rep.params as f64 / 1e6),
                format!("{:.2}", 2.0 * rep.macs as f64 / 1e9),
                if fps.is_nan() { "-".into() } else { format!("{:.0}", fps / 3.0) },
                if fps.is_nan() { "-".into() } else { format!("{fps:.0}") },
            ]);
            jrows.push(Json::obj_from(vec![
                ("arch", Json::Str(arch.name.into())),
                ("variant", Json::Str(variant.name().into())),
                ("layers", Json::Num(rep.layers as f64)),
                ("params", Json::Num(rep.params as f64)),
                ("flops", Json::Num(2.0 * rep.macs as f64)),
                ("infer_fps", Json::Num(fps)),
                ("threads", Json::Num(cfg.opt.resolved_threads() as f64)),
                ("arena_peak_bytes", Json::Num(arena_peak)),
            ]));
        }
    }
    Ok(Report {
        id: "table1".into(),
        title: "ResNet stats before/after vanilla LRD (paper Table 1)".into(),
        header: ["Model", "Layers", "Params (M)", "FLOPs (B)", "Train fps*", "Infer fps"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        rows,
        notes: {
            let mut notes = vec![
            format!(
                "fps measured on {} at {}x{} batch {} ({} executor thread(s)); \
                 paper used GPU at 224 (DESIGN.md §5)",
                engine.platform(),
                cfg.hw,
                cfg.hw,
                cfg.batch,
                cfg.opt.resolved_threads()
            ),
            "Train fps* estimated as infer fps / 3 (fwd:fwd+bwd MACs); measured train \
             throughput for the mini models is in table456"
                .into(),
            "FLOPs column computed at the paper's 224x224".into(),
            ];
            notes.extend(profile_notes);
            notes
        },
        json: Json::obj_from(vec![("rows", Json::Arr(jrows))]),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_only_runs_fast_and_matches_paper_shape() {
        let engine = Engine::cpu().unwrap();
        let cfg = Config {
            archs: vec!["resnet50".into(), "resnet101".into(), "resnet152".into()],
            no_measure: true,
            ..Default::default()
        };
        let rep = run(&engine, &cfg).unwrap();
        assert_eq!(rep.rows.len(), 6);
        // paper Table 1 params column: 25.56 / 12.78 for ResNet-50
        assert_eq!(rep.rows[0][2], "25.56");
        let lrd_params: f64 = rep.rows[1][2].parse().unwrap();
        assert!((12.0..14.0).contains(&lrd_params));
        // layer counts: 50 -> ~115
        assert_eq!(rep.rows[0][1], "50");
        let lrd_layers: i64 = rep.rows[1][1].parse().unwrap();
        assert!((114..=116).contains(&lrd_layers));
    }
}
