//! Table 2: Algorithm 1 optimized ranks for the early/late ResNet-152
//! layers the paper lists (layer1.0.conv1..3, layer4.2.conv1..3, fc).
//!
//! Two timing backends: the real PJRT layer timer (`--real`, measures
//! XLA:CPU wall-clock per candidate rank) or the deterministic analytic
//! timer (tile-efficiency cost model — reproduces the *mechanism* of the
//! paper's 15% cliff without minutes of compiles).

use anyhow::Result;

use super::Report;
use crate::decompose::rank_opt::{
    optimize_site, AnalyticTimer, LayerTimer, RankOptConfig,
};
use crate::decompose::{Scheme, SchemeFamily};
use crate::model::Arch;
use crate::profiler::Timer;
use crate::runtime::layer_factory::EngineLayerTimer;
use crate::runtime::{CompileOptions, Engine};
use crate::util::json::Json;

pub struct Config {
    pub arch: String,
    pub sites: Vec<String>,
    pub real: bool,
    pub batch: usize,
    pub hw: usize,
    pub stride: usize,
    pub refine: usize,
    /// decomposition family the sweep lowers candidates to (`--scheme`)
    pub family: SchemeFamily,
    /// compile options for the `--real` engine timer (`--opt-level`)
    pub opt: CompileOptions,
    /// when set, each optimized site also times its sparse-residual
    /// composition (W ~= chain + S at this density) as a companion
    /// `{site}+s` row (`--sparse-density`)
    pub sparse_density: Option<f64>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            arch: "resnet152".into(),
            sites: [
                "layer1.0.conv1",
                "layer1.0.conv2",
                "layer1.0.conv3",
                "layer4.2.conv1",
                "layer4.2.conv2",
                "layer4.2.conv3",
                "fc",
            ]
            .iter()
            .map(|s| s.to_string())
            .collect(),
            real: false,
            batch: 4,
            hw: 32,
            stride: 4,
            refine: 4,
            family: SchemeFamily::Svd,
            opt: CompileOptions::default(),
            sparse_density: None,
        }
    }
}

/// Paper's Table 2 "Optimized Ranks" column for reference in the output.
fn paper_rank(site: &str) -> &'static str {
    match site {
        "layer1.0.conv1" => "ORG",
        "layer1.0.conv2" => "32",
        "layer1.0.conv3" => "24",
        "layer4.2.conv1" => "202",
        "layer4.2.conv2" => "308",
        "layer4.2.conv3" => "200",
        "fc" => "253",
        _ => "-",
    }
}

pub fn run(engine: &Engine, cfg: &Config) -> Result<Report> {
    let arch = Arch::by_name(&cfg.arch)
        .ok_or_else(|| anyhow::anyhow!("unknown arch {}", cfg.arch))?;
    let sites = arch.sites();
    let mut real_timer;
    let mut analytic_timer;
    let timer: &mut dyn LayerTimer = if cfg.real {
        real_timer = EngineLayerTimer::with_options(
            engine.clone(),
            Timer { warmup: 1, min_samples: 4, max_samples: 10, cv_target: 0.15 },
            cfg.opt.clone(),
        );
        &mut real_timer
    } else {
        analytic_timer = AnalyticTimer { lane: 16, ..Default::default() };
        &mut analytic_timer
    };
    let ocfg = RankOptConfig {
        alpha: 2.0,
        rmin_frac: 0.5,
        stride: cfg.stride,
        refine: cfg.refine,
        batch: cfg.batch,
        hw: cfg.hw,
        family: cfg.family,
    };

    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    for name in &cfg.sites {
        let site = sites
            .iter()
            .find(|t| &t.name == name)
            .ok_or_else(|| anyhow::anyhow!("no site {name} in {}", cfg.arch))?;
        // the fc site's spatial extent is 1 — time it at hw=1
        let (b, hw) = if site.k == 1 && site.name == "fc" {
            (cfg.batch * 8, 1)
        } else {
            (cfg.batch, cfg.hw)
        };
        let d = optimize_site(timer, site, &RankOptConfig { batch: b, hw, ..ocfg.clone() })?;
        let chosen = match d.chosen_rank {
            Some(r) => r.to_string(),
            None => "ORG".to_string(),
        };
        rows.push(vec![
            name.clone(),
            site.c.to_string(),
            site.s.to_string(),
            d.initial_rank.to_string(),
            chosen.clone(),
            paper_rank(name).to_string(),
            format!("{:.2}x", d.speedup()),
        ]);
        jrows.push(Json::obj_from(vec![
            ("site", Json::Str(name.clone())),
            ("initial_rank", Json::Num(d.initial_rank as f64)),
            (
                "chosen_rank",
                d.chosen_rank.map(|r| Json::Num(r as f64)).unwrap_or(Json::Null),
            ),
            ("t_orig", Json::Num(d.t_orig)),
            ("t_chosen", Json::Num(d.t_chosen)),
            ("speedup", Json::Num(d.speedup())),
            (
                "sweep",
                Json::Arr(
                    d.sweep
                        .iter()
                        .map(|&(r, t)| {
                            Json::Arr(vec![Json::Num(r as f64), Json::Num(t)])
                        })
                        .collect(),
                ),
            ),
        ]));
        // companion row: the chosen chain composed with a sparse residual
        if let (Some(density), Some(_)) = (cfg.sparse_density, d.chosen_rank) {
            let ppm = (density * 1e6).round() as u32;
            let sch = Scheme::Sparse { base: Box::new(d.scheme(site)), ppm };
            let t_sparse = timer.time_layer(site, &sch, b, hw)?;
            rows.push(vec![
                format!("{name}+s"),
                site.c.to_string(),
                site.s.to_string(),
                "-".into(),
                chosen.clone(),
                "-".into(),
                format!("{:.2}x", d.t_orig / t_sparse),
            ]);
            jrows.push(Json::obj_from(vec![
                ("site", Json::Str(format!("{name}+s"))),
                ("density", Json::Num(density)),
                ("t_sparse", Json::Num(t_sparse)),
                ("speedup", Json::Num(d.t_orig / t_sparse)),
            ]));
        }
    }
    Ok(Report {
        id: "table2".into(),
        title: format!(
            "Algorithm 1 optimized ranks, {} ({} timing)",
            cfg.arch,
            if cfg.real {
                format!("{} wall-clock", engine.platform())
            } else {
                "analytic tile model".to_string()
            }
        ),
        header: ["Layer", "In", "Out", "2x Rank", "Opt Rank", "Paper", "Speedup"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        rows,
        notes: vec![
            "Paper column = their Table 2 (V100-class GPU); absolute optimized ranks \
             are device-specific by design — what must reproduce is the *behaviour*: \
             ranks snap to tile-aligned values at/below the 2x rank, and layers where \
             decomposition loses keep ORG"
                .into(),
            format!(
                "search: coarse stride {} + stride-1 refine ±{}, Rmin = R/2",
                cfg.stride, cfg.refine
            ),
        ],
        json: Json::obj_from(vec![("rows", Json::Arr(jrows))]),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_table2_reproduces_paper_behaviour() {
        let engine = Engine::cpu().unwrap();
        let cfg = Config { stride: 1, refine: 0, ..Default::default() };
        let rep = run(&engine, &cfg).unwrap();
        assert_eq!(rep.rows.len(), 7);
        // 2x ranks column must match the paper exactly (it's pure eq. 7)
        let by: std::collections::HashMap<String, Vec<String>> =
            rep.rows.iter().map(|r| (r[0].clone(), r.clone())).collect();
        assert_eq!(by["layer1.0.conv1"][3], "16");
        assert_eq!(by["layer1.0.conv2"][3], "38");
        assert_eq!(by["layer4.2.conv2"][3], "309");
        assert_eq!(by["layer4.2.conv1"][3], "204");
        // optimized ranks stay within [R/2, R]; the large Tucker site must
        // snap to a lane-16 boundary (the Fig. 2 cliff mechanism)
        for r in &rep.rows {
            let opt = &r[4];
            if opt != "ORG" {
                let v: usize = opt.parse().unwrap();
                let init: usize = r[3].parse().unwrap();
                assert!(v <= init && v >= init / 2, "{}: rank {v} outside bounds", r[0]);
            }
        }
        let big = &by["layer4.2.conv2"][4];
        if big != "ORG" {
            let v: usize = big.parse().unwrap();
            assert_eq!(v % 16, 0, "512-wide core should snap to lane 16, got {v}");
        }
    }

    #[test]
    fn sparse_density_adds_companion_rows() {
        let engine = Engine::cpu().unwrap();
        let cfg = Config {
            stride: 1,
            refine: 0,
            sparse_density: Some(0.05),
            ..Default::default()
        };
        let rep = run(&engine, &cfg).unwrap();
        let base: Vec<_> = rep.rows.iter().filter(|r| !r[0].ends_with("+s")).collect();
        let sparse: Vec<_> = rep.rows.iter().filter(|r| r[0].ends_with("+s")).collect();
        assert_eq!(base.len(), 7);
        // every decomposed site gains exactly one `{site}+s` companion
        let n_org = base.iter().filter(|r| r[4] == "ORG").count();
        assert_eq!(sparse.len(), 7 - n_org);
        for r in &sparse {
            assert!(r[6].ends_with('x'), "{}: speedup cell {:?}", r[0], r[6]);
        }
    }
}
