//! Table 3: layers / compression / ΔFLOPs / train & infer speed-up for all
//! five methods on ResNet-50/101/152.
//!
//! Layers, ΔParams, ΔFLOPs are analytic (exact). Infer speed-up is measured
//! on the builder networks. Train speed-up: for Layer Freezing it is the
//! measured mini train-artifact ratio scaled by the model's frozen-fraction
//! (reported by table456's machinery); for the other methods the paper's
//! training cost tracks the forward cost, so we report the measured infer
//! speed-up as the train proxy (noted in the output).

use anyhow::Result;

use super::{fmt_pct, measure_fps, pct_delta, Report};
use crate::decompose::{plan_variant, sparsify_plan, Plan, Variant};
use crate::model::{cost, Arch};
use crate::profiler::Timer;
use crate::runtime::netbuilder::BuiltNet;
use crate::runtime::{CompileOptions, Engine};
use crate::util::json::Json;

pub struct Config {
    pub archs: Vec<String>,
    pub hw: usize,
    pub batch: usize,
    pub alpha: f64,
    pub groups: usize,
    pub no_measure: bool,
    /// opt-variant rank overrides (e.g. from `lrdx rank-search`)
    pub opt_plans: std::collections::BTreeMap<String, Plan>,
    /// compile options for the measured networks (`--opt-level`)
    pub opt: CompileOptions,
    /// when set, append sparse-residual composed rows (`svd+s`,
    /// `tucker2+s`, `cp+s`) AFTER the paper's five methods
    /// (`--sparse-density`)
    pub sparse_density: Option<f64>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            archs: vec!["resnet50".into()],
            hw: 64,
            batch: 8,
            alpha: 2.0,
            groups: 4,
            no_measure: false,
            opt_plans: Default::default(),
            opt: CompileOptions::default(),
            sparse_density: None,
        }
    }
}

fn label(v: Variant) -> &'static str {
    match v {
        Variant::Orig => "(original)",
        Variant::Lrd => "Vanilla LRD",
        Variant::Opt => "Optimized Ranks",
        Variant::Freeze => "Layer Freezing",
        Variant::Merged => "Layer Merging",
        Variant::Branched => "Layer Branching",
        Variant::Tucker2 => "Tucker-2 Chain",
        Variant::Cp => "CP Chain",
    }
}

pub fn run(engine: &Engine, cfg: &Config) -> Result<Report> {
    let timer = Timer::default();
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    for arch_name in &cfg.archs {
        let arch = Arch::by_name(arch_name)
            .ok_or_else(|| anyhow::anyhow!("unknown arch {arch_name}"))?;
        let plan0 = plan_variant(&arch, Variant::Orig, cfg.alpha, cfg.groups, None)?;
        let rep0 = cost::report(&arch, &plan0, 224);
        let fps0 = if cfg.no_measure {
            f64::NAN
        } else {
            let net =
                BuiltNet::compile(engine, &arch, &plan0, cfg.batch, cfg.hw, 1, &cfg.opt)?;
            measure_fps(engine, &net, &timer)?
        };
        rows.push(vec![
            format!("{arch_name}"),
            rep0.layers.to_string(),
            "-".into(),
            "-".into(),
            "-".into(),
            if fps0.is_nan() { "-".into() } else { format!("{fps0:.0} fps") },
        ]);
        let mut measured: Vec<(crate::decompose::Plan, f64)> = Vec::new();
        for variant in
            [Variant::Lrd, Variant::Opt, Variant::Freeze, Variant::Merged, Variant::Branched]
        {
            let overrides = cfg.opt_plans.get(arch_name.as_str());
            let plan = plan_variant(&arch, variant, cfg.alpha, cfg.groups, overrides)?;
            let rep = cost::report(&arch, &plan, 224);
            // Identical plans are identical graphs (Freeze ≡ LRD at
            // inference; Opt ≡ LRD when no overrides): reuse the
            // measurement instead of recompiling and re-timing — avoids
            // both wasted minutes and spurious cross-run variance.
            let fps = if cfg.no_measure {
                f64::NAN
            } else if let Some((_, f)) = measured.iter().find(|(p, _)| *p == plan) {
                *f
            } else {
                let net =
                    BuiltNet::compile(engine, &arch, &plan, cfg.batch, cfg.hw, 1, &cfg.opt)?;
                let f = measure_fps(engine, &net, &timer)?;
                measured.push((plan.clone(), f));
                f
            };
            let dparams = pct_delta(rep.params as f64, rep0.params as f64);
            let dflops = pct_delta(rep.macs as f64, rep0.macs as f64);
            let dinfer = if fps.is_nan() {
                f64::NAN
            } else {
                pct_delta(fps, fps0)
            };
            // Train-speed proxy: freezing accelerates the *backward* pass
            // by the frozen-parameter fraction on top of the fwd speedup.
            let dtrain = if variant == Variant::Freeze {
                // bwd is ~2/3 of a train step; frozen factors remove their
                // share of it. Measured end-to-end in table456 on the mini.
                let frozen_frac = frozen_param_fraction(&arch, &plan)?;
                if dinfer.is_nan() {
                    f64::NAN
                } else {
                    dinfer + frozen_frac * 2.0 / 3.0 * 100.0
                }
            } else {
                dinfer
            };
            rows.push(vec![
                label(variant).to_string(),
                rep.layers.to_string(),
                fmt_pct(dparams),
                fmt_pct(dflops),
                if dtrain.is_nan() { "-".into() } else { fmt_pct(dtrain) },
                if dinfer.is_nan() { "-".into() } else { fmt_pct(dinfer) },
            ]);
            jrows.push(Json::obj_from(vec![
                ("arch", Json::Str(arch_name.clone())),
                ("variant", Json::Str(variant.name().into())),
                ("layers", Json::Num(rep.layers as f64)),
                ("delta_params_pct", Json::Num(dparams)),
                ("delta_flops_pct", Json::Num(dflops)),
                ("delta_infer_pct", Json::Num(dinfer)),
                ("delta_train_pct", Json::Num(dtrain)),
            ]));
        }
        // composed chain+S rows ride AFTER the paper's five methods so
        // positional consumers of the original rows stay valid
        if let Some(density) = cfg.sparse_density {
            let ppm = (density * 1e6).round() as u32;
            for (variant, tag) in
                [(Variant::Lrd, "svd"), (Variant::Tucker2, "tucker2"), (Variant::Cp, "cp")]
            {
                let base = plan_variant(&arch, variant, cfg.alpha, cfg.groups, None)?;
                let plan = sparsify_plan(base, ppm);
                let rep = cost::report(&arch, &plan, 224);
                let fps = if cfg.no_measure {
                    f64::NAN
                } else {
                    let net = BuiltNet::compile(
                        engine, &arch, &plan, cfg.batch, cfg.hw, 1, &cfg.opt,
                    )?;
                    measure_fps(engine, &net, &timer)?
                };
                let dparams = pct_delta(rep.params as f64, rep0.params as f64);
                let dflops = pct_delta(rep.macs as f64, rep0.macs as f64);
                let dinfer = if fps.is_nan() { f64::NAN } else { pct_delta(fps, fps0) };
                rows.push(vec![
                    format!("{tag}+s"),
                    rep.layers.to_string(),
                    fmt_pct(dparams),
                    fmt_pct(dflops),
                    if dinfer.is_nan() { "-".into() } else { fmt_pct(dinfer) },
                    if dinfer.is_nan() { "-".into() } else { fmt_pct(dinfer) },
                ]);
                jrows.push(Json::obj_from(vec![
                    ("arch", Json::Str(arch_name.clone())),
                    ("variant", Json::Str(format!("{tag}+s"))),
                    ("density", Json::Num(density)),
                    ("layers", Json::Num(rep.layers as f64)),
                    ("delta_params_pct", Json::Num(dparams)),
                    ("delta_flops_pct", Json::Num(dflops)),
                    ("delta_infer_pct", Json::Num(dinfer)),
                ]));
            }
        }
    }
    Ok(Report {
        id: "table3".into(),
        title: "acceleration methods vs vanilla LRD (paper Table 3)".into(),
        header: ["Method", "Layers", "ΔParams %", "ΔFLOPs %", "ΔTrain %", "ΔInfer %"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        rows,
        notes: vec![
            format!(
                "infer speed-up measured on {}, {}x{} batch {}",
                engine.platform(),
                cfg.hw,
                cfg.hw,
                cfg.batch
            ),
            "ΔTrain for Layer Freezing adds the frozen-fraction backward saving; for other \
             methods training cost tracks the forward graph (measured end-to-end on the mini \
             models in table456)"
                .into(),
        ],
        json: Json::obj_from(vec![("rows", Json::Arr(jrows))]),
    })
}

/// Fraction of weight parameters frozen by §2.2 in this plan.
pub fn frozen_param_fraction(arch: &Arch, plan: &Plan) -> Result<f64> {
    use crate::decompose::Scheme;
    let mut frozen = 0usize;
    let mut total = 0usize;
    for t in arch.sites() {
        let k2 = t.k * t.k;
        let (scheme, sparse_ppm) =
            plan.get(&t.name).unwrap_or(&Scheme::Orig).split_sparse();
        if let Some(ppm) = sparse_ppm {
            // residual vals + indices are mask-frozen on top of the chain
            let nnz = Scheme::sparse_nnz(t.c, t.s, t.k, ppm);
            frozen += 2 * nnz;
            total += 2 * nnz;
        }
        match scheme {
            Scheme::Orig => total += t.c * t.s * k2,
            Scheme::Svd { r } => {
                total += r * (t.c + t.s);
                frozen += r * t.c; // w0
            }
            Scheme::Tucker { r1, r2 } => {
                total += t.c * r1 + r1 * r2 * k2 + r2 * t.s;
                frozen += t.c * r1 + r2 * t.s; // u and v
            }
            Scheme::Branched { r1, r2, groups } => {
                total += t.c * r1 + (r1 / groups) * (r2 / groups) * k2 * groups + r2 * t.s;
                frozen += t.c * r1 + r2 * t.s;
            }
            Scheme::Tucker2 { r1, r2 } => {
                total += t.c * r1 + r1 * r2 * k2 + r2 * t.s;
                frozen += t.c * r1 + r2 * t.s; // u and v
            }
            Scheme::Cp { r } => {
                if t.k == 1 {
                    total += r * (t.c + t.s);
                    frozen += r * t.c; // u
                } else {
                    total += r * (t.c + t.s + 2 * t.k);
                    frozen += r * (t.c + 2 * t.k); // u, kh, kw
                }
            }
            Scheme::Merged { r1, r2 } => total += r1 * r2 * k2,
            Scheme::MergedInto { .. } => {} // counted via peer's merged cost
            Scheme::Sparse { .. } => unreachable!("split_sparse strips the wrapper"),
        }
    }
    Ok(frozen as f64 / total as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_table3_orders_methods_like_the_paper() {
        let engine = Engine::cpu().unwrap();
        let cfg = Config {
            archs: vec!["resnet152".into()],
            no_measure: true,
            ..Default::default()
        };
        let rep = run(&engine, &cfg).unwrap();
        // rows: header(arch), lrd, opt, freeze, merged, branched
        let dflops: Vec<f64> = rep.rows[1..]
            .iter()
            .map(|r| r[3].parse::<f64>().unwrap())
            .collect();
        let (lrd, merged, branched) = (dflops[0], dflops[3], dflops[4]);
        assert!(lrd < -40.0 && lrd > -55.0, "vanilla LRD ΔFLOPs {lrd}");
        assert!(merged < lrd, "merging must save more than vanilla ({merged} vs {lrd})");
        assert!(branched < lrd, "branching must save more than vanilla");
        // merged restores original depth
        assert_eq!(rep.rows[4][1], "152");
    }

    #[test]
    fn sparse_rows_append_after_the_five_methods() {
        let engine = Engine::cpu().unwrap();
        let cfg = Config {
            archs: vec!["resnet152".into()],
            no_measure: true,
            sparse_density: Some(0.05),
            ..Default::default()
        };
        let rep = run(&engine, &cfg).unwrap();
        // header(arch) + five methods + three composed rows
        assert_eq!(rep.rows.len(), 9);
        assert_eq!(rep.rows[4][1], "152", "positional rows must not shift");
        assert_eq!(rep.rows[6][0], "svd+s");
        assert_eq!(rep.rows[7][0], "tucker2+s");
        assert_eq!(rep.rows[8][0], "cp+s");
        // the residual arm adds params/FLOPs on top of its pure chain
        let pct = |s: &str| s.parse::<f64>().unwrap();
        assert!(pct(&rep.rows[6][3]) > pct(&rep.rows[1][3]), "svd+s must cost more FLOPs");
        assert!(pct(&rep.rows[6][3]) < 0.0, "chain+S must still beat the original");
    }

    #[test]
    fn frozen_fraction_substantial() {
        let arch = Arch::by_name("resnet50").unwrap();
        let plan = plan_variant(&arch, Variant::Freeze, 2.0, 4, None).unwrap();
        let f = frozen_param_fraction(&arch, &plan).unwrap();
        assert!((0.2..0.9).contains(&f), "{f}");
    }
}
