//! Tables 4-6: accuracy + efficiency of the LRD acceleration methods vs a
//! pruning baseline.
//!
//! The ImageNet substitution (DESIGN.md §5): train the mini ResNet from
//! scratch on the synthetic class-grating dataset, one-shot-decompose the
//! *trained* weights per variant, fine-tune each, and evaluate. On a
//! PJRT engine the training/eval units are the python-AOT artifacts; on
//! the native engine the whole protocol runs through the rust-native
//! autograd train step (`train::NativeTrainSession`) — zero artifacts —
//! and the report additionally shows the forward/backward re-merge
//! fusion split that explains each variant's train-step speed. The
//! magnitude filter-pruning baseline runs under the identical protocol
//! (masks re-applied after each step). Paper-quoted rows are printed
//! alongside for the qualitative comparison (sign/ordering of ΔTop-1).

use anyhow::{anyhow, Result};

use super::{fmt_pct, pct_delta, Report};
use crate::baselines::pruning;
use crate::decompose::params::decompose_params;
use crate::decompose::{plan_variant, Variant};
use crate::model::{cost, Arch};
use crate::runtime::artifacts::{ArtifactLibrary, ForwardModel, TrainSession};
use crate::runtime::netbuilder::{BnMode, BuiltNet};
use crate::runtime::{CompileOptions, Engine};
use crate::train::{NativeTrainSession, SgdHyper};
use crate::trainsim::{
    data::SynthData, evaluate, evaluate_built, finetune_variant_native, run_training,
};
use crate::util::json::Json;
use crate::util::rng::Rng;

pub struct Config {
    pub arch: String,
    pub artifacts: std::path::PathBuf,
    pub train_steps: usize,
    pub finetune_steps: usize,
    pub prune_fraction: f64,
    pub seed: u64,
    /// Native-path knobs (the artifact path takes these from the AOT
    /// manifest instead).
    pub batch: usize,
    pub alpha: f64,
    pub groups: usize,
    pub opt: CompileOptions,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            arch: "resnet-mini".into(),
            artifacts: std::path::PathBuf::from("artifacts"),
            train_steps: 250,
            finetune_steps: 120,
            prune_fraction: 0.3,
            seed: 0x7AB1E456,
            batch: 16,
            alpha: 2.0,
            groups: 2,
            opt: CompileOptions::default(),
        }
    }
}

struct MethodResult {
    name: String,
    oneshot_acc: f32,
    final_acc: f32,
    train_secs: f64,
    dflops: f64,
    loss_curve: Vec<(usize, f32)>,
    /// native path: (fwd fusions, bwd fusions) of the train-step graph
    fusions: Option<(usize, usize)>,
    /// pruning rows: achieved weight density after masking (measured on
    /// the tensors, not the requested fraction)
    density: Option<f64>,
}

pub fn run(engine: &Engine, cfg: &Config) -> Result<Report> {
    // The artifact protocol needs a backend that can compile HLO text;
    // the native engine runs the identical protocol through the
    // rust-native autograd training subsystem instead.
    if engine.platform() == "native-cpu" {
        return run_native(engine, cfg);
    }
    let lib = ArtifactLibrary::load(&cfg.artifacts)?;
    let arch = Arch::by_name(&cfg.arch)
        .ok_or_else(|| anyhow!("unknown arch {}", cfg.arch))?;
    let gen = SynthData::new(32, arch.classes);
    let mut rng = Rng::new(cfg.seed);

    // ---- 1. train the original from scratch ----
    let orig_train = lib
        .find_by(&cfg.arch, "orig", "train")
        .ok_or_else(|| anyhow!("missing {}/orig train artifact", cfg.arch))?;
    let mut orig_sess = TrainSession::load(engine, orig_train)?;
    let (orig_curve, orig_secs, _) =
        run_training(&mut orig_sess, &gen, &mut rng, cfg.train_steps, 10)?;
    let trained = orig_sess.export_params()?;
    let orig_fwd_spec = lib
        .find_by(&cfg.arch, "orig", "forward")
        .ok_or_else(|| anyhow!("missing orig forward artifact"))?;
    let orig_fwd = ForwardModel::load_with_params(engine, orig_fwd_spec, &trained)?;
    let mut eval_rng = Rng::new(0xE7A1);
    let orig_acc = evaluate(&orig_fwd, &gen, &mut eval_rng, 25)?;
    let orig_plan = &orig_fwd_spec.plan;
    let orig_macs = cost::count_macs(&arch, orig_plan, 224);

    // ---- 2. decomposition variants ----
    let mut results: Vec<MethodResult> = Vec::new();
    for variant in ["lrd", "freeze", "merged", "branched"] {
        let tspec = lib
            .find_by(&cfg.arch, variant, "train")
            .ok_or_else(|| anyhow!("missing {variant} train artifact"))?;
        // one-shot init: decompose the TRAINED original under this plan
        let init = decompose_params(&arch, &tspec.plan, &trained)?;
        let fwd_variant = if variant == "freeze" { "lrd" } else { variant };
        let fspec = lib
            .find_by(&cfg.arch, fwd_variant, "forward")
            .ok_or_else(|| anyhow!("missing {fwd_variant} forward artifact"))?;
        let oneshot_fwd = ForwardModel::load_with_params(engine, fspec, &init)?;
        let mut er = Rng::new(0xE7A1);
        let oneshot_acc = evaluate(&oneshot_fwd, &gen, &mut er, 25)?;

        let mut sess = TrainSession::load_with_params(engine, tspec, &init)?;
        let (curve, secs, _) =
            run_training(&mut sess, &gen, &mut rng, cfg.finetune_steps, 10)?;
        let tuned = sess.export_params()?;
        let tuned_fwd = ForwardModel::load_with_params(engine, fspec, &tuned)?;
        let mut er = Rng::new(0xE7A1);
        let final_acc = evaluate(&tuned_fwd, &gen, &mut er, 25)?;
        let macs = cost::count_macs(&arch, &tspec.plan, 224);
        results.push(MethodResult {
            name: variant.to_string(),
            oneshot_acc,
            final_acc,
            train_secs: secs,
            dflops: pct_delta(macs as f64, orig_macs as f64),
            loss_curve: curve,
            fusions: None,
            density: None,
        });
    }

    // ---- 3. magnitude-pruning baseline (mask re-applied every step) ----
    {
        let masks = pruning::magnitude_masks(&arch, &trained, cfg.prune_fraction);
        let mut pruned = trained.clone();
        pruning::apply_masks(&mut pruned, &masks);
        let achieved = pruning::density_stats(&pruned, &masks).overall;
        let oneshot_fwd = ForwardModel::load_with_params(engine, orig_fwd_spec, &pruned)?;
        let mut er = Rng::new(0xE7A1);
        let oneshot_acc = evaluate(&oneshot_fwd, &gen, &mut er, 25)?;

        let mut sess = TrainSession::load_with_params(engine, orig_train, &pruned)?;
        let t0 = std::time::Instant::now();
        let mut curve = Vec::new();
        for step in 0..cfg.finetune_steps {
            let (x, y) = gen.batch(&mut rng, sess.spec.batch);
            let (loss, _acc) = sess.step(&x, &y)?;
            sess.apply_channel_masks(&masks)?;
            if step % 10 == 0 {
                curve.push((step, loss));
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        let tuned = sess.export_params()?;
        let tuned_fwd = ForwardModel::load_with_params(engine, orig_fwd_spec, &tuned)?;
        let mut er = Rng::new(0xE7A1);
        let final_acc = evaluate(&tuned_fwd, &gen, &mut er, 25)?;
        results.push(MethodResult {
            name: format!("magnitude-prune {:.0}%", cfg.prune_fraction * 100.0),
            oneshot_acc,
            final_acc,
            train_secs: secs,
            dflops: -pruning::pruned_cost_fraction(cfg.prune_fraction) * 100.0,
            loss_curve: curve,
            fusions: None,
            density: Some(achieved),
        });
    }

    render_report(cfg, orig_acc, orig_secs, orig_curve, results)
}

/// The native-engine protocol: identical experiment, every training and
/// evaluation unit built by `netbuilder` + `runtime::autograd` and run
/// through the planned executor. No python, no artifacts.
fn run_native(engine: &Engine, cfg: &Config) -> Result<Report> {
    let arch = Arch::by_name(&cfg.arch)
        .ok_or_else(|| anyhow!("unknown arch {}", cfg.arch))?;
    let gen = SynthData::new(32, arch.classes);
    let mut rng = Rng::new(cfg.seed);
    // Every accuracy cell in the table comes through this one helper:
    // same eval batch count, same fixed eval seed, same BN semantics.
    const EVAL_BATCHES: usize = 25;
    let eval = |plan: &crate::decompose::Plan,
                params: &crate::decompose::params::Params|
     -> Result<f32> {
        let net = BuiltNet::compile_with_params_mode(
            engine,
            &arch,
            plan,
            cfg.batch,
            gen.hw,
            params,
            &cfg.opt,
            BnMode::BatchStats,
        )?;
        let mut er = Rng::new(0xE7A1);
        evaluate_built(engine, &net, &gen, &mut er, EVAL_BATCHES)
    };

    // ---- 1. train the original from scratch, natively ----
    let orig_plan = plan_variant(&arch, Variant::Orig, cfg.alpha, cfg.groups, None)?;
    let mut orig_sess = NativeTrainSession::new(
        engine,
        &arch,
        &orig_plan,
        cfg.batch,
        gen.hw,
        false,
        &SgdHyper::default(),
        &cfg.opt,
        None,
        cfg.seed,
    )?;
    let (orig_curve, orig_secs, _) =
        run_training(&mut orig_sess, &gen, &mut rng, cfg.train_steps, 10)?;
    let trained = orig_sess.export_params()?;
    let orig_acc = eval(&orig_plan, &trained)?;
    let orig_macs = cost::count_macs(&arch, &orig_plan, 224);

    // ---- 2. decomposition variants ----
    let mut results: Vec<MethodResult> = Vec::new();
    for variant in [Variant::Lrd, Variant::Freeze, Variant::Merged, Variant::Branched] {
        let plan = plan_variant(&arch, variant, cfg.alpha, cfg.groups, None)?;
        let init = decompose_params(&arch, &plan, &trained)?;
        let oneshot_acc = eval(&plan, &init)?;

        let (report, stats) = finetune_variant_native(
            engine,
            &arch,
            variant,
            &plan,
            Some(&init),
            &gen,
            &mut rng,
            cfg.finetune_steps,
            cfg.batch,
            EVAL_BATCHES,
            &cfg.opt,
        )?;
        let macs = cost::count_macs(&arch, &plan, 224);
        results.push(MethodResult {
            name: variant.name().to_string(),
            oneshot_acc,
            final_acc: report.eval_acc,
            train_secs: report.train_secs,
            dflops: pct_delta(macs as f64, orig_macs as f64),
            loss_curve: report.loss_curve,
            fusions: stats.train.as_ref().map(|t| (t.fusions_fwd, t.fusions_bwd)),
            density: None,
        });
    }

    // ---- 3. magnitude-pruning baseline (mask re-applied every step) ----
    {
        let masks = pruning::magnitude_masks(&arch, &trained, cfg.prune_fraction);
        let mut pruned = trained.clone();
        pruning::apply_masks(&mut pruned, &masks);
        let achieved = pruning::density_stats(&pruned, &masks).overall;
        let oneshot_acc = eval(&orig_plan, &pruned)?;

        let mut sess = NativeTrainSession::new(
            engine,
            &arch,
            &orig_plan,
            cfg.batch,
            gen.hw,
            false,
            &SgdHyper::default(),
            &cfg.opt,
            Some(&pruned),
            cfg.seed ^ 0xF00D,
        )?;
        let t0 = std::time::Instant::now();
        let mut curve = Vec::new();
        for step in 0..cfg.finetune_steps {
            let (x, y) = gen.batch(&mut rng, cfg.batch);
            let (loss, _acc) = sess.step(&x, &y)?;
            sess.apply_channel_masks(&masks)?;
            if step % 10 == 0 {
                curve.push((step, loss));
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        let tuned = sess.export_params()?;
        let final_acc = eval(&orig_plan, &tuned)?;
        results.push(MethodResult {
            name: format!("magnitude-prune {:.0}%", cfg.prune_fraction * 100.0),
            oneshot_acc,
            final_acc,
            train_secs: secs,
            dflops: -pruning::pruned_cost_fraction(cfg.prune_fraction) * 100.0,
            loss_curve: curve,
            fusions: None,
            density: Some(achieved),
        });
    }

    render_report(cfg, orig_acc, orig_secs, orig_curve, results)
}

fn render_report(
    cfg: &Config,
    orig_acc: f32,
    orig_secs: f64,
    orig_curve: Vec<(usize, f32)>,
    results: Vec<MethodResult>,
) -> Result<Report> {
    let mut rows = vec![vec![
        "original (trained)".into(),
        format!("{:.1}", orig_acc * 100.0),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{orig_secs:.1}s"),
    ]];
    let mut jrows = Vec::new();
    for r in &results {
        rows.push(vec![
            r.name.clone(),
            format!("{:.1}", r.final_acc * 100.0),
            fmt_pct((r.final_acc - orig_acc) as f64 * 100.0),
            format!("{:.1}", r.oneshot_acc * 100.0),
            fmt_pct(r.dflops),
            format!("{:.1}s", r.train_secs),
        ]);
        let mut fields = vec![
            ("method", Json::Str(r.name.clone())),
            ("final_acc", Json::Num(r.final_acc as f64)),
            ("oneshot_acc", Json::Num(r.oneshot_acc as f64)),
            ("delta_top1", Json::Num((r.final_acc - orig_acc) as f64 * 100.0)),
            ("delta_flops_pct", Json::Num(r.dflops)),
            ("finetune_secs", Json::Num(r.train_secs)),
            (
                "loss_curve",
                Json::Arr(
                    r.loss_curve
                        .iter()
                        .map(|&(s, l)| Json::Arr(vec![Json::Num(s as f64), Json::Num(l as f64)]))
                        .collect(),
                ),
            ),
        ];
        if let Some((fwd, bwd)) = r.fusions {
            fields.push(("remerge_fusions_fwd", Json::Num(fwd as f64)));
            fields.push(("remerge_fusions_bwd", Json::Num(bwd as f64)));
        }
        if let Some(d) = r.density {
            fields.push(("achieved_density", Json::Num(d)));
        }
        jrows.push(Json::obj_from(fields));
    }

    let freeze_secs = results.iter().find(|r| r.name == "freeze").map(|r| r.train_secs);
    let lrd_secs = results.iter().find(|r| r.name == "lrd").map(|r| r.train_secs);
    let mut notes = vec![
        format!(
            "protocol: {} scratch steps on synthetic data, one-shot decompose of the \
             trained weights, {} fine-tune steps per variant (DESIGN.md §5 substitution \
             for ImageNet)",
            cfg.train_steps, cfg.finetune_steps
        ),
        "paper Tables 4-6 quote DCP/CCP/NPPM/... from their papers; the executable \
         comparator here is magnitude filter pruning under the identical protocol"
            .into(),
    ];
    if let (Some(f), Some(l)) = (freeze_secs, lrd_secs) {
        notes.push(format!(
            "measured Layer-Freezing fine-tune speed-up vs full LRD fine-tune: {:+.1}% \
             (paper Table 3: +24.57% on ResNet-50)",
            (l / f - 1.0) * 100.0
        ));
    }
    for r in &results {
        if let Some((fwd, bwd)) = r.fusions {
            notes.push(format!(
                "{}: re-merge fused {fwd} forward / {bwd} backward factor chains in \
                 the native train-step graph (backward fusions are the merged \
                 training scheme — frozen factors unlock them)",
                r.name
            ));
        }
        if let Some(d) = r.density {
            notes.push(format!(
                "{}: achieved weight density {:.1}% after masking (measured on the \
                 tensors; differs from the requested fraction by mask rounding)",
                r.name,
                d * 100.0
            ));
        }
    }
    Ok(Report {
        id: "table456".into(),
        title: format!("accuracy/efficiency after fine-tuning, {} (paper Tables 4-6)", cfg.arch),
        header: ["Method", "Top-1", "ΔTop-1", "One-shot", "ΔFLOPs %", "Fine-tune"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        rows,
        notes,
        json: Json::obj_from(vec![
            ("orig_acc", Json::Num(orig_acc as f64)),
            ("orig_train_secs", Json::Num(orig_secs)),
            (
                "orig_loss_curve",
                Json::Arr(
                    orig_curve
                        .iter()
                        .map(|&(s, l)| Json::Arr(vec![Json::Num(s as f64), Json::Num(l as f64)]))
                        .collect(),
                ),
            ),
            ("rows", Json::Arr(jrows)),
        ]),
    })
}

/// Paper-quoted comparison rows (Tables 4-6) for side-by-side printing.
pub fn paper_quoted_rows() -> Vec<(&'static str, &'static str, &'static str, &'static str)> {
    vec![
        // (table, method, delta_top1, delta_flops)
        ("T4/R50", "DCP", "-1.06", "-55.6"),
        ("T4/R50", "CCP", "-0.94", "-54.1"),
        ("T4/R50", "GBN", "-0.67", "-55.1"),
        ("T4/R50", "LeGR", "-0.40", "-42.0"),
        ("T4/R50", "NPPM", "-0.19", "-56.0"),
        ("T4/R50", "Vanilla LRD", "+0.54", "-43.26"),
        ("T4/R50", "Layer Merging", "-0.21", "-55.09"),
        ("T5/R101", "FPGM", "-0.05", "-41.1"),
        ("T5/R101", "NPPM", "+0.46", "-56.0"),
        ("T5/R101", "Vanilla LRD", "-0.43", "-46.53"),
        ("T5/R101", "Layer Merging", "-0.82", "-58.86"),
        ("T5/R101", "Layer Branching", "-0.70", "0"),
        ("T6/R152", "Layer Freezing", "-0.48", "-47.69"),
        ("T6/R152", "Layer Merging", "-0.44", "-60.18"),
        ("T6/R152", "Layer Branching", "-0.34", "-66.75"),
    ]
}

#[cfg(test)]
mod tests {
    #[test]
    fn quoted_rows_parse_as_numbers() {
        for (_, _, dt, df) in super::paper_quoted_rows() {
            dt.parse::<f64>().unwrap();
            df.parse::<f64>().unwrap();
        }
    }
}
