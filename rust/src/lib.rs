//! # lrdx — Accelerating Low-Rank Decomposed Models
//!
//! Reproduction of Hajimolahoseini et al., *"Accelerating the Low-Rank
//! Decomposed Models"* (2024) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time, python)** — Pallas kernels + JAX ResNet variants,
//!   AOT-lowered to HLO-text artifacts (`python/compile`; regenerate with
//!   `python python/compile/aot.py --out rust/artifacts`).
//! * **L3 (this crate)** — the runtime: a pluggable `runtime::Backend`
//!   (pure-rust `native` interpreter by default, PJRT execution of the AOT
//!   artifacts under `--features xla-pjrt`), a graph-IR layer/network
//!   factory for rank sweeps, reverse-mode autodiff (`runtime::autograd`)
//!   with a fully native training subsystem (`train`), the Algorithm 1
//!   rank optimizer, the serving coordinator, the fine-tuning driver, and
//!   the benchmark harness that regenerates every table/figure of the
//!   paper.
//!
//! Python never runs on the request path: the native backend is fully
//! self-contained, and after the AOT step the PJRT path is too.
//!
//! See `DESIGN.md` (repo root) for the system inventory, the backend
//! trait and the feature matrix.

// Every `unsafe` operation must sit in its own visible `unsafe` block
// with its own `// SAFETY:` obligation — no implicit unsafety inside
// `unsafe fn` bodies. See DESIGN.md §7 for the audit that backs them.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod baselines;
pub mod coordinator;
pub mod decompose;
pub mod harness;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod profiler;
pub mod runtime;
pub mod train;
pub mod trainsim;
pub mod util;
