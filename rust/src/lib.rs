//! # lrdx — Accelerating Low-Rank Decomposed Models
//!
//! Reproduction of Hajimolahoseini et al., *"Accelerating the Low-Rank
//! Decomposed Models"* (2024) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time, python)** — Pallas kernels + JAX ResNet variants,
//!   AOT-lowered to HLO-text artifacts (`python/compile`, `make artifacts`).
//! * **L3 (this crate)** — the runtime: PJRT execution of the artifacts, an
//!   XlaBuilder layer/network factory for rank sweeps, the Algorithm 1 rank
//!   optimizer, the serving coordinator, the fine-tuning driver, and the
//!   benchmark harness that regenerates every table/figure of the paper.
//!
//! Python never runs on the request path: after `make artifacts` the rust
//! binary is self-contained.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod baselines;
pub mod coordinator;
pub mod decompose;
pub mod harness;
pub mod linalg;
pub mod model;
pub mod profiler;
pub mod runtime;
pub mod trainsim;
pub mod util;
