//! CP (canonical polyadic) decomposition of an OIHW conv tensor by
//! alternating least squares — the Lebedev et al. factorization behind the
//! `Scheme::Cp` chain: W[s,c,h,w] ~= sum_r S[s,r] C[c,r] H[h,r] W[w,r].
//!
//! Each mode update solves the normal equations `A_n * G = M` where `M` is
//! the matricized-tensor-times-Khatri-Rao product (computed directly from
//! the dense tensor) and `G` is the Hadamard product of the other modes'
//! Gramians, ridge-regularized for rank-deficient iterates.

use super::{Matrix, Tensor4};
use crate::util::rng::Rng;

/// CP factors, one matrix per mode, each `[dim, r]`.
#[derive(Clone, Debug)]
pub struct CpFactors {
    pub s: Matrix,
    pub c: Matrix,
    pub h: Matrix,
    pub w: Matrix,
}

impl CpFactors {
    pub fn rank(&self) -> usize {
        self.s.cols
    }

    /// Dense reconstruction of the rank-R model.
    pub fn reconstruct(&self, o: usize, i: usize, h: usize, w: usize) -> Tensor4 {
        let r = self.rank();
        let mut out = Tensor4::zeros(o, i, h, w);
        for si in 0..o {
            for ci in 0..i {
                for hi in 0..h {
                    for wi in 0..w {
                        let mut acc = 0.0f32;
                        for j in 0..r {
                            acc += self.s[(si, j)]
                                * self.c[(ci, j)]
                                * self.h[(hi, j)]
                                * self.w[(wi, j)];
                        }
                        *out.at_mut(si, ci, hi, wi) = acc;
                    }
                }
            }
        }
        out
    }

    /// Relative Frobenius reconstruction error against `t`.
    pub fn rel_error(&self, t: &Tensor4) -> f64 {
        let rec = self.reconstruct(t.o, t.i, t.h, t.w);
        let denom = t.fro().max(1e-30);
        t.sub(&rec).fro() / denom
    }

    /// Exact parameter count of the four factor matrices.
    pub fn params(&self) -> usize {
        [&self.s, &self.c, &self.h, &self.w]
            .iter()
            .map(|m| m.rows * m.cols)
            .sum()
    }
}

/// Solve `G * Y = B` for symmetric positive semi-definite `G` [r,r] and
/// `B` [r,n] by Gaussian elimination with partial pivoting, after adding a
/// small ridge proportional to trace(G)/r.
fn solve_ridge(g: &Matrix, b: &Matrix) -> Matrix {
    let r = g.rows;
    assert_eq!(g.cols, r);
    assert_eq!(b.rows, r);
    let n = b.cols;
    let ridge = {
        let tr: f32 = (0..r).map(|i| g[(i, i)]).sum();
        (tr / r.max(1) as f32).abs() * 1e-6 + 1e-12
    };
    let mut a = g.clone();
    for i in 0..r {
        a[(i, i)] += ridge;
    }
    let mut y = b.clone();
    for col in 0..r {
        // partial pivot
        let mut piv = col;
        for row in col + 1..r {
            if a[(row, col)].abs() > a[(piv, col)].abs() {
                piv = row;
            }
        }
        if piv != col {
            for j in 0..r {
                a.data.swap(col * r + j, piv * r + j);
            }
            for j in 0..n {
                y.data.swap(col * n + j, piv * n + j);
            }
        }
        let d = a[(col, col)];
        if d.abs() < 1e-30 {
            continue;
        }
        for row in col + 1..r {
            let f = a[(row, col)] / d;
            if f == 0.0 {
                continue;
            }
            for j in col..r {
                a[(row, j)] -= f * a[(col, j)];
            }
            for j in 0..n {
                y[(row, j)] -= f * y[(col, j)];
            }
        }
    }
    // back substitution
    for col in (0..r).rev() {
        let d = a[(col, col)];
        if d.abs() < 1e-30 {
            continue;
        }
        for j in 0..n {
            let mut acc = y[(col, j)];
            for k in col + 1..r {
                acc -= a[(col, k)] * y[(k, j)];
            }
            y[(col, j)] = acc / d;
        }
    }
    y
}

fn gram(m: &Matrix) -> Matrix {
    m.transpose().matmul(m)
}

fn hadamard3(a: &Matrix, b: &Matrix, c: &Matrix) -> Matrix {
    let mut out = a.clone();
    for ((o, &bv), &cv) in out.data.iter_mut().zip(&b.data).zip(&c.data) {
        *o *= bv * cv;
    }
    out
}

/// MTTKRP for one mode computed directly from the dense tensor:
/// `m[i_mode, r] = sum_{others} W[s,c,h,w] * prod_{other modes} A[idx, r]`.
fn mttkrp(t: &Tensor4, f: &CpFactors, mode: usize) -> Matrix {
    let r = f.rank();
    let dim = [t.o, t.i, t.h, t.w][mode];
    let mut out = Matrix::zeros(dim, r);
    let mut prod = vec![0.0f32; r];
    for si in 0..t.o {
        for ci in 0..t.i {
            for hi in 0..t.h {
                for wi in 0..t.w {
                    let x = t.at(si, ci, hi, wi);
                    if x == 0.0 {
                        continue;
                    }
                    let row = match mode {
                        0 => si,
                        1 => ci,
                        2 => hi,
                        _ => wi,
                    };
                    for (j, p) in prod.iter_mut().enumerate() {
                        let mut v = x;
                        if mode != 0 {
                            v *= f.s[(si, j)];
                        }
                        if mode != 1 {
                            v *= f.c[(ci, j)];
                        }
                        if mode != 2 {
                            v *= f.h[(hi, j)];
                        }
                        if mode != 3 {
                            v *= f.w[(wi, j)];
                        }
                        *p = v;
                    }
                    for (j, p) in prod.iter().enumerate() {
                        out[(row, j)] += *p;
                    }
                }
            }
        }
    }
    out
}

fn normalize_cols(m: &mut Matrix) {
    for j in 0..m.cols {
        let mut n = 0.0f64;
        for i in 0..m.rows {
            n += (m[(i, j)] as f64) * (m[(i, j)] as f64);
        }
        let n = n.sqrt() as f32;
        if n > 1e-20 {
            for i in 0..m.rows {
                m[(i, j)] /= n;
            }
        }
    }
}

/// Rank-`r` CP-ALS with `sweeps` full passes. Deterministic: the random
/// init is seeded from the tensor shape and rank.
pub fn cp_als(t: &Tensor4, r: usize, sweeps: usize) -> CpFactors {
    assert!(r >= 1, "cp rank must be positive");
    let mut rng =
        Rng::new(0xC9_A15 ^ ((t.o as u64) << 32) ^ ((t.i as u64) << 16) ^ r as u64);
    let init = |rows: usize, rng: &mut Rng| {
        let mut m = Matrix::from_fn(rows, r, |_, _| rng.normal_f32());
        normalize_cols(&mut m);
        m
    };
    let mut f = CpFactors {
        s: init(t.o, &mut rng),
        c: init(t.i, &mut rng),
        h: init(t.h, &mut rng),
        w: init(t.w, &mut rng),
    };
    for _ in 0..sweeps.max(1) {
        // modes c, h, w carry unit columns; the final s update absorbs scale
        for mode in [1usize, 2, 3, 0] {
            let m = mttkrp(t, &f, mode);
            let g = match mode {
                0 => hadamard3(&gram(&f.c), &gram(&f.h), &gram(&f.w)),
                1 => hadamard3(&gram(&f.s), &gram(&f.h), &gram(&f.w)),
                2 => hadamard3(&gram(&f.s), &gram(&f.c), &gram(&f.w)),
                _ => hadamard3(&gram(&f.s), &gram(&f.c), &gram(&f.h)),
            };
            // A_n = M * G^{-1}  <=>  G * A_n^T = M^T (G symmetric)
            let a = solve_ridge(&g, &m.transpose()).transpose();
            match mode {
                0 => f.s = a,
                1 => {
                    f.c = a;
                    normalize_cols(&mut f.c);
                }
                2 => {
                    f.h = a;
                    normalize_cols(&mut f.h);
                }
                _ => {
                    f.w = a;
                    normalize_cols(&mut f.w);
                }
            }
        }
    }
    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::assert_allclose;

    fn planted(o: usize, i: usize, k: usize, r: usize, seed: u64) -> Tensor4 {
        let mut rng = Rng::new(seed);
        let f = CpFactors {
            s: Matrix::from_fn(o, r, |_, _| rng.normal_f32()),
            c: Matrix::from_fn(i, r, |_, _| rng.normal_f32()),
            h: Matrix::from_fn(k, r, |_, _| rng.normal_f32()),
            w: Matrix::from_fn(k, r, |_, _| rng.normal_f32()),
        };
        f.reconstruct(o, i, k, k)
    }

    #[test]
    fn planted_rank_recovered() {
        let t = planted(12, 10, 3, 3, 0x11);
        let f = cp_als(&t, 3, 40);
        assert!(
            f.rel_error(&t) < 1e-2,
            "planted rank-3 not recovered: rel err {}",
            f.rel_error(&t)
        );
    }

    #[test]
    fn error_decreases_with_sweeps() {
        let mut rng = Rng::new(0x22);
        let t = Tensor4::random(8, 8, 3, 3, &mut rng);
        let e1 = cp_als(&t, 6, 1).rel_error(&t);
        let e5 = cp_als(&t, 6, 8).rel_error(&t);
        assert!(e5 <= e1 + 1e-6, "ALS regressed: {e5} after 8 vs {e1} after 1");
    }

    #[test]
    fn shapes_and_params() {
        let t = planted(6, 5, 3, 2, 0x33);
        let f = cp_als(&t, 4, 2);
        assert_eq!((f.s.rows, f.s.cols), (6, 4));
        assert_eq!((f.c.rows, f.c.cols), (5, 4));
        assert_eq!((f.h.rows, f.h.cols), (3, 4));
        assert_eq!((f.w.rows, f.w.cols), (3, 4));
        assert_eq!(f.params(), 4 * (6 + 5 + 3 + 3));
    }

    #[test]
    fn full_reconstruction_on_separable_tensor() {
        // a rank-1 tensor is reproduced essentially exactly
        let t = planted(5, 4, 3, 1, 0x44);
        let f = cp_als(&t, 1, 25);
        let rec = f.reconstruct(5, 4, 3, 3);
        assert_allclose(&rec.data, &t.data, 1e-2, 1e-2);
    }
}
