//! Dense linear-algebra substrate, built from scratch (no LA crate offline):
//! row-major matrices, QR, one-sided Jacobi SVD and Tucker-2 HOSVD over
//! OIHW tensors. Sized for the paper's layers (up to 2048 x 512 factors).

pub mod cp;
pub mod qr;
pub mod svd;
pub mod tensor4;
pub mod tucker;

pub use cp::{cp_als, CpFactors};
pub use qr::qr;
pub use svd::{svd, Svd};
pub use tensor4::Tensor4;
pub use tucker::{tucker2, Tucker2};

/// Row-major dense f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f32) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn random(rows: usize, cols: usize, rng: &mut crate::util::rng::Rng) -> Matrix {
        Matrix {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.normal_f32()).collect(),
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// `self @ other`, blocked over rows; f64 accumulation.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let arow = self.row(i);
            let orow = &mut out.data[i * n..(i + 1) * n];
            for (p, &a) in arow.iter().enumerate().take(k) {
                if a == 0.0 {
                    continue;
                }
                let brow = other.row(p);
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    pub fn scale(&self, s: f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Keep the leading `r` columns.
    pub fn take_cols(&self, r: usize) -> Matrix {
        assert!(r <= self.cols);
        Matrix::from_fn(self.rows, r, |i, j| self[(i, j)])
    }

    /// Columns `lo..hi`.
    pub fn col_block(&self, lo: usize, hi: usize) -> Matrix {
        assert!(lo <= hi && hi <= self.cols);
        Matrix::from_fn(self.rows, hi - lo, |i, j| self[(i, lo + j)])
    }

    /// Rows `lo..hi`.
    pub fn row_block(&self, lo: usize, hi: usize) -> Matrix {
        assert!(lo <= hi && hi <= self.rows);
        Matrix::from_fn(hi - lo, self.cols, |i, j| self[(lo + i, j)])
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::assert_allclose;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Matrix::random(5, 7, &mut rng);
        let i = Matrix::eye(7);
        assert_allclose(&a.matmul(&i).data, &a.data, 1e-6, 1e-7);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.matmul(&b).data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = Matrix::random(3, 8, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matmul_transpose_property() {
        crate::util::check::property(10, |rng| {
            let (m, k, n) = (rng.range(1, 6), rng.range(1, 6), rng.range(1, 6));
            let a = Matrix::random(m, k, rng);
            let b = Matrix::random(k, n, rng);
            let ab_t = a.matmul(&b).transpose();
            let bt_at = b.transpose().matmul(&a.transpose());
            assert_allclose(&ab_t.data, &bt_at.data, 1e-5, 1e-6);
        });
    }

    #[test]
    fn blocks() {
        let a = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f32);
        assert_eq!(a.col_block(1, 3).data, vec![1., 2., 5., 6., 9., 10., 13., 14.]);
        assert_eq!(a.row_block(2, 3).data, vec![8., 9., 10., 11.]);
        assert_eq!(a.take_cols(1).data, vec![0., 4., 8., 12.]);
    }

    #[test]
    fn fro_norm() {
        let a = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.fro() - 5.0).abs() < 1e-12);
    }
}
