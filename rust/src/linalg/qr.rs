//! Householder QR decomposition (used by tests as an orthogonality oracle
//! and by the pruning baseline's subspace analysis).

use super::Matrix;

/// Reduced QR: `a = q @ r` with `q`: [m, k], `r`: [k, n], k = min(m, n).
pub fn qr(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = (a.rows, a.cols);
    let k = m.min(n);
    // Work in f64 for stability.
    let mut r: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut q: Vec<f64> = vec![0.0; m * m];
    for i in 0..m {
        q[i * m + i] = 1.0;
    }
    let idx = |i: usize, j: usize, cols: usize| i * cols + j;

    for col in 0..k {
        // Householder vector for column `col` below the diagonal.
        let mut norm = 0.0;
        for i in col..m {
            norm += r[idx(i, col, n)] * r[idx(i, col, n)];
        }
        let norm = norm.sqrt();
        if norm < 1e-300 {
            continue;
        }
        let alpha = if r[idx(col, col, n)] >= 0.0 { -norm } else { norm };
        let mut v = vec![0.0; m];
        for i in col..m {
            v[i] = r[idx(i, col, n)];
        }
        v[col] -= alpha;
        let vnorm2: f64 = v[col..].iter().map(|x| x * x).sum();
        if vnorm2 < 1e-300 {
            continue;
        }
        // R <- (I - 2 v v^T / |v|^2) R
        for j in col..n {
            let dot: f64 = (col..m).map(|i| v[i] * r[idx(i, j, n)]).sum();
            let s = 2.0 * dot / vnorm2;
            for i in col..m {
                r[idx(i, j, n)] -= s * v[i];
            }
        }
        // Q <- Q (I - 2 v v^T / |v|^2)
        for i in 0..m {
            let dot: f64 = (col..m).map(|j| q[idx(i, j, m)] * v[j]).sum();
            let s = 2.0 * dot / vnorm2;
            for j in col..m {
                q[idx(i, j, m)] -= s * v[j];
            }
        }
    }

    let qk = Matrix::from_fn(m, k, |i, j| q[idx(i, j, m)] as f32);
    let rk = Matrix::from_fn(k, n, |i, j| if i <= j { r[idx(i, j, n)] as f32 } else { 0.0 });
    (qk, rk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_allclose, property};

    #[test]
    fn reconstructs() {
        property(10, |rng| {
            let (m, n) = (rng.range(1, 10), rng.range(1, 10));
            let a = Matrix::random(m, n, rng);
            let (q, r) = qr(&a);
            assert_allclose(&q.matmul(&r).data, &a.data, 1e-4, 1e-4);
        });
    }

    #[test]
    fn q_orthonormal() {
        property(10, |rng| {
            let (m, n) = (rng.range(2, 10), rng.range(1, 8));
            let a = Matrix::random(m, n, rng);
            let (q, _r) = qr(&a);
            let qtq = q.transpose().matmul(&q);
            let eye = Matrix::eye(q.cols);
            assert_allclose(&qtq.data, &eye.data, 1e-4, 1e-4);
        });
    }

    #[test]
    fn r_upper_triangular() {
        let mut rng = crate::util::rng::Rng::new(9);
        let a = Matrix::random(6, 4, &mut rng);
        let (_q, r) = qr(&a);
        for i in 0..r.rows {
            for j in 0..i.min(r.cols) {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }
}
