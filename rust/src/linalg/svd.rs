//! One-sided Jacobi SVD (Hestenes), f64 internally.
//!
//! The decomposition engine behind eq. (1)-(3): thin SVD `a = u @ diag(s) @ vt`
//! with singular values sorted descending. One-sided Jacobi is simple,
//! numerically robust, and fast enough for the paper's largest factor
//! (2048 x 512) — it is the same family of algorithm LAPACK uses for
//! high-accuracy SVD (xGEJSV).

use super::Matrix;

#[derive(Clone, Debug)]
pub struct Svd {
    /// [m, k] left singular vectors (k = min(m, n))
    pub u: Matrix,
    /// k singular values, descending
    pub s: Vec<f32>,
    /// [k, n] right singular vectors, transposed
    pub vt: Matrix,
}

/// Thin SVD via one-sided Jacobi. Orthogonalises the columns of A by plane
/// rotations; converged column norms are the singular values.
pub fn svd(a: &Matrix) -> Svd {
    if a.rows < a.cols {
        // Work on the transpose and swap factors.
        let t = svd(&a.transpose());
        return Svd { u: t.vt.transpose(), s: t.s, vt: t.u.transpose() };
    }
    let (m, n) = (a.rows, a.cols);
    // Column-major working copy in f64: cols[j][i]
    let mut cols: Vec<Vec<f64>> = (0..n)
        .map(|j| (0..m).map(|i| a[(i, j)] as f64).collect())
        .collect();
    let mut v = vec![vec![0.0f64; n]; n];
    for (j, row) in v.iter_mut().enumerate() {
        row[j] = 1.0;
    }

    let eps = 1e-12;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for i in 0..m {
                    app += cols[p][i] * cols[p][i];
                    aqq += cols[q][i] * cols[q][i];
                    apq += cols[p][i] * cols[q][i];
                }
                if apq.abs() <= eps * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let (xp, xq) = (cols[p][i], cols[q][i]);
                    cols[p][i] = c * xp - s * xq;
                    cols[q][i] = s * xp + c * xq;
                }
                for row in v.iter_mut() {
                    let (vp, vq) = (row[p], row[q]);
                    row[p] = c * vp - s * vq;
                    row[q] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-14 {
            break;
        }
    }

    // Singular values = column norms; sort descending.
    let mut sv: Vec<(f64, usize)> = (0..n)
        .map(|j| ((0..m).map(|i| cols[j][i] * cols[j][i]).sum::<f64>().sqrt(), j))
        .collect();
    sv.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let k = n; // thin: m >= n here
    let mut u = Matrix::zeros(m, k);
    let mut s_out = Vec::with_capacity(k);
    let mut vt = Matrix::zeros(k, n);
    for (rank, &(sval, j)) in sv.iter().enumerate() {
        s_out.push(sval as f32);
        let inv = if sval > 1e-300 { 1.0 / sval } else { 0.0 };
        for i in 0..m {
            u[(i, rank)] = (cols[j][i] * inv) as f32;
        }
        for (i, row) in v.iter().enumerate() {
            vt[(rank, i)] = row[j] as f32;
        }
    }
    Svd { u, s: s_out, vt }
}

impl Svd {
    /// Reconstruct with the leading `r` components (eq. 2).
    pub fn reconstruct(&self, r: usize) -> Matrix {
        let r = r.min(self.s.len());
        let mut us = self.u.take_cols(r);
        for i in 0..us.rows {
            for j in 0..r {
                us[(i, j)] *= self.s[j];
            }
        }
        us.matmul(&self.vt.row_block(0, r))
    }

    /// The paper's eq. (3) split: `w ~= w1 @ w0` with each factor absorbing
    /// `sqrt(sigma)`. Input convention matches python `decompose.py`:
    /// `self` decomposes an [S, C] weight; returns (w0: [R, C], w1: [S, R]).
    pub fn split(&self, r: usize) -> (Matrix, Matrix) {
        let r = r.min(self.s.len());
        let mut w1 = self.u.take_cols(r); // [S, R]
        let mut w0 = self.vt.row_block(0, r); // [R, C]
        for j in 0..r {
            let sq = self.s[j].max(0.0).sqrt();
            for i in 0..w1.rows {
                w1[(i, j)] *= sq;
            }
            for c in 0..w0.cols {
                w0[(j, c)] *= sq;
            }
        }
        (w0, w1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_allclose, property};
    use crate::util::rng::Rng;

    #[test]
    fn reconstructs_full_rank() {
        property(8, |rng| {
            let (m, n) = (rng.range(1, 12), rng.range(1, 12));
            let a = Matrix::random(m, n, rng);
            let d = svd(&a);
            let r = m.min(n);
            assert_allclose(&d.reconstruct(r).data, &a.data, 1e-4, 1e-4);
        });
    }

    #[test]
    fn singular_values_descending_nonnegative() {
        property(8, |rng| {
            let a = Matrix::random(rng.range(2, 10), rng.range(2, 10), rng);
            let d = svd(&a);
            for w in d.s.windows(2) {
                assert!(w[0] >= w[1] - 1e-6);
            }
            assert!(d.s.iter().all(|&x| x >= 0.0));
        });
    }

    #[test]
    fn u_and_v_orthonormal() {
        let mut rng = Rng::new(3);
        let a = Matrix::random(10, 6, &mut rng);
        let d = svd(&a);
        let utu = d.u.transpose().matmul(&d.u);
        assert_allclose(&utu.data, &Matrix::eye(6).data, 1e-4, 1e-4);
        let vvt = d.vt.matmul(&d.vt.transpose());
        assert_allclose(&vvt.data, &Matrix::eye(6).data, 1e-4, 1e-4);
    }

    #[test]
    fn truncation_error_equals_tail_energy() {
        // ||A - A_r||_F^2 == sum of squared trailing singular values
        let mut rng = Rng::new(5);
        let a = Matrix::random(8, 8, &mut rng);
        let d = svd(&a);
        for r in [2usize, 4, 6] {
            let err = a.sub(&d.reconstruct(r)).fro();
            let tail: f64 = d.s[r..].iter().map(|&x| (x as f64) * (x as f64)).sum();
            assert!((err - tail.sqrt()).abs() < 1e-3, "r={r}: {err} vs {}", tail.sqrt());
        }
    }

    #[test]
    fn split_matches_reconstruct() {
        let mut rng = Rng::new(7);
        let a = Matrix::random(9, 5, &mut rng);
        let d = svd(&a);
        let (w0, w1) = d.split(3);
        assert_eq!(w0.rows, 3);
        assert_eq!(w1.cols, 3);
        assert_allclose(&w1.matmul(&w0).data, &d.reconstruct(3).data, 1e-4, 1e-4);
    }

    #[test]
    fn wide_matrix_handled_by_transpose() {
        let mut rng = Rng::new(8);
        let a = Matrix::random(4, 11, &mut rng);
        let d = svd(&a);
        assert_eq!(d.u.rows, 4);
        assert_eq!(d.vt.cols, 11);
        assert_allclose(&d.reconstruct(4).data, &a.data, 1e-4, 1e-4);
    }

    #[test]
    fn rank_one_matrix() {
        let u = Matrix::from_vec(3, 1, vec![1.0, 2.0, 3.0]);
        let v = Matrix::from_vec(1, 2, vec![4.0, 5.0]);
        let a = u.matmul(&v);
        let d = svd(&a);
        assert!(d.s[0] > 1.0);
        assert!(d.s[1] < 1e-5);
        assert_allclose(&d.reconstruct(1).data, &a.data, 1e-4, 1e-4);
    }
}
