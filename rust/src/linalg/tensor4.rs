//! OIHW 4-D tensor with the mode unfoldings Tucker-2 needs.

use super::Matrix;

/// Conv weight tensor, OIHW layout: `[o, i, h, w]` = `[S, C, k, k]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor4 {
    pub o: usize,
    pub i: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<f32>,
}

impl Tensor4 {
    pub fn zeros(o: usize, i: usize, h: usize, w: usize) -> Tensor4 {
        Tensor4 { o, i, h, w, data: vec![0.0; o * i * h * w] }
    }

    pub fn from_vec(o: usize, i: usize, h: usize, w: usize, data: Vec<f32>) -> Tensor4 {
        assert_eq!(o * i * h * w, data.len());
        Tensor4 { o, i, h, w, data }
    }

    pub fn random(
        o: usize,
        i: usize,
        h: usize,
        w: usize,
        rng: &mut crate::util::rng::Rng,
    ) -> Tensor4 {
        Tensor4 { o, i, h, w, data: (0..o * i * h * w).map(|_| rng.normal_f32()).collect() }
    }

    #[inline]
    pub fn at(&self, o: usize, i: usize, h: usize, w: usize) -> f32 {
        self.data[((o * self.i + i) * self.h + h) * self.w + w]
    }

    #[inline]
    pub fn at_mut(&mut self, o: usize, i: usize, h: usize, w: usize) -> &mut f32 {
        &mut self.data[((o * self.i + i) * self.h + h) * self.w + w]
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Mode-O ("output channel" / paper's S-mode) unfolding: [O, I*h*w].
    /// Rows are output channels — this is just the natural layout.
    pub fn unfold_o(&self) -> Matrix {
        Matrix::from_vec(self.o, self.i * self.h * self.w, self.data.clone())
    }

    /// Mode-I ("input channel" / paper's C-mode) unfolding: [I, O*h*w].
    pub fn unfold_i(&self) -> Matrix {
        let mut m = Matrix::zeros(self.i, self.o * self.h * self.w);
        for o in 0..self.o {
            for i in 0..self.i {
                for h in 0..self.h {
                    for w in 0..self.w {
                        m[(i, (o * self.h + h) * self.w + w)] = self.at(o, i, h, w);
                    }
                }
            }
        }
        m
    }

    /// Inverse of `unfold_o`: rebuild `[o, i, h, w]` from an `[o, i*h*w]`
    /// matrix (the natural layout, so this is a reshape).
    pub fn fold_o(m: &Matrix, i: usize, h: usize, w: usize) -> Tensor4 {
        assert_eq!(m.cols, i * h * w, "fold_o: {} cols != {i}*{h}*{w}", m.cols);
        Tensor4 { o: m.rows, i, h, w, data: m.data.clone() }
    }

    /// Inverse of `unfold_i`: rebuild `[o, i, h, w]` from an `[i, o*h*w]`
    /// matrix whose columns are ordered `(o, h, w)`.
    pub fn fold_i(m: &Matrix, o: usize, h: usize, w: usize) -> Tensor4 {
        assert_eq!(m.cols, o * h * w, "fold_i: {} cols != {o}*{h}*{w}", m.cols);
        let i = m.rows;
        let mut t = Tensor4::zeros(o, i, h, w);
        for oi in 0..o {
            for ii in 0..i {
                for hi in 0..h {
                    for wi in 0..w {
                        *t.at_mut(oi, ii, hi, wi) = m[(ii, (oi * h + hi) * w + wi)];
                    }
                }
            }
        }
        t
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn sub(&self, other: &Tensor4) -> Tensor4 {
        assert_eq!(
            (self.o, self.i, self.h, self.w),
            (other.o, other.i, other.h, other.w)
        );
        Tensor4 {
            o: self.o,
            i: self.i,
            h: self.h,
            w: self.w,
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    /// L2 norm of one output-channel filter (used by the pruning baseline).
    pub fn filter_norm(&self, o: usize) -> f64 {
        let span = self.i * self.h * self.w;
        self.data[o * span..(o + 1) * span]
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn unfold_o_layout() {
        let t = Tensor4::from_vec(2, 1, 1, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let m = t.unfold_o();
        assert_eq!(m.row(0), &[1.0, 2.0]);
        assert_eq!(m.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn unfold_i_layout() {
        // o=2, i=2, 1x1: W[o][i] = o*2+i
        let t = Tensor4::from_vec(2, 2, 1, 1, vec![0.0, 1.0, 2.0, 3.0]);
        let m = t.unfold_i();
        assert_eq!(m.row(0), &[0.0, 2.0]); // input channel 0 across outputs
        assert_eq!(m.row(1), &[1.0, 3.0]);
    }

    #[test]
    fn unfold_fold_roundtrips() {
        let mut rng = Rng::new(7);
        let t = Tensor4::random(4, 3, 2, 5, &mut rng);
        assert_eq!(Tensor4::fold_o(&t.unfold_o(), t.i, t.h, t.w), t);
        assert_eq!(Tensor4::fold_i(&t.unfold_i(), t.o, t.h, t.w), t);
    }

    #[test]
    fn unfoldings_preserve_norm() {
        let mut rng = Rng::new(2);
        let t = Tensor4::random(3, 4, 3, 3, &mut rng);
        assert!((t.unfold_o().fro() - t.fro()).abs() < 1e-9);
        assert!((t.unfold_i().fro() - t.fro()).abs() < 1e-9);
    }

    #[test]
    fn filter_norm_matches_manual() {
        let t = Tensor4::from_vec(2, 1, 1, 2, vec![3.0, 4.0, 1.0, 0.0]);
        assert!((t.filter_norm(0) - 5.0).abs() < 1e-12);
        assert!((t.filter_norm(1) - 1.0).abs() < 1e-12);
    }
}
