//! Tucker-2 HOSVD over the two channel modes of an OIHW conv tensor
//! (paper eq. 4-6), mirroring `python/compile/decompose.py` exactly.

use super::{svd, Matrix, Tensor4};

/// Tucker-2 factors in the Fig. 1b stack convention:
/// `u`: [r1, C] (first 1x1), `core`: [r2, r1, k, k], `v`: [S, r2] (last 1x1).
#[derive(Clone, Debug)]
pub struct Tucker2 {
    pub u: Matrix,
    pub core: Tensor4,
    pub v: Matrix,
}

/// HOSVD: mode factors from the unfoldings' left singular vectors, core by
/// contracting both factors into the weight.
pub fn tucker2(w: &Tensor4, r1: usize, r2: usize) -> Tucker2 {
    let (s_ch, c_ch, kh, kw) = (w.o, w.i, w.h, w.w);
    assert!(r1 >= 1 && r1 <= c_ch, "r1={r1} out of range (C={c_ch})");
    assert!(r2 >= 1 && r2 <= s_ch, "r2={r2} out of range (S={s_ch})");
    // U_c: [C, r1] from mode-I unfolding; U_s: [S, r2] from mode-O unfolding.
    let uc = svd(&w.unfold_i()).u.take_cols(r1);
    let us = svd(&w.unfold_o()).u.take_cols(r2);
    // core[j, i, h, w] = sum_{s,c} W[s,c,h,w] * uc[c,i] * us[s,j]
    // two-step contraction for O(S*C*k^2*(r1 + r2)) work:
    //   tmp[s, i, h, w] = sum_c W[s,c,h,w] uc[c,i]
    let mut tmp = Tensor4::zeros(s_ch, r1, kh, kw);
    for s in 0..s_ch {
        for c in 0..c_ch {
            for h in 0..kh {
                for w_ in 0..kw {
                    let x = w.at(s, c, h, w_);
                    if x == 0.0 {
                        continue;
                    }
                    for i in 0..r1 {
                        *tmp.at_mut(s, i, h, w_) += x * uc[(c, i)];
                    }
                }
            }
        }
    }
    let mut core = Tensor4::zeros(r2, r1, kh, kw);
    for s in 0..s_ch {
        for i in 0..r1 {
            for h in 0..kh {
                for w_ in 0..kw {
                    let x = tmp.at(s, i, h, w_);
                    if x == 0.0 {
                        continue;
                    }
                    for j in 0..r2 {
                        *core.at_mut(j, i, h, w_) += x * us[(s, j)];
                    }
                }
            }
        }
    }
    Tucker2 { u: uc.transpose(), core, v: us }
}

impl Tucker2 {
    /// Reconstruct W' = core x_C U x_S V (inverse of `tucker2`).
    pub fn reconstruct(&self) -> Tensor4 {
        let (r2, r1, kh, kw) = (self.core.o, self.core.i, self.core.h, self.core.w);
        let c_ch = self.u.cols;
        let s_ch = self.v.rows;
        // tmp[j, c, h, w] = sum_i core[j,i,h,w] u[i,c]
        let mut tmp = Tensor4::zeros(r2, c_ch, kh, kw);
        for j in 0..r2 {
            for i in 0..r1 {
                for h in 0..kh {
                    for w_ in 0..kw {
                        let x = self.core.at(j, i, h, w_);
                        if x == 0.0 {
                            continue;
                        }
                        for c in 0..c_ch {
                            *tmp.at_mut(j, c, h, w_) += x * self.u[(i, c)];
                        }
                    }
                }
            }
        }
        let mut out = Tensor4::zeros(s_ch, c_ch, kh, kw);
        for j in 0..r2 {
            for c in 0..c_ch {
                for h in 0..kh {
                    for w_ in 0..kw {
                        let x = tmp.at(j, c, h, w_);
                        if x == 0.0 {
                            continue;
                        }
                        for s in 0..s_ch {
                            *out.at_mut(s, c, h, w_) += x * self.v[(s, j)];
                        }
                    }
                }
            }
        }
        out
    }

    /// Parameter count of the decomposed stack (Fig. 1b).
    pub fn params(&self) -> usize {
        self.u.rows * self.u.cols + self.core.numel() + self.v.rows * self.v.cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_allclose, property};
    use crate::util::rng::Rng;

    #[test]
    fn full_rank_exact() {
        let mut rng = Rng::new(0);
        let w = Tensor4::random(6, 5, 3, 3, &mut rng);
        let t = tucker2(&w, 5, 6);
        assert_allclose(&t.reconstruct().data, &w.data, 1e-3, 1e-3);
    }

    #[test]
    fn shapes() {
        let mut rng = Rng::new(1);
        let w = Tensor4::random(12, 8, 3, 3, &mut rng);
        let t = tucker2(&w, 3, 5);
        assert_eq!((t.u.rows, t.u.cols), (3, 8));
        assert_eq!((t.core.o, t.core.i, t.core.h, t.core.w), (5, 3, 3, 3));
        assert_eq!((t.v.rows, t.v.cols), (12, 5));
    }

    #[test]
    fn error_monotone_in_rank() {
        let mut rng = Rng::new(2);
        let w = Tensor4::random(8, 8, 3, 3, &mut rng);
        let mut prev = f64::INFINITY;
        for r in [2usize, 4, 6, 8] {
            let t = tucker2(&w, r, r);
            let err = w.sub(&t.reconstruct()).fro();
            assert!(err <= prev + 1e-6, "rank {r}: {err} > {prev}");
            prev = err;
        }
    }

    #[test]
    fn factors_orthonormal() {
        property(5, |rng| {
            let w = Tensor4::random(rng.range(4, 8), rng.range(4, 8), 3, 3, rng);
            let r1 = rng.range(1, w.i);
            let r2 = rng.range(1, w.o);
            let t = tucker2(&w, r1, r2);
            // u [r1, C]: rows orthonormal; v [S, r2]: cols orthonormal
            let uut = t.u.matmul(&t.u.transpose());
            assert_allclose(&uut.data, &Matrix::eye(r1).data, 1e-3, 1e-3);
            let vtv = t.v.transpose().matmul(&t.v);
            assert_allclose(&vtv.data, &Matrix::eye(r2).data, 1e-3, 1e-3);
        });
    }

    #[test]
    fn property_full_rank_round_trip() {
        // rank sweep endpoint: at (C, S) the projection is exact for any
        // random tensor and kernel size
        property(4, |rng| {
            let k = rng.range(1, 3);
            let w = Tensor4::random(rng.range(3, 7), rng.range(3, 7), k, k, rng);
            let t = tucker2(&w, w.i, w.o);
            assert_allclose(&t.reconstruct().data, &w.data, 1e-3, 1e-3);
        });
    }

    #[test]
    fn property_error_bounded_by_truncated_spectra() {
        // HOSVD projection bound: ||W - W_hat||^2 <= tail_I^2 + tail_O^2,
        // the truncated singular-value tails of the two mode unfoldings
        property(4, |rng| {
            let w = Tensor4::random(rng.range(4, 9), rng.range(4, 9), 3, 3, rng);
            let si = svd(&w.unfold_i()).s;
            let so = svd(&w.unfold_o()).s;
            let r1 = rng.range(1, w.i);
            let r2 = rng.range(1, w.o);
            let t = tucker2(&w, r1, r2);
            let err = w.sub(&t.reconstruct()).fro();
            let tail: f64 = si[r1..]
                .iter()
                .chain(so[r2..].iter())
                .map(|&x| (x as f64) * (x as f64))
                .sum();
            assert!(
                err * err <= tail * 1.05 + 1e-6,
                "({},{})@({r1},{r2}): err^2 {} > spectral tail {}",
                w.o,
                w.i,
                err * err,
                tail
            );
        });
    }

    #[test]
    fn params_formula() {
        let mut rng = Rng::new(4);
        let w = Tensor4::random(16, 8, 3, 3, &mut rng);
        let t = tucker2(&w, 4, 6);
        assert_eq!(t.params(), 4 * 8 + 6 * 4 * 9 + 16 * 6);
    }
}
