//! `lrdx` — leader entrypoint / CLI for the LRD acceleration stack.
//!
//! ```text
//! lrdx info                             runtime + artifact inventory
//! lrdx cost   --arch resnet50 ...       analytic cost report per variant
//! lrdx plan   --arch resnet50 --variant merged --out plan.json
//! lrdx rank-search --arch resnet50 [--real] [--out plan.json]
//! lrdx verify                           run every artifact vs recorded numerics
//! lrdx train  --variant freeze --steps 200
//! lrdx serve  --arch resnet-mini --variants orig,lrd --requests 64
//! lrdx bench  table1|table2|table3|table456|fig2|fig5 [flags]
//! ```
//!
//! Common flags: `--artifacts DIR` (default ./artifacts), `--reports DIR`
//! (default ./reports), `--hw`, `--batch`, `--alpha`, `--groups`.

use anyhow::{anyhow, bail, Result};
use lrdx::coordinator::batcher::BatchPolicy;
use lrdx::coordinator::{Coordinator, ServableModel};
use lrdx::decompose::rank_opt::{optimize_model, AnalyticTimer, LayerTimer, RankOptConfig};
use lrdx::decompose::{plan_to_json, plan_variant, plan_variant_with, SchemeFamily, Variant};
use lrdx::harness::{self, Report};
use lrdx::model::{cost, Arch};
use lrdx::profiler::Timer;
use lrdx::runtime::artifacts::{ArtifactLibrary, ForwardModel, TrainSession};
use lrdx::runtime::layer_factory::EngineLayerTimer;
use lrdx::runtime::netbuilder::{pow2_ladder, ServableNet};
use lrdx::runtime::{CompileOptions, Engine, OptLevel, TileConfig};
use lrdx::trainsim::{self, data::SynthData};
use lrdx::util::cli::Args;
use lrdx::util::rng::Rng;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e:#}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    // `--trace out.json`: record spans across the whole command and export
    // a Chrome trace-event file (chrome://tracing, Perfetto) at the end.
    let trace_path = args.get("trace").map(|s| s.to_string());
    if trace_path.is_some() {
        lrdx::obs::enable();
    }
    let result = match cmd {
        "info" => cmd_info(args),
        "cost" => cmd_cost(args),
        "plan" => cmd_plan(args),
        "rank-search" => cmd_rank_search(args),
        "verify" => cmd_verify(args),
        "train" => cmd_train(args),
        "serve" => cmd_serve(args),
        "bench" => cmd_bench(args),
        "profile" => cmd_profile(args),
        "help" | "--help" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{HELP}"),
    };
    if let Some(path) = trace_path {
        let events = lrdx::obs::drain();
        std::fs::write(&path, lrdx::obs::chrome_trace(&events).render())?;
        println!("wrote {} trace events to {path}", events.len());
    }
    result
}

const HELP: &str = "\
lrdx — Accelerating Low-Rank Decomposed Models (rust+JAX+Pallas reproduction)

commands:
  info          runtime platform + artifact inventory
  cost          analytic layers/params/FLOPs for --arch x --variant
  plan          emit a decomposition plan JSON (--arch, --variant, --out)
  rank-search   Algorithm 1 over a model (--arch, [--real], [--out])
  verify        execute every artifact and check recorded numerics
  train         training simulation (--variant, --steps, [--smoke]): fully
                rust-native autograd train step on the native engine (no
                artifacts), AOT artifacts on a PJRT engine
  serve         serving demo through the coordinator (--variants a,b)
  bench         regenerate a paper table/figure:
                table1 table2 table3 table456 fig2 fig5
  profile       per-op profile of dense vs lrd vs merged vs chain+S on the
                native engine: measured ms per layer site, GFLOP/s, and a
                cost-model calibration (predicted-vs-measured ratio plus
                the fitted effective lane width per op kind). flags:
                --arch (default resnet-mini), --runs N (default 5),
                --hw, --batch, --alpha, --scheme, --sparse-density
flags: --artifacts DIR  --reports DIR  --arch NAME  --hw N  --batch N
       --alpha F  --groups N  --real  --full  --no-measure
       --profile          record per-step wall time / bytes / MACs inside
                          the native executor (any command that compiles a
                          graph). Never changes results — outputs stay
                          bitwise identical; `profile` implies it
       --trace FILE       export every span recorded during the command
                          (compile passes, arena build, verifier, executor
                          steps, worker-pool chunks, serve request path,
                          train steps) as Chrome trace-event JSON — open
                          in chrome://tracing or Perfetto
       --scheme svd|tucker2|cp  factor-chain family decomposed layers lower
                          to (default svd: the paper's two-factor pair;
                          tucker2 = 1x1 -> core -> 1x1 sandwich; cp =
                          separable depthwise chain). bench/rank-search/
                          train honour it
       --sparse-density F compose a sparse residual arm (W ~= chain + S)
                          onto every chain-decomposed site at density F
                          (fraction of dense entries, e.g. 0.05). honoured
                          by train, rank-search and bench table2/table3
       --opt-level 0|1|2  IR pass pipeline for compiled graphs (default 2:
                          cleanup + low-rank re-merge fusion; 0 = as built)
       --verify on|off    run the IR verifier after every pass and audit
                          the arena plan before execution (default: on in
                          debug builds, off in release). distinct from the
                          `verify` command, which replays artifact numerics
       --lane N           lane width for the re-merge profitability gate
       --tile MRxNRxKBxNB pin one packed-GEMM register tile + blocking for
                          every large contraction (e.g. 8x16x128x256);
                          performance-only — any tile gives bitwise-
                          identical outputs. Overrides the autotuner
       --no-autotune      skip compile-time tile autotuning (on by default
                          in the CLI: the first compile of each (M,N,K)
                          shape bucket times the candidate tiles once and
                          caches the winner process-wide). With this flag
                          every contraction uses the fixed default tile
       --threads N        native executor kernel threads (bench/rank-search
                          default 1; 0 = auto). serve defaults to auto and
                          treats N as the TOTAL budget, split across models
                          and then across each model's replicas; any N
                          gives bitwise-identical outputs
       --replicas N       serve: worker replicas per model (default 1)
       --buckets A,B,..   serve: executable bucket ladder per worker
                          (ascending, last = max batch; default: powers of
                          two up to --batch). Each collected batch runs on
                          its smallest covering bucket instead of padding
                          to a fixed device batch
       --queue-cap N      serve: bound on queued requests per replica;
                          admission sheds load with an explicit error when
                          a queue is full (default 1024)
       --max-wait MS      serve: batcher deadline after the first request
                          of a batch arrives (default 5 ms)";

/// `--opt-level` / `--lane` / `--threads` → the `Engine::compile`
/// options (serve, the table/fig benches and `rank-search --real` all
/// honour them).
fn compile_opts(args: &Args) -> Result<CompileOptions> {
    let opt_level = match args.get("opt-level") {
        Some(s) => OptLevel::parse(s)?,
        None => OptLevel::TOP,
    };
    let lane = args.usize_or("lane", 16)?;
    if lane == 0 {
        bail!("--lane must be >= 1 (hardware lane width)");
    }
    let threads = args.usize_or("threads", 1)?;
    let verify = match args.get("verify") {
        None => cfg!(debug_assertions),
        Some(v) => match v {
            "true" | "1" | "yes" | "on" => true,
            "false" | "0" | "no" | "off" => false,
            other => bail!("--verify expects on/off (or true/false), got {other:?}"),
        },
    };
    let tile = match args.get("tile") {
        Some(s) => Some(TileConfig::parse(s).map_err(|e| anyhow!(e))?),
        None => None,
    };
    Ok(CompileOptions {
        opt_level,
        lane,
        threads,
        amortize: None,
        verify,
        profile: args.bool("profile") || args.get("trace").is_some(),
        tile,
        // CLI compiles are long-lived (serve ladders, bench sweeps), so
        // autotuning pays for itself; library/test compiles default off.
        autotune: !args.bool("no-autotune"),
    })
}

/// `--scheme svd|tucker2|cp` → the factor-chain family (default svd).
fn scheme_family(args: &Args) -> Result<SchemeFamily> {
    let name = args.get_or("scheme", "svd");
    SchemeFamily::by_name(name)
        .ok_or_else(|| anyhow!("unknown --scheme {name:?} (svd|tucker2|cp)"))
}

/// `--sparse-density F` → fraction of dense entries the residual keeps
/// (e.g. 0.05), or None when no sparse arm was requested.
fn sparse_density(args: &Args) -> Result<Option<f64>> {
    match args.get("sparse-density") {
        None => Ok(None),
        Some(s) => {
            let f: f64 = s
                .parse()
                .map_err(|_| anyhow!("--sparse-density expects a number, got {s:?}"))?;
            if !(f > 0.0 && f < 1.0) {
                bail!("--sparse-density must be in (0, 1), got {f}");
            }
            Ok(Some(f))
        }
    }
}

/// `--sparse-density` in the integer parts-per-million `Scheme::Sparse`
/// carries.
fn sparse_ppm(args: &Args) -> Result<Option<u32>> {
    Ok(sparse_density(args)?.map(|f| (f * 1e6).round() as u32))
}

fn artifacts_dir(args: &Args) -> std::path::PathBuf {
    std::path::PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn reports_dir(args: &Args) -> std::path::PathBuf {
    std::path::PathBuf::from(args.get_or("reports", "reports"))
}

fn finish(report: Report, args: &Args) -> Result<()> {
    print!("{}", report.render());
    let path = report.save(&reports_dir(args))?;
    println!("(saved {})", path.display());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let engine = Engine::cpu()?;
    println!("platform: {}", engine.platform());
    println!("archs: {}", Arch::all_names().join(", "));
    match ArtifactLibrary::load(artifacts_dir(args)) {
        Ok(lib) => {
            println!("artifacts ({}):", lib.specs.len());
            for s in &lib.specs {
                println!(
                    "  {:44} {:7} {} params={}",
                    s.name,
                    s.kind,
                    if s.use_pallas { "pallas" } else { "      " },
                    s.params.len()
                );
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}

fn cmd_cost(args: &Args) -> Result<()> {
    let arch = Arch::by_name(args.get_or("arch", "resnet50"))
        .ok_or_else(|| anyhow!("unknown --arch"))?;
    let alpha = args.f64_or("alpha", 2.0)?;
    let groups = args.usize_or("groups", 4)?;
    let hw = args.usize_or("hw", 224)?;
    println!(
        "{:16} {:>7} {:>12} {:>12} {:>10}",
        "variant", "layers", "params", "FLOPs(B)", "Δ FLOPs"
    );
    let base = cost::count_macs(
        &arch,
        &plan_variant(&arch, Variant::Orig, alpha, groups, None)?,
        hw,
    );
    for v in Variant::all() {
        if *v == Variant::Merged && arch.block != lrdx::model::BlockKind::Bottleneck {
            continue;
        }
        let plan = plan_variant(&arch, *v, alpha, groups, None)?;
        let rep = cost::report(&arch, &plan, hw);
        println!(
            "{:16} {:>7} {:>12} {:>12.2} {:>+9.2}%",
            v.name(),
            rep.layers,
            rep.params,
            2.0 * rep.macs as f64 / 1e9,
            (rep.macs as f64 / base as f64 - 1.0) * 100.0
        );
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    let arch = Arch::by_name(args.get_or("arch", "resnet50"))
        .ok_or_else(|| anyhow!("unknown --arch"))?;
    let variant = Variant::by_name(args.get_or("variant", "lrd"))
        .ok_or_else(|| anyhow!("unknown --variant"))?;
    let plan = plan_variant(
        &arch,
        variant,
        args.f64_or("alpha", 2.0)?,
        args.usize_or("groups", 4)?,
        None,
    )?;
    let text = plan_to_json(&plan).render();
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!("wrote {path}");
        }
        None => println!("{text}"),
    }
    Ok(())
}

fn cmd_rank_search(args: &Args) -> Result<()> {
    let engine = Engine::cpu()?;
    let arch = Arch::by_name(args.get_or("arch", "resnet50"))
        .ok_or_else(|| anyhow!("unknown --arch"))?;
    let cfg = RankOptConfig {
        alpha: args.f64_or("alpha", 2.0)?,
        rmin_frac: args.f64_or("rmin-frac", 0.5)?,
        stride: args.usize_or("stride", 4)?,
        refine: args.usize_or("refine", 4)?,
        batch: args.usize_or("batch", 4)?,
        hw: args.usize_or("hw", 32)?,
        family: scheme_family(args)?,
    };
    let mut real;
    let mut analytic;
    let timer: &mut dyn LayerTimer = if args.bool("real") {
        real = EngineLayerTimer::with_options(
            engine.clone(),
            Timer { warmup: 1, min_samples: 4, max_samples: 10, cv_target: 0.15 },
            compile_opts(args)?,
        );
        &mut real
    } else {
        analytic = AnalyticTimer { lane: args.usize_or("lane", 16)?, ..Default::default() };
        &mut analytic
    };
    println!(
        "Algorithm 1 on {} ({} timing):",
        arch.name,
        if args.bool("real") { engine.platform() } else { "analytic".to_string() }
    );
    let (decisions, plan) = optimize_model(timer, &arch, &cfg, |d| {
        println!(
            "  {:24} R={:<4} -> {:6} ({:.2}x)",
            d.name,
            d.initial_rank,
            d.chosen_rank.map(|r| r.to_string()).unwrap_or_else(|| "ORG".into()),
            d.speedup()
        );
    })?;
    let kept = decisions.iter().filter(|d| d.chosen_rank.is_none()).count();
    println!("{} sites, {} kept original", decisions.len(), kept);
    let plan = match sparse_ppm(args)? {
        Some(ppm) => {
            println!(
                "composing sparse residual at {:.2}% density onto chain sites",
                ppm as f64 / 1e4
            );
            lrdx::decompose::sparsify_plan(plan, ppm)
        }
        None => plan,
    };
    if let Some(path) = args.get("out") {
        std::fs::write(path, plan_to_json(&plan).render())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let engine = Engine::cpu()?;
    let lib = ArtifactLibrary::load(artifacts_dir(args))?;
    let mut failures = 0;
    for spec in &lib.specs {
        let outcome = match spec.kind.as_str() {
            "forward" => ForwardModel::load(&engine, spec)
                .and_then(|m| m.verify())
                .map(|d| format!("max |Δ| = {d:.2e}")),
            "train" => {
                let x = lrdx::util::det_input(spec.batch, spec.hw);
                let y = lrdx::util::det_labels(spec.batch, spec.classes);
                TrainSession::load(&engine, spec).and_then(|mut s| {
                    let (loss, _) = s.step(&x, &y)?;
                    let want = spec.expected.get("loss0")?.num()?;
                    let tol = spec.expected.get("tol")?.num()?;
                    if (loss as f64 - want).abs() > tol {
                        bail!("loss {loss} vs recorded {want}");
                    }
                    Ok(format!("loss0 {loss:.4} ≈ {want:.4}"))
                })
            }
            k => Err(anyhow!("unknown kind {k}")),
        };
        match outcome {
            Ok(msg) => println!("  OK   {:44} {msg}", spec.name),
            Err(e) => {
                failures += 1;
                println!("  FAIL {:44} {e:#}", spec.name);
            }
        }
    }
    if failures > 0 {
        bail!("{failures} artifact(s) failed verification");
    }
    println!("all {} artifacts verified", lib.specs.len());
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let engine = Engine::cpu()?;
    let smoke = args.bool("smoke");
    let arch_name = args.get_or("arch", "resnet-mini");
    let variant_name = args.get_or("variant", "lrd");
    let steps = args.usize_or("steps", if smoke { 8 } else { 150 })?;
    let mut rng = Rng::new(args.usize_or("seed", 1)? as u64);

    // A PJRT engine fine-tunes through the AOT artifacts; the native
    // engine runs the fully rust-native autograd train step — zero
    // python, zero artifacts.
    if engine.platform() != "native-cpu" {
        let lib = ArtifactLibrary::load(artifacts_dir(args))?;
        let gen = SynthData::new(32, 10);
        println!(
            "fine-tuning {arch_name}/{variant_name} for {steps} steps via AOT artifacts"
        );
        let report = trainsim::finetune_variant(
            &engine, &lib, arch_name, variant_name, None, &gen, &mut rng, steps,
        )?;
        return finish_train(&report);
    }

    let copts = compile_opts(args)?;
    let arch =
        Arch::by_name(arch_name).ok_or_else(|| anyhow!("unknown --arch {arch_name}"))?;
    let variant = Variant::by_name(variant_name)
        .ok_or_else(|| anyhow!("unknown --variant {variant_name}"))?;
    let hw = args.usize_or("hw", if smoke { 12 } else { 24 })?;
    let batch = args.usize_or("batch", if smoke { 8 } else { 16 })?;
    let gen = SynthData::new(hw, arch.classes);
    println!(
        "training {arch_name}/{variant_name} natively for {steps} steps \
         (hw {hw}, batch {batch}, {}, threads {}) — no python, no artifacts",
        copts.opt_level.name(),
        copts.resolved_threads(),
    );
    let plan = plan_variant_with(
        &arch,
        variant,
        scheme_family(args)?,
        args.f64_or("alpha", 2.0)?,
        args.usize_or("groups", 2)?,
        None,
        sparse_ppm(args)?,
    )?;
    let (report, stats) = trainsim::finetune_variant_native(
        &engine,
        &arch,
        variant,
        &plan,
        None,
        &gen,
        &mut rng,
        steps,
        batch,
        8,
        &copts,
    )?;
    println!("  step graph: {}", stats.summary());
    finish_train(&report)
}

fn finish_train(report: &trainsim::TrainReport) -> Result<()> {
    for (s, l) in &report.loss_curve {
        println!("  step {s:>5}  loss {l:.4}");
    }
    println!(
        "done: {:.1}s, final train acc {:.1}%, eval acc {:.1}%",
        report.train_secs,
        report.train_acc * 100.0,
        report.eval_acc * 100.0
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let root = artifacts_dir(args);
    let arch = args.get_or("arch", "resnet-mini").to_string();
    let variants: Vec<String> = args
        .get_or("variants", "orig,lrd,merged")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let requests = args.usize_or("requests", 64)?;
    let copts = compile_opts(args)?;

    // A backend that can compile HLO serves the AOT artifacts (and a bad
    // --artifacts dir is a hard error there, not a silent fallback); the
    // native backend serves synthetic netbuilder models at --opt-level.
    let engine_probe = Engine::cpu()?;
    let artifact_lib = if engine_probe.platform() != "native-cpu" {
        Some(ArtifactLibrary::load(&root)?)
    } else {
        None
    };

    // `--threads` (serve default: 0 = machine auto) is the TOTAL kernel-
    // thread budget: divided across the served models here, then across
    // each model's replicas by the coordinator (WorkerCtx::threads) — so
    // the whole deployment never exceeds the budget.
    let replicas = args.usize_or("replicas", 1)?;
    let total_budget = lrdx::runtime::resolve_threads(args.usize_or("threads", 0)?);
    let per_model_budget = (total_budget / variants.len().max(1)).max(1);
    let policy = BatchPolicy {
        max_wait: std::time::Duration::from_millis(args.usize_or("max-wait", 5)? as u64),
        queue_cap: args.usize_or("queue-cap", 1024)?,
        ..Default::default()
    };
    let mut coord = Coordinator::with_thread_budget(policy, per_model_budget);
    let hw;
    match &artifact_lib {
        Some(lib) => {
            hw = lib
                .find_by(&arch, &variants[0], "forward")
                .ok_or_else(|| anyhow!("no {arch}/{} forward artifact", variants[0]))?
                .hw;
            println!(
                "serving AOT HLO artifacts: fixed-batch executables \
                 (one-bucket ladder per worker)"
            );
            for v in &variants {
                let (root, arch, v2) = (root.clone(), arch.clone(), v.clone());
                coord.register(v, hw, replicas, move |ctx| {
                    let lib = ArtifactLibrary::load(&root)?;
                    let spec = lib
                        .find_by(&arch, &v2, "forward")
                        .ok_or_else(|| anyhow!("no {arch}/{v2} forward artifact"))?;
                    Ok(Box::new(ForwardModel::load(ctx.engine(), spec)?)
                        as Box<dyn ServableModel>)
                })?;
            }
        }
        None => {
            hw = args.usize_or("hw", 32)?;
            let batch = args.usize_or("batch", 8)?;
            let buckets: Vec<usize> = match args.get("buckets") {
                Some(s) => {
                    let mut v = Vec::new();
                    for part in s.split(',') {
                        v.push(part.trim().parse::<usize>().map_err(|_| {
                            anyhow!("--buckets expects comma-separated sizes, got {s:?}")
                        })?);
                    }
                    v
                }
                None => pow2_ladder(batch),
            };
            let a = Arch::by_name(&arch).ok_or_else(|| anyhow!("unknown --arch {arch}"))?;
            println!(
                "artifacts unavailable on {} — serving synthetic {arch} \
                 netbuilder models ({}), bucket ladder {buckets:?}",
                engine_probe.platform(),
                copts.opt_level.name()
            );
            let ceiling = buckets.last().copied().unwrap_or(batch);
            for v in &variants {
                let variant = Variant::by_name(v)
                    .ok_or_else(|| anyhow!("unknown variant {v:?}"))?;
                let plan = plan_variant(&a, variant, args.f64_or("alpha", 2.0)?, 4, None)?;
                // report what the pipeline does to this variant's
                // ceiling-bucket graph (pipeline only — the workers
                // compile their ladders lazily)
                let (graph, _) =
                    lrdx::runtime::netbuilder::build_forward(&a, &plan, ceiling, hw)?;
                let (_, stats) = lrdx::runtime::passes::run_pipeline(&graph, &copts)?;
                println!("  {v:10} {}", stats.summary());
                let (a2, copts2, buckets2) = (a.clone(), copts.clone(), buckets.clone());
                coord.register(v, hw, replicas, move |ctx| {
                    // the worker's budget share, not the raw CLI value
                    let copts = CompileOptions { threads: ctx.threads(), ..copts2.clone() };
                    let mut net = ServableNet::compile(
                        ctx.engine(),
                        &a2,
                        &plan,
                        &buckets2,
                        hw,
                        0x5EED,
                        &copts,
                    )?;
                    // pay every bucket's compile at registration so no
                    // serving request eats a first-use compile spike
                    net.precompile_all()?;
                    Ok(Box::new(net) as Box<dyn ServableModel>)
                })?;
            }
        }
    }
    println!("serving {} variants of {arch}; {requests} requests each", variants.len());
    let gen = SynthData::new(hw, 10);
    let mut rng = Rng::new(7);
    for v in &variants {
        let t0 = std::time::Instant::now();
        let pending: Vec<_> = (0..requests)
            .map(|_| {
                let (x, _) = gen.batch(&mut rng, 1);
                coord.infer(v, x)
            })
            .collect::<Result<_>>()?;
        for rx in pending {
            rx.recv().map_err(|_| anyhow!("worker died"))??;
        }
        let secs = t0.elapsed().as_secs_f64();
        println!("  {v:10} {:.1} req/s", requests as f64 / secs);
    }
    println!("{}", coord.metrics.snapshot().render());
    coord.shutdown();
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let engine = Engine::cpu()?;
    let copts = compile_opts(args)?;
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow!("bench needs a target (table1..table456, fig2, fig5)"))?;
    let archs = |d: &str| -> Vec<String> {
        if args.bool("full") {
            vec!["resnet50".into(), "resnet101".into(), "resnet152".into()]
        } else {
            args.get_or("arch", d).split(',').map(|s| s.to_string()).collect()
        }
    };
    let report = match which {
        "table1" => harness::table1::run(
            &engine,
            &harness::table1::Config {
                archs: archs("resnet50"),
                hw: args.usize_or("hw", 64)?,
                batch: args.usize_or("batch", 8)?,
                alpha: args.f64_or("alpha", 2.0)?,
                no_measure: args.bool("no-measure"),
                opt: copts.clone(),
            },
        )?,
        "table2" => harness::table2::run(
            &engine,
            &harness::table2::Config {
                real: args.bool("real"),
                batch: args.usize_or("batch", 4)?,
                hw: args.usize_or("hw", 32)?,
                stride: args.usize_or("stride", 4)?,
                refine: args.usize_or("refine", 4)?,
                family: scheme_family(args)?,
                opt: copts.clone(),
                sparse_density: sparse_density(args)?,
                ..Default::default()
            },
        )?,
        "table3" => harness::table3::run(
            &engine,
            &harness::table3::Config {
                archs: archs("resnet50"),
                hw: args.usize_or("hw", 64)?,
                batch: args.usize_or("batch", 8)?,
                alpha: args.f64_or("alpha", 2.0)?,
                groups: args.usize_or("groups", 4)?,
                no_measure: args.bool("no-measure"),
                opt: copts.clone(),
                sparse_density: sparse_density(args)?,
                ..Default::default()
            },
        )?,
        "table456" => harness::table456::run(
            &engine,
            &harness::table456::Config {
                artifacts: artifacts_dir(args),
                train_steps: args.usize_or("train-steps", 250)?,
                finetune_steps: args.usize_or("finetune-steps", 200)?,
                prune_fraction: args.f64_or("prune", 0.3)?,
                batch: args.usize_or("batch", 16)?,
                alpha: args.f64_or("alpha", 2.0)?,
                groups: args.usize_or("groups", 2)?,
                opt: copts.clone(),
                ..Default::default()
            },
        )?,
        "fig2" => harness::fig2::run(
            &engine,
            &harness::fig2::Config {
                real: args.bool("real"),
                rank_lo: args.usize_or("rank-lo", 240)?,
                rank_hi: args.usize_or("rank-hi", 320)?,
                step: args.usize_or("step", 4)?,
                batch: args.usize_or("batch", 2)?,
                hw: args.usize_or("hw", 16)?,
                opt: copts.clone(),
                ..Default::default()
            },
        )?,
        "fig5" => harness::fig5::run(
            &engine,
            &harness::fig5::Config {
                arch: args.get_or("arch", "resnet50").to_string(),
                hw: args.usize_or("hw", 64)?,
                batch: args.usize_or("batch", 8)?,
                no_measure: args.bool("no-measure"),
                opt: copts.clone(),
                ..Default::default()
            },
        )?,
        other => bail!("unknown bench target {other:?}"),
    };
    finish(report, args)
}

/// `lrdx profile` — compile the paper's four variants (dense, decomposed,
/// merged, chain + sparse residual) with per-step profiling on, run each a
/// few times, and render the per-site measured table plus a cost-model
/// calibration: `AnalyticTimer`-predicted vs measured time per site, and
/// the effective lane width `fit_effective_lane` recovers per op kind.
fn cmd_profile(args: &Args) -> Result<()> {
    use lrdx::decompose::Plan;
    use lrdx::obs;
    use lrdx::runtime::netbuilder::BuiltNet;
    use lrdx::util::json::Json;

    let engine = Engine::cpu()?;
    let arch_name = args.get_or("arch", "resnet-mini");
    let arch =
        Arch::by_name(arch_name).ok_or_else(|| anyhow!("unknown --arch {arch_name}"))?;
    let hw = args.usize_or("hw", 32)?;
    let batch = args.usize_or("batch", 4)?;
    let runs = args.usize_or("runs", 5)?.max(1);
    let alpha = args.f64_or("alpha", 2.0)?;
    let groups = args.usize_or("groups", 4)?;
    let ppm = sparse_ppm(args)?.unwrap_or(50_000); // chain+S default: 5%
    let mut copts = compile_opts(args)?;
    copts.profile = true;
    let timer = AnalyticTimer { lane: copts.lane, ..Default::default() };

    let mut variants: Vec<(&str, Plan)> = vec![
        ("orig", plan_variant(&arch, Variant::Orig, alpha, groups, None)?),
        ("lrd", plan_variant(&arch, Variant::Lrd, alpha, groups, None)?),
    ];
    if arch.block == lrdx::model::BlockKind::Bottleneck {
        variants.push(("merged", plan_variant(&arch, Variant::Merged, alpha, groups, None)?));
    }
    variants.push((
        "chain+S",
        plan_variant_with(&arch, Variant::Lrd, scheme_family(args)?, alpha, groups, None, Some(ppm))?,
    ));

    const TOP_SITES: usize = 8;
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    let mut notes = vec![format!(
        "{arch_name} at {hw}x{hw} batch {batch}, {runs} runs/variant, {} thread(s), {}; \
         predicted = AnalyticTimer (lane {}, {:.0} GFLOP/s peak, {:.0}us dispatch)",
        copts.resolved_threads(),
        copts.opt_level.name(),
        timer.lane,
        timer.flops_per_sec / 1e9,
        timer.overhead * 1e6,
    )];
    // (op kind -> (gate dim, measured FLOP/s)) across every variant
    let mut cal_points: std::collections::BTreeMap<&'static str, Vec<(usize, f64)>> =
        std::collections::BTreeMap::new();

    for (label, plan) in &variants {
        let net = BuiltNet::compile(&engine, &arch, plan, batch, hw, 0xBEEF, &copts)?;
        let x: Vec<f32> = lrdx::util::det_input(batch, hw);
        let xb = engine.upload(&x, &[batch, 3, hw, hw])?;
        for _ in 0..runs {
            net.forward(&xb)?.sync()?;
        }
        let p = net
            .exe
            .profile()
            .ok_or_else(|| anyhow!("{label}: backend returned no profile"))?;
        obs::inject(p.trace_events()); // rides along into --trace output

        // calibration points: per-step measured rate vs the step's gate dim
        for (m, a) in p.meta.iter().zip(&p.steps) {
            if m.macs > 0 && a.total_secs > 0.0 && m.gate > 0 {
                let rate = 2.0 * (m.macs as u64 * a.calls) as f64 / a.total_secs;
                cal_points.entry(m.op).or_default().push((m.gate, rate));
            }
        }

        let sites = p.by_site();
        let arena = net
            .pass_stats()
            .arena
            .as_ref()
            .map(|a| a.peak_bytes)
            .unwrap_or(0);
        rows.push(vec![
            format!("{label} TOTAL"),
            String::new(),
            format!("{:.3}", p.run_secs / p.runs.max(1) as f64 * 1e3),
            String::new(),
            String::new(),
            format!("cov {:.0}% arena {:.1}MB", p.coverage() * 100.0, arena as f64 / 1e6),
        ]);
        let mut jsites = Vec::new();
        for (i, s) in sites.iter().enumerate() {
            // predicted: MACs through the tile-efficiency curve at the
            // step's gate dim, plus the per-dispatch overhead
            let eff = cost::tile_efficiency(s.gate, timer.lane).max(1e-3);
            let pred_secs = 2.0 * s.macs_total as f64 / (timer.flops_per_sec * eff)
                + timer.overhead * s.calls as f64;
            let ratio = if pred_secs > 0.0 { s.total_secs / pred_secs } else { f64::NAN };
            jsites.push(Json::obj_from(vec![
                ("site", Json::Str(s.site.clone())),
                ("op", Json::Str(s.op.into())),
                ("ms_per_run", Json::Num(s.ms_per_run(p.runs))),
                ("gflops", Json::Num(s.gflops())),
                ("meas_over_pred", Json::Num(ratio)),
                ("macs", Json::Num(s.macs_total as f64)),
                ("bytes", Json::Num(s.bytes_total as f64)),
            ]));
            if i >= TOP_SITES {
                continue; // JSON keeps every site; the table shows the top
            }
            rows.push(vec![
                label.to_string(),
                format!("{} [{}]", s.site, s.op),
                format!("{:.3}", s.ms_per_run(p.runs)),
                if s.macs_total > 0 { format!("{:.2}", s.gflops()) } else { "-".into() },
                if s.macs_total > 0 { format!("{ratio:.2}") } else { "-".into() },
                format!("{} step(s) x{}", s.steps, s.calls),
            ]);
        }
        if sites.len() > TOP_SITES {
            notes.push(format!(
                "{label}: table shows the {TOP_SITES} heaviest of {} site rows \
                 (all rows in the JSON report)",
                sites.len()
            ));
        }
        // per-kernel throughput attribution: one row per op kind, the
        // measured GFLOP/s of everything the kernel executed
        let jops: Vec<Json> = p
            .by_op()
            .iter()
            .map(|o| {
                Json::obj_from(vec![
                    ("op", Json::Str(o.op.into())),
                    ("ms_per_run", Json::Num(o.ms_per_run(p.runs))),
                    ("gflops", Json::Num(o.gflops())),
                    ("macs", Json::Num(o.macs_total as f64)),
                ])
            })
            .collect();
        jrows.push(Json::obj_from(vec![
            ("variant", Json::Str(label.to_string())),
            ("runs", Json::Num(p.runs as f64)),
            ("ms_per_run", Json::Num(p.run_secs / p.runs.max(1) as f64 * 1e3)),
            ("coverage", Json::Num(p.coverage())),
            ("arena_peak_bytes", Json::Num(arena as f64)),
            ("ops", Json::Arr(jops)),
            ("sites", Json::Arr(jsites)),
        ]));
    }

    // Calibration: which lane width explains the measured rates per op kind
    for (op, pts) in &cal_points {
        match cost::fit_effective_lane(pts) {
            Some((lane, peak, resid)) => notes.push(format!(
                "calibration[{op}]: effective lane {lane} at {:.2} GFLOP/s peak \
                 (rel residual {:.2}, {} points) — configured gate lane is {}",
                peak / 1e9,
                resid,
                pts.len(),
                copts.lane,
            )),
            None => notes.push(format!("calibration[{op}]: no usable points")),
        }
    }

    // Second, independent calibration source: the tile autotuner's
    // candidate sweeps (populated whenever compiles ran with tuning on —
    // the CLI default). These rates come from dedicated serial timing
    // rather than profiled step wall time, so agreement between the two
    // fits is itself a sanity check on the cost model.
    let tuned = lrdx::runtime::native::autotune::points();
    if !tuned.is_empty() {
        let pts: Vec<(usize, f64)> = tuned.iter().map(|p| (p.n, p.gflops * 1e9)).collect();
        if let Some((lane, peak, resid)) = cost::fit_effective_lane(&pts) {
            notes.push(format!(
                "autotune: {} shape bucket(s) timed; effective lane {lane} at \
                 {:.2} GFLOP/s serial peak (rel residual {resid:.2}); winners: {}",
                tuned.len(),
                peak / 1e9,
                tuned
                    .iter()
                    .map(|p| format!("{}x{}x{}:{}", p.m, p.n, p.k, p.cfg.key()))
                    .collect::<Vec<_>>()
                    .join(" "),
            ));
        }
    }

    finish(
        Report {
            id: "profile".into(),
            title: format!("Per-op profile & cost calibration ({arch_name})"),
            header: ["Variant", "Site [op]", "ms/run", "GFLOP/s", "meas/pred", "notes"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            rows,
            notes,
            json: Json::obj_from(vec![("variants", Json::Arr(jrows))]),
        },
        args,
    )
}
