//! Analytic cost model: layer counts, parameters, MACs/FLOPs and the
//! hardware tile-efficiency estimate driving the §2.1 rank discussion.
//!
//! Mirrors `python/compile/resnet.py::flops/count_layers` — pinned tests on
//! both sides keep them in sync.

use std::collections::BTreeMap;

use crate::decompose::chain::FactorChain;
use crate::decompose::{Plan, Scheme};
use crate::model::{Arch, BlockKind, SiteKind};

/// Full cost report for one (arch, plan) pair.
#[derive(Clone, Debug)]
pub struct CostReport {
    /// conv+fc layer count (paper Table 1 "Layers")
    pub layers: usize,
    /// trainable parameters (weights only; BN affines excluded like the paper)
    pub params: usize,
    /// multiply-accumulates for one image (FLOPs = 2x this)
    pub macs: usize,
}

/// Spatial sizes each site's *output* sees, replaying the forward pass.
pub fn spatial_map(arch: &Arch, hw: usize) -> BTreeMap<String, (usize, usize)> {
    let mut spatial = BTreeMap::new();
    let mut h = hw.div_ceil(2); // stem conv, stride 2
    let mut w = hw.div_ceil(2);
    spatial.insert("stem.conv".to_string(), (h, w));
    h = h.div_ceil(2); // maxpool 3x3/2
    w = w.div_ceil(2);
    let site_names: std::collections::HashSet<String> =
        arch.sites().into_iter().map(|t| t.name).collect();
    for (si, &n_blocks) in arch.layers.iter().enumerate() {
        let stage_stride = if si == 0 { 1 } else { 2 };
        for bi in 0..n_blocks {
            let pre = format!("layer{}.{}", si + 1, bi);
            let blk_stride = if bi == 0 { stage_stride } else { 1 };
            let (h_in, w_in) = (h, w);
            if blk_stride == 2 {
                h = h.div_ceil(2);
                w = w.div_ceil(2);
            }
            match arch.block {
                BlockKind::Bottleneck => {
                    // conv1 is stride-1 at the block input resolution
                    spatial.insert(format!("{pre}.conv1"), (h_in, w_in));
                    spatial.insert(format!("{pre}.conv2"), (h, w));
                    spatial.insert(format!("{pre}.conv3"), (h, w));
                }
                BlockKind::Basic => {
                    spatial.insert(format!("{pre}.conv1"), (h, w));
                    spatial.insert(format!("{pre}.conv2"), (h, w));
                }
            }
            if site_names.contains(&format!("{pre}.downsample")) {
                spatial.insert(format!("{pre}.downsample"), (h, w));
            }
        }
    }
    spatial.insert("fc".to_string(), (1, 1));
    spatial
}

/// Conv+fc layer count — downsample convs are not counted (torch convention,
/// matches the paper's 50/101/152 and 115/233/352).
pub fn count_layers(arch: &Arch, plan: &Plan) -> usize {
    arch.sites()
        .iter()
        .filter(|t| t.kind != SiteKind::Downsample)
        .map(|t| {
            // a sparse arm is a branch of its site, not an extra layer
            match plan.get(&t.name).unwrap_or(&Scheme::Orig).split_sparse().0 {
                Scheme::Orig | Scheme::Merged { .. } | Scheme::MergedInto { .. } => 1,
                Scheme::Svd { .. } => 2,
                Scheme::Tucker { .. } | Scheme::Branched { .. } | Scheme::Tucker2 { .. } => 3,
                Scheme::Cp { .. } => {
                    if t.k == 1 {
                        2
                    } else {
                        4
                    }
                }
                Scheme::Sparse { .. } => unreachable!("split_sparse strips the wrapper"),
            }
        })
        .sum()
}

/// Parameter count for the plan: weights + BatchNorm affines + fc bias
/// (the torchvision convention the paper's 25.56M/44.55M/60.19M follow).
pub fn count_params(arch: &Arch, plan: &Plan) -> usize {
    count_params_split(arch, plan).0
}

/// (total, bn_affines) parameter counts.
pub fn count_params_split(arch: &Arch, plan: &Plan) -> (usize, usize) {
    let by_name: BTreeMap<String, _> =
        arch.sites().into_iter().map(|t| (t.name.clone(), t)).collect();
    let mut weights = 0usize;
    let mut bn = 0usize;
    for t in by_name.values() {
        let k2 = t.k * t.k;
        let (scheme, sparse_ppm) = plan.get(&t.name).unwrap_or(&Scheme::Orig).split_sparse();
        // the residual arm stores vals [nnz] plus the f32-encoded index
        // pattern [nnz] — both counted (honest artifact size accounting)
        if let Some(ppm) = sparse_ppm {
            weights += 2 * Scheme::sparse_nnz(t.c, t.s, t.k, ppm);
        }
        weights += match scheme {
            Scheme::Orig => t.c * t.s * k2 + if t.kind == SiteKind::Fc { t.s } else { 0 },
            Scheme::Svd { r } => {
                r * (t.c + t.s) + if t.kind == SiteKind::Fc { t.s } else { 0 }
            }
            Scheme::Tucker { r1, r2 } => t.c * r1 + r1 * r2 * k2 + r2 * t.s,
            Scheme::Branched { r1, r2, groups } => {
                t.c * r1 + (r1 / groups) * (r2 / groups) * k2 * groups + r2 * t.s
            }
            Scheme::Merged { r1, r2 } => r1 * r2 * k2,
            Scheme::MergedInto { peer } => {
                let (r1, r2) = match &plan[peer] {
                    Scheme::Merged { r1, r2 } => (*r1, *r2),
                    other => panic!("merged_into peer has scheme {other:?}"),
                };
                if t.name.ends_with(".conv1") {
                    t.c * r1
                } else {
                    r2 * t.s
                }
            }
            s @ (Scheme::Tucker2 { .. } | Scheme::Cp { .. }) => {
                // exact three/four-factor chain counts via the descriptor
                FactorChain::of(t, s).expect("chain scheme").params()
                    + if t.kind == SiteKind::Fc { t.s } else { 0 }
            }
            Scheme::Sparse { .. } => unreachable!("split_sparse strips the wrapper"),
        };
        // BN affine (gamma + beta) on the site's output channels; merging
        // shrinks the inner BNs to the ranks (see decompose::params).
        if t.kind != SiteKind::Fc {
            bn += 2 * match scheme {
                Scheme::Merged { r2, .. } => *r2,
                Scheme::MergedInto { peer } if t.name.ends_with(".conv1") => {
                    match &plan[peer] {
                        Scheme::Merged { r1, .. } => *r1,
                        _ => t.s,
                    }
                }
                _ => t.s,
            };
        }
    }
    (weights + bn, bn)
}

/// MACs for one image at `hw` input resolution (FLOPs = 2x).
pub fn count_macs(arch: &Arch, plan: &Plan, hw: usize) -> usize {
    let spatial = spatial_map(arch, hw);
    arch.sites()
        .iter()
        .map(|t| {
            let (ho, wo) = spatial[&t.name];
            let a = ho * wo;
            let k2 = t.k * t.k;
            let (scheme, sparse_ppm) = plan.get(&t.name).unwrap_or(&Scheme::Orig).split_sparse();
            // each residual nonzero is one MAC per output pixel
            let sparse_macs = match sparse_ppm {
                Some(ppm) => a * Scheme::sparse_nnz(t.c, t.s, t.k, ppm),
                None => 0,
            };
            let base_macs = match scheme {
                Scheme::Orig => a * t.c * t.s * k2,
                Scheme::Svd { r } => a * r * (t.c + t.s),
                Scheme::Tucker { r1, r2 } => a * (t.c * r1 + r1 * r2 * k2 + r2 * t.s),
                Scheme::Branched { r1, r2, groups } => {
                    a * (t.c * r1 + (r1 / groups) * (r2 / groups) * k2 * groups + r2 * t.s)
                }
                Scheme::Merged { r1, r2 } => a * r1 * r2 * k2,
                Scheme::MergedInto { peer } => {
                    let (r1, r2) = match &plan[peer] {
                        Scheme::Merged { r1, r2 } => (*r1, *r2),
                        other => panic!("merged_into peer has scheme {other:?}"),
                    };
                    if t.name.ends_with(".conv1") {
                        a * t.c * r1
                    } else {
                        a * r2 * t.s
                    }
                }
                s @ (Scheme::Tucker2 { .. } | Scheme::Cp { .. }) => {
                    FactorChain::of(t, s).expect("chain scheme").macs(a)
                }
                Scheme::Sparse { .. } => unreachable!("split_sparse strips the wrapper"),
            };
            base_macs + sparse_macs
        })
        .sum()
}

pub fn report(arch: &Arch, plan: &Plan, hw: usize) -> CostReport {
    CostReport {
        layers: count_layers(arch, plan),
        params: count_params(arch, plan),
        macs: count_macs(arch, plan, hw),
    }
}

// --------------------------------------------------------------------------
// Tile efficiency — the §2.1 / Fig. 2 hardware model
// --------------------------------------------------------------------------

/// Fraction of lanes doing useful work when a dimension of size `dim` is
/// processed in `lane`-wide tiles: dim / (ceil(dim/lane) * lane).
///
/// This is the mechanism behind the paper's Fig. 2 cliff (rank 257 -> 256 =
/// +15% throughput on CUDA tiles) and behind our TPU adaptation (MXU lane
/// width 128; DESIGN.md §Hardware-Adaptation). On the native backend the
/// lane is no longer an assumption: the packed microkernel's register
/// tile (`native::kernels::TileConfig`, NR = 8 or 16 f32 lanes) is the
/// physical tile this curve models, and the autotuner's candidate sweeps
/// plus `lrdx profile`'s [`fit_effective_lane`] recover the *achieved*
/// lane per machine (see the `gemm` section of `BENCH_native.json` for
/// the standing measurement).
pub fn tile_efficiency(dim: usize, lane: usize) -> f64 {
    if dim == 0 {
        return 0.0;
    }
    dim as f64 / (dim.div_ceil(lane) * lane) as f64
}

/// Combined tile efficiency of a low-rank stack: the rank dimension appears
/// as both a contraction output and input, so it gates both factor matmuls.
pub fn rank_efficiency(r: usize, lane: usize) -> f64 {
    tile_efficiency(r, lane)
}

/// Relative cost of one sparse-residual MAC against one dense-GEMM MAC on
/// a `lane`-wide engine. CSR row gathers run at scalar rate, so a sparse
/// MAC occupies a full lane-wide issue slot (`lane`x a dense MAC). Once
/// the chain is contracted back to a dense weight the residual rides the
/// activation tile the contraction already streams, halving its price —
/// the asymmetry the three-way re-merge gate trades on.
///
/// Re-measured against the vectorized kernels (PR 10): `spmm_rows`' dense
/// axpy now uses the same 8-wide lane primitive as the packed GEMM
/// (`kernels::axpy_lanes`), so both sides of the ratio vectorize equally
/// and the lane/2-vs-lane asymmetry — which comes from the *gather*, not
/// the multiply — is unchanged. The nnz = 288 flip point pinned in
/// `runtime::passes::remerge` therefore stands.
pub fn spmm_unit_cost(lane: usize, merged: bool) -> f64 {
    let lane = lane.max(1) as f64;
    if merged {
        lane / 2.0
    } else {
        lane
    }
}

/// Calibrate the tile-efficiency model against measurements: given
/// `(gate_dim, measured_rate)` points — gate dimension of a kernel (the
/// contiguous dimension its inner loop vectorizes over) and its measured
/// throughput (e.g. GFLOP/s) — find the lane width `L` whose
/// `rate ≈ c · tile_efficiency(gate_dim, L)` fit has the smallest
/// least-squares residual. Returns `(lane, peak_rate, rel_residual)`
/// where `peak_rate` is the fitted full-lane throughput `c` and
/// `rel_residual` is `sqrt(Σerr² / Σrate²)` (0 = perfect fit).
///
/// This is the measured counterpart of [`tile_efficiency`]: the profiler
/// feeds per-op observed rates in, and the reported lane is the
/// *effective* vector width the kernel actually achieved — the number
/// `AnalyticTimer { lane }` should be configured with for this machine.
pub fn fit_effective_lane(points: &[(usize, f64)]) -> Option<(usize, f64, f64)> {
    const CANDIDATES: [usize; 8] = [1, 2, 4, 8, 16, 32, 64, 128];
    let pts: Vec<(usize, f64)> = points
        .iter()
        .copied()
        .filter(|&(dim, rate)| dim > 0 && rate.is_finite() && rate > 0.0)
        .collect();
    if pts.is_empty() {
        return None;
    }
    let rate_sq: f64 = pts.iter().map(|&(_, r)| r * r).sum();
    let mut best: Option<(usize, f64)> = None;
    let mut best_resid = f64::INFINITY;
    for &lane in &CANDIDATES {
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for &(dim, rate) in &pts {
            let eff = tile_efficiency(dim, lane);
            num += rate * eff;
            den += eff * eff;
        }
        if den == 0.0 {
            continue;
        }
        let c = num / den;
        let resid: f64 = pts
            .iter()
            .map(|&(dim, rate)| {
                let err = rate - c * tile_efficiency(dim, lane);
                err * err
            })
            .sum();
        if resid < best_resid {
            best_resid = resid;
            best = Some((lane, c));
        }
    }
    best.map(|(lane, c)| (lane, c, (best_resid / rate_sq).sqrt()))
}

/// Estimated VMEM bytes of one grid step of the fused low-rank matmul
/// kernel — mirrors `python/compile/kernels/lowrank_matmul.py::vmem_bytes`.
pub fn lowrank_vmem_bytes(b: usize, c: usize, r: usize, s: usize) -> usize {
    let round_block = |dim: usize, target: usize| {
        let mut bl = dim.min(target);
        while dim % bl != 0 {
            bl -= 1;
        }
        bl
    };
    let bm = round_block(b, 128);
    let bn = round_block(s, 128);
    4 * (bm * c + c * r + r * bn + bm * r + bm * bn)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::{plan_variant, Variant};

    fn arch(n: &str) -> Arch {
        Arch::by_name(n).unwrap()
    }

    #[test]
    fn table1_layer_counts() {
        for (name, orig, lrd) in
            [("resnet50", 50, 115), ("resnet101", 101, 233), ("resnet152", 152, 352)]
        {
            let a = arch(name);
            let p_orig = plan_variant(&a, Variant::Orig, 2.0, 4, None).unwrap();
            let p_lrd = plan_variant(&a, Variant::Lrd, 2.0, 4, None).unwrap();
            assert_eq!(count_layers(&a, &p_orig), orig, "{name} orig");
            let got = count_layers(&a, &p_lrd);
            assert!((got as i64 - lrd as i64).abs() <= 1, "{name} lrd: {got} vs {lrd}");
        }
    }

    #[test]
    fn table1_params() {
        // paper: ResNet-50 25.56M / LRD 12.78M; 101: 44.55/22.21; 152: 60.19/30.01
        for (name, orig_m, lrd_m) in
            [("resnet50", 25.56, 12.78), ("resnet101", 44.55, 22.21), ("resnet152", 60.19, 30.01)]
        {
            let a = arch(name);
            let p0 = count_params(&a, &plan_variant(&a, Variant::Orig, 2.0, 4, None).unwrap());
            let p1 = count_params(&a, &plan_variant(&a, Variant::Lrd, 2.0, 4, None).unwrap());
            assert!(
                ((p0 as f64) / 1e6 - orig_m).abs() < 0.2,
                "{name} orig params {}",
                p0 as f64 / 1e6
            );
            assert!(
                ((p1 as f64) / 1e6 - lrd_m).abs() < 0.7,
                "{name} lrd params {}",
                p1 as f64 / 1e6
            );
        }
    }

    #[test]
    fn resnet50_macs_canonical() {
        let a = arch("resnet50");
        let m = count_macs(&a, &plan_variant(&a, Variant::Orig, 2.0, 4, None).unwrap(), 224);
        assert!((4.0e9..4.2e9).contains(&(m as f64)), "{m}");
    }

    #[test]
    fn variant_ordering_macs() {
        // merged < lrd < orig; branched < lrd (Table 3/6 shape)
        let a = arch("resnet152");
        let m = |v| count_macs(&a, &plan_variant(&a, v, 2.0, 4, None).unwrap(), 224);
        let (orig, lrd, merged, branched) =
            (m(Variant::Orig), m(Variant::Lrd), m(Variant::Merged), m(Variant::Branched));
        assert!(merged < lrd && lrd < orig);
        assert!(branched < lrd);
        // Table 1: LRD roughly halves FLOPs
        let ratio = lrd as f64 / orig as f64;
        assert!((0.40..0.60).contains(&ratio), "{ratio}");
    }

    #[test]
    fn merged_restores_depth() {
        let a = arch("resnet50");
        let p = plan_variant(&a, Variant::Merged, 2.0, 4, None).unwrap();
        assert_eq!(count_layers(&a, &p), 50);
    }

    #[test]
    fn chain_variant_counts_hand_computed() {
        // one 64x64x3x3 conv site under each new scheme, checked against
        // closed-form counts (satellite of the factor-chain refactor)
        use crate::model::ConvSite;
        let t = ConvSite {
            name: "t".into(),
            c: 64,
            s: 64,
            k: 3,
            stride: 1,
            padding: 1,
            kind: SiteKind::Conv,
        };
        let t2 = FactorChain::of(&t, &Scheme::Tucker2 { r1: 38, r2: 38 }).unwrap();
        assert_eq!(t2.params(), 64 * 38 + 38 * 38 * 9 + 38 * 64);
        assert_eq!(t2.macs(49), 49 * (64 * 38 + 38 * 38 * 9 + 38 * 64));
        let cp = FactorChain::of(&t, &Scheme::Cp { r: 137 }).unwrap();
        assert_eq!(cp.params(), 137 * (64 + 64 + 2 * 3));
        assert_eq!(cp.macs(49), 49 * 137 * (64 + 64 + 2 * 3));
    }

    #[test]
    fn chain_variants_compress_params_near_alpha() {
        // the family plans must land near the requested 2x on whole nets
        let a = arch("resnet50");
        let orig =
            count_params(&a, &plan_variant(&a, Variant::Orig, 2.0, 4, None).unwrap());
        for v in [Variant::Tucker2, Variant::Cp] {
            let p = count_params(&a, &plan_variant(&a, v, 2.0, 4, None).unwrap());
            let ratio = orig as f64 / p as f64;
            assert!((1.5..2.6).contains(&ratio), "{v:?}: ratio {ratio}");
        }
    }

    #[test]
    fn sparse_wrapper_costs_add_the_residual_arm() {
        use crate::decompose::{plan_variant_with, SchemeFamily};
        let a = arch("resnet-mini");
        let base = plan_variant(&a, Variant::Lrd, 2.0, 4, None).unwrap();
        let sp = plan_variant_with(
            &a,
            Variant::Lrd,
            SchemeFamily::Svd,
            2.0,
            4,
            None,
            Some(50_000),
        )
        .unwrap();
        // layer count is untouched: the residual is a branch, not a layer
        assert_eq!(count_layers(&a, &sp), count_layers(&a, &base));
        // params grow by exactly 2*nnz per wrapped site (vals + idx)
        let extra: usize = a
            .sites()
            .iter()
            .filter(|t| matches!(sp[&t.name], Scheme::Sparse { .. }))
            .map(|t| 2 * Scheme::sparse_nnz(t.c, t.s, t.k, 50_000))
            .sum();
        assert!(extra > 0);
        assert_eq!(count_params(&a, &sp), count_params(&a, &base) + extra);
        // macs grow by exactly nnz * out_area per wrapped site
        let spat = spatial_map(&a, 32);
        let extra_macs: usize = a
            .sites()
            .iter()
            .filter(|t| matches!(sp[&t.name], Scheme::Sparse { .. }))
            .map(|t| {
                let (h, w) = spat[&t.name];
                h * w * Scheme::sparse_nnz(t.c, t.s, t.k, 50_000)
            })
            .sum();
        assert_eq!(count_macs(&a, &sp, 32), count_macs(&a, &base, 32) + extra_macs);
    }

    #[test]
    fn spmm_pricing_is_cheaper_after_contraction() {
        assert_eq!(spmm_unit_cost(16, false), 16.0);
        assert_eq!(spmm_unit_cost(16, true), 8.0);
        assert_eq!(spmm_unit_cost(0, false), 1.0); // degenerate lane clamps
    }

    #[test]
    fn tile_efficiency_cliff() {
        // Fig. 2: 256 is perfectly tiled, 257 wastes almost a full tile
        assert_eq!(tile_efficiency(256, 128), 1.0);
        assert!(tile_efficiency(257, 128) < 0.67);
        assert!(tile_efficiency(0, 128) == 0.0);
        assert!((tile_efficiency(308, 8) - 308.0 / 312.0).abs() < 1e-12);
    }

    #[test]
    fn spatial_map_resnet50_at_224() {
        let a = arch("resnet50");
        let sp = spatial_map(&a, 224);
        assert_eq!(sp["stem.conv"], (112, 112));
        assert_eq!(sp["layer1.0.conv1"], (56, 56));
        assert_eq!(sp["layer2.0.conv1"], (56, 56)); // pre-stride resolution
        assert_eq!(sp["layer2.0.conv2"], (28, 28));
        assert_eq!(sp["layer4.2.conv3"], (7, 7));
    }

    #[test]
    fn vmem_estimate_sane() {
        let b = lowrank_vmem_bytes(128, 512, 256, 512);
        assert!(b > 0 && b < 16 * 1024 * 1024, "{b}");
    }

    #[test]
    fn fit_effective_lane_recovers_the_generating_lane() {
        // Synthesize rates from the model itself at lane 8 / 40 GFLOP/s
        // peak; dims straddle tile boundaries so lanes are separable.
        let dims = [3usize, 7, 8, 12, 16, 23, 57, 64, 100, 129];
        let pts: Vec<(usize, f64)> =
            dims.iter().map(|&d| (d, 40e9 * tile_efficiency(d, 8))).collect();
        let (lane, peak, resid) = fit_effective_lane(&pts).unwrap();
        assert_eq!(lane, 8);
        assert!((peak - 40e9).abs() / 40e9 < 1e-9, "peak {peak}");
        assert!(resid < 1e-9, "residual {resid}");
        // degenerate inputs
        assert!(fit_effective_lane(&[]).is_none());
        assert!(fit_effective_lane(&[(0, 1.0), (4, f64::NAN), (4, -1.0)]).is_none());
    }
}
