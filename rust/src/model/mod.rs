//! Model IR: architecture descriptions and decomposable weight sites.
//!
//! Mirrors `python/compile/resnet.py` exactly — the two sides are kept in
//! sync by pinned tests (Table 2 shapes, Table 1 layer counts) so rust can
//! plan/cost/build variants without touching python.

pub mod cost;

/// What role a site plays in the network.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SiteKind {
    Stem,
    Conv,
    Downsample,
    Fc,
}

/// One decomposable weight site (conv or fc).
#[derive(Clone, Debug)]
pub struct ConvSite {
    pub name: String,
    /// input channels (fc: input features)
    pub c: usize,
    /// output channels (fc: classes)
    pub s: usize,
    /// kernel size (1 for fc)
    pub k: usize,
    pub stride: usize,
    pub padding: usize,
    pub kind: SiteKind,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockKind {
    Basic,
    Bottleneck,
}

/// Architecture family descriptor (ResNet-style).
#[derive(Clone, Debug)]
pub struct Arch {
    pub name: &'static str,
    pub block: BlockKind,
    pub layers: [usize; 4],
    pub width: usize,
    pub expansion: usize,
    pub classes: usize,
}

impl Arch {
    pub fn by_name(name: &str) -> Option<Arch> {
        let a = |name, block, layers, width, expansion, classes| Arch {
            name,
            block,
            layers,
            width,
            expansion,
            classes,
        };
        Some(match name {
            "resnet18" => a("resnet18", BlockKind::Basic, [2, 2, 2, 2], 64, 1, 1000),
            "resnet34" => a("resnet34", BlockKind::Basic, [3, 4, 6, 3], 64, 1, 1000),
            "resnet50" => a("resnet50", BlockKind::Bottleneck, [3, 4, 6, 3], 64, 4, 1000),
            "resnet101" => {
                a("resnet101", BlockKind::Bottleneck, [3, 4, 23, 3], 64, 4, 1000)
            }
            "resnet152" => {
                a("resnet152", BlockKind::Bottleneck, [3, 8, 36, 3], 64, 4, 1000)
            }
            "resnet-mini" => {
                a("resnet-mini", BlockKind::Bottleneck, [1, 1, 1, 1], 16, 4, 10)
            }
            _ => return None,
        })
    }

    pub fn all_names() -> &'static [&'static str] {
        &["resnet18", "resnet34", "resnet50", "resnet101", "resnet152", "resnet-mini"]
    }

    pub fn stage_widths(&self) -> [usize; 4] {
        [self.width, 2 * self.width, 4 * self.width, 8 * self.width]
    }

    /// Enumerate every decomposable site, torch-style names (paper Table 2).
    pub fn sites(&self) -> Vec<ConvSite> {
        let mut out = vec![ConvSite {
            name: "stem.conv".into(),
            c: 3,
            s: self.width,
            k: 7,
            stride: 2,
            padding: 3,
            kind: SiteKind::Stem,
        }];
        let mut c_in = self.width;
        for (si, (&n_blocks, &w)) in
            self.layers.iter().zip(self.stage_widths().iter()).enumerate()
        {
            let stage_stride = if si == 0 { 1 } else { 2 };
            let c_out = match self.block {
                BlockKind::Bottleneck => w * self.expansion,
                BlockKind::Basic => w,
            };
            for bi in 0..n_blocks {
                let pre = format!("layer{}.{}", si + 1, bi);
                let blk_stride = if bi == 0 { stage_stride } else { 1 };
                match self.block {
                    BlockKind::Bottleneck => {
                        out.push(site(&pre, "conv1", c_in, w, 1, 1, 0));
                        out.push(site(&pre, "conv2", w, w, 3, blk_stride, 1));
                        out.push(site(&pre, "conv3", w, c_out, 1, 1, 0));
                    }
                    BlockKind::Basic => {
                        out.push(site(&pre, "conv1", c_in, w, 3, blk_stride, 1));
                        out.push(site(&pre, "conv2", w, w, 3, 1, 1));
                    }
                }
                if bi == 0 && (blk_stride != 1 || c_in != c_out) {
                    out.push(ConvSite {
                        name: format!("{pre}.downsample"),
                        c: c_in,
                        s: c_out,
                        k: 1,
                        stride: blk_stride,
                        padding: 0,
                        kind: SiteKind::Downsample,
                    });
                }
                c_in = c_out;
            }
        }
        out.push(ConvSite {
            name: "fc".into(),
            c: c_in,
            s: self.classes,
            k: 1,
            stride: 1,
            padding: 0,
            kind: SiteKind::Fc,
        });
        out
    }
}

fn site(
    pre: &str,
    nm: &str,
    c: usize,
    s: usize,
    k: usize,
    stride: usize,
    padding: usize,
) -> ConvSite {
    ConvSite {
        name: format!("{pre}.{nm}"),
        c,
        s,
        k,
        stride,
        padding,
        kind: SiteKind::Conv,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_site_inventory() {
        let a = Arch::by_name("resnet50").unwrap();
        let s = a.sites();
        let convs = s
            .iter()
            .filter(|t| matches!(t.kind, SiteKind::Stem | SiteKind::Conv))
            .count();
        assert_eq!(convs, 1 + 16 * 3);
        assert_eq!(s.iter().filter(|t| t.kind == SiteKind::Downsample).count(), 4);
        let fc = s.last().unwrap();
        assert_eq!((fc.c, fc.s), (2048, 1000));
    }

    #[test]
    fn table2_shapes_resnet152() {
        let a = Arch::by_name("resnet152").unwrap();
        let by: std::collections::HashMap<_, _> =
            a.sites().into_iter().map(|t| (t.name.clone(), t)).collect();
        assert_eq!((by["layer1.0.conv1"].c, by["layer1.0.conv1"].s), (64, 64));
        assert_eq!((by["layer1.0.conv2"].c, by["layer1.0.conv2"].s), (64, 64));
        assert_eq!((by["layer1.0.conv3"].c, by["layer1.0.conv3"].s), (64, 256));
        assert_eq!((by["layer4.2.conv1"].c, by["layer4.2.conv1"].s), (2048, 512));
        assert_eq!((by["layer4.2.conv2"].c, by["layer4.2.conv2"].s), (512, 512));
        assert_eq!((by["layer4.2.conv3"].c, by["layer4.2.conv3"].s), (512, 2048));
    }

    #[test]
    fn stride_on_conv2_in_bottleneck() {
        let a = Arch::by_name("resnet50").unwrap();
        let by: std::collections::HashMap<_, _> =
            a.sites().into_iter().map(|t| (t.name.clone(), t)).collect();
        assert_eq!(by["layer2.0.conv2"].stride, 2);
        assert_eq!(by["layer2.0.conv1"].stride, 1);
        assert_eq!(by["layer2.0.downsample"].stride, 2);
        assert_eq!(by["layer3.1.conv2"].stride, 1);
    }

    #[test]
    fn unknown_arch_is_none() {
        assert!(Arch::by_name("resnet1001").is_none());
    }

    #[test]
    fn basic_block_arch() {
        let a = Arch::by_name("resnet18").unwrap();
        let s = a.sites();
        // 1 stem + 8 blocks x 2 convs + 3 downsamples + fc
        assert_eq!(s.len(), 1 + 16 + 3 + 1);
        assert_eq!(s.last().unwrap().c, 512);
    }
}
