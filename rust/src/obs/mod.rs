//! Cross-layer observability: spans, per-step execution profiles, and
//! Chrome trace-event export.
//!
//! Two data paths, deliberately separate:
//!
//! 1. **Spans** — coarse pipeline stages (compile passes, plan/arena
//!    build, verifier stages, train steps, the serve request path). Each
//!    instrumented thread appends finished spans to a *thread-local*
//!    buffer ([`SpanGuard`] / [`event_from`]) and flushes it to the
//!    global sink at coarse boundaries ([`flush_thread`]) — the shared
//!    `Mutex` is touched once per flush, never per span. With the sink
//!    disabled (the default) every entry point is a single relaxed
//!    atomic load and no allocation.
//!
//! 2. **Execution profiles** — per-`Step` wall time with analytic
//!    MAC/byte attribution ([`ExecProfile`]). These are *not* routed
//!    through the global sink: the native executor owns its
//!    [`ProfileState`] (one mutex acquisition per `run`, after the step
//!    loop) and the worker pool records per-chunk events into lock-free
//!    per-chunk slots that are drained after the completion barrier. The
//!    kernel inner loops are never instrumented — profiling wraps the
//!    unchanged kernel calls with clock reads, so enabling it cannot
//!    perturb partitioning or accumulation order (the bitwise-determinism
//!    regression in `tests/obs_profile.rs`).
//!
//! Both paths export to the Chrome trace-event JSON format
//! ([`chrome_trace`]), loadable in Perfetto / `chrome://tracing`.

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::util::json::Json;

// --------------------------------------------------------------------------
// Global span sink
// --------------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static GLOBAL: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// Flush the thread-local buffer into the global sink once it holds this
/// many spans (bounds per-thread memory without per-span lock traffic).
const LOCAL_FLUSH: usize = 1024;

thread_local! {
    static LOCAL: RefCell<Vec<TraceEvent>> = const { RefCell::new(Vec::new()) };
    static TID: Cell<u64> = const { Cell::new(0) };
}

/// Turn the span sink on (idempotent). Timestamps are microseconds since
/// the first call to `enable`/`epoch` in the process.
pub fn enable() {
    epoch();
    ENABLED.store(true, Ordering::Release);
}

/// Turn the span sink off. Buffered spans stay until [`drain`].
pub fn disable() {
    ENABLED.store(false, Ordering::Release);
}

/// Is the span sink collecting? One relaxed-ish atomic load — the cost of
/// every instrumentation point when tracing is off.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Acquire)
}

/// The process-wide trace epoch (first use wins).
pub fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds elapsed since the trace epoch.
pub fn now_us() -> f64 {
    epoch().elapsed().as_secs_f64() * 1e6
}

/// Stable small integer identifying the calling thread in trace exports.
pub fn tid() -> u64 {
    TID.with(|t| {
        let v = t.get();
        if v != 0 {
            return v;
        }
        let v = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        t.set(v);
        v
    })
}

/// Move this thread's buffered spans into the global sink (one lock).
/// Instrumented threads call this at coarse boundaries — after a compile,
/// after a served batch — never on the kernel path.
pub fn flush_thread() {
    let local: Vec<TraceEvent> = LOCAL.with(|l| std::mem::take(&mut *l.borrow_mut()));
    if local.is_empty() {
        return;
    }
    GLOBAL.lock().expect("obs sink").extend(local);
}

/// Take every span flushed so far (plus the calling thread's buffer).
/// Spans buffered on *other* live threads are not stolen — they arrive at
/// those threads' next flush.
pub fn drain() -> Vec<TraceEvent> {
    flush_thread();
    std::mem::take(&mut *GLOBAL.lock().expect("obs sink"))
}

/// Append pre-built events — e.g. an [`ExecProfile`]'s per-step rows —
/// to the global sink so the next [`drain`] exports them alongside the
/// live spans. No-op while the sink is disabled.
pub fn inject(events: Vec<TraceEvent>) {
    if !enabled() || events.is_empty() {
        return;
    }
    GLOBAL.lock().expect("obs sink").extend(events);
}

fn push_event(e: TraceEvent) {
    let full = LOCAL.with(|l| {
        let mut b = l.borrow_mut();
        b.push(e);
        b.len() >= LOCAL_FLUSH
    });
    if full {
        flush_thread();
    }
}

/// One complete ("ph":"X") trace event.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub name: String,
    /// Category — "compile", "exec", "serve", "train", "verify", ...
    pub cat: &'static str,
    pub tid: u64,
    /// Microseconds since [`epoch`].
    pub ts_us: f64,
    pub dur_us: f64,
    pub args: Vec<(String, Json)>,
}

/// RAII span: measures from construction to drop and appends to the
/// thread-local buffer. Inert (no allocation, no clock read beyond one
/// atomic load) when the sink is disabled.
pub struct SpanGuard {
    name: Option<String>,
    cat: &'static str,
    t0: Instant,
}

/// Open a span named `name`. Prefer [`span_with`] when the name needs
/// formatting — the closure is only run when the sink is enabled.
pub fn span(name: &str, cat: &'static str) -> SpanGuard {
    span_with(|| name.to_string(), cat)
}

/// Open a span with a lazily-built name (skips the allocation when the
/// sink is off).
pub fn span_with(name: impl FnOnce() -> String, cat: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { name: None, cat, t0: epoch() };
    }
    SpanGuard { name: Some(name()), cat, t0: Instant::now() }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(name) = self.name.take() else { return };
        let dur_us = self.t0.elapsed().as_secs_f64() * 1e6;
        let ts_us = self.t0.duration_since(epoch()).as_secs_f64() * 1e6;
        push_event(TraceEvent { name, cat: self.cat, tid: tid(), ts_us, dur_us, args: Vec::new() });
    }
}

/// Record an already-measured interval (for call sites that time a stage
/// themselves, like the pass pipeline's `record_pass`). No-op when the
/// sink is disabled — but guard the `format!` building `name` with
/// [`enabled`] at the call site to keep the off path allocation-free.
pub fn event_from(name: &str, cat: &'static str, t0: Instant, dur: Duration) {
    if !enabled() {
        return;
    }
    push_event(TraceEvent {
        name: name.to_string(),
        cat,
        tid: tid(),
        ts_us: t0.duration_since(epoch()).as_secs_f64() * 1e6,
        dur_us: dur.as_secs_f64() * 1e6,
        args: Vec::new(),
    });
}

// --------------------------------------------------------------------------
// Execution profiles (native executor)
// --------------------------------------------------------------------------

/// Static attribution for one plan step, built by the planner in lockstep
/// with `ExecPlan::steps`. `site` maps the step back to the parameter
/// site that feeds it (`conv2.w0`, `conv2.s`, ...) so decomposed factors,
/// residual taps and merged siblings are separately attributable.
#[derive(Clone, Debug)]
pub struct StepMeta {
    /// Graph node this step computes.
    pub node: usize,
    /// Kernel kind ("dot", "spmm", "bin", ...).
    pub op: &'static str,
    /// Nearest parameter site feeding this step, or "(activations)".
    pub site: String,
    /// Analytic multiply-accumulates per execution (0 for non-contraction
    /// kernels).
    pub macs: usize,
    /// Bytes moved per execution (inputs read + output written, f32).
    pub bytes: usize,
    /// The lane-gated dimension the cost model tiles over (`n` for dot,
    /// 1 for the scalar-rate spmm, 0 when not applicable).
    pub gate: usize,
}

/// One timed step execution (microseconds since [`epoch`]).
#[derive(Clone, Copy, Debug)]
pub struct StepSample {
    pub step: usize,
    pub ts_us: f64,
    pub dur_us: f64,
}

/// One pool chunk dispatched while profiling: which worker lane ran which
/// chunk of which step, and when.
#[derive(Clone, Copy, Debug)]
pub struct ChunkEvent {
    pub step: usize,
    pub chunk: usize,
    /// Pool lane (0 = the calling thread, 1.. = workers).
    pub lane: usize,
    pub ts_us: f64,
    pub dur_us: f64,
}

/// Accumulated timing for one plan step across runs.
#[derive(Clone, Copy, Debug)]
pub struct StepAgg {
    pub calls: u64,
    pub total_secs: f64,
    pub min_secs: f64,
}

impl StepAgg {
    fn new() -> StepAgg {
        StepAgg { calls: 0, total_secs: 0.0, min_secs: f64::INFINITY }
    }

    fn add(&mut self, secs: f64) {
        self.calls += 1;
        self.total_secs += secs;
        if secs < self.min_secs {
            self.min_secs = secs;
        }
    }
}

/// Raw samples kept for trace export are capped so long profiled serves
/// don't grow without bound; the per-step aggregates keep counting.
const SAMPLE_CAP: usize = 65_536;
const CHUNK_CAP: usize = 65_536;
const SPAN_CAP: usize = 8_192;

/// Mutable profiling state owned by one executable (behind its own
/// mutex, locked once per run *after* the step loop).
#[derive(Debug, Default)]
pub struct ProfileState {
    pub runs: u64,
    pub run_secs: f64,
    /// (ts_us, dur_us) of each run, capped at `SPAN_CAP`.
    pub run_spans: Vec<(f64, f64)>,
    /// Per-step aggregates, indexed like `ExecPlan::steps`.
    pub agg: Vec<StepAgg>,
    /// Raw step samples for trace export, capped at `SAMPLE_CAP`.
    pub samples: Vec<StepSample>,
    /// Raw pool chunk events, capped at `CHUNK_CAP`.
    pub chunks: Vec<ChunkEvent>,
}

impl ProfileState {
    pub fn new(n_steps: usize) -> ProfileState {
        ProfileState { agg: vec![StepAgg::new(); n_steps], ..ProfileState::default() }
    }

    /// Fold one run's measurements in (one call per `run`, under the
    /// state's own lock — the step loop itself takes no locks).
    pub fn record_run(
        &mut self,
        ts_us: f64,
        dur_secs: f64,
        samples: Vec<StepSample>,
        chunks: Vec<ChunkEvent>,
    ) {
        self.runs += 1;
        self.run_secs += dur_secs;
        for s in &samples {
            if let Some(a) = self.agg.get_mut(s.step) {
                a.add(s.dur_us * 1e-6);
            }
        }
        if self.run_spans.len() < SPAN_CAP {
            self.run_spans.push((ts_us, dur_secs * 1e6));
        }
        let room = SAMPLE_CAP.saturating_sub(self.samples.len());
        self.samples.extend(samples.into_iter().take(room));
        let room = CHUNK_CAP.saturating_sub(self.chunks.len());
        self.chunks.extend(chunks.into_iter().take(room));
    }
}

/// Immutable snapshot of an executable's profile, with the plan's step
/// attribution attached — what `Compiled::profile()` returns.
#[derive(Clone, Debug)]
pub struct ExecProfile {
    pub graph: String,
    pub meta: Vec<StepMeta>,
    pub runs: u64,
    /// Total wall seconds inside `run` across all runs.
    pub run_secs: f64,
    pub run_spans: Vec<(f64, f64)>,
    pub steps: Vec<StepAgg>,
    pub samples: Vec<StepSample>,
    pub chunks: Vec<ChunkEvent>,
}

/// Per-(site, op) aggregate over the plan steps attributed to it.
#[derive(Clone, Debug)]
pub struct SiteAgg {
    pub site: String,
    pub op: &'static str,
    /// Distinct plan steps folded into this row.
    pub steps: usize,
    pub calls: u64,
    pub total_secs: f64,
    /// Total analytic MACs executed (per-step MACs x calls).
    pub macs_total: u64,
    pub bytes_total: u64,
    /// Representative (max) lane-gate dimension among the grouped steps.
    pub gate: usize,
}

impl SiteAgg {
    /// Measured MAC throughput in GFLOP/s (2 flops per MAC).
    pub fn gflops(&self) -> f64 {
        if self.total_secs > 0.0 {
            2.0 * self.macs_total as f64 / self.total_secs / 1e9
        } else {
            0.0
        }
    }

    /// Mean milliseconds spent in this row per run.
    pub fn ms_per_run(&self, runs: u64) -> f64 {
        if runs == 0 {
            0.0
        } else {
            self.total_secs * 1e3 / runs as f64
        }
    }
}

/// Synthetic trace rows: the executor's step timeline and one row per
/// pool lane, so chunk events sit visually under their step span.
pub const EXEC_TID: u64 = 100;
pub const LANE_TID_BASE: u64 = 101;

impl ExecProfile {
    /// Sum of per-step wall time (the numerator of [`coverage`]).
    ///
    /// [`coverage`]: ExecProfile::coverage
    pub fn step_secs(&self) -> f64 {
        self.steps.iter().map(|a| a.total_secs).sum()
    }

    /// Fraction of end-to-end run time accounted for by step timings —
    /// the CI gate asserts >= 0.9 (the remainder is arg validation, the
    /// arena lock and root routing).
    pub fn coverage(&self) -> f64 {
        if self.run_secs > 0.0 {
            self.step_secs() / self.run_secs
        } else {
            0.0
        }
    }

    /// Group step aggregates by (parameter site, op kind), heaviest
    /// first.
    pub fn by_site(&self) -> Vec<SiteAgg> {
        let mut map: BTreeMap<(String, &'static str), SiteAgg> = BTreeMap::new();
        for (i, m) in self.meta.iter().enumerate() {
            let Some(a) = self.steps.get(i) else { continue };
            if a.calls == 0 {
                continue;
            }
            let e = map.entry((m.site.clone(), m.op)).or_insert_with(|| SiteAgg {
                site: m.site.clone(),
                op: m.op,
                steps: 0,
                calls: 0,
                total_secs: 0.0,
                macs_total: 0,
                bytes_total: 0,
                gate: 0,
            });
            e.steps += 1;
            e.calls += a.calls;
            e.total_secs += a.total_secs;
            e.macs_total += m.macs as u64 * a.calls;
            e.bytes_total += m.bytes as u64 * a.calls;
            e.gate = e.gate.max(m.gate);
        }
        let mut v: Vec<SiteAgg> = map.into_values().collect();
        v.sort_by(|a, b| {
            b.total_secs.partial_cmp(&a.total_secs).unwrap_or(std::cmp::Ordering::Equal)
        });
        v
    }

    /// Group step aggregates by op kind alone, heaviest first — the
    /// per-kernel throughput attribution (`SiteAgg::gflops` on a "dot"
    /// row is the measured packed-GEMM rate, on "spmm" the CSR rate, and
    /// so on), with `site` carrying the op name. `lrdx profile`'s
    /// lane-fit calibration consumes the same grouping.
    pub fn by_op(&self) -> Vec<SiteAgg> {
        let mut map: BTreeMap<&'static str, SiteAgg> = BTreeMap::new();
        for (i, m) in self.meta.iter().enumerate() {
            let Some(a) = self.steps.get(i) else { continue };
            if a.calls == 0 {
                continue;
            }
            let e = map.entry(m.op).or_insert_with(|| SiteAgg {
                site: m.op.to_string(),
                op: m.op,
                steps: 0,
                calls: 0,
                total_secs: 0.0,
                macs_total: 0,
                bytes_total: 0,
                gate: 0,
            });
            e.steps += 1;
            e.calls += a.calls;
            e.total_secs += a.total_secs;
            e.macs_total += m.macs as u64 * a.calls;
            e.bytes_total += m.bytes as u64 * a.calls;
            e.gate = e.gate.max(m.gate);
        }
        let mut v: Vec<SiteAgg> = map.into_values().collect();
        v.sort_by(|a, b| {
            b.total_secs.partial_cmp(&a.total_secs).unwrap_or(std::cmp::Ordering::Equal)
        });
        v
    }

    /// Render the profile as complete trace events (runs, steps, chunks)
    /// for merging into a Chrome trace export.
    pub fn trace_events(&self) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        for (ri, &(ts_us, dur_us)) in self.run_spans.iter().enumerate() {
            out.push(TraceEvent {
                name: format!("run:{}", self.graph),
                cat: "exec",
                tid: EXEC_TID,
                ts_us,
                dur_us,
                args: vec![("run".into(), Json::Num(ri as f64))],
            });
        }
        for s in &self.samples {
            let (name, macs) = match self.meta.get(s.step) {
                Some(m) => (format!("{}:{}", m.op, m.site), m.macs),
                None => (format!("step{}", s.step), 0),
            };
            out.push(TraceEvent {
                name,
                cat: "step",
                tid: EXEC_TID,
                ts_us: s.ts_us,
                dur_us: s.dur_us,
                args: vec![
                    ("step".into(), Json::Num(s.step as f64)),
                    ("macs".into(), Json::Num(macs as f64)),
                ],
            });
        }
        for c in &self.chunks {
            out.push(TraceEvent {
                name: format!("chunk{}", c.chunk),
                cat: "chunk",
                tid: LANE_TID_BASE + c.lane as u64,
                ts_us: c.ts_us,
                dur_us: c.dur_us,
                args: vec![("step".into(), Json::Num(c.step as f64))],
            });
        }
        out
    }
}

// --------------------------------------------------------------------------
// Chrome trace-event export
// --------------------------------------------------------------------------

/// Serialize events as a Chrome trace-event JSON document (the
/// `{"traceEvents": [...]}` object form; load in Perfetto or
/// `chrome://tracing`).
pub fn chrome_trace(events: &[TraceEvent]) -> Json {
    Json::obj_from(vec![(
        "traceEvents",
        Json::Arr(events.iter().map(trace_event_json).collect()),
    )])
}

fn trace_event_json(e: &TraceEvent) -> Json {
    let mut pairs = vec![
        ("ph", Json::Str("X".into())),
        ("name", Json::Str(e.name.clone())),
        ("cat", Json::Str(e.cat.into())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(e.tid as f64)),
        ("ts", Json::Num(e.ts_us)),
        ("dur", Json::Num(e.dur_us)),
    ];
    if !e.args.is_empty() {
        let obj: BTreeMap<String, Json> =
            e.args.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        pairs.push(("args", Json::Obj(obj)));
    }
    Json::obj_from(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_sink_records_nothing() {
        // default-off: guards are inert (other tests may have enabled the
        // sink concurrently, so only assert when it is actually off)
        if !enabled() {
            let _s = span("obs-test-should-not-appear", "test");
            drop(_s);
            let got = drain();
            assert!(
                got.iter().all(|e| e.name != "obs-test-should-not-appear"),
                "disabled sink must drop spans"
            );
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // clock reads are unsupported under isolation
    fn spans_flush_and_drain() {
        enable();
        {
            let _outer = span("obs-test-outer", "test");
            let _inner = span("obs-test-inner", "test");
        }
        let got = drain();
        disable();
        let mine: Vec<&TraceEvent> =
            got.iter().filter(|e| e.name.starts_with("obs-test-")).collect();
        assert_eq!(mine.len(), 2, "both spans recorded");
        for e in &mine {
            assert!(e.dur_us >= 0.0);
            assert!(e.ts_us >= 0.0);
            assert_eq!(e.cat, "test");
            assert_eq!(e.tid, tid());
        }
        // inner closed before outer => inner's interval nests inside
        let inner = mine.iter().find(|e| e.name == "obs-test-inner").unwrap();
        let outer = mine.iter().find(|e| e.name == "obs-test-outer").unwrap();
        assert!(inner.ts_us >= outer.ts_us);
        assert!(inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us + 1.0);
    }

    #[test]
    fn chrome_trace_round_trips_through_json() {
        let events = vec![
            TraceEvent {
                name: "compile:g".into(),
                cat: "compile",
                tid: 1,
                ts_us: 10.5,
                dur_us: 100.0,
                args: vec![("nodes".into(), Json::Num(42.0))],
            },
            TraceEvent {
                name: "dot:conv2.w0".into(),
                cat: "step",
                tid: EXEC_TID,
                ts_us: 120.0,
                dur_us: 7.25,
                args: Vec::new(),
            },
        ];
        let doc = chrome_trace(&events);
        let back = Json::parse(&doc.render()).expect("rendered trace parses");
        let arr = match back.get("traceEvents").unwrap() {
            Json::Arr(v) => v,
            other => panic!("traceEvents not an array: {other:?}"),
        };
        assert_eq!(arr.len(), 2);
        for (e, j) in events.iter().zip(arr) {
            assert_eq!(j.get("ph").unwrap(), &Json::Str("X".into()));
            assert_eq!(j.get("name").unwrap(), &Json::Str(e.name.clone()));
            assert_eq!(j.get("cat").unwrap(), &Json::Str(e.cat.into()));
            assert_eq!(j.get("pid").unwrap(), &Json::Num(0.0));
            assert_eq!(j.get("tid").unwrap(), &Json::Num(e.tid as f64));
            assert_eq!(j.get("ts").unwrap(), &Json::Num(e.ts_us));
            assert_eq!(j.get("dur").unwrap(), &Json::Num(e.dur_us));
        }
        assert_eq!(
            arr[0].get("args").unwrap().get("nodes").unwrap(),
            &Json::Num(42.0)
        );
        assert!(arr[1].get("args").is_err(), "empty args omitted");
    }

    #[test]
    fn profile_state_aggregates_and_caps() {
        let mut st = ProfileState::new(2);
        st.record_run(
            0.0,
            0.001,
            vec![
                StepSample { step: 0, ts_us: 0.0, dur_us: 400.0 },
                StepSample { step: 1, ts_us: 400.0, dur_us: 500.0 },
            ],
            vec![ChunkEvent { step: 1, chunk: 0, lane: 1, ts_us: 410.0, dur_us: 100.0 }],
        );
        st.record_run(
            1000.0,
            0.002,
            vec![
                StepSample { step: 0, ts_us: 1000.0, dur_us: 800.0 },
                StepSample { step: 1, ts_us: 1800.0, dur_us: 1100.0 },
            ],
            Vec::new(),
        );
        assert_eq!(st.runs, 2);
        assert_eq!(st.agg[0].calls, 2);
        assert!((st.agg[0].total_secs - 1.2e-3).abs() < 1e-9);
        assert!((st.agg[0].min_secs - 4e-4).abs() < 1e-9);
        assert_eq!(st.samples.len(), 4);
        assert_eq!(st.chunks.len(), 1);

        let p = ExecProfile {
            graph: "g".into(),
            meta: vec![
                StepMeta {
                    node: 0,
                    op: "dot",
                    site: "conv1.w".into(),
                    macs: 1000,
                    bytes: 64,
                    gate: 8,
                },
                StepMeta {
                    node: 1,
                    op: "unary",
                    site: "(activations)".into(),
                    macs: 0,
                    bytes: 32,
                    gate: 0,
                },
            ],
            runs: st.runs,
            run_secs: st.run_secs,
            run_spans: st.run_spans.clone(),
            steps: st.agg.clone(),
            samples: st.samples.clone(),
            chunks: st.chunks.clone(),
        };
        // steps were timed inside the run span: sum <= run total
        assert!(p.step_secs() <= p.run_secs + 1e-9);
        assert!(p.coverage() > 0.9, "coverage {}", p.coverage());
        let sites = p.by_site();
        assert_eq!(sites.len(), 2);
        assert_eq!(sites[0].site, "(activations)", "heaviest first");
        let dot = sites.iter().find(|s| s.op == "dot").unwrap();
        assert_eq!(dot.macs_total, 2000);
        assert!(dot.gflops() > 0.0);
        let ev = p.trace_events();
        // 2 run spans + 4 step samples + 1 chunk
        assert_eq!(ev.len(), 7);
        assert!(ev.iter().any(|e| e.name == "dot:conv1.w"));
        assert!(ev.iter().any(|e| e.tid == LANE_TID_BASE + 1));
    }
}
