//! Steady-state wall-clock profiler (the paper used the PyTorch profiler;
//! this plays the same role for Algorithm 1 and all fps tables).
//!
//! Method: `warmup` untimed runs (JIT caches, page faults), then timed
//! samples until either the coefficient of variation of the collected
//! sample drops under `cv_target` or `max_samples` is reached. The primary
//! statistic is the 80% trimmed mean (robust to scheduler noise).

use anyhow::Result;

use crate::util::stats::Summary;

#[derive(Clone, Debug)]
pub struct Timer {
    pub warmup: usize,
    pub min_samples: usize,
    pub max_samples: usize,
    pub cv_target: f64,
}

impl Default for Timer {
    fn default() -> Self {
        Timer { warmup: 3, min_samples: 10, max_samples: 50, cv_target: 0.05 }
    }
}

impl Timer {
    /// Cheaper settings for inner-loop searches (Algorithm 1 sweeps).
    pub fn quick() -> Timer {
        Timer { warmup: 2, min_samples: 5, max_samples: 15, cv_target: 0.10 }
    }

    /// Higher-confidence settings for headline numbers.
    pub fn thorough() -> Timer {
        Timer { warmup: 5, min_samples: 20, max_samples: 100, cv_target: 0.03 }
    }

    /// Measure seconds-per-call of `f` at steady state.
    pub fn measure(&self, mut f: impl FnMut() -> Result<()>) -> Result<Summary> {
        for _ in 0..self.warmup {
            f()?;
        }
        let mut samples = Vec::with_capacity(self.max_samples);
        while samples.len() < self.max_samples {
            let t0 = std::time::Instant::now();
            f()?;
            samples.push(t0.elapsed().as_secs_f64());
            if samples.len() >= self.min_samples {
                let s = Summary::of(&samples);
                if s.cv() < self.cv_target {
                    return Ok(s);
                }
            }
        }
        // max_samples exhausted without meeting cv_target: don't trust
        // this silently — flag it so harness/bench output can warn.
        let mut s = Summary::of(&samples);
        s.converged = false;
        Ok(s)
    }

    /// Throughput helper: items/second given seconds-per-call.
    pub fn fps(items_per_call: usize, sec_per_call: f64) -> f64 {
        items_per_call as f64 / sec_per_call
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_sleep() {
        let t = Timer { warmup: 1, min_samples: 3, max_samples: 5, cv_target: 0.5 };
        let s = t
            .measure(|| {
                std::thread::sleep(std::time::Duration::from_millis(2));
                Ok(())
            })
            .unwrap();
        assert!(s.trimmed_mean >= 0.002, "{}", s.trimmed_mean);
        assert!(s.trimmed_mean < 0.050);
    }

    #[test]
    fn stops_early_when_stable() {
        let t = Timer { warmup: 0, min_samples: 4, max_samples: 1000, cv_target: 0.9 };
        let mut calls = 0;
        let s = t
            .measure(|| {
                calls += 1;
                Ok(())
            })
            .unwrap();
        assert!(calls < 1000);
        assert_eq!(s.n, calls);
        assert!(s.converged, "early-exit means the CV target was met");
    }

    #[test]
    fn flags_non_convergence_at_max_samples() {
        // an impossible CV target: the loop must hit max_samples and the
        // summary must say so instead of silently looking authoritative
        let t = Timer { warmup: 0, min_samples: 2, max_samples: 6, cv_target: 0.0 };
        let mut tick = 0u32;
        let s = t
            .measure(|| {
                tick += 1;
                std::thread::sleep(std::time::Duration::from_micros(50 * tick as u64));
                Ok(())
            })
            .unwrap();
        assert_eq!(s.n, 6);
        assert!(!s.converged, "max_samples fallthrough must clear converged");
        assert!(s.cv() > 0.0, "achieved CV stays readable");
    }

    #[test]
    fn propagates_errors() {
        let t = Timer::default();
        let r = t.measure(|| anyhow::bail!("boom"));
        assert!(r.is_err());
    }

    #[test]
    fn fps_math() {
        assert_eq!(Timer::fps(8, 0.5), 16.0);
    }
}
