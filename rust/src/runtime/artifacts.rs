//! Artifact library: loads the python-AOT HLO-text modules + weights per
//! `artifacts/manifest.json` and wraps them as runnable forward/train units.
//!
//! This is the PJRT production path of the three-layer architecture:
//! python lowered the L2 jax model (with L1 pallas kernels inlined) once at
//! build time; here rust compiles the HLO and keeps every weight resident
//! on device. Compiling HLO text requires the `xla-pjrt` backend; on the
//! native backend loading reports a descriptive error and callers fall
//! back to `runtime::netbuilder` synthetic models (the integration tests
//! do exactly that).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::{Buffer, Compiled, Engine, HostTensor};
use crate::decompose::{plan_from_json, Plan};
use crate::util::json::Json;

/// One parameter (weight) of an artifact.
#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub file: PathBuf,
}

/// Parsed manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: String, // "forward" | "train"
    pub arch: String,
    pub variant: String,
    pub use_pallas: bool,
    pub hw: usize,
    pub batch: usize,
    pub classes: usize,
    pub hlo: PathBuf,
    pub params: Vec<ParamEntry>,
    pub frozen_params: Vec<ParamEntry>,
    pub plan: Plan,
    pub expected: Json,
}

/// The artifact library rooted at `artifacts/`.
pub struct ArtifactLibrary {
    pub root: PathBuf,
    pub specs: Vec<ArtifactSpec>,
}

fn parse_params(root: &Path, j: &Json) -> Result<Vec<ParamEntry>> {
    let mut out = Vec::new();
    for p in j.arr()? {
        out.push(ParamEntry {
            name: p.get("name")?.str()?.to_string(),
            shape: p
                .get("shape")?
                .arr()?
                .iter()
                .map(|d| d.num().map(|v| v as usize))
                .collect::<Result<_>>()?,
            file: root.join(p.get("file")?.str()?),
        });
    }
    Ok(out)
}

impl ArtifactLibrary {
    pub fn load(root: impl AsRef<Path>) -> Result<ArtifactLibrary> {
        let root = root.as_ref().to_path_buf();
        let manifest = Json::parse_file(&root.join("manifest.json")).context(
            "artifacts/manifest.json missing — run \
             `python python/compile/aot.py --out rust/artifacts` first",
        )?;
        let mut specs = Vec::new();
        for e in manifest.get("artifacts")?.arr()? {
            specs.push(ArtifactSpec {
                name: e.get("name")?.str()?.to_string(),
                kind: e.get("kind")?.str()?.to_string(),
                arch: e.get("arch")?.str()?.to_string(),
                variant: e.get("variant")?.str()?.to_string(),
                use_pallas: e
                    .opt("use_pallas")
                    .map(|v| v.boolean().unwrap_or(false))
                    .unwrap_or(false),
                hw: e.get("hw")?.int()? as usize,
                batch: e.get("batch")?.int()? as usize,
                classes: e.get("classes")?.int()? as usize,
                hlo: root.join(e.get("hlo")?.str()?),
                params: parse_params(&root, e.get("params")?)?,
                frozen_params: match e.opt("frozen_params") {
                    Some(j) => parse_params(&root, j)?,
                    None => Vec::new(),
                },
                plan: plan_from_json(e.get("plan")?)?,
                expected: e.get("expected")?.clone(),
            });
        }
        Ok(ArtifactLibrary { root, specs })
    }

    pub fn find(&self, name: &str) -> Result<&ArtifactSpec> {
        self.specs
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    /// Find by (arch, variant, kind), e.g. ("resnet50", "lrd", "forward").
    pub fn find_by(&self, arch: &str, variant: &str, kind: &str) -> Option<&ArtifactSpec> {
        self.specs
            .iter()
            .find(|s| s.arch == arch && s.variant == variant && s.kind == kind && !s.use_pallas)
    }

    pub fn forward_specs(&self) -> impl Iterator<Item = &ArtifactSpec> {
        self.specs.iter().filter(|s| s.kind == "forward")
    }
}

/// Read a raw little-endian f32 `.bin` weight file.
pub fn read_f32_bin(path: &Path, expect_len: usize) -> Result<Vec<f32>> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() != expect_len * 4 {
        bail!("{}: {} bytes, expected {}", path.display(), bytes.len(), expect_len * 4);
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn upload_params(engine: &Engine, entries: &[ParamEntry]) -> Result<Vec<Buffer>> {
    entries
        .iter()
        .map(|p| {
            let n: usize = p.shape.iter().product();
            let host = read_f32_bin(&p.file, n)?;
            engine.upload(&host, &p.shape)
        })
        .collect()
}

// --------------------------------------------------------------------------
// Forward artifacts
// --------------------------------------------------------------------------

/// A compiled forward artifact with weights resident on the backend.
pub struct ForwardModel {
    pub spec: ArtifactSpec,
    exe: Compiled,
    weights: Vec<Buffer>,
    engine: Engine,
}

impl ForwardModel {
    pub fn load(engine: &Engine, spec: &ArtifactSpec) -> Result<ForwardModel> {
        if spec.kind != "forward" {
            bail!("{} is a {} artifact", spec.name, spec.kind);
        }
        let exe = engine.compile_hlo_text_file(&spec.hlo)?;
        let weights = upload_params(engine, &spec.params)?;
        Ok(ForwardModel { spec: spec.clone(), exe, weights, engine: engine.clone() })
    }

    /// Load the artifact's graph but substitute custom parameter values
    /// (e.g. weights fine-tuned in rust, or a one-shot decomposition of a
    /// rust-trained original). Shapes must match the manifest.
    pub fn load_with_params(
        engine: &Engine,
        spec: &ArtifactSpec,
        params: &crate::decompose::params::Params,
    ) -> Result<ForwardModel> {
        if spec.kind != "forward" {
            bail!("{} is a {} artifact", spec.name, spec.kind);
        }
        let exe = engine.compile_hlo_text_file(&spec.hlo)?;
        let weights = spec
            .params
            .iter()
            .map(|p| {
                let t = params
                    .get(&p.name)
                    .ok_or_else(|| anyhow!("missing param {}", p.name))?;
                if t.dims != p.shape {
                    bail!("{}: got {:?}, artifact expects {:?}", p.name, t.dims, p.shape);
                }
                engine.upload(&t.data, &t.dims)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ForwardModel { spec: spec.clone(), exe, weights, engine: engine.clone() })
    }

    /// Logits for a host batch [batch, 3, hw, hw] -> [batch, classes].
    pub fn infer(&self, x: &HostTensor) -> Result<HostTensor> {
        if x.dims != [self.spec.batch, 3, self.spec.hw, self.spec.hw] {
            bail!(
                "{}: input {:?}, artifact expects [{}, 3, {}, {}]",
                self.spec.name,
                x.dims,
                self.spec.batch,
                self.spec.hw,
                self.spec.hw
            );
        }
        let xb = self.engine.upload(&x.data, &x.dims)?;
        let out = self.infer_buffer(&xb)?;
        // jax modules are lowered with return_tuple=True: `to_host`
        // unwraps the 1-tuple.
        out.to_host()
    }

    /// Backend-buffer hot path (used by the coordinator and benches).
    /// NOTE: on PJRT the returned buffer is the module's 1-tuple result;
    /// callers unwrap at host-read time (`Buffer::to_host`).
    pub fn infer_buffer(&self, x: &Buffer) -> Result<Buffer> {
        let mut args: Vec<&Buffer> = Vec::with_capacity(1 + self.weights.len());
        args.extend(self.weights.iter());
        args.push(x);
        let mut outs = self.exe.run_buffers(&args)?;
        Ok(outs.swap_remove(0))
    }

    /// Check the artifact reproduces the manifest's recorded logits for the
    /// deterministic test input. Returns max |Δ| over the recorded row.
    pub fn verify(&self) -> Result<f64> {
        let x = HostTensor::new(
            vec![self.spec.batch, 3, self.spec.hw, self.spec.hw],
            crate::util::det_input(self.spec.batch, self.spec.hw),
        );
        let logits = self.infer(&x)?;
        let want: Vec<f64> = self
            .spec
            .expected
            .get("logits_row0")?
            .arr()?
            .iter()
            .map(|v| v.num())
            .collect::<Result<_>>()?;
        let tol = self.spec.expected.get("tol")?.num()?;
        let mut max_delta = 0.0f64;
        for (i, &w) in want.iter().enumerate() {
            let g = logits.data[i] as f64;
            max_delta = max_delta.max((g - w).abs());
        }
        if max_delta > tol {
            bail!("{}: max |Δ| {max_delta} > tol {tol}", self.spec.name);
        }
        Ok(max_delta)
    }
}

// --------------------------------------------------------------------------
// Train artifacts
// --------------------------------------------------------------------------

/// A compiled train-step artifact holding the full optimizer state on
/// the backend: trainable params, frozen params, momentum velocities.
/// Each `step` feeds buffers back in — python is long gone.
pub struct TrainSession {
    pub spec: ArtifactSpec,
    exe: Compiled,
    trainable: Vec<Buffer>,
    frozen: Vec<Buffer>,
    velocity: Vec<Buffer>,
    engine: Engine,
    pub steps_done: usize,
}

impl TrainSession {
    pub fn load(engine: &Engine, spec: &ArtifactSpec) -> Result<TrainSession> {
        if spec.kind != "train" {
            bail!("{} is a {} artifact", spec.name, spec.kind);
        }
        let exe = engine.compile_hlo_text_file(&spec.hlo)?;
        let trainable = upload_params(engine, &spec.params)?;
        let frozen = upload_params(engine, &spec.frozen_params)?;
        let velocity = spec
            .params
            .iter()
            .map(|p| {
                let n: usize = p.shape.iter().product();
                let zeros = vec![0f32; n];
                engine.upload(&zeros, &p.shape)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TrainSession {
            spec: spec.clone(),
            exe,
            trainable,
            frozen,
            velocity,
            engine: engine.clone(),
            steps_done: 0,
        })
    }

    /// Load the step graph but start from custom parameter values (e.g. the
    /// decomposition of a rust-trained original, for fine-tuning).
    pub fn load_with_params(
        engine: &Engine,
        spec: &ArtifactSpec,
        params: &crate::decompose::params::Params,
    ) -> Result<TrainSession> {
        let mut sess = TrainSession::load(engine, spec)?;
        let upload = |entries: &[ParamEntry]| -> Result<Vec<Buffer>> {
            entries
                .iter()
                .map(|p| {
                    let t = params
                        .get(&p.name)
                        .ok_or_else(|| anyhow!("missing param {}", p.name))?;
                    if t.dims != p.shape {
                        bail!("{}: got {:?}, expects {:?}", p.name, t.dims, p.shape);
                    }
                    engine.upload(&t.data, &t.dims)
                })
                .collect()
        };
        sess.trainable = upload(&sess.spec.params.clone())?;
        sess.frozen = upload(&sess.spec.frozen_params.clone())?;
        Ok(sess)
    }

    /// Download the current (trainable + frozen) parameters by name.
    pub fn export_params(&self) -> Result<crate::decompose::params::Params> {
        let mut out = crate::decompose::params::Params::new();
        for (entry, buf) in self
            .spec
            .params
            .iter()
            .zip(self.trainable.iter())
            .chain(self.spec.frozen_params.iter().zip(self.frozen.iter()))
        {
            let t = buf
                .to_host()
                .map_err(|e| anyhow!("download {}: {e:#}", entry.name))?;
            out.insert(entry.name.clone(), t);
        }
        Ok(out)
    }

    /// Zero out masked entries of named trainable params (used by the
    /// magnitude-pruning baseline to keep pruned filters at zero through
    /// fine-tuning). `masks` maps param name -> keep-flags per output
    /// channel (dim 0 of the weight).
    pub fn apply_channel_masks(
        &mut self,
        masks: &std::collections::BTreeMap<String, Vec<bool>>,
    ) -> Result<()> {
        for (i, entry) in self.spec.params.clone().iter().enumerate() {
            let Some(mask) = masks.get(&entry.name) else { continue };
            let mut t = self.trainable[i]
                .to_host()
                .map_err(|e| anyhow!("download {}: {e:#}", entry.name))?;
            let span: usize = t.dims.iter().skip(1).product();
            if mask.len() != t.dims[0] {
                bail!("{}: mask len {} vs dim0 {}", entry.name, mask.len(), t.dims[0]);
            }
            for (o, keep) in mask.iter().enumerate() {
                if !keep {
                    t.data[o * span..(o + 1) * span].fill(0.0);
                }
            }
            self.trainable[i] = self.engine.upload(&t.data, &t.dims)?;
        }
        Ok(())
    }

    pub fn n_trainable(&self) -> usize {
        self.trainable.len()
    }

    pub fn n_frozen(&self) -> usize {
        self.frozen.len()
    }

    /// One SGD+momentum step on a host batch. Returns (loss, accuracy).
    pub fn step(&mut self, x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let (b, hw) = (self.spec.batch, self.spec.hw);
        if x.len() != b * 3 * hw * hw || y.len() != b {
            bail!("bad batch shapes: x={} y={}", x.len(), y.len());
        }
        let xb = self.engine.upload(x, &[b, 3, hw, hw])?;
        let yb = self.engine.upload_i32(y, &[b])?;
        let nt = self.trainable.len();
        let mut args: Vec<&Buffer> =
            Vec::with_capacity(2 * nt + self.frozen.len() + 2);
        args.extend(self.trainable.iter());
        args.extend(self.frozen.iter());
        args.extend(self.velocity.iter());
        args.push(&xb);
        args.push(&yb);
        // The AOT module was lowered with return_tuple=True; PJRT usually
        // "untuples" the result into separate buffers, otherwise we pull
        // the single tuple to the host and re-upload the state.
        let outs = self.exe.run_buffers(&args)?;
        if outs.len() == 2 * nt + 2 {
            // tuple already flattened by the backend
            let mut it = outs.into_iter();
            self.trainable = (&mut it).take(nt).collect();
            self.velocity = (&mut it).take(nt).collect();
            let loss_b = it.next().unwrap();
            let acc_b = it.next().unwrap();
            let loss = scalar_f32(&loss_b)?;
            let acc = scalar_f32(&acc_b)?;
            self.steps_done += 1;
            Ok((loss, acc))
        } else {
            // single tuple buffer: pull to host and re-upload state
            let parts = outs[0].to_host_all()?;
            if parts.len() != 2 * nt + 2 {
                bail!("train step returned {} outputs, expected {}", parts.len(), 2 * nt + 2);
            }
            for (i, part) in parts.iter().take(nt).enumerate() {
                self.trainable[i] = self.engine.upload(&part.data, &part.dims)?;
            }
            for (i, part) in parts.iter().skip(nt).take(nt).enumerate() {
                self.velocity[i] = self.engine.upload(&part.data, &part.dims)?;
            }
            let loss = parts[2 * nt].data[0];
            let acc = parts[2 * nt + 1].data[0];
            self.steps_done += 1;
            Ok((loss, acc))
        }
    }
}

fn scalar_f32(buf: &Buffer) -> Result<f32> {
    let t = buf.to_host()?;
    t.data
        .first()
        .copied()
        .ok_or_else(|| anyhow!("empty scalar buffer"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Integration tests against real artifacts live in rust/tests/; here we
    // only test the manifest parsing against a synthetic manifest.

    fn fake_manifest(dir: &Path) {
        std::fs::create_dir_all(dir.join("params/m1")).unwrap();
        std::fs::write(
            dir.join("params/m1/w.bin"),
            [1f32, 2.0, 3.0, 4.0]
                .iter()
                .flat_map(|f| f.to_le_bytes())
                .collect::<Vec<u8>>(),
        )
        .unwrap();
        std::fs::write(dir.join("m1.hlo.txt"), "HloModule fake").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"seed": 1, "artifacts": [{
                "name": "m1", "kind": "forward", "arch": "resnet-mini",
                "variant": "lrd", "use_pallas": false, "hw": 8, "batch": 1,
                "classes": 10, "groups": 1, "hlo": "m1.hlo.txt",
                "params": [{"name": "w", "shape": [2, 2], "file": "params/m1/w.bin"}],
                "plan": {"stem.conv": ["orig"], "fc": ["svd", 4]},
                "expected": {"input": "det_sin", "logits_row0": [0.1], "tol": 0.02}
            }]}"#,
        )
        .unwrap();
    }

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join(format!("lrdx_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir);
        let lib = ArtifactLibrary::load(&dir).unwrap();
        assert_eq!(lib.specs.len(), 1);
        let s = lib.find("m1").unwrap();
        assert_eq!(s.params[0].shape, vec![2, 2]);
        assert_eq!(s.hw, 8);
        assert!(matches!(
            s.plan.get("fc"),
            Some(crate::decompose::Scheme::Svd { r: 4 })
        ));
        assert!(lib.find_by("resnet-mini", "lrd", "forward").is_some());
        assert!(lib.find_by("resnet50", "lrd", "forward").is_none());
        let w = read_f32_bin(&s.params[0].file, 4).unwrap();
        assert_eq!(w, vec![1.0, 2.0, 3.0, 4.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_bin_length_checked() {
        let dir = std::env::temp_dir().join(format!("lrdx_bin_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("x.bin");
        std::fs::write(&f, [0u8; 8]).unwrap();
        assert!(read_f32_bin(&f, 2).is_ok());
        assert!(read_f32_bin(&f, 3).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn forward_model_load_fails_cleanly_on_native_backend() {
        // On the native backend the HLO path must error descriptively, not
        // panic — this is the signal the integration tests use to fall
        // back to netbuilder synthetic models.
        let dir = std::env::temp_dir().join(format!("lrdx_native_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir);
        let lib = ArtifactLibrary::load(&dir).unwrap();
        let spec = lib.find("m1").unwrap();
        let engine = Engine::native();
        let err = ForwardModel::load(&engine, spec).err().expect("must fail");
        assert!(format!("{err:#}").contains("xla-pjrt"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
