//! Reverse-mode autodiff over the graph IR.
//!
//! Given a forward `Graph` whose root is a scalar loss, `loss_and_grads`
//! appends per-op VJP (vector-Jacobian product) nodes for the requested
//! parameters and returns one **joint graph** whose root packs
//! `[loss, grad(p) for p in wrt]` into a flat vector. The joint graph is
//! a plain `Graph`: it runs through the same `passes` pipeline (constant
//! folding, CSE, DCE, **low-rank re-merge** — which recognises the
//! backward `W0ᵀ·(W1ᵀ·δ)` factor chains this module emits) and the same
//! planned arena executor as any forward computation. `train::`
//! builds the full fwd+bwd+SGD-update step on top of the same [`Tape`].
//!
//! Emission style matters for the optimizer: the tape peepholes
//! transpose-of-transpose and reshape-of-reshape away *at emission time*,
//! so the gradient flowing through a `conv1x1` factor pair comes out as
//! the pristine chain `dot(W0, dot(W1, δ, [0],[0]), [0],[0])` that
//! `passes::remerge` pattern-matches (the paper's merged training
//! scheme). `Gt` is non-differentiable by construction — `needs_grad`
//! treats it as a constant mask, so relu backward is `δ · gt(x, 0)` with
//! no dead adjoint chains behind the mask.
//!
//! The joint graph's forward/backward split (the train-segment
//! `boundary` = the forward graph's node count at adoption time) is a
//! convention the pass pipeline relies on when attributing fusions and
//! splitting executables; it is not merely assumed — with
//! `CompileOptions::verify` on, `verify::check_boundary` re-proves after
//! every pass that no node below the boundary reads one above it.

use std::collections::HashSet;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::graph::{Graph, Node, NodeId, OpKind};

// ---------------------------------------------------------------------------
// Tape: append-only node builder over an existing graph
// ---------------------------------------------------------------------------

/// A `Graph` being extended in place. Unlike `GraphBuilder` this works on
/// raw `Node`s (no `Rc` handles), can adopt a finished graph, and
/// peepholes the transpose/reshape compositions autograd emits in bulk.
pub struct Tape {
    name: String,
    nodes: Vec<Node>,
    n_params: usize,
}

impl Tape {
    /// Adopt a finished graph; returns the tape and the old root.
    pub fn from_graph(g: &Graph) -> (Tape, NodeId) {
        (
            Tape {
                name: g.name.clone(),
                nodes: g.nodes.clone(),
                n_params: g.n_params,
            },
            g.root,
        )
    }

    pub fn dims(&self, id: NodeId) -> &[usize] {
        &self.nodes[id.0].dims
    }

    pub fn n_params(&self) -> usize {
        self.n_params
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0]
    }

    pub fn push(&mut self, op: OpKind, inputs: Vec<NodeId>, dims: Vec<usize>) -> NodeId {
        self.nodes.push(Node { op, inputs, dims });
        NodeId(self.nodes.len() - 1)
    }

    /// Declare a fresh positional parameter (index allocated at the end
    /// of the current parameter list).
    pub fn param(&mut self, dims: &[usize], name: &str) -> NodeId {
        let index = self.n_params;
        self.n_params += 1;
        self.push(
            OpKind::Parameter { index, name: name.to_string() },
            vec![],
            dims.to_vec(),
        )
    }

    pub fn scalar(&mut self, value: f32) -> NodeId {
        self.push(OpKind::ConstScalar { value }, vec![], vec![])
    }

    /// Node id of the parameter with positional `index`, if declared.
    pub fn param_node(&self, index: usize) -> Option<NodeId> {
        self.nodes
            .iter()
            .position(|n| matches!(&n.op, OpKind::Parameter { index: i, .. } if *i == index))
            .map(NodeId)
    }

    /// Zero tensor of `dims` (scalar const broadcast).
    pub fn zeros(&mut self, dims: &[usize]) -> NodeId {
        let z = self.scalar(0.0);
        if dims.is_empty() {
            return z;
        }
        self.push(OpKind::Broadcast, vec![z], dims.to_vec())
    }

    fn binary(&mut self, op: OpKind, a: NodeId, b: NodeId) -> NodeId {
        let (da, db) = (self.dims(a).to_vec(), self.dims(b).to_vec());
        let dims = if da == db {
            da
        } else if da.is_empty() {
            db
        } else {
            debug_assert!(db.is_empty(), "tape binary: {da:?} vs {db:?}");
            da
        };
        self.push(op, vec![a, b], dims)
    }

    pub fn add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary(OpKind::Add, a, b)
    }

    pub fn sub(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary(OpKind::Sub, a, b)
    }

    pub fn mul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary(OpKind::Mul, a, b)
    }

    pub fn max(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary(OpKind::Max, a, b)
    }

    pub fn gt(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary(OpKind::Gt, a, b)
    }

    pub fn select(&mut self, pred: NodeId, t: NodeId, f: NodeId) -> NodeId {
        let dims = self.dims(pred).to_vec();
        debug_assert_eq!(self.dims(t), &dims[..]);
        debug_assert_eq!(self.dims(f), &dims[..]);
        self.push(OpKind::Select, vec![pred, t, f], dims)
    }

    fn unary(&mut self, op: OpKind, a: NodeId) -> NodeId {
        let dims = self.dims(a).to_vec();
        self.push(op, vec![a], dims)
    }

    pub fn neg(&mut self, a: NodeId) -> NodeId {
        self.unary(OpKind::Neg, a)
    }

    pub fn exp(&mut self, a: NodeId) -> NodeId {
        self.unary(OpKind::Exp, a)
    }

    pub fn log(&mut self, a: NodeId) -> NodeId {
        self.unary(OpKind::Log, a)
    }

    pub fn sqrt(&mut self, a: NodeId) -> NodeId {
        self.unary(OpKind::Sqrt, a)
    }

    pub fn recip(&mut self, a: NodeId) -> NodeId {
        self.unary(OpKind::Recip, a)
    }

    /// Transpose with composition peephole: `transpose(transpose(x))`
    /// composes at emission time and identities vanish — this is what
    /// keeps the backward factor chains in the pristine shape the
    /// re-merge pass matches.
    pub fn transpose(&mut self, a: NodeId, perm: &[usize]) -> NodeId {
        let (src, composed): (NodeId, Vec<usize>) = match &self.node(a).op {
            OpKind::Transpose { perm: inner } => {
                (self.node(a).inputs[0], perm.iter().map(|&p| inner[p]).collect())
            }
            _ => (a, perm.to_vec()),
        };
        if composed.iter().enumerate().all(|(i, &p)| i == p) {
            return src;
        }
        let dims: Vec<usize> =
            composed.iter().map(|&p| self.dims(src)[p]).collect();
        self.push(OpKind::Transpose { perm: composed }, vec![src], dims)
    }

    /// Reshape with elision: no-op reshapes vanish, reshape-of-reshape
    /// collapses to one.
    pub fn reshape(&mut self, a: NodeId, dims: &[usize]) -> NodeId {
        let src = match &self.node(a).op {
            OpKind::Reshape => self.node(a).inputs[0],
            _ => a,
        };
        if self.dims(src) == dims {
            return src;
        }
        debug_assert_eq!(
            self.dims(src).iter().product::<usize>(),
            dims.iter().product::<usize>()
        );
        self.push(OpKind::Reshape, vec![src], dims.to_vec())
    }

    pub fn broadcast_in_dim(
        &mut self,
        a: NodeId,
        out_dims: &[usize],
        mapping: &[usize],
    ) -> NodeId {
        debug_assert_eq!(mapping.len(), self.dims(a).len());
        self.push(
            OpKind::BroadcastInDim { mapping: mapping.to_vec() },
            vec![a],
            out_dims.to_vec(),
        )
    }

    pub fn reduce_sum(&mut self, a: NodeId, rdims: &[usize]) -> NodeId {
        let d = self.dims(a).to_vec();
        let out: Vec<usize> = d
            .iter()
            .enumerate()
            .filter(|(i, _)| !rdims.contains(i))
            .map(|(_, &e)| e)
            .collect();
        self.push(OpKind::ReduceSum { dims: rdims.to_vec() }, vec![a], out)
    }

    pub fn reduce_mean(&mut self, a: NodeId, rdims: &[usize]) -> NodeId {
        let d = self.dims(a).to_vec();
        let out: Vec<usize> = d
            .iter()
            .enumerate()
            .filter(|(i, _)| !rdims.contains(i))
            .map(|(_, &e)| e)
            .collect();
        self.push(OpKind::ReduceMean { dims: rdims.to_vec() }, vec![a], out)
    }

    /// Stride-1 slice along `dim`.
    pub fn slice1(&mut self, a: NodeId, start: usize, stop: usize, dim: usize) -> NodeId {
        self.slice(a, start, stop, 1, dim)
    }

    pub fn slice(
        &mut self,
        a: NodeId,
        start: usize,
        stop: usize,
        stride: usize,
        dim: usize,
    ) -> NodeId {
        let mut dims = self.dims(a).to_vec();
        debug_assert!(stride >= 1 && start < stop && stop <= dims[dim]);
        dims[dim] = (stop - start).div_ceil(stride);
        self.push(OpKind::Slice { dim, start, stop, stride }, vec![a], dims)
    }

    pub fn concat(&mut self, parts: &[NodeId], dim: usize) -> NodeId {
        debug_assert!(!parts.is_empty());
        if parts.len() == 1 {
            return parts[0];
        }
        let mut dims = self.dims(parts[0]).to_vec();
        dims[dim] = parts.iter().map(|&p| self.dims(p)[dim]).sum();
        self.push(OpKind::Concat { dim }, parts.to_vec(), dims)
    }

    pub fn dot(
        &mut self,
        lhs: NodeId,
        rhs: NodeId,
        lhs_contract: &[usize],
        rhs_contract: &[usize],
    ) -> NodeId {
        let (ld, rd) = (self.dims(lhs).to_vec(), self.dims(rhs).to_vec());
        let mut dims = Vec::new();
        for (i, &e) in ld.iter().enumerate() {
            if !lhs_contract.contains(&i) {
                dims.push(e);
            }
        }
        for (i, &e) in rd.iter().enumerate() {
            if !rhs_contract.contains(&i) {
                dims.push(e);
            }
        }
        self.push(
            OpKind::DotGeneral {
                lhs_contract: lhs_contract.to_vec(),
                rhs_contract: rhs_contract.to_vec(),
            },
            vec![lhs, rhs],
            dims,
        )
    }

    /// Freeze the tape into a graph rooted at `root`.
    pub fn into_graph(self, root: NodeId) -> Graph {
        Graph { name: self.name, nodes: self.nodes, n_params: self.n_params, root }
    }
}

// ---------------------------------------------------------------------------
// Packing multiple logical outputs into the single-root IR
// ---------------------------------------------------------------------------

/// Where each logical output lives inside the packed flat root vector.
#[derive(Clone, Debug)]
pub struct PackEntry {
    pub dims: Vec<usize>,
    pub offset: usize,
    pub len: usize,
}

/// Flatten every output to 1-D and concatenate: the IR has a single
/// root, so multi-output computations (loss + grads, train steps) ship
/// as one vector the host splits by this layout.
pub fn pack(tape: &mut Tape, outputs: &[NodeId]) -> (NodeId, Vec<PackEntry>) {
    let mut entries = Vec::with_capacity(outputs.len());
    let mut flats = Vec::with_capacity(outputs.len());
    let mut offset = 0usize;
    for &o in outputs {
        let dims = tape.dims(o).to_vec();
        let len = dims.iter().product::<usize>();
        flats.push(tape.reshape(o, &[len]));
        entries.push(PackEntry { dims, offset, len });
        offset += len;
    }
    (tape.concat(&flats, 0), entries)
}

// ---------------------------------------------------------------------------
// Reverse sweep
// ---------------------------------------------------------------------------

/// Append the reverse-mode sweep for `loss` (must be scalar) onto the
/// tape and return one gradient node per `wrt` entry (same order).
/// Parameters the loss does not depend on get explicit zero tensors.
pub fn append_backward(
    tape: &mut Tape,
    loss: NodeId,
    wrt: &[NodeId],
) -> Result<Vec<NodeId>> {
    if !tape.dims(loss).is_empty() {
        bail!(
            "autograd: loss must be scalar, got shape {:?}",
            tape.dims(loss)
        );
    }
    let n = tape.len();
    let wrt_set: HashSet<usize> = wrt.iter().map(|id| id.0).collect();

    // needs[i]: does node i lie on a differentiable path out of a wrt
    // parameter? `Gt` has zero derivative everywhere it has one at all,
    // so it blocks propagation (a relu mask is a constant to the sweep).
    let mut needs = vec![false; n];
    for i in 0..n {
        needs[i] = wrt_set.contains(&i)
            || (!matches!(tape.nodes[i].op, OpKind::Gt)
                && tape.nodes[i].inputs.iter().any(|id| needs[id.0]));
    }

    let mut adjoint: Vec<Option<NodeId>> = vec![None; n];
    if needs[loss.0] {
        let one = tape.scalar(1.0);
        adjoint[loss.0] = Some(one);
    }

    for i in (0..=loss.0).rev() {
        let Some(g) = adjoint[i] else { continue };
        let node = tape.nodes[i].clone();
        let mut contribs: Vec<(NodeId, NodeId)> = Vec::new(); // (input, grad)
        match &node.op {
            OpKind::Parameter { .. } | OpKind::ConstScalar { .. } | OpKind::Gt => {}
            OpKind::Broadcast => {
                let input = node.inputs[0];
                if needs[input.0] {
                    let all: Vec<usize> = (0..node.dims.len()).collect();
                    let s = if all.is_empty() { g } else { tape.reduce_sum(g, &all) };
                    contribs.push((input, s));
                }
            }
            OpKind::BroadcastInDim { mapping } => {
                let input = node.inputs[0];
                if needs[input.0] {
                    let reduce_dims: Vec<usize> = (0..node.dims.len())
                        .filter(|d| !mapping.contains(d))
                        .collect();
                    let red = if reduce_dims.is_empty() {
                        g
                    } else {
                        tape.reduce_sum(g, &reduce_dims)
                    };
                    // `red` lists the mapped axes in increasing output
                    // order; permute back to operand axis order.
                    let mut order: Vec<usize> = (0..mapping.len()).collect();
                    order.sort_by_key(|&j| mapping[j]);
                    let mut perm = vec![0usize; mapping.len()];
                    for (pos, &axis) in order.iter().enumerate() {
                        perm[axis] = pos;
                    }
                    contribs.push((input, tape.transpose(red, &perm)));
                }
            }
            OpKind::Concat { dim } => {
                let mut offset = 0usize;
                for &input in &node.inputs {
                    let mid = tape.dims(input)[*dim];
                    if needs[input.0] {
                        let part = tape.slice1(g, offset, offset + mid, *dim);
                        contribs.push((input, part));
                    }
                    offset += mid;
                }
            }
            OpKind::Slice { dim, start, stop: _, stride } => {
                let input = node.inputs[0];
                if needs[input.0] {
                    let in_dims = tape.dims(input).to_vec();
                    let scattered = slice_vjp(
                        tape,
                        g,
                        &in_dims,
                        *dim,
                        *start,
                        *stride,
                        node.dims[*dim],
                    );
                    contribs.push((input, scattered));
                }
            }
            OpKind::Reshape => {
                let input = node.inputs[0];
                if needs[input.0] {
                    let d = tape.dims(input).to_vec();
                    contribs.push((input, tape.reshape(g, &d)));
                }
            }
            OpKind::Transpose { perm } => {
                let input = node.inputs[0];
                if needs[input.0] {
                    let mut inv = vec![0usize; perm.len()];
                    for (o, &p) in perm.iter().enumerate() {
                        inv[p] = o;
                    }
                    contribs.push((input, tape.transpose(g, &inv)));
                }
            }
            OpKind::DotGeneral { lhs_contract, rhs_contract } => {
                let (lhs, rhs) = (node.inputs[0], node.inputs[1]);
                let (gl, gr) = dot_vjp(
                    tape,
                    g,
                    lhs,
                    rhs,
                    lhs_contract,
                    rhs_contract,
                    needs[lhs.0],
                    needs[rhs.0],
                );
                if let Some(v) = gl {
                    contribs.push((lhs, v));
                }
                if let Some(v) = gr {
                    contribs.push((rhs, v));
                }
            }
            OpKind::Add | OpKind::Sub => {
                let negate_rhs = matches!(node.op, OpKind::Sub);
                for (slot, &input) in node.inputs.iter().enumerate() {
                    if !needs[input.0] {
                        continue;
                    }
                    let mut v = g;
                    if tape.dims(input).is_empty() && !node.dims.is_empty() {
                        let all: Vec<usize> = (0..node.dims.len()).collect();
                        v = tape.reduce_sum(v, &all);
                    }
                    if negate_rhs && slot == 1 {
                        v = tape.neg(v);
                    }
                    contribs.push((input, v));
                }
            }
            OpKind::Mul => {
                let (a, b) = (node.inputs[0], node.inputs[1]);
                for (input, other) in [(a, b), (b, a)] {
                    if !needs[input.0] {
                        continue;
                    }
                    let mut v = tape.mul(g, other);
                    if tape.dims(input).is_empty() && !node.dims.is_empty() {
                        let all: Vec<usize> = (0..node.dims.len()).collect();
                        v = tape.reduce_sum(v, &all);
                    }
                    contribs.push((input, v));
                }
            }
            OpKind::Max => {
                // subgradient: ties route to the rhs, matching the
                // kernel's `a.max(b)` (which returns b unless a > b)
                let (a, b) = (node.inputs[0], node.inputs[1]);
                let mask = tape.gt(a, b); // 1 where lhs wins
                let one = tape.scalar(1.0);
                let inv_mask = tape.sub(one, mask);
                for (input, m) in [(a, mask), (b, inv_mask)] {
                    if !needs[input.0] {
                        continue;
                    }
                    let mut v = tape.mul(g, m);
                    if tape.dims(input).is_empty() && !node.dims.is_empty() {
                        let all: Vec<usize> = (0..node.dims.len()).collect();
                        v = tape.reduce_sum(v, &all);
                    }
                    contribs.push((input, v));
                }
            }
            OpKind::Select => {
                // the predicate is a non-differentiable routing input —
                // it gets no contribution
                let (pred, t, f) = (node.inputs[0], node.inputs[1], node.inputs[2]);
                if needs[t.0] || needs[f.0] {
                    let z = tape.zeros(&node.dims);
                    if needs[t.0] {
                        contribs.push((t, tape.select(pred, g, z)));
                    }
                    if needs[f.0] {
                        contribs.push((f, tape.select(pred, z, g)));
                    }
                }
            }
            OpKind::ReduceMean { dims } | OpKind::ReduceSum { dims } => {
                let input = node.inputs[0];
                if needs[input.0] {
                    let in_dims = tape.dims(input).to_vec();
                    let kept: Vec<usize> = (0..in_dims.len())
                        .filter(|i| !dims.contains(i))
                        .collect();
                    let mut v = tape.broadcast_in_dim(g, &in_dims, &kept);
                    if matches!(node.op, OpKind::ReduceMean { .. }) {
                        let count: usize = dims.iter().map(|&r| in_dims[r]).product();
                        let inv = tape.scalar(1.0 / count as f32);
                        v = tape.mul(v, inv);
                    }
                    contribs.push((input, v));
                }
            }
            OpKind::Sqrt => {
                let input = node.inputs[0];
                if needs[input.0] {
                    // d√x = 1 / (2√x), reusing the forward output
                    let this = NodeId(i);
                    let r = tape.recip(this);
                    let half = tape.scalar(0.5);
                    let hr = tape.mul(r, half);
                    contribs.push((input, tape.mul(g, hr)));
                }
            }
            OpKind::Neg => {
                let input = node.inputs[0];
                if needs[input.0] {
                    contribs.push((input, tape.neg(g)));
                }
            }
            OpKind::Exp => {
                let input = node.inputs[0];
                if needs[input.0] {
                    let this = NodeId(i);
                    contribs.push((input, tape.mul(g, this)));
                }
            }
            OpKind::Log => {
                let input = node.inputs[0];
                if needs[input.0] {
                    let r = tape.recip(input);
                    contribs.push((input, tape.mul(g, r)));
                }
            }
            OpKind::Recip => {
                let input = node.inputs[0];
                if needs[input.0] {
                    // d(1/x) = -1/x² — reuse the forward output squared
                    let this = NodeId(i);
                    let sq = tape.mul(this, this);
                    let gv = tape.mul(g, sq);
                    contribs.push((input, tape.neg(gv)));
                }
            }
            OpKind::SpmmCsr { n_rows, n_cols, row_ptr, col_idx, rhs_axis, val_perm } => {
                // The frozen-S convention: sparse residual values are a
                // mask-fixed parameter (`.s` is in the freeze suffix set),
                // so no ∂vals path exists — refuse loudly rather than
                // silently returning zeros if someone asks for one.
                let (vals, x) = (node.inputs[0], node.inputs[1]);
                if needs[vals.0] {
                    bail!(
                        "autograd: SpmmCsr values are mask-frozen (the `.s` \
                         freeze convention) — exclude the sparse residual \
                         from `wrt`"
                    );
                }
                if needs[x.0] {
                    // ∂x = Sᵀ·g: the same op with the transposed pattern,
                    // riding the forward value vector through `val_perm`
                    // (counting-sort transpose keeps per-row columns —
                    // here the original row ids — strictly ascending).
                    let nnz = col_idx.len();
                    let mut counts = vec![0u32; *n_cols + 1];
                    for &c in col_idx.iter() {
                        counts[c as usize + 1] += 1;
                    }
                    for c in 0..*n_cols {
                        counts[c + 1] += counts[c];
                    }
                    let mut next: Vec<u32> = counts[..*n_cols].to_vec();
                    let mut col_idx_t = vec![0u32; nnz];
                    let mut perm_t = vec![0u32; nnz];
                    for r in 0..*n_rows {
                        for e in row_ptr[r] as usize..row_ptr[r + 1] as usize {
                            let c = col_idx[e] as usize;
                            let pos = next[c] as usize;
                            next[c] += 1;
                            col_idx_t[pos] = r as u32;
                            perm_t[pos] = match val_perm {
                                Some(p) => p[e],
                                None => e as u32,
                            };
                        }
                    }
                    let gd = tape.dims(g).to_vec();
                    let mut out_dims = vec![*n_cols];
                    out_dims.extend_from_slice(&gd[1..]);
                    let gx = tape.push(
                        OpKind::SpmmCsr {
                            n_rows: *n_cols,
                            n_cols: *n_rows,
                            row_ptr: Arc::new(counts),
                            col_idx: Arc::new(col_idx_t),
                            rhs_axis: 0,
                            val_perm: Some(Arc::new(perm_t)),
                        },
                        vec![vals, g],
                        out_dims,
                    );
                    // route the contracted axis back to its x position
                    let xr = tape.dims(x).len();
                    let perm: Vec<usize> = (0..xr)
                        .map(|a| match a.cmp(rhs_axis) {
                            std::cmp::Ordering::Less => a + 1,
                            std::cmp::Ordering::Equal => 0,
                            std::cmp::Ordering::Greater => a,
                        })
                        .collect();
                    contribs.push((x, tape.transpose(gx, &perm)));
                }
            }
        }
        for (input, v) in contribs {
            adjoint[input.0] = Some(match adjoint[input.0] {
                Some(prev) => tape.add(prev, v),
                None => v,
            });
        }
    }

    Ok(wrt
        .iter()
        .map(|&p| {
            adjoint[p.0].unwrap_or_else(|| {
                // unreachable from the loss: the gradient is exactly zero
                let d = tape.dims(p).to_vec();
                tape.zeros(&d)
            })
        })
        .collect())
}

/// VJP of `out = dot(lhs, rhs, CL, CR)`:
/// * `∂lhs = transpose(dot(g, rhs, FRpos(g), FR))` back into lhs layout,
/// * `∂rhs = transpose(dot(lhs, g, FL, FLpos(g)))` back into rhs layout,
/// where FL/FR are the free axes and the transposes route each
/// contracted axis back to its operand position (identity permutations
/// are elided by the tape, which is what leaves the backward factor
/// chains in re-merge-matchable shape).
#[allow(clippy::too_many_arguments)]
fn dot_vjp(
    tape: &mut Tape,
    g: NodeId,
    lhs: NodeId,
    rhs: NodeId,
    lhs_contract: &[usize],
    rhs_contract: &[usize],
    want_lhs: bool,
    want_rhs: bool,
) -> (Option<NodeId>, Option<NodeId>) {
    let ld = tape.dims(lhs).to_vec();
    let rd = tape.dims(rhs).to_vec();
    let fl: Vec<usize> =
        (0..ld.len()).filter(|i| !lhs_contract.contains(i)).collect();
    let fr: Vec<usize> =
        (0..rd.len()).filter(|i| !rhs_contract.contains(i)).collect();

    let gl = want_lhs.then(|| {
        // contract g's rhs-free positions against rhs's free axes
        let g_axes: Vec<usize> = (fl.len()..fl.len() + fr.len()).collect();
        let x = tape.dot(g, rhs, &g_axes, &fr);
        // x = [ld[f] for f in fl] ++ [rd[c] for c in sorted(CR)]
        let mut cr_sorted = rhs_contract.to_vec();
        cr_sorted.sort_unstable();
        let mut perm = vec![0usize; ld.len()];
        for (pos, &axis) in fl.iter().enumerate() {
            perm[axis] = pos;
        }
        for (k, &axis) in lhs_contract.iter().enumerate() {
            let slot = cr_sorted.iter().position(|&v| v == rhs_contract[k]).unwrap();
            perm[axis] = fl.len() + slot;
        }
        tape.transpose(x, &perm)
    });

    let gr = want_rhs.then(|| {
        // contract lhs's free axes against g's lhs-free positions
        let g_axes: Vec<usize> = (0..fl.len()).collect();
        let x = tape.dot(lhs, g, &fl, &g_axes);
        // x = [ld[c] for c in sorted(CL)] ++ [rd[f] for f in fr]
        let mut cl_sorted = lhs_contract.to_vec();
        cl_sorted.sort_unstable();
        let mut perm = vec![0usize; rd.len()];
        for (k, &axis) in rhs_contract.iter().enumerate() {
            let slot = cl_sorted.iter().position(|&v| v == lhs_contract[k]).unwrap();
            perm[axis] = slot;
        }
        for (pos, &axis) in fr.iter().enumerate() {
            perm[axis] = cl_sorted.len() + pos;
        }
        tape.transpose(x, &perm)
    });

    (gl, gr)
}

/// Scatter-adjoint of a (possibly strided) slice: place `g`'s entries at
/// `start + i·stride` along `dim` of a zero tensor shaped like the
/// slice's input. Strided slices interleave via a reshape/concat trick
/// (the IR has no scatter): `[.., mid_out, ..] → [.., mid_out, 1, ..]`,
/// concat `stride - 1` zeros on the new axis, flatten to
/// `mid_out·stride`, trim to the covered span and pad both ends.
fn slice_vjp(
    tape: &mut Tape,
    g: NodeId,
    in_dims: &[usize],
    dim: usize,
    start: usize,
    stride: usize,
    mid_out: usize,
) -> NodeId {
    let mid_in = in_dims[dim];
    let g_dims = tape.dims(g).to_vec();

    let (body, body_w) = if stride == 1 {
        (g, mid_out)
    } else {
        // interleave stride-1 zeros behind every entry
        let mut split = g_dims.clone();
        split[dim] = mid_out;
        split.insert(dim + 1, 1);
        let g_split = tape.reshape(g, &split);
        let mut zdims = split.clone();
        zdims[dim + 1] = stride - 1;
        let z = tape.zeros(&zdims);
        let cat = tape.concat(&[g_split, z], dim + 1);
        let mut flat = g_dims.clone();
        flat[dim] = mid_out * stride;
        let flat_node = tape.reshape(cat, &flat);
        // the interleave overshoots the input by up to stride-1: trim
        let avail = mid_in - start;
        if mid_out * stride > avail {
            (tape.slice1(flat_node, 0, avail, dim), avail)
        } else {
            (flat_node, mid_out * stride)
        }
    };

    let mut parts: Vec<NodeId> = Vec::with_capacity(3);
    if start > 0 {
        let mut zdims = g_dims.clone();
        zdims[dim] = start;
        parts.push(tape.zeros(&zdims));
    }
    parts.push(body);
    let tail = mid_in - start - body_w;
    if tail > 0 {
        let mut zdims = g_dims.clone();
        zdims[dim] = tail;
        parts.push(tape.zeros(&zdims));
    }
    tape.concat(&parts, dim)
}

// ---------------------------------------------------------------------------
// Public entry point
// ---------------------------------------------------------------------------

/// Layout of the packed `[loss, grads...]` joint graph.
#[derive(Clone, Debug)]
pub struct GradLayout {
    /// entry 0 = the scalar loss, then one entry per `wrt` parameter
    pub entries: Vec<PackEntry>,
    /// node count of the forward segment (`Engine::compile_train`'s
    /// boundary)
    pub fwd_nodes: usize,
}

impl GradLayout {
    /// Split a packed flat output back into per-entry tensors.
    pub fn unpack(&self, flat: &[f32]) -> Vec<super::HostTensor> {
        self.entries
            .iter()
            .map(|e| {
                super::HostTensor::new(
                    e.dims.clone(),
                    flat[e.offset..e.offset + e.len].to_vec(),
                )
            })
            .collect()
    }
}

/// Build the joint forward+backward graph for `fwd` (root = scalar
/// loss): the new root packs `[loss, grad(param) for param in wrt]`
/// (parameter positional indices) into one flat vector.
pub fn loss_and_grads(fwd: &Graph, wrt: &[usize]) -> Result<(Graph, GradLayout)> {
    let param_nodes = param_node_ids(fwd, wrt)?;
    let (mut tape, loss) = Tape::from_graph(fwd);
    let fwd_nodes = tape.len();
    let grads = append_backward(&mut tape, loss, &param_nodes)?;
    let mut outputs = vec![loss];
    outputs.extend(grads);
    let (root, entries) = pack(&mut tape, &outputs);
    Ok((tape.into_graph(root), GradLayout { entries, fwd_nodes }))
}

/// Node ids of the given parameter indices.
fn param_node_ids(g: &Graph, wrt: &[usize]) -> Result<Vec<NodeId>> {
    let mut by_index = vec![None; g.n_params];
    for (i, node) in g.nodes.iter().enumerate() {
        if let OpKind::Parameter { index, .. } = &node.op {
            by_index[*index] = Some(NodeId(i));
        }
    }
    wrt.iter()
        .map(|&p| {
            by_index
                .get(p)
                .copied()
                .flatten()
                .ok_or_else(|| anyhow::anyhow!("no parameter with index {p}"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::graph::GraphBuilder;
    use crate::runtime::{CompileOptions, Engine, HostTensor};
    use crate::util::check::assert_allclose;

    fn grads_of(
        g: &Graph,
        wrt: &[usize],
        args: &[HostTensor],
    ) -> (f32, Vec<HostTensor>) {
        let (joint, layout) = loss_and_grads(g, wrt).unwrap();
        let exe = Engine::native().compile(&joint, &CompileOptions::o0()).unwrap();
        let out = exe.run_hosts(args).unwrap().remove(0);
        let mut parts = layout.unpack(&out.data);
        let loss = parts.remove(0).data[0];
        (loss, parts)
    }

    #[test]
    fn grad_of_dot_matches_hand_derivation() {
        // loss = sum(x · w), x: [2,3], w: [3] → ∂x[i,j] = w[j], ∂w[j] = Σ_i x[i,j]
        let b = GraphBuilder::new("t");
        let x = b.parameter(0, &[2, 3], "x").unwrap();
        let w = b.parameter(1, &[3], "w").unwrap();
        let y = x.dot_general(&w, &[1], &[0]).unwrap(); // [2]
        let loss = y.reduce_sum(&[0], false).unwrap();
        let g = b.build(&loss).unwrap();
        let xs = HostTensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let ws = HostTensor::new(vec![3], vec![10., 20., 30.]);
        let (loss_v, grads) = grads_of(&g, &[0, 1], &[xs, ws]);
        assert_allclose(&[loss_v], &[140. + 320.], 1e-4, 1e-4);
        assert_eq!(grads[0].dims, vec![2, 3]);
        assert_allclose(&grads[0].data, &[10., 20., 30., 10., 20., 30.], 1e-4, 1e-4);
        assert_allclose(&grads[1].data, &[5., 7., 9.], 1e-4, 1e-4);
    }

    #[test]
    fn grad_of_relu_masks_negative_side() {
        let b = GraphBuilder::new("relu");
        let x = b.parameter(0, &[4], "x").unwrap();
        let zero = b.c0(0.0).unwrap();
        let y = x.max(&zero).unwrap();
        let loss = y.reduce_sum(&[0], false).unwrap();
        let g = b.build(&loss).unwrap();
        let xs = HostTensor::new(vec![4], vec![-1., 2., -3., 4.]);
        let (loss_v, grads) = grads_of(&g, &[0], &[xs]);
        assert_allclose(&[loss_v], &[6.0], 1e-5, 1e-5);
        assert_eq!(grads[0].data, vec![0., 1., 0., 1.]);
    }

    #[test]
    fn grad_of_strided_slice_scatters_with_zeros() {
        // x: [6]; slice 1..6 step 2 → picks x[1], x[3], x[5]
        let b = GraphBuilder::new("sl");
        let x = b.parameter(0, &[6], "x").unwrap();
        let s = x.slice_in_dim(1, 6, 2, 0).unwrap();
        let w = b.parameter(1, &[3], "w").unwrap();
        let loss = (s * w).unwrap().reduce_sum(&[0], false).unwrap();
        let g = b.build(&loss).unwrap();
        let xs = HostTensor::new(vec![6], vec![0., 1., 2., 3., 4., 5.]);
        let ws = HostTensor::new(vec![3], vec![7., 11., 13.]);
        let (_, grads) = grads_of(&g, &[0], &[xs, ws]);
        assert_eq!(grads[0].data, vec![0., 7., 0., 11., 0., 13.]);
    }

    #[test]
    fn unreached_parameter_gets_zero_grad() {
        let b = GraphBuilder::new("z");
        let x = b.parameter(0, &[2], "x").unwrap();
        let u = b.parameter(1, &[3], "unused").unwrap();
        let _ = &u;
        let loss = x.reduce_sum(&[0], false).unwrap();
        let g = b.build(&loss).unwrap();
        let (_, grads) = grads_of(
            &g,
            &[0, 1],
            &[
                HostTensor::new(vec![2], vec![1., 2.]),
                HostTensor::new(vec![3], vec![9., 9., 9.]),
            ],
        );
        assert_eq!(grads[0].data, vec![1., 1.]);
        assert_eq!(grads[1].data, vec![0., 0., 0.]);
    }

    #[test]
    fn backward_through_factor_pair_is_premerged_shape() {
        // conv1x1 factor chain: the ∂x chain must come out as
        // dot(w0, dot(w1, δ, [0],[0]), [0],[0]) — no transpose pairs in
        // between — so remerge can fire on it when factors are frozen.
        let (n, c, r, s, hw) = (1, 4, 3, 4, 2);
        let b = GraphBuilder::new("pre");
        let x = b.parameter(0, &[n, c, hw, hw], "x").unwrap();
        let w0 = b.parameter(1, &[r, c], "w0").unwrap();
        let w1 = b.parameter(2, &[s, r], "w1").unwrap();
        let t = w0.dot_general(&x, &[1], &[1]).unwrap().transpose(&[1, 0, 2, 3]).unwrap();
        let y = w1.dot_general(&t, &[1], &[1]).unwrap().transpose(&[1, 0, 2, 3]).unwrap();
        let loss = y.reduce_sum(&[0, 1, 2, 3], false).unwrap();
        let g = b.build(&loss).unwrap();
        // differentiate wrt x ONLY (the frozen-factor shape)
        let (joint, _) = loss_and_grads(&g, &[0]).unwrap();
        let fwd_len = g.nodes.len();
        let bwd_dots: Vec<&crate::runtime::graph::Node> = joint.nodes[fwd_len..]
            .iter()
            .filter(|nd| matches!(nd.op, OpKind::DotGeneral { .. }))
            .collect();
        assert_eq!(bwd_dots.len(), 2, "∂x needs exactly the two factor dots");
        for nd in bwd_dots {
            match &nd.op {
                OpKind::DotGeneral { lhs_contract, rhs_contract } => {
                    assert_eq!((lhs_contract.as_slice(), rhs_contract.as_slice()),
                        ([0usize].as_slice(), [0usize].as_slice()),
                        "backward factor dot not in transposed-weight form");
                }
                _ => unreachable!(),
            }
        }
    }
}
