//! Backend-neutral tensor-graph IR.
//!
//! `GraphBuilder`/`Op` mirror the small slice of the XlaBuilder API the
//! layer factory and netbuilder need (pad/slice/concat/dot_general/
//! transpose/broadcast/reduce + elementwise), with eager shape inference so
//! construction errors surface at build time on every backend. A finished
//! `Graph` is a flat, topologically-ordered node list that the `native`
//! interpreter executes directly and the `xla-pjrt` backend translates
//! 1:1 into an XlaBuilder computation.

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{bail, Result};

/// Index of a node inside its graph (nodes are append-only, so every
/// node's inputs precede it — the node list is already a schedule).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct NodeId(pub usize);

/// One operation. Output shape lives on the `Node`, not the op.
#[derive(Clone, Debug)]
pub enum OpKind {
    /// Positional input `index` (the execute-time argument order).
    Parameter { index: usize, name: String },
    /// f32 scalar constant.
    ConstScalar { value: f32 },
    /// Scalar broadcast to the node's output shape.
    Broadcast,
    /// Map operand axis `i` to output axis `mapping[i]`; other output axes
    /// are broadcast.
    BroadcastInDim { mapping: Vec<usize> },
    /// Concatenate all inputs along `dim`.
    Concat { dim: usize },
    /// Strided slice `start..stop` (exclusive) along `dim`.
    Slice { dim: usize, start: usize, stop: usize, stride: usize },
    Reshape,
    /// Output axis `i` takes operand axis `perm[i]` (XLA convention).
    Transpose { perm: Vec<usize> },
    /// Contract `lhs_contract` dims of input 0 with `rhs_contract` dims of
    /// input 1; output = lhs free dims ++ rhs free dims (no batch dims).
    DotGeneral { lhs_contract: Vec<usize>, rhs_contract: Vec<usize> },
    Add,
    /// Elementwise subtraction (scalar operand broadcasts).
    Sub,
    Mul,
    /// Elementwise max (scalar operand broadcasts).
    Max,
    /// Elementwise `lhs > rhs` as 0.0/1.0 (scalar operand broadcasts).
    /// Non-differentiable: autograd treats it as a constant mask.
    Gt,
    /// `select(pred, on_true, on_false)`: 3 same-shape inputs; where the
    /// predicate is non-zero take `on_true`, else `on_false`.
    Select,
    /// Mean over `dims`, which are removed from the shape.
    ReduceMean { dims: Vec<usize> },
    /// Sum over `dims`, which are removed from the shape.
    ReduceSum { dims: Vec<usize> },
    Sqrt,
    Neg,
    Exp,
    Log,
    /// Elementwise reciprocal `1 / x`.
    Recip,
    /// Sparse `[n_rows, n_cols]` CSR matrix times a dense operand,
    /// contracting the sparse columns with axis `rhs_axis` of the dense
    /// input. Inputs are `[vals, x]`: `vals` is the 1-D `[nnz]` value
    /// vector (a parameter — the pattern is compile-time structure, the
    /// values are weights), `x` is dense. Output shape is `[n_rows]`
    /// followed by `x`'s dims with `rhs_axis` removed (sparse free dim
    /// first, like `DotGeneral`). `val_perm`, when present, maps CSR
    /// stream position `j` to `vals[val_perm[j]]` — the transposed
    /// pattern autograd emits reuses the forward value vector in place.
    SpmmCsr {
        n_rows: usize,
        n_cols: usize,
        row_ptr: Arc<Vec<u32>>,
        col_idx: Arc<Vec<u32>>,
        rhs_axis: usize,
        val_perm: Option<Arc<Vec<u32>>>,
    },
}

#[derive(Clone, Debug)]
pub struct Node {
    pub op: OpKind,
    pub inputs: Vec<NodeId>,
    pub dims: Vec<usize>,
}

/// A finished computation: immutable, `Send`, backend-neutral.
#[derive(Clone, Debug)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    /// Number of `Parameter` nodes; their `index` fields cover 0..n_params.
    pub n_params: usize,
    pub root: NodeId,
}

impl Graph {
    /// Shapes of the parameters in positional order.
    pub fn param_dims(&self) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.n_params];
        for node in &self.nodes {
            if let OpKind::Parameter { index, .. } = &node.op {
                out[*index] = node.dims.clone();
            }
        }
        out
    }
}

struct Inner {
    name: String,
    nodes: Vec<Node>,
    param_indices: Vec<usize>,
}

/// Graph under construction. Cheap to clone (shared handle).
#[derive(Clone)]
pub struct GraphBuilder {
    inner: Rc<RefCell<Inner>>,
}

/// Handle to a node of a builder (the XlaOp analogue).
#[derive(Clone)]
pub struct Op {
    builder: GraphBuilder,
    id: NodeId,
}

impl GraphBuilder {
    pub fn new(name: &str) -> GraphBuilder {
        GraphBuilder {
            inner: Rc::new(RefCell::new(Inner {
                name: name.to_string(),
                nodes: Vec::new(),
                param_indices: Vec::new(),
            })),
        }
    }

    fn push(&self, op: OpKind, inputs: Vec<NodeId>, dims: Vec<usize>) -> Op {
        let mut inner = self.inner.borrow_mut();
        inner.nodes.push(Node { op, inputs, dims });
        Op { builder: self.clone(), id: NodeId(inner.nodes.len() - 1) }
    }

    fn dims_of(&self, id: NodeId) -> Vec<usize> {
        self.inner.borrow().nodes[id.0].dims.clone()
    }

    /// Declare positional input `index` with the given shape.
    pub fn parameter(&self, index: usize, dims: &[usize], name: &str) -> Result<Op> {
        {
            let inner = self.inner.borrow();
            if inner.param_indices.contains(&index) {
                bail!("{}: duplicate parameter index {index}", inner.name);
            }
        }
        self.inner.borrow_mut().param_indices.push(index);
        Ok(self.push(
            OpKind::Parameter { index, name: name.to_string() },
            vec![],
            dims.to_vec(),
        ))
    }

    /// f32 scalar constant (shape `[]`).
    pub fn c0(&self, value: f32) -> Result<Op> {
        Ok(self.push(OpKind::ConstScalar { value }, vec![], vec![]))
    }

    /// Finalize: validate the parameter list and freeze the node list.
    pub fn build(&self, root: &Op) -> Result<Graph> {
        if !Rc::ptr_eq(&self.inner, &root.builder.inner) {
            bail!("build: root op belongs to a different builder");
        }
        let inner = self.inner.borrow();
        let n_params = inner.param_indices.len();
        let mut seen = vec![false; n_params];
        for &i in &inner.param_indices {
            if i >= n_params {
                bail!(
                    "{}: parameter indices not contiguous (index {i}, {n_params} params)",
                    inner.name
                );
            }
            seen[i] = true;
        }
        if seen.iter().any(|s| !s) {
            bail!("{}: parameter indices not contiguous", inner.name);
        }
        Ok(Graph {
            name: inner.name.clone(),
            nodes: inner.nodes.clone(),
            n_params,
            root: root.id,
        })
    }
}

fn product(dims: &[usize]) -> usize {
    dims.iter().product()
}

/// Structural CSR validation shared by the builder and the sparse
/// fitter: monotone row pointers covering `col_idx`, columns in range
/// and strictly ascending within each row (ascending order is what
/// makes the kernel's per-row accumulation order well-defined, hence
/// bitwise thread-invariant).
pub fn validate_csr(
    n_rows: usize,
    n_cols: usize,
    row_ptr: &[u32],
    col_idx: &[u32],
) -> Result<()> {
    let nnz = col_idx.len();
    if row_ptr.len() != n_rows + 1 || row_ptr[0] != 0 || row_ptr[n_rows] as usize != nnz {
        bail!("csr: row_ptr must be [0..={nnz}] over {n_rows} rows, got len {}", row_ptr.len());
    }
    for r in 0..n_rows {
        let (lo, hi) = (row_ptr[r] as usize, row_ptr[r + 1] as usize);
        if hi < lo || hi > nnz {
            bail!("csr: row {r} range {lo}..{hi} is not monotone");
        }
        for j in lo..hi {
            if col_idx[j] as usize >= n_cols {
                bail!("csr: col {} out of range {n_cols} in row {r}", col_idx[j]);
            }
            if j > lo && col_idx[j] <= col_idx[j - 1] {
                bail!("csr: row {r} columns not strictly ascending");
            }
        }
    }
    Ok(())
}

impl Op {
    pub fn dims(&self) -> Vec<usize> {
        self.builder.dims_of(self.id)
    }

    fn same_builder(&self, other: &Op, what: &str) -> Result<()> {
        if !Rc::ptr_eq(&self.builder.inner, &other.builder.inner) {
            bail!("{what}: operands belong to different builders");
        }
        Ok(())
    }

    /// Broadcast a scalar to `dims`.
    pub fn broadcast(&self, dims: &[usize]) -> Result<Op> {
        let d = self.dims();
        if !d.is_empty() {
            bail!("broadcast: operand must be scalar, got {d:?}");
        }
        Ok(self.builder.push(OpKind::Broadcast, vec![self.id], dims.to_vec()))
    }

    /// XLA `BroadcastInDim`: operand axis `i` maps to output axis
    /// `mapping[i]`; sizes must match along mapped axes.
    pub fn broadcast_in_dim(&self, out_dims: &[usize], mapping: &[usize]) -> Result<Op> {
        let d = self.dims();
        if mapping.len() != d.len() {
            bail!("broadcast_in_dim: {} axes mapped for operand {d:?}", mapping.len());
        }
        for (i, &m) in mapping.iter().enumerate() {
            if m >= out_dims.len() {
                bail!("broadcast_in_dim: axis map {m} out of range for {out_dims:?}");
            }
            if d[i] != out_dims[m] {
                bail!(
                    "broadcast_in_dim: operand axis {i} ({}) != output axis {m} ({})",
                    d[i],
                    out_dims[m]
                );
            }
        }
        Ok(self.builder.push(
            OpKind::BroadcastInDim { mapping: mapping.to_vec() },
            vec![self.id],
            out_dims.to_vec(),
        ))
    }

    /// Concatenate `self` followed by `others` along `dim`.
    pub fn concat_in_dim(&self, others: &[Op], dim: usize) -> Result<Op> {
        let mut dims = self.dims();
        if dim >= dims.len() {
            bail!("concat: dim {dim} out of range for {dims:?}");
        }
        let mut inputs = vec![self.id];
        for o in others {
            self.same_builder(o, "concat")?;
            let od = o.dims();
            if od.len() != dims.len() {
                bail!("concat: rank mismatch {dims:?} vs {od:?}");
            }
            for a in 0..dims.len() {
                if a != dim && od[a] != dims[a] {
                    bail!("concat: shape mismatch on axis {a}: {dims:?} vs {od:?}");
                }
            }
            dims[dim] += od[dim];
            inputs.push(o.id);
        }
        Ok(self.builder.push(OpKind::Concat { dim }, inputs, dims))
    }

    /// Strided slice `start..stop` (stop exclusive) along `dim`.
    pub fn slice_in_dim(
        &self,
        start: usize,
        stop: usize,
        stride: usize,
        dim: usize,
    ) -> Result<Op> {
        let d = self.dims();
        if dim >= d.len() {
            bail!("slice: dim {dim} out of range for {d:?}");
        }
        if stride == 0 || start >= stop || stop > d[dim] {
            bail!("slice: bad range {start}..{stop} step {stride} on axis {dim} of {d:?}");
        }
        let count = (stop - start).div_ceil(stride);
        let mut dims = d;
        dims[dim] = count;
        Ok(self
            .builder
            .push(OpKind::Slice { dim, start, stop, stride }, vec![self.id], dims))
    }

    /// Stride-1 slice.
    pub fn slice_in_dim1(&self, start: usize, stop: usize, dim: usize) -> Result<Op> {
        self.slice_in_dim(start, stop, 1, dim)
    }

    pub fn reshape(&self, dims: &[usize]) -> Result<Op> {
        let d = self.dims();
        if product(&d) != product(dims) {
            bail!("reshape: {d:?} -> {dims:?} changes element count");
        }
        Ok(self.builder.push(OpKind::Reshape, vec![self.id], dims.to_vec()))
    }

    /// Output axis `i` takes operand axis `perm[i]`.
    pub fn transpose(&self, perm: &[usize]) -> Result<Op> {
        let d = self.dims();
        if perm.len() != d.len() {
            bail!("transpose: perm {perm:?} for shape {d:?}");
        }
        let mut seen = vec![false; d.len()];
        let mut dims = Vec::with_capacity(d.len());
        for &p in perm {
            if p >= d.len() || seen[p] {
                bail!("transpose: invalid perm {perm:?} for shape {d:?}");
            }
            seen[p] = true;
            dims.push(d[p]);
        }
        Ok(self
            .builder
            .push(OpKind::Transpose { perm: perm.to_vec() }, vec![self.id], dims))
    }

    /// General contraction (no batch dims): output shape is the lhs free
    /// dims followed by the rhs free dims, both in operand order.
    pub fn dot_general(
        &self,
        rhs: &Op,
        lhs_contract: &[usize],
        rhs_contract: &[usize],
    ) -> Result<Op> {
        self.same_builder(rhs, "dot_general")?;
        let (ld, rd) = (self.dims(), rhs.dims());
        if lhs_contract.len() != rhs_contract.len() {
            bail!("dot_general: contract arity mismatch");
        }
        for (&lc, &rc) in lhs_contract.iter().zip(rhs_contract.iter()) {
            if lc >= ld.len() || rc >= rd.len() {
                bail!("dot_general: contract dim out of range ({ld:?} x {rd:?})");
            }
            if ld[lc] != rd[rc] {
                bail!(
                    "dot_general: contracted extents differ: lhs[{lc}]={} rhs[{rc}]={}",
                    ld[lc],
                    rd[rc]
                );
            }
        }
        let mut dims = Vec::new();
        for (i, &e) in ld.iter().enumerate() {
            if !lhs_contract.contains(&i) {
                dims.push(e);
            }
        }
        for (i, &e) in rd.iter().enumerate() {
            if !rhs_contract.contains(&i) {
                dims.push(e);
            }
        }
        Ok(self.builder.push(
            OpKind::DotGeneral {
                lhs_contract: lhs_contract.to_vec(),
                rhs_contract: rhs_contract.to_vec(),
            },
            vec![self.id, rhs.id],
            dims,
        ))
    }

    /// Sparse×dense contraction: `self` is the 1-D `[nnz]` value vector
    /// of a CSR matrix `[n_rows, n_cols]` whose pattern is baked into
    /// the op; `x`'s axis `rhs_axis` (extent `n_cols`) is contracted.
    /// Output: `[n_rows]` ++ `x.dims` minus `rhs_axis`.
    pub fn spmm_csr(
        &self,
        x: &Op,
        n_rows: usize,
        n_cols: usize,
        row_ptr: Arc<Vec<u32>>,
        col_idx: Arc<Vec<u32>>,
        rhs_axis: usize,
        val_perm: Option<Arc<Vec<u32>>>,
    ) -> Result<Op> {
        self.same_builder(x, "spmm_csr")?;
        let vd = self.dims();
        let nnz = col_idx.len();
        if vd.len() != 1 || vd[0] != nnz {
            bail!("spmm_csr: vals must be [nnz]={nnz}, got {vd:?}");
        }
        validate_csr(n_rows, n_cols, &row_ptr, &col_idx)?;
        // length + range is the cheap builder-side gate; the IR verifier
        // (`verify::verify_graph`) additionally proves bijectivity (no
        // index hit twice), which this O(nnz) check deliberately skips
        if let Some(p) = &val_perm {
            if p.len() != nnz || p.iter().any(|&j| j as usize >= nnz) {
                bail!("spmm_csr: val_perm must be a permutation of 0..{nnz}");
            }
        }
        let xd = x.dims();
        if rhs_axis >= xd.len() || xd[rhs_axis] != n_cols {
            bail!("spmm_csr: rhs axis {rhs_axis} of {xd:?} must have extent {n_cols}");
        }
        let mut dims = vec![n_rows];
        for (i, &e) in xd.iter().enumerate() {
            if i != rhs_axis {
                dims.push(e);
            }
        }
        Ok(self.builder.push(
            OpKind::SpmmCsr { n_rows, n_cols, row_ptr, col_idx, rhs_axis, val_perm },
            vec![self.id, x.id],
            dims,
        ))
    }

    fn binary(&self, other: &Op, op: OpKind, what: &str) -> Result<Op> {
        self.same_builder(other, what)?;
        let (a, b) = (self.dims(), other.dims());
        let dims = if a == b {
            a
        } else if a.is_empty() {
            b
        } else if b.is_empty() {
            a
        } else {
            bail!("{what}: shape mismatch {a:?} vs {b:?} (only scalar broadcast supported)");
        };
        Ok(self.builder.push(op, vec![self.id, other.id], dims))
    }

    pub fn max(&self, other: &Op) -> Result<Op> {
        self.binary(other, OpKind::Max, "max")
    }

    /// Elementwise `self > other` as a 0.0/1.0 mask (the relu-gradient
    /// mask; scalar operands broadcast like the other binaries).
    pub fn gt(&self, other: &Op) -> Result<Op> {
        self.binary(other, OpKind::Gt, "gt")
    }

    /// `select(self, on_true, on_false)`: `self` is the predicate mask;
    /// all three operands must share one shape.
    pub fn select(&self, on_true: &Op, on_false: &Op) -> Result<Op> {
        self.same_builder(on_true, "select")?;
        self.same_builder(on_false, "select")?;
        let (p, t, f) = (self.dims(), on_true.dims(), on_false.dims());
        if p != t || p != f {
            bail!("select: shapes differ (pred {p:?}, true {t:?}, false {f:?})");
        }
        Ok(self
            .builder
            .push(OpKind::Select, vec![self.id, on_true.id, on_false.id], p))
    }

    fn reduce(&self, dims: &[usize], keep_dims: bool, mean: bool) -> Result<Op> {
        let what = if mean { "reduce_mean" } else { "reduce_sum" };
        if keep_dims {
            bail!("{what}: keep_dims not supported");
        }
        let d = self.dims();
        let mut out = Vec::new();
        for (i, &e) in d.iter().enumerate() {
            if !dims.contains(&i) {
                out.push(e);
            }
        }
        for &r in dims {
            if r >= d.len() {
                bail!("{what}: dim {r} out of range for {d:?}");
            }
            if d[r] == 0 {
                // a 0/0 mean (and a degenerate sum): reject at build time
                bail!("{what}: axis {r} of {d:?} is zero-size (empty reduce)");
            }
        }
        let op = if mean {
            OpKind::ReduceMean { dims: dims.to_vec() }
        } else {
            OpKind::ReduceSum { dims: dims.to_vec() }
        };
        Ok(self.builder.push(op, vec![self.id], out))
    }

    /// Mean over `dims` (removed from the shape; keep_dims unsupported).
    pub fn reduce_mean(&self, dims: &[usize], keep_dims: bool) -> Result<Op> {
        self.reduce(dims, keep_dims, true)
    }

    /// Sum over `dims` (removed from the shape; keep_dims unsupported).
    pub fn reduce_sum(&self, dims: &[usize], keep_dims: bool) -> Result<Op> {
        self.reduce(dims, keep_dims, false)
    }

    fn unary(&self, op: OpKind) -> Op {
        let dims = self.dims();
        self.builder.push(op, vec![self.id], dims)
    }

    pub fn sqrt(&self) -> Result<Op> {
        Ok(self.unary(OpKind::Sqrt))
    }

    pub fn neg(&self) -> Result<Op> {
        Ok(self.unary(OpKind::Neg))
    }

    pub fn exp(&self) -> Result<Op> {
        Ok(self.unary(OpKind::Exp))
    }

    pub fn log(&self) -> Result<Op> {
        Ok(self.unary(OpKind::Log))
    }

    /// Elementwise reciprocal `1 / x` (the missing half of `a / b`).
    pub fn recip(&self) -> Result<Op> {
        Ok(self.unary(OpKind::Recip))
    }
}

impl std::ops::Add for Op {
    type Output = Result<Op>;
    fn add(self, rhs: Op) -> Result<Op> {
        self.binary(&rhs, OpKind::Add, "add")
    }
}

impl std::ops::Sub for Op {
    type Output = Result<Op>;
    fn sub(self, rhs: Op) -> Result<Op> {
        self.binary(&rhs, OpKind::Sub, "sub")
    }
}

impl std::ops::Mul for Op {
    type Output = Result<Op>;
    fn mul(self, rhs: Op) -> Result<Op> {
        self.binary(&rhs, OpKind::Mul, "mul")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_inference_conv_style() {
        let b = GraphBuilder::new("t");
        let x = b.parameter(0, &[2, 3, 8, 8], "x").unwrap();
        // strided window slice: start 1, stop 8, stride 2 -> ceil(7/2) = 4
        let s = x.slice_in_dim(1, 8, 2, 2).unwrap();
        assert_eq!(s.dims(), vec![2, 3, 4, 8]);
        let t = s.transpose(&[1, 0, 2, 3]).unwrap();
        assert_eq!(t.dims(), vec![3, 2, 4, 8]);
        let w = b.parameter(1, &[5, 3], "w").unwrap();
        // [5,3] x [3,2,4,8] contracting 3 -> [5,2,4,8]
        let d = w.dot_general(&t, &[1], &[0]).unwrap();
        assert_eq!(d.dims(), vec![5, 2, 4, 8]);
        let m = d.reduce_mean(&[2, 3], false).unwrap();
        assert_eq!(m.dims(), vec![5, 2]);
    }

    #[test]
    fn concat_and_broadcast_shapes() {
        let b = GraphBuilder::new("t");
        let x = b.parameter(0, &[1, 2, 4, 4], "x").unwrap();
        let pad = b.c0(0.0).unwrap().broadcast(&[1, 2, 1, 4]).unwrap();
        let y = pad.concat_in_dim(&[x.clone(), pad.clone()], 2).unwrap();
        assert_eq!(y.dims(), vec![1, 2, 6, 4]);
        let g = b.parameter(1, &[2], "g").unwrap();
        let gb = g.broadcast_in_dim(&[1, 2, 6, 4], &[1]).unwrap();
        let prod = (y * gb).unwrap();
        assert_eq!(prod.dims(), vec![1, 2, 6, 4]);
    }

    #[test]
    fn invalid_shapes_rejected() {
        let b = GraphBuilder::new("t");
        let x = b.parameter(0, &[2, 3], "x").unwrap();
        assert!(x.reshape(&[7]).is_err());
        assert!(x.transpose(&[0, 0]).is_err());
        assert!(x.slice_in_dim(2, 2, 1, 0).is_err());
        let y = b.parameter(1, &[3, 2], "y").unwrap();
        assert!((x.clone() + y.clone()).is_err());
        assert!(x.dot_general(&y, &[0], &[0]).is_err()); // 2 != 3
    }

    #[test]
    fn build_validates_parameters() {
        let b = GraphBuilder::new("t");
        let x = b.parameter(0, &[2], "x").unwrap();
        assert!(b.parameter(0, &[2], "dup").is_err());
        let g = b.build(&x).unwrap();
        assert_eq!(g.n_params, 1);
        assert_eq!(g.param_dims(), vec![vec![2]]);

        let b2 = GraphBuilder::new("gap");
        let y = b2.parameter(3, &[1], "y").unwrap();
        assert!(b2.build(&y).is_err(), "non-contiguous parameter indices");
    }

    #[test]
    fn training_op_shapes() {
        let b = GraphBuilder::new("t");
        let x = b.parameter(0, &[2, 3], "x").unwrap();
        let y = b.parameter(1, &[2, 3], "y").unwrap();
        let d = (x.clone() - y.clone()).unwrap();
        assert_eq!(d.dims(), vec![2, 3]);
        assert_eq!(d.exp().unwrap().dims(), vec![2, 3]);
        assert_eq!(x.log().unwrap().recip().unwrap().neg().unwrap().dims(), vec![2, 3]);
        let mask = x.gt(&y).unwrap();
        assert_eq!(mask.select(&x, &y).unwrap().dims(), vec![2, 3]);
        let s = x.reduce_sum(&[0, 1], false).unwrap();
        assert_eq!(s.dims(), Vec::<usize>::new());
        // scalar broadcast works for sub/gt like the other binaries
        let c = b.c0(1.0).unwrap();
        assert_eq!((x.clone() - c.clone()).unwrap().dims(), vec![2, 3]);
        assert_eq!(x.gt(&c).unwrap().dims(), vec![2, 3]);
        // select demands one shape
        let z = b.parameter(2, &[3, 2], "z").unwrap();
        assert!(mask.select(&x, &z).is_err());
        // empty reduces rejected for sum too
        let e = b.parameter(3, &[2, 0], "e").unwrap();
        assert!(e.reduce_sum(&[1], false).is_err());
    }

    #[test]
    fn spmm_csr_shapes_and_validation() {
        let b = GraphBuilder::new("t");
        // 2x3 sparse: row 0 = {0, 2}, row 1 = {1}
        let rp = Arc::new(vec![0u32, 2, 3]);
        let ci = Arc::new(vec![0u32, 2, 1]);
        let vals = b.parameter(0, &[3], "s").unwrap();
        let x = b.parameter(1, &[4, 3, 5], "x").unwrap();
        let y = vals.spmm_csr(&x, 2, 3, rp.clone(), ci.clone(), 1, None).unwrap();
        assert_eq!(y.dims(), vec![2, 4, 5]);
        // rhs axis extent mismatch
        assert!(vals.spmm_csr(&x, 2, 3, rp.clone(), ci.clone(), 0, None).is_err());
        // vals length must equal nnz
        let bad = b.parameter(2, &[2], "bad").unwrap();
        assert!(bad.spmm_csr(&x, 2, 3, rp.clone(), ci.clone(), 1, None).is_err());
        // non-ascending columns rejected
        let ci_bad = Arc::new(vec![2u32, 0, 1]);
        assert!(vals.spmm_csr(&x, 2, 3, rp, ci_bad, 1, None).is_err());
        // bad perm rejected
        let rp2 = Arc::new(vec![0u32, 2, 3]);
        let ci2 = Arc::new(vec![0u32, 2, 1]);
        let perm_bad = Some(Arc::new(vec![0u32, 1, 7]));
        assert!(vals.spmm_csr(&x, 2, 3, rp2, ci2, 1, perm_bad).is_err());
    }

    #[test]
    fn cross_builder_ops_rejected() {
        let b1 = GraphBuilder::new("a");
        let b2 = GraphBuilder::new("b");
        let x = b1.parameter(0, &[2], "x").unwrap();
        let y = b2.parameter(0, &[2], "y").unwrap();
        assert!((x.clone() + y).is_err());
        assert!(b2.build(&x).is_err());
    }
}
