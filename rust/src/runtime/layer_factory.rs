//! Graph-IR layer factory: constructs the computations of single layers
//! (original / SVD / Tucker / branched / merged) at ANY rank directly in
//! rust, so the Algorithm 1 rank search and the Fig. 2/5 sweeps run with
//! zero python involvement and an executable cache keyed by configuration.
//! The graphs compile on every `runtime::Backend` (native CPU by default,
//! XLA:CPU under `--features xla-pjrt`).
//!
//! Convolution strategy mirrors the L1 Pallas kernel (DESIGN.md
//! §Hardware-Adaptation): pad, then k x k shifted strided slices, each
//! contracted with the corresponding weight plane via `dot_general` — the
//! same arithmetic as im2col without materialising the im2col matrix. The
//! IR has no conv primitive, so this *is* our conv lowering.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::graph::{Graph, GraphBuilder, Op};
use super::{Buffer, Compiled, CompileOptions, Engine};
use crate::decompose::rank_opt::LayerTimer;
use crate::decompose::sparse::SparseResidual;
use crate::decompose::Scheme;
use crate::model::ConvSite;
use crate::profiler::Timer;
use crate::util::rng::Rng;

type B = GraphBuilder;

// --------------------------------------------------------------------------
// Op library (shared with netbuilder)
// --------------------------------------------------------------------------

/// Zero-pad spatial dims (2, 3) of an NCHW op by `p` on each side.
pub fn pad_hw(b: &B, x: &Op, dims: &[usize; 4], p: usize, fill: f32) -> Result<Op> {
    if p == 0 {
        return Ok(x.clone());
    }
    let (n, c, h, w) = (dims[0], dims[1], dims[2], dims[3]);
    let scalar = b.c0(fill)?;
    let pad_h = scalar.broadcast(&[n, c, p, w])?;
    let x = pad_h.concat_in_dim(&[x.clone(), pad_h.clone()], 2)?;
    let hp = h + 2 * p;
    let pad_w = scalar.broadcast(&[n, c, hp, p])?;
    pad_w.concat_in_dim(&[x, pad_w.clone()], 3)
}

/// Zero-pad ONE spatial axis (2 = H, 3 = W) of an NCHW op by `p` on each
/// side — the CP chain pads per depthwise stage instead of both at once.
pub fn pad_axis(b: &B, x: &Op, dims: &[usize; 4], p: usize, axis: usize) -> Result<Op> {
    if p == 0 {
        return Ok(x.clone());
    }
    let mut pad_dims = *dims;
    pad_dims[axis] = p;
    let scalar = b.c0(0f32)?;
    let pad = scalar.broadcast(&pad_dims)?;
    pad.concat_in_dim(&[x.clone(), pad.clone()], axis)
}

/// Depthwise 1-D conv along `axis` (2 = H, 3 = W): channel `j` of the
/// output is a k-tap FIR of channel `j` of the input with taps `taps[j,:]`
/// ([R, k]). `dims` are x's dims, already padded along `axis`. This is the
/// kx1 / 1xk stage of the CP (Lebedev) chain, built from slice +
/// broadcast-multiply + add so every node already has a VJP.
pub fn depthwise_1d(
    x: &Op,
    taps: &Op,
    dims: &[usize; 4],
    k: usize,
    stride: usize,
    axis: usize,
) -> Result<Op> {
    let r = dims[1];
    let len = dims[axis];
    if len < k {
        bail!("axis extent {len} smaller than kernel {k}");
    }
    let o = (len - k) / stride + 1;
    let mut out_dims = dims.to_vec();
    out_dims[axis] = o;
    let mut acc: Option<Op> = None;
    for j in 0..k {
        let xs = x.slice_in_dim(j, j + (o - 1) * stride + 1, stride, axis)?;
        let tap = taps
            .slice_in_dim1(j, j + 1, 1)?
            .reshape(&[r])?
            .broadcast_in_dim(&out_dims, &[1])?;
        let contrib = (xs * tap)?;
        acc = Some(match acc {
            None => contrib,
            Some(a) => (a + contrib)?,
        });
    }
    Ok(acc.unwrap())
}

/// NCHW conv via shifted-slice matmuls. `x`: [N,C,H,W] (already padded),
/// `w`: [S,C,k,k]. Returns [N,S,Ho,Wo].
pub fn conv2d(
    _b: &B,
    x: &Op,
    w: &Op,
    padded: &[usize; 4],
    s_ch: usize,
    k: usize,
    stride: usize,
) -> Result<Op> {
    let (n, c, hp, wp) = (padded[0], padded[1], padded[2], padded[3]);
    if hp < k || wp < k {
        bail!("spatial {hp}x{wp} smaller than kernel {k}");
    }
    let ho = (hp - k) / stride + 1;
    let wo = (wp - k) / stride + 1;
    let mut acc: Option<Op> = None;
    for kh in 0..k {
        for kw in 0..k {
            // strided window: [N, C, Ho, Wo]
            let xs = x
                .slice_in_dim(kh, kh + (ho - 1) * stride + 1, stride, 2)?
                .slice_in_dim(kw, kw + (wo - 1) * stride + 1, stride, 3)?;
            // weight plane: [S, C]
            let wk = w
                .slice_in_dim1(kh, kh + 1, 2)?
                .slice_in_dim1(kw, kw + 1, 3)?
                .reshape(&[s_ch, c])?;
            // [S, C] x [N, C, Ho, Wo] contracting C -> [S, N, Ho, Wo]
            let contrib = wk.dot_general(&xs, &[1], &[1])?;
            acc = Some(match acc {
                None => contrib,
                Some(a) => (a + contrib)?,
            });
        }
    }
    let snhw = acc.unwrap();
    let _ = n;
    snhw.transpose(&[1, 0, 2, 3])
}

/// 1x1 conv as a channel contraction, with optional spatial stride
/// (slicing — equivalent to a strided 1x1 conv). `w`: [S, C].
pub fn conv1x1(x: &Op, w: &Op, stride: usize) -> Result<Op> {
    let x = if stride == 1 {
        x.clone()
    } else {
        let dims = x.dims();
        x.slice_in_dim(0, dims[2], stride, 2)?
            .slice_in_dim(0, dims[3], stride, 3)?
    };
    // [S, C] x [N, C, H, W] -> [S, N, H, W] -> [N, S, H, W]
    let out = w.dot_general(&x, &[1], &[1])?;
    out.transpose(&[1, 0, 2, 3])
}

/// Grouped conv (Fig. 4): per-group channel slabs convolved independently,
/// concatenated along the output-channel dim.
#[allow(clippy::too_many_arguments)]
pub fn grouped_conv2d(
    b: &B,
    x: &Op,
    w: &Op,
    padded: &[usize; 4],
    s_ch: usize,
    k: usize,
    stride: usize,
    groups: usize,
) -> Result<Op> {
    let (n, c, hp, wp) = (padded[0], padded[1], padded[2], padded[3]);
    if c % groups != 0 || s_ch % groups != 0 {
        bail!("bad grouping C={c} S={s_ch} G={groups}");
    }
    let (cg, sg) = (c / groups, s_ch / groups);
    let mut parts = Vec::with_capacity(groups);
    for g in 0..groups {
        let xg = x.slice_in_dim1(g * cg, (g + 1) * cg, 1)?;
        let wg = w.slice_in_dim1(g * sg, (g + 1) * sg, 0)?;
        parts.push(conv2d(b, &xg, &wg, &[n, cg, hp, wp], sg, k, stride)?);
    }
    let first = parts[0].clone();
    first.concat_in_dim(&parts[1..], 1)
}

/// Sparse-residual conv arm: applies S (stored as per-tap CSR slabs over
/// the [S, C] plane) to `x` with the SAME padding and stride as the dense
/// conv at the site, so its output aligns with the chain's [N, S, Ho, Wo].
/// `x` is the UNPADDED [N, C, H, W] input, `vals` the [nnz] value vector
/// in tap-major stream order; each tap's slab slices a contiguous range of
/// `vals`, so no `val_perm` is needed.
#[allow(clippy::too_many_arguments)]
pub fn sparse_conv(
    b: &B,
    x: &Op,
    vals: &Op,
    pattern: &SparseResidual,
    dims: &[usize; 4],
    s_ch: usize,
    k: usize,
    stride: usize,
    padding: usize,
) -> Result<Op> {
    let c = dims[1];
    let xp = pad_hw(b, x, dims, padding, 0.0)?;
    let (hp, wp) = (dims[2] + 2 * padding, dims[3] + 2 * padding);
    if hp < k || wp < k {
        bail!("spatial {hp}x{wp} smaller than kernel {k}");
    }
    let ho = (hp - k) / stride + 1;
    let wo = (wp - k) / stride + 1;
    let mut acc: Option<Op> = None;
    for tap in pattern.taps()? {
        // the same shifted strided window the dense conv uses for (kh, kw)
        let xs = xp
            .slice_in_dim(tap.h, tap.h + (ho - 1) * stride + 1, stride, 2)?
            .slice_in_dim(tap.w, tap.w + (wo - 1) * stride + 1, stride, 3)?;
        let vt = vals.slice_in_dim1(tap.lo, tap.hi, 0)?;
        // [nnz_tap] spmm [N, C, Ho, Wo] contracting C -> [S, N, Ho, Wo]
        let contrib = vt.spmm_csr(
            &xs,
            s_ch,
            c,
            Arc::new(tap.row_ptr),
            Arc::new(tap.col_idx),
            1,
            None,
        )?;
        acc = Some(match acc {
            None => contrib,
            Some(a) => (a + contrib)?,
        });
    }
    match acc {
        Some(snhw) => snhw.transpose(&[1, 0, 2, 3]),
        None => bail!("sparse pattern has no taps"),
    }
}

/// Per-channel affine (inference-mode BN): `x * g[c] + b[c]`.
pub fn bn_affine(x: &Op, gamma: &Op, beta: &Op, dims: &[usize; 4]) -> Result<Op> {
    let out_dims: Vec<usize> = dims.to_vec();
    let g = gamma.broadcast_in_dim(&out_dims, &[1])?;
    let bta = beta.broadcast_in_dim(&out_dims, &[1])?;
    (x.clone() * g)? + bta
}

/// Batch-statistics BN (training-mode, matching the python train graphs'
/// `_bn`): normalise with the batch mean/variance over (N, H, W), then
/// the per-channel affine. Fully differentiable through `autograd` —
/// mean, variance and rsqrt all get VJPs.
pub fn bn_batchstats(b: &B, x: &Op, gamma: &Op, beta: &Op, dims: &[usize; 4]) -> Result<Op> {
    let out_dims: Vec<usize> = dims.to_vec();
    let mu = x.reduce_mean(&[0, 2, 3], false)?; // [C]
    let mu_b = mu.broadcast_in_dim(&out_dims, &[1])?;
    let centered = (x.clone() - mu_b)?;
    let var = (centered.clone() * centered.clone())?.reduce_mean(&[0, 2, 3], false)?;
    let eps = b.c0(1e-5)?;
    let rstd = ((var + eps)?.sqrt()?).recip()?; // [C]
    let rstd_b = rstd.broadcast_in_dim(&out_dims, &[1])?;
    let xn = (centered * rstd_b)?;
    let g = gamma.broadcast_in_dim(&out_dims, &[1])?;
    let bta = beta.broadcast_in_dim(&out_dims, &[1])?;
    (xn * g)? + bta
}

/// ReLU: max(x, 0).
pub fn relu(b: &B, x: &Op) -> Result<Op> {
    let zero = b.c0(0f32)?;
    x.max(&zero)
}

/// 3x3/2 max-pool with padding 1 (the ResNet stem pool): -inf pad + shifted
/// slice max (no reduce_window in this IR).
pub fn maxpool_3x3_s2(b: &B, x: &Op, dims: &[usize; 4]) -> Result<Op> {
    let padded = pad_hw(b, x, dims, 1, f32::NEG_INFINITY)?;
    let (hp, wp) = (dims[2] + 2, dims[3] + 2);
    let ho = (hp - 3) / 2 + 1;
    let wo = (wp - 3) / 2 + 1;
    let mut acc: Option<Op> = None;
    for kh in 0..3usize {
        for kw in 0..3usize {
            let xs = padded
                .slice_in_dim(kh, kh + (ho - 1) * 2 + 1, 2, 2)?
                .slice_in_dim(kw, kw + (wo - 1) * 2 + 1, 2, 3)?;
            acc = Some(match acc {
                None => xs,
                Some(a) => a.max(&xs)?,
            });
        }
    }
    Ok(acc.unwrap())
}

/// Global average pool: mean over H, W -> [N, C].
pub fn gap(x: &Op) -> Result<Op> {
    x.reduce_mean(&[2, 3], false)
}

// --------------------------------------------------------------------------
// Single-layer computations for the rank search
// --------------------------------------------------------------------------

/// Build the computation for one site under one scheme. Parameters:
/// p0 = input [batch, C, hw, hw], then the weights in scheme order.
/// Returns (graph, weight shapes in parameter order).
pub fn build_layer(
    site: &ConvSite,
    scheme: &Scheme,
    batch: usize,
    hw: usize,
) -> Result<(Graph, Vec<Vec<usize>>)> {
    let b = B::new(&format!("{}_{}", site.name, scheme_tag(scheme)));
    let x = b.parameter(0, &[batch, site.c, hw, hw], "x")?;
    let dims = [batch, site.c, hw, hw];
    let mut shapes: Vec<Vec<usize>> = Vec::new();
    let mut pidx = 1usize;
    let mut param = |b: &B, shape: Vec<usize>, name: &str| -> Result<Op> {
        let p = b.parameter(pidx, &shape, name)?;
        pidx += 1;
        shapes.push(shape);
        Ok(p)
    };

    let out = match scheme {
        Scheme::Orig | Scheme::Merged { .. } => {
            // Merged conv2 is just a smaller dense conv; shapes come from
            // the scheme for Merged, from the site for Orig.
            let (ci, co) = match scheme {
                Scheme::Merged { r1, r2 } => (*r1, *r2),
                _ => (site.c, site.s),
            };
            if site.k == 1 {
                let w = param(&b, vec![co, ci], "w")?;
                let x = if ci == site.c {
                    x
                } else {
                    // merged site consumes r1 channels; re-declare input
                    bail!("merged layer input must be pre-projected; use full-stack timing")
                };
                conv1x1(&x, &w, site.stride)?
            } else {
                let w = param(&b, vec![co, ci, site.k, site.k], "w")?;
                let x = if ci == site.c {
                    x
                } else {
                    // For isolated timing of a merged core we declare the
                    // input at the reduced width instead.
                    let bb = B::new("merged_core");
                    let x2 = bb.parameter(0, &[batch, ci, hw, hw], "x")?;
                    let w2 = bb.parameter(1, &[co, ci, site.k, site.k], "w")?;
                    let pd = [batch, ci, hw + 2 * site.padding, hw + 2 * site.padding];
                    let xp = pad_hw(&bb, &x2, &[batch, ci, hw, hw], site.padding, 0.0)?;
                    let o = conv2d(&bb, &xp, &w2, &pd, co, site.k, site.stride)?;
                    let graph = bb.build(&o)?;
                    return Ok((graph, vec![vec![co, ci, site.k, site.k]]));
                };
                let xp = pad_hw(&b, &x, &dims, site.padding, 0.0)?;
                let pd = [batch, ci, hw + 2 * site.padding, hw + 2 * site.padding];
                conv2d(&b, &xp, &w, &pd, co, site.k, site.stride)?
            }
        }
        Scheme::MergedInto { .. } => bail!("merged_into sites are timed via their peer"),
        chain => lower_chain(&b, &x, site, chain, batch, hw, &mut param)?,
    };
    let graph = b.build(&out)?;
    Ok((graph, shapes))
}

/// Lower a factor-chain scheme (or its sparse-residual composition) at
/// `site` onto builder `b`. `param` declares each weight in scheme order.
/// Split out of `build_layer` so `Scheme::Sparse` can recurse into its
/// base chain and then add the residual arm on the SAME input.
fn lower_chain(
    b: &B,
    x: &Op,
    site: &ConvSite,
    scheme: &Scheme,
    batch: usize,
    hw: usize,
    param: &mut dyn FnMut(&B, Vec<usize>, &str) -> Result<Op>,
) -> Result<Op> {
    let out = match scheme {
        Scheme::Svd { r } => {
            let w0 = param(b, vec![*r, site.c], "w0")?;
            let w1 = param(b, vec![site.s, *r], "w1")?;
            if site.k != 1 {
                bail!("svd scheme on k={} site", site.k);
            }
            let t = conv1x1(x, &w0, site.stride)?;
            conv1x1(&t, &w1, 1)?
        }
        Scheme::Tucker { r1, r2 } => {
            let u = param(b, vec![*r1, site.c], "u")?;
            let core = param(b, vec![*r2, *r1, site.k, site.k], "core")?;
            let v = param(b, vec![site.s, *r2], "v")?;
            let t = conv1x1(x, &u, 1)?;
            let tdims = [batch, *r1, hw, hw];
            let tp = pad_hw(b, &t, &tdims, site.padding, 0.0)?;
            let pd = [batch, *r1, hw + 2 * site.padding, hw + 2 * site.padding];
            let t = conv2d(b, &tp, &core, &pd, *r2, site.k, site.stride)?;
            conv1x1(&t, &v, 1)?
        }
        Scheme::Branched { r1, r2, groups } => {
            let u = param(b, vec![*r1, site.c], "u")?;
            let core = param(b, vec![*r2, r1 / groups, site.k, site.k], "core")?;
            let v = param(b, vec![site.s, *r2], "v")?;
            let t = conv1x1(x, &u, 1)?;
            let tdims = [batch, *r1, hw, hw];
            let tp = pad_hw(b, &t, &tdims, site.padding, 0.0)?;
            let pd = [batch, *r1, hw + 2 * site.padding, hw + 2 * site.padding];
            let t = grouped_conv2d(b, &tp, &core, &pd, *r2, site.k, site.stride, *groups)?;
            conv1x1(&t, &v, 1)?
        }
        Scheme::Tucker2 { r1, r2 } => {
            let u = param(b, vec![*r1, site.c], "u")?;
            if site.k == 1 {
                // three chained 1x1s; stride rides on the first factor
                let core = param(b, vec![*r2, *r1], "core")?;
                let v = param(b, vec![site.s, *r2], "v")?;
                let t = conv1x1(x, &u, site.stride)?;
                let t = conv1x1(&t, &core, 1)?;
                conv1x1(&t, &v, 1)?
            } else {
                let core = param(b, vec![*r2, *r1, site.k, site.k], "core")?;
                let v = param(b, vec![site.s, *r2], "v")?;
                let t = conv1x1(x, &u, 1)?;
                let tdims = [batch, *r1, hw, hw];
                let tp = pad_hw(b, &t, &tdims, site.padding, 0.0)?;
                let pd = [batch, *r1, hw + 2 * site.padding, hw + 2 * site.padding];
                let t = conv2d(b, &tp, &core, &pd, *r2, site.k, site.stride)?;
                conv1x1(&t, &v, 1)?
            }
        }
        Scheme::Cp { r } => {
            if site.k == 1 {
                // the CP chain of a matrix is the SVD pair
                let w0 = param(b, vec![*r, site.c], "w0")?;
                let w1 = param(b, vec![site.s, *r], "w1")?;
                let t = conv1x1(x, &w0, site.stride)?;
                conv1x1(&t, &w1, 1)?
            } else {
                // Lebedev chain: 1x1 -> kx1 depthwise -> 1xk depthwise -> 1x1
                let u = param(b, vec![*r, site.c], "u")?;
                let kh = param(b, vec![*r, site.k], "kh")?;
                let kw = param(b, vec![*r, site.k], "kw")?;
                let w1 = param(b, vec![site.s, *r], "w1")?;
                let t = conv1x1(x, &u, 1)?;
                let tdims = [batch, *r, hw, hw];
                let tp = pad_axis(b, &t, &tdims, site.padding, 2)?;
                let hp = hw + 2 * site.padding;
                let t = depthwise_1d(&tp, &kh, &[batch, *r, hp, hw], site.k, site.stride, 2)?;
                let ho = (hp - site.k) / site.stride + 1;
                let tp = pad_axis(b, &t, &[batch, *r, ho, hw], site.padding, 3)?;
                let wp = hw + 2 * site.padding;
                let t = depthwise_1d(&tp, &kw, &[batch, *r, ho, wp], site.k, site.stride, 3)?;
                conv1x1(&t, &w1, 1)?
            }
        }
        Scheme::Sparse { base, ppm } => {
            let dense = lower_chain(b, x, site, base, batch, hw, &mut *param)?;
            let wdims = if site.k == 1 {
                vec![site.s, site.c]
            } else {
                vec![site.s, site.c, site.k, site.k]
            };
            let nnz = Scheme::sparse_nnz(site.c, site.s, site.k, *ppm);
            // deterministic synthetic pattern: isolated timing needs the
            // CSR geometry at the right density, not fitted values
            let pattern = SparseResidual::synthetic(&wdims, nnz)?;
            let vals = param(b, vec![nnz], "s")?;
            let dims = [batch, site.c, hw, hw];
            let sp = sparse_conv(
                b,
                x,
                &vals,
                &pattern,
                &dims,
                site.s,
                site.k,
                site.stride,
                site.padding,
            )?;
            (dense + sp)?
        }
        Scheme::Orig | Scheme::Merged { .. } | Scheme::MergedInto { .. } => {
            bail!("not a factor-chain scheme: {scheme:?}")
        }
    };
    Ok(out)
}

fn scheme_tag(s: &Scheme) -> String {
    match s {
        Scheme::Orig => "orig".into(),
        Scheme::Svd { r } => format!("svd{r}"),
        Scheme::Tucker { r1, r2 } => format!("tk{r1}x{r2}"),
        Scheme::Branched { r1, r2, groups } => format!("br{r1}x{r2}g{groups}"),
        Scheme::Merged { r1, r2 } => format!("mg{r1}x{r2}"),
        Scheme::MergedInto { .. } => "mgi".into(),
        Scheme::Tucker2 { r1, r2 } => format!("tk2_{r1}x{r2}"),
        Scheme::Cp { r } => format!("cp{r}"),
        Scheme::Sparse { base, ppm } => format!("{}+s{ppm}", scheme_tag(base)),
    }
}

// --------------------------------------------------------------------------
// Engine-backed LayerTimer with executable + buffer cache
// --------------------------------------------------------------------------

/// Times layer variants on a real `runtime::Engine` (native CPU by
/// default, XLA:CPU under the `xla-pjrt` feature). Compiled executables
/// are cached by (site shape, scheme, batch, hw, compile options) so
/// Algorithm 1 sweeps and repeated experiments don't recompile.
///
/// The timer compiles through `Engine::compile` with its configured
/// `CompileOptions` (top opt level by default), so Algorithm 1's
/// engine-backed rank search times *optimized* graphs — including the
/// re-merge fusion's verdict on unprofitable ranks — instead of naive
/// factor chains.
pub struct EngineLayerTimer {
    engine: Engine,
    pub timer: Timer,
    opts: CompileOptions,
    cache: HashMap<String, Compiled>,
    rng: Rng,
    pub compiles: usize,
    pub cache_hits: usize,
}

impl EngineLayerTimer {
    pub fn new(engine: Engine) -> EngineLayerTimer {
        EngineLayerTimer {
            engine,
            timer: Timer::quick(),
            opts: CompileOptions::default(),
            cache: HashMap::new(),
            rng: Rng::new(0xA11CE),
            compiles: 0,
            cache_hits: 0,
        }
    }

    pub fn with_timer(engine: Engine, timer: Timer) -> EngineLayerTimer {
        EngineLayerTimer { timer, ..EngineLayerTimer::new(engine) }
    }

    pub fn with_options(engine: Engine, timer: Timer, opts: CompileOptions) -> EngineLayerTimer {
        EngineLayerTimer { timer, opts, ..EngineLayerTimer::new(engine) }
    }

    pub fn options(&self) -> &CompileOptions {
        &self.opts
    }

    fn key(&self, site: &ConvSite, scheme: &Scheme, batch: usize, hw: usize) -> String {
        format!(
            "{}x{}k{}s{}p{}/{}/b{batch}hw{hw}/{}",
            site.c,
            site.s,
            site.k,
            site.stride,
            site.padding,
            scheme_tag(scheme),
            self.opts.cache_key()
        )
    }

    fn executable(
        &mut self,
        site: &ConvSite,
        scheme: &Scheme,
        batch: usize,
        hw: usize,
    ) -> Result<(Compiled, Vec<Vec<usize>>)> {
        let key = self.key(site, scheme, batch, hw);
        let (graph, shapes) = build_layer(site, scheme, batch, hw)?;
        if let Some(exe) = self.cache.get(&key) {
            self.cache_hits += 1;
            return Ok((exe.clone(), shapes));
        }
        let exe = self.engine.compile(&graph, &self.opts)?;
        self.compiles += 1;
        self.cache.insert(key, exe.clone());
        Ok((exe, shapes))
    }

    /// Median-of-steady-state seconds per execution for the configuration.
    pub fn measure(
        &mut self,
        site: &ConvSite,
        scheme: &Scheme,
        batch: usize,
        hw: usize,
    ) -> Result<f64> {
        let (exe, shapes) = self.executable(site, scheme, batch, hw)?;
        // Input at the width the (possibly merged) layer expects.
        let cin = match scheme {
            Scheme::Merged { r1, .. } => *r1,
            _ => site.c,
        };
        let x_host: Vec<f32> = (0..batch * cin * hw * hw)
            .map(|_| self.rng.normal_f32() * 0.1)
            .collect();
        let mut bufs = vec![self.engine.upload(&x_host, &[batch, cin, hw, hw])?];
        for shp in &shapes {
            let n: usize = shp.iter().product();
            let w = self.rng.he_weights(n, shp.iter().skip(1).product::<usize>().max(1));
            bufs.push(self.engine.upload(&w, shp)?);
        }
        let refs: Vec<&Buffer> = bufs.iter().collect();
        let summary = self.timer.measure(|| {
            let out = exe.run_buffers(&refs)?;
            // Synchronise: forces completion of any asynchronous backend
            // execution before the sample is recorded.
            out[0].sync()?;
            Ok(())
        })?;
        Ok(summary.trimmed_mean)
    }
}

impl LayerTimer for EngineLayerTimer {
    fn time_layer(
        &mut self,
        site: &ConvSite,
        scheme: &Scheme,
        batch: usize,
        hw: usize,
    ) -> Result<f64> {
        self.measure(site, scheme, batch, hw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SiteKind;
    use crate::runtime::HostTensor;

    fn site(c: usize, s: usize, k: usize, stride: usize) -> ConvSite {
        ConvSite {
            name: format!("t{c}x{s}"),
            c,
            s,
            k,
            stride,
            padding: if k > 1 { 1 } else { 0 },
            kind: SiteKind::Conv,
        }
    }

    fn run_layer(
        site: &ConvSite,
        scheme: &Scheme,
        batch: usize,
        hw: usize,
        x: &[f32],
        weights: &[Vec<f32>],
    ) -> Vec<f32> {
        let eng = Engine::native();
        let (graph, shapes) = build_layer(site, scheme, batch, hw).unwrap();
        assert_eq!(shapes.len(), weights.len());
        let exe = eng.compile(&graph, &CompileOptions::default()).unwrap();
        let mut args = vec![HostTensor::new(vec![batch, site.c, hw, hw], x.to_vec())];
        for (shp, w) in shapes.iter().zip(weights.iter()) {
            args.push(HostTensor::new(shp.clone(), w.clone()));
        }
        let out = exe.run_hosts(&args).unwrap();
        out[0].data.clone()
    }

    /// Reference NCHW conv on the host for cross-checking the IR conv.
    fn ref_conv(
        x: &[f32],
        w: &[f32],
        (n, c, h, wd): (usize, usize, usize, usize),
        (s, k, stride, pad): (usize, usize, usize, usize),
    ) -> Vec<f32> {
        let ho = (h + 2 * pad - k) / stride + 1;
        let wo = (wd + 2 * pad - k) / stride + 1;
        let mut out = vec![0f32; n * s * ho * wo];
        for ni in 0..n {
            for si in 0..s {
                for oy in 0..ho {
                    for ox in 0..wo {
                        let mut acc = 0f32;
                        for ci in 0..c {
                            for ky in 0..k {
                                for kx in 0..k {
                                    let iy = oy * stride + ky;
                                    let ix = ox * stride + kx;
                                    if iy < pad || ix < pad {
                                        continue;
                                    }
                                    let (iy, ix) = (iy - pad, ix - pad);
                                    if iy >= h || ix >= wd {
                                        continue;
                                    }
                                    acc += x[((ni * c + ci) * h + iy) * wd + ix]
                                        * w[((si * c + ci) * k + ky) * k + kx];
                                }
                            }
                        }
                        out[((ni * s + si) * ho + oy) * wo + ox] = acc;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn builder_conv_matches_reference() {
        let (n, c, s, h, k) = (2, 3, 5, 8, 3);
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..n * c * h * h).map(|_| rng.normal_f32()).collect();
        let w: Vec<f32> = (0..s * c * k * k).map(|_| rng.normal_f32()).collect();
        for stride in [1usize, 2] {
            let t = site(c, s, k, stride);
            let got = run_layer(&t, &Scheme::Orig, n, h, &x, &[w.clone()]);
            let want = ref_conv(&x, &w, (n, c, h, h), (s, k, stride, 1));
            crate::util::check::assert_allclose(&got, &want, 1e-4, 1e-4);
        }
    }

    #[test]
    fn svd_stack_matches_composition() {
        let (n, c, s, r, h) = (2, 6, 8, 3, 4);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..n * c * h * h).map(|_| rng.normal_f32()).collect();
        let w0: Vec<f32> = (0..r * c).map(|_| rng.normal_f32()).collect();
        let w1: Vec<f32> = (0..s * r).map(|_| rng.normal_f32()).collect();
        let t = site(c, s, 1, 1);
        let got = run_layer(&t, &Scheme::Svd { r }, n, h, &x, &[w0.clone(), w1.clone()]);
        // compose on host: w = w1 @ w0, then 1x1 conv
        let mut w = vec![0f32; s * c];
        for si in 0..s {
            for ci in 0..c {
                for ri in 0..r {
                    w[si * c + ci] += w1[si * r + ri] * w0[ri * c + ci];
                }
            }
        }
        let want = ref_conv(&x, &w, (n, c, h, h), (s, 1, 1, 0));
        crate::util::check::assert_allclose(&got, &want, 1e-3, 1e-3);
    }

    #[test]
    fn grouped_equals_blockdiag_dense() {
        let (n, c, s, h, k, g) = (1, 4, 6, 6, 3, 2);
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..n * c * h * h).map(|_| rng.normal_f32()).collect();
        let wg: Vec<f32> = (0..s * (c / g) * k * k).map(|_| rng.normal_f32()).collect();
        // block-diagonal dense equivalent
        let mut wd = vec![0f32; s * c * k * k];
        let (cg, sg) = (c / g, s / g);
        for gi in 0..g {
            for so in 0..sg {
                for ci in 0..cg {
                    for ky in 0..k {
                        for kx in 0..k {
                            let s_abs = gi * sg + so;
                            let c_abs = gi * cg + ci;
                            wd[((s_abs * c + c_abs) * k + ky) * k + kx] =
                                wg[((s_abs * cg + ci) * k + ky) * k + kx];
                        }
                    }
                }
            }
        }
        let eng = Engine::native();
        let b = B::new("g");
        let x_op = b.parameter(0, &[1, c, h, h], "x").unwrap();
        let w_op = b.parameter(1, &[s, c / g, k, k], "w").unwrap();
        let xp = pad_hw(&b, &x_op, &[1, c, h, h], 1, 0.0).unwrap();
        let o = grouped_conv2d(&b, &xp, &w_op, &[1, c, h + 2, h + 2], s, k, 1, g).unwrap();
        let exe = eng
            .compile(&b.build(&o).unwrap(), &CompileOptions::default())
            .unwrap();
        let got = exe
            .run_hosts(&[
                HostTensor::new(vec![1, c, h, h], x.clone()),
                HostTensor::new(vec![s, c / g, k, k], wg),
            ])
            .unwrap()
            .remove(0);
        let want = ref_conv(&x, &wd, (n, c, h, h), (s, k, 1, 1));
        crate::util::check::assert_allclose(&got.data, &want, 1e-4, 1e-4);
    }

    #[test]
    fn maxpool_matches_reference() {
        let (n, c, h) = (1, 2, 6);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..n * c * h * h).map(|_| rng.normal_f32()).collect();
        let b = B::new("mp");
        let x_op = b.parameter(0, &[n, c, h, h], "x").unwrap();
        let o = maxpool_3x3_s2(&b, &x_op, &[n, c, h, h]).unwrap();
        let exe = Engine::native()
            .compile(&b.build(&o).unwrap(), &CompileOptions::default())
            .unwrap();
        let got = exe
            .run_hosts(&[HostTensor::new(vec![n, c, h, h], x.clone())])
            .unwrap()
            .remove(0);
        let ho = (h + 2 - 3) / 2 + 1;
        assert_eq!(got.dims, vec![n, c, ho, ho]);
        // reference: -inf-padded 3x3/2 max
        let mut want = vec![f32::NEG_INFINITY; n * c * ho * ho];
        for ci in 0..c {
            for oy in 0..ho {
                for ox in 0..ho {
                    let mut m = f32::NEG_INFINITY;
                    for ky in 0..3 {
                        for kx in 0..3 {
                            let iy = (oy * 2 + ky) as isize - 1;
                            let ix = (ox * 2 + kx) as isize - 1;
                            if iy < 0 || ix < 0 || iy >= h as isize || ix >= h as isize {
                                continue;
                            }
                            m = m.max(x[(ci * h + iy as usize) * h + ix as usize]);
                        }
                    }
                    want[(ci * ho + oy) * ho + ox] = m;
                }
            }
        }
        crate::util::check::assert_allclose(&got.data, &want, 1e-6, 1e-6);
    }

    #[test]
    fn tucker2_1x1_chain_matches_composition() {
        // three-matrix chain on a 1x1 site == dense conv with v @ core @ u
        let (n, c, s, r1, r2, h) = (2, 6, 8, 3, 4, 4);
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..n * c * h * h).map(|_| rng.normal_f32()).collect();
        let u: Vec<f32> = (0..r1 * c).map(|_| rng.normal_f32()).collect();
        let core: Vec<f32> = (0..r2 * r1).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..s * r2).map(|_| rng.normal_f32()).collect();
        for stride in [1usize, 2] {
            let t = site(c, s, 1, stride);
            let got = run_layer(
                &t,
                &Scheme::Tucker2 { r1, r2 },
                n,
                h,
                &x,
                &[u.clone(), core.clone(), v.clone()],
            );
            let mut w = vec![0f32; s * c];
            for si in 0..s {
                for ci in 0..c {
                    for j in 0..r2 {
                        for i in 0..r1 {
                            w[si * c + ci] +=
                                v[si * r2 + j] * core[j * r1 + i] * u[i * c + ci];
                        }
                    }
                }
            }
            let want = ref_conv(&x, &w, (n, c, h, h), (s, 1, stride, 0));
            crate::util::check::assert_allclose(&got, &want, 1e-3, 1e-3);
        }
    }

    #[test]
    fn cp_chain_matches_dense_composition() {
        // 1x1 -> kx1 -> 1xk -> 1x1 == dense conv with the rank-R sum
        // W[s,c,ky,kx] = sum_j w1[s,j] u[j,c] kh[j,ky] kw[j,kx]
        let (n, c, s, r, h, k) = (2, 4, 5, 3, 6, 3);
        let mut rng = Rng::new(8);
        let x: Vec<f32> = (0..n * c * h * h).map(|_| rng.normal_f32()).collect();
        let u: Vec<f32> = (0..r * c).map(|_| rng.normal_f32()).collect();
        let kh: Vec<f32> = (0..r * k).map(|_| rng.normal_f32()).collect();
        let kw: Vec<f32> = (0..r * k).map(|_| rng.normal_f32()).collect();
        let w1: Vec<f32> = (0..s * r).map(|_| rng.normal_f32()).collect();
        for stride in [1usize, 2] {
            let t = site(c, s, k, stride);
            let got = run_layer(
                &t,
                &Scheme::Cp { r },
                n,
                h,
                &x,
                &[u.clone(), kh.clone(), kw.clone(), w1.clone()],
            );
            let mut w = vec![0f32; s * c * k * k];
            for si in 0..s {
                for ci in 0..c {
                    for ky in 0..k {
                        for kx in 0..k {
                            let mut acc = 0f32;
                            for j in 0..r {
                                acc += w1[si * r + j]
                                    * u[j * c + ci]
                                    * kh[j * k + ky]
                                    * kw[j * k + kx];
                            }
                            w[((si * c + ci) * k + ky) * k + kx] = acc;
                        }
                    }
                }
            }
            let want = ref_conv(&x, &w, (n, c, h, h), (s, k, stride, 1));
            crate::util::check::assert_allclose(&got, &want, 1e-3, 1e-3);
        }
    }

    /// Densify a synthetic sparse pattern with the given vals into `w`.
    fn scatter_synthetic(w: &mut [f32], wdims: &[usize], vals: &[f32]) {
        let pat = SparseResidual::synthetic(wdims, vals.len()).unwrap();
        for (j, &fi) in pat.idx.iter().enumerate() {
            w[fi as usize] += vals[j];
        }
    }

    #[test]
    fn sparse_arm_adds_residual_to_svd_chain() {
        let (n, c, s, r, h) = (2, 6, 8, 3, 4);
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..n * c * h * h).map(|_| rng.normal_f32()).collect();
        let w0: Vec<f32> = (0..r * c).map(|_| rng.normal_f32()).collect();
        let w1: Vec<f32> = (0..s * r).map(|_| rng.normal_f32()).collect();
        let ppm = 100_000u32; // 10% of 48 entries -> nnz 4
        let nnz = Scheme::sparse_nnz(c, s, 1, ppm);
        assert_eq!(nnz, 4);
        let vals: Vec<f32> = (0..nnz).map(|_| rng.normal_f32()).collect();
        let sch = Scheme::Sparse { base: Box::new(Scheme::Svd { r }), ppm };
        let t = site(c, s, 1, 1);
        let got = run_layer(&t, &sch, n, h, &x, &[w0.clone(), w1.clone(), vals.clone()]);
        let mut w = vec![0f32; s * c];
        for si in 0..s {
            for ci in 0..c {
                for ri in 0..r {
                    w[si * c + ci] += w1[si * r + ri] * w0[ri * c + ci];
                }
            }
        }
        scatter_synthetic(&mut w, &[s, c], &vals);
        let want = ref_conv(&x, &w, (n, c, h, h), (s, 1, 1, 0));
        crate::util::check::assert_allclose(&got, &want, 1e-3, 1e-3);
    }

    #[test]
    fn sparse_arm_matches_dense_on_kxk_site() {
        // residual over a Tucker2 chain on a 3x3 site, both strides: the
        // per-tap CSR slabs must line up with the dense conv's windows
        let (n, c, s, r1, r2, h, k) = (1, 4, 6, 2, 3, 6, 3);
        let mut rng = Rng::new(10);
        let x: Vec<f32> = (0..n * c * h * h).map(|_| rng.normal_f32()).collect();
        let u: Vec<f32> = (0..r1 * c).map(|_| rng.normal_f32()).collect();
        let core: Vec<f32> = (0..r2 * r1 * k * k).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..s * r2).map(|_| rng.normal_f32()).collect();
        let ppm = 100_000u32; // 10% of 216 entries -> nnz 21
        let nnz = Scheme::sparse_nnz(c, s, k, ppm);
        assert_eq!(nnz, 21);
        let vals: Vec<f32> = (0..nnz).map(|_| rng.normal_f32()).collect();
        let sch = Scheme::Sparse { base: Box::new(Scheme::Tucker2 { r1, r2 }), ppm };
        // dense equivalent: v @ core @ u per tap, plus the scattered residual
        let mut w = vec![0f32; s * c * k * k];
        for si in 0..s {
            for ci in 0..c {
                for ky in 0..k {
                    for kx in 0..k {
                        let mut acc = 0f32;
                        for j in 0..r2 {
                            for i in 0..r1 {
                                acc += v[si * r2 + j]
                                    * core[((j * r1 + i) * k + ky) * k + kx]
                                    * u[i * c + ci];
                            }
                        }
                        w[((si * c + ci) * k + ky) * k + kx] = acc;
                    }
                }
            }
        }
        scatter_synthetic(&mut w, &[s, c, k, k], &vals);
        for stride in [1usize, 2] {
            let t = site(c, s, k, stride);
            let got = run_layer(
                &t,
                &sch,
                n,
                h,
                &x,
                &[u.clone(), core.clone(), v.clone(), vals.clone()],
            );
            let want = ref_conv(&x, &w, (n, c, h, h), (s, k, stride, 1));
            crate::util::check::assert_allclose(&got, &want, 1e-3, 1e-3);
        }
    }

    #[test]
    fn timer_caches_executables() {
        let eng = Engine::native();
        let mut t = EngineLayerTimer::new(eng);
        let s1 = site(8, 8, 3, 1);
        let sch = Scheme::Tucker { r1: 4, r2: 4 };
        t.measure(&s1, &sch, 1, 8).unwrap();
        assert_eq!((t.compiles, t.cache_hits), (1, 0));
        t.measure(&s1, &sch, 1, 8).unwrap();
        assert_eq!((t.compiles, t.cache_hits), (1, 1));
    }
}
