//! Execution runtime: pluggable backends behind one `Engine` facade.
//!
//! * `graph` — backend-neutral tensor IR built by `layer_factory` and
//!   `netbuilder` (the Algorithm 1 rank search and the fps tables never
//!   touch python).
//! * `native` — pure-rust CPU interpreter, the **default** backend: the
//!   whole request path (register → batch → execute → metrics) runs on
//!   stock `cargo test` with no external runtime library.
//! * `xla_backend` (feature `xla-pjrt`) — translates the same IR to
//!   XlaBuilder computations and compiles python-AOT HLO-text artifacts
//!   with PJRT; selected with `LRDX_BACKEND=xla`.
//! * `artifacts` — the python-AOT artifact library (HLO text + weights).
//!
//! The `Backend` trait covers engine identity, computation compilation,
//! buffer upload and execution; everything above it (`coordinator`,
//! `harness`, `decompose::rank_opt`, the bins and the integration tests)
//! is backend-agnostic.

pub mod artifacts;
pub mod graph;
pub mod layer_factory;
pub mod native;
pub mod netbuilder;
#[cfg(feature = "xla-pjrt")]
pub mod xla_backend;

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use graph::Graph;

/// Host-side f32 tensor handed around by the coordinator and the tests.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> HostTensor {
        let n = dims.iter().product();
        HostTensor { dims, data: vec![0.0; n] }
    }
}

/// A device-resident (or, for the native backend, host-resident) buffer.
/// Cheap to clone: payloads are behind `Arc`s / backend handles.
#[derive(Clone)]
pub enum Buffer {
    F32(Arc<HostTensor>),
    I32 { dims: Vec<usize>, data: Arc<Vec<i32>> },
    #[cfg(feature = "xla-pjrt")]
    Pjrt(Arc<xla::PjRtBuffer>),
}

impl Buffer {
    /// Bring the buffer to the host as f32. PJRT 1-tuple results are
    /// unwrapped to their first element (jax `return_tuple=True` modules).
    pub fn to_host(&self) -> Result<HostTensor> {
        let mut parts = self.to_host_all()?;
        if parts.is_empty() {
            bail!("buffer decomposed to zero tensors");
        }
        Ok(parts.remove(0))
    }

    /// Host copies of every component (PJRT tuples flatten; native buffers
    /// are always a single tensor).
    pub fn to_host_all(&self) -> Result<Vec<HostTensor>> {
        match self {
            Buffer::F32(t) => Ok(vec![t.as_ref().clone()]),
            Buffer::I32 { .. } => bail!("i32 buffer read back as f32"),
            #[cfg(feature = "xla-pjrt")]
            Buffer::Pjrt(b) => xla_backend::buffer_to_hosts(b),
        }
    }

    /// Force completion of any asynchronous execution producing this
    /// buffer (native: no-op; PJRT: device-to-host fence). Used by the
    /// profiler so timed regions include the actual compute.
    pub fn sync(&self) -> Result<()> {
        match self {
            Buffer::F32(_) | Buffer::I32 { .. } => Ok(()),
            #[cfg(feature = "xla-pjrt")]
            Buffer::Pjrt(b) => {
                b.to_literal_sync()
                    .map_err(|e| anyhow::anyhow!("sync: {e:?}"))
                    .map(|_| ())
            }
        }
    }
}

/// One execution backend: engine identity, compilation, upload, execute.
pub trait Backend {
    fn name(&self) -> &'static str;
    fn compile_graph(&self, graph: &Graph) -> Result<Arc<dyn BackendExec>>;
    fn compile_hlo_text_file(&self, path: &Path) -> Result<Arc<dyn BackendExec>>;
    fn upload(&self, data: &[f32], dims: &[usize]) -> Result<Buffer>;
    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer>;
}

/// A compiled computation, executable over backend buffers.
pub trait BackendExec {
    fn execute(&self, args: &[&Buffer]) -> Result<Vec<Buffer>>;
}

/// Process-facing engine handle (one backend instance, `Arc`-shared).
///
/// Backends are not required to be `Send` (PJRT wrapper types hold raw
/// pointers), so threaded users — the coordinator's worker pool —
/// construct one `Engine` per thread.
#[derive(Clone)]
pub struct Engine {
    backend: Arc<dyn Backend>,
}

impl Engine {
    /// The pure-rust CPU interpreter backend.
    pub fn native() -> Engine {
        Engine { backend: Arc::new(native::NativeBackend::new()) }
    }

    /// The PJRT/XLA backend (feature `xla-pjrt`).
    #[cfg(feature = "xla-pjrt")]
    pub fn xla() -> Result<Engine> {
        Ok(Engine { backend: Arc::new(xla_backend::XlaBackend::cpu()?) })
    }

    /// Default CPU engine. `LRDX_BACKEND` selects `native` (default) or
    /// `xla` (requires the `xla-pjrt` feature).
    pub fn cpu() -> Result<Engine> {
        let choice = std::env::var("LRDX_BACKEND").unwrap_or_else(|_| "native".to_string());
        match choice.as_str() {
            "native" => Ok(Engine::native()),
            "xla" => Engine::xla_or_unavailable(),
            other => bail!("unknown LRDX_BACKEND {other:?} (expected \"native\" or \"xla\")"),
        }
    }

    #[cfg(feature = "xla-pjrt")]
    fn xla_or_unavailable() -> Result<Engine> {
        Engine::xla()
    }

    #[cfg(not(feature = "xla-pjrt"))]
    fn xla_or_unavailable() -> Result<Engine> {
        bail!("LRDX_BACKEND=xla requires building with --features xla-pjrt")
    }

    pub fn platform(&self) -> String {
        self.backend.name().to_string()
    }

    /// Compile a graph-IR computation.
    pub fn compile(&self, graph: &Graph) -> Result<Executable> {
        let raw = self.backend.compile_graph(graph)?;
        Ok(Executable { raw, engine: self.clone() })
    }

    /// Compile an HLO-text file (the python AOT interchange format — see
    /// `python/compile/aot.py` for why text, not serialized proto).
    /// PJRT-only: the native backend reports a descriptive error.
    pub fn compile_hlo_text_file(&self, path: &Path) -> Result<Executable> {
        let raw = self.backend.compile_hlo_text_file(path)?;
        Ok(Executable { raw, engine: self.clone() })
    }

    /// Upload an f32 host buffer to the backend.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        self.backend.upload(data, dims)
    }

    /// Upload an i32 host buffer (train-step labels).
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        self.backend.upload_i32(data, dims)
    }
}

/// A compiled computation plus conveniences for host/buffer execution.
#[derive(Clone)]
pub struct Executable {
    raw: Arc<dyn BackendExec>,
    engine: Engine,
}

impl Executable {
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Execute with backend buffers (hot path — no host copies on PJRT).
    pub fn run_buffers(&self, args: &[&Buffer]) -> Result<Vec<Buffer>> {
        self.raw.execute(args)
    }

    /// Execute with buffers and bring every output to the host (PJRT
    /// tuple results flatten).
    pub fn run_to_host(&self, args: &[&Buffer]) -> Result<Vec<HostTensor>> {
        let outs = self.run_buffers(args)?;
        let mut hosts = Vec::with_capacity(outs.len());
        for o in &outs {
            hosts.extend(o.to_host_all()?);
        }
        Ok(hosts)
    }

    /// Execute with host tensors (convenience / tests).
    pub fn run_hosts(&self, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let bufs = args
            .iter()
            .map(|t| self.engine.upload(&t.data, &t.dims))
            .collect::<Result<Vec<_>>>()?;
        let refs: Vec<&Buffer> = bufs.iter().collect();
        self.run_to_host(&refs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::graph::GraphBuilder;

    fn engine() -> Engine {
        Engine::native()
    }

    #[test]
    fn builder_roundtrip() {
        let eng = engine();
        let b = GraphBuilder::new("t");
        let p = b.parameter(0, &[2, 2], "x").unwrap();
        let out = (p.clone() + p).unwrap();
        let exe = eng.compile(&b.build(&out).unwrap()).unwrap();
        let x = HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let res = exe.run_hosts(&[x]).unwrap();
        assert_eq!(res[0].data, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn buffer_execution() {
        let eng = engine();
        let b = GraphBuilder::new("t2");
        let p = b.parameter(0, &[4], "x").unwrap();
        let exe = eng.compile(&b.build(&p.sqrt().unwrap()).unwrap()).unwrap();
        let buf = eng.upload(&[1.0, 4.0, 9.0, 16.0], &[4]).unwrap();
        let out = exe.run_to_host(&[&buf]).unwrap();
        assert_eq!(out[0].data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn host_tensor_shape_checked() {
        let r = std::panic::catch_unwind(|| HostTensor::new(vec![2, 3], vec![0.0; 5]));
        assert!(r.is_err());
    }

    #[test]
    fn cpu_engine_defaults_to_native() {
        // Only meaningful when the selector is unset — running the suite
        // with LRDX_BACKEND=xla is a supported workflow and must not trip
        // this unrelated assertion.
        if std::env::var("LRDX_BACKEND").is_err() {
            let eng = Engine::cpu().unwrap();
            assert_eq!(eng.platform(), "native-cpu");
        }
    }

    #[test]
    fn hlo_compilation_reports_backend_requirement() {
        let eng = engine();
        let err = eng
            .compile_hlo_text_file(Path::new("nope.hlo.txt"))
            .err()
            .expect("native backend cannot compile HLO");
        let msg = format!("{err:#}");
        assert!(msg.contains("xla-pjrt"), "unhelpful error: {msg}");
    }

    #[test]
    fn i32_upload_and_misuse() {
        let eng = engine();
        let b = eng.upload_i32(&[1, 2, 3], &[3]).unwrap();
        assert!(b.to_host().is_err());
        assert!(b.sync().is_ok());
    }
}
