//! Execution runtime: pluggable backends behind one `Engine` facade.
//!
//! * `graph` — backend-neutral tensor IR built by `layer_factory` and
//!   `netbuilder` (the Algorithm 1 rank search and the fps tables never
//!   touch python).
//! * `passes` — the opt-level-gated IR optimization pipeline behind
//!   `Engine::compile`: cleanup (const fold, canonicalize, CSE, DCE) plus
//!   the low-rank re-merge fusion (the paper's merged scheme as a rewrite).
//! * `native` — pure-rust CPU interpreter, the **default** backend: the
//!   whole request path (register → batch → execute → metrics) runs on
//!   stock `cargo test` with no external runtime library.
//! * `xla_backend` (feature `xla-pjrt`) — translates the same IR to
//!   XlaBuilder computations and compiles python-AOT HLO-text artifacts
//!   with PJRT; selected with `LRDX_BACKEND=xla`.
//! * `artifacts` — the python-AOT artifact library (HLO text + weights).
//!
//! The `Backend` trait covers engine identity, computation compilation,
//! buffer upload and execution, and is crate-internal: everything above
//! the runtime (`coordinator`, `harness`, `decompose::rank_opt`, the bins
//! and the integration tests) goes through `Engine::compile(graph,
//! &CompileOptions)`, which runs the `passes` pipeline before the backend
//! sees the graph and returns a `Compiled` handle carrying `PassStats`.

// Pedantic unsafe hygiene, promoted to hard errors for the runtime
// subtree (the only place `unsafe` is allowed — CI greps for strays):
// every unsafe block documents its obligation and holds one operation.
#![deny(clippy::undocumented_unsafe_blocks, clippy::multiple_unsafe_ops_per_block)]

pub mod artifacts;
pub mod autograd;
pub mod graph;
pub mod layer_factory;
pub mod native;
pub mod netbuilder;
pub mod passes;
pub mod verify;
#[cfg(feature = "xla-pjrt")]
pub mod xla_backend;

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use graph::Graph;
pub use native::kernels::TileConfig;
pub use native::TunePolicy;
pub use passes::{
    resolve_threads, ArenaStats, CompileOptions, OptLevel, PassRecord, PassStats,
    TrainSegments,
};
pub use verify::{VerifyError, VerifyStats, Violation, ViolationKind};

/// Host-side f32 tensor handed around by the coordinator and the tests.
///
/// Deliberately NOT `PartialEq`: exact f32 equality across graphs invites
/// flaky comparisons — use [`HostTensor::approx_eq`] (or compare `.data`
/// explicitly when bitwise identity is the point).
#[derive(Clone, Debug)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> HostTensor {
        let n = dims.iter().product();
        HostTensor { dims, data: vec![0.0; n] }
    }

    /// Shape-exact, elementwise-within-`tol` comparison (absolute
    /// tolerance; NaN never compares equal).
    pub fn approx_eq(&self, other: &HostTensor, tol: f32) -> bool {
        self.dims == other.dims
            && self
                .data
                .iter()
                .zip(other.data.iter())
                .all(|(a, b)| (a - b).abs() <= tol)
    }

    /// Number of exactly-nonzero entries. Pruning masks and the sparse
    /// fitter write hard `0.0`s, so exact comparison is the convention —
    /// a near-zero weight still counts as occupied.
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&x| x != 0.0).count()
    }

    /// Fraction of nonzero entries; an empty tensor is vacuously dense.
    pub fn density(&self) -> f64 {
        if self.data.is_empty() {
            1.0
        } else {
            self.nnz() as f64 / self.data.len() as f64
        }
    }
}

/// A device-resident (or, for the native backend, host-resident) buffer.
/// Cheap to clone: payloads are behind `Arc`s / backend handles.
#[derive(Clone)]
pub enum Buffer {
    F32(Arc<HostTensor>),
    I32 { dims: Vec<usize>, data: Arc<Vec<i32>> },
    #[cfg(feature = "xla-pjrt")]
    Pjrt(Arc<xla::PjRtBuffer>),
}

impl Buffer {
    /// Bring the buffer to the host as f32. PJRT 1-tuple results are
    /// unwrapped to their first element (jax `return_tuple=True` modules).
    pub fn to_host(&self) -> Result<HostTensor> {
        let mut parts = self.to_host_all()?;
        if parts.is_empty() {
            bail!("buffer decomposed to zero tensors");
        }
        Ok(parts.remove(0))
    }

    /// Typed i32 readback (label buffers from `trainsim::data`): returns
    /// `(dims, data)`. The f32 path (`to_host`) rejects i32 buffers, and
    /// vice versa — no silent reinterpretation.
    pub fn to_host_i32(&self) -> Result<(Vec<usize>, Vec<i32>)> {
        match self {
            Buffer::I32 { dims, data } => Ok((dims.clone(), data.as_ref().clone())),
            Buffer::F32(_) => bail!("f32 buffer read back as i32"),
            #[cfg(feature = "xla-pjrt")]
            Buffer::Pjrt(b) => {
                let lit = b
                    .to_literal_sync()
                    .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
                let shape =
                    lit.array_shape().map_err(|e| anyhow::anyhow!("array_shape: {e:?}"))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit
                    .to_vec::<i32>()
                    .map_err(|e| anyhow::anyhow!("to_vec<i32>: {e:?}"))?;
                Ok((dims, data))
            }
        }
    }

    /// Host copies of every component (PJRT tuples flatten; native buffers
    /// are always a single tensor).
    pub fn to_host_all(&self) -> Result<Vec<HostTensor>> {
        match self {
            Buffer::F32(t) => Ok(vec![t.as_ref().clone()]),
            Buffer::I32 { .. } => bail!("i32 buffer read back as f32"),
            #[cfg(feature = "xla-pjrt")]
            Buffer::Pjrt(b) => xla_backend::buffer_to_hosts(b),
        }
    }

    /// Force completion of any asynchronous execution producing this
    /// buffer (native: no-op; PJRT: device-to-host fence). Used by the
    /// profiler so timed regions include the actual compute.
    pub fn sync(&self) -> Result<()> {
        match self {
            Buffer::F32(_) | Buffer::I32 { .. } => Ok(()),
            #[cfg(feature = "xla-pjrt")]
            Buffer::Pjrt(b) => {
                b.to_literal_sync()
                    .map_err(|e| anyhow::anyhow!("sync: {e:?}"))
                    .map(|_| ())
            }
        }
    }
}

/// One execution backend: engine identity, compilation, upload, execute.
///
/// Crate-internal by design: `compile_graph` receives the graph exactly
/// as the pass pipeline left it, so external callers must go through
/// `Engine::compile` (the only place optimization levels are applied).
pub(crate) trait Backend {
    fn name(&self) -> &'static str;
    /// Compile an already-optimized graph. `opts` carries the execution
    /// knobs a backend planner honours (today: `threads` for the native
    /// executor); the IR rewrites selected by `opts.opt_level` have
    /// already been applied by the caller.
    fn compile_graph(&self, graph: &Graph, opts: &CompileOptions) -> Result<Arc<dyn BackendExec>>;
    fn compile_hlo_text_file(&self, path: &Path) -> Result<Arc<dyn BackendExec>>;
    fn upload(&self, data: &[f32], dims: &[usize]) -> Result<Buffer>;
    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer>;
}

/// A compiled computation, executable over backend buffers.
pub(crate) trait BackendExec {
    fn execute(&self, args: &[&Buffer]) -> Result<Vec<Buffer>>;

    /// Buffer-arena accounting of the execution plan, if the backend
    /// plans host memory itself (PJRT manages its own device memory).
    fn arena(&self) -> Option<ArenaStats> {
        None
    }

    /// Per-step profile accumulated since compile, if the executable was
    /// compiled with `CompileOptions::profile` and the backend supports
    /// step timing (today: the native executor).
    fn profile(&self) -> Option<crate::obs::ExecProfile> {
        None
    }
}

/// Process-facing engine handle (one backend instance, `Arc`-shared).
///
/// Backends are not required to be `Send` (PJRT wrapper types hold raw
/// pointers), so threaded users — the coordinator's worker pool —
/// construct one `Engine` per thread.
#[derive(Clone)]
pub struct Engine {
    backend: Arc<dyn Backend>,
}

impl Engine {
    /// The pure-rust CPU interpreter backend.
    pub fn native() -> Engine {
        Engine { backend: Arc::new(native::NativeBackend::new()) }
    }

    /// The PJRT/XLA backend (feature `xla-pjrt`).
    #[cfg(feature = "xla-pjrt")]
    pub fn xla() -> Result<Engine> {
        Ok(Engine { backend: Arc::new(xla_backend::XlaBackend::cpu()?) })
    }

    /// Default CPU engine. `LRDX_BACKEND` selects `native` (default) or
    /// `xla` (requires the `xla-pjrt` feature).
    pub fn cpu() -> Result<Engine> {
        let choice = std::env::var("LRDX_BACKEND").unwrap_or_else(|_| "native".to_string());
        match choice.as_str() {
            "native" => Ok(Engine::native()),
            "xla" => Engine::xla_or_unavailable(),
            other => bail!("unknown LRDX_BACKEND {other:?} (expected \"native\" or \"xla\")"),
        }
    }

    #[cfg(feature = "xla-pjrt")]
    fn xla_or_unavailable() -> Result<Engine> {
        Engine::xla()
    }

    #[cfg(not(feature = "xla-pjrt"))]
    fn xla_or_unavailable() -> Result<Engine> {
        bail!("LRDX_BACKEND=xla requires building with --features xla-pjrt")
    }

    pub fn platform(&self) -> String {
        self.backend.name().to_string()
    }

    /// Compile a graph-IR computation: run the `passes` pipeline selected
    /// by `opts` over the IR, hand the rewritten graph to the backend, and
    /// return the executable together with its `PassStats`.
    pub fn compile(&self, graph: &Graph, opts: &CompileOptions) -> Result<Compiled> {
        let (optimized, mut stats) = passes::run_pipeline(graph, opts)?;
        let raw = self.backend.compile_graph(&optimized, opts)?;
        stats.arena = raw.arena();
        Ok(Compiled { raw, engine: self.clone(), stats: Arc::new(stats) })
    }

    /// `compile` for autograd-joint training graphs: `fwd_boundary` is
    /// the node count of the forward segment (everything the graph held
    /// before `runtime::autograd` appended gradients and updates). The
    /// boundary is tracked through the pass pipeline so the returned
    /// `PassStats::train` splits node counts and re-merge fusions into
    /// forward vs backward — the evidence for where a training speedup
    /// comes from.
    pub fn compile_train(
        &self,
        graph: &Graph,
        opts: &CompileOptions,
        fwd_boundary: usize,
    ) -> Result<Compiled> {
        let (optimized, mut stats) =
            passes::run_pipeline_seg(graph, opts, Some(fwd_boundary))?;
        let raw = self.backend.compile_graph(&optimized, opts)?;
        stats.arena = raw.arena();
        Ok(Compiled { raw, engine: self.clone(), stats: Arc::new(stats) })
    }

    /// Compile an HLO-text file (the python AOT interchange format — see
    /// `python/compile/aot.py` for why text, not serialized proto).
    /// PJRT-only: the native backend reports a descriptive error. The
    /// returned handle carries empty (`external`) pass stats: HLO modules
    /// bypass the IR pipeline and are optimized by XLA itself.
    pub fn compile_hlo_text_file(&self, path: &Path) -> Result<Compiled> {
        let raw = self.backend.compile_hlo_text_file(path)?;
        Ok(Compiled {
            raw,
            engine: self.clone(),
            stats: Arc::new(PassStats::external()),
        })
    }

    /// Upload an f32 host buffer to the backend.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        self.backend.upload(data, dims)
    }

    /// Upload an i32 host buffer (train-step labels).
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        self.backend.upload_i32(data, dims)
    }
}

/// A compiled computation plus conveniences for host/buffer execution and
/// the record of what the pass pipeline did to its graph.
#[derive(Clone)]
pub struct Compiled {
    raw: Arc<dyn BackendExec>,
    engine: Engine,
    stats: Arc<PassStats>,
}

impl Compiled {
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Per-pass node counts, fusions applied and wall time. Empty
    /// (`PassStats::external`) for HLO-text artifacts.
    pub fn stats(&self) -> &PassStats {
        &self.stats
    }

    /// Execute with backend buffers (hot path — no host copies on PJRT).
    pub fn run_buffers(&self, args: &[&Buffer]) -> Result<Vec<Buffer>> {
        self.raw.execute(args)
    }

    /// Execute with buffers and bring every output to the host (PJRT
    /// tuple results flatten).
    pub fn run_to_host(&self, args: &[&Buffer]) -> Result<Vec<HostTensor>> {
        let outs = self.run_buffers(args)?;
        let mut hosts = Vec::with_capacity(outs.len());
        for o in &outs {
            hosts.extend(o.to_host_all()?);
        }
        Ok(hosts)
    }

    /// The per-step/per-site execution profile accumulated across runs —
    /// `Some` only when compiled with `CompileOptions::profile` on a
    /// backend that times steps (the native executor). Snapshots; the
    /// executable keeps accumulating.
    pub fn profile(&self) -> Option<crate::obs::ExecProfile> {
        self.raw.profile()
    }

    /// Execute with host tensors (convenience / tests).
    pub fn run_hosts(&self, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let bufs = args
            .iter()
            .map(|t| self.engine.upload(&t.data, &t.dims))
            .collect::<Result<Vec<_>>>()?;
        let refs: Vec<&Buffer> = bufs.iter().collect();
        self.run_to_host(&refs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::graph::GraphBuilder;

    fn engine() -> Engine {
        Engine::native()
    }

    #[test]
    fn builder_roundtrip() {
        let eng = engine();
        let b = GraphBuilder::new("t");
        let p = b.parameter(0, &[2, 2], "x").unwrap();
        let out = (p.clone() + p).unwrap();
        let exe = eng
            .compile(&b.build(&out).unwrap(), &CompileOptions::default())
            .unwrap();
        let x = HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let res = exe.run_hosts(&[x]).unwrap();
        assert_eq!(res[0].data, vec![2.0, 4.0, 6.0, 8.0]);
        assert!(exe.stats().nodes_after <= exe.stats().nodes_before);
    }

    #[test]
    fn buffer_execution() {
        let eng = engine();
        let b = GraphBuilder::new("t2");
        let p = b.parameter(0, &[4], "x").unwrap();
        let exe = eng
            .compile(&b.build(&p.sqrt().unwrap()).unwrap(), &CompileOptions::o0())
            .unwrap();
        let buf = eng.upload(&[1.0, 4.0, 9.0, 16.0], &[4]).unwrap();
        let out = exe.run_to_host(&[&buf]).unwrap();
        assert_eq!(out[0].data, vec![1.0, 2.0, 3.0, 4.0]);
        assert!(exe.stats().passes.is_empty(), "O0 must run no passes");
    }

    #[test]
    fn host_tensor_shape_checked() {
        let r = std::panic::catch_unwind(|| HostTensor::new(vec![2, 3], vec![0.0; 5]));
        assert!(r.is_err());
    }

    #[test]
    fn host_tensor_nnz_and_density() {
        let t = HostTensor::new(vec![2, 3], vec![0.0, 1.5, 0.0, -2.0, 0.0, 1e-30]);
        // exact-zero convention: the denormal-tiny 1e-30 still occupies a slot
        assert_eq!(t.nnz(), 3);
        assert!((t.density() - 0.5).abs() < 1e-12);
        assert_eq!(HostTensor::zeros(vec![4]).nnz(), 0);
        assert_eq!(HostTensor::zeros(vec![4]).density(), 0.0);
        // empty tensor is vacuously dense, not 0/0
        assert_eq!(HostTensor::new(vec![0], vec![]).density(), 1.0);
    }

    #[test]
    fn cpu_engine_defaults_to_native() {
        // Only meaningful when the selector is unset — running the suite
        // with LRDX_BACKEND=xla is a supported workflow and must not trip
        // this unrelated assertion.
        if std::env::var("LRDX_BACKEND").is_err() {
            let eng = Engine::cpu().unwrap();
            assert_eq!(eng.platform(), "native-cpu");
        }
    }

    #[test]
    fn hlo_compilation_reports_backend_requirement() {
        let eng = engine();
        let err = eng
            .compile_hlo_text_file(Path::new("nope.hlo.txt"))
            .err()
            .expect("native backend cannot compile HLO");
        let msg = format!("{err:#}");
        assert!(msg.contains("xla-pjrt"), "unhelpful error: {msg}");
    }

    #[test]
    fn i32_upload_and_misuse() {
        let eng = engine();
        let b = eng.upload_i32(&[1, 2, 3], &[3]).unwrap();
        assert!(b.to_host().is_err());
        assert!(b.sync().is_ok());
    }

    #[test]
    fn i32_typed_readback() {
        let eng = engine();
        let labels = [3i32, 1, 4, 1, 5, 9];
        let b = eng.upload_i32(&labels, &[2, 3]).unwrap();
        let (dims, data) = b.to_host_i32().unwrap();
        assert_eq!(dims, vec![2, 3]);
        assert_eq!(data, labels);
        // and the f32 buffer rejects the typed i32 readback
        let f = eng.upload(&[1.0, 2.0], &[2]).unwrap();
        assert!(f.to_host_i32().is_err());
    }

    #[test]
    fn host_tensor_approx_eq() {
        let a = HostTensor::new(vec![2], vec![1.0, 2.0]);
        let b = HostTensor::new(vec![2], vec![1.0 + 5e-7, 2.0]);
        assert!(a.approx_eq(&b, 1e-6));
        assert!(!a.approx_eq(&b, 1e-8));
        // shape mismatch is never approximately equal
        let c = HostTensor::new(vec![1, 2], vec![1.0, 2.0]);
        assert!(!a.approx_eq(&c, 1.0));
        // NaN poisons equality
        let d = HostTensor::new(vec![2], vec![f32::NAN, 2.0]);
        assert!(!d.approx_eq(&d, 1.0));
    }
}
