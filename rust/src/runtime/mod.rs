//! PJRT runtime: the only layer that talks to XLA.
//!
//! * `Engine` wraps the PJRT CPU client (one per process, `Arc`-shared).
//! * `Executable` wraps a compiled module with shape metadata and
//!   buffer-based execution (weights stay on device across calls).
//! * `artifacts` loads the python-AOT HLO-text artifacts + weights.
//! * `layer_factory` constructs layer/network computations directly with
//!   the XlaBuilder — the Algorithm 1 rank search and the fps tables never
//!   touch python.

pub mod artifacts;
pub mod layer_factory;
pub mod netbuilder;

use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

/// Process-wide PJRT engine.
#[derive(Clone)]
pub struct Engine {
    client: Arc<xla::PjRtClient>,
}

impl Engine {
    /// Create a CPU PJRT engine. (GPU/TPU would be a one-line change here;
    /// everything above this type is backend-agnostic.)
    pub fn cpu() -> Result<Engine> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Engine { client: Arc::new(client) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Compile an HLO-text file (the python AOT interchange format — see
    /// `python/compile/aot.py` for why text, not serialized proto).
    pub fn compile_hlo_text_file(&self, path: &std::path::Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.compile_computation(&comp)
    }

    pub fn compile_computation(&self, comp: &xla::XlaComputation) -> Result<Executable> {
        let exe = self
            .client
            .compile(comp)
            .map_err(|e| anyhow!("XLA compile: {e:?}"))?;
        Ok(Executable { exe: Arc::new(exe), engine: self.clone() })
    }

    /// Upload an f32 host buffer to the device.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload {dims:?}: {e:?}"))
    }

    /// Upload an i32 host buffer to the device.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32 {dims:?}: {e:?}"))
    }
}

/// A compiled computation plus conveniences for literal/buffer execution.
#[derive(Clone)]
pub struct Executable {
    exe: Arc<xla::PjRtLoadedExecutable>,
    engine: Engine,
}

impl Executable {
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Execute with on-device buffers (hot path — no host copies).
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let mut outs = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("execute_b: {e:?}"))?;
        Ok(outs.swap_remove(0))
    }

    /// Execute with host literals (convenience / tests).
    pub fn run_literals(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let outs = self
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        decompose_tuple(lit)
    }

    /// Execute with buffers and bring the (tuple) result back to the host.
    pub fn run_to_host(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let outs = self.run_buffers(args)?;
        let lit = outs[0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        decompose_tuple(lit)
    }
}

/// jax `return_tuple=True` modules return a single tuple literal; builder
/// modules may return a plain array. Normalise both to a Vec<Literal>.
pub(crate) fn decompose_tuple(lit: xla::Literal) -> Result<Vec<xla::Literal>> {
    let shape = lit.shape().map_err(|e| anyhow!("shape: {e:?}"))?;
    match shape {
        xla::Shape::Tuple(_) => lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}")),
        _ => Ok(vec![lit]),
    }
}

/// Host-side f32 tensor handed around by the coordinator.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> HostTensor {
        let n = dims.iter().product();
        HostTensor { dims, data: vec![0.0; n] }
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().map_err(|e| anyhow!("array_shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        Ok(HostTensor::new(dims, data))
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&self.data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Engine {
        Engine::cpu().expect("cpu engine")
    }

    #[test]
    fn builder_roundtrip() {
        let eng = engine();
        let b = xla::XlaBuilder::new("t");
        let p = b.parameter(0, xla::ElementType::F32, &[2, 2], "x").unwrap();
        let out = (p.clone() + p).unwrap();
        let comp = b.build(&out).unwrap();
        let exe = eng.compile_computation(&comp).unwrap();
        let x = HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let res = exe.run_literals(&[x.to_literal().unwrap()]).unwrap();
        let t = HostTensor::from_literal(&res[0]).unwrap();
        assert_eq!(t.data, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn buffer_execution() {
        let eng = engine();
        let b = xla::XlaBuilder::new("t2");
        let p = b.parameter(0, xla::ElementType::F32, &[4], "x").unwrap();
        let comp = b.build(&p.sqrt().unwrap()).unwrap();
        let exe = eng.compile_computation(&comp).unwrap();
        let buf = eng.upload(&[1.0, 4.0, 9.0, 16.0], &[4]).unwrap();
        let out = exe.run_to_host(&[&buf]).unwrap();
        let t = HostTensor::from_literal(&out[0]).unwrap();
        assert_eq!(t.data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn host_tensor_shape_checked() {
        let r = std::panic::catch_unwind(|| HostTensor::new(vec![2, 3], vec![0.0; 5]));
        assert!(r.is_err());
    }
}
