//! Pure-rust CPU backend: a reference interpreter for the graph IR.
//!
//! Executes a `Graph` node-by-node over host `f32` tensors. Contractions
//! (`DotGeneral`) are lowered to a cache-friendly i-k-j matmul over
//! permuted operands — the same arithmetic the conv lowering in
//! `layer_factory` expresses as shifted-slice contractions, so the whole
//! decomposed/original layer zoo runs hermetically on stock `cargo test`.
//! Intermediates are freed at their last use, which keeps the resident set
//! of a deep ResNet forward pass near its widest layer instead of the sum
//! of all layers.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::graph::{Graph, OpKind};
use super::{Backend, BackendExec, Buffer, HostTensor};

/// The default engine: interprets graphs on the host CPU.
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native-cpu"
    }

    fn compile_graph(&self, graph: &Graph) -> Result<Arc<dyn BackendExec>> {
        Ok(Arc::new(NativeExecutable::new(graph.clone())?))
    }

    fn compile_hlo_text_file(&self, path: &std::path::Path) -> Result<Arc<dyn BackendExec>> {
        bail!(
            "{}: HLO-text artifacts require the PJRT backend — rebuild with \
             --features xla-pjrt and LRDX_BACKEND=xla (native models are built \
             via runtime::netbuilder instead)",
            path.display()
        )
    }

    fn upload(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        if dims.iter().product::<usize>() != data.len() {
            bail!("upload: {} elements for shape {dims:?}", data.len());
        }
        Ok(Buffer::F32(Arc::new(HostTensor::new(dims.to_vec(), data.to_vec()))))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        if dims.iter().product::<usize>() != data.len() {
            bail!("upload_i32: {} elements for shape {dims:?}", data.len());
        }
        Ok(Buffer::I32 { dims: dims.to_vec(), data: Arc::new(data.to_vec()) })
    }
}

/// A "compiled" graph: node list plus a per-node consumer count used to
/// free intermediates at their last use.
pub struct NativeExecutable {
    graph: Graph,
    use_counts: Vec<usize>,
}

impl NativeExecutable {
    pub fn new(graph: Graph) -> Result<NativeExecutable> {
        let mut use_counts = vec![0usize; graph.nodes.len()];
        for node in &graph.nodes {
            for inp in &node.inputs {
                use_counts[inp.0] += 1;
            }
        }
        use_counts[graph.root.0] += 1;
        Ok(NativeExecutable { graph, use_counts })
    }

    /// Core evaluation over `Arc`'d tensors: parameters are refcount
    /// bumps, not copies, so the per-call cost is the compute itself —
    /// important for the layer timer and the fps harness, whose timed
    /// regions run through here.
    pub fn run(&self, args: &[Arc<HostTensor>]) -> Result<Arc<HostTensor>> {
        let g = &self.graph;
        if args.len() != g.n_params {
            bail!("{}: {} args, expected {}", g.name, args.len(), g.n_params);
        }
        let mut remaining = self.use_counts.clone();
        let mut values: Vec<Option<Arc<HostTensor>>> = vec![None; g.nodes.len()];
        for (i, node) in g.nodes.iter().enumerate() {
            if remaining[i] == 0 {
                continue; // dead node (e.g. unused parameter)
            }
            let out = match &node.op {
                OpKind::Parameter { index, name } => {
                    let a = &args[*index];
                    if a.dims != node.dims {
                        bail!(
                            "{}: parameter {index} ({name}) got {:?}, expects {:?}",
                            g.name,
                            a.dims,
                            node.dims
                        );
                    }
                    Arc::clone(a)
                }
                op => {
                    let ins: Vec<&HostTensor> = node
                        .inputs
                        .iter()
                        .map(|id| {
                            values[id.0]
                                .as_deref()
                                .ok_or_else(|| anyhow!("{}: input freed early", g.name))
                        })
                        .collect::<Result<_>>()?;
                    Arc::new(eval_op(op, &ins, &node.dims)?)
                }
            };
            values[i] = Some(out);
            for inp in &node.inputs {
                remaining[inp.0] -= 1;
                if remaining[inp.0] == 0 {
                    values[inp.0] = None;
                }
            }
        }
        values[g.root.0]
            .take()
            .ok_or_else(|| anyhow!("{}: root value missing", g.name))
    }

    /// Convenience for tests: borrowed host tensors in, owned tensor out.
    pub fn execute_hosts(&self, args: &[&HostTensor]) -> Result<HostTensor> {
        let arcs: Vec<Arc<HostTensor>> =
            args.iter().map(|t| Arc::new((*t).clone())).collect();
        let out = self.run(&arcs)?;
        Ok(Arc::try_unwrap(out).unwrap_or_else(|a| (*a).clone()))
    }
}

impl BackendExec for NativeExecutable {
    fn execute(&self, args: &[&Buffer]) -> Result<Vec<Buffer>> {
        let arcs: Vec<Arc<HostTensor>> = args
            .iter()
            .map(|b| match b {
                Buffer::F32(t) => Ok(Arc::clone(t)),
                _ => Err(anyhow!("native backend takes f32 buffers")),
            })
            .collect::<Result<_>>()?;
        Ok(vec![Buffer::F32(self.run(&arcs)?)])
    }
}

// ---------------------------------------------------------------------------
// Op kernels
// ---------------------------------------------------------------------------

fn strides(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

fn numel(dims: &[usize]) -> usize {
    dims.iter().product()
}

fn eval_op(op: &OpKind, ins: &[&HostTensor], out_dims: &[usize]) -> Result<HostTensor> {
    let out = match op {
        OpKind::Parameter { .. } => unreachable!("parameters handled by the driver"),
        OpKind::ConstScalar { value } => HostTensor::new(vec![], vec![*value]),
        OpKind::Broadcast => {
            HostTensor::new(out_dims.to_vec(), vec![ins[0].data[0]; numel(out_dims)])
        }
        OpKind::BroadcastInDim { mapping } => broadcast_in_dim(ins[0], out_dims, mapping),
        OpKind::Concat { dim } => concat(ins, out_dims, *dim),
        OpKind::Slice { dim, start, stop: _, stride } => {
            slice(ins[0], out_dims, *dim, *start, *stride)
        }
        OpKind::Reshape => HostTensor::new(out_dims.to_vec(), ins[0].data.clone()),
        OpKind::Transpose { perm } => transpose(ins[0], out_dims, perm),
        OpKind::DotGeneral { lhs_contract, rhs_contract } => {
            dot_general(ins[0], ins[1], lhs_contract, rhs_contract, out_dims)?
        }
        OpKind::Add => binary(ins[0], ins[1], out_dims, |a, b| a + b)?,
        OpKind::Mul => binary(ins[0], ins[1], out_dims, |a, b| a * b)?,
        OpKind::Max => binary(ins[0], ins[1], out_dims, f32::max)?,
        OpKind::ReduceMean { dims } => reduce_mean(ins[0], out_dims, dims),
        OpKind::Sqrt => HostTensor::new(
            out_dims.to_vec(),
            ins[0].data.iter().map(|x| x.sqrt()).collect(),
        ),
    };
    Ok(out)
}

fn broadcast_in_dim(x: &HostTensor, out_dims: &[usize], mapping: &[usize]) -> HostTensor {
    let out_strides = strides(out_dims);
    let in_strides = strides(&x.dims);
    let n = numel(out_dims);
    let mut data = vec![0f32; n];
    for (flat, slot) in data.iter_mut().enumerate() {
        let mut src = 0usize;
        for (axis_in, &axis_out) in mapping.iter().enumerate() {
            let coord = (flat / out_strides[axis_out]) % out_dims[axis_out];
            src += coord * in_strides[axis_in];
        }
        *slot = x.data[src];
    }
    HostTensor::new(out_dims.to_vec(), data)
}

fn concat(ins: &[&HostTensor], out_dims: &[usize], dim: usize) -> HostTensor {
    let outer: usize = out_dims[..dim].iter().product();
    let inner: usize = out_dims[dim + 1..].iter().product();
    let total = out_dims[dim];
    let mut data = vec![0f32; numel(out_dims)];
    let mut offset = 0usize; // running position along the concat axis
    for t in ins {
        let mid = t.dims[dim];
        for o in 0..outer {
            let src = &t.data[o * mid * inner..(o + 1) * mid * inner];
            let dst_base = (o * total + offset) * inner;
            data[dst_base..dst_base + mid * inner].copy_from_slice(src);
        }
        offset += mid;
    }
    HostTensor::new(out_dims.to_vec(), data)
}

fn slice(
    x: &HostTensor,
    out_dims: &[usize],
    dim: usize,
    start: usize,
    stride: usize,
) -> HostTensor {
    let outer: usize = x.dims[..dim].iter().product();
    let mid_in = x.dims[dim];
    let inner: usize = x.dims[dim + 1..].iter().product();
    let mid_out = out_dims[dim];
    let mut data = vec![0f32; numel(out_dims)];
    for o in 0..outer {
        for m in 0..mid_out {
            let src = (o * mid_in + start + m * stride) * inner;
            let dst = (o * mid_out + m) * inner;
            data[dst..dst + inner].copy_from_slice(&x.data[src..src + inner]);
        }
    }
    HostTensor::new(out_dims.to_vec(), data)
}

fn transpose(x: &HostTensor, out_dims: &[usize], perm: &[usize]) -> HostTensor {
    let in_strides = strides(&x.dims);
    let out_strides = strides(out_dims);
    let n = numel(out_dims);
    let mut data = vec![0f32; n];
    for (flat, slot) in data.iter_mut().enumerate() {
        let mut src = 0usize;
        for (axis_out, &axis_in) in perm.iter().enumerate() {
            let coord = (flat / out_strides[axis_out]) % out_dims[axis_out];
            src += coord * in_strides[axis_in];
        }
        *slot = x.data[src];
    }
    HostTensor::new(out_dims.to_vec(), data)
}

fn binary(
    a: &HostTensor,
    b: &HostTensor,
    out_dims: &[usize],
    f: impl Fn(f32, f32) -> f32,
) -> Result<HostTensor> {
    let data = if a.dims == b.dims {
        a.data.iter().zip(b.data.iter()).map(|(&x, &y)| f(x, y)).collect()
    } else if a.dims.is_empty() {
        let s = a.data[0];
        b.data.iter().map(|&y| f(s, y)).collect()
    } else if b.dims.is_empty() {
        let s = b.data[0];
        a.data.iter().map(|&x| f(x, s)).collect()
    } else {
        // GraphBuilder rejects this at construction time, but Graph is a
        // pub type and the interpreter accepts arbitrary graphs.
        bail!("elementwise op on mismatched shapes {:?} vs {:?}", a.dims, b.dims);
    };
    Ok(HostTensor::new(out_dims.to_vec(), data))
}

fn reduce_mean(x: &HostTensor, out_dims: &[usize], reduce: &[usize]) -> HostTensor {
    let in_strides = strides(&x.dims);
    let kept: Vec<usize> =
        (0..x.dims.len()).filter(|i| !reduce.contains(i)).collect();
    let out_strides = strides(out_dims);
    let mut acc = vec![0f64; numel(out_dims)];
    let count: usize = reduce.iter().map(|&r| x.dims[r]).product();
    for (flat, &v) in x.data.iter().enumerate() {
        let mut dst = 0usize;
        for (slot, &axis) in kept.iter().enumerate() {
            let coord = (flat / in_strides[axis]) % x.dims[axis];
            dst += coord * out_strides[slot];
        }
        acc[dst] += v as f64;
    }
    let data = acc.iter().map(|&s| (s / count as f64) as f32).collect();
    HostTensor::new(out_dims.to_vec(), data)
}

/// Contraction via permute-to-matrix + i-k-j matmul.
fn dot_general(
    lhs: &HostTensor,
    rhs: &HostTensor,
    lhs_contract: &[usize],
    rhs_contract: &[usize],
    out_dims: &[usize],
) -> Result<HostTensor> {
    let lhs_free: Vec<usize> =
        (0..lhs.dims.len()).filter(|i| !lhs_contract.contains(i)).collect();
    let rhs_free: Vec<usize> =
        (0..rhs.dims.len()).filter(|i| !rhs_contract.contains(i)).collect();
    let m: usize = lhs_free.iter().map(|&i| lhs.dims[i]).product();
    let n: usize = rhs_free.iter().map(|&i| rhs.dims[i]).product();
    let k: usize = lhs_contract.iter().map(|&i| lhs.dims[i]).product();
    let k2: usize = rhs_contract.iter().map(|&i| rhs.dims[i]).product();
    if k != k2 {
        bail!("dot_general: contracted sizes differ ({k} vs {k2})");
    }

    // lhs as [M, K] (free-major), rhs as [K, N] (contract-major).
    let mut l_perm: Vec<usize> = lhs_free.clone();
    l_perm.extend_from_slice(lhs_contract);
    let mut r_perm: Vec<usize> = rhs_contract.to_vec();
    r_perm.extend_from_slice(&rhs_free);
    let a = permuted(lhs, &l_perm);
    let b = permuted(rhs, &r_perm);
    let a: &[f32] = a.as_deref().unwrap_or(&lhs.data);
    let b: &[f32] = b.as_deref().unwrap_or(&rhs.data);

    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
    Ok(HostTensor::new(out_dims.to_vec(), out))
}

/// Materialize `x` with its axes permuted; `None` when `perm` is identity
/// (caller reuses the original data).
fn permuted(x: &HostTensor, perm: &[usize]) -> Option<Vec<f32>> {
    if perm.iter().enumerate().all(|(i, &p)| i == p) {
        return None;
    }
    let out_dims: Vec<usize> = perm.iter().map(|&p| x.dims[p]).collect();
    Some(transpose(x, &out_dims, perm).data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::graph::GraphBuilder;
    use crate::util::check::assert_allclose;

    fn run1(g: &Graph, args: &[HostTensor]) -> HostTensor {
        let exe = NativeExecutable::new(g.clone()).unwrap();
        let refs: Vec<&HostTensor> = args.iter().collect();
        exe.execute_hosts(&refs).unwrap()
    }

    #[test]
    fn add_and_sqrt() {
        let b = GraphBuilder::new("t");
        let p = b.parameter(0, &[2, 2], "x").unwrap();
        let s = (p.clone() + p).unwrap().sqrt().unwrap();
        let g = b.build(&s).unwrap();
        let x = HostTensor::new(vec![2, 2], vec![2.0, 8.0, 18.0, 32.0]);
        let out = run1(&g, &[x]);
        assert_eq!(out.data, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn dot_general_matches_manual_matmul() {
        // [2,3] x [3,2] contracting the 3-dim
        let b = GraphBuilder::new("mm");
        let x = b.parameter(0, &[2, 3], "x").unwrap();
        let y = b.parameter(1, &[3, 2], "y").unwrap();
        let d = x.dot_general(&y, &[1], &[0]).unwrap();
        let g = b.build(&d).unwrap();
        let out = run1(
            &g,
            &[
                HostTensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]),
                HostTensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]),
            ],
        );
        assert_eq!(out.dims, vec![2, 2]);
        assert_eq!(out.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn dot_general_with_high_rank_rhs() {
        // [S=2, C=2] x [N=1, C=2, H=2, W=2] contracting C -> [2, 1, 2, 2]
        let b = GraphBuilder::new("conv1x1");
        let w = b.parameter(0, &[2, 2], "w").unwrap();
        let x = b.parameter(1, &[1, 2, 2, 2], "x").unwrap();
        let d = w.dot_general(&x, &[1], &[1]).unwrap();
        let g = b.build(&d).unwrap();
        let xs = HostTensor::new(vec![1, 2, 2, 2], (1..=8).map(|v| v as f32).collect());
        let ws = HostTensor::new(vec![2, 2], vec![1., 0., 1., 2.]);
        let out = run1(&g, &[ws, xs]);
        assert_eq!(out.dims, vec![2, 1, 2, 2]);
        // channel out 0 = in ch 0; channel out 1 = ch0 + 2*ch1
        assert_eq!(out.data[..4], [1., 2., 3., 4.]);
        assert_eq!(out.data[4..], [1. + 10., 2. + 12., 3. + 14., 4. + 16.]);
    }

    #[test]
    fn slice_concat_transpose_roundtrip() {
        let b = GraphBuilder::new("sct");
        let x = b.parameter(0, &[2, 4], "x").unwrap();
        let lo = x.slice_in_dim1(0, 2, 1).unwrap();
        let hi = x.slice_in_dim1(2, 4, 1).unwrap();
        let back = lo.concat_in_dim(&[hi], 1).unwrap();
        let g = b.build(&back).unwrap();
        let x0 = HostTensor::new(vec![2, 4], (0..8).map(|v| v as f32).collect());
        assert_eq!(run1(&g, &[x0.clone()]).data, x0.data);

        let b2 = GraphBuilder::new("tr");
        let y = b2.parameter(0, &[2, 3], "y").unwrap();
        let t = y.transpose(&[1, 0]).unwrap();
        let g2 = b2.build(&t).unwrap();
        let y0 = HostTensor::new(vec![2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(run1(&g2, &[y0]).data, vec![0., 3., 1., 4., 2., 5.]);
    }

    #[test]
    fn strided_slice_takes_every_other() {
        let b = GraphBuilder::new("st");
        let x = b.parameter(0, &[1, 6], "x").unwrap();
        let s = x.slice_in_dim(1, 6, 2, 1).unwrap();
        let g = b.build(&s).unwrap();
        let x0 = HostTensor::new(vec![1, 6], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(run1(&g, &[x0]).data, vec![1., 3., 5.]);
    }

    #[test]
    fn reduce_mean_over_spatial() {
        let b = GraphBuilder::new("rm");
        let x = b.parameter(0, &[1, 2, 2, 2], "x").unwrap();
        let m = x.reduce_mean(&[2, 3], false).unwrap();
        let g = b.build(&m).unwrap();
        let x0 = HostTensor::new(vec![1, 2, 2, 2], (1..=8).map(|v| v as f32).collect());
        let out = run1(&g, &[x0]);
        assert_eq!(out.dims, vec![1, 2]);
        assert_allclose(&out.data, &[2.5, 6.5], 1e-6, 1e-6);
    }

    #[test]
    fn broadcast_in_dim_per_channel() {
        let b = GraphBuilder::new("bn");
        let x = b.parameter(0, &[1, 2, 1, 2], "x").unwrap();
        let gm = b.parameter(1, &[2], "g").unwrap();
        let gb = gm.broadcast_in_dim(&[1, 2, 1, 2], &[1]).unwrap();
        let y = (x * gb).unwrap();
        let g = b.build(&y).unwrap();
        let out = run1(
            &g,
            &[
                HostTensor::new(vec![1, 2, 1, 2], vec![1., 2., 3., 4.]),
                HostTensor::new(vec![2], vec![10., 100.]),
            ],
        );
        assert_eq!(out.data, vec![10., 20., 300., 400.]);
    }

    #[test]
    fn scalar_broadcast_max_is_relu() {
        let b = GraphBuilder::new("relu");
        let x = b.parameter(0, &[4], "x").unwrap();
        let zero = b.c0(0.0).unwrap();
        let y = x.max(&zero).unwrap();
        let g = b.build(&y).unwrap();
        let out = run1(&g, &[HostTensor::new(vec![4], vec![-1., 2., -3., 4.])]);
        assert_eq!(out.data, vec![0., 2., 0., 4.]);
    }

    #[test]
    fn shape_mismatch_at_execute_is_reported() {
        let b = GraphBuilder::new("chk");
        let x = b.parameter(0, &[2, 2], "x").unwrap();
        let g = b.build(&x).unwrap();
        let exe = NativeExecutable::new(g).unwrap();
        let bad = HostTensor::new(vec![4], vec![0.0; 4]);
        assert!(exe.execute_hosts(&[&bad]).is_err());
    }
}
