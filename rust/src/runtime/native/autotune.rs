//! Compile-time tile autotuning for the packed GEMM path.
//!
//! When a shape class (a power-of-two (M, N, K) bucket, the same
//! bucketing idea the serve ladder uses) first appears during
//! `Engine::compile` with tuning enabled, [`choice`] times every
//! [`TileConfig::CANDIDATES`] entry on a capped stand-in problem and
//! caches the winner in a process-global table — later compiles of any
//! shape in the bucket reuse the measurement for free.
//!
//! The choice is **performance-only state**: every tile config produces
//! bitwise-identical output (see `kernels::dot_packed`), so the cache
//! is keyed and stored exactly like the serve bucket ladder's compiled
//! artifacts — outside anything that feeds bitwise-identity checks, and
//! deliberately excluded from `CompileOptions::cache_key`.
//!
//! The measured GFLOP/s double as calibration data: [`points`] exposes
//! `(gate_dim, rate)` pairs that `model::cost::fit_effective_lane`
//! turns into this machine's effective lane width, replacing the
//! paper-cited lane assumptions in `model::cost` with measurements.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use super::kernels::{dot_packed, packed_a_len, packed_b_len, TileConfig};
use super::pool::WorkerPool;

/// How a compiled executable picks tile configs for packed `Dot` steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TunePolicy {
    /// Use [`TileConfig::DEFAULT`] everywhere (library default: no
    /// timing work at compile, fully deterministic compile times).
    Off,
    /// Time the candidate set per shape bucket at compile and use each
    /// bucket's winner (the CLI default).
    Auto,
    /// Force one config for every packed step (`--tile MRxNRxKBxNB`).
    Fixed(TileConfig),
}

/// One autotuned bucket: the winning config and its measured rate.
#[derive(Clone, Copy, Debug)]
pub struct Choice {
    pub cfg: TileConfig,
    /// Winner's serial throughput on the stand-in problem, GFLOP/s.
    pub gflops: f64,
}

/// A calibration sample for `cost::fit_effective_lane`: the bucket's
/// gate dimension (N — the dimension the register tile vectorizes
/// over) and the measured rate at that dimension.
#[derive(Clone, Copy, Debug)]
pub struct TunePoint {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub cfg: TileConfig,
    pub gflops: f64,
}

type Bucket = (u32, u32, u32);

fn cache() -> &'static Mutex<HashMap<Bucket, Choice>> {
    static CACHE: OnceLock<Mutex<HashMap<Bucket, Choice>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Power-of-two shape bucket, clamped so degenerate dims stay valid.
fn bucket_dim(d: usize) -> u32 {
    d.clamp(1, 1 << 20).next_power_of_two() as u32
}

/// Timing dims are capped: a 4096³ bucket measures the same microkernel
/// behaviour at 256³ in a fraction of the time (both stream KB×NB
/// blocks through the same register tile), and compile latency stays
/// bounded no matter what shape first hits a bucket.
const TIME_DIM_CAP: usize = 256;

/// The autotuned choice for an (m, n, k) contraction — cached per
/// bucket, timed on first appearance.
pub fn choice(m: usize, n: usize, k: usize) -> Choice {
    let key = (bucket_dim(m), bucket_dim(n), bucket_dim(k));
    if let Ok(g) = cache().lock() {
        if let Some(c) = g.get(&key) {
            return *c;
        }
    }
    let c = time_bucket(key);
    if let Ok(mut g) = cache().lock() {
        g.insert(key, c);
    }
    c
}

/// Convenience: just the winning config.
pub fn choose(m: usize, n: usize, k: usize) -> TileConfig {
    choice(m, n, k).cfg
}

/// Snapshot of every bucket measured so far, as lane-fit calibration
/// points (pass `[(p.n, p.gflops), ..]` to `cost::fit_effective_lane`).
pub fn points() -> Vec<TunePoint> {
    let Ok(g) = cache().lock() else {
        return Vec::new();
    };
    let mut pts: Vec<TunePoint> = g
        .iter()
        .map(|(&(bm, bn, bk), c)| TunePoint {
            m: bm as usize,
            n: bn as usize,
            k: bk as usize,
            cfg: c.cfg,
            gflops: c.gflops,
        })
        .collect();
    pts.sort_by_key(|p| (p.m, p.n, p.k));
    pts
}

/// Time every candidate on the bucket's (capped) stand-in problem and
/// return the winner. Serial on purpose: the lane constants the fit
/// feeds model single-lane issue width, and serial timing is immune to
/// pool scheduling noise.
fn time_bucket(key: Bucket) -> Choice {
    let m = (key.0 as usize).min(TIME_DIM_CAP);
    let n = (key.1 as usize).min(TIME_DIM_CAP);
    let k = (key.2 as usize).min(TIME_DIM_CAP);
    // Deterministic non-trivial fill; values are irrelevant to timing
    // but NaN/Inf-free so no candidate hits slow denormal paths.
    let a: Vec<f32> = (0..m * k).map(|i| (i % 13) as f32 * 0.25 - 1.5).collect();
    let b: Vec<f32> = (0..k * n).map(|i| (i % 7) as f32 * 0.5 - 1.0).collect();
    let mut out = vec![0f32; m * n];
    let mut apk = vec![0f32; packed_a_len(m, k)];
    let mut bpk = vec![0f32; packed_b_len(n, k)];
    let serial = WorkerPool::serial();
    let flops = 2.0 * (m * n * k) as f64;
    let mut best = Choice { cfg: TileConfig::DEFAULT, gflops: 0.0 };
    for &cand in &TileConfig::CANDIDATES {
        // One warm-up (pays the page faults / icache misses), then the
        // better of two timed runs.
        dot_packed(&a, &b, n, k, &mut out, &serial, cand, &mut apk, &mut bpk);
        let mut secs = f64::INFINITY;
        for _ in 0..2 {
            let t0 = Instant::now();
            dot_packed(&a, &b, n, k, &mut out, &serial, cand, &mut apk, &mut bpk);
            secs = secs.min(t0.elapsed().as_secs_f64());
        }
        let rate = if secs > 0.0 { flops / secs / 1e9 } else { 0.0 };
        if rate > best.gflops {
            best = Choice { cfg: cand, gflops: rate };
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_dims_are_powers_of_two() {
        assert_eq!(bucket_dim(0), 1);
        assert_eq!(bucket_dim(1), 1);
        assert_eq!(bucket_dim(3), 4);
        assert_eq!(bucket_dim(256), 256);
        assert_eq!(bucket_dim(257), 512);
    }

    // Times real GEMMs — meaningless (and very slow) under miri's
    // interpreter, so the miri job runs only the bucket-math test.
    #[cfg(not(miri))]
    #[test]
    fn choice_is_cached_per_bucket() {
        // Tiny bucket so the timing pass is milliseconds even under the
        // test profile. Both calls land in the same (64, 64, 64) bucket
        // and the second must be a pure cache hit (same winner).
        let first = choice(40, 33, 50);
        let again = choice(64, 64, 64);
        assert_eq!(first.cfg, again.cfg);
        assert!(first.gflops > 0.0, "timing produced no rate");
        assert!(points().iter().any(|p| (p.m, p.n, p.k) == (64, 64, 64)));
    }
}
