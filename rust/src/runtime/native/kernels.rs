//! Parallel CPU kernels for the native executor.
//!
//! Every kernel writes into a caller-provided `out` slice. Parallel
//! kernels partition the *output* into contiguous chunks and dispatch
//! them over the executable's persistent [`WorkerPool`], so each output
//! element is produced by exactly one lane with a fixed,
//! partition-independent accumulation order — results are bitwise
//! identical for every thread count (the contract `tests/native_exec.rs`
//! pins). The chunking is computed from the pool's *thread count* alone,
//! never from scheduling, so which worker executes which chunk cannot
//! change a bit either. Work below the `PAR_MIN_*` thresholds runs
//! inline: dispatch costs more than it saves there, and skipping it
//! cannot change a single bit.
//!
//! `dot_general` is the hot kernel: an i-k-j matmul blocked over N and K
//! so the active B panel stays cache-resident across the rows of a
//! thread's chunk, with rows (M) partitioned across lanes. There is
//! deliberately NO zero-operand fast path: `0 × NaN` and `0 × Inf` must
//! produce NaN per IEEE 754 — the seed's `av == 0.0` skip silently
//! swallowed poisoned activations inside decomposed W0·W1 chains.

use super::pool::{SendPtr, WorkerPool};

/// Row-major strides for `dims`.
pub fn strides(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

pub fn numel(dims: &[usize]) -> usize {
    dims.iter().product()
}

/// Minimum output elements before an elementwise/gather kernel fans out.
/// Public so `runtime::verify::plan` can replay the fan-out decision and
/// prove the resulting partition is a disjoint exact cover.
pub const PAR_MIN_ELEMS: usize = 16 * 1024;
/// Minimum M*N*K before `dot_general`/`spmm_csr` fans out.
pub const PAR_MIN_MACS: usize = 64 * 1024;
/// Minimum output elements before `reduce` fans out (cheaper threshold:
/// each output element already amortizes `count` reads).
pub const PAR_MIN_REDUCE: usize = 1024;
/// N-dimension block: the B panel column strip kept hot in cache.
const NB: usize = 256;
/// K-dimension block: B panel rows per strip (NB*KB*4 B ≈ 128 KiB ≤ L2).
const KB: usize = 128;

/// Run `f(global_offset, chunk)` over `out` split into at most
/// `pool.threads()` contiguous chunks, dispatched over the pool. `f`
/// must derive each element purely from its global index so the
/// partition cannot affect values.
pub fn par_map<F>(out: &mut [f32], pool: &WorkerPool, min_elems: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let n = out.len();
    let threads = pool.threads();
    if threads <= 1 || n < min_elems.max(2) {
        f(0, out);
        return;
    }
    let per = n.div_ceil(threads.min(n));
    let chunks = n.div_ceil(per);
    let base = SendPtr(out.as_mut_ptr());
    pool.run(chunks, &|ci| {
        let start = ci * per;
        let len = per.min(n - start);
        debug_assert!(start + len <= n, "chunk {ci} overruns out");
        // SAFETY: `start = ci*per < n` (pool only issues `ci < chunks`
        // and `(chunks-1)*per < n`), so the offset stays inside the
        // allocation `base` points to.
        let ptr = unsafe { base.0.add(start) };
        // SAFETY: `[start, start+len)` ranges for distinct `ci` are
        // disjoint and in-bounds (`verify::plan::par_partition` mirrors
        // this arithmetic and `check_cover` proves it is an exact
        // disjoint cover for every lane count), and `out` stays
        // exclusively borrowed by the issuing `run` until every chunk
        // completes — so each `&mut` sub-slice is unique and live.
        let chunk = unsafe { std::slice::from_raw_parts_mut(ptr, len) };
        f(start, chunk);
    });
}

// ---------------------------------------------------------------------------
// Elementwise
// ---------------------------------------------------------------------------

pub fn fill(out: &mut [f32], value: f32) {
    out.fill(value);
}

/// `out[i] = f(a[i], b[i])` (shapes already equal).
pub fn binary<F>(a: &[f32], b: &[f32], out: &mut [f32], pool: &WorkerPool, f: F)
where
    F: Fn(f32, f32) -> f32 + Sync,
{
    par_map(out, pool, PAR_MIN_ELEMS, |off, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = f(a[off + i], b[off + i]);
        }
    });
}

/// `out[i] = f(out[i], b[i])` — in-place over a dying lhs slot.
pub fn binary_inplace<F>(out: &mut [f32], b: &[f32], pool: &WorkerPool, f: F)
where
    F: Fn(f32, f32) -> f32 + Sync,
{
    par_map(out, pool, PAR_MIN_ELEMS, |off, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = f(*o, b[off + i]);
        }
    });
}

/// `out[i] = f(out[i], out[i])` — both operands were the same dying slot.
pub fn binary_inplace_self<F>(out: &mut [f32], pool: &WorkerPool, f: F)
where
    F: Fn(f32, f32) -> f32 + Sync,
{
    par_map(out, pool, PAR_MIN_ELEMS, |_, chunk| {
        for o in chunk.iter_mut() {
            *o = f(*o, *o);
        }
    });
}

/// `out[i] = f(a[i], s)` (scalar rhs; pass `swap` to flip operand order).
pub fn binary_scalar<F>(
    a: &[f32],
    s: f32,
    swap: bool,
    out: &mut [f32],
    pool: &WorkerPool,
    f: F,
) where
    F: Fn(f32, f32) -> f32 + Sync,
{
    par_map(out, pool, PAR_MIN_ELEMS, |off, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            let v = a[off + i];
            *o = if swap { f(s, v) } else { f(v, s) };
        }
    });
}

/// `out[i] = f(out[i], s)` in place (`swap` flips operand order).
pub fn binary_scalar_inplace<F>(out: &mut [f32], s: f32, swap: bool, pool: &WorkerPool, f: F)
where
    F: Fn(f32, f32) -> f32 + Sync,
{
    par_map(out, pool, PAR_MIN_ELEMS, |_, chunk| {
        for o in chunk.iter_mut() {
            *o = if swap { f(s, *o) } else { f(*o, s) };
        }
    });
}

pub fn unary<F>(a: &[f32], out: &mut [f32], pool: &WorkerPool, f: F)
where
    F: Fn(f32) -> f32 + Sync,
{
    par_map(out, pool, PAR_MIN_ELEMS, |off, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = f(a[off + i]);
        }
    });
}

pub fn unary_inplace<F>(out: &mut [f32], pool: &WorkerPool, f: F)
where
    F: Fn(f32) -> f32 + Sync,
{
    par_map(out, pool, PAR_MIN_ELEMS, |_, chunk| {
        for o in chunk.iter_mut() {
            *o = f(*o);
        }
    });
}

/// `out[i] = if p[i] != 0 { t[i] } else { f[i] }` — the `Select` op.
pub fn select(p: &[f32], t: &[f32], f: &[f32], out: &mut [f32], pool: &WorkerPool) {
    par_map(out, pool, PAR_MIN_ELEMS, |off, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            let j = off + i;
            *o = if p[j] != 0.0 { t[j] } else { f[j] };
        }
    });
}

// ---------------------------------------------------------------------------
// Gather (transpose / broadcast_in_dim share one addressing form)
// ---------------------------------------------------------------------------

/// One output axis of a gather: walk `out_extent` positions of stride
/// `out_stride` in the flat output, advancing the source offset by
/// `src_stride` per position (0 for broadcast axes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GatherAxis {
    pub out_stride: usize,
    pub out_extent: usize,
    pub src_stride: usize,
}

/// `out[flat] = x[Σ_axis ((flat / out_stride) % out_extent) * src_stride]`.
pub fn gather(x: &[f32], axes: &[GatherAxis], out: &mut [f32], pool: &WorkerPool) {
    par_map(out, pool, PAR_MIN_ELEMS, |off, chunk| {
        for (i, slot) in chunk.iter_mut().enumerate() {
            let flat = off + i;
            let mut src = 0usize;
            for ax in axes {
                src += (flat / ax.out_stride) % ax.out_extent * ax.src_stride;
            }
            *slot = x[src];
        }
    });
}

// ---------------------------------------------------------------------------
// Data movement
// ---------------------------------------------------------------------------

pub fn copy(x: &[f32], out: &mut [f32]) {
    out.copy_from_slice(x);
}

/// Copy one concat operand (`mid` wide along the concat axis) into its
/// band of the output (`total` wide), starting at `offset`.
pub fn concat_part(
    x: &[f32],
    outer: usize,
    mid: usize,
    inner: usize,
    total: usize,
    offset: usize,
    out: &mut [f32],
) {
    for o in 0..outer {
        let src = &x[o * mid * inner..(o + 1) * mid * inner];
        let dst = (o * total + offset) * inner;
        out[dst..dst + mid * inner].copy_from_slice(src);
    }
}

#[allow(clippy::too_many_arguments)]
pub fn slice(
    x: &[f32],
    outer: usize,
    mid_in: usize,
    inner: usize,
    start: usize,
    stride: usize,
    mid_out: usize,
    out: &mut [f32],
) {
    for o in 0..outer {
        for m in 0..mid_out {
            let src = (o * mid_in + start + m * stride) * inner;
            let dst = (o * mid_out + m) * inner;
            out[dst..dst + inner].copy_from_slice(&x[src..src + inner]);
        }
    }
}

// ---------------------------------------------------------------------------
// Contraction
// ---------------------------------------------------------------------------

/// `out[m,n] = Σ_k a[m,k] · b[k,n]`, cache-tiled, rows partitioned
/// across the pool's lanes. Per output element the k-sum always runs in
/// ascending k order, so tiling and threading never change a bit.
pub fn dot_general(
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    out: &mut [f32],
    pool: &WorkerPool,
) {
    if out.is_empty() {
        return;
    }
    if k == 0 {
        out.fill(0.0); // empty contraction: a sum over nothing
        return;
    }
    let m = out.len() / n;
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let t = if m * n * k >= PAR_MIN_MACS { pool.threads().min(m) } else { 1 };
    if t <= 1 {
        dot_rows(a, b, n, k, out);
        return;
    }
    let rows_per = m.div_ceil(t);
    let chunks = m.div_ceil(rows_per);
    let base = SendPtr(out.as_mut_ptr());
    pool.run(chunks, &|ci| {
        let r0 = ci * rows_per;
        let rows = rows_per.min(m - r0);
        debug_assert!((r0 + rows) * n <= m * n, "row chunk {ci} overruns out");
        // SAFETY: `r0 = ci*rows_per < m`, so `r0*n` is inside the `m*n`
        // allocation behind `base`.
        let ptr = unsafe { base.0.add(r0 * n) };
        // SAFETY: row ranges `[r0, r0+rows)` for distinct `ci` are
        // disjoint and exactly cover `0..m` (`verify::plan::row_partition`
        // mirrors this arithmetic and `check_cover` proves it for every
        // lane count), and `out` stays exclusively borrowed by the
        // issuing `run` until every chunk completes.
        let ochunk = unsafe { std::slice::from_raw_parts_mut(ptr, rows * n) };
        dot_rows(&a[r0 * k..(r0 + rows) * k], b, n, k, ochunk);
    });
}

/// Serial tiled core over a row block: i-k-j with N×K blocking.
fn dot_rows(a: &[f32], b: &[f32], n: usize, k: usize, out: &mut [f32]) {
    out.fill(0.0);
    if n == 0 || k == 0 || out.is_empty() {
        return;
    }
    let rows = out.len() / n;
    for jb in (0..n).step_by(NB) {
        let je = (jb + NB).min(n);
        for kb in (0..k).step_by(KB) {
            let ke = (kb + KB).min(k);
            for i in 0..rows {
                let arow = &a[i * k + kb..i * k + ke];
                let orow = &mut out[i * n + jb..i * n + je];
                for (p, &av) in arow.iter().enumerate() {
                    let brow = &b[(kb + p) * n + jb..(kb + p) * n + je];
                    for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                        *o += av * bv;
                    }
                }
            }
        }
    }
}

/// CSR sparse×dense: `out[r, j] = Σ_{e ∈ row r} vals[perm(e)] · x[col(e), j]`
/// with `x` pre-permuted so the contracted axis leads (`[n_cols, m]`
/// row-major, like `dot_general`'s B operand). Rows are partitioned
/// across lanes; within a row the entries accumulate in ascending CSR
/// order, so — exactly like `dot_general` — neither threading nor
/// chunking can change a bit. No zero-value skip, for the same IEEE
/// reason as the dense kernel (stored zeros must still poison on NaN).
#[allow(clippy::too_many_arguments)]
pub fn spmm_csr(
    vals: &[f32],
    x: &[f32],
    row_ptr: &[u32],
    col_idx: &[u32],
    val_perm: Option<&[u32]>,
    m: usize,
    out: &mut [f32],
    pool: &WorkerPool,
) {
    if out.is_empty() {
        return;
    }
    let n_rows = row_ptr.len() - 1;
    debug_assert_eq!(out.len(), n_rows * m);
    let macs = col_idx.len() * m;
    let t = if macs >= PAR_MIN_MACS { pool.threads().min(n_rows) } else { 1 };
    if t <= 1 {
        spmm_rows(vals, x, row_ptr, col_idx, val_perm, m, 0, n_rows, out);
        return;
    }
    let rows_per = n_rows.div_ceil(t);
    let chunks = n_rows.div_ceil(rows_per);
    let base = SendPtr(out.as_mut_ptr());
    pool.run(chunks, &|ci| {
        let r0 = ci * rows_per;
        let rows = rows_per.min(n_rows - r0);
        debug_assert!((r0 + rows) * m <= n_rows * m, "row chunk {ci} overruns out");
        // SAFETY: `r0 = ci*rows_per < n_rows`, so `r0*m` is inside the
        // `n_rows*m` allocation behind `base`.
        let ptr = unsafe { base.0.add(r0 * m) };
        // SAFETY: row ranges `[r0, r0+rows)` for distinct `ci` are
        // disjoint and exactly cover `0..n_rows` (mirrored and proven by
        // `verify::plan::{row_partition, check_cover}` for every lane
        // count), and `out` stays exclusively borrowed by the issuing
        // `run` until every chunk completes.
        let ochunk = unsafe { std::slice::from_raw_parts_mut(ptr, rows * m) };
        spmm_rows(vals, x, row_ptr, col_idx, val_perm, m, r0, rows, ochunk);
    });
}

/// Serial core over a row block: per row, ascending-entry axpy into the
/// output row (the fixed accumulation order the determinism pin needs).
#[allow(clippy::too_many_arguments)]
fn spmm_rows(
    vals: &[f32],
    x: &[f32],
    row_ptr: &[u32],
    col_idx: &[u32],
    val_perm: Option<&[u32]>,
    m: usize,
    r0: usize,
    rows: usize,
    out: &mut [f32],
) {
    out.fill(0.0);
    for i in 0..rows {
        let r = r0 + i;
        let orow = &mut out[i * m..(i + 1) * m];
        for e in row_ptr[r] as usize..row_ptr[r + 1] as usize {
            let v = match val_perm {
                Some(p) => vals[p[e] as usize],
                None => vals[e],
            };
            let c = col_idx[e] as usize;
            let xrow = &x[c * m..(c + 1) * m];
            for (o, &xv) in orow.iter_mut().zip(xrow.iter()) {
                *o += v * xv;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Reduction
// ---------------------------------------------------------------------------

/// Precomputed geometry of a reduction: kept axes address the base
/// offset per output element; `red` is the (extent, stride) odometer of
/// the reduced subspace; `contiguous` marks reductions over trailing
/// axes, where the subspace is one dense run of `count` elements.
#[derive(Clone, Debug)]
pub struct ReduceGeom {
    pub kept: Vec<GatherAxis>,
    pub red: Vec<(usize, usize)>,
    pub count: usize,
    pub contiguous: bool,
}

/// Sum (and for `mean` the average) over the reduced subspace, one
/// output element per chunk slot, accumulated in f64 in a fixed order.
/// `geom.count` must be non-zero (the planner and `GraphBuilder` reject
/// empty reduces).
pub fn reduce(x: &[f32], geom: &ReduceGeom, mean: bool, out: &mut [f32], pool: &WorkerPool) {
    debug_assert!(geom.count > 0, "reduce over an empty subspace");
    let inv = geom.count as f64;
    par_map(out, pool, PAR_MIN_REDUCE, |off, chunk| {
        for (i, slot) in chunk.iter_mut().enumerate() {
            let flat = off + i;
            let mut base = 0usize;
            for ax in &geom.kept {
                base += (flat / ax.out_stride) % ax.out_extent * ax.src_stride;
            }
            let mut acc = 0f64;
            if geom.contiguous {
                for &v in &x[base..base + geom.count] {
                    acc += v as f64;
                }
            } else {
                for r in 0..geom.count {
                    let mut rem = r;
                    let mut src = base;
                    for &(extent, stride) in geom.red.iter().rev() {
                        src += rem % extent * stride;
                        rem /= extent;
                    }
                    acc += x[src] as f64;
                }
            }
            *slot = if mean { (acc / inv) as f32 } else { acc as f32 };
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(threads: usize) -> WorkerPool {
        WorkerPool::new(threads)
    }

    #[test]
    fn dot_has_no_zero_skip() {
        // 0-weight row meeting NaN/Inf activations must poison the output
        let a = [0.0f32, 0.0];
        let b = [f32::NAN, 1.0, f32::INFINITY, 2.0]; // [2, 2]
        let mut out = [0f32; 2];
        dot_general(&a, &b, 2, 2, &mut out, &pool(1));
        assert!(out[0].is_nan(), "0*NaN + 0*Inf must be NaN, got {}", out[0]);
        assert_eq!(out[1], 0.0, "finite column stays exact");
    }

    #[test]
    fn dot_matches_naive_bitwise_across_threads_and_tiles() {
        let (m, n, k) = (7, 300, 190); // forces partial N/K tiles
        let a: Vec<f32> = (0..m * k).map(|i| ((i * 37 % 97) as f32 - 48.0) * 0.37).collect();
        let b: Vec<f32> = (0..k * n).map(|i| ((i * 61 % 89) as f32 - 44.0) * 0.13).collect();
        let mut naive = vec![0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                for j in 0..n {
                    naive[i * n + j] += av * b[p * n + j];
                }
            }
        }
        for threads in [1, 2, 5] {
            let mut out = vec![0f32; m * n];
            dot_general(&a, &b, n, k, &mut out, &pool(threads));
            assert_eq!(out, naive, "threads={threads}");
        }
    }

    #[test]
    fn par_map_is_partition_invariant() {
        let mut a = vec![0f32; 40_000];
        let mut b = vec![0f32; 40_000];
        par_map(&mut a, &pool(1), 1, |off, c| {
            for (i, o) in c.iter_mut().enumerate() {
                *o = ((off + i) as f32).sin();
            }
        });
        par_map(&mut b, &pool(7), 1, |off, c| {
            for (i, o) in c.iter_mut().enumerate() {
                *o = ((off + i) as f32).sin();
            }
        });
        assert_eq!(a, b);
    }

    #[test]
    fn select_picks_by_mask() {
        let p = [1.0f32, 0.0, 2.0, 0.0];
        let t = [10f32, 20.0, 30.0, 40.0];
        let f = [-1f32, -2.0, -3.0, -4.0];
        let mut out = [0f32; 4];
        select(&p, &t, &f, &mut out, &pool(2));
        assert_eq!(out, [10.0, -2.0, 30.0, -4.0]);
    }

    #[test]
    fn spmm_matches_ordered_naive_bitwise_across_threads() {
        // 37x29 sparse against a [29, 401] dense block — big enough to
        // cross PAR_MIN_MACS once m is large, with ragged rows.
        let (n_rows, n_cols, m) = (37usize, 29usize, 401usize);
        let mut row_ptr = vec![0u32];
        let mut col_idx = Vec::new();
        for r in 0..n_rows {
            for c in 0..n_cols {
                if (r * 7 + c * 13) % 5 == 0 {
                    col_idx.push(c as u32);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        let vals: Vec<f32> =
            (0..col_idx.len()).map(|i| ((i * 31 % 17) as f32 - 8.0) * 0.21).collect();
        let x: Vec<f32> =
            (0..n_cols * m).map(|i| ((i * 43 % 23) as f32 - 11.0) * 0.09).collect();
        // naive with the same per-row ascending accumulation order
        let mut naive = vec![0f32; n_rows * m];
        for r in 0..n_rows {
            for e in row_ptr[r] as usize..row_ptr[r + 1] as usize {
                let (v, c) = (vals[e], col_idx[e] as usize);
                for j in 0..m {
                    naive[r * m + j] += v * x[c * m + j];
                }
            }
        }
        for threads in [1, 2, 8] {
            let mut out = vec![0f32; n_rows * m];
            spmm_csr(&vals, &x, &row_ptr, &col_idx, None, m, &mut out, &pool(threads));
            assert_eq!(out, naive, "threads={threads}");
        }
        // a permuted value stream reads through the perm
        let perm: Vec<u32> = (0..vals.len() as u32).rev().collect();
        let rvals: Vec<f32> = vals.iter().rev().copied().collect();
        let mut out = vec![0f32; n_rows * m];
        spmm_csr(&rvals, &x, &row_ptr, &col_idx, Some(&perm), m, &mut out, &pool(3));
        assert_eq!(out, naive);
    }

    #[test]
    fn spmm_has_no_zero_skip() {
        // stored zero meeting NaN must poison, same as the dense kernel
        let row_ptr = [0u32, 1];
        let col_idx = [0u32];
        let vals = [0.0f32];
        let x = [f32::NAN, 1.0];
        let mut out = [0f32; 2];
        spmm_csr(&vals, &x, &row_ptr, &col_idx, None, 2, &mut out, &pool(1));
        assert!(out[0].is_nan(), "0*NaN must be NaN, got {}", out[0]);
        assert_eq!(out[1], 0.0);
    }

    #[test]
    fn reduce_sum_and_mean_agree_up_to_count() {
        // [2, 3] reduced over axis 1
        let x = [1f32, 2.0, 3.0, 10.0, 20.0, 30.0];
        let geom = ReduceGeom {
            kept: vec![GatherAxis { out_stride: 1, out_extent: 2, src_stride: 3 }],
            red: vec![(3, 1)],
            count: 3,
            contiguous: true,
        };
        let mut sum = [0f32; 2];
        let mut mean = [0f32; 2];
        reduce(&x, &geom, false, &mut sum, &pool(1));
        reduce(&x, &geom, true, &mut mean, &pool(1));
        assert_eq!(sum, [6.0, 60.0]);
        assert_eq!(mean, [2.0, 20.0]);
    }
}
