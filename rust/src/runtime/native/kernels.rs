//! Parallel CPU kernels for the native executor.
//!
//! Every kernel writes into a caller-provided `out` slice. Parallel
//! kernels partition the *output* into contiguous chunks and dispatch
//! them over the executable's persistent [`WorkerPool`], so each output
//! element is produced by exactly one lane with a fixed,
//! partition-independent accumulation order — results are bitwise
//! identical for every thread count (the contract `tests/native_exec.rs`
//! pins). The chunking is computed from the pool's *thread count* alone,
//! never from scheduling, so which worker executes which chunk cannot
//! change a bit either. Work below the `PAR_MIN_*` thresholds runs
//! inline: dispatch costs more than it saves there, and skipping it
//! cannot change a single bit.
//!
//! `dot_general` is the hot kernel. Above [`PACK_MIN_MACS`] it runs a
//! BLIS-style packed path: A is repacked into MR-row panels, B into
//! NR-column panels, and an MR×NR register-tile microkernel walks the
//! packed panels in ascending-k order. Each accumulator lane owns
//! exactly one output element for the whole k extent, so the per-element
//! operation sequence (mul, then add, k ascending) is identical to the
//! naive triple loop — tile shape, KB/NB blocking, packing, and thread
//! count are all bitwise-irrelevant. Below the threshold (and as the
//! bench baseline) the original i-k-j `dot_scalar` core runs instead;
//! both paths produce identical bits. There is deliberately NO
//! zero-operand fast path anywhere: `0 × NaN` and `0 × Inf` must
//! produce NaN per IEEE 754 — the seed's `av == 0.0` skip silently
//! swallowed poisoned activations inside decomposed W0·W1 chains.

use super::pool::{SendPtr, WorkerPool};

/// Row-major strides for `dims`.
pub fn strides(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

pub fn numel(dims: &[usize]) -> usize {
    dims.iter().product()
}

/// Minimum output elements before an elementwise/gather kernel fans out.
/// Public so `runtime::verify::plan` can replay the fan-out decision and
/// prove the resulting partition is a disjoint exact cover.
pub const PAR_MIN_ELEMS: usize = 16 * 1024;
/// Minimum M*N*K before `dot_general`/`spmm_csr` fans out. Re-derived
/// for the packed microkernel: pool dispatch costs on the order of
/// 10 µs, and the packed serial core clears several f32 GMAC/s (the
/// `benches/native_exec.rs` GEMM sweep records the live number per
/// machine in `BENCH_native.json`), so a fan-out only amortizes from
/// roughly 10 µs × GMAC/s ≈ 2¹⁸ MACs upward — 4× the seed's 64·1024,
/// which was calibrated against the slower scalar core. The small-shape
/// rows of the sweep's CI gate keep this from regressing small dots.
pub const PAR_MIN_MACS: usize = 256 * 1024;
/// Minimum output elements before `reduce` fans out (cheaper threshold:
/// each output element already amortizes `count` reads).
pub const PAR_MIN_REDUCE: usize = 1024;
/// Minimum M*N*K before `dot_general` pays for packing A and B into
/// panels. Packing moves (M·K + K·N) floats to win register-tiled
/// accumulation over M·N·K MACs; below ~32K MACs (e.g. 32³) the copy
/// traffic is a double-digit fraction of the MAC count and the scalar
/// core is at least as fast — the GEMM sweep's small-shape rows track
/// the live crossover.
pub const PACK_MIN_MACS: usize = 32 * 1024;
/// N-dimension block: the B panel column strip kept hot in cache.
const NB: usize = 256;
/// K-dimension block: B panel rows per strip (NB*KB*4 B ≈ 128 KiB ≤ L2).
const KB: usize = 128;

/// Run `f(global_offset, chunk)` over `out` split into at most
/// `pool.threads()` contiguous chunks, dispatched over the pool. `f`
/// must derive each element purely from its global index so the
/// partition cannot affect values.
pub fn par_map<F>(out: &mut [f32], pool: &WorkerPool, min_elems: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let n = out.len();
    let threads = pool.threads();
    if threads <= 1 || n < min_elems.max(2) {
        f(0, out);
        return;
    }
    let per = n.div_ceil(threads.min(n));
    let chunks = n.div_ceil(per);
    let base = SendPtr(out.as_mut_ptr());
    pool.run(chunks, &|ci| {
        let start = ci * per;
        let len = per.min(n - start);
        debug_assert!(start + len <= n, "chunk {ci} overruns out");
        // SAFETY: `start = ci*per < n` (pool only issues `ci < chunks`
        // and `(chunks-1)*per < n`), so the offset stays inside the
        // allocation `base` points to.
        let ptr = unsafe { base.0.add(start) };
        // SAFETY: `[start, start+len)` ranges for distinct `ci` are
        // disjoint and in-bounds (`verify::plan::par_partition` mirrors
        // this arithmetic and `check_cover` proves it is an exact
        // disjoint cover for every lane count), and `out` stays
        // exclusively borrowed by the issuing `run` until every chunk
        // completes — so each `&mut` sub-slice is unique and live.
        let chunk = unsafe { std::slice::from_raw_parts_mut(ptr, len) };
        f(start, chunk);
    });
}

// ---------------------------------------------------------------------------
// Elementwise
// ---------------------------------------------------------------------------

pub fn fill(out: &mut [f32], value: f32) {
    out.fill(value);
}

/// `out[i] = f(a[i], b[i])` (shapes already equal).
pub fn binary<F>(a: &[f32], b: &[f32], out: &mut [f32], pool: &WorkerPool, f: F)
where
    F: Fn(f32, f32) -> f32 + Sync,
{
    par_map(out, pool, PAR_MIN_ELEMS, |off, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = f(a[off + i], b[off + i]);
        }
    });
}

/// `out[i] = f(out[i], b[i])` — in-place over a dying lhs slot.
pub fn binary_inplace<F>(out: &mut [f32], b: &[f32], pool: &WorkerPool, f: F)
where
    F: Fn(f32, f32) -> f32 + Sync,
{
    par_map(out, pool, PAR_MIN_ELEMS, |off, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = f(*o, b[off + i]);
        }
    });
}

/// `out[i] = f(out[i], out[i])` — both operands were the same dying slot.
pub fn binary_inplace_self<F>(out: &mut [f32], pool: &WorkerPool, f: F)
where
    F: Fn(f32, f32) -> f32 + Sync,
{
    par_map(out, pool, PAR_MIN_ELEMS, |_, chunk| {
        for o in chunk.iter_mut() {
            *o = f(*o, *o);
        }
    });
}

/// `out[i] = f(a[i], s)` (scalar rhs; pass `swap` to flip operand order).
pub fn binary_scalar<F>(
    a: &[f32],
    s: f32,
    swap: bool,
    out: &mut [f32],
    pool: &WorkerPool,
    f: F,
) where
    F: Fn(f32, f32) -> f32 + Sync,
{
    par_map(out, pool, PAR_MIN_ELEMS, |off, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            let v = a[off + i];
            *o = if swap { f(s, v) } else { f(v, s) };
        }
    });
}

/// `out[i] = f(out[i], s)` in place (`swap` flips operand order).
pub fn binary_scalar_inplace<F>(out: &mut [f32], s: f32, swap: bool, pool: &WorkerPool, f: F)
where
    F: Fn(f32, f32) -> f32 + Sync,
{
    par_map(out, pool, PAR_MIN_ELEMS, |_, chunk| {
        for o in chunk.iter_mut() {
            *o = if swap { f(s, *o) } else { f(*o, s) };
        }
    });
}

pub fn unary<F>(a: &[f32], out: &mut [f32], pool: &WorkerPool, f: F)
where
    F: Fn(f32) -> f32 + Sync,
{
    par_map(out, pool, PAR_MIN_ELEMS, |off, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = f(a[off + i]);
        }
    });
}

pub fn unary_inplace<F>(out: &mut [f32], pool: &WorkerPool, f: F)
where
    F: Fn(f32) -> f32 + Sync,
{
    par_map(out, pool, PAR_MIN_ELEMS, |_, chunk| {
        for o in chunk.iter_mut() {
            *o = f(*o);
        }
    });
}

/// `out[i] = if p[i] != 0 { t[i] } else { f[i] }` — the `Select` op.
pub fn select(p: &[f32], t: &[f32], f: &[f32], out: &mut [f32], pool: &WorkerPool) {
    par_map(out, pool, PAR_MIN_ELEMS, |off, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            let j = off + i;
            *o = if p[j] != 0.0 { t[j] } else { f[j] };
        }
    });
}

// ---------------------------------------------------------------------------
// Gather (transpose / broadcast_in_dim share one addressing form)
// ---------------------------------------------------------------------------

/// One output axis of a gather: walk `out_extent` positions of stride
/// `out_stride` in the flat output, advancing the source offset by
/// `src_stride` per position (0 for broadcast axes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GatherAxis {
    pub out_stride: usize,
    pub out_extent: usize,
    pub src_stride: usize,
}

/// `out[flat] = x[Σ_axis ((flat / out_stride) % out_extent) * src_stride]`.
pub fn gather(x: &[f32], axes: &[GatherAxis], out: &mut [f32], pool: &WorkerPool) {
    par_map(out, pool, PAR_MIN_ELEMS, |off, chunk| {
        for (i, slot) in chunk.iter_mut().enumerate() {
            let flat = off + i;
            let mut src = 0usize;
            for ax in axes {
                src += (flat / ax.out_stride) % ax.out_extent * ax.src_stride;
            }
            *slot = x[src];
        }
    });
}

// ---------------------------------------------------------------------------
// Data movement
// ---------------------------------------------------------------------------

pub fn copy(x: &[f32], out: &mut [f32]) {
    out.copy_from_slice(x);
}

/// Copy one concat operand (`mid` wide along the concat axis) into its
/// band of the output (`total` wide), starting at `offset`.
pub fn concat_part(
    x: &[f32],
    outer: usize,
    mid: usize,
    inner: usize,
    total: usize,
    offset: usize,
    out: &mut [f32],
) {
    for o in 0..outer {
        let src = &x[o * mid * inner..(o + 1) * mid * inner];
        let dst = (o * total + offset) * inner;
        out[dst..dst + mid * inner].copy_from_slice(src);
    }
}

#[allow(clippy::too_many_arguments)]
pub fn slice(
    x: &[f32],
    outer: usize,
    mid_in: usize,
    inner: usize,
    start: usize,
    stride: usize,
    mid_out: usize,
    out: &mut [f32],
) {
    for o in 0..outer {
        for m in 0..mid_out {
            let src = (o * mid_in + start + m * stride) * inner;
            let dst = (o * mid_out + m) * inner;
            out[dst..dst + inner].copy_from_slice(&x[src..src + inner]);
        }
    }
}

// ---------------------------------------------------------------------------
// Contraction
// ---------------------------------------------------------------------------

/// Tile geometry of the packed GEMM path: MR×NR is the register tile
/// (one accumulator lane per output element), KB the k-block streamed
/// per pass over the output, NB the column strip kept L2-resident.
///
/// The config is performance-only state: every config produces
/// bitwise-identical output (each element's k-sum is the same ascending
/// mul/add sequence regardless of tiling), which is why the autotuner's
/// choice may be cached outside the bitwise-identity-relevant parts of
/// an executable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileConfig {
    /// Register-tile rows (A panel height). One of {1, 2, 4, 8}.
    pub mr: usize,
    /// Register-tile columns (B panel width). One of {8, 16}.
    pub nr: usize,
    /// K-block length per pass over the output tile.
    pub kb: usize,
    /// Column-strip width (rounded up to a multiple of `nr`).
    pub nb: usize,
}

impl TileConfig {
    /// Shape-oblivious default, used when the autotuner is off. A 4×8
    /// tile holds its 32 accumulators in 8 xmm registers even under
    /// baseline x86-64 codegen (no `target-cpu` assumption), so it
    /// never spills; wider tiles only win where the autotuner can
    /// verify the register file supports them.
    pub const DEFAULT: TileConfig = TileConfig { mr: 4, nr: 8, kb: 128, nb: 256 };

    /// Candidate set the compile-time autotuner times per shape bucket.
    /// Kept small on purpose: each entry is one monomorphized
    /// microkernel instantiation plus one blocking choice, spanning
    /// register budgets from SSE2 xmm (4×8) up to AVX-512 zmm (8×16).
    pub const CANDIDATES: [TileConfig; 6] = [
        TileConfig { mr: 4, nr: 8, kb: 128, nb: 256 },
        TileConfig { mr: 8, nr: 8, kb: 128, nb: 256 },
        TileConfig { mr: 4, nr: 16, kb: 256, nb: 256 },
        TileConfig { mr: 8, nr: 16, kb: 128, nb: 256 },
        TileConfig { mr: 2, nr: 16, kb: 128, nb: 512 },
        TileConfig { mr: 1, nr: 16, kb: 256, nb: 512 },
    ];

    /// Parse the CLI form `MRxNRxKBxNB`, e.g. `8x16x128x256`.
    pub fn parse(s: &str) -> Result<TileConfig, String> {
        let parts: Vec<&str> = s.split('x').collect();
        if parts.len() != 4 {
            return Err(format!("tile '{s}': want MRxNRxKBxNB, e.g. 8x16x128x256"));
        }
        let mut v = [0usize; 4];
        for (slot, p) in v.iter_mut().zip(&parts) {
            *slot = p.parse::<usize>().map_err(|_| format!("tile '{s}': '{p}' not a number"))?;
        }
        let cfg = TileConfig { mr: v[0], nr: v[1], kb: v[2], nb: v[3] };
        if !matches!(cfg.mr, 1 | 2 | 4 | 8) {
            return Err(format!("tile '{s}': MR must be one of 1/2/4/8"));
        }
        if !matches!(cfg.nr, 8 | 16) {
            return Err(format!("tile '{s}': NR must be 8 or 16"));
        }
        if cfg.kb == 0 || cfg.nb == 0 {
            return Err(format!("tile '{s}': KB and NB must be positive"));
        }
        Ok(cfg)
    }

    /// Report form, inverse of [`TileConfig::parse`].
    pub fn key(&self) -> String {
        format!("{}x{}x{}x{}", self.mr, self.nr, self.kb, self.nb)
    }

    /// Clamp to what the kernel can execute for an `m`-row output:
    /// `mr` drops to the shape's effective panel height, `nb` rounds up
    /// to a whole number of `nr` panels, `kb` gets a sane floor. Pure
    /// function of (config, m) — `verify::plan` re-derives it when
    /// proving panel partitions.
    pub fn normalized(&self, m: usize) -> TileConfig {
        let mr = effective_mr(self.mr, m);
        let nr = if self.nr >= 16 { 16 } else { 8 };
        let kb = self.kb.max(8);
        let nb = self.nb.max(nr).div_ceil(nr) * nr;
        TileConfig { mr, nr, kb, nb }
    }
}

/// Largest microkernel panel height `<= min(mr, m)` (a power of two,
/// at least 1): an m-row output never pays for accumulator rows that
/// could only ever hold padding.
pub fn effective_mr(mr: usize, m: usize) -> usize {
    let cap = mr.min(m.max(1)).min(8);
    let mut e = 1usize;
    while e * 2 <= cap {
        e *= 2;
    }
    e
}

/// Widest panel height any [`TileConfig`] can request — pack-buffer
/// capacities are sized for it so one buffer fits every candidate.
pub const MR_MAX: usize = 8;
/// Widest panel width any [`TileConfig`] can request.
pub const NR_MAX: usize = 16;

/// f32 capacity of the packed-A scratch for an `m`×`k` operand, valid
/// for every tile config and thread count (panel heights are powers of
/// two `<=` [`MR_MAX`], so rounding `m` up to `MR_MAX` covers them all).
/// The planner sizes the arena slot with this; the kernel asserts it.
pub fn packed_a_len(m: usize, k: usize) -> usize {
    m.div_ceil(MR_MAX) * MR_MAX * k
}

/// f32 capacity of the packed-B scratch for a `k`×`n` operand, valid
/// for every tile config and thread count.
pub fn packed_b_len(n: usize, k: usize) -> usize {
    n.div_ceil(NR_MAX) * NR_MAX * k
}

/// Monomorphized MR×NR register-tile kernel: (packed A block, packed B
/// block, klen, first-k-block?, out base, n, i0, j0, live rows, live
/// cols).
type MicroFn = fn(&[f32], &[f32], usize, bool, SendPtr, usize, usize, usize, usize, usize);

fn micro_fn(mr: usize, nr: usize) -> MicroFn {
    match (mr, nr) {
        (1, 8) => micro_tile::<1, 8>,
        (1, 16) => micro_tile::<1, 16>,
        (2, 8) => micro_tile::<2, 8>,
        (2, 16) => micro_tile::<2, 16>,
        (4, 8) => micro_tile::<4, 8>,
        (4, 16) => micro_tile::<4, 16>,
        (8, 8) => micro_tile::<8, 8>,
        _ => micro_tile::<8, 16>,
    }
}

/// One MR×NR register tile over one k-block. `first` zeroes the
/// accumulators; later k-blocks reload the partial sums already stored,
/// so per output element the sum still runs over k in ascending order —
/// the bitwise contract. The accumulator array is the explicit
/// vectorization: NR f32 lanes per row that the compiler lowers to
/// AVX/NEON mul+add (no FMA contraction — the scalar path rounds twice
/// per MAC, so the packed path must too). Edge tiles (`rows < MR`,
/// `cols < NR`) compute the full tile against the packs' zero padding
/// but load/store through masked scalar row loops, so padding lanes
/// never touch `out`.
#[allow(clippy::too_many_arguments)]
fn micro_tile<const MR: usize, const NR: usize>(
    ap: &[f32],
    bp: &[f32],
    klen: usize,
    first: bool,
    base: SendPtr,
    n: usize,
    i0: usize,
    j0: usize,
    rows: usize,
    cols: usize,
) {
    debug_assert!(rows <= MR && cols <= NR);
    debug_assert!(ap.len() == klen * MR && bp.len() == klen * NR);
    let mut acc = [[0f32; NR]; MR];
    if !first {
        for (r, arow) in acc.iter_mut().enumerate().take(rows) {
            // SAFETY: `(i0+r)*n + j0` addresses row `i0+r < m`, col `j0`
            // of the m×n allocation behind `base` (the caller's tile
            // ranges come from the panel partition `verify::plan` proves
            // is in-bounds), so the offset stays inside the allocation.
            let p = unsafe { base.0.add((i0 + r) * n + j0) };
            // SAFETY: `[j0, j0+cols)` of row `i0+r` lies inside this
            // chunk's exclusive output rectangle (disjoint exact cover
            // across chunks per `verify::plan::check_cover`), and the
            // slice dies before the matching store below re-borrows it.
            let prev = unsafe { std::slice::from_raw_parts(p, cols) };
            arow[..cols].copy_from_slice(prev);
        }
    }
    for (av, bv) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        for r in 0..MR {
            let a = av[r];
            let arow = &mut acc[r];
            for (o, &bb) in arow.iter_mut().zip(bv) {
                *o += a * bb;
            }
        }
    }
    for (r, arow) in acc.iter().enumerate().take(rows) {
        // SAFETY: same in-bounds argument as the load above.
        let p = unsafe { base.0.add((i0 + r) * n + j0) };
        // SAFETY: same exclusive-rectangle argument as the load above;
        // no other slice over this range is live.
        let orow = unsafe { std::slice::from_raw_parts_mut(p, cols) };
        orow.copy_from_slice(&arow[..cols]);
    }
}

/// Pack `pc` row-panels of `a` (global panels `p0..p0+pc`, `mr` rows
/// each) into `dst`, `[panel][k][mr]`-contiguous with `dst[0]` the
/// first element of panel `p0`; rows past `m` pad with zeros. Pure data
/// movement: contributes nothing to accumulation order.
fn pack_a_panels(a: &[f32], m: usize, k: usize, mr: usize, p0: usize, pc: usize, dst: &mut [f32]) {
    debug_assert_eq!(dst.len(), pc * k * mr);
    for lp in 0..pc {
        let r0 = (p0 + lp) * mr;
        let rows = mr.min(m.saturating_sub(r0));
        let panel = &mut dst[lp * k * mr..(lp + 1) * k * mr];
        if rows < mr {
            panel.fill(0.0);
        }
        for r in 0..rows {
            let arow = &a[(r0 + r) * k..(r0 + r + 1) * k];
            for (kk, &v) in arow.iter().enumerate() {
                panel[kk * mr + r] = v;
            }
        }
    }
}

/// Pack `pc` column-panels of `b` (global panels `p0..p0+pc`, `nr`
/// columns each) into `dst`, `[panel][k][nr]`-contiguous; columns past
/// `n` pad with zeros.
fn pack_b_panels(b: &[f32], n: usize, k: usize, nr: usize, p0: usize, pc: usize, dst: &mut [f32]) {
    debug_assert_eq!(dst.len(), pc * k * nr);
    for lp in 0..pc {
        let j0 = (p0 + lp) * nr;
        let cols = nr.min(n - j0);
        let panel = &mut dst[lp * k * nr..(lp + 1) * k * nr];
        for kk in 0..k {
            let drow = &mut panel[kk * nr..(kk + 1) * nr];
            drow[..cols].copy_from_slice(&b[kk * n + j0..kk * n + j0 + cols]);
            drow[cols..].fill(0.0);
        }
    }
}

/// Serial packed-GEMM driver over one output rectangle
/// `[row0, row0+rows) × [col0, col0+cols)`. `ap`/`bp` hold exactly the
/// packed panels covering that rectangle (panel-local: their first
/// panel starts at offset 0). `row0`/`col0` must be panel-aligned.
/// Loop order per rectangle: NB column strips outermost, then ascending
/// KB k-blocks, then row/column panels — so every output element sees
/// its k-sum in ascending order across k-blocks.
#[allow(clippy::too_many_arguments)]
fn dot_packed_block(
    ap: &[f32],
    bp: &[f32],
    n: usize,
    k: usize,
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
    cfg: TileConfig,
    micro: MicroFn,
    base: SendPtr,
) {
    let TileConfig { mr, nr, kb, nb } = cfg;
    debug_assert!(row0 % mr == 0 && col0 % nr == 0 && nb % nr == 0);
    for jb in (0..cols).step_by(nb) {
        let je = (jb + nb).min(cols);
        for k0 in (0..k).step_by(kb) {
            let ke = (k0 + kb).min(k);
            let (first, klen) = (k0 == 0, ke - k0);
            let mut i0 = 0usize;
            while i0 < rows {
                let trows = mr.min(rows - i0);
                let pa = i0 / mr * k * mr;
                let ap_blk = &ap[pa + k0 * mr..pa + ke * mr];
                let mut j0 = jb;
                while j0 < je {
                    let tcols = nr.min(je - j0);
                    let pb = j0 / nr * k * nr;
                    let bp_blk = &bp[pb + k0 * nr..pb + ke * nr];
                    micro(
                        ap_blk,
                        bp_blk,
                        klen,
                        first,
                        base,
                        n,
                        row0 + i0,
                        col0 + j0,
                        trows,
                        tcols,
                    );
                    j0 += nr;
                }
                i0 += mr;
            }
        }
    }
}

/// Packed BLIS-style `out[m,n] = Σ_k a[m,k] · b[k,n]` with
/// caller-provided pack scratch (arena slots sized by
/// [`packed_a_len`]/[`packed_b_len`]). Partitioning: row panels across
/// lanes when `m >= threads`; otherwise — the tall-skinny fix — column
/// panels across lanes (batch-1 `m = 1` now fans out over N). Both
/// partitions and the `normalized` tile are pure functions of
/// (shape, thread count, config), and every accumulator lane owns one
/// output element over the full ascending-k extent — so output bits
/// never depend on threads or tile.
#[allow(clippy::too_many_arguments)]
pub fn dot_packed(
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    out: &mut [f32],
    pool: &WorkerPool,
    cfg: TileConfig,
    a_pack: &mut [f32],
    b_pack: &mut [f32],
) {
    if out.is_empty() {
        return;
    }
    if k == 0 {
        out.fill(0.0); // empty contraction: a sum over nothing
        return;
    }
    let m = out.len() / n;
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let cfg = cfg.normalized(m);
    let (mr, nr) = (cfg.mr, cfg.nr);
    let rp = m.div_ceil(mr); // row panels
    let cp = n.div_ceil(nr); // column panels
    assert!(a_pack.len() >= rp * k * mr, "packed-A scratch undersized");
    assert!(b_pack.len() >= cp * k * nr, "packed-B scratch undersized");
    let micro = micro_fn(mr, nr);
    let threads = pool.threads();
    let base = SendPtr(out.as_mut_ptr());
    if threads <= 1 || m * n * k < PAR_MIN_MACS {
        pack_b_panels(b, n, k, nr, 0, cp, &mut b_pack[..cp * k * nr]);
        pack_a_panels(a, m, k, mr, 0, rp, &mut a_pack[..rp * k * mr]);
        dot_packed_block(a_pack, b_pack, n, k, 0, m, 0, n, cfg, micro, base);
        return;
    }
    if m >= threads {
        // Row-panel partition: pack B once (panels split across lanes),
        // then each lane packs and contracts its own row panels.
        par_pack_b(b, n, k, nr, cp, pool, b_pack);
        let bp: &[f32] = b_pack;
        let t = threads.min(rp);
        let per = rp.div_ceil(t);
        let chunks = rp.div_ceil(per);
        let abase = SendPtr(a_pack.as_mut_ptr());
        pool.run(chunks, &|ci| {
            let p0 = ci * per;
            let pc = per.min(rp - p0);
            // SAFETY: `p0 < rp`, so `p0*k*mr` is inside the `>= rp*k*mr`
            // allocation behind `abase` (asserted above).
            let aptr = unsafe { abase.0.add(p0 * k * mr) };
            // SAFETY: panel ranges `[p0, p0+pc)` for distinct `ci` are
            // disjoint and exactly cover `0..rp`
            // (`verify::plan::panel_partition` mirrors this arithmetic
            // and `check_cover` proves it for every lane count), so the
            // `pc*k*mr` regions never alias, and `a_pack` stays
            // exclusively borrowed by this `run`.
            let ap = unsafe { std::slice::from_raw_parts_mut(aptr, pc * k * mr) };
            pack_a_panels(a, m, k, mr, p0, pc, ap);
            let row0 = p0 * mr;
            let rows = ((p0 + pc) * mr).min(m) - row0;
            dot_packed_block(ap, bp, n, k, row0, rows, 0, n, cfg, micro, base);
        });
    } else {
        // Column-panel partition (tall-skinny fallback): pack all of A
        // up front (m < threads, so it is tiny), then each lane packs
        // and contracts its own column panels.
        pack_a_panels(a, m, k, mr, 0, rp, &mut a_pack[..rp * k * mr]);
        let ap: &[f32] = a_pack;
        let t = threads.min(cp);
        if t <= 1 {
            pack_b_panels(b, n, k, nr, 0, cp, &mut b_pack[..cp * k * nr]);
            dot_packed_block(ap, b_pack, n, k, 0, m, 0, n, cfg, micro, base);
            return;
        }
        let per = cp.div_ceil(t);
        let chunks = cp.div_ceil(per);
        let bbase = SendPtr(b_pack.as_mut_ptr());
        pool.run(chunks, &|ci| {
            let p0 = ci * per;
            let pc = per.min(cp - p0);
            // SAFETY: `p0 < cp`, so `p0*k*nr` is inside the `>= cp*k*nr`
            // allocation behind `bbase` (asserted above).
            let bptr = unsafe { bbase.0.add(p0 * k * nr) };
            // SAFETY: panel ranges `[p0, p0+pc)` for distinct `ci` are
            // disjoint and exactly cover `0..cp`
            // (`verify::plan::panel_partition` mirrors this arithmetic
            // and `check_cover` proves it for every lane count), so the
            // `pc*k*nr` regions never alias, and `b_pack` stays
            // exclusively borrowed by this `run`.
            let bp = unsafe { std::slice::from_raw_parts_mut(bptr, pc * k * nr) };
            pack_b_panels(b, n, k, nr, p0, pc, bp);
            let col0 = p0 * nr;
            let cols = ((p0 + pc) * nr).min(n) - col0;
            dot_packed_block(ap, bp, n, k, 0, m, col0, cols, cfg, micro, base);
        });
    }
}

/// Pack all `cp` column panels of B in parallel (panels split across
/// lanes with the same exact-cover partition the contraction uses).
fn par_pack_b(
    b: &[f32],
    n: usize,
    k: usize,
    nr: usize,
    cp: usize,
    pool: &WorkerPool,
    b_pack: &mut [f32],
) {
    let t = pool.threads().min(cp);
    if t <= 1 {
        pack_b_panels(b, n, k, nr, 0, cp, &mut b_pack[..cp * k * nr]);
        return;
    }
    let per = cp.div_ceil(t);
    let chunks = cp.div_ceil(per);
    let bbase = SendPtr(b_pack.as_mut_ptr());
    pool.run(chunks, &|ci| {
        let p0 = ci * per;
        let pc = per.min(cp - p0);
        // SAFETY: `p0 < cp`, so `p0*k*nr` stays inside the `>= cp*k*nr`
        // capacity the caller asserted for `b_pack`.
        let bptr = unsafe { bbase.0.add(p0 * k * nr) };
        // SAFETY: panel ranges for distinct `ci` are disjoint and
        // exactly cover `0..cp` (`verify::plan::panel_partition` +
        // `check_cover`), and `b_pack` stays exclusively borrowed by
        // this `run` until every chunk completes.
        let bp = unsafe { std::slice::from_raw_parts_mut(bptr, pc * k * nr) };
        pack_b_panels(b, n, k, nr, p0, pc, bp);
    });
}

/// `out[m,n] = Σ_k a[m,k] · b[k,n]` — the self-contained entry the
/// reference interpreter and tests use. Above [`PACK_MIN_MACS`] it
/// allocates transient pack scratch and runs the packed path (the
/// planned executor passes arena slots to [`dot_packed`] instead);
/// below, the scalar core. Both produce identical bits.
pub fn dot_general(
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    out: &mut [f32],
    pool: &WorkerPool,
) {
    if out.is_empty() {
        return;
    }
    if k == 0 {
        out.fill(0.0);
        return;
    }
    let m = out.len() / n;
    if m * n * k < PACK_MIN_MACS {
        dot_scalar(a, b, n, k, out, pool);
        return;
    }
    let mut ap = vec![0f32; packed_a_len(m, k)];
    let mut bp = vec![0f32; packed_b_len(n, k)];
    dot_packed(a, b, n, k, out, pool, TileConfig::DEFAULT, &mut ap, &mut bp);
}

/// The pre-packing i-k-j core, kept as the small-shape path and as the
/// bench baseline the packed path is gated against: rows partitioned
/// across lanes, per-element ascending-k accumulation (bitwise equal to
/// the packed path).
pub fn dot_scalar(
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    out: &mut [f32],
    pool: &WorkerPool,
) {
    if out.is_empty() {
        return;
    }
    if k == 0 {
        out.fill(0.0); // empty contraction: a sum over nothing
        return;
    }
    let m = out.len() / n;
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let t = if m * n * k >= PAR_MIN_MACS { pool.threads().min(m) } else { 1 };
    if t <= 1 {
        dot_rows(a, b, n, k, out);
        return;
    }
    let rows_per = m.div_ceil(t);
    let chunks = m.div_ceil(rows_per);
    let base = SendPtr(out.as_mut_ptr());
    pool.run(chunks, &|ci| {
        let r0 = ci * rows_per;
        let rows = rows_per.min(m - r0);
        debug_assert!((r0 + rows) * n <= m * n, "row chunk {ci} overruns out");
        // SAFETY: `r0 = ci*rows_per < m`, so `r0*n` is inside the `m*n`
        // allocation behind `base`.
        let ptr = unsafe { base.0.add(r0 * n) };
        // SAFETY: row ranges `[r0, r0+rows)` for distinct `ci` are
        // disjoint and exactly cover `0..m` (`verify::plan::row_partition`
        // mirrors this arithmetic and `check_cover` proves it for every
        // lane count), and `out` stays exclusively borrowed by the
        // issuing `run` until every chunk completes.
        let ochunk = unsafe { std::slice::from_raw_parts_mut(ptr, rows * n) };
        dot_rows(&a[r0 * k..(r0 + rows) * k], b, n, k, ochunk);
    });
}

/// Serial tiled core over a row block: i-k-j with N×K blocking.
fn dot_rows(a: &[f32], b: &[f32], n: usize, k: usize, out: &mut [f32]) {
    out.fill(0.0);
    if n == 0 || k == 0 || out.is_empty() {
        return;
    }
    let rows = out.len() / n;
    for jb in (0..n).step_by(NB) {
        let je = (jb + NB).min(n);
        for kb in (0..k).step_by(KB) {
            let ke = (kb + KB).min(k);
            for i in 0..rows {
                let arow = &a[i * k + kb..i * k + ke];
                let orow = &mut out[i * n + jb..i * n + je];
                for (p, &av) in arow.iter().enumerate() {
                    let brow = &b[(kb + p) * n + jb..(kb + p) * n + je];
                    for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                        *o += av * bv;
                    }
                }
            }
        }
    }
}

/// CSR sparse×dense: `out[r, j] = Σ_{e ∈ row r} vals[perm(e)] · x[col(e), j]`
/// with `x` pre-permuted so the contracted axis leads (`[n_cols, m]`
/// row-major, like `dot_general`'s B operand). Rows are partitioned
/// across lanes; within a row the entries accumulate in ascending CSR
/// order, so — exactly like `dot_general` — neither threading nor
/// chunking can change a bit. No zero-value skip, for the same IEEE
/// reason as the dense kernel (stored zeros must still poison on NaN).
#[allow(clippy::too_many_arguments)]
pub fn spmm_csr(
    vals: &[f32],
    x: &[f32],
    row_ptr: &[u32],
    col_idx: &[u32],
    val_perm: Option<&[u32]>,
    m: usize,
    out: &mut [f32],
    pool: &WorkerPool,
) {
    if out.is_empty() {
        return;
    }
    let n_rows = row_ptr.len() - 1;
    debug_assert_eq!(out.len(), n_rows * m);
    let macs = col_idx.len() * m;
    let t = if macs >= PAR_MIN_MACS { pool.threads().min(n_rows) } else { 1 };
    if t <= 1 {
        spmm_rows(vals, x, row_ptr, col_idx, val_perm, m, 0, n_rows, out);
        return;
    }
    let rows_per = n_rows.div_ceil(t);
    let chunks = n_rows.div_ceil(rows_per);
    let base = SendPtr(out.as_mut_ptr());
    pool.run(chunks, &|ci| {
        let r0 = ci * rows_per;
        let rows = rows_per.min(n_rows - r0);
        debug_assert!((r0 + rows) * m <= n_rows * m, "row chunk {ci} overruns out");
        // SAFETY: `r0 = ci*rows_per < n_rows`, so `r0*m` is inside the
        // `n_rows*m` allocation behind `base`.
        let ptr = unsafe { base.0.add(r0 * m) };
        // SAFETY: row ranges `[r0, r0+rows)` for distinct `ci` are
        // disjoint and exactly cover `0..n_rows` (mirrored and proven by
        // `verify::plan::{row_partition, check_cover}` for every lane
        // count), and `out` stays exclusively borrowed by the issuing
        // `run` until every chunk completes.
        let ochunk = unsafe { std::slice::from_raw_parts_mut(ptr, rows * m) };
        spmm_rows(vals, x, row_ptr, col_idx, val_perm, m, r0, rows, ochunk);
    });
}

/// f32 lanes per accumulator chunk in the explicitly unrolled axpy —
/// the same 8-wide unit the packed microkernel's register tiles build
/// on (one AVX/NEON-pair vector of f32).
pub const LANES: usize = 8;

/// `out[j] += v * x[j]`, unrolled into [`LANES`]-wide chunks so the
/// compiler lowers it to vector mul+add. Element order and rounding are
/// identical to the plain scalar loop (each `out[j]` sees exactly one
/// mul and one add, in ascending j), so this is bitwise-neutral.
#[inline]
fn axpy_lanes(v: f32, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let mut oc = out.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (o, xv) in (&mut oc).zip(&mut xc) {
        for l in 0..LANES {
            o[l] += v * xv[l];
        }
    }
    for (o, &xv) in oc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o += v * xv;
    }
}

/// Serial core over a row block: per row, ascending-entry axpy into the
/// output row (the fixed accumulation order the determinism pin needs).
/// The dense-axis inner loop runs through [`axpy_lanes`], the same
/// fixed-width lane primitive the packed microkernel uses.
#[allow(clippy::too_many_arguments)]
fn spmm_rows(
    vals: &[f32],
    x: &[f32],
    row_ptr: &[u32],
    col_idx: &[u32],
    val_perm: Option<&[u32]>,
    m: usize,
    r0: usize,
    rows: usize,
    out: &mut [f32],
) {
    out.fill(0.0);
    for i in 0..rows {
        let r = r0 + i;
        let orow = &mut out[i * m..(i + 1) * m];
        for e in row_ptr[r] as usize..row_ptr[r + 1] as usize {
            let v = match val_perm {
                Some(p) => vals[p[e] as usize],
                None => vals[e],
            };
            let c = col_idx[e] as usize;
            axpy_lanes(v, &x[c * m..(c + 1) * m], orow);
        }
    }
}

// ---------------------------------------------------------------------------
// Reduction
// ---------------------------------------------------------------------------

/// Precomputed geometry of a reduction: kept axes address the base
/// offset per output element; `red` is the (extent, stride) odometer of
/// the reduced subspace; `contiguous` marks reductions over trailing
/// axes, where the subspace is one dense run of `count` elements.
#[derive(Clone, Debug)]
pub struct ReduceGeom {
    pub kept: Vec<GatherAxis>,
    pub red: Vec<(usize, usize)>,
    pub count: usize,
    pub contiguous: bool,
}

/// Sum (and for `mean` the average) over the reduced subspace, one
/// output element per chunk slot, accumulated in f64 in a fixed order.
/// `geom.count` must be non-zero (the planner and `GraphBuilder` reject
/// empty reduces).
pub fn reduce(x: &[f32], geom: &ReduceGeom, mean: bool, out: &mut [f32], pool: &WorkerPool) {
    debug_assert!(geom.count > 0, "reduce over an empty subspace");
    let inv = geom.count as f64;
    par_map(out, pool, PAR_MIN_REDUCE, |off, chunk| {
        for (i, slot) in chunk.iter_mut().enumerate() {
            let flat = off + i;
            let mut base = 0usize;
            for ax in &geom.kept {
                base += (flat / ax.out_stride) % ax.out_extent * ax.src_stride;
            }
            let mut acc = 0f64;
            if geom.contiguous {
                for &v in &x[base..base + geom.count] {
                    acc += v as f64;
                }
            } else {
                for r in 0..geom.count {
                    let mut rem = r;
                    let mut src = base;
                    for &(extent, stride) in geom.red.iter().rev() {
                        src += rem % extent * stride;
                        rem /= extent;
                    }
                    acc += x[src] as f64;
                }
            }
            *slot = if mean { (acc / inv) as f32 } else { acc as f32 };
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(threads: usize) -> WorkerPool {
        WorkerPool::new(threads)
    }

    #[test]
    fn dot_has_no_zero_skip() {
        // 0-weight row meeting NaN/Inf activations must poison the output
        let a = [0.0f32, 0.0];
        let b = [f32::NAN, 1.0, f32::INFINITY, 2.0]; // [2, 2]
        let mut out = [0f32; 2];
        dot_general(&a, &b, 2, 2, &mut out, &pool(1));
        assert!(out[0].is_nan(), "0*NaN + 0*Inf must be NaN, got {}", out[0]);
        assert_eq!(out[1], 0.0, "finite column stays exact");
    }

    #[test]
    fn dot_matches_naive_bitwise_across_threads_and_tiles() {
        let (m, n, k) = (7, 300, 190); // forces partial N/K tiles
        let a: Vec<f32> = (0..m * k).map(|i| ((i * 37 % 97) as f32 - 48.0) * 0.37).collect();
        let b: Vec<f32> = (0..k * n).map(|i| ((i * 61 % 89) as f32 - 44.0) * 0.13).collect();
        let naive = naive_dot(&a, &b, m, n, k);
        for threads in [1, 2, 5] {
            let mut out = vec![0f32; m * n];
            dot_general(&a, &b, n, k, &mut out, &pool(threads));
            assert_eq!(out, naive, "threads={threads}");
        }
    }

    fn naive_dot(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut naive = vec![0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                for j in 0..n {
                    naive[i * n + j] += av * b[p * n + j];
                }
            }
        }
        naive
    }

    fn det_mat(len: usize, mul: usize, md: usize, off: f32, scale: f32) -> Vec<f32> {
        (0..len).map(|i| ((i * mul % md) as f32 - off) * scale).collect()
    }

    fn run_packed(
        a: &[f32],
        b: &[f32],
        m: usize,
        n: usize,
        k: usize,
        cfg: TileConfig,
        threads: usize,
    ) -> Vec<f32> {
        let mut out = vec![f32::NAN; m * n]; // stale arena garbage must not leak
        let mut ap = vec![0f32; packed_a_len(m, k)];
        let mut bp = vec![0f32; packed_b_len(n, k)];
        dot_packed(a, b, n, k, &mut out, &pool(threads), cfg, &mut ap, &mut bp);
        out
    }

    #[test]
    fn pack_roundtrip_restores_edge_panels() {
        // M%MR, N%NR, K odd — every edge case the panels must pad
        let (m, n, k) = (7usize, 13usize, 5usize);
        let a = det_mat(m * k, 7, 31, 15.0, 0.5);
        let b = det_mat(k * n, 11, 29, 14.0, 0.25);
        for mr in [1usize, 2, 4, 8] {
            let rp = m.div_ceil(mr);
            let mut packed = vec![f32::NAN; rp * k * mr];
            pack_a_panels(&a, m, k, mr, 0, rp, &mut packed);
            for pi in 0..rp {
                for kk in 0..k {
                    for r in 0..mr {
                        let got = packed[pi * k * mr + kk * mr + r];
                        let row = pi * mr + r;
                        let want = if row < m { a[row * k + kk] } else { 0.0 };
                        assert_eq!(got, want, "a panel {pi} k {kk} r {r} (mr={mr})");
                    }
                }
            }
        }
        for nr in [8usize, 16] {
            let cp = n.div_ceil(nr);
            let mut packed = vec![f32::NAN; cp * k * nr];
            pack_b_panels(&b, n, k, nr, 0, cp, &mut packed);
            for pj in 0..cp {
                for kk in 0..k {
                    for c in 0..nr {
                        let got = packed[pj * k * nr + kk * nr + c];
                        let col = pj * nr + c;
                        let want = if col < n { b[kk * n + col] } else { 0.0 };
                        assert_eq!(got, want, "b panel {pj} k {kk} c {c} (nr={nr})");
                    }
                }
            }
        }
    }

    #[test]
    fn packed_is_bitwise_equal_across_tile_configs() {
        // small enough to stay serial — the config sweep isolates tiling
        let (m, n, k) = (11, 37, 23); // M%MR, N%NR, K%KB all non-zero
        let a = det_mat(m * k, 37, 97, 48.0, 0.37);
        let b = det_mat(k * n, 61, 89, 44.0, 0.13);
        let naive = naive_dot(&a, &b, m, n, k);
        let mut scalar = vec![0f32; m * n];
        dot_scalar(&a, &b, n, k, &mut scalar, &pool(1));
        assert_eq!(scalar, naive, "scalar path diverged from naive");
        for cfg in TileConfig::CANDIDATES.iter().chain([&TileConfig::DEFAULT]) {
            let out = run_packed(&a, &b, m, n, k, *cfg, 1);
            assert_eq!(out, naive, "tile {} changed bits", cfg.key());
        }
        // an intentionally awkward blocking: KB/NB smaller than the tile
        let odd = TileConfig { mr: 4, nr: 8, kb: 8, nb: 8 };
        assert_eq!(run_packed(&a, &b, m, n, k, odd, 1), naive, "odd blocking changed bits");
    }

    #[test]
    fn packed_row_partition_is_bitwise_across_threads() {
        // crosses PAR_MIN_MACS with m >= threads: row-panel partition
        let (m, n, k) = (16, 160, 110);
        assert!(m * n * k >= PAR_MIN_MACS);
        let a = det_mat(m * k, 37, 97, 48.0, 0.37);
        let b = det_mat(k * n, 61, 89, 44.0, 0.13);
        let t1 = run_packed(&a, &b, m, n, k, TileConfig::DEFAULT, 1);
        assert_eq!(t1, naive_dot(&a, &b, m, n, k));
        for threads in [2usize, 8] {
            let out = run_packed(&a, &b, m, n, k, TileConfig::DEFAULT, threads);
            assert_eq!(out, t1, "threads={threads} changed bits (row path)");
        }
    }

    #[test]
    fn packed_column_partition_feeds_tall_skinny_shapes() {
        // m=2 < threads while N·K is large: the seed starved here
        // (threads capped at min(threads, m)); the column-panel
        // partition must fan out and stay bitwise with serial
        let (m, n, k) = (2, 1000, 150);
        assert!(m * n * k >= PAR_MIN_MACS);
        let a = det_mat(m * k, 13, 61, 30.0, 0.21);
        let b = det_mat(k * n, 17, 53, 26.0, 0.11);
        let t1 = run_packed(&a, &b, m, n, k, TileConfig::DEFAULT, 1);
        assert_eq!(t1, naive_dot(&a, &b, m, n, k));
        for threads in [2usize, 8] {
            let out = run_packed(&a, &b, m, n, k, TileConfig::DEFAULT, threads);
            assert_eq!(out, t1, "threads={threads} changed bits (column path)");
        }
        // batch-1 (m=1) rides the same fallback
        let (m, n, k) = (1, 2000, 160);
        assert!(m * n * k >= PAR_MIN_MACS);
        let a = det_mat(m * k, 19, 47, 23.0, 0.17);
        let b = det_mat(k * n, 23, 43, 21.0, 0.09);
        let t1 = run_packed(&a, &b, m, n, k, TileConfig::DEFAULT, 1);
        assert_eq!(t1, naive_dot(&a, &b, m, n, k));
        let t8 = run_packed(&a, &b, m, n, k, TileConfig::DEFAULT, 8);
        assert_eq!(t8, t1, "batch-1 column partition changed bits");
    }

    #[test]
    fn packed_has_no_zero_skip() {
        // NaN/Inf activations against an all-zero weight row must
        // poison through the packed path too (PR 3's pin, re-applied)
        let (m, n, k) = (5, 17, 9);
        let a = vec![0f32; m * k];
        let mut b = det_mat(k * n, 7, 19, 9.0, 0.5);
        b[3] = f32::NAN; // column 3 of row 0
        b[n + 4] = f32::INFINITY; // column 4 of row 1
        let out = run_packed(&a, &b, m, n, k, TileConfig::DEFAULT, 1);
        for i in 0..m {
            assert!(out[i * n + 3].is_nan(), "0*NaN must poison row {i}");
            assert!(out[i * n + 4].is_nan(), "0*Inf then 0*finite must be NaN in row {i}");
        }
        assert_eq!(out[5], 0.0, "finite columns stay exact zero");
    }

    #[test]
    fn pack_capacity_covers_every_candidate() {
        for (m, n, k) in [(1usize, 1usize, 1usize), (7, 13, 5), (16, 160, 110), (33, 65, 17)] {
            for cfg in TileConfig::CANDIDATES {
                let c = cfg.normalized(m);
                assert!(
                    m.div_ceil(c.mr) * c.mr * k <= packed_a_len(m, k),
                    "a capacity m={m} k={k} tile {}",
                    cfg.key()
                );
                assert!(
                    n.div_ceil(c.nr) * c.nr * k <= packed_b_len(n, k),
                    "b capacity n={n} k={k} tile {}",
                    cfg.key()
                );
            }
        }
    }

    #[test]
    fn tile_config_parse_roundtrip_and_rejects() {
        let cfg = TileConfig::parse("4x8x64x128").unwrap();
        assert_eq!(cfg, TileConfig { mr: 4, nr: 8, kb: 64, nb: 128 });
        assert_eq!(TileConfig::parse(&cfg.key()).unwrap(), cfg);
        for bad in ["4x8x64", "3x8x64x128", "4x9x64x128", "4x8x0x128", "axbxcxd"] {
            assert!(TileConfig::parse(bad).is_err(), "{bad} should not parse");
        }
        assert_eq!(effective_mr(8, 3), 2);
        assert_eq!(effective_mr(8, 1), 1);
        assert_eq!(effective_mr(4, 100), 4);
    }

    #[test]
    fn par_map_is_partition_invariant() {
        let mut a = vec![0f32; 40_000];
        let mut b = vec![0f32; 40_000];
        par_map(&mut a, &pool(1), 1, |off, c| {
            for (i, o) in c.iter_mut().enumerate() {
                *o = ((off + i) as f32).sin();
            }
        });
        par_map(&mut b, &pool(7), 1, |off, c| {
            for (i, o) in c.iter_mut().enumerate() {
                *o = ((off + i) as f32).sin();
            }
        });
        assert_eq!(a, b);
    }

    #[test]
    fn select_picks_by_mask() {
        let p = [1.0f32, 0.0, 2.0, 0.0];
        let t = [10f32, 20.0, 30.0, 40.0];
        let f = [-1f32, -2.0, -3.0, -4.0];
        let mut out = [0f32; 4];
        select(&p, &t, &f, &mut out, &pool(2));
        assert_eq!(out, [10.0, -2.0, 30.0, -4.0]);
    }

    #[test]
    fn spmm_matches_ordered_naive_bitwise_across_threads() {
        // 37x29 sparse (nnz = 215) against a [29, 1301] dense block —
        // 215 x 1301 MACs crosses PAR_MIN_MACS, with ragged rows.
        let (n_rows, n_cols, m) = (37usize, 29usize, 1301usize);
        let mut row_ptr = vec![0u32];
        let mut col_idx = Vec::new();
        for r in 0..n_rows {
            for c in 0..n_cols {
                if (r * 7 + c * 13) % 5 == 0 {
                    col_idx.push(c as u32);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        assert!(col_idx.len() * m >= PAR_MIN_MACS, "pattern must reach the parallel branch");
        let vals: Vec<f32> =
            (0..col_idx.len()).map(|i| ((i * 31 % 17) as f32 - 8.0) * 0.21).collect();
        let x: Vec<f32> =
            (0..n_cols * m).map(|i| ((i * 43 % 23) as f32 - 11.0) * 0.09).collect();
        // naive with the same per-row ascending accumulation order
        let mut naive = vec![0f32; n_rows * m];
        for r in 0..n_rows {
            for e in row_ptr[r] as usize..row_ptr[r + 1] as usize {
                let (v, c) = (vals[e], col_idx[e] as usize);
                for j in 0..m {
                    naive[r * m + j] += v * x[c * m + j];
                }
            }
        }
        for threads in [1, 2, 8] {
            let mut out = vec![0f32; n_rows * m];
            spmm_csr(&vals, &x, &row_ptr, &col_idx, None, m, &mut out, &pool(threads));
            assert_eq!(out, naive, "threads={threads}");
        }
        // a permuted value stream reads through the perm
        let perm: Vec<u32> = (0..vals.len() as u32).rev().collect();
        let rvals: Vec<f32> = vals.iter().rev().copied().collect();
        let mut out = vec![0f32; n_rows * m];
        spmm_csr(&rvals, &x, &row_ptr, &col_idx, Some(&perm), m, &mut out, &pool(3));
        assert_eq!(out, naive);
    }

    #[test]
    fn spmm_has_no_zero_skip() {
        // stored zero meeting NaN must poison, same as the dense kernel
        let row_ptr = [0u32, 1];
        let col_idx = [0u32];
        let vals = [0.0f32];
        let x = [f32::NAN, 1.0];
        let mut out = [0f32; 2];
        spmm_csr(&vals, &x, &row_ptr, &col_idx, None, 2, &mut out, &pool(1));
        assert!(out[0].is_nan(), "0*NaN must be NaN, got {}", out[0]);
        assert_eq!(out[1], 0.0);
    }

    #[test]
    fn reduce_sum_and_mean_agree_up_to_count() {
        // [2, 3] reduced over axis 1
        let x = [1f32, 2.0, 3.0, 10.0, 20.0, 30.0];
        let geom = ReduceGeom {
            kept: vec![GatherAxis { out_stride: 1, out_extent: 2, src_stride: 3 }],
            red: vec![(3, 1)],
            count: 3,
            contiguous: true,
        };
        let mut sum = [0f32; 2];
        let mut mean = [0f32; 2];
        reduce(&x, &geom, false, &mut sum, &pool(1));
        reduce(&x, &geom, true, &mut mean, &pool(1));
        assert_eq!(sum, [6.0, 60.0]);
        assert_eq!(mean, [2.0, 20.0]);
    }
}
