//! Pure-rust CPU backend: a planned, arena-backed, multi-threaded
//! executor for the graph IR.
//!
//! `NativeExecutable::new` runs the planner (`plan`) once at compile
//! time: topological schedule, liveness-based buffer-arena slot
//! assignment (in-place elementwise ops over dying inputs, aliasing
//! reshapes, recycled dot-permute scratch) and all shape math. `run`
//! then executes precomputed steps over persistent slot buffers — the
//! steady state allocates nothing but the returned output tensor.
//! Kernels (`kernels`) are cache-tiled and partition work across scoped
//! worker threads with a partition-invariant accumulation order, so any
//! `CompileOptions::threads` value produces bitwise-identical results.
//!
//! `run_reference` (`reference`) keeps the seed's per-node interpret
//! loop — same kernels, serial, one fresh allocation per node — as the
//! differential baseline for the arena-aliasing property suite and the
//! "seed interpreter" rows of `benches/native_exec.rs`.
//!
//! Parallel kernels dispatch over a **persistent per-executable worker
//! pool** (`pool`): the `threads - 1` workers are spawned once — lazily,
//! on the executable's first above-threshold op — and parked between
//! steps, so parallel ops pay a condvar wake instead of the old per-op
//! `std::thread::scope` spawn/join (~20–50 µs each), and executables
//! that never fan out never pin OS threads (the ROADMAP worker-pool
//! item).

pub mod autotune;
pub mod kernels;
pub mod plan;
pub mod pool;
mod reference;

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::graph::Graph;
use super::passes::ArenaStats;
use super::{Backend, BackendExec, Buffer, CompileOptions, HostTensor};
use crate::obs;
pub use autotune::TunePolicy;
use kernels::TileConfig;
use plan::{ExecPlan, InPlace, Kernel, Step, ValueRef};
use pool::WorkerPool;

/// The default engine: executes planned graphs on the host CPU.
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native-cpu"
    }

    fn compile_graph(
        &self,
        graph: &Graph,
        opts: &CompileOptions,
    ) -> Result<Arc<dyn BackendExec>> {
        let policy = match (opts.tile, opts.autotune) {
            (Some(cfg), _) => TunePolicy::Fixed(cfg),
            (None, true) => TunePolicy::Auto,
            (None, false) => TunePolicy::Off,
        };
        Ok(Arc::new(NativeExecutable::with_tuning(
            graph.clone(),
            opts.resolved_threads(),
            opts.verify,
            opts.profile,
            policy,
        )?))
    }

    fn compile_hlo_text_file(&self, path: &std::path::Path) -> Result<Arc<dyn BackendExec>> {
        bail!(
            "{}: HLO-text artifacts require the PJRT backend — rebuild with \
             --features xla-pjrt and LRDX_BACKEND=xla (native models are built \
             via runtime::netbuilder instead)",
            path.display()
        )
    }

    fn upload(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        if dims.iter().product::<usize>() != data.len() {
            bail!("upload: {} elements for shape {dims:?}", data.len());
        }
        Ok(Buffer::F32(Arc::new(HostTensor::new(dims.to_vec(), data.to_vec()))))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        if dims.iter().product::<usize>() != data.len() {
            bail!("upload_i32: {} elements for shape {dims:?}", data.len());
        }
        Ok(Buffer::I32 { dims: dims.to_vec(), data: Arc::new(data.to_vec()) })
    }
}

/// A compiled graph: the execution plan plus its persistent arena.
///
/// The arena lives behind a `Mutex`: one `run` at a time per executable
/// (concurrent serving replicas each compile their own — the
/// coordinator's per-worker-engine design).
pub struct NativeExecutable {
    graph: Graph,
    plan: ExecPlan,
    /// Persistent worker pool: `threads - 1` parked OS threads, spawned
    /// lazily on the first parallel dispatch and reused by every
    /// above-threshold kernel of every run.
    pool: WorkerPool,
    arena: Mutex<Vec<Vec<f32>>>,
    /// Tile config per step (only packed `Dot` steps read theirs) —
    /// resolved once at compile from the [`TunePolicy`]. Performance-only
    /// state: every config yields bitwise-identical output, so `tiles`
    /// never participates in identity or cache-key comparisons.
    tiles: Vec<TileConfig>,
    /// Per-step timing state, present only when compiled with
    /// `CompileOptions::profile`. `None` keeps the hot path structurally
    /// identical to an unprofiled build (one branch per run).
    profile: Option<Mutex<obs::ProfileState>>,
}

impl NativeExecutable {
    /// Plan `graph` for execution with `threads` lanes (`>= 1`; pass 1
    /// for the fully serial reference configuration). The arena and the
    /// worker pool are allocated here, never during `run`. Audits the
    /// plan in debug builds; use [`NativeExecutable::with_verify`] to
    /// control auditing explicitly.
    pub fn new(graph: Graph, threads: usize) -> Result<NativeExecutable> {
        NativeExecutable::with_verify(graph, threads, cfg!(debug_assertions))
    }

    /// `new` with the plan audit explicitly on or off. With `verify`
    /// set, `runtime::verify::plan::audit_plan` replays the arena's
    /// liveness story and the kernels' chunk partitions before the plan
    /// can ever execute; a violation aborts compilation with a typed
    /// [`super::verify::VerifyError`] (`pass == "plan"`).
    pub fn with_verify(graph: Graph, threads: usize, verify: bool) -> Result<NativeExecutable> {
        NativeExecutable::with_options(graph, threads, verify, false)
    }

    /// `with_verify` plus per-step profiling (`CompileOptions::profile`):
    /// the executable accumulates an [`obs::ExecProfile`] across runs,
    /// readable via `BackendExec::profile`. Profiling wraps the unchanged
    /// kernel calls with clock reads — it cannot change partitioning or
    /// accumulation order, so outputs stay bitwise identical (regression:
    /// `tests/obs_profile.rs`).
    pub fn with_options(
        graph: Graph,
        threads: usize,
        verify: bool,
        profile: bool,
    ) -> Result<NativeExecutable> {
        NativeExecutable::with_tuning(graph, threads, verify, profile, TunePolicy::Off)
    }

    /// `with_options` plus an explicit tile policy for the packed GEMM
    /// path. [`TunePolicy::Off`] (the library default) uses
    /// `TileConfig::DEFAULT` everywhere; [`TunePolicy::Auto`] times the
    /// candidate set per shape bucket (cached process-wide, so repeat
    /// compiles of a bucket are free); [`TunePolicy::Fixed`] pins one
    /// config. The policy cannot change output bits — only throughput —
    /// which is why it lives outside `CompileOptions::cache_key`.
    pub fn with_tuning(
        graph: Graph,
        threads: usize,
        verify: bool,
        profile: bool,
        policy: TunePolicy,
    ) -> Result<NativeExecutable> {
        let t0 = Instant::now();
        let plan = plan::build_plan(&graph)?;
        if obs::enabled() {
            obs::event_from(&format!("plan:{}", graph.name), "compile", t0, t0.elapsed());
        }
        let threads = threads.max(1);
        if verify {
            let t0 = Instant::now();
            let violations = super::verify::audit_plan(&graph, &plan, threads);
            if obs::enabled() {
                obs::event_from(&format!("audit-plan:{}", graph.name), "verify", t0, t0.elapsed());
            }
            if !violations.is_empty() {
                return Err(
                    super::verify::VerifyError::new(graph.name.clone(), "plan", violations)
                        .into(),
                );
            }
        }
        let t0 = Instant::now();
        let arena: Vec<Vec<f32>> = plan.slot_caps.iter().map(|&c| vec![0f32; c]).collect();
        if obs::enabled() {
            obs::event_from(&format!("arena:{}", graph.name), "compile", t0, t0.elapsed());
        }
        // Resolve each step's tile once, at compile. Auto-tuning only
        // ever times shapes that actually route through the packed path.
        let t0 = Instant::now();
        let mut tuned = 0usize;
        let tiles: Vec<TileConfig> = plan
            .steps
            .iter()
            .map(|s| match (&s.kernel, policy) {
                (_, TunePolicy::Fixed(cfg)) => cfg,
                (Kernel::Dot { n, k, pack: Some(_), .. }, TunePolicy::Auto) if *n > 0 => {
                    tuned += 1;
                    autotune::choose(s.out_len / n, *n, *k)
                }
                _ => TileConfig::DEFAULT,
            })
            .collect();
        if tuned > 0 && obs::enabled() {
            obs::event_from(&format!("autotune:{}", graph.name), "compile", t0, t0.elapsed());
        }
        let profile = profile.then(|| Mutex::new(obs::ProfileState::new(plan.steps.len())));
        Ok(NativeExecutable {
            graph,
            plan,
            pool: WorkerPool::new(threads),
            arena: Mutex::new(arena),
            tiles,
            profile,
        })
    }

    /// The plan's buffer-arena accounting.
    pub fn arena_stats(&self) -> &ArenaStats {
        &self.plan.stats
    }

    /// Snapshot of the per-step profile accumulated since compile —
    /// `None` unless built with `with_options(.., profile = true)`.
    pub fn exec_profile(&self) -> Option<obs::ExecProfile> {
        let state = self.profile.as_ref()?;
        let st = state.lock().ok()?;
        Some(obs::ExecProfile {
            graph: self.graph.name.clone(),
            meta: self.plan.meta.clone(),
            runs: st.runs,
            run_secs: st.run_secs,
            run_spans: st.run_spans.clone(),
            steps: st.agg.clone(),
            samples: st.samples.clone(),
            chunks: st.chunks.clone(),
        })
    }

    /// Core evaluation over `Arc`'d tensors: parameters are refcount
    /// bumps, not copies, and every intermediate writes into its planned
    /// arena slot — the per-call cost is the compute plus one output
    /// allocation.
    pub fn run(&self, args: &[Arc<HostTensor>]) -> Result<Arc<HostTensor>> {
        let g = &self.graph;
        if args.len() != g.n_params {
            bail!("{}: {} args, expected {}", g.name, args.len(), g.n_params);
        }
        for p in &self.plan.params {
            let a = &args[p.index];
            if a.dims != p.dims {
                bail!(
                    "{}: parameter {} ({}) got {:?}, expects {:?}",
                    g.name,
                    p.index,
                    p.name,
                    a.dims,
                    p.dims
                );
            }
        }
        let mut guard = self
            .arena
            .lock()
            .map_err(|_| anyhow!("{}: executor arena poisoned", g.name))?;
        let bufs: &mut [Vec<f32>] = &mut guard[..];
        match &self.profile {
            None => {
                for (step, tile) in self.plan.steps.iter().zip(&self.tiles) {
                    self.exec_step(step, *tile, args, bufs);
                }
            }
            Some(state) => {
                // Timed variant: same steps, same order, same kernels —
                // only clock reads around each call. The pool tags chunk
                // dispatches into lock-free per-chunk slots; everything
                // is folded into the shared state under ONE lock, here,
                // after the loop.
                self.pool.profile_begin();
                let run_t0 = Instant::now();
                let run_ts = obs::now_us();
                let mut samples = Vec::with_capacity(self.plan.steps.len());
                for (i, step) in self.plan.steps.iter().enumerate() {
                    self.pool.profile_set_step(i);
                    let ts = obs::now_us();
                    let t0 = Instant::now();
                    self.exec_step(step, self.tiles[i], args, bufs);
                    samples.push(obs::StepSample {
                        step: i,
                        ts_us: ts,
                        dur_us: t0.elapsed().as_secs_f64() * 1e6,
                    });
                }
                let dur = run_t0.elapsed().as_secs_f64();
                let chunks = self.pool.profile_end();
                let mut st = state
                    .lock()
                    .map_err(|_| anyhow!("{}: profile state poisoned", g.name))?;
                st.record_run(run_ts, dur, samples, chunks);
            }
        }
        Ok(match self.plan.root {
            ValueRef::Arg(i) => {
                let a = &args[i];
                if a.dims == self.plan.root_dims {
                    Arc::clone(a)
                } else {
                    // root is a reshape-alias of an argument
                    Arc::new(HostTensor::new(self.plan.root_dims.clone(), a.data.clone()))
                }
            }
            ValueRef::Slot(s) => {
                let n = kernels::numel(&self.plan.root_dims);
                Arc::new(HostTensor::new(
                    self.plan.root_dims.clone(),
                    bufs[s][..n].to_vec(),
                ))
            }
        })
    }

    fn exec_step(
        &self,
        step: &Step,
        tile: TileConfig,
        args: &[Arc<HostTensor>],
        bufs: &mut [Vec<f32>],
    ) {
        let t = &self.pool;
        // Dot/spmm operand permutes gather into their scratch slots first
        // (planner guarantees scratch ≠ inputs ≠ output).
        let preps: [Option<(&plan::DotPrep, usize)>; 2] = match &step.kernel {
            Kernel::Dot { lhs_prep, rhs_prep, .. } => {
                [lhs_prep.as_ref().map(|p| (p, 0)), rhs_prep.as_ref().map(|p| (p, 1))]
            }
            Kernel::Spmm { rhs_prep, .. } => [rhs_prep.as_ref().map(|p| (p, 1)), None],
            _ => [None, None],
        };
        for (p, which) in preps.into_iter().flatten() {
            let (vin, len) = step.ins[which];
            let mut scratch = std::mem::take(&mut bufs[p.slot]);
            kernels::gather(resolve(vin, len, args, bufs), &p.axes, &mut scratch[..p.len], t);
            bufs[p.slot] = scratch;
        }
        // The output slot is taken out of the arena wholesale, so input
        // reads borrow `bufs` freely; in-place steps find their dying
        // input already sitting in `out`.
        let mut out_buf = std::mem::take(&mut bufs[step.out]);
        let out = &mut out_buf[..step.out_len];
        let ins = &step.ins;
        match &step.kernel {
            Kernel::ConstFill { value } => kernels::fill(out, *value),
            Kernel::Fill => {
                kernels::fill(out, resolve(ins[0].0, 1, args, bufs)[0]);
            }
            Kernel::Gather { axes } => {
                kernels::gather(resolve(ins[0].0, ins[0].1, args, bufs), axes, out, t);
            }
            Kernel::Concat { outer, inner, total, mids } => {
                let mut offset = 0usize;
                for (&(v, len), &mid) in ins.iter().zip(mids.iter()) {
                    let x = resolve(v, len, args, bufs);
                    kernels::concat_part(x, *outer, mid, *inner, *total, offset, out);
                    offset += mid;
                }
            }
            Kernel::Slice { outer, mid_in, inner, start, stride, mid_out } => {
                let x = resolve(ins[0].0, ins[0].1, args, bufs);
                kernels::slice(x, *outer, *mid_in, *inner, *start, *stride, *mid_out, out);
            }
            Kernel::Dot { n, k, lhs_prep, rhs_prep, pack } => {
                // Pack scratch comes out of the arena first (the planner
                // guarantees the pack slots alias neither inputs, preps,
                // nor output — `verify::plan` audits it), so the operand
                // reads below can borrow `bufs` freely.
                let mut packs = pack.map(|pb| {
                    (std::mem::take(&mut bufs[pb.a_slot]), std::mem::take(&mut bufs[pb.b_slot]))
                });
                let a = match lhs_prep {
                    Some(p) => &bufs[p.slot][..p.len],
                    None => resolve(ins[0].0, ins[0].1, args, bufs),
                };
                let b = match rhs_prep {
                    Some(p) => &bufs[p.slot][..p.len],
                    None => resolve(ins[1].0, ins[1].1, args, bufs),
                };
                match (&mut packs, pack) {
                    (Some((apk, bpk)), Some(pb)) => kernels::dot_packed(
                        a,
                        b,
                        *n,
                        *k,
                        out,
                        t,
                        tile,
                        &mut apk[..pb.a_len],
                        &mut bpk[..pb.b_len],
                    ),
                    _ => kernels::dot_scalar(a, b, *n, *k, out, t),
                }
                if let (Some((apk, bpk)), Some(pb)) = (packs, pack) {
                    bufs[pb.a_slot] = apk;
                    bufs[pb.b_slot] = bpk;
                }
            }
            Kernel::Spmm { m, row_ptr, col_idx, val_perm, rhs_prep } => {
                let vals = resolve(ins[0].0, ins[0].1, args, bufs);
                let x = match rhs_prep {
                    Some(p) => &bufs[p.slot][..p.len],
                    None => resolve(ins[1].0, ins[1].1, args, bufs),
                };
                kernels::spmm_csr(
                    vals,
                    x,
                    row_ptr,
                    col_idx,
                    val_perm.as_ref().map(|p| &p[..]),
                    *m,
                    out,
                    t,
                );
            }
            Kernel::Bin { op, in_place } => {
                let op = *op;
                match in_place {
                    InPlace::No => kernels::binary(
                        resolve(ins[0].0, ins[0].1, args, bufs),
                        resolve(ins[1].0, ins[1].1, args, bufs),
                        out,
                        t,
                        |a, b| op.apply(a, b),
                    ),
                    // `out` holds the lhs: cur is the lhs operand
                    InPlace::Lhs => kernels::binary_inplace(
                        out,
                        resolve(ins[0].0, ins[0].1, args, bufs),
                        t,
                        |cur, other| op.apply(cur, other),
                    ),
                    // `out` holds the rhs: keep operand order exact
                    InPlace::Rhs => kernels::binary_inplace(
                        out,
                        resolve(ins[0].0, ins[0].1, args, bufs),
                        t,
                        |cur, other| op.apply(other, cur),
                    ),
                    InPlace::Both => {
                        kernels::binary_inplace_self(out, t, |a, b| op.apply(a, b))
                    }
                }
            }
            Kernel::BinScalar { op, swap, in_place } => {
                let op = *op;
                if *in_place {
                    let s = resolve(ins[0].0, 1, args, bufs)[0];
                    kernels::binary_scalar_inplace(out, s, *swap, t, |a, b| op.apply(a, b));
                } else {
                    let x = resolve(ins[0].0, ins[0].1, args, bufs);
                    let s = resolve(ins[1].0, 1, args, bufs)[0];
                    kernels::binary_scalar(x, s, *swap, out, t, |a, b| op.apply(a, b));
                }
            }
            Kernel::Unary { op, in_place } => {
                let op = *op;
                if *in_place {
                    kernels::unary_inplace(out, t, |x| op.apply(x));
                } else {
                    kernels::unary(
                        resolve(ins[0].0, ins[0].1, args, bufs),
                        out,
                        t,
                        |x| op.apply(x),
                    );
                }
            }
            Kernel::Select => {
                kernels::select(
                    resolve(ins[0].0, ins[0].1, args, bufs),
                    resolve(ins[1].0, ins[1].1, args, bufs),
                    resolve(ins[2].0, ins[2].1, args, bufs),
                    out,
                    t,
                );
            }
            Kernel::Reduce { geom, mean } => {
                kernels::reduce(
                    resolve(ins[0].0, ins[0].1, args, bufs),
                    geom,
                    *mean,
                    out,
                    t,
                );
            }
        }
        bufs[step.out] = out_buf;
    }

    /// Convenience for tests: borrowed host tensors in, owned tensor out.
    pub fn execute_hosts(&self, args: &[&HostTensor]) -> Result<HostTensor> {
        let arcs: Vec<Arc<HostTensor>> =
            args.iter().map(|t| Arc::new((*t).clone())).collect();
        let out = self.run(&arcs)?;
        Ok(Arc::try_unwrap(out).unwrap_or_else(|a| (*a).clone()))
    }
}

fn resolve<'a>(
    v: ValueRef,
    len: usize,
    args: &'a [Arc<HostTensor>],
    bufs: &'a [Vec<f32>],
) -> &'a [f32] {
    match v {
        ValueRef::Arg(i) => &args[i].data[..len],
        ValueRef::Slot(s) => &bufs[s][..len],
    }
}

impl BackendExec for NativeExecutable {
    fn execute(&self, args: &[&Buffer]) -> Result<Vec<Buffer>> {
        let arcs: Vec<Arc<HostTensor>> = args
            .iter()
            .map(|b| match b {
                Buffer::F32(t) => Ok(Arc::clone(t)),
                _ => Err(anyhow!("native backend takes f32 buffers")),
            })
            .collect::<Result<_>>()?;
        Ok(vec![Buffer::F32(self.run(&arcs)?)])
    }

    fn arena(&self) -> Option<ArenaStats> {
        Some(self.plan.stats.clone())
    }

    fn profile(&self) -> Option<obs::ExecProfile> {
        self.exec_profile()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::graph::{GraphBuilder, Node, NodeId, OpKind};
    use crate::util::check::assert_allclose;

    fn run1(g: &Graph, args: &[HostTensor]) -> HostTensor {
        let exe = NativeExecutable::new(g.clone(), 1).unwrap();
        let refs: Vec<&HostTensor> = args.iter().collect();
        exe.execute_hosts(&refs).unwrap()
    }

    /// Planned (at 1 and 3 threads) and reference execution agree
    /// bitwise — run every fixture through all three.
    fn run_all_ways(g: &Graph, args: &[HostTensor]) -> HostTensor {
        let arcs: Vec<Arc<HostTensor>> =
            args.iter().map(|t| Arc::new(t.clone())).collect();
        let exe1 = NativeExecutable::new(g.clone(), 1).unwrap();
        let exe3 = NativeExecutable::new(g.clone(), 3).unwrap();
        let planned = exe1.run(&arcs).unwrap();
        let threaded = exe3.run(&arcs).unwrap();
        let reference = exe1.run_reference(&arcs).unwrap();
        assert_eq!(planned.data, reference.data, "planned vs reference");
        assert_eq!(planned.data, threaded.data, "1 vs 3 threads");
        assert_eq!(planned.dims, reference.dims);
        (*planned).clone()
    }

    #[test]
    fn add_and_sqrt() {
        let b = GraphBuilder::new("t");
        let p = b.parameter(0, &[2, 2], "x").unwrap();
        let s = (p.clone() + p).unwrap().sqrt().unwrap();
        let g = b.build(&s).unwrap();
        let x = HostTensor::new(vec![2, 2], vec![2.0, 8.0, 18.0, 32.0]);
        let out = run_all_ways(&g, &[x]);
        assert_eq!(out.data, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn dot_general_matches_manual_matmul() {
        // [2,3] x [3,2] contracting the 3-dim
        let b = GraphBuilder::new("mm");
        let x = b.parameter(0, &[2, 3], "x").unwrap();
        let y = b.parameter(1, &[3, 2], "y").unwrap();
        let d = x.dot_general(&y, &[1], &[0]).unwrap();
        let g = b.build(&d).unwrap();
        let out = run_all_ways(
            &g,
            &[
                HostTensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]),
                HostTensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]),
            ],
        );
        assert_eq!(out.dims, vec![2, 2]);
        assert_eq!(out.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn dot_general_with_high_rank_rhs() {
        // [S=2, C=2] x [N=1, C=2, H=2, W=2] contracting C -> [2, 1, 2, 2]
        let b = GraphBuilder::new("conv1x1");
        let w = b.parameter(0, &[2, 2], "w").unwrap();
        let x = b.parameter(1, &[1, 2, 2, 2], "x").unwrap();
        let d = w.dot_general(&x, &[1], &[1]).unwrap();
        let g = b.build(&d).unwrap();
        let xs = HostTensor::new(vec![1, 2, 2, 2], (1..=8).map(|v| v as f32).collect());
        let ws = HostTensor::new(vec![2, 2], vec![1., 0., 1., 2.]);
        let out = run_all_ways(&g, &[ws, xs]);
        assert_eq!(out.dims, vec![2, 1, 2, 2]);
        // channel out 0 = in ch 0; channel out 1 = ch0 + 2*ch1
        assert_eq!(out.data[..4], [1., 2., 3., 4.]);
        assert_eq!(out.data[4..], [1. + 10., 2. + 12., 3. + 14., 4. + 16.]);
    }

    #[test]
    fn dot_general_zero_weight_times_nan_is_nan() {
        // THE seed bug: the `av == 0.0` skip turned 0 × NaN into 0. A
        // poisoned activation hitting a zero weight row must stay NaN.
        let b = GraphBuilder::new("ieee");
        let x = b.parameter(0, &[1, 2], "x").unwrap();
        let w = b.parameter(1, &[2, 2], "w").unwrap();
        let d = x.dot_general(&w, &[1], &[0]).unwrap();
        let g = b.build(&d).unwrap();
        let x0 = HostTensor::new(vec![1, 2], vec![0.0, 0.0]);
        let w0 = HostTensor::new(vec![2, 2], vec![f32::NAN, 1.0, f32::INFINITY, 2.0]);
        let out = run1(&g, &[x0, w0]);
        assert!(out.data[0].is_nan(), "0*NaN + 0*Inf must be NaN, got {}", out.data[0]);
        assert_eq!(out.data[1], 0.0);
    }

    #[test]
    fn slice_concat_transpose_roundtrip() {
        let b = GraphBuilder::new("sct");
        let x = b.parameter(0, &[2, 4], "x").unwrap();
        let lo = x.slice_in_dim1(0, 2, 1).unwrap();
        let hi = x.slice_in_dim1(2, 4, 1).unwrap();
        let back = lo.concat_in_dim(&[hi], 1).unwrap();
        let g = b.build(&back).unwrap();
        let x0 = HostTensor::new(vec![2, 4], (0..8).map(|v| v as f32).collect());
        assert_eq!(run_all_ways(&g, &[x0.clone()]).data, x0.data);

        let b2 = GraphBuilder::new("tr");
        let y = b2.parameter(0, &[2, 3], "y").unwrap();
        let t = y.transpose(&[1, 0]).unwrap();
        let g2 = b2.build(&t).unwrap();
        let y0 = HostTensor::new(vec![2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(run_all_ways(&g2, &[y0]).data, vec![0., 3., 1., 4., 2., 5.]);
    }

    #[test]
    fn strided_slice_takes_every_other() {
        let b = GraphBuilder::new("st");
        let x = b.parameter(0, &[1, 6], "x").unwrap();
        let s = x.slice_in_dim(1, 6, 2, 1).unwrap();
        let g = b.build(&s).unwrap();
        let x0 = HostTensor::new(vec![1, 6], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(run_all_ways(&g, &[x0]).data, vec![1., 3., 5.]);
    }

    #[test]
    fn reduce_mean_over_spatial() {
        let b = GraphBuilder::new("rm");
        let x = b.parameter(0, &[1, 2, 2, 2], "x").unwrap();
        let m = x.reduce_mean(&[2, 3], false).unwrap();
        let g = b.build(&m).unwrap();
        let x0 = HostTensor::new(vec![1, 2, 2, 2], (1..=8).map(|v| v as f32).collect());
        let out = run_all_ways(&g, &[x0]);
        assert_eq!(out.dims, vec![1, 2]);
        assert_allclose(&out.data, &[2.5, 6.5], 1e-6, 1e-6);
    }

    #[test]
    fn reduce_mean_over_interior_axis() {
        // exercises the non-contiguous (odometer) reduce path
        let b = GraphBuilder::new("rmi");
        let x = b.parameter(0, &[2, 3, 2], "x").unwrap();
        let m = x.reduce_mean(&[1], false).unwrap();
        let g = b.build(&m).unwrap();
        let x0 = HostTensor::new(vec![2, 3, 2], (0..12).map(|v| v as f32).collect());
        let out = run_all_ways(&g, &[x0]);
        assert_eq!(out.dims, vec![2, 2]);
        assert_allclose(&out.data, &[2.0, 3.0, 8.0, 9.0], 1e-6, 1e-6);
    }

    #[test]
    fn reduce_mean_over_zero_size_axis_is_a_shape_error() {
        // 0/0 must be a compile-time error, not Inf/NaN. GraphBuilder
        // rejects it too; hand-build the node list to hit the planner.
        let g = Graph {
            name: "zrm".into(),
            nodes: vec![
                Node {
                    op: OpKind::Parameter { index: 0, name: "x".into() },
                    inputs: vec![],
                    dims: vec![2, 0],
                },
                Node {
                    op: OpKind::ReduceMean { dims: vec![1] },
                    inputs: vec![NodeId(0)],
                    dims: vec![2],
                },
            ],
            n_params: 1,
            root: NodeId(1),
        };
        let err = NativeExecutable::new(g, 1).err().expect("0/0 mean must not compile");
        let msg = format!("{err:#}");
        assert!(msg.contains("zero-size"), "unhelpful error: {msg}");
    }

    #[test]
    fn broadcast_in_dim_per_channel() {
        let b = GraphBuilder::new("bn");
        let x = b.parameter(0, &[1, 2, 1, 2], "x").unwrap();
        let gm = b.parameter(1, &[2], "g").unwrap();
        let gb = gm.broadcast_in_dim(&[1, 2, 1, 2], &[1]).unwrap();
        let y = (x * gb).unwrap();
        let g = b.build(&y).unwrap();
        let out = run_all_ways(
            &g,
            &[
                HostTensor::new(vec![1, 2, 1, 2], vec![1., 2., 3., 4.]),
                HostTensor::new(vec![2], vec![10., 100.]),
            ],
        );
        assert_eq!(out.data, vec![10., 20., 300., 400.]);
    }

    #[test]
    fn scalar_broadcast_max_is_relu() {
        let b = GraphBuilder::new("relu");
        let x = b.parameter(0, &[4], "x").unwrap();
        let zero = b.c0(0.0).unwrap();
        let y = x.max(&zero).unwrap();
        let g = b.build(&y).unwrap();
        let out = run_all_ways(&g, &[HostTensor::new(vec![4], vec![-1., 2., -3., 4.])]);
        assert_eq!(out.data, vec![0., 2., 0., 4.]);
    }

    #[test]
    fn spmm_csr_matches_densified_dot() {
        // 3x4 sparse with pattern {0:(1,3), 1:(), 2:(0,2)} against
        // x [2,4,5], contracting axis 1 -> [3,2,5] (like a 1x1 conv tap)
        let rp = Arc::new(vec![0u32, 2, 2, 4]);
        let ci = Arc::new(vec![1u32, 3, 0, 2]);
        let vals_v = vec![2.0f32, -1.0, 0.5, 3.0];
        let mut rng = crate::util::rng::Rng::new(0x5EED);
        let x_v: Vec<f32> = (0..2 * 4 * 5).map(|_| rng.normal_f32()).collect();

        let b = GraphBuilder::new("spmm");
        let vals = b.parameter(0, &[4], "s").unwrap();
        let x = b.parameter(1, &[2, 4, 5], "x").unwrap();
        let y = vals.spmm_csr(&x, 3, 4, rp.clone(), ci.clone(), 1, None).unwrap();
        let g = b.build(&y).unwrap();
        let out = run_all_ways(
            &g,
            &[
                HostTensor::new(vec![4], vals_v.clone()),
                HostTensor::new(vec![2, 4, 5], x_v.clone()),
            ],
        );
        assert_eq!(out.dims, vec![3, 2, 5]);

        // densify and run the same contraction through dot_general
        let mut dense = vec![0f32; 3 * 4];
        for r in 0..3 {
            for e in rp[r] as usize..rp[r + 1] as usize {
                dense[r * 4 + ci[e] as usize] = vals_v[e];
            }
        }
        let b2 = GraphBuilder::new("dense");
        let w = b2.parameter(0, &[3, 4], "w").unwrap();
        let x2 = b2.parameter(1, &[2, 4, 5], "x").unwrap();
        let d = w.dot_general(&x2, &[1], &[1]).unwrap();
        let g2 = b2.build(&d).unwrap();
        let want = run1(
            &g2,
            &[HostTensor::new(vec![3, 4], dense), HostTensor::new(vec![2, 4, 5], x_v)],
        );
        assert_allclose(&out.data, &want.data, 1e-6, 1e-6);
    }

    #[test]
    fn spmm_csr_randomized_property_suite() {
        // the acceptance pin: planned == reference bitwise, and planned
        // output identical across threads {1, 2, 8}, over randomized
        // shapes / densities / rhs axes.
        let mut rng = crate::util::rng::Rng::new(0xC5A);
        for case in 0..12 {
            let n_rows = 1 + (rng.next_u64() % 40) as usize;
            let n_cols = 1 + (rng.next_u64() % 40) as usize;
            let m_extra = 1 + (rng.next_u64() % 30) as usize;
            let rhs_axis = (case % 2) as usize; // x is rank 2 either way
            let mut row_ptr = vec![0u32];
            let mut col_idx: Vec<u32> = Vec::new();
            for _ in 0..n_rows {
                for c in 0..n_cols {
                    if rng.next_u64() % 5 == 0 {
                        col_idx.push(c as u32);
                    }
                }
                row_ptr.push(col_idx.len() as u32);
            }
            let nnz = col_idx.len();
            let vals_v: Vec<f32> = (0..nnz).map(|_| rng.normal_f32()).collect();
            let xdims = if rhs_axis == 0 {
                vec![n_cols, m_extra]
            } else {
                vec![m_extra, n_cols]
            };
            let x_v: Vec<f32> =
                (0..n_cols * m_extra).map(|_| rng.normal_f32()).collect();

            let b = GraphBuilder::new("prop");
            let vals = b.parameter(0, &[nnz], "s").unwrap();
            let x = b.parameter(1, &xdims, "x").unwrap();
            let y = vals
                .spmm_csr(
                    &x,
                    n_rows,
                    n_cols,
                    Arc::new(row_ptr),
                    Arc::new(col_idx),
                    rhs_axis,
                    None,
                )
                .unwrap();
            let g = b.build(&y).unwrap();
            let args: Vec<Arc<HostTensor>> = vec![
                Arc::new(HostTensor::new(vec![nnz], vals_v)),
                Arc::new(HostTensor::new(xdims, x_v)),
            ];
            let reference =
                NativeExecutable::new(g.clone(), 1).unwrap().run_reference(&args).unwrap();
            for threads in [1usize, 2, 8] {
                let exe = NativeExecutable::new(g.clone(), threads).unwrap();
                let out = exe.run(&args).unwrap();
                assert_eq!(
                    out.data, reference.data,
                    "case {case}: planned@{threads} vs reference"
                );
                assert_eq!(out.dims, reference.dims);
            }
        }
    }

    #[test]
    fn reshape_aliases_and_root_reshape_of_param() {
        let b = GraphBuilder::new("rs");
        let x = b.parameter(0, &[2, 3], "x").unwrap();
        let r = x.reshape(&[3, 2]).unwrap();
        let g = b.build(&r).unwrap();
        let x0 = HostTensor::new(vec![2, 3], (0..6).map(|v| v as f32).collect());
        let out = run_all_ways(&g, &[x0.clone()]);
        assert_eq!(out.dims, vec![3, 2]);
        assert_eq!(out.data, x0.data);
    }

    #[test]
    fn repeated_runs_reuse_the_arena_bitwise() {
        // the same executable run twice must not read stale slot data
        let b = GraphBuilder::new("rep");
        let x = b.parameter(0, &[4, 4], "x").unwrap();
        let y = b.parameter(1, &[4, 4], "y").unwrap();
        let d = x.dot_general(&y, &[1], &[0]).unwrap();
        let s = (d.clone() + d).unwrap().sqrt().unwrap();
        let g = b.build(&s).unwrap();
        let exe = NativeExecutable::new(g, 2).unwrap();
        let mk = |seed: u64| {
            let mut rng = crate::util::rng::Rng::new(seed);
            Arc::new(HostTensor::new(
                vec![4, 4],
                (0..16).map(|_| rng.normal_f32().abs()).collect(),
            ))
        };
        let (a1, b1) = (mk(1), mk(2));
        let first = exe.run(&[a1.clone(), b1.clone()]).unwrap();
        // different inputs in between dirty every slot
        exe.run(&[mk(7), mk(8)]).unwrap();
        let again = exe.run(&[a1, b1]).unwrap();
        assert_eq!(first.data, again.data);
    }

    #[test]
    fn arena_reuses_slots_below_naive_total() {
        // a chain of same-shape elementwise ops must fold into O(1) slots
        let b = GraphBuilder::new("chain");
        let x = b.parameter(0, &[32, 32], "x").unwrap();
        let mut y = x.sqrt().unwrap();
        for _ in 0..8 {
            y = (y.clone() + y).unwrap().sqrt().unwrap();
        }
        let g = b.build(&y).unwrap();
        let exe = NativeExecutable::new(g, 1).unwrap();
        let stats = exe.arena_stats();
        assert!(
            stats.peak_bytes < stats.naive_bytes,
            "arena never reused a slot: {stats:?}"
        );
        assert!(stats.in_place_steps > 0, "elementwise chain never ran in place");
        assert!(stats.slots <= 3, "17 same-shape nodes need at most 3 slots: {stats:?}");
    }

    #[test]
    fn shape_mismatch_at_execute_is_reported() {
        let b = GraphBuilder::new("chk");
        let x = b.parameter(0, &[2, 2], "x").unwrap();
        let g = b.build(&x).unwrap();
        let exe = NativeExecutable::new(g, 1).unwrap();
        let bad = HostTensor::new(vec![4], vec![0.0; 4]);
        assert!(exe.execute_hosts(&[&bad]).is_err());
    }
}
