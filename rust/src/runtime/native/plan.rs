//! Compile-time execution planning for the native backend.
//!
//! `build_plan` turns a (topologically ordered) `Graph` into a flat step
//! list plus a **buffer arena**: every live node's output is assigned a
//! physical slot by a liveness scan — a slot is recycled as soon as the
//! last consumer of its tenant has run, elementwise steps whose input
//! dies at that very step write in place, and `Reshape` never moves data
//! at all (it aliases its input's slot or argument). All shape math —
//! gather strides, contraction M/N/K and operand permutes, reduce
//! geometry — is resolved here, once, so `run` executes precomputed
//! steps with zero per-step shape work and zero steady-state tensor
//! allocation (permuted dot operands get arena *scratch* slots, freed
//! within the step that used them).
//!
//! The planner never consults the thread count: the plan (and therefore
//! every in-place/aliasing decision) is identical for all `threads`
//! values, which is one half of the bitwise-determinism contract; the
//! other half is the kernels' partition-invariant accumulation order.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::super::graph::{Graph, OpKind};
use super::super::passes::ArenaStats;
use super::kernels::{self, GatherAxis, ReduceGeom};
use crate::obs::StepMeta;

/// Where a node's value lives at execution time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ValueRef {
    /// The caller's positional argument (parameters and their aliases).
    Arg(usize),
    /// An arena slot.
    Slot(usize),
}

/// Elementwise binary operator (the only ops eligible for in-place).
/// The executor preserves operand order through every in-place variant,
/// so non-commutative members (`Sub`, `Gt`) are first-class citizens.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Max,
    Gt,
}

impl BinOp {
    #[inline]
    pub fn apply(self, a: f32, b: f32) -> f32 {
        match self {
            BinOp::Add => a + b,
            BinOp::Sub => a - b,
            BinOp::Mul => a * b,
            BinOp::Max => a.max(b),
            BinOp::Gt => (a > b) as u32 as f32,
        }
    }
}

/// Elementwise unary operator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnOp {
    Sqrt,
    Neg,
    Exp,
    Log,
    Recip,
}

impl UnOp {
    #[inline]
    pub fn apply(self, x: f32) -> f32 {
        match self {
            UnOp::Sqrt => x.sqrt(),
            UnOp::Neg => -x,
            UnOp::Exp => x.exp(),
            UnOp::Log => x.ln(),
            UnOp::Recip => 1.0 / x,
        }
    }
}

/// How an elementwise step aliases its output over a dying input slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InPlace {
    No,
    /// Output slot is the lhs input's slot.
    Lhs,
    /// Output slot is the rhs input's slot; the executor swaps the
    /// operand order back, so non-commutative ops (`Sub`, `Gt`) are
    /// eligible too.
    Rhs,
    /// Both inputs were the same dying slot (`x ⊕ x`).
    Both,
}

/// A permuted dot operand: gather `axes` into arena slot `slot` first.
#[derive(Clone, Debug)]
pub struct DotPrep {
    pub slot: usize,
    pub len: usize,
    pub axes: Vec<GatherAxis>,
}

/// Arena-planned packing scratch for a packed `Dot` step: A row-panels
/// land in `a_slot`, B column-panels in `b_slot`. The lengths round the
/// panel counts up to the *widest* candidate tile (`packed_a_len` /
/// `packed_b_len`), so one plan serves every tile config and the tile
/// choice stays plan- and bitwise-irrelevant. Like `DotPrep` scratch,
/// the slots are liveness-tracked: released right after the step's
/// output is allocated, free for any later step to reuse.
#[derive(Clone, Copy, Debug)]
pub struct PackBufs {
    pub a_slot: usize,
    pub a_len: usize,
    pub b_slot: usize,
    pub b_len: usize,
}

/// One executable step with all shape math pre-resolved.
#[derive(Clone, Debug)]
pub enum Kernel {
    /// Write the constant (1 element).
    ConstFill { value: f32 },
    /// Broadcast the scalar input over the output.
    Fill,
    /// transpose / broadcast_in_dim.
    Gather { axes: Vec<GatherAxis> },
    /// Per-input (mid extent, source offset along the concat axis).
    Concat { outer: usize, inner: usize, total: usize, mids: Vec<usize> },
    Slice { outer: usize, mid_in: usize, inner: usize, start: usize, stride: usize, mid_out: usize },
    Dot {
        n: usize,
        k: usize,
        lhs_prep: Option<DotPrep>,
        rhs_prep: Option<DotPrep>,
        /// `Some` routes the step through the packed microkernel; `None`
        /// (small shapes) keeps the scalar row core, scratch-free.
        pack: Option<PackBufs>,
    },
    /// CSR sparse×dense (`SpmmCsr`): the pattern rides in the plan (it
    /// is compile-time structure, uploaded once with the executable, not
    /// re-derived per run); `rhs_prep` permutes the dense operand so the
    /// contracted axis leads, exactly like a dot operand prep.
    Spmm {
        m: usize,
        row_ptr: Arc<Vec<u32>>,
        col_idx: Arc<Vec<u32>>,
        val_perm: Option<Arc<Vec<u32>>>,
        rhs_prep: Option<DotPrep>,
    },
    Bin { op: BinOp, in_place: InPlace },
    /// `f(scalar-broadcast)` variant: `swap` means the scalar is the lhs.
    BinScalar { op: BinOp, swap: bool, in_place: bool },
    Unary { op: UnOp, in_place: bool },
    /// `select(pred, on_true, on_false)` — three same-shape inputs.
    Select,
    /// Sum (`mean == false`) or mean over the reduced subspace.
    Reduce { geom: ReduceGeom, mean: bool },
}

#[derive(Clone, Debug)]
pub struct Step {
    pub kernel: Kernel,
    /// Resolved inputs with their exact element counts (in-place steps
    /// omit the aliased input — it is already in the output slot).
    pub ins: Vec<(ValueRef, usize)>,
    pub out: usize,
    pub out_len: usize,
}

/// Shape of one declared (live) parameter, validated per `run`.
#[derive(Clone, Debug)]
pub struct ParamCheck {
    pub index: usize,
    pub name: String,
    pub dims: Vec<usize>,
}

/// The planned executable: steps + arena layout + root routing.
#[derive(Clone, Debug)]
pub struct ExecPlan {
    pub steps: Vec<Step>,
    /// Capacity (elements) of each arena slot.
    pub slot_caps: Vec<usize>,
    pub params: Vec<ParamCheck>,
    pub root: ValueRef,
    pub root_dims: Vec<usize>,
    pub stats: ArenaStats,
    /// Profiling attribution, one entry per step (same order). Purely
    /// descriptive: the executor and the plan auditor never read it.
    pub meta: Vec<StepMeta>,
}

/// Display name of a kernel kind for profiles and trace exports.
pub fn kernel_name(k: &Kernel) -> &'static str {
    match k {
        Kernel::ConstFill { .. } => "const",
        Kernel::Fill => "fill",
        Kernel::Gather { .. } => "gather",
        Kernel::Concat { .. } => "concat",
        Kernel::Slice { .. } => "slice",
        Kernel::Dot { .. } => "dot",
        Kernel::Spmm { .. } => "spmm",
        Kernel::Bin { .. } => "bin",
        Kernel::BinScalar { .. } => "bin-scalar",
        Kernel::Unary { .. } => "unary",
        Kernel::Select => "select",
        Kernel::Reduce { .. } => "reduce",
    }
}

// ---------------------------------------------------------------------------
// Shared shape-resolution helpers (the reference interpreter reuses these
// so both executors run arithmetically identical kernels)
// ---------------------------------------------------------------------------

/// Gather axes of `transpose(perm)`: out axis i reads in axis perm[i].
pub fn transpose_axes(in_dims: &[usize], out_dims: &[usize], perm: &[usize]) -> Vec<GatherAxis> {
    let in_strides = kernels::strides(in_dims);
    let out_strides = kernels::strides(out_dims);
    perm.iter()
        .enumerate()
        .map(|(axis_out, &axis_in)| GatherAxis {
            out_stride: out_strides[axis_out],
            out_extent: out_dims[axis_out],
            src_stride: in_strides[axis_in],
        })
        .collect()
}

/// Gather axes of `broadcast_in_dim(mapping)`: in axis i feeds out axis
/// mapping[i]; unmapped output axes replicate (no gather entry needed).
pub fn broadcast_axes(in_dims: &[usize], out_dims: &[usize], mapping: &[usize]) -> Vec<GatherAxis> {
    let in_strides = kernels::strides(in_dims);
    let out_strides = kernels::strides(out_dims);
    mapping
        .iter()
        .enumerate()
        .map(|(axis_in, &axis_out)| GatherAxis {
            out_stride: out_strides[axis_out],
            out_extent: out_dims[axis_out],
            src_stride: in_strides[axis_in],
        })
        .collect()
}

/// Resolved contraction: operand permutes (None when already laid out)
/// plus the matmul extents.
pub struct DotShape {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// Permutation bringing lhs to [M, K] row-major, if needed.
    pub lhs_perm: Option<Vec<usize>>,
    /// Permutation bringing rhs to [K, N] row-major, if needed.
    pub rhs_perm: Option<Vec<usize>>,
}

pub fn dot_shape(
    lhs_dims: &[usize],
    rhs_dims: &[usize],
    lhs_contract: &[usize],
    rhs_contract: &[usize],
) -> Result<DotShape> {
    let lhs_free: Vec<usize> =
        (0..lhs_dims.len()).filter(|i| !lhs_contract.contains(i)).collect();
    let rhs_free: Vec<usize> =
        (0..rhs_dims.len()).filter(|i| !rhs_contract.contains(i)).collect();
    let m: usize = lhs_free.iter().map(|&i| lhs_dims[i]).product();
    let n: usize = rhs_free.iter().map(|&i| rhs_dims[i]).product();
    let k: usize = lhs_contract.iter().map(|&i| lhs_dims[i]).product();
    let k2: usize = rhs_contract.iter().map(|&i| rhs_dims[i]).product();
    if k != k2 {
        bail!("dot_general: contracted sizes differ ({k} vs {k2})");
    }
    let mut l_perm: Vec<usize> = lhs_free;
    l_perm.extend_from_slice(lhs_contract);
    let mut r_perm: Vec<usize> = rhs_contract.to_vec();
    r_perm.extend_from_slice(&rhs_free);
    let identity = |p: &[usize]| p.iter().enumerate().all(|(i, &v)| i == v);
    Ok(DotShape {
        m,
        n,
        k,
        lhs_perm: (!identity(&l_perm)).then_some(l_perm),
        rhs_perm: (!identity(&r_perm)).then_some(r_perm),
    })
}

/// (outer, inner, total-mid) of a concat/slice axis split.
pub fn axis_split(dims: &[usize], dim: usize) -> (usize, usize, usize) {
    let outer: usize = dims[..dim].iter().product();
    let inner: usize = dims[dim + 1..].iter().product();
    (outer, inner, dims[dim])
}

/// Reduce geometry; errors on an empty reduce subspace (0/0 mean).
pub fn reduce_geom(in_dims: &[usize], out_dims: &[usize], reduce: &[usize]) -> Result<ReduceGeom> {
    let count: usize = reduce.iter().map(|&r| in_dims[r]).product();
    if count == 0 {
        bail!(
            "reduce over zero-size axes {reduce:?} of shape {in_dims:?} \
             is an empty reduce (0/0 mean)"
        );
    }
    let in_strides = kernels::strides(in_dims);
    let out_strides = kernels::strides(out_dims);
    let kept_axes: Vec<usize> =
        (0..in_dims.len()).filter(|i| !reduce.contains(i)).collect();
    let kept = kept_axes
        .iter()
        .enumerate()
        .map(|(slot, &axis)| GatherAxis {
            out_stride: out_strides[slot],
            out_extent: out_dims[slot],
            src_stride: in_strides[axis],
        })
        .collect();
    let mut sorted = reduce.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    let trailing = sorted.len() == reduce.len()
        && sorted
            .iter()
            .enumerate()
            .all(|(i, &ax)| ax == in_dims.len() - sorted.len() + i);
    let red = reduce.iter().map(|&r| (in_dims[r], in_strides[r])).collect();
    Ok(ReduceGeom { kept, red, count, contiguous: trailing })
}

// ---------------------------------------------------------------------------
// The planner
// ---------------------------------------------------------------------------

struct Arena {
    caps: Vec<usize>,
    /// Outstanding consumptions per slot (sum of remaining uses of every
    /// node aliasing it); 0 once allocated-but-unassigned.
    refs: Vec<usize>,
    free: Vec<usize>,
}

impl Arena {
    /// Best-fit allocate: smallest free slot that already fits, else grow
    /// the largest free slot (cheaper than a fresh allocation), else a
    /// new slot.
    fn alloc(&mut self, need: usize) -> usize {
        let fit = self
            .free
            .iter()
            .enumerate()
            .filter(|(_, &s)| self.caps[s] >= need)
            .min_by_key(|(_, &s)| self.caps[s])
            .map(|(i, _)| i);
        let pos = fit.or_else(|| {
            self.free
                .iter()
                .enumerate()
                .max_by_key(|(_, &s)| self.caps[s])
                .map(|(i, _)| i)
        });
        match pos {
            Some(i) => {
                let s = self.free.swap_remove(i);
                self.caps[s] = self.caps[s].max(need);
                s
            }
            None => {
                self.caps.push(need);
                self.refs.push(0);
                self.caps.len() - 1
            }
        }
    }

    fn release(&mut self, slot: usize) {
        debug_assert_eq!(self.refs[slot], 0);
        self.free.push(slot);
    }
}

pub fn build_plan(g: &Graph) -> Result<ExecPlan> {
    let n = g.nodes.len();
    // Live set: reverse reachability from the root. Dead nodes (unused
    // parameters, orphans) get no step and pin no memory.
    let mut live = vec![false; n];
    let mut stack = vec![g.root.0];
    while let Some(i) = stack.pop() {
        if live[i] {
            continue;
        }
        live[i] = true;
        stack.extend(g.nodes[i].inputs.iter().map(|id| id.0));
    }
    // Remaining consumptions per live node (+1 for the root readout).
    let mut remaining = vec![0usize; n];
    for (i, node) in g.nodes.iter().enumerate() {
        if live[i] {
            for inp in &node.inputs {
                remaining[inp.0] += 1;
            }
        }
    }
    remaining[g.root.0] += 1;

    // Profiling attribution: the nearest parameter site feeding each
    // node, found by a forward scan (parameters tag themselves, every
    // other node inherits the shallowest tag among its inputs, rightmost
    // input winning ties — the weight operand of a contraction sits at
    // depth 1 while the activation chain is deeper, so `conv2.w0`, the
    // `conv2.s` residual tap and a merged sibling each land on their own
    // row in `lrdx profile`). Arg 0 is the network input by netbuilder
    // convention and never originates a tag.
    let mut site_of: Vec<Option<(String, usize)>> = vec![None; n];
    for (i, node) in g.nodes.iter().enumerate() {
        if !live[i] {
            continue;
        }
        let tag = match &node.op {
            OpKind::Parameter { index, name } if *index > 0 => Some((name.clone(), 0usize)),
            OpKind::Parameter { .. } => None,
            _ => node
                .inputs
                .iter()
                .rev()
                .filter_map(|id| site_of[id.0].clone())
                .min_by_key(|&(_, d)| d)
                .map(|(s, d)| (s, d + 1)),
        };
        site_of[i] = tag;
    }

    let mut arena = Arena { caps: Vec::new(), refs: Vec::new(), free: Vec::new() };
    let mut values: Vec<Option<ValueRef>> = vec![None; n];
    let mut steps: Vec<Step> = Vec::new();
    let mut meta: Vec<StepMeta> = Vec::new();
    let mut params: Vec<ParamCheck> = Vec::new();
    let mut naive_bytes = 0usize;
    let mut in_place_steps = 0usize;

    for (i, node) in g.nodes.iter().enumerate() {
        if !live[i] {
            continue;
        }
        let out_len = kernels::numel(&node.dims);
        // Inline helpers (macros, not closures: they must not hold
        // borrows across the arena mutations below).
        macro_rules! in_dims {
            ($slot:expr) => {
                &g.nodes[node.inputs[$slot].0].dims[..]
            };
        }
        macro_rules! in_len {
            ($slot:expr) => {
                kernels::numel(in_dims!($slot))
            };
        }
        macro_rules! val {
            ($slot:expr) => {
                values[node.inputs[$slot].0]
                    .expect("topological order guarantees inputs")
            };
        }

        match &node.op {
            OpKind::Parameter { index, name } => {
                params.push(ParamCheck {
                    index: *index,
                    name: name.clone(),
                    dims: node.dims.clone(),
                });
                values[i] = Some(ValueRef::Arg(*index));
                continue;
            }
            OpKind::Reshape => {
                // Pure alias: same bytes, new dims. The slot (if any)
                // first inherits this node's future uses, then sheds the
                // edge being consumed — never dipping to 0 in between.
                let v = val!(0);
                let id = node.inputs[0].0;
                if let ValueRef::Slot(s) = v {
                    arena.refs[s] += remaining[i];
                    arena.refs[s] -= 1;
                    if arena.refs[s] == 0 {
                        arena.release(s);
                    }
                }
                remaining[id] -= 1;
                values[i] = Some(v);
                naive_bytes += out_len * 4; // the old interpreter copied
                continue;
            }
            _ => {}
        }

        naive_bytes += out_len * 4;

        // In-place candidates: elementwise ops over a dying input slot of
        // the same extent. `dying` means every outstanding use of the
        // slot is an edge into this very node.
        macro_rules! dying_slot {
            ($v:expr, $len:expr) => {{
                match $v {
                    ValueRef::Slot(s)
                        if $len == out_len
                            && arena.refs[s]
                                == node
                                    .inputs
                                    .iter()
                                    .filter(|id| {
                                        values[id.0] == Some(ValueRef::Slot(s))
                                    })
                                    .count() =>
                    {
                        Some(s)
                    }
                    _ => None,
                }
            }};
        }

        let (kernel, ins, out_slot) = match &node.op {
            OpKind::Parameter { .. } | OpKind::Reshape => unreachable!("handled above"),
            OpKind::ConstScalar { value } => {
                (Kernel::ConstFill { value: *value }, vec![], None)
            }
            OpKind::Broadcast => {
                (Kernel::Fill, vec![(val!(0), 1)], None)
            }
            OpKind::BroadcastInDim { mapping } => (
                Kernel::Gather { axes: broadcast_axes(in_dims!(0), &node.dims, mapping) },
                vec![(val!(0), in_len!(0))],
                None,
            ),
            OpKind::Transpose { perm } => (
                Kernel::Gather { axes: transpose_axes(in_dims!(0), &node.dims, perm) },
                vec![(val!(0), in_len!(0))],
                None,
            ),
            OpKind::Concat { dim } => {
                let (outer, inner, total) = axis_split(&node.dims, *dim);
                let mids: Vec<usize> =
                    (0..node.inputs.len()).map(|p| in_dims!(p)[*dim]).collect();
                let ins = (0..node.inputs.len()).map(|p| (val!(p), in_len!(p))).collect();
                (Kernel::Concat { outer, inner, total, mids }, ins, None)
            }
            OpKind::Slice { dim, start, stop: _, stride } => {
                let (outer, inner, _) = axis_split(in_dims!(0), *dim);
                (
                    Kernel::Slice {
                        outer,
                        mid_in: in_dims!(0)[*dim],
                        inner,
                        start: *start,
                        stride: *stride,
                        mid_out: node.dims[*dim],
                    },
                    vec![(val!(0), in_len!(0))],
                    None,
                )
            }
            OpKind::DotGeneral { lhs_contract, rhs_contract } => {
                let shape = dot_shape(in_dims!(0), in_dims!(1), lhs_contract, rhs_contract)?;
                // Scratch for permuted operands: allocated while the
                // inputs are live, released before the output below so a
                // LATER step can reuse them — never this step's output.
                let mut mk_prep = |perm: Option<Vec<usize>>, which: usize| -> Option<DotPrep> {
                    perm.map(|p| {
                        let len = in_len!(which);
                        let pdims: Vec<usize> =
                            p.iter().map(|&ax| in_dims!(which)[ax]).collect();
                        let axes = transpose_axes(in_dims!(which), &pdims, &p);
                        naive_bytes += len * 4;
                        DotPrep { slot: arena.alloc(len), len, axes }
                    })
                };
                let lhs_prep = mk_prep(shape.lhs_perm, 0);
                let rhs_prep = mk_prep(shape.rhs_perm, 1);
                // Packing scratch, only for shapes the executor will
                // actually route through the packed microkernel (the
                // executor and the plan apply the same MAC threshold).
                // Allocated while the inputs are live, released with the
                // operand preps below.
                let pack = (shape.m * shape.n * shape.k >= kernels::PACK_MIN_MACS)
                    .then(|| {
                        let a_len = kernels::packed_a_len(shape.m, shape.k);
                        let b_len = kernels::packed_b_len(shape.n, shape.k);
                        naive_bytes += (a_len + b_len) * 4; // ad-hoc Vecs otherwise
                        PackBufs {
                            a_slot: arena.alloc(a_len),
                            a_len,
                            b_slot: arena.alloc(b_len),
                            b_len,
                        }
                    });
                (
                    Kernel::Dot { n: shape.n, k: shape.k, lhs_prep, rhs_prep, pack },
                    vec![(val!(0), in_len!(0)), (val!(1), in_len!(1))],
                    None,
                )
            }
            OpKind::SpmmCsr { row_ptr, col_idx, rhs_axis, val_perm, .. } => {
                let xd = in_dims!(1);
                let m: usize = xd
                    .iter()
                    .enumerate()
                    .filter(|&(ax, _)| ax != *rhs_axis)
                    .map(|(_, &e)| e)
                    .product();
                let rhs_prep = if *rhs_axis == 0 {
                    None // contracted axis already leads in row-major layout
                } else {
                    let mut p = vec![*rhs_axis];
                    p.extend((0..xd.len()).filter(|ax| ax != rhs_axis));
                    let len = in_len!(1);
                    let pdims: Vec<usize> = p.iter().map(|&ax| xd[ax]).collect();
                    let axes = transpose_axes(xd, &pdims, &p);
                    naive_bytes += len * 4;
                    Some(DotPrep { slot: arena.alloc(len), len, axes })
                };
                (
                    Kernel::Spmm {
                        m,
                        row_ptr: row_ptr.clone(),
                        col_idx: col_idx.clone(),
                        val_perm: val_perm.clone(),
                        rhs_prep,
                    },
                    vec![(val!(0), in_len!(0)), (val!(1), in_len!(1))],
                    None,
                )
            }
            OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Max | OpKind::Gt => {
                let op = match &node.op {
                    OpKind::Add => BinOp::Add,
                    OpKind::Sub => BinOp::Sub,
                    OpKind::Mul => BinOp::Mul,
                    OpKind::Max => BinOp::Max,
                    _ => BinOp::Gt,
                };
                let (ld, rd) = (in_dims!(0), in_dims!(1));
                if ld == rd {
                    let (a, b) = (val!(0), val!(1));
                    if let Some(s) = dying_slot!(a, in_len!(0)) {
                        let ip = if a == b { InPlace::Both } else { InPlace::Lhs };
                        in_place_steps += 1;
                        let ins = if a == b { vec![] } else { vec![(b, in_len!(1))] };
                        (Kernel::Bin { op, in_place: ip }, ins, Some(s))
                    } else if let Some(s) = dying_slot!(b, in_len!(1)) {
                        in_place_steps += 1;
                        (
                            Kernel::Bin { op, in_place: InPlace::Rhs },
                            vec![(a, in_len!(0))],
                            Some(s),
                        )
                    } else {
                        (
                            Kernel::Bin { op, in_place: InPlace::No },
                            vec![(a, in_len!(0)), (b, in_len!(1))],
                            None,
                        )
                    }
                } else {
                    // GraphBuilder rejects this at construction time, but
                    // Graph is a pub type and the planner accepts any graph.
                    if !ld.is_empty() && !rd.is_empty() {
                        bail!("elementwise op on mismatched shapes {ld:?} vs {rd:?}");
                    }
                    let scalar_is_lhs = ld.is_empty();
                    let (sc, tensor, tlen) = if scalar_is_lhs {
                        (val!(0), val!(1), in_len!(1))
                    } else {
                        (val!(1), val!(0), in_len!(0))
                    };
                    // `sc == tensor` (a scalar reshape-aliasing the tensor
                    // slot) must not go in place: the executor would read
                    // the scalar out of the already-taken output buffer.
                    if let Some(s) = dying_slot!(tensor, tlen).filter(|_| sc != tensor) {
                        in_place_steps += 1;
                        (
                            Kernel::BinScalar { op, swap: scalar_is_lhs, in_place: true },
                            vec![(sc, 1)],
                            Some(s),
                        )
                    } else {
                        (
                            Kernel::BinScalar { op, swap: scalar_is_lhs, in_place: false },
                            vec![(tensor, tlen), (sc, 1)],
                            None,
                        )
                    }
                }
            }
            OpKind::Sqrt | OpKind::Neg | OpKind::Exp | OpKind::Log | OpKind::Recip => {
                let op = match &node.op {
                    OpKind::Sqrt => UnOp::Sqrt,
                    OpKind::Neg => UnOp::Neg,
                    OpKind::Exp => UnOp::Exp,
                    OpKind::Log => UnOp::Log,
                    _ => UnOp::Recip,
                };
                let a = val!(0);
                if let Some(s) = dying_slot!(a, in_len!(0)) {
                    in_place_steps += 1;
                    (Kernel::Unary { op, in_place: true }, vec![], Some(s))
                } else {
                    (Kernel::Unary { op, in_place: false }, vec![(a, in_len!(0))], None)
                }
            }
            OpKind::Select => {
                // Not in-place: a 3-operand in-place kernel variant isn't
                // worth its complexity for the few selects a relu backward
                // emits (they are elementwise, so it would be sound).
                let ins: Vec<(ValueRef, usize)> =
                    (0..3).map(|p| (val!(p), in_len!(p))).collect();
                (Kernel::Select, ins, None)
            }
            OpKind::ReduceMean { dims } | OpKind::ReduceSum { dims } => (
                Kernel::Reduce {
                    geom: reduce_geom(in_dims!(0), &node.dims, dims)?,
                    mean: matches!(node.op, OpKind::ReduceMean { .. }),
                },
                vec![(val!(0), in_len!(0))],
                None,
            ),
        };

        // Allocate the output while inputs and dot scratch are still
        // held, so it can alias neither; only then hand the scratch
        // slots back to the free list for LATER steps to reuse.
        let out = match out_slot {
            Some(s) => s, // in-place: slot stays allocated, refs adjusted below
            None => arena.alloc(out_len),
        };
        match &kernel {
            Kernel::Dot { lhs_prep, rhs_prep, pack, .. } => {
                for p in [lhs_prep, rhs_prep].into_iter().flatten() {
                    arena.release(p.slot);
                }
                if let Some(pb) = pack {
                    arena.release(pb.a_slot);
                    arena.release(pb.b_slot);
                }
            }
            Kernel::Spmm { rhs_prep: Some(p), .. } => arena.release(p.slot),
            _ => {}
        }
        // Consume the input edges (for in-place steps this drives the
        // reused slot's refs to 0 without releasing it — we immediately
        // re-assign it to this node's output below).
        for inp in &node.inputs {
            let id = inp.0;
            remaining[id] -= 1;
            if let Some(ValueRef::Slot(s)) = values[id] {
                arena.refs[s] -= 1;
                if arena.refs[s] == 0 && Some(s) != out_slot {
                    arena.release(s);
                }
            }
        }
        arena.refs[out] += remaining[i];
        values[i] = Some(ValueRef::Slot(out));
        // Attribution rides beside the step (never inside it): analytic
        // MACs for the contractions, bytes moved, and the lane-gated
        // dimension the cost model tiles over.
        let (macs, gate) = match &kernel {
            Kernel::Dot { n, k, .. } => (out_len * *k, *n),
            Kernel::Spmm { m, col_idx, .. } => {
                (col_idx.len() * (out_len / (*m).max(1)), 1)
            }
            _ => (0, 0),
        };
        meta.push(StepMeta {
            node: i,
            op: kernel_name(&kernel),
            site: site_of[i]
                .as_ref()
                .map(|(s, _)| s.clone())
                .unwrap_or_else(|| "(activations)".into()),
            macs,
            bytes: (ins.iter().map(|&(_, l)| l).sum::<usize>() + out_len) * 4,
            gate,
        });
        steps.push(Step { kernel, ins, out, out_len });
    }

    let root = values[g.root.0].expect("root is live");
    let peak_bytes = arena.caps.iter().sum::<usize>() * 4;
    let stats = ArenaStats {
        slots: arena.caps.len(),
        peak_bytes,
        naive_bytes,
        in_place_steps,
    };
    Ok(ExecPlan {
        steps,
        slot_caps: arena.caps,
        params,
        root,
        root_dims: g.nodes[g.root.0].dims.clone(),
        stats,
        meta,
    })
}
