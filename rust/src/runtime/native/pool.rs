//! Persistent per-executable worker pool.
//!
//! The PR 3 kernels fanned out with `std::thread::scope` **per
//! above-threshold op**, paying the ~20–50 µs spawn/join for every dot /
//! elementwise step of every forward (and now backward) pass — which caps
//! the threading win on small models (ROADMAP item, quantified by
//! `benches/native_exec.rs`). This pool spawns its `threads - 1` workers
//! once — lazily, at the first dispatch that actually fans out — parks
//! them between jobs and reuses them for every step of every run until
//! the executable drops.
//!
//! Dispatch is chunk-indexed: a job is a borrowed `Fn(usize)` closure plus
//! a chunk count; workers (and the calling thread, which always
//! participates) pull chunk indices from a shared cursor. The *partition*
//! of work into chunks is computed by the kernels exactly as before — from
//! the pool's thread count, never from scheduling — so which worker runs
//! which chunk cannot affect a single bit (the determinism contract of
//! `tests/native_exec.rs`).
//!
//! Safety: `run` type-erases the borrowed closure to a raw pointer so the
//! long-lived workers can call it. The pointer is only dereferenced
//! between the moment `run` publishes the job and the moment `run`
//! returns, and `run` blocks until every chunk has finished (panics in
//! workers are caught, counted and re-thrown on the caller) — the borrow
//! therefore always outlives its uses.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

use crate::obs;

thread_local! {
    /// Which pool lane this thread is: 0 for any caller thread, `1..`
    /// for spawned workers (set once at spawn). Only read while
    /// profiling, to tag chunk events with the lane that ran them.
    static POOL_LANE: Cell<usize> = const { Cell::new(0) };
}

/// Type-erased pointer to the job closure. Only ever dereferenced while
/// the issuing `run` call is blocked waiting for completion.
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (bound on `run`), so calling it from a
// worker thread is sound; the lifetime contract — `run` publishes the
// pointer, blocks on the completion barrier (`pending == 0`), and
// retires the pointer (`job = None`) before returning — guarantees the
// borrowed closure outlives every dereference. Workers only load the
// pointer from the slot while `job.is_some()`, i.e. inside that window.
unsafe impl Send for JobPtr {}

struct Slot {
    job: Option<JobPtr>,
    /// Total chunks of the current job.
    chunks: usize,
    /// Next chunk index to hand out.
    next: usize,
    /// Chunks not yet finished (executed or panicked).
    pending: usize,
    /// Chunks whose closure panicked in a worker.
    panicked: usize,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    /// Workers wait here for a job (or shutdown).
    work: Condvar,
    /// The caller waits here for `pending == 0`.
    done: Condvar,
}

/// A fixed-size pool of parked worker threads. `threads == 1` (or 0)
/// never spawns and `run` executes inline — the serial reference
/// configuration costs exactly what it did before the pool existed.
/// Workers are spawned **lazily**, on the first dispatch that actually
/// fans out: executables whose ops all stay under the parallel
/// thresholds (small rank-search layers, of which `EngineLayerTimer`
/// caches hundreds) never pin OS threads.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: std::sync::Mutex<Vec<JoinHandle<()>>>,
    spawned: std::sync::atomic::AtomicBool,
    threads: usize,
    /// Chunk-event tagging, driven by the profiled executor between
    /// `profile_begin`/`profile_end`. When off (always, unless the owning
    /// executable was compiled with `CompileOptions::profile`) the only
    /// cost on the dispatch path is one relaxed atomic load per
    /// fanned-out job; the serial/inline path doesn't even pay that.
    prof_on: AtomicBool,
    prof_step: AtomicUsize,
    prof_events: Mutex<Vec<obs::ChunkEvent>>,
}

impl WorkerPool {
    /// Pool executing jobs with `threads` total lanes (the caller counts
    /// as one, so up to `threads - 1` OS threads are spawned on first
    /// use).
    pub fn new(threads: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot {
                job: None,
                chunks: 0,
                next: 0,
                pending: 0,
                panicked: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        WorkerPool {
            shared,
            handles: std::sync::Mutex::new(Vec::new()),
            spawned: std::sync::atomic::AtomicBool::new(false),
            threads: threads.max(1),
            prof_on: AtomicBool::new(false),
            prof_step: AtomicUsize::new(0),
            prof_events: Mutex::new(Vec::new()),
        }
    }

    /// The no-thread pool (inline execution), for the reference
    /// interpreter and other strictly serial callers.
    pub fn serial() -> WorkerPool {
        WorkerPool::new(1)
    }

    /// Total execution lanes (workers + the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn ensure_spawned(&self) {
        if self.spawned.load(Ordering::Acquire) {
            return;
        }
        let mut handles = self.handles.lock().expect("pool handles lock");
        if handles.is_empty() {
            for lane in 1..self.threads {
                let shared = Arc::clone(&self.shared);
                handles.push(std::thread::spawn(move || {
                    POOL_LANE.with(|l| l.set(lane));
                    worker_loop(&shared)
                }));
            }
        }
        self.spawned.store(true, Ordering::Release);
    }

    /// Execute `f(0), f(1), .., f(chunks - 1)` across the pool, blocking
    /// until all chunks completed. Chunks must be independent; `f` must
    /// derive everything from the chunk index (see module docs).
    pub fn run(&self, chunks: usize, f: &(dyn Fn(usize) + Sync)) {
        if chunks == 0 {
            return;
        }
        if self.threads <= 1 || chunks == 1 {
            for ci in 0..chunks {
                f(ci);
            }
            return;
        }
        if self.prof_on.load(Ordering::Relaxed) {
            self.run_profiled(chunks, f);
            return;
        }
        self.dispatch(chunks, f);
    }

    /// Enable chunk-event tagging for subsequent fanned-out jobs (called
    /// by the profiled executor before its step loop).
    pub(crate) fn profile_begin(&self) {
        self.prof_on.store(true, Ordering::Relaxed);
    }

    /// Tag subsequent chunk events with this plan-step index.
    pub(crate) fn profile_set_step(&self, step: usize) {
        self.prof_step.store(step, Ordering::Relaxed);
    }

    /// Disable tagging and take the events recorded since
    /// `profile_begin`.
    pub(crate) fn profile_end(&self) -> Vec<obs::ChunkEvent> {
        self.prof_on.store(false, Ordering::Relaxed);
        std::mem::take(&mut *self.prof_events.lock().expect("pool profile events"))
    }

    /// The profiled fan-out: wrap `f` so each chunk records (lane, t0,
    /// duration) into its own pre-allocated `OnceLock` slot — lock-free
    /// on the kernel path — then push them into the event buffer once,
    /// after the completion barrier. The wrapper calls `f(ci)` with the
    /// exact same chunk indices the plain path would, so partitioning
    /// and accumulation order (the bitwise-determinism contract) are
    /// untouched.
    fn run_profiled(&self, chunks: usize, f: &(dyn Fn(usize) + Sync)) {
        let step = self.prof_step.load(Ordering::Relaxed);
        let recs: Vec<OnceLock<(usize, f64, f64)>> =
            (0..chunks).map(|_| OnceLock::new()).collect();
        let wrapped = |ci: usize| {
            let ts = obs::now_us();
            let t0 = std::time::Instant::now();
            f(ci);
            let dur = t0.elapsed().as_secs_f64() * 1e6;
            let lane = POOL_LANE.with(|l| l.get());
            let _ = recs[ci].set((lane, ts, dur));
        };
        self.dispatch(chunks, &wrapped);
        let mut ev = self.prof_events.lock().expect("pool profile events");
        for (ci, r) in recs.iter().enumerate() {
            if let Some(&(lane, ts_us, dur_us)) = r.get() {
                ev.push(obs::ChunkEvent { step, chunk: ci, lane, ts_us, dur_us });
            }
        }
    }

    /// The fan-out machinery shared by the plain and profiled paths:
    /// publish, participate, barrier, retire.
    fn dispatch(&self, chunks: usize, f: &(dyn Fn(usize) + Sync)) {
        self.ensure_spawned();
        // Publish the job. The raw pointer stays valid until we observe
        // pending == 0 below, which is after the last dereference.
        {
            let mut s = self.shared.slot.lock().expect("pool lock");
            debug_assert!(s.job.is_none(), "pool jobs never overlap");
            s.job = Some(JobPtr(f as *const _));
            s.chunks = chunks;
            s.next = 0;
            s.pending = chunks;
            s.panicked = 0;
            self.shared.work.notify_all();
        }
        // The caller participates instead of idling. Its chunks are
        // caught like the workers' so the completion barrier (and with it
        // the pointer's validity window) holds even across panics.
        loop {
            let ci = {
                let mut s = self.shared.slot.lock().expect("pool lock");
                if s.next >= s.chunks {
                    break;
                }
                s.next += 1;
                s.next - 1
            };
            let outcome =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(ci)));
            let mut s = self.shared.slot.lock().expect("pool lock");
            if outcome.is_err() {
                s.panicked += 1;
            }
            s.pending -= 1;
            if s.pending == 0 {
                self.shared.done.notify_all();
            }
        }
        // Wait for workers to drain their in-flight chunks, then retire
        // the job so the stale pointer can never be picked up again.
        let mut s = self.shared.slot.lock().expect("pool lock");
        while s.pending > 0 {
            s = self.shared.done.wait(s).expect("pool wait");
        }
        let panicked = s.panicked;
        // Lifetime contract: every chunk was handed out and completed
        // before the job pointer is retired — after this, no worker can
        // observe (let alone dereference) the stale pointer.
        debug_assert!(s.next >= s.chunks, "job retired with chunks unissued");
        debug_assert_eq!(s.pending, 0, "job retired with chunks in flight");
        debug_assert!(
            std::ptr::addr_eq(s.job.expect("job still published").0, f),
            "job slot was overwritten while this run was in flight"
        );
        s.job = None;
        drop(s);
        assert!(panicked == 0, "{panicked} pool chunk(s) panicked");
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let (job, ci, chunks) = {
            let mut s = shared.slot.lock().expect("pool lock");
            loop {
                if s.shutdown {
                    return;
                }
                let grabbed = s.job.filter(|_| s.next < s.chunks);
                match grabbed {
                    Some(job) => {
                        s.next += 1;
                        break (job, s.next - 1, s.chunks);
                    }
                    None => s = shared.work.wait(s).expect("pool wait"),
                }
            }
        };
        // Catch panics so `pending` always reaches 0 and the caller can
        // re-throw instead of deadlocking.
        debug_assert!(ci < chunks, "worker drew a chunk index past the job");
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // SAFETY: the pointer was loaded from the slot while
            // `job.is_some()`, and the issuing `run` keeps the closure
            // borrowed (blocked on `pending == 0`, which this chunk has
            // not yet decremented) until after this call returns — the
            // pointee is alive for the whole dereference.
            let job_ref = unsafe { &*job.0 };
            job_ref(ci)
        }));
        let mut s = shared.slot.lock().expect("pool lock");
        if outcome.is_err() {
            s.panicked += 1;
        }
        s.pending -= 1;
        if s.pending == 0 {
            shared.done.notify_all();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut s = self.shared.slot.lock().expect("pool lock");
            s.shutdown = true;
            self.shared.work.notify_all();
        }
        let handles = std::mem::take(self.handles.get_mut().expect("pool handles lock"));
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Raw mutable base pointer smuggled into `Fn(usize)` chunk closures;
/// chunks address disjoint ranges, so concurrent writes never alias.
///
/// Lifetime contract: a `SendPtr` is constructed from a `&mut [f32]`
/// immediately before `WorkerPool::run` and every use happens inside
/// that `run` call, which blocks until all chunks complete — the
/// backing slice strictly outlives every dereference.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr(pub *mut f32);
// SAFETY: the pointer is only offset and dereferenced inside pool chunk
// closures, and each chunk writes a distinct, in-bounds range of the
// backing slice (the disjoint exact-cover invariant that
// `runtime::verify::plan` proves for every kernel partition), so moving
// the pointer to a worker thread cannot create an aliasing write.
unsafe impl Send for SendPtr {}
// SAFETY: chunk closures capture `SendPtr` by shared reference; the
// same disjoint-range argument makes concurrent `.add`/write through it
// race-free, so sharing the wrapper across threads is sound.
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_every_chunk_exactly_once() {
        let pool = WorkerPool::new(4);
        for chunks in [1usize, 2, 3, 7, 64] {
            let hits: Vec<AtomicUsize> =
                (0..chunks).map(|_| AtomicUsize::new(0)).collect();
            pool.run(chunks, &|ci| {
                hits[ci].fetch_add(1, Ordering::SeqCst);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1), "{chunks} chunks");
        }
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = WorkerPool::serial();
        assert_eq!(pool.threads(), 1);
        let count = AtomicUsize::new(0);
        pool.run(5, &|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn pool_survives_many_jobs() {
        // the persistence property: one pool, thousands of dispatches
        let pool = WorkerPool::new(3);
        let total = AtomicUsize::new(0);
        for _ in 0..2000 {
            pool.run(4, &|ci| {
                total.fetch_add(ci + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 2000 * (1 + 2 + 3 + 4));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // clock reads are unsupported under isolation
    fn profiled_run_tags_every_chunk() {
        let pool = WorkerPool::new(3);
        let hits: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        pool.profile_begin();
        pool.profile_set_step(7);
        pool.run(8, &|ci| {
            hits[ci].fetch_add(1, Ordering::SeqCst);
        });
        let events = pool.profile_end();
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        assert_eq!(events.len(), 8, "one event per chunk");
        let mut seen = vec![false; 8];
        for e in &events {
            assert_eq!(e.step, 7);
            assert!(e.lane < 3, "lane {} out of range", e.lane);
            assert!(e.dur_us >= 0.0);
            seen[e.chunk] = true;
        }
        assert!(seen.iter().all(|&s| s), "every chunk tagged");
        // tagging off again: plain dispatch records nothing
        pool.run(4, &|_| {});
        assert!(pool.profile_end().is_empty());
    }

    #[test]
    fn worker_panic_reaches_the_caller() {
        let pool = WorkerPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, &|ci| {
                if ci % 2 == 1 {
                    panic!("chunk {ci}");
                }
            });
        }));
        assert!(r.is_err(), "panicking chunks must not be swallowed");
        // and the pool is still usable afterwards
        let n = AtomicUsize::new(0);
        pool.run(3, &|_| {
            n.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(n.load(Ordering::SeqCst), 3);
    }
}
