//! The seed interpreter, kept as a differential baseline.
//!
//! `run_reference` executes the graph node by node with one fresh heap
//! allocation per node and no worker threads — exactly the PR 1
//! execution model — but through the *same* kernels as the planned
//! path, so the arena-aliasing property suite can demand bitwise
//! equality between the two executors, and `benches/native_exec.rs` can
//! price the plan + arena + threading against the seed honestly.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::super::graph::OpKind;
use super::super::HostTensor;
use super::plan::{self, BinOp, UnOp};
use super::pool::WorkerPool;
use super::{kernels, NativeExecutable};

impl NativeExecutable {
    /// Interpret the graph the way the seed backend did: per-node output
    /// allocation, intermediates freed at last use, fully serial.
    pub fn run_reference(&self, args: &[Arc<HostTensor>]) -> Result<Arc<HostTensor>> {
        let g = &self.graph;
        if args.len() != g.n_params {
            bail!("{}: {} args, expected {}", g.name, args.len(), g.n_params);
        }
        let mut remaining = vec![0usize; g.nodes.len()];
        for node in &g.nodes {
            for inp in &node.inputs {
                remaining[inp.0] += 1;
            }
        }
        remaining[g.root.0] += 1;
        // One inline-executing pool for the whole run — strictly serial,
        // exactly the seed's per-node execution model.
        let serial = WorkerPool::serial();
        let mut values: Vec<Option<Arc<HostTensor>>> = vec![None; g.nodes.len()];
        for (i, node) in g.nodes.iter().enumerate() {
            if remaining[i] == 0 {
                continue; // dead node (e.g. unused parameter)
            }
            let out = match &node.op {
                OpKind::Parameter { index, name } => {
                    let a = &args[*index];
                    if a.dims != node.dims {
                        bail!(
                            "{}: parameter {index} ({name}) got {:?}, expects {:?}",
                            g.name,
                            a.dims,
                            node.dims
                        );
                    }
                    Arc::clone(a)
                }
                op => {
                    let ins: Vec<&HostTensor> = node
                        .inputs
                        .iter()
                        .map(|id| {
                            values[id.0]
                                .as_deref()
                                .ok_or_else(|| anyhow!("{}: input freed early", g.name))
                        })
                        .collect::<Result<_>>()?;
                    Arc::new(eval_op(op, &ins, &node.dims, &serial)?)
                }
            };
            values[i] = Some(out);
            for inp in &node.inputs {
                remaining[inp.0] -= 1;
                if remaining[inp.0] == 0 {
                    values[inp.0] = None;
                }
            }
        }
        values[g.root.0]
            .take()
            .ok_or_else(|| anyhow!("{}: root value missing", g.name))
    }
}

fn eval_op(
    op: &OpKind,
    ins: &[&HostTensor],
    out_dims: &[usize],
    serial: &WorkerPool,
) -> Result<HostTensor> {
    let n = kernels::numel(out_dims);
    let mut data = vec![0f32; n];
    match op {
        OpKind::Parameter { .. } => unreachable!("parameters handled by the driver"),
        OpKind::ConstScalar { value } => kernels::fill(&mut data, *value),
        OpKind::Broadcast => kernels::fill(&mut data, ins[0].data[0]),
        OpKind::BroadcastInDim { mapping } => {
            let axes = plan::broadcast_axes(&ins[0].dims, out_dims, mapping);
            kernels::gather(&ins[0].data, &axes, &mut data, serial);
        }
        OpKind::Concat { dim } => {
            let (outer, inner, total) = plan::axis_split(out_dims, *dim);
            let mut offset = 0usize;
            for t in ins {
                let mid = t.dims[*dim];
                kernels::concat_part(&t.data, outer, mid, inner, total, offset, &mut data);
                offset += mid;
            }
        }
        OpKind::Slice { dim, start, stop: _, stride } => {
            let (outer, inner, _) = plan::axis_split(&ins[0].dims, *dim);
            kernels::slice(
                &ins[0].data,
                outer,
                ins[0].dims[*dim],
                inner,
                *start,
                *stride,
                out_dims[*dim],
                &mut data,
            );
        }
        OpKind::Reshape => kernels::copy(&ins[0].data, &mut data),
        OpKind::Transpose { perm } => {
            let axes = plan::transpose_axes(&ins[0].dims, out_dims, perm);
            kernels::gather(&ins[0].data, &axes, &mut data, serial);
        }
        OpKind::DotGeneral { lhs_contract, rhs_contract } => {
            let (lhs, rhs) = (ins[0], ins[1]);
            let shape = plan::dot_shape(&lhs.dims, &rhs.dims, lhs_contract, rhs_contract)?;
            let a = permuted(lhs, shape.lhs_perm.as_deref(), serial);
            let b = permuted(rhs, shape.rhs_perm.as_deref(), serial);
            let a: &[f32] = a.as_deref().unwrap_or(&lhs.data);
            let b: &[f32] = b.as_deref().unwrap_or(&rhs.data);
            kernels::dot_general(a, b, shape.n, shape.k, &mut data, serial);
        }
        OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Max | OpKind::Gt => {
            let op = match op {
                OpKind::Add => BinOp::Add,
                OpKind::Sub => BinOp::Sub,
                OpKind::Mul => BinOp::Mul,
                OpKind::Max => BinOp::Max,
                _ => BinOp::Gt,
            };
            let (a, b) = (ins[0], ins[1]);
            if a.dims == b.dims {
                kernels::binary(&a.data, &b.data, &mut data, serial, |x, y| op.apply(x, y));
            } else if a.dims.is_empty() {
                kernels::binary_scalar(&b.data, a.data[0], true, &mut data, serial, |x, y| {
                    op.apply(x, y)
                });
            } else if b.dims.is_empty() {
                kernels::binary_scalar(&a.data, b.data[0], false, &mut data, serial, |x, y| {
                    op.apply(x, y)
                });
            } else {
                bail!("elementwise op on mismatched shapes {:?} vs {:?}", a.dims, b.dims);
            }
        }
        OpKind::SpmmCsr { row_ptr, col_idx, rhs_axis, val_perm, .. } => {
            let (vals, x) = (ins[0], ins[1]);
            // bring the contracted axis to the front, like a dot rhs prep
            let xp = if *rhs_axis == 0 {
                None
            } else {
                let mut p = vec![*rhs_axis];
                p.extend((0..x.dims.len()).filter(|ax| ax != rhs_axis));
                Some(p)
            };
            let xbuf = permuted(x, xp.as_deref(), serial);
            let xflat: &[f32] = xbuf.as_deref().unwrap_or(&x.data);
            let m: usize = out_dims[1..].iter().product();
            kernels::spmm_csr(
                &vals.data,
                xflat,
                row_ptr,
                col_idx,
                val_perm.as_ref().map(|p| &p[..]),
                m,
                &mut data,
                serial,
            );
        }
        OpKind::Select => {
            kernels::select(&ins[0].data, &ins[1].data, &ins[2].data, &mut data, serial);
        }
        OpKind::ReduceMean { dims } | OpKind::ReduceSum { dims } => {
            let geom = plan::reduce_geom(&ins[0].dims, out_dims, dims)?;
            let mean = matches!(op, OpKind::ReduceMean { .. });
            kernels::reduce(&ins[0].data, &geom, mean, &mut data, serial);
        }
        OpKind::Sqrt | OpKind::Neg | OpKind::Exp | OpKind::Log | OpKind::Recip => {
            let op = match op {
                OpKind::Sqrt => UnOp::Sqrt,
                OpKind::Neg => UnOp::Neg,
                OpKind::Exp => UnOp::Exp,
                OpKind::Log => UnOp::Log,
                _ => UnOp::Recip,
            };
            kernels::unary(&ins[0].data, &mut data, serial, |x| op.apply(x));
        }
    }
    Ok(HostTensor::new(out_dims.to_vec(), data))
}

/// Materialize `x` with its axes permuted; `None` for the identity.
fn permuted(x: &HostTensor, perm: Option<&[usize]>, serial: &WorkerPool) -> Option<Vec<f32>> {
    let perm = perm?;
    let out_dims: Vec<usize> = perm.iter().map(|&p| x.dims[p]).collect();
    let axes = plan::transpose_axes(&x.dims, &out_dims, perm);
    let mut data = vec![0f32; x.data.len()];
    kernels::gather(&x.data, &axes, &mut data, serial);
    Some(data)
}
