//! Full-network graph construction: the entire ResNet forward pass for
//! any (arch, plan) pair, weights as parameters. Used by the fps tables
//! (Table 1/3, Fig. 5), the coordinator's synthetic workers and the
//! artifact-free integration tests, so sweeping models/variants needs no
//! python and no artifact explosion; numerics are cross-checked against
//! the python AOT artifacts in the integration tests when artifacts are
//! present.
//!
//! BatchNorm is inference-mode (per-channel affine) here — the measured
//! quantity is throughput, and affine-BN is exactly what a deployed
//! inference graph folds to.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::graph::{Graph, GraphBuilder, Op};
use super::layer_factory as lf;
use super::{Buffer, Compiled, CompileOptions, Engine, HostTensor, PassStats};
use crate::decompose::params::Params;
use crate::decompose::sparse::SparseResidual;
use crate::decompose::{Plan, Scheme};
use crate::model::{Arch, BlockKind, ConvSite, SiteKind};
use crate::util::rng::Rng;

type B = GraphBuilder;

/// Parameter spec of a built network (order == parameter index - 1; the
/// input image is always parameter 0).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

struct NetCtx<'a> {
    b: &'a B,
    specs: Vec<ParamSpec>,
    next_idx: usize,
    /// Decomposed parameters the net is being built against, when known —
    /// the source of fitted sparse-residual CSR patterns (`{site}.s_idx`).
    params: Option<&'a Params>,
}

impl NetCtx<'_> {
    fn param(&mut self, name: &str, shape: Vec<usize>) -> Result<Op> {
        let p = self.b.parameter(self.next_idx, &shape, name)?;
        self.next_idx += 1;
        self.specs.push(ParamSpec { name: name.to_string(), shape });
        Ok(p)
    }

    /// CSR pattern for a sparse-residual site: the fitted one when the
    /// net is built against decomposed params, a deterministic synthetic
    /// one at the same density otherwise (He-initialised nets only need
    /// the right geometry).
    fn sparse_pattern(
        &self,
        idx_name: &str,
        wdims: &[usize],
        nnz: usize,
    ) -> Result<SparseResidual> {
        match self.params.and_then(|p| p.get(idx_name)) {
            Some(idx) => {
                let zeros = HostTensor::new(idx.dims.clone(), vec![0.0; idx.data.len()]);
                SparseResidual::from_tensors(wdims, &zeros, idx)
            }
            None => SparseResidual::synthetic(wdims, nnz),
        }
    }
}

/// Apply one (possibly decomposed) conv site WITHOUT its BN/ReLU.
/// Returns the op and output (channels, h, w).
fn apply_site(
    ctx: &mut NetCtx,
    site: &ConvSite,
    plan: &Plan,
    x: &Op,
    n: usize,
    h: usize,
    w: usize,
) -> Result<(Op, usize, usize, usize)> {
    let scheme = plan.get(&site.name).unwrap_or(&Scheme::Orig);
    apply_scheme(ctx, site, plan, scheme, x, n, h, w)
}

/// `apply_site` with an explicit scheme — `Scheme::Sparse` recurses into
/// its base chain here, then rides the CSR residual arm on the same input.
#[allow(clippy::too_many_arguments)]
fn apply_scheme(
    ctx: &mut NetCtx,
    site: &ConvSite,
    plan: &Plan,
    scheme: &Scheme,
    x: &Op,
    n: usize,
    h: usize,
    w: usize,
) -> Result<(Op, usize, usize, usize)> {
    let (ho, wo) = (
        (h + 2 * site.padding - site.k) / site.stride + 1,
        (w + 2 * site.padding - site.k) / site.stride + 1,
    );
    let nm = &site.name;
    Ok(match scheme {
        Scheme::Orig => {
            if site.k == 1 {
                let wp = ctx.param(&format!("{nm}.w"), vec![site.s, site.c])?;
                (lf::conv1x1(x, &wp, site.stride)?, site.s, ho, wo)
            } else {
                let wp =
                    ctx.param(&format!("{nm}.w"), vec![site.s, site.c, site.k, site.k])?;
                let xp = lf::pad_hw(ctx.b, x, &[n, site.c, h, w], site.padding, 0.0)?;
                let pd = [n, site.c, h + 2 * site.padding, w + 2 * site.padding];
                (lf::conv2d(ctx.b, &xp, &wp, &pd, site.s, site.k, site.stride)?, site.s, ho, wo)
            }
        }
        Scheme::Svd { r } => {
            let w0 = ctx.param(&format!("{nm}.w0"), vec![*r, site.c])?;
            let w1 = ctx.param(&format!("{nm}.w1"), vec![site.s, *r])?;
            let t = lf::conv1x1(x, &w0, site.stride)?;
            (lf::conv1x1(&t, &w1, 1)?, site.s, ho, wo)
        }
        Scheme::Tucker { r1, r2 } => {
            let u = ctx.param(&format!("{nm}.u"), vec![*r1, site.c])?;
            let core =
                ctx.param(&format!("{nm}.core"), vec![*r2, *r1, site.k, site.k])?;
            let v = ctx.param(&format!("{nm}.v"), vec![site.s, *r2])?;
            let t = lf::conv1x1(x, &u, 1)?;
            let tp = lf::pad_hw(ctx.b, &t, &[n, *r1, h, w], site.padding, 0.0)?;
            let pd = [n, *r1, h + 2 * site.padding, w + 2 * site.padding];
            let t = lf::conv2d(ctx.b, &tp, &core, &pd, *r2, site.k, site.stride)?;
            (lf::conv1x1(&t, &v, 1)?, site.s, ho, wo)
        }
        Scheme::Branched { r1, r2, groups } => {
            let u = ctx.param(&format!("{nm}.u"), vec![*r1, site.c])?;
            let core = ctx
                .param(&format!("{nm}.core"), vec![*r2, r1 / groups, site.k, site.k])?;
            let v = ctx.param(&format!("{nm}.v"), vec![site.s, *r2])?;
            let t = lf::conv1x1(x, &u, 1)?;
            let tp = lf::pad_hw(ctx.b, &t, &[n, *r1, h, w], site.padding, 0.0)?;
            let pd = [n, *r1, h + 2 * site.padding, w + 2 * site.padding];
            let t =
                lf::grouped_conv2d(ctx.b, &tp, &core, &pd, *r2, site.k, site.stride, *groups)?;
            (lf::conv1x1(&t, &v, 1)?, site.s, ho, wo)
        }
        Scheme::Merged { r1, r2 } => {
            // the core conv of a merged bottleneck: input is already r1 wide
            let core =
                ctx.param(&format!("{nm}.w"), vec![*r2, *r1, site.k, site.k])?;
            let xp = lf::pad_hw(ctx.b, x, &[n, *r1, h, w], site.padding, 0.0)?;
            let pd = [n, *r1, h + 2 * site.padding, w + 2 * site.padding];
            (lf::conv2d(ctx.b, &xp, &core, &pd, *r2, site.k, site.stride)?, *r2, ho, wo)
        }
        Scheme::Tucker2 { r1, r2 } => {
            let u = ctx.param(&format!("{nm}.u"), vec![*r1, site.c])?;
            if site.k == 1 {
                // explicit three-matrix chain: stride rides the first 1x1
                let core = ctx.param(&format!("{nm}.core"), vec![*r2, *r1])?;
                let v = ctx.param(&format!("{nm}.v"), vec![site.s, *r2])?;
                let t = lf::conv1x1(x, &u, site.stride)?;
                let t = lf::conv1x1(&t, &core, 1)?;
                (lf::conv1x1(&t, &v, 1)?, site.s, ho, wo)
            } else {
                let core =
                    ctx.param(&format!("{nm}.core"), vec![*r2, *r1, site.k, site.k])?;
                let v = ctx.param(&format!("{nm}.v"), vec![site.s, *r2])?;
                let t = lf::conv1x1(x, &u, 1)?;
                let tp = lf::pad_hw(ctx.b, &t, &[n, *r1, h, w], site.padding, 0.0)?;
                let pd = [n, *r1, h + 2 * site.padding, w + 2 * site.padding];
                let t = lf::conv2d(ctx.b, &tp, &core, &pd, *r2, site.k, site.stride)?;
                (lf::conv1x1(&t, &v, 1)?, site.s, ho, wo)
            }
        }
        Scheme::Cp { r } => {
            if site.k == 1 {
                // the CP chain of a matrix degenerates to the SVD pair
                let w0 = ctx.param(&format!("{nm}.w0"), vec![*r, site.c])?;
                let w1 = ctx.param(&format!("{nm}.w1"), vec![site.s, *r])?;
                let t = lf::conv1x1(x, &w0, site.stride)?;
                (lf::conv1x1(&t, &w1, 1)?, site.s, ho, wo)
            } else {
                // Lebedev chain: 1x1 -> kx1 depthwise -> 1xk depthwise -> 1x1
                let u = ctx.param(&format!("{nm}.u"), vec![*r, site.c])?;
                let kh = ctx.param(&format!("{nm}.kh"), vec![*r, site.k])?;
                let kw = ctx.param(&format!("{nm}.kw"), vec![*r, site.k])?;
                let w1 = ctx.param(&format!("{nm}.w1"), vec![site.s, *r])?;
                let t = lf::conv1x1(x, &u, 1)?;
                let tp = lf::pad_axis(ctx.b, &t, &[n, *r, h, w], site.padding, 2)?;
                let hp = h + 2 * site.padding;
                let t = lf::depthwise_1d(&tp, &kh, &[n, *r, hp, w], site.k, site.stride, 2)?;
                let tp = lf::pad_axis(ctx.b, &t, &[n, *r, ho, w], site.padding, 3)?;
                let wp = w + 2 * site.padding;
                let t = lf::depthwise_1d(&tp, &kw, &[n, *r, ho, wp], site.k, site.stride, 3)?;
                (lf::conv1x1(&t, &w1, 1)?, site.s, ho, wo)
            }
        }
        Scheme::Sparse { base, ppm } => {
            // base chain first (declares its factors), then the residual
            // arm on the SAME input, aligned by identical stride/padding
            let (dense, cc, nh, nw) = apply_scheme(ctx, site, plan, base, x, n, h, w)?;
            if cc != site.s {
                bail!("{nm}: sparse base emits {cc} channels, site wants {}", site.s);
            }
            let wdims = if site.k == 1 {
                vec![site.s, site.c]
            } else {
                vec![site.s, site.c, site.k, site.k]
            };
            let nnz = Scheme::sparse_nnz(site.c, site.s, site.k, *ppm);
            let pattern = ctx.sparse_pattern(&format!("{nm}.s_idx"), &wdims, nnz)?;
            let vals = ctx.param(&format!("{nm}.s"), vec![pattern.nnz()])?;
            let sp = lf::sparse_conv(
                ctx.b,
                x,
                &vals,
                &pattern,
                &[n, site.c, h, w],
                site.s,
                site.k,
                site.stride,
                site.padding,
            )?;
            ((dense + sp)?, cc, nh, nw)
        }
        Scheme::MergedInto { peer } => {
            let (r1, r2) = match plan.get(peer) {
                Some(Scheme::Merged { r1, r2 }) => (*r1, *r2),
                other => bail!("{nm}: merged_into peer {peer} has scheme {other:?}"),
            };
            let (co, ci) = if nm.ends_with(".conv1") { (r1, site.c) } else { (site.s, r2) };
            let wp = ctx.param(&format!("{nm}.w"), vec![co, ci])?;
            (lf::conv1x1(x, &wp, site.stride)?, co, ho, wo)
        }
    })
}

/// How BatchNorm lowers in a built network.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BnMode {
    /// Inference-mode per-channel affine — what a deployed graph folds
    /// to; the throughput benchmarks measure this.
    Affine,
    /// Batch-statistics normalisation + affine — what the python train
    /// graphs compute; the native training subsystem differentiates
    /// through it.
    BatchStats,
}

/// BN (per `mode`) + optional ReLU on an NCHW op.
fn bn_relu(
    ctx: &mut NetCtx,
    name: &str,
    x: &Op,
    dims: &[usize; 4],
    relu: bool,
    mode: BnMode,
) -> Result<Op> {
    let g = ctx.param(&format!("{name}.bn.g"), vec![dims[1]])?;
    let bta = ctx.param(&format!("{name}.bn.b"), vec![dims[1]])?;
    let y = match mode {
        BnMode::Affine => lf::bn_affine(x, &g, &bta, dims)?,
        BnMode::BatchStats => lf::bn_batchstats(ctx.b, x, &g, &bta, dims)?,
    };
    if relu {
        lf::relu(ctx.b, &y)
    } else {
        Ok(y)
    }
}

/// Build the full forward computation with inference-mode (affine) BN.
/// Parameter 0 is the input image [batch, 3, hw, hw]; the returned specs
/// describe parameters 1..N.
pub fn build_forward(
    arch: &Arch,
    plan: &Plan,
    batch: usize,
    hw: usize,
) -> Result<(Graph, Vec<ParamSpec>)> {
    build_forward_mode(arch, plan, batch, hw, BnMode::Affine)
}

/// `build_forward` with an explicit BN lowering mode. The parameter
/// names and order are identical across modes — only the BN body
/// differs — so weights trained through `BnMode::BatchStats` load
/// straight into an affine inference graph.
pub fn build_forward_mode(
    arch: &Arch,
    plan: &Plan,
    batch: usize,
    hw: usize,
    bn: BnMode,
) -> Result<(Graph, Vec<ParamSpec>)> {
    build_forward_with(arch, plan, batch, hw, bn, None)
}

/// `build_forward_mode` built against known decomposed parameters:
/// sparse-residual sites bake the FITTED CSR pattern (`{site}.s_idx`)
/// into the graph instead of a synthetic one. Parameter names, order and
/// shapes are unchanged — `.s_idx` never becomes a graph parameter.
pub fn build_forward_with(
    arch: &Arch,
    plan: &Plan,
    batch: usize,
    hw: usize,
    bn: BnMode,
    params: Option<&Params>,
) -> Result<(Graph, Vec<ParamSpec>)> {
    let b = B::new(&format!("{}_fwd", arch.name));
    let x = b.parameter(0, &[batch, 3, hw, hw], "x")?;
    let mut ctx = NetCtx { b: &b, specs: Vec::new(), next_idx: 1, params };
    let sites = arch.sites();
    let by_name: std::collections::HashMap<String, ConvSite> =
        sites.iter().map(|t| (t.name.clone(), t.clone())).collect();

    // Stem
    let stem = &by_name["stem.conv"];
    let (mut y, mut c, mut h, mut w) = apply_site(&mut ctx, stem, plan, &x, batch, hw, hw)?;
    y = bn_relu(&mut ctx, "stem.conv", &y, &[batch, c, h, w], true, bn)?;
    y = lf::maxpool_3x3_s2(&b, &y, &[batch, c, h, w])?;
    h = (h + 2 - 3) / 2 + 1;
    w = (w + 2 - 3) / 2 + 1;

    for (si, &n_blocks) in arch.layers.iter().enumerate() {
        for bi in 0..n_blocks {
            let pre = format!("layer{}.{}", si + 1, bi);
            let identity = (y.clone(), c, h, w);
            let names: Vec<String> = match arch.block {
                BlockKind::Bottleneck => {
                    vec![format!("{pre}.conv1"), format!("{pre}.conv2"), format!("{pre}.conv3")]
                }
                BlockKind::Basic => vec![format!("{pre}.conv1"), format!("{pre}.conv2")],
            };
            let mut hh = (y.clone(), c, h, w);
            for (i, nm) in names.iter().enumerate() {
                let site = &by_name[nm];
                let (op, cc, nh, nw) =
                    apply_site(&mut ctx, site, plan, &hh.0, batch, hh.2, hh.3)?;
                let last = i == names.len() - 1;
                let op = bn_relu(&mut ctx, nm, &op, &[batch, cc, nh, nw], !last, bn)?;
                hh = (op, cc, nh, nw);
            }
            let (mut idy, _idc, _idh, _idw) = identity.clone();
            if let Some(ds) = by_name.get(&format!("{pre}.downsample")) {
                let (op, cc, nh, nw) =
                    apply_site(&mut ctx, ds, plan, &identity.0, batch, identity.2, identity.3)?;
                idy = bn_relu(&mut ctx, &ds.name, &op, &[batch, cc, nh, nw], false, bn)?;
            }
            let sum = (hh.0 + idy)?;
            y = lf::relu(&b, &sum)?;
            (c, h, w) = (hh.1, hh.2, hh.3);
        }
    }

    // Head
    let pooled = lf::gap(&y)?; // [batch, C]
    // User-reachable (any CLI --arch lands here): a typed error beats a
    // panic if an architecture table ever ships without its fc head.
    let Some(fc) = sites.last() else {
        bail!("{}: architecture declares no sites", arch.name);
    };
    if fc.kind != SiteKind::Fc {
        bail!("{}: last site {:?} is not the fc head", arch.name, fc.name);
    }
    let (fc_base, fc_sparse) = plan.get("fc").unwrap_or(&Scheme::Orig).split_sparse();
    let logits = match fc_base {
        Scheme::Svd { r } | Scheme::Cp { r } => {
            let w0 = ctx.param("fc.w0", vec![*r, fc.c])?;
            let w1 = ctx.param("fc.w1", vec![fc.s, *r])?;
            let t = pooled.dot_general(&w0, &[1], &[1])?;
            t.dot_general(&w1, &[1], &[1])?
        }
        Scheme::Tucker2 { r1, r2 } => {
            let u = ctx.param("fc.u", vec![*r1, fc.c])?;
            let core = ctx.param("fc.core", vec![*r2, *r1])?;
            let v = ctx.param("fc.v", vec![fc.s, *r2])?;
            let t = pooled.dot_general(&u, &[1], &[1])?;
            let t = t.dot_general(&core, &[1], &[1])?;
            t.dot_general(&v, &[1], &[1])?
        }
        _ => {
            let wp = ctx.param("fc.w", vec![fc.s, fc.c])?;
            pooled.dot_general(&wp, &[1], &[1])?
        }
    };
    let logits = match fc_sparse {
        Some(ppm) => {
            let nnz = Scheme::sparse_nnz(fc.c, fc.s, 1, ppm);
            let pattern = ctx.sparse_pattern("fc.s_idx", &[fc.s, fc.c], nnz)?;
            let taps = pattern.taps()?;
            if taps.len() != 1 {
                bail!("fc sparse pattern must be a single tap, got {}", taps.len());
            }
            let tap = taps.into_iter().next().unwrap();
            let vals = ctx.param("fc.s", vec![pattern.nnz()])?;
            // [nnz] spmm [batch, C] contracting C -> [S, batch] -> [batch, S]
            let sp = vals.spmm_csr(
                &pooled,
                fc.s,
                fc.c,
                Arc::new(tap.row_ptr),
                Arc::new(tap.col_idx),
                1,
                None,
            )?;
            (logits + sp.transpose(&[1, 0])?)?
        }
        None => logits,
    };
    let bias = ctx.param("fc.b", vec![fc.s])?;
    let bias = bias.broadcast_in_dim(&[batch, fc.s], &[1])?;
    let out = (logits + bias)?;
    let graph = b.build(&out)?;
    Ok((graph, ctx.specs))
}

/// Host-side initial value for one named parameter: BN scales start at
/// 1, biases at 0, everything else He-initialised. The single source of
/// the name-suffix rules — `BuiltNet::compile` and `benches/native_exec`
/// must agree on what network they run.
pub fn init_param_host(spec: &ParamSpec, rng: &mut Rng) -> Vec<f32> {
    let n: usize = spec.shape.iter().product();
    let fan_in = spec.shape.iter().skip(1).product::<usize>().max(1);
    if spec.name.ends_with(".bn.g") {
        vec![1.0f32; n]
    } else if spec.name.ends_with(".bn.b") || spec.name == "fc.b" {
        vec![0.0f32; n]
    } else if spec.name.ends_with(".s") {
        // sparse-residual values start small, not He-scaled: a synthetic
        // residual must not drown the chain it rides on
        (0..n).map(|_| rng.normal_f32() * 0.05).collect()
    } else {
        rng.he_weights(n, fan_in)
    }
}

/// A compiled network with weights resident on the backend — the unit the
/// fps benchmarks (and the coordinator's synthetic workers) execute.
pub struct BuiltNet {
    pub exe: Compiled,
    pub weight_bufs: Vec<Buffer>,
    pub batch: usize,
    pub hw: usize,
    pub classes: usize,
}

impl BuiltNet {
    /// Compile (arch, plan) under `opts` and upload He-initialised weights.
    pub fn compile(
        engine: &Engine,
        arch: &Arch,
        plan: &Plan,
        batch: usize,
        hw: usize,
        seed: u64,
        opts: &CompileOptions,
    ) -> Result<BuiltNet> {
        let (graph, specs) = build_forward(arch, plan, batch, hw)?;
        let exe = engine.compile(&graph, opts)?;
        let mut rng = Rng::new(seed);
        let mut weight_bufs = Vec::with_capacity(specs.len());
        for spec in &specs {
            let host = init_param_host(spec, &mut rng);
            weight_bufs.push(engine.upload(&host, &spec.shape)?);
        }
        Ok(BuiltNet { exe, weight_bufs, batch, hw, classes: arch.classes })
    }

    /// Compile (arch, plan) and upload the given named parameters (e.g. the
    /// one-shot decomposition of a trained original — `decompose::params`).
    pub fn compile_with_params(
        engine: &Engine,
        arch: &Arch,
        plan: &Plan,
        batch: usize,
        hw: usize,
        params: &crate::decompose::params::Params,
        opts: &CompileOptions,
    ) -> Result<BuiltNet> {
        BuiltNet::compile_with_params_mode(
            engine,
            arch,
            plan,
            batch,
            hw,
            params,
            opts,
            BnMode::Affine,
        )
    }

    /// `compile_with_params` with an explicit BN mode — the native
    /// training path evaluates through `BnMode::BatchStats` so eval
    /// normalisation matches how the train-step graph normalised.
    #[allow(clippy::too_many_arguments)]
    pub fn compile_with_params_mode(
        engine: &Engine,
        arch: &Arch,
        plan: &Plan,
        batch: usize,
        hw: usize,
        params: &crate::decompose::params::Params,
        opts: &CompileOptions,
        bn: BnMode,
    ) -> Result<BuiltNet> {
        let (graph, specs) = build_forward_with(arch, plan, batch, hw, bn, Some(params))?;
        let exe = engine.compile(&graph, opts)?;
        let mut weight_bufs = Vec::with_capacity(specs.len());
        for spec in &specs {
            let t = params
                .get(&spec.name)
                .ok_or_else(|| anyhow!("missing param {}", spec.name))?;
            if t.dims != spec.shape {
                bail!("{}: params give {:?}, net expects {:?}", spec.name, t.dims, spec.shape);
            }
            weight_bufs.push(engine.upload(&t.data, &t.dims)?);
        }
        Ok(BuiltNet { exe, weight_bufs, batch, hw, classes: arch.classes })
    }

    /// What the pass pipeline did to this network's graph.
    pub fn pass_stats(&self) -> &PassStats {
        self.exe.stats()
    }

    /// Run one forward pass on an input buffer; returns the logits buffer.
    pub fn forward(&self, x: &Buffer) -> Result<Buffer> {
        let mut args: Vec<&Buffer> = Vec::with_capacity(1 + self.weight_bufs.len());
        args.push(x);
        args.extend(self.weight_bufs.iter());
        let mut outs = self.exe.run_buffers(&args)?;
        Ok(outs.swap_remove(0))
    }
}

// --------------------------------------------------------------------------
// Shape-bucketed serving network
// --------------------------------------------------------------------------

/// Power-of-two bucket ladder `1, 2, 4, …` capped at — and always
/// containing — `max`: the default executable ladder for bucketed serving.
pub fn pow2_ladder(max: usize) -> Vec<usize> {
    let max = max.max(1);
    let mut v = Vec::new();
    let mut b = 1usize;
    while b < max {
        v.push(b);
        b *= 2;
    }
    v.push(max);
    v
}

/// Compile/upload accounting of a [`ServableNet`] — the evidence that a
/// worker's whole bucket ladder shares one weight set.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeCacheStats {
    /// Graphs compiled so far (== distinct buckets `run_bucket` touched).
    pub compiles: usize,
    /// Weight buffers resident on the engine — uploaded exactly once, at
    /// construction, no matter how many buckets ever compile.
    pub weight_uploads: usize,
    /// Buckets holding a compiled executable, ascending.
    pub compiled_buckets: Vec<usize>,
}

/// A batch-parametric serving network: ONE weight set shared by a ladder
/// of compiled executables (batch 1, 2, 4, …, ceiling), each compiled
/// lazily on the first batch that lands in it. Parameter specs are
/// batch-invariant (weights never carry the batch dimension), so a
/// collected batch of `n` requests dispatches to the smallest covering
/// bucket instead of padding to a fixed device batch.
///
/// Bitwise contract: the re-merge amortization is pinned to the ladder
/// ceiling (`CompileOptions::amortize`), so every bucket makes identical
/// fusion decisions and the logits for one request are bitwise-identical
/// whichever bucket carries it (`tests/serve_buckets.rs`).
pub struct ServableNet {
    engine: Engine,
    arch: Arch,
    plan: Plan,
    opts: CompileOptions,
    buckets: Vec<usize>,
    weight_bufs: Vec<Buffer>,
    compiled: std::collections::HashMap<usize, Compiled>,
    compiles: usize,
    pub hw: usize,
    pub classes: usize,
}

impl ServableNet {
    /// Upload He-initialised weights for (arch, plan) once and prepare a
    /// lazy executable ladder over `buckets` (strictly ascending; the
    /// last entry is the serving ceiling).
    pub fn compile(
        engine: &Engine,
        arch: &Arch,
        plan: &Plan,
        buckets: &[usize],
        hw: usize,
        seed: u64,
        opts: &CompileOptions,
    ) -> Result<ServableNet> {
        let buckets = validate_ladder(buckets)?;
        let ceiling = *buckets.last().unwrap();
        let (_graph, specs) = build_forward(arch, plan, ceiling, hw)?;
        let mut rng = Rng::new(seed);
        let mut weight_bufs = Vec::with_capacity(specs.len());
        for spec in &specs {
            let host = init_param_host(spec, &mut rng);
            weight_bufs.push(engine.upload(&host, &spec.shape)?);
        }
        Ok(ServableNet {
            engine: engine.clone(),
            arch: arch.clone(),
            plan: plan.clone(),
            opts: opts.clone(),
            buckets,
            weight_bufs,
            compiled: std::collections::HashMap::new(),
            compiles: 0,
            hw,
            classes: arch.classes,
        })
    }

    /// The executable ladder, ascending; the last entry is the ceiling.
    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Smallest bucket covering a batch of `n` real requests.
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| b >= n)
    }

    pub fn cache_stats(&self) -> ServeCacheStats {
        let mut compiled_buckets: Vec<usize> = self.compiled.keys().copied().collect();
        compiled_buckets.sort_unstable();
        ServeCacheStats {
            compiles: self.compiles,
            weight_uploads: self.weight_bufs.len(),
            compiled_buckets,
        }
    }

    /// Pass-pipeline accounting for one bucket's executable, if that
    /// bucket has compiled.
    pub fn pass_stats(&self, bucket: usize) -> Option<&PassStats> {
        self.compiled.get(&bucket).map(|e| e.stats())
    }

    /// Compile every bucket of the ladder now. Lazy compile-on-first-use
    /// is the default, but a first-request compile spike is unacceptable
    /// in benchmarks and latency-sensitive deployments — call this at
    /// worker construction to pay it all up front.
    pub fn precompile_all(&mut self) -> Result<()> {
        for b in self.buckets.clone() {
            self.executable(b)?;
        }
        Ok(())
    }

    fn executable(&mut self, bucket: usize) -> Result<Compiled> {
        if let Some(exe) = self.compiled.get(&bucket) {
            return Ok(exe.clone());
        }
        let (graph, _) = build_forward(&self.arch, &self.plan, bucket, self.hw)?;
        let ceiling = *self.buckets.last().unwrap();
        let opts =
            CompileOptions { amortize: Some((bucket, ceiling)), ..self.opts.clone() };
        let exe = self.engine.compile(&graph, &opts)?;
        self.compiles += 1;
        self.compiled.insert(bucket, exe.clone());
        Ok(exe)
    }

    /// Run one padded batch on the bucket's executable (compiled on
    /// first use): `x` is `[bucket, 3, hw, hw]` flattened; returns
    /// flattened logits `[bucket, classes]`.
    pub fn run_bucket(&mut self, x: &[f32], bucket: usize) -> Result<Vec<f32>> {
        if !self.buckets.contains(&bucket) {
            bail!("bucket {bucket} not in ladder {:?}", self.buckets);
        }
        let expect = bucket * 3 * self.hw * self.hw;
        if x.len() != expect {
            bail!("bucket {bucket} expects {expect} floats, got {}", x.len());
        }
        let exe = self.executable(bucket)?;
        let xb = self.engine.upload(x, &[bucket, 3, self.hw, self.hw])?;
        let mut args: Vec<&Buffer> = Vec::with_capacity(1 + self.weight_bufs.len());
        args.push(&xb);
        args.extend(self.weight_bufs.iter());
        let mut outs = exe.run_buffers(&args)?;
        Ok(outs.swap_remove(0).to_host()?.data)
    }
}

/// Validate an executable ladder: non-empty, strictly ascending, all ≥ 1.
/// The single source of the ladder rules — `ServableNet::compile` and the
/// coordinator's worker both apply it.
pub fn validate_ladder(buckets: &[usize]) -> Result<Vec<usize>> {
    if buckets.is_empty() {
        bail!("bucket ladder must not be empty");
    }
    if buckets[0] == 0 {
        bail!("bucket sizes must be >= 1, got {buckets:?}");
    }
    for w in buckets.windows(2) {
        if w[0] >= w[1] {
            bail!("bucket ladder must be strictly ascending, got {buckets:?}");
        }
    }
    Ok(buckets.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::{plan_variant, Variant};

    fn forward_logits(variant: Variant) -> Vec<f32> {
        let engine = Engine::native();
        let arch = Arch::by_name("resnet-mini").unwrap();
        let plan = plan_variant(&arch, variant, 2.0, 2, None).unwrap();
        let net =
            BuiltNet::compile(&engine, &arch, &plan, 2, 16, 7, &CompileOptions::default())
                .unwrap();
        let x = crate::util::det_input(2, 16);
        let xb = engine.upload(&x, &[2, 3, 16, 16]).unwrap();
        let out = net.forward(&xb).unwrap();
        out.to_host().unwrap().data
    }

    #[test]
    fn builds_and_runs_all_variants() {
        for v in [
            Variant::Orig,
            Variant::Lrd,
            Variant::Merged,
            Variant::Branched,
            Variant::Tucker2,
            Variant::Cp,
        ] {
            let logits = forward_logits(v);
            assert_eq!(logits.len(), 2 * 10, "{v:?}");
            assert!(logits.iter().all(|x| x.is_finite()), "{v:?}: {logits:?}");
            // batch entries must differ (no accidental weight/input mixup)
            assert!(logits[..10] != logits[10..], "{v:?}");
        }
    }

    #[test]
    fn sparse_composed_net_builds_and_runs() {
        let engine = Engine::native();
        let arch = Arch::by_name("resnet-mini").unwrap();
        let plan = crate::decompose::plan_variant_with(
            &arch,
            Variant::Lrd,
            crate::decompose::SchemeFamily::Svd,
            2.0,
            2,
            None,
            Some(50_000),
        )
        .unwrap();
        let (_graph, specs) = build_forward(&arch, &plan, 1, 16).unwrap();
        // every wrapped site declares `.s` vals; the pattern is baked, so
        // `.s_idx` must never surface as a graph parameter
        assert!(specs.iter().any(|s| s.name.ends_with(".s")));
        assert!(specs.iter().all(|s| !s.name.ends_with(".s_idx")));
        assert!(specs.iter().any(|s| s.name == "fc.s"));
        let net =
            BuiltNet::compile(&engine, &arch, &plan, 2, 16, 7, &CompileOptions::default())
                .unwrap();
        let x = crate::util::det_input(2, 16);
        let xb = engine.upload(&x, &[2, 3, 16, 16]).unwrap();
        let out = net.forward(&xb).unwrap().to_host().unwrap().data;
        assert_eq!(out.len(), 20);
        assert!(out.iter().all(|v| v.is_finite()));
        assert!(out[..10] != out[10..]);
    }

    #[test]
    fn param_specs_unique_names() {
        let arch = Arch::by_name("resnet-mini").unwrap();
        let plan = plan_variant(&arch, Variant::Lrd, 2.0, 2, None).unwrap();
        let (_graph, specs) = build_forward(&arch, &plan, 1, 16).unwrap();
        let names: std::collections::HashSet<_> =
            specs.iter().map(|s| s.name.clone()).collect();
        assert_eq!(names.len(), specs.len());
        assert!(names.contains("layer1.0.conv2.core"));
        assert!(names.contains("fc.w0"));
    }

    #[test]
    fn pow2_ladder_shapes() {
        assert_eq!(pow2_ladder(8), vec![1, 2, 4, 8]);
        assert_eq!(pow2_ladder(6), vec![1, 2, 4, 6]);
        assert_eq!(pow2_ladder(1), vec![1]);
        assert_eq!(pow2_ladder(0), vec![1], "0 clamps to a 1-bucket ladder");
    }

    #[test]
    fn ladder_validation() {
        assert!(validate_ladder(&[]).is_err());
        assert!(validate_ladder(&[0, 2]).is_err());
        assert!(validate_ladder(&[2, 2]).is_err());
        assert!(validate_ladder(&[4, 2]).is_err());
        assert_eq!(validate_ladder(&[1, 2, 4]).unwrap(), vec![1, 2, 4]);
    }

    #[test]
    fn servable_net_lazy_cache_and_shared_weights() {
        let engine = Engine::native();
        let arch = Arch::by_name("resnet-mini").unwrap();
        let plan = plan_variant(&arch, Variant::Lrd, 2.0, 2, None).unwrap();
        let mut net = ServableNet::compile(
            &engine,
            &arch,
            &plan,
            &[1, 2, 4],
            16,
            7,
            &CompileOptions::default(),
        )
        .unwrap();
        let uploads = net.cache_stats().weight_uploads;
        assert!(uploads > 0);
        assert_eq!(net.cache_stats().compiles, 0, "compilation must be lazy");
        assert_eq!(net.bucket_for(3), Some(4));
        assert_eq!(net.bucket_for(5), None);

        let x1 = crate::util::det_input(1, 16);
        let l1 = net.run_bucket(&x1, 1).unwrap();
        assert_eq!(l1.len(), 10);
        let after_first = net.cache_stats();
        assert_eq!(after_first.compiles, 1);
        assert_eq!(after_first.weight_uploads, uploads);
        assert_eq!(after_first.compiled_buckets, vec![1]);
        // second hit on the same bucket: no recompile, bitwise-stable
        let l1b = net.run_bucket(&x1, 1).unwrap();
        assert_eq!(l1, l1b);
        assert_eq!(net.cache_stats().compiles, 1);

        let x4 = crate::util::det_input(4, 16);
        let l4 = net.run_bucket(&x4, 4).unwrap();
        assert_eq!(l4.len(), 40);
        let stats = net.cache_stats();
        assert_eq!(stats.compiles, 2);
        assert_eq!(stats.compiled_buckets, vec![1, 4]);
        assert_eq!(
            stats.weight_uploads, uploads,
            "every bucket must share the construction-time weight upload"
        );
        // wrong bucket / wrong length are build errors, not panics
        assert!(net.run_bucket(&x1, 3).is_err());
        assert!(net.run_bucket(&x1, 2).is_err());
    }

    #[test]
    fn forward_is_deterministic_across_engines() {
        // Two independently-constructed native engines must agree bit-wise
        // on the same (arch, plan, seed) — the property the coordinator's
        // per-worker engine construction relies on.
        let a = forward_logits(Variant::Lrd);
        let b = forward_logits(Variant::Lrd);
        assert_eq!(a, b);
    }
}
